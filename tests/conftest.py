"""Test fixtures.

NOTE on device count: collective-engine correctness tests fundamentally
need multiple ranks, so we use 8 virtual host devices here — NOT the 512
of the production dry-run (launch/dryrun.py is the only place that sets
512). Smoke tests run tiny configs on (1,1,1)/(2,2,2) sub-meshes of these
8, so they see effectively single-device workloads.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def mesh222():
    from repro.core.topology import make_mesh
    return make_mesh((2, 2, 2), ("pod", "data", "model"))


@pytest.fixture(scope="session")
def mesh111():
    from repro.core.topology import make_mesh
    return make_mesh((1, 1, 1), ("pod", "data", "model"))


@pytest.fixture(scope="session")
def mesh8():
    from repro.core.topology import make_mesh
    return make_mesh((8,), ("x",))


@pytest.fixture()
def rng():
    return np.random.default_rng(0)
