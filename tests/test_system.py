"""End-to-end behaviour: short training runs converge; engine backends
agree; the paper's two use cases produce correct results at small scale."""
import math

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs import get_config, reduced_config
from repro.configs.base import ParallelConfig
from repro.optim import adamw
from repro.parallel import stages


def test_training_memorizes_fixed_batch(mesh222, rng):
    cfg = reduced_config(get_config("qwen3-0.6b"))
    pcfg = ParallelConfig(backend="microcode", remat="none")
    ts = stages.build_train_step(cfg, pcfg, mesh222,
                                 adamw.AdamWConfig(lr=1e-2))
    params = stages.init_params(cfg, mesh222, ts.ctx.tp, seed=0)
    opt = adamw.adamw_init(params)
    opt = jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh222, s)),
        opt, ts.opt_specs)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)),
                              jnp.int32)}
    first = last = None
    for i in range(8):
        params, opt, m = ts.fn(params, opt, batch, jnp.int32(i))
        ce = float(m["ce_mean"])
        first = first if first is not None else ce
        last = ce
    assert last < first - 1.0, (first, last)
    assert math.isfinite(last)


def test_backends_agree_on_loss(mesh222, rng):
    cfg = reduced_config(get_config("smollm-360m"))
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)),
                              jnp.int32)}
    ces = {}
    for backend in ("microcode", "native"):
        pcfg = ParallelConfig(backend=backend, remat="none")
        ts = stages.build_train_step(cfg, pcfg, mesh222,
                                     adamw.AdamWConfig())
        params = stages.init_params(cfg, mesh222, ts.ctx.tp, seed=0)
        opt = adamw.adamw_init(params)
        opt = jax.tree.map(
            lambda x, s: jax.device_put(x, NamedSharding(mesh222, s)),
            opt, ts.opt_specs)
        _, _, m = ts.fn(params, opt, batch, jnp.int32(0))
        ces[backend] = float(m["ce_mean"])
    assert abs(ces["microcode"] - ces["native"]) < 1e-3, ces


def test_sequence_parallel_matches_baseline(mesh222, rng):
    cfg = reduced_config(get_config("qwen3-0.6b"))
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)),
                              jnp.int32)}
    ces = {}
    for sp in (False, True):
        pcfg = ParallelConfig(backend="microcode", remat="none",
                              sequence_parallel=sp)
        ts = stages.build_train_step(cfg, pcfg, mesh222,
                                     adamw.AdamWConfig())
        params = stages.init_params(cfg, mesh222, ts.ctx.tp, seed=0)
        opt = adamw.adamw_init(params)
        opt = jax.tree.map(
            lambda x, s: jax.device_put(x, NamedSharding(mesh222, s)),
            opt, ts.opt_specs)
        _, _, m = ts.fn(params, opt, batch, jnp.int32(0))
        ces[sp] = float(m["ce_mean"])
    assert abs(ces[True] - ces[False]) < 1e-3, ces


def test_grad_compression_trains(mesh222, rng):
    cfg = reduced_config(get_config("smollm-360m"))
    pcfg = ParallelConfig(backend="microcode", remat="none",
                          grad_compression="int8")
    ts = stages.build_train_step(cfg, pcfg, mesh222,
                                 adamw.AdamWConfig(lr=1e-2))
    params = stages.init_params(cfg, mesh222, ts.ctx.tp, seed=0)
    opt = adamw.adamw_init(params)
    opt = jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh222, s)),
        opt, ts.opt_specs)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)),
                              jnp.int32)}
    first = last = None
    for i in range(6):
        params, opt, m = ts.fn(params, opt, batch, jnp.int32(i))
        ce = float(m["ce_mean"])
        first = first if first is not None else ce
        last = ce
    assert math.isfinite(last) and last < first
