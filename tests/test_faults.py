"""Fault-tolerant transport (core/faults.py + sequencer/simulator
integration): deterministic fault plans, reliability tiers, typed
terminal states, abort cleanup (the PR 5 watch item), the alltoall
leading-dim clamp, degraded-communicator replanning, and the chaos
invariant — every request under every fault schedule ends bitwise-equal
to the fault-free run or in a typed terminal state, never a hang."""
import os

import numpy as np
import pytest

import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import (
    CollectiveEngine, Communicator, FaultPlan, Request, RequestCancelled,
    Selector, Sequencer, TIERS,
)
from repro.core.faults import (
    PeerFailedError, ReliabilityTier, TransportTimeout,
)
from repro.core.hw_spec import ACCL_CLUSTER
from repro.core.program import fit_segments
from tests._hypothesis_compat import given, settings, st


@pytest.fixture(scope="module")
def eng8(mesh8):
    return CollectiveEngine(mesh8, backend="microcode")


def _feeds(reqs, seed, n=8):
    """Deterministic per-rank integer-valued feeds for leaf requests
    (integer-valued so int8 sums are exact modulo wraparound and fp32
    sums are exact, making bitwise comparisons meaningful)."""
    rng = np.random.default_rng(seed)
    return {r: [rng.integers(-20, 20, size=r.operand.shape)
                .astype(r.dtype) for _ in range(n)]
            for r in reqs if not isinstance(r.operand, Request)}


# --------------------------------------------------------------------------
# Backoff / tier determinism (no wall-clock anywhere in the model)
# --------------------------------------------------------------------------

def test_backoff_schedule_deterministic():
    tier = TIERS["tcp-like"]
    sched = tier.backoff_schedule()
    assert sched == tier.backoff_schedule()  # pure function of the tier
    assert sched == (2e-6, 4e-6, 8e-6, 1.6e-5, 3.2e-5)
    assert tier.backoff(0) == 0.0
    # the cap binds eventually
    capped = ReliabilityTier("t", max_retries=30, backoff_base=1e-6,
                             backoff_cap=1e-4)
    assert capped.backoff_schedule()[-1] == 1e-4
    assert max(capped.backoff_schedule()) == 1e-4


def test_expected_transmissions_truncated_geometric():
    udp, tcp = TIERS["udp-like"], TIERS["tcp-like"]
    assert udp.expected_transmissions(0.0) == 1.0
    assert udp.expected_transmissions(0.7) == 1.0  # one shot, no retry
    assert tcp.expected_transmissions(0.0) == 1.0
    assert tcp.expected_transmissions(0.5) == pytest.approx(
        (1 - 0.5 ** 6) / 0.5)
    assert tcp.expected_backoff(0.0) == 0.0
    assert tcp.expected_backoff(0.5) > 0.0


def test_fault_plan_drop_decisions_order_independent():
    plan = FaultPlan(seed=7, drop_prob=0.3)
    coords = [(x, s, d, a) for x in range(4) for s in range(4)
              for d in range(4) for a in range(2)]
    fwd = [plan.drops_segment(*c) for c in coords]
    rev = [plan.drops_segment(*c) for c in reversed(coords)]
    assert fwd == list(reversed(rev))      # order-independent
    assert fwd == [FaultPlan(seed=7, drop_prob=0.3).drops_segment(*c)
                   for c in coords]        # plan-identity-independent
    assert any(fwd) and not all(fwd)
    # retries re-roll: some first-attempt drop succeeds on attempt 1
    assert any(plan.drops_segment(x, s, d, 0)
               and not plan.drops_segment(x, s, d, 1)
               for x in range(8) for s in range(4) for d in range(4))


def test_fault_plan_flaps_and_dead():
    plan = FaultPlan(flaps=((0, 1, 2, 5),), dead=((3, 4),))
    assert not plan.link_flapped(0, 1, 1)
    assert plan.link_flapped(0, 1, 2) and plan.link_flapped(0, 1, 4)
    assert not plan.link_flapped(0, 1, 5)      # end exclusive
    assert not plan.link_flapped(1, 0, 3)      # directional
    assert plan.dead_at(3) == frozenset()
    assert plan.dead_at(4) == {3} == plan.dead_at(9)


# --------------------------------------------------------------------------
# Typed terminal states in the simulated drain
# --------------------------------------------------------------------------

def test_tcp_tier_recovers_bitwise_from_explicit_drop(eng8):
    xs = [np.zeros((64,), np.float32) for _ in range(2)]
    ref_seq = Sequencer(eng8)
    ref = [ref_seq.issue("allreduce", x, "x", algorithm="ring") for x in xs]
    ref_out = ref_seq.simulate_drain(_feeds(ref, seed=11))

    seq = Sequencer(eng8)
    reqs = [seq.issue("allreduce", x, "x", algorithm="ring") for x in xs]
    # drop the first attempt of one segment; the tcp tier retransmits
    out = seq.simulate_drain(
        _feeds(reqs, seed=11),
        fault_plan=FaultPlan(drops=frozenset({(0, 0, 1), (3, 2, 3)})),
        tier=TIERS["tcp-like"])
    for r_ref, r in zip(ref, reqs):
        assert r.status == Request.DONE
        for a, b in zip(ref_out[r_ref], out[r]):
            np.testing.assert_array_equal(a, b)


def test_udp_tier_loss_is_typed_timeout_not_hang(eng8):
    seq = Sequencer(eng8)
    r = seq.issue("allreduce", np.zeros((64,), np.float32), "x",
                  algorithm="ring")
    seq.simulate_drain(_feeds([r], seed=0),
                       fault_plan=FaultPlan(drops=frozenset({(0, 0, 1)})),
                       tier=TIERS["udp-like"])
    assert r.status == Request.TIMED_OUT
    assert isinstance(r.error, TransportTimeout)
    with pytest.raises(TransportTimeout):
        r.wait()
    assert seq.outstanding() == []  # no hang, nothing stuck in the queue


def test_dead_rank_is_peer_failed_and_cascades_cancel(eng8):
    seq = Sequencer(eng8)
    r1 = seq.issue("allreduce", np.zeros((64,), np.float32), "x",
                   algorithm="ring")
    r2 = seq.issue("allreduce", r1, "x", algorithm="ring")  # depends on r1
    seq.simulate_drain(_feeds([r1], seed=1),
                       fault_plan=FaultPlan(dead=((2, 0),)),
                       tier=TIERS["tcp-like"])
    assert r1.status == Request.PEER_FAILED
    assert isinstance(r1.error, PeerFailedError) and r1.error.rank == 2
    assert r2.status == Request.CANCELLED
    with pytest.raises(RequestCancelled):
        r2.wait()
    assert seq.outstanding() == []


def test_virtual_timeout_deterministic_no_wallclock(eng8):
    # the virtual clock is the priced program cost: a deadline below it
    # times out, one above it succeeds — identical on every run, because
    # no wall-clock is consulted anywhere in the simulated path
    for _ in range(2):
        seq = Sequencer(eng8)
        fast = seq.issue("allreduce", np.zeros((64,), np.float32), "x",
                         algorithm="ring", timeout=1.0)
        slow = seq.issue("allreduce", np.zeros((64,), np.float32), "x",
                         algorithm="ring", timeout=1e-12)
        seq.simulate_drain(_feeds([fast, slow], seed=2))
        assert fast.status == Request.DONE
        assert slow.status == Request.TIMED_OUT
        assert isinstance(slow.error, TransportTimeout)


def test_cancel_request_and_dependents(eng8):
    seq = Sequencer(eng8)
    r1 = seq.issue("allreduce", np.zeros((8,), np.float32), "x")
    r2 = seq.issue("allreduce", r1, "x")
    r3 = seq.issue("allreduce", np.zeros((8,), np.float32), "x")
    r1.cancel()
    assert r1.status == Request.CANCELLED
    assert r2.status == Request.CANCELLED  # dataflow dependent cascades
    assert r3.status == Request.PENDING    # independent request untouched
    r1.cancel()                            # idempotent
    assert seq.outstanding() == [r3]


# --------------------------------------------------------------------------
# PR 5 watch item: abort provably empties engine.queue
# --------------------------------------------------------------------------

def test_abort_mid_drain_leaves_engine_queue_empty(eng8, rng):
    eng = eng8

    def traced(a, b):
        r1 = eng.iallreduce(a, "x", algorithm="ring")
        eng.iallreduce(b, "x", algorithm="ring")  # never waited
        out = r1.wait()
        dropped = eng.queue.abort()  # abandon the rest mid-drain
        assert len(dropped) == 1
        return out

    a = jnp.asarray(rng.standard_normal((8, 32)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((8, 32)).astype(np.float32))
    got = eng.run(traced, in_specs=(P("x"), P("x")), out_specs=P())(a, b)
    # the queue is empty: no request, no buffer-identity entry, hence no
    # stale TRACER can leak out of the abandoned trace
    assert eng.queue.outstanding() == []
    assert eng.queue._buffer_owner == {}
    # and the next collective (a fresh trace) is unaffected
    want = eng.run(lambda x: eng.allreduce(x, "x", algorithm="ring"),
                   in_specs=P("x"), out_specs=P())(a)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_context_manager_aborts_leftovers(eng8):
    with Sequencer(eng8) as seq:
        r1 = seq.issue("allreduce", np.zeros((16,), np.float32), "x")
        r2 = seq.issue("allreduce", r1, "x")
    assert r1.status == Request.CANCELLED
    assert r2.status == Request.CANCELLED
    assert seq.outstanding() == [] and seq._buffer_owner == {}
    with pytest.raises(RequestCancelled):
        r1.wait()


def test_context_manager_aborts_on_exception_mid_drain(eng8):
    with pytest.raises(RuntimeError, match="boom"):
        with Sequencer(eng8) as seq:
            seq.issue("allreduce", np.zeros((16,), np.float32), "x")
            raise RuntimeError("boom")
    assert seq.outstanding() == []


# --------------------------------------------------------------------------
# Graceful degradation: shrink the communicator, replan, continue
# --------------------------------------------------------------------------

def test_communicator_shrink_helpers():
    comm = Communicator(axis="x", size=8)
    assert comm.shrunk(7).size == 7
    assert comm.shrunk(7).axis == comm.axis
    assert comm.without_ranks({3}).size == 7
    assert comm.without_ranks({3, 5}).size == 6
    with pytest.raises(ValueError):
        comm.shrunk(0)
    with pytest.raises(ValueError):
        comm.without_ranks({11})
    # rank-id-aware remap: mid-mesh survivors keep their GLOBAL ids,
    # and repeated failures compose through the rank table
    assert comm.global_ranks == tuple(range(8))
    d = comm.without_ranks({3, 5})
    assert d.global_ranks == (0, 1, 2, 4, 6, 7)
    assert d.without_ranks({0}).global_ranks == (1, 2, 4, 6, 7)
    with pytest.raises(ValueError):
        d.without_ranks({6})  # local ids index the CURRENT group (0..5)


def test_dead_rank_shrinks_communicator_and_replans(eng8):
    """The dead-rank grad-sync scenario at queue level: the request in
    flight when the rank dies ends PEER_FAILED, the communicator shrinks
    to the 7 survivors, the selector replans the still-queued collectives
    on the degraded fabric, and they complete with survivor-exact sums."""
    xs = [np.zeros((64,), np.float32) for _ in range(3)]
    seq = Sequencer(eng8)
    reqs = [seq.issue("allreduce", x, "x", algorithm="ring") for x in xs]
    feeds = _feeds(reqs, seed=5)
    out = seq.simulate_drain(feeds, fault_plan=FaultPlan(dead=((3, 2),)),
                             tier=TIERS["tcp-like"], degrade=True)
    assert reqs[0].status == Request.PEER_FAILED
    survivors = [r for r in range(8) if r != 3]
    for req in reqs[1:]:
        assert req.status == Request.DONE
        per = out[req]
        assert len(per) == 7  # executed on the shrunk communicator
        want = np.sum([feeds[req][r] for r in survivors], axis=0)
        for got in per:
            np.testing.assert_allclose(got, want, rtol=1e-6)
    assert seq.outstanding() == []


# --------------------------------------------------------------------------
# Honest retransmission pricing
# --------------------------------------------------------------------------

def test_tier_pricing_neutral_by_default_and_monotone(eng8):
    comm = eng8.comm("x")
    sched = eng8._cached_schedule("allreduce", "ring", comm, 0, "add")
    prog = sched.compile()
    nbytes = 1 << 16
    base = prog.cost(nbytes, comm)
    assert prog.cost(nbytes, comm, tier=None) == base  # bitwise-neutral
    assert prog.cost(nbytes, comm, tier=TIERS["tcp-like"],
                     drop_prob=0.0) == base            # lossless: no charge
    lossy = prog.cost(nbytes, comm, tier=TIERS["tcp-like"], drop_prob=0.2)
    lossier = prog.cost(nbytes, comm, tier=TIERS["tcp-like"], drop_prob=0.5)
    assert base < lossy < lossier
    lat, wire = prog.cost_terms(nbytes, comm, tier=TIERS["tcp-like"],
                                drop_prob=0.2)
    assert lat + wire == pytest.approx(lossy)


def test_makespan_reflects_reliability_tier(eng8):
    seq = Sequencer(eng8)
    for _ in range(4):
        seq.issue("allreduce", np.zeros((1024,), np.float32), "x",
                  algorithm="ring")
    base = seq.makespan("x")
    priced = seq.makespan("x", tier=TIERS["tcp-like"], drop_prob=0.1)
    assert priced > base
    assert seq.makespan("x", tier=TIERS["udp-like"], drop_prob=0.1) >= base
    seq.clear()


# --------------------------------------------------------------------------
# alltoall leading-dim clamp (carried caveat, now closed)
# --------------------------------------------------------------------------

def test_alltoall_prime_leading_dim_prices_executable_segments():
    """Leading dim 12 over 4 ranks = 3 rows/chunk (prime). The flat
    element grid admits pow2 segment counts the ROW grid cannot execute;
    with `lead_dim` the selector's priced k equals the executor's
    clamped k by construction."""
    comm = Communicator(axis="x", size=4, hw=ACCL_CLUSTER)
    sel = Selector()
    lead, row = 12, 16384
    nbytes = lead * row * 4
    flat_pick = sel.choose("alltoall", nbytes, comm)
    row_pick = sel.choose("alltoall", nbytes, comm, lead_dim=lead)
    rows_per_chunk = lead // comm.size
    # the regression this guards: the flat-grid pick is NOT executable
    # on the row grid (it silently clamped below the priced count)
    assert fit_segments(rows_per_chunk, flat_pick.segments,
                        row) != flat_pick.segments
    assert fit_segments(rows_per_chunk, row_pick.segments,
                        row) == row_pick.segments
    assert row_pick.segments > 1  # not vacuous: segmentation still won


def test_alltoall_prime_leading_dim_engine_parity(eng8, rng):
    """End-to-end through the engine on an indivisible leading dim: the
    auto-selected (row-clamped) segment count executes correctly."""
    eng = eng8
    n = 8
    lead, width = 24, 4096  # 3 rows per chunk locally — prime
    data = rng.integers(-30, 30, size=(n * lead, width)).astype(np.float32)
    got = eng.run(lambda x: eng.alltoall(x, "x"),
                  in_specs=P("x"), out_specs=P("x"))(jnp.asarray(data))
    got = np.asarray(got)
    shards = [data[r * lead:(r + 1) * lead] for r in range(n)]
    csize = lead // n
    want = np.concatenate([
        np.concatenate([shards[j][r * csize:(r + 1) * csize]
                        for j in range(n)], axis=0)
        for r in range(n)], axis=0)
    np.testing.assert_array_equal(got, want)
    # the priced choice is executable as-is on the row grid
    comm = eng.comm("x")
    choice = eng.selector.choose(
        "alltoall", lead * width * 4, comm, elem_bytes=4, lead_dim=lead)
    assert fit_segments(lead // n, choice.segments,
                        width) == choice.segments


# --------------------------------------------------------------------------
# The chaos property: bitwise-or-typed-failure, never a hang
# --------------------------------------------------------------------------

_CHAOS_CASES = [
    ("allreduce", "ring"),               # ring
    ("allreduce", "recursive_doubling"), # hypercube
    ("bcast", "binomial_tree"),          # tree
]


@settings(max_examples=24, deadline=None)
@given(data=st.data())
def test_chaos_bitwise_or_typed_failure(eng8, data):
    """For every generated fault schedule, every request either
    materializes bitwise-identical to the fault-free drain (retries
    recovered) or terminates in a typed failure state — zero hangs,
    zero silent corruption."""
    # the CI chaos lane shifts every drawn seed by CHAOS_SEED so each
    # matrix entry exercises a different deterministic schedule family
    seed = data.draw(st.integers(min_value=0, max_value=10_000)) \
        + 20_000 * int(os.environ.get("CHAOS_SEED", "0"))
    drop_prob = data.draw(st.sampled_from([0.0, 0.05, 0.3, 0.9]))
    tier = TIERS[data.draw(st.sampled_from(list(TIERS)))]
    dtype = data.draw(st.sampled_from([np.float32, np.int8]))
    collective, algorithm = data.draw(st.sampled_from(_CHAOS_CASES))
    dead = data.draw(st.sampled_from([(), ((1, 3),), ((6, 0),)]))
    plan = FaultPlan(seed=seed, drop_prob=drop_prob, dead=dead)

    def build(seq):
        kw = {"root": 1} if collective == "bcast" else {}
        reqs = [seq.issue(collective, np.zeros((32,), dtype), "x",
                          algorithm=algorithm, **kw)
                for _ in range(3)]
        # one dependent request so failure cascades are exercised
        reqs.append(seq.issue("allreduce", reqs[0], "x",
                              algorithm="ring"))
        return reqs

    ref_seq = Sequencer(eng8)
    ref_reqs = build(ref_seq)
    ref_out = ref_seq.simulate_drain(_feeds(ref_reqs, seed=seed))

    seq = Sequencer(eng8)
    reqs = build(seq)
    feeds = {r: ref_feed for r, (_rr, ref_feed) in zip(
        [r for r in reqs if not isinstance(r.operand, Request)],
        _feeds(ref_reqs, seed=seed).items())}
    out = seq.simulate_drain(feeds, fault_plan=plan, tier=tier)

    assert seq.outstanding() == []  # the drain returned and is empty
    for r_ref, r in zip(ref_reqs, reqs):
        assert r.finished, "no request may be left in limbo"
        if r.status == Request.DONE:
            for a, b in zip(ref_out[r_ref], out[r]):
                np.testing.assert_array_equal(a, b)
        else:
            assert r.status in (Request.TIMED_OUT, Request.CANCELLED,
                                Request.PEER_FAILED)
            with pytest.raises(Exception):
                r.wait()
