"""Golden references: the pre-IR hand-written loop lowerings, verbatim.

These are the five per-algorithm data-plane lowerings that lived in
`core/engine.py` before every collective was unified behind the micro-op
`execute_program` path. They are kept here — NOT in the engine — purely as
bitwise oracles: test_golden_parity.py asserts the compiled-IR execution
reproduces their outputs exactly. Do not "fix" or modernize this file; its
value is that it does not change.
"""

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import plugins
from repro.core.topology import Communicator


def _maybe_codec(compression):
    return plugins.get_codec(compression) if compression else None


def _fit_segments(seg_len: int, segments) -> int:
    k = max(1, int(segments or 1))
    k = min(k, max(1, seg_len))
    while k > 1 and seg_len % k:
        k -= 1
    return k


def _ring_send(payload, axis, comm, codec, use_pallas, shape_dtype, shift=1):
    if codec is None:
        return lax.ppermute(payload, axis, comm.ring_perm(shift))
    wire = codec.compress(payload, use_pallas=use_pallas)
    wire = jax.tree.map(lambda l: lax.ppermute(l, axis, comm.ring_perm(shift)),
                        wire)
    return codec.decompress(wire, payload.shape, shape_dtype,
                            use_pallas=use_pallas)


def _pipelined_exchange(payload, send, consume, segments: int):
    k = int(segments)
    if k <= 1:
        return consume(0, send(payload))
    pay = payload.reshape((k, payload.shape[0] // k) + payload.shape[1:])
    inflight = send(pay[0])

    def seg_body(carry, i):
        nxt = send(pay[i + 1])
        out = consume(i, carry)
        return nxt, out

    last, outs = lax.scan(seg_body, inflight, jnp.arange(k - 1))
    tail = consume(k - 1, last)
    flat = jnp.concatenate(
        [outs.reshape((-1,) + outs.shape[2:]), tail], axis=0)
    return flat


def ring_reduce_scatter_loop(x2d, axis, comm: Communicator, op="add",
                             compression=None, use_pallas=False,
                             segments: int = 1):
    """x2d: (n, csize); returns rank's fully-reduced row (csize,)."""
    n = comm.size
    rank = lax.axis_index(axis)
    codec = _maybe_codec(compression)
    segs = _fit_segments(x2d.shape[1], segments)

    def body(buf, s):
        send_idx = (rank - s - 1) % n
        recv_idx = (rank - s - 2) % n
        payload = buf[send_idx]
        tgt = buf[recv_idx].reshape((segs, -1) + buf.shape[2:])

        def send(seg):
            return _ring_send(seg, axis, comm, codec, use_pallas, buf.dtype)

        def consume(i, incoming):
            return plugins.combine(op, tgt[i], incoming.astype(buf.dtype),
                                   use_pallas=use_pallas)

        new_val = _pipelined_exchange(payload, send, consume, segs)
        buf = lax.dynamic_update_index_in_dim(
            buf, new_val.reshape(buf.shape[1:]), recv_idx, 0)
        return buf, None

    buf, _ = lax.scan(body, x2d, jnp.arange(n - 1))
    return buf[rank]


def ring_allgather_loop(shard, axis, comm: Communicator, segments: int = 1):
    """shard: (csize, ...); returns (n, csize, ...) rows in rank order."""
    n = comm.size
    rank = lax.axis_index(axis)
    buf = jnp.zeros((n,) + shard.shape, shard.dtype)
    buf = lax.dynamic_update_index_in_dim(buf, shard, rank, 0)
    segs = _fit_segments(shard.shape[0] if shard.ndim else 1, segments)

    def body(buf, s):
        send_idx = (rank - s) % n
        recv_idx = (rank - s - 1) % n

        def send(seg):
            return lax.ppermute(seg, axis, comm.ring_perm(1))

        incoming = _pipelined_exchange(buf[send_idx], send,
                                       lambda i, seg: seg, segs)
        buf = lax.dynamic_update_index_in_dim(
            buf, incoming.reshape(buf.shape[1:]), recv_idx, 0)
        return buf, None

    buf, _ = lax.scan(body, buf, jnp.arange(n - 1))
    return buf


def ring_allreduce_loop(x2d, axis, comm: Communicator, op="add",
                        compression=None, use_pallas=False,
                        segments: int = 1):
    """x2d: (n, csize) -> (n, csize) fully reduced (RS loop + AG loop)."""
    shard = ring_reduce_scatter_loop(x2d, axis, comm, op, compression,
                                     use_pallas, segments=segments)
    return ring_allgather_loop(shard, axis, comm, segments=1)


def bidi_ring_allreduce_loop(x2d, axis, comm: Communicator, op="add",
                             compression=None, use_pallas=False,
                             segments: int = 1):
    """x2d: (2n, csize): rows [0,n) ride the +1 ring, [n,2n) the -1 ring."""
    n = comm.size
    rank = lax.axis_index(axis)
    codec = _maybe_codec(compression)
    segs = _fit_segments(x2d.shape[1], segments)

    def _dir_new_row(buf, send_idx, recv_idx, shift, combine_op):
        k = segs if combine_op is not None else 1
        payload = buf[send_idx]
        tgt = buf[recv_idx].reshape((k, -1) + buf.shape[2:])
        cdc = codec if combine_op is not None else None

        def send(seg):
            return _ring_send(seg, axis, comm, cdc, use_pallas, buf.dtype,
                              shift=shift)

        def consume(i, incoming):
            inc = incoming.astype(buf.dtype)
            if combine_op is None:
                return inc
            return plugins.combine(combine_op, tgt[i], inc,
                                   use_pallas=use_pallas)

        new_val = _pipelined_exchange(payload, send, consume, k)
        return new_val.reshape(buf.shape[1:])

    def rs_body(buf, s):
        cw_send, cw_recv = (rank - s - 1) % n, (rank - s - 2) % n
        ccw_send, ccw_recv = n + (rank + s + 1) % n, n + (rank + s + 2) % n
        new_c = _dir_new_row(buf, cw_send, cw_recv, 1, op)
        new_w = _dir_new_row(buf, ccw_send, ccw_recv, -1, op)
        buf = lax.dynamic_update_index_in_dim(buf, new_c, cw_recv, 0)
        buf = lax.dynamic_update_index_in_dim(buf, new_w, ccw_recv, 0)
        return buf, None

    def ag_body(buf, s):
        cw_send, cw_recv = (rank - s) % n, (rank - s - 1) % n
        ccw_send, ccw_recv = n + (rank + s) % n, n + (rank + s + 1) % n
        new_c = _dir_new_row(buf, cw_send, cw_recv, 1, None)
        new_w = _dir_new_row(buf, ccw_send, ccw_recv, -1, None)
        buf = lax.dynamic_update_index_in_dim(buf, new_c, cw_recv, 0)
        buf = lax.dynamic_update_index_in_dim(buf, new_w, ccw_recv, 0)
        return buf, None

    buf, _ = lax.scan(rs_body, x2d, jnp.arange(n - 1))
    buf, _ = lax.scan(ag_body, buf, jnp.arange(n - 1))
    return buf


def linear_alltoall_collect(x2d, axis, comm: Communicator):
    """x2d: (n, csize): row j -> rank j."""
    n = comm.size
    rank = lax.axis_index(axis)
    received = []
    for s in range(1, n):
        payload = x2d[(rank + s) % n]
        received.append(lax.ppermute(payload, axis, comm.ring_perm(s)))
    stacked = jnp.stack([x2d[rank]] + received)   # slot s = from rank r-s
    src_slot = (rank - jnp.arange(n)) % n         # out[j] = from rank j
    return jnp.take(stacked, src_slot, axis=0)
