"""Segmented, double-buffered collective lowerings (ACCL+ §4.4.3).

Parity: every segmented lowering must be numerics-identical to the
unsegmented one (segments cut elementwise combines into disjoint pieces,
so uncompressed paths are bitwise-equal). Model: the pipelined alpha-beta
prediction must strictly dominate the 1-segment baseline for large
messages.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import CollectiveEngine, Communicator, Selector
from repro.core import algorithms as A
from repro.core.engine import _fit_segments


@pytest.fixture(scope="module")
def eng8():
    from repro.core.topology import make_mesh
    mesh = make_mesh((8,), ("x",))
    return CollectiveEngine(mesh, backend="microcode"), mesh


def run(mesh, fn, x, in_spec=P("x"), out_spec=P("x")):
    g = jax.jit(jax.shard_map(fn, mesh=mesh, in_specs=in_spec,
                              out_specs=out_spec, check_vma=False))
    return np.asarray(g(jnp.asarray(x)))


X = np.random.default_rng(7).normal(size=(8, 32, 4)).astype(np.float32)


# -- data-plane parity across segment counts ---------------------------------

@pytest.mark.parametrize("algo", ["ring", "bidi_ring"])
@pytest.mark.parametrize("segments", [2, 3, 4, 8])
def test_allreduce_ring_segment_parity(eng8, algo, segments):
    eng, mesh = eng8
    base = run(mesh, lambda xs: eng.allreduce(
        xs[0], "x", algorithm=algo, segments=1)[None], X)
    seg = run(mesh, lambda xs: eng.allreduce(
        xs[0], "x", algorithm=algo, segments=segments)[None], X)
    np.testing.assert_array_equal(seg, base)
    for r in range(8):
        np.testing.assert_allclose(seg[r], X.sum(0), atol=1e-4)


@pytest.mark.parametrize("algo", ["recursive_doubling", "halving_doubling"])
@pytest.mark.parametrize("segments", [2, 4])
def test_allreduce_interpreted_segment_parity(eng8, algo, segments):
    """Hypercube schedules run through the segmented interpreter path."""
    eng, mesh = eng8
    base = run(mesh, lambda xs: eng.allreduce(
        xs[0], "x", algorithm=algo, segments=1)[None], X)
    seg = run(mesh, lambda xs: eng.allreduce(
        xs[0], "x", algorithm=algo, segments=segments)[None], X)
    np.testing.assert_allclose(seg, base, atol=1e-5)
    for r in range(8):
        np.testing.assert_allclose(seg[r], X.sum(0), atol=1e-4)


@pytest.mark.parametrize("segments", [2, 3, 4])
def test_reduce_scatter_segment_parity(eng8, segments):
    eng, mesh = eng8
    flat = X.reshape(8, -1)
    cs = flat.shape[1] // 8
    base = run(mesh, lambda xs: eng.reduce_scatter(
        xs[0], "x", algorithm="ring", segments=1)[None], X)
    seg = run(mesh, lambda xs: eng.reduce_scatter(
        xs[0], "x", algorithm="ring", segments=segments)[None], X)
    np.testing.assert_array_equal(seg, base)
    for r in range(8):
        np.testing.assert_allclose(seg[r], flat.sum(0)[r * cs:(r + 1) * cs],
                                   atol=1e-4)


@pytest.mark.parametrize("segments", [2, 4, 8])
def test_allgather_segment_parity(eng8, segments):
    eng, mesh = eng8
    base = run(mesh, lambda xs: eng.allgather(
        xs[0], "x", algorithm="ring", segments=1)[None], X)
    seg = run(mesh, lambda xs: eng.allgather(
        xs[0], "x", algorithm="ring", segments=segments)[None], X)
    np.testing.assert_array_equal(seg, base)
    np.testing.assert_allclose(seg[0], X.reshape(-1))


@pytest.mark.parametrize("op", ["max", "min", "mul"])
def test_segmented_nonadd_ops(eng8, op):
    eng, mesh = eng8
    Xp = np.abs(X) + 0.5  # keep mul well-conditioned
    base = run(mesh, lambda xs: eng.allreduce(
        xs[0], "x", op=op, algorithm="ring", segments=1)[None], Xp)
    seg = run(mesh, lambda xs: eng.allreduce(
        xs[0], "x", op=op, algorithm="ring", segments=4)[None], Xp)
    np.testing.assert_array_equal(seg, base)


def test_compressed_auto_allreduce_scale_reuse_parity(eng8):
    """The selector prices compressed-segmented variants (codec-aware
    choose) and the data plane guarantees per-segment scale reuse: the
    executor only admits segment sizes that are whole codec scale blocks,
    so the auto-segmented compressed wire is BITWISE-identical to the
    unsegmented codec — auto == explicit (same algorithm, segments=1)."""
    eng, mesh = eng8
    # 4 MiB: large enough that the codec-aware auto pick segments a
    # STREAMED algorithm under the split model (smaller compressed
    # messages now honestly prefer the unsegmented hypercube)
    big = np.random.default_rng(9).normal(
        size=(8, 1 << 20)).astype(np.float32)
    nbytes = big[0].nbytes
    ch = eng.selector.choose("allreduce", nbytes, eng.comm("x"),
                             codec="int8")
    assert ch.segments > 1  # the codec-aware auto pick segments this size
    assert ch.compressed and ch.codec == "int8"

    def call(algorithm, segments):
        g = jax.jit(jax.shard_map(
            lambda v: eng.allreduce(v, "x", algorithm=algorithm,
                                    compression="int8", segments=segments),
            mesh=mesh, in_specs=P("x"), out_specs=P("x"), check_vma=False))
        return np.asarray(g(jnp.asarray(big)))

    auto = call("auto", None)
    k1 = call(ch.algorithm, 1)
    np.testing.assert_array_equal(auto, k1)


def test_segmented_compressed_allreduce(eng8):
    """Codec paths stay within quantization tolerance when segmented."""
    eng, mesh = eng8
    out = run(mesh, lambda xs: eng.allreduce(
        xs[0] * 40, "x", algorithm="ring", compression="int8",
        segments=4)[None], X)
    ref = X.sum(0) * 40
    rel = np.abs(out[0] - ref).max() / np.abs(ref).max()
    assert rel < 0.02


# -- grad-path parity ---------------------------------------------------------

@pytest.mark.parametrize("algo", ["ring", "bidi_ring"])
def test_allreduce_grad_segment_parity(eng8, algo):
    eng, mesh = eng8

    def make_loss(segments):
        def loss(v):
            y = eng.allreduce(v, "x", algorithm=algo, segments=segments)
            return (y ** 3).sum()
        return loss

    grads = {}
    for segments in (1, 4):
        g = jax.jit(jax.shard_map(
            jax.grad(make_loss(segments)), mesh=mesh, in_specs=P("x"),
            out_specs=P("x"), check_vma=False))
        grads[segments] = np.asarray(g(jnp.asarray(X.reshape(8, -1))))
    np.testing.assert_allclose(grads[4], grads[1], atol=1e-5)


def test_allgather_grad_segment_parity(eng8):
    eng, mesh = eng8

    def make_loss(segments):
        def loss(v):
            y = eng.allgather(v, "x", algorithm="ring", segments=segments)
            return (y ** 2).sum()
        return loss

    grads = {}
    for segments in (1, 3):
        g = jax.jit(jax.shard_map(
            jax.grad(make_loss(segments)), mesh=mesh, in_specs=P("x"),
            out_specs=P("x"), check_vma=False))
        grads[segments] = np.asarray(g(jnp.asarray(X.reshape(8, -1))))
    np.testing.assert_allclose(grads[3], grads[1], atol=1e-5)


# -- streaming fusions --------------------------------------------------------

def test_allgather_matmul_segmented(eng8, rng):
    eng, mesh = eng8
    x = rng.normal(size=(8 * 4, 3)).astype(np.float32)
    w = rng.normal(size=(3, 5)).astype(np.float32)
    outs = {}
    for segments in (1, 2, 4):
        g = jax.jit(jax.shard_map(
            lambda a, b, s=segments: eng.allgather_matmul(a, b, "x",
                                                          segments=s),
            mesh=mesh, in_specs=(P("x"), P()), out_specs=P(),
            check_vma=False))
        outs[segments] = np.asarray(g(jnp.asarray(x), jnp.asarray(w)))
    np.testing.assert_allclose(outs[1], x @ w, atol=1e-4)
    np.testing.assert_array_equal(outs[2], outs[1])
    np.testing.assert_array_equal(outs[4], outs[1])


def test_matmul_reduce_scatter_segmented(eng8, rng):
    eng, mesh = eng8
    x = rng.normal(size=(16, 8 * 4)).astype(np.float32)
    w = rng.normal(size=(8 * 4, 6)).astype(np.float32)
    outs = {}
    for segments in (1, 2):
        g = jax.jit(jax.shard_map(
            lambda a, b, s=segments: eng.matmul_reduce_scatter(a, b, "x",
                                                               segments=s),
            mesh=mesh, in_specs=(P(None, "x"), P("x")), out_specs=P("x"),
            check_vma=False))
        outs[segments] = np.asarray(g(jnp.asarray(x), jnp.asarray(w)))
    np.testing.assert_allclose(outs[1], x @ w, atol=1e-4)
    np.testing.assert_array_equal(outs[2], outs[1])


@pytest.mark.parametrize("causal", [True, False])
def test_ring_attention_segmented(eng8, rng, causal):
    eng, mesh = eng8
    B, S, H, KV, hd = 2, 64, 4, 2, 16
    q = rng.normal(size=(B, S, H, hd)).astype(np.float32)
    k = rng.normal(size=(B, S, KV, hd)).astype(np.float32)
    v = rng.normal(size=(B, S, KV, hd)).astype(np.float32)
    outs = {}
    for segments in (1, 2):
        g = jax.jit(jax.shard_map(
            lambda a, b, c, s=segments: eng.ring_attention(
                a, b, c, "x", causal=causal, segments=s),
            mesh=mesh,
            in_specs=(P(None, "x"), P(None, "x"), P(None, "x")),
            out_specs=P(None, "x"), check_vma=False))
        outs[segments] = np.asarray(
            g(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v)))
    # online softmax is exact under any block split — only rounding differs
    np.testing.assert_allclose(outs[2], outs[1], atol=2e-5)


# -- tree_allreduce buckets ---------------------------------------------------

def test_tree_allreduce_dtype_buckets_no_upcast(eng8, rng):
    """bf16 leaves must ride the wire in bf16 (dtype-grouped buckets)."""
    eng, mesh = eng8
    trees = [{"a": rng.normal(size=(4, 3)).astype(np.float32),
              "b": rng.normal(size=(8,)).astype(np.float32),
              "c": (rng.normal(size=(6,)) / 8).astype(jnp.bfloat16)}
             for _ in range(8)]
    stacked = {k: np.stack([np.asarray(t[k], np.float32) for t in trees])
               for k in trees[0]}
    eng.trace_log.clear()
    g = jax.jit(jax.shard_map(
        lambda t: jax.tree.map(
            lambda l: l[None],
            eng.tree_allreduce(jax.tree.map(lambda a: a[0], t), ("x",))),
        mesh=mesh, in_specs=P("x"), out_specs=P("x"), check_vma=False))
    out = g({k: jnp.asarray(v, (jnp.bfloat16 if k == "c" else jnp.float32))
             for k, v in stacked.items()})
    assert np.asarray(out["c"]).dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out["a"])[0],
                               stacked["a"].sum(0), atol=1e-4)
    np.testing.assert_allclose(np.asarray(out["c"], np.float32)[0],
                               stacked["c"].sum(0), rtol=0.05, atol=0.05)
    # fp32 and bf16 leaves must not share a fused buffer: the engine issued
    # at least two collectives (one per dtype bucket)
    assert len(eng.trace_log) >= 2


def test_tree_allreduce_size_cap_splits_buckets(eng8, rng):
    eng, mesh = eng8
    trees = [[rng.normal(size=(256,)).astype(np.float32) for _ in range(4)]
             for _ in range(8)]
    stacked = [np.stack([t[i] for t in trees]) for i in range(4)]
    eng.trace_log.clear()
    g = jax.jit(jax.shard_map(
        lambda t: jax.tree.map(
            lambda l: l[None],
            eng.tree_allreduce(jax.tree.map(lambda a: a[0], t), ("x",),
                               bucket_bytes=2 * 256 * 4)),
        mesh=mesh, in_specs=P("x"), out_specs=P("x"), check_vma=False))
    out = g([jnp.asarray(s) for s in stacked])
    for i in range(4):
        np.testing.assert_allclose(np.asarray(out[i])[0],
                                   stacked[i].sum(0), atol=1e-4)
    # 4 leaves x 1 KiB with a 2 KiB cap -> 2 buckets -> 2 collectives
    assert len(eng.trace_log) == 2


# -- the pipelined alpha-beta model -------------------------------------------

def test_fit_segments_divisor_clamp():
    assert _fit_segments(24, 8) == 8
    assert _fit_segments(24, 16) == 12  # largest divisor <= 16
    assert _fit_segments(6, 4) == 3
    assert _fit_segments(7, 4) == 1
    assert _fit_segments(5, 1) == 1
    assert _fit_segments(0, 4) == 1


@pytest.mark.parametrize("nbytes", [1 << 20, 16 << 20, 256 << 20])
def test_pipelining_dominates_unsegmented_at_1mib(nbytes):
    """Acceptance: for >= 1 MiB some k > 1 strictly beats k = 1 (priced
    on the compiled, stream-fused programs — `Program.cost`)."""
    comm = Communicator(axis="x", size=8)
    for gen in (A.ring_allreduce, A.ring_reduce_scatter, A.ring_allgather):
        sched = gen(comm)
        t1 = sched.compile(segments=1).cost(nbytes, comm)
        best = min(sched.compile(segments=k).cost(nbytes, comm)
                   for k in (2, 4, 8, 16, 32))
        assert best < t1, (gen.__name__, nbytes)


def test_program_cost_segment_model_shape():
    """(S + k - 1) * t_seg for a homogeneous ring stream; k=1 reduces to
    the legacy per-step sum. The model moved onto the compiled program
    (`Program.cost`) but its shape is unchanged — the golden parity test
    in test_program_cost.py pins the full surface."""
    comm = Communicator(axis="x", size=8)
    sched = A.ring_reduce_scatter(comm)
    S = sched.n_steps()
    B, alpha, bw = 8 << 20, comm.hop_latency, comm.link_bw
    legacy = sum(alpha + B * s.bytes_frac / bw for s in sched.steps)
    assert sched.compile(segments=1).cost(B, comm) == pytest.approx(legacy)
    k = 4
    t_seg = alpha + (B / 8) / (k * bw)
    assert sched.compile(segments=k).cost(B, comm) == pytest.approx(
        (S + k - 1) * t_seg)
    with pytest.raises(ValueError):
        sched.compile(segments=0)


def test_unstreamable_copy_collectives_never_auto_segment():
    """bcast trees mask receivers — no cross-step stream, so
    segmentation would only add per-segment alpha and the selector must
    not pick it. Ring allgather STREAMS and linear all-to-all CHAINS
    (immutable relay='original' payloads), so both may auto-segment
    (see test_stream_fusion); tuning can still pin any count."""
    sel = Selector()
    comm = Communicator(axis="x", size=8)
    c = sel.choose("bcast", 64 << 20, comm)
    assert c.segments == 1, c
    assert sel.choose("alltoall", 64 << 20, comm).segments > 1
    assert sel.choose("allgather", 64 << 20, comm).segments > 1
    sel.set_tuning("allgather", "ring", segments=4)
    assert sel.choose("allgather", 64 << 20, comm).segments == 4


def test_selector_picks_segments_for_large_messages():
    sel = Selector()
    comm = Communicator(axis="x", size=8)
    big = sel.choose("allreduce", 64 << 20, comm)
    assert big.segments > 1
    assert big.schedule.segments == big.segments
    small = sel.choose("allreduce", 1024, comm)
    assert small.segments == 1  # below the Rx-buffer floor
