"""Schedule generators vs numpy oracles in the rank simulator, plus
hypothesis property tests on schedule structure."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import algorithms as A
from repro.core.simulator import oracle, simulate
from repro.core.topology import Communicator


def _inputs(rng, n, chunks, width=3):
    return [rng.normal(size=(chunks * 2, width)).astype(np.float32)
            for _ in range(n)]


@pytest.mark.parametrize("n", [2, 3, 4, 5, 8, 16])
def test_ring_allreduce(rng, n):
    comm = Communicator(axis="x", size=n)
    xs = _inputs(rng, n, n)
    out = simulate(A.ring_allreduce(comm), xs)
    ref = oracle("allreduce", xs)
    for r in range(n):
        np.testing.assert_allclose(out[r], ref, atol=1e-4)


@pytest.mark.parametrize("n", [2, 4, 8, 16])
def test_bidi_ring_allreduce(rng, n):
    comm = Communicator(axis="x", size=n)
    xs = _inputs(rng, n, 2 * n)
    out = simulate(A.bidi_ring_allreduce(comm), xs)
    ref = oracle("allreduce", xs)
    for r in range(n):
        np.testing.assert_allclose(out[r], ref, atol=1e-4)


@pytest.mark.parametrize("n", [2, 4, 8])
@pytest.mark.parametrize("gen,coll", [
    (A.recursive_doubling_allreduce, "allreduce"),
    (A.halving_doubling_allreduce, "allreduce"),
])
def test_hypercube_allreduce(rng, n, gen, coll):
    comm = Communicator(axis="x", size=n)
    xs = _inputs(rng, n, n)
    out = simulate(gen(comm), xs)
    ref = oracle(coll, xs)
    for r in range(n):
        np.testing.assert_allclose(out[r], ref, atol=1e-4)


@pytest.mark.parametrize("n", [2, 4, 8])
def test_recursive_halving_rs(rng, n):
    comm = Communicator(axis="x", size=n)
    xs = _inputs(rng, n, n)
    sched = A.recursive_halving_reduce_scatter(comm)
    out = simulate(sched, xs)
    ref = oracle("reduce_scatter", xs)
    c = xs[0].shape[0] // n
    for r in range(n):
        np.testing.assert_allclose(out[r][r * c:(r + 1) * c],
                                   ref[r * c:(r + 1) * c], atol=1e-4)


@pytest.mark.parametrize("n", [2, 3, 5, 8])
@pytest.mark.parametrize("root", [0, 1])
@pytest.mark.parametrize("gen", [A.binomial_tree_bcast, A.one_to_all_bcast])
def test_bcast(rng, n, root, gen):
    if root >= n:
        pytest.skip("root out of range")
    comm = Communicator(axis="x", size=n)
    xs = _inputs(rng, n, 1)
    out = simulate(gen(comm, root=root), xs)
    for r in range(n):
        np.testing.assert_allclose(out[r], xs[root])


@pytest.mark.parametrize("n", [2, 3, 5, 8])
@pytest.mark.parametrize("gen", [A.ring_reduce, A.all_to_one_reduce,
                                 A.binomial_tree_reduce])
def test_reduce_root(rng, n, gen):
    comm = Communicator(axis="x", size=n)
    xs = _inputs(rng, n, 1)
    out = simulate(gen(comm, root=0), xs)
    np.testing.assert_allclose(out[0], oracle("allreduce", xs), atol=1e-4)


@pytest.mark.parametrize("n", [2, 4, 8])
@pytest.mark.parametrize("gen", [A.ring_gather, A.all_to_one_gather,
                                 A.binomial_tree_gather])
def test_gather_root(rng, n, gen):
    comm = Communicator(axis="x", size=n)
    data = [rng.normal(size=(2, 3)).astype(np.float32) for _ in range(n)]
    ins = []
    for r in range(n):
        buf = np.zeros((n * 2, 3), np.float32)
        buf[r * 2:(r + 1) * 2] = data[r]
        ins.append(buf)
    out = simulate(gen(comm, root=0), ins)
    np.testing.assert_allclose(out[0], np.concatenate(data, 0))


@pytest.mark.parametrize("n", [2, 3, 4, 8])
@pytest.mark.parametrize("gen", [A.linear_alltoall, A.bruck_alltoall])
def test_alltoall(rng, n, gen):
    if gen is A.bruck_alltoall and n & (n - 1):
        pytest.skip("bruck needs pow2")
    comm = Communicator(axis="x", size=n)
    xs = _inputs(rng, n, n)
    out = simulate(gen(comm), xs)
    refs = oracle("alltoall", xs)
    for r in range(n):
        np.testing.assert_allclose(out[r], refs[r])


# ---------------------------------------------------------------------------
# Property tests (hypothesis): structural invariants of every schedule
# ---------------------------------------------------------------------------

_POW2 = st.sampled_from([2, 4, 8, 16])
_ANY_N = st.integers(min_value=2, max_value=16)


@given(n=_POW2)
@settings(max_examples=10, deadline=None)
def test_ring_allreduce_wire_bytes_optimal(n):
    """Ring allreduce must move exactly 2(n-1)/n of the message per rank."""
    comm = Communicator(axis="x", size=n)
    sched = A.ring_allreduce(comm)
    assert abs(sched.bytes_on_wire(1.0) - 2 * (n - 1) / n) < 1e-9


@given(n=_ANY_N)
@settings(max_examples=15, deadline=None)
def test_schedules_validate(n):
    comm = Communicator(axis="x", size=n)
    gens = [A.ring_allreduce, A.ring_reduce_scatter, A.ring_allgather,
            A.binomial_tree_bcast, A.one_to_all_bcast, A.ring_reduce,
            A.all_to_one_reduce, A.binomial_tree_reduce, A.linear_alltoall]
    if n & (n - 1) == 0:
        gens += [A.recursive_doubling_allreduce, A.bruck_alltoall,
                 A.halving_doubling_allreduce, A.bidi_ring_allreduce]
    for gen in gens:
        sched = gen(comm)
        sched.validate()  # no duplicate src/dst, ranks in range
        assert sched.n_steps() >= 1


@given(n=_POW2, data=st.data())
@settings(max_examples=8, deadline=None)
def test_allreduce_linearity(n, data):
    """allreduce(a x + b y) == a allreduce(x) + b allreduce(y)."""
    comm = Communicator(axis="x", size=n)
    sched = A.ring_allreduce(comm)
    rng = np.random.default_rng(data.draw(st.integers(0, 2 ** 16)))
    xs = [rng.normal(size=(n * 2, 2)).astype(np.float32) for _ in range(n)]
    ys = [rng.normal(size=(n * 2, 2)).astype(np.float32) for _ in range(n)]
    a, b = 2.0, -0.5
    lhs = simulate(sched, [a * x + b * y for x, y in zip(xs, ys)])
    rx = simulate(sched, xs)
    ry = simulate(sched, ys)
    for r in range(n):
        np.testing.assert_allclose(lhs[r], a * rx[r] + b * ry[r],
                                   atol=1e-3)
