"""Per-architecture smoke tests (deliverable f): every assigned arch at a
REDUCED config runs one forward/train step on CPU; output shapes checked
and loss finite (~log vocab)."""
import math

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import NamedSharding

from repro.configs import ARCH_IDS, get_config, reduced_config
from repro.configs.base import ParallelConfig
from repro.optim import adamw
from repro.parallel import stages

B, S = 4, 32


def _batch(cfg, rng):
    b = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                               jnp.int32),
         "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                               jnp.int32)}
    if cfg.family == "vlm":
        b["vis_embed"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_vis_tokens, cfg.d_model)), jnp.float32)
    if cfg.encoder_layers:
        b["frames"] = jnp.asarray(
            0.1 * rng.normal(size=(B, S, cfg.d_model)), jnp.float32)
    return b


@pytest.mark.parametrize("arch_id", sorted(ARCH_IDS))
def test_arch_train_step_smoke(arch_id, rng, mesh222):
    cfg = reduced_config(get_config(arch_id))
    pcfg = ParallelConfig(backend="microcode", remat="none")
    ts = stages.build_train_step(cfg, pcfg, mesh222,
                                 adamw.AdamWConfig(lr=1e-3))
    params = stages.init_params(cfg, mesh222, ts.ctx.tp, seed=0)
    opt = adamw.adamw_init(params)
    opt = jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh222, s)),
        opt, ts.opt_specs)
    batch = _batch(cfg, rng)
    new_params, opt, metrics = ts.fn(params, opt, batch, jnp.int32(0))
    ce = float(metrics["ce_mean"])
    assert math.isfinite(ce), f"{arch_id}: non-finite loss"
    assert abs(ce - math.log(cfg.vocab_size)) < 1.0, \
        f"{arch_id}: init CE {ce} far from log(V)"
    # params keep their shapes and stay finite
    for (pth, a), (_, b) in zip(
            jax.tree.flatten_with_path(params)[0],
            jax.tree.flatten_with_path(new_params)[0]):
        assert a.shape == b.shape, pth
    gn = float(metrics["grad_norm"])
    assert math.isfinite(gn) and gn > 0


def test_full_configs_match_assignment():
    """The full configs carry the exact assigned dimensions."""
    expect = {
        "internvl2-26b": (48, 6144, 48, 8, 16384, 92553),
        "mamba2-1.3b": (48, 2048, 0, 0, 0, 50280),
        "qwen3-14b": (40, 5120, 40, 8, 17408, 151936),
        "smollm-360m": (32, 960, 15, 5, 2560, 49152),
        "qwen3-0.6b": (28, 1024, 16, 8, 3072, 151936),
        "stablelm-12b": (40, 5120, 32, 8, 13824, 100352),
        "mixtral-8x7b": (32, 4096, 32, 8, 0, 32000),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 0, 151936),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
    }
    for arch_id, (L, d, h, kv, ff, v) in expect.items():
        cfg = get_config(arch_id)
        assert cfg.n_layers == L and cfg.d_model == d, arch_id
        assert cfg.n_heads == h and cfg.n_kv_heads == kv, arch_id
        assert cfg.d_ff == ff and cfg.vocab_size == v, arch_id
    # MoE / SSM extras
    assert get_config("mixtral-8x7b").n_experts == 8
    assert get_config("mixtral-8x7b").experts_per_token == 2
    assert get_config("mixtral-8x7b").moe_d_ff == 14336
    assert get_config("qwen3-moe-30b-a3b").n_experts == 128
    assert get_config("qwen3-moe-30b-a3b").experts_per_token == 8
    assert get_config("qwen3-moe-30b-a3b").moe_d_ff == 768
    assert get_config("mamba2-1.3b").ssm_state == 128
    assert get_config("hymba-1.5b").ssm_state == 16
    assert get_config("whisper-medium").encoder_layers == 24


def test_param_counts_sane():
    """n_params roughly matches the models' nominal sizes."""
    approx = {
        "qwen3-14b": (13e9, 16e9),
        "smollm-360m": (0.3e9, 0.5e9),
        "qwen3-0.6b": (0.55e9, 0.8e9),
        "mixtral-8x7b": (45e9, 49e9),
        "mamba2-1.3b": (1.1e9, 1.6e9),
        "internvl2-26b": (18e9, 23e9),   # LM backbone only (ViT is a stub)
        "stablelm-12b": (11e9, 13.5e9),
    }
    for arch_id, (lo, hi) in approx.items():
        n = get_config(arch_id).n_params()
        assert lo < n < hi, f"{arch_id}: {n/1e9:.2f}B outside [{lo},{hi}]"
