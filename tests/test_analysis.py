"""HLO static analyzer: loop multiplicity, flops, collective bytes."""
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.topology import make_mesh
from repro.launch.analysis import analyze_hlo


def test_loop_free_matches_cost_analysis():
    def mm(x, w):
        return jnp.dot(x, w)
    c = jax.jit(mm).lower(
        jax.ShapeDtypeStruct((256, 128), jnp.float32),
        jax.ShapeDtypeStruct((128, 64), jnp.float32)).compile()
    st = analyze_hlo(c.as_text())
    ca = c.cost_analysis()
    if isinstance(ca, (list, tuple)):  # jax 0.4.x returns [dict]
        ca = ca[0]
    assert st.flops == float(ca["flops"]) == 2 * 256 * 128 * 64


def test_scan_flops_multiplied():
    def h(x):
        def body(c, _):
            return c @ x, None
        out, _ = jax.lax.scan(body, jnp.eye(64), None, length=10)
        return out
    c = jax.jit(h).lower(jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    st = analyze_hlo(c.as_text())
    assert st.flops == 10 * 2 * 64 ** 3
    assert st.loops >= 1


def test_nested_scan_collectives():
    mesh = make_mesh((8,), ("x",))

    def f(x):
        def outer(c, _):
            def inner(c2, _):
                return jax.lax.ppermute(
                    c2, "x", [(i, (i + 1) % 8) for i in range(8)]), None
            c, _ = jax.lax.scan(inner, c, None, length=3)
            return c, None
        c, _ = jax.lax.scan(outer, x, None, length=4)
        return c

    t = jax.jit(jax.shard_map(f, mesh=mesh, in_specs=P("x"),
                              out_specs=P("x"))).lower(
        jax.ShapeDtypeStruct((8, 4), jnp.float32)).compile().as_text()
    st = analyze_hlo(t)
    assert st.coll_ops == 12
    assert st.coll_wire_bytes == 12 * 16  # f32[1,4] per hop


def test_allreduce_wire_model():
    mesh = make_mesh((8,), ("x",))

    def f(x):
        return jax.lax.psum(x, "x")

    t = jax.jit(jax.shard_map(f, mesh=mesh, in_specs=P("x"),
                              out_specs=P("x"))).lower(
        jax.ShapeDtypeStruct((8, 128), jnp.float32)).compile().as_text()
    st = analyze_hlo(t)
    # ring model: 2 * bytes * (n-1)/n
    assert abs(st.coll_wire_bytes - 2 * 128 * 4 * 7 / 8) < 1e-6
