"""CollectiveEngine (jax lowering) vs oracles on 8 virtual devices."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import CollectiveEngine


@pytest.fixture(scope="module")
def engines(request):
    from repro.core.topology import make_mesh
    mesh = make_mesh((8,), ("x",))
    return (CollectiveEngine(mesh, backend="microcode"),
            CollectiveEngine(mesh, backend="native"), mesh)


def run(mesh, fn, x, in_spec=P("x"), out_spec=P("x")):
    g = jax.jit(jax.shard_map(fn, mesh=mesh, in_specs=in_spec,
                              out_specs=out_spec, check_vma=False))
    return np.asarray(g(jnp.asarray(x)))


X = np.random.default_rng(0).normal(size=(8, 16, 3)).astype(np.float32)


@pytest.mark.parametrize("algo", ["ring", "bidi_ring", "recursive_doubling",
                                  "halving_doubling", "auto"])
def test_allreduce(engines, algo):
    eng, _, mesh = engines
    out = run(mesh, lambda xs: eng.allreduce(xs[0], "x", algorithm=algo)[None], X)
    for r in range(8):
        np.testing.assert_allclose(out[r], X.sum(0), atol=1e-4)


@pytest.mark.parametrize("op", ["max", "min"])
def test_allreduce_ops(engines, op):
    eng, _, mesh = engines
    ref = {"max": X.max(0), "min": X.min(0)}[op]
    out = run(mesh, lambda xs: eng.allreduce(xs[0], "x", op=op,
                                             algorithm="ring")[None], X)
    np.testing.assert_allclose(out[0], ref, atol=1e-6)


@pytest.mark.parametrize("algo", ["ring", "recursive_halving", "auto"])
def test_reduce_scatter(engines, algo):
    eng, _, mesh = engines
    flat = X.reshape(8, -1)
    cs = flat.shape[1] // 8
    out = run(mesh, lambda xs: eng.reduce_scatter(
        xs[0], "x", algorithm=algo)[None], X)
    for r in range(8):
        np.testing.assert_allclose(out[r], flat.sum(0)[r * cs:(r + 1) * cs],
                                   atol=1e-4)


@pytest.mark.parametrize("algo", ["ring", "recursive_doubling", "auto"])
def test_allgather(engines, algo):
    eng, _, mesh = engines
    out = run(mesh, lambda xs: eng.allgather(xs[0], "x",
                                             algorithm=algo)[None], X)
    np.testing.assert_allclose(out[0], X.reshape(-1))


@pytest.mark.parametrize("algo", ["one_to_all", "binomial_tree"])
def test_bcast(engines, algo):
    eng, _, mesh = engines
    out = run(mesh, lambda xs: eng.bcast(xs[0], "x", root=3,
                                         algorithm=algo)[None], X)
    for r in range(8):
        np.testing.assert_allclose(out[r], X[3])


@pytest.mark.parametrize("algo", ["ring", "all_to_one", "binomial_tree"])
def test_reduce(engines, algo):
    eng, _, mesh = engines
    out = run(mesh, lambda xs: eng.reduce(xs[0], "x", root=2,
                                          algorithm=algo)[None], X)
    np.testing.assert_allclose(out[2], X.sum(0), atol=1e-4)


@pytest.mark.parametrize("algo", ["linear", "bruck"])
def test_alltoall(engines, algo):
    eng, _, mesh = engines
    ref = np.stack([np.concatenate([X[j][r * 2:(r + 1) * 2]
                                    for j in range(8)]) for r in range(8)])
    out = run(mesh, lambda xs: eng.alltoall(xs[0], "x",
                                            algorithm=algo)[None], X)
    for r in range(8):
        np.testing.assert_allclose(out[r], ref[r])


def test_native_matches_microcode(engines):
    eng, nat, mesh = engines
    a = run(mesh, lambda xs: eng.allreduce(xs[0], "x")[None], X)
    b = run(mesh, lambda xs: nat.allreduce(xs[0], "x")[None], X)
    np.testing.assert_allclose(a, b, atol=1e-4)


@pytest.mark.parametrize("codec,tol", [("bf16", 0.05), ("int8", 0.02)])
def test_compressed_allreduce(engines, codec, tol):
    eng, _, mesh = engines
    out = run(mesh, lambda xs: eng.allreduce(
        xs[0] * 40, "x", algorithm="ring", compression=codec)[None], X)
    ref = X.sum(0) * 40
    rel = np.abs(out[0] - ref).max() / np.abs(ref).max()
    assert rel < tol


def test_streaming_allgather_matmul(engines, rng):
    eng, _, mesh = engines
    x = rng.normal(size=(8 * 4, 3)).astype(np.float32)
    w = rng.normal(size=(3, 5)).astype(np.float32)
    g = jax.jit(jax.shard_map(
        lambda a, b: eng.allgather_matmul(a, b, "x"), mesh=mesh,
        in_specs=(P("x"), P()), out_specs=P(), check_vma=False))
    out = np.asarray(g(jnp.asarray(x), jnp.asarray(w)))
    np.testing.assert_allclose(out, x @ w, atol=1e-4)


def test_streaming_matmul_reduce_scatter(engines, rng):
    eng, _, mesh = engines
    x = rng.normal(size=(16, 8 * 4)).astype(np.float32)
    w = rng.normal(size=(8 * 4, 6)).astype(np.float32)
    g = jax.jit(jax.shard_map(
        lambda a, b: eng.matmul_reduce_scatter(a, b, "x"), mesh=mesh,
        in_specs=(P(None, "x"), P("x")), out_specs=P("x"), check_vma=False))
    out = np.asarray(g(jnp.asarray(x), jnp.asarray(w)))
    np.testing.assert_allclose(out, x @ w, atol=1e-4)


def test_hierarchical_allreduce(rng):
    from repro.core.topology import make_mesh
    mesh = make_mesh((4, 2), ("data", "model"))
    eng = CollectiveEngine(mesh, backend="microcode")
    y = rng.normal(size=(8, 12)).astype(np.float32)
    g = jax.jit(jax.shard_map(
        lambda v: eng.allreduce_multi(v[0], ("data", "model"))[None],
        mesh=mesh, in_specs=P(("data", "model")),
        out_specs=P(("data", "model")), check_vma=False))
    out = np.asarray(g(jnp.asarray(y)))
    for r in range(8):
        np.testing.assert_allclose(out[r], y.sum(0), atol=1e-4)


def test_tree_allreduce_bucketing(engines, rng):
    eng, _, mesh = engines
    trees = [{"a": rng.normal(size=(4, 3)).astype(np.float32),
              "b": rng.normal(size=(7,)).astype(np.float32)}
             for _ in range(8)]
    stacked = {k: np.stack([t[k] for t in trees]) for k in trees[0]}
    g = jax.jit(jax.shard_map(
        lambda t: jax.tree.map(
            lambda l: l[None],
            eng.tree_allreduce(jax.tree.map(lambda a: a[0], t), ("x",))),
        mesh=mesh, in_specs=P("x"), out_specs=P("x"), check_vma=False))
    out = g({k: jnp.asarray(v) for k, v in stacked.items()})
    for k in stacked:
        np.testing.assert_allclose(np.asarray(out[k])[0],
                                   stacked[k].sum(0), atol=1e-4)


# -- control plane: schedule cache & single-generation ------------------------

def _fresh_engine():
    from repro.core.topology import make_mesh
    return CollectiveEngine(make_mesh((8,), ("x",)), backend="microcode")


def test_auto_resolve_generates_each_schedule_once():
    """Auto picks with default root/op reuse the selector's schedule —
    the engine-side generator must never run (no double generation)."""
    eng = _fresh_engine()
    g = jax.jit(jax.shard_map(
        lambda v: eng.allreduce(v, "x", algorithm="auto"),
        mesh=eng.mesh, in_specs=P("x"), out_specs=P("x"), check_vma=False))
    g.lower(jax.ShapeDtypeStruct((8, 64), jnp.float32))
    assert eng.stats["gen_calls"] == 0
    assert eng.selector.stats["gen_calls"] > 0


def test_repeated_collectives_hit_caches():
    """A step issuing the same collective many times prices it once and
    generates its schedule at most once."""
    eng = _fresh_engine()

    def step(v):
        for _ in range(5):
            v = eng.allreduce(v, "x", algorithm="auto")
        return v

    g = jax.jit(jax.shard_map(step, mesh=eng.mesh, in_specs=P("x"),
                              out_specs=P("x"), check_vma=False))
    g.lower(jax.ShapeDtypeStruct((8, 64), jnp.float32))
    st = eng.selector.stats
    assert st["choose_calls"] == 5
    assert st["cache_hits"] == 4
    # generators ran only for the first choose's candidate sweep
    assert st["gen_calls"] == len(
        list(eng.selector.candidates("allreduce", eng.comm("x"))))


def test_explicit_algorithm_schedule_cached():
    eng = _fresh_engine()

    def step(v):
        v = eng.allreduce(v, "x", algorithm="ring")
        v = eng.allreduce(v, "x", algorithm="ring")
        v = eng.allreduce(v, "x", op="max", algorithm="ring")
        return v

    g = jax.jit(jax.shard_map(step, mesh=eng.mesh, in_specs=P("x"),
                              out_specs=P("x"), check_vma=False))
    g.lower(jax.ShapeDtypeStruct((8, 64), jnp.float32))
    # two cache keys: (ring, add) generated once then hit, (ring, max) once
    assert eng.stats["gen_calls"] == 2
    assert eng.stats["sched_cache_hits"] == 1


def test_nondefault_op_regenerates_with_op():
    """Auto pick with op != add must re-key the schedule on the op."""
    eng = _fresh_engine()
    out = run(eng.mesh, lambda xs: eng.allreduce(
        xs[0], "x", op="max", algorithm="auto")[None], X)
    np.testing.assert_allclose(out[0], X.max(0), atol=1e-6)


@pytest.mark.parametrize("causal", [True, False])
def test_ring_attention_matches_full(engines, rng, causal):
    """Context-parallel streaming attention == full-sequence attention."""
    from repro.models.attention import chunked_attention
    eng, _, mesh = engines
    B, S, H, KV, hd = 2, 64, 4, 2, 16  # S sharded 8-way (8 per rank)
    q = rng.normal(size=(B, S, H, hd)).astype(np.float32)
    k = rng.normal(size=(B, S, KV, hd)).astype(np.float32)
    v = rng.normal(size=(B, S, KV, hd)).astype(np.float32)
    ref = np.asarray(chunked_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), causal=causal,
        q_block=16, kv_block=16))

    g = jax.jit(jax.shard_map(
        lambda a, b, c: eng.ring_attention(a, b, c, "x", causal=causal),
        mesh=mesh,
        in_specs=(P(None, "x"), P(None, "x"), P(None, "x")),
        out_specs=P(None, "x"), check_vma=False))
    out = np.asarray(g(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v)))
    np.testing.assert_allclose(out, ref, atol=2e-4)
