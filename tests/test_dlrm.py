"""Distributed DLRM (paper use case 2) vs single-device reference."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.dlrm import reduced
from repro.configs.base import ParallelConfig
from repro.core.engine import CollectiveEngine
from repro.core.topology import make_mesh
from repro.models import dlrm as dlrm_mod
from repro.models.common import Builder
from repro.parallel.ops import ParCtx


def test_dlrm_distributed_matches_reference(rng):
    cfg = reduced()
    mesh = make_mesh((1, 2, 4), ("pod", "data", "model"))
    eng = CollectiveEngine(mesh, backend="microcode")
    ctx = ParCtx(engine=eng, pcfg=ParallelConfig(), mesh=mesh)
    b = Builder("init", key=jax.random.PRNGKey(0), dtype=jnp.float32)
    params = dlrm_mod.dlrm_params(b, cfg, 4)
    specs = dlrm_mod.dlrm_specs(cfg, 4)
    B = 8
    idx = rng.integers(0, cfg.rows_per_table, (B, cfg.n_tables)).astype(np.int32)

    g = jax.jit(jax.shard_map(
        lambda p, i: dlrm_mod.dlrm_forward(p, i, ctx),
        mesh=mesh, in_specs=(specs, P(("pod", "data"), None)),
        out_specs=P(("pod", "data"), None), check_vma=False))
    out = np.asarray(g(params, jnp.asarray(idx)))
    ref = np.asarray(dlrm_mod.dlrm_reference(params, jnp.asarray(idx)))
    np.testing.assert_allclose(out, ref, atol=1e-3, rtol=1e-3)


def test_dlrm_pallas_lookup_matches(rng):
    cfg = reduced()
    mesh = make_mesh((1, 1, 2), ("pod", "data", "model"))
    eng = CollectiveEngine(mesh, backend="microcode")
    ctx = ParCtx(engine=eng, pcfg=ParallelConfig(), mesh=mesh)
    b = Builder("init", key=jax.random.PRNGKey(1), dtype=jnp.float32)
    params = dlrm_mod.dlrm_params(b, cfg, 2)
    specs = dlrm_mod.dlrm_specs(cfg, 2)
    idx = rng.integers(0, cfg.rows_per_table, (4, cfg.n_tables)).astype(np.int32)

    outs = {}
    for use_pallas in (False, True):
        g = jax.jit(jax.shard_map(
            lambda p, i, up=use_pallas: dlrm_mod.embedding_lookup(
                p["tables"], i, ctx, use_pallas=up),
            mesh=mesh, in_specs=(specs, P(None, None)),
            out_specs=P(None, None), check_vma=False))
        outs[use_pallas] = np.asarray(g(params, jnp.asarray(idx)))
    np.testing.assert_allclose(outs[True], outs[False], atol=1e-5)
