"""Self-tests for scripts/lint_conventions.py (the AST linter that
replaced the CI grep guards), plus the clean-tree check over src/."""
import importlib.util
import pathlib
import textwrap

import pytest

_ROOT = pathlib.Path(__file__).resolve().parent.parent
_SCRIPT = _ROOT / "scripts" / "lint_conventions.py"

spec = importlib.util.spec_from_file_location("lint_conventions", _SCRIPT)
lint = importlib.util.module_from_spec(spec)
spec.loader.exec_module(lint)


def _rules(snippet):
    text = textwrap.dedent(snippet)
    return [v.rule for v in lint.check_source(text, "<test>")]


# --------------------------------------------------------------------------
# LC001 — resurrected legacy entry points
# --------------------------------------------------------------------------

def test_lc001_flags_legacy_call():
    assert _rules("interpret_schedule(sched, xs)") == ["LC001"]


def test_lc001_flags_definition_site():
    assert "LC001" in _rules("""
        def ring_allreduce_loop(comm, xs):
            return xs
    """)


def test_lc001_flags_attribute_reference():
    assert _rules("simulator.interpret_schedule(s, xs)") == ["LC001"]


def test_lc001_flags_wire_scale_kwarg():
    assert _rules("cost_model(prog, wire_scale=2.0)") == ["LC001"]


def test_lc001_clean_on_docstring_mention():
    """The grep guard false-positived on prose; the AST linter doesn't."""
    assert _rules('''
        def f():
            """This replaced interpret_schedule long ago."""
            return 1
    ''') == []


# --------------------------------------------------------------------------
# LC002 — bare pricing kwargs on call sites
# --------------------------------------------------------------------------

def test_lc002_flags_bare_tier_kwarg():
    assert _rules("prog.cost(nbytes, tier='dcn')") == ["LC002"]


def test_lc002_flags_multiline_call():
    """A continuation-line kwarg — invisible to a line-based grep."""
    assert _rules("""
        t = makespan(
            programs,
            drop_prob=0.1,
        )
    """) == ["LC002"]


def test_lc002_clean_on_env_and_def_sites():
    assert _rules("prog.cost(nbytes, env=PricingEnv(tier='dcn'))") == []
    # definition sites legitimately keep the deprecation-shim params
    assert _rules("""
        def cost(self, nbytes, env=None, *, tier=None, drop_prob=None):
            return 0.0
    """) == []


def test_lc002_ignores_unrelated_fns():
    assert _rules("draw(tier=3)") == []


# --------------------------------------------------------------------------
# LC003 — executing a raw Schedule (skipping the compiler + verifier)
# --------------------------------------------------------------------------

def test_lc003_flags_generator_inline():
    assert _rules("execute_program(ring_allreduce(comm), xs, axis)") \
        == ["LC003"]


def test_lc003_flags_schedule_literal():
    assert "LC003" in _rules(
        "execute_program(Schedule(name='s', steps=()), xs, axis)")


def test_lc003_clean_on_compiled_inline_and_variables():
    assert _rules("execute_program(sched.compile(), xs, axis)") == []
    assert _rules(
        "execute_program(compile_schedule(sched, 4), xs, axis)") == []
    assert _rules("execute_program(prog, xs, axis)") == []


# --------------------------------------------------------------------------
# LC004 — side-channel telemetry (direct .stats[...] writes, bare print)
# --------------------------------------------------------------------------

def test_lc004_flags_stats_subscript_assign():
    assert _rules("self.stats['issued'] = 1") == ["LC004"]


def test_lc004_flags_stats_subscript_augassign():
    assert _rules("eng.stats['gen_calls'] += 1") == ["LC004"]


def test_lc004_flags_bare_print():
    assert _rules("print('debug', x)") == ["LC004"]


def test_lc004_clean_on_registry_and_reads():
    assert _rules("self.metrics.inc('issued')") == []
    assert _rules("n = self.stats['issued']") == []       # reads are fine
    assert _rules("other['k'] = 1") == []                 # not a .stats view
    assert _rules("log.print('x')") == []                 # method, not bare


def test_lc004_exempt_paths():
    snippet = "self.stats['x'] = 1\nprint('hi')\n"
    assert [v.rule for v in lint.check_source(
        snippet, "src/repro/core/telemetry.py")] == []
    assert [v.rule for v in lint.check_source(
        snippet, "src/repro/launch/serve.py")] == []
    assert [v.rule for v in lint.check_source(
        snippet, "src/repro/core/engine.py")] == ["LC004", "LC004"]


# --------------------------------------------------------------------------
# Harness behaviour
# --------------------------------------------------------------------------

def test_violation_rendering():
    (v,) = lint.check_source("prog.cost(1, tier='ici')", "a/b.py")
    assert str(v) == ("a/b.py:1: LC002 call to cost() with deprecated "
                      "bare kwarg(s) ['tier'] — pricing parameters "
                      "travel in env=PricingEnv(...)")


def test_main_exit_codes(tmp_path, capsys):
    bad = tmp_path / "bad.py"
    bad.write_text("interpret_schedule(s, xs)\n")
    good = tmp_path / "good.py"
    good.write_text("x = 1\n")
    assert lint.main([str(good)]) == 0
    assert lint.main([str(bad)]) == 1
    assert "LC001" in capsys.readouterr().out
    assert lint.main([]) == 2


def test_src_tree_is_clean():
    """The shipped source obeys its own conventions."""
    violations = lint.check_paths([str(_ROOT / "src")])
    assert violations == [], "\n".join(str(v) for v in violations)


@pytest.mark.parametrize("rule", ["LC001", "LC002", "LC003", "LC004"])
def test_every_rule_documented(rule):
    assert rule in _SCRIPT.read_text()
