"""The pricing/issue API contracts this PR's redesign pins:

  * `PricingEnv` is the ONE bundle of pricing parameters, accepted
    everywhere pricing happens (Program.cost/cost_terms,
    Sequencer.makespan, Selector.choose, MeshMakespan) — default env is
    bitwise-neutral, the old bare kwargs are a deprecation shim that
    prices identically, and mixing the two is a TypeError;
  * `CollectiveEngine.issue`/`issue_multi`/`i*` expose the SAME public
    call shapes as the `Sequencer` methods they delegate to (the
    signature contract comment in core/engine.py);
  * degraded `Communicator`s carry a rank-id table (`without_ranks`),
    so non-contiguous survivors keep their global shards.
"""
import inspect

import numpy as np
import pytest

from repro.core import (
    CollectiveEngine, Communicator, PricingEnv, Selector, Sequencer,
    TIERS, resolve_env,
)


@pytest.fixture()
def eng8(mesh8):
    return CollectiveEngine(mesh8)


def _public_params(fn):
    """(name, kind, default) for every public parameter — the call
    shape a caller sees. Private `_pre`/`_post`/`_shape` plumbing and
    `self` are not part of the contract."""
    return [(p.name, p.kind, p.default)
            for p in inspect.signature(fn).parameters.values()
            if p.name != "self" and not p.name.startswith("_")]


# -- engine <-> sequencer signature parity ------------------------------------

def test_engine_issue_matches_sequencer_issue():
    assert _public_params(CollectiveEngine.issue) == \
        _public_params(Sequencer.issue)


def test_engine_issue_multi_matches_sequencer_issue_multi():
    assert _public_params(CollectiveEngine.issue_multi) == \
        _public_params(Sequencer.issue_multi)


def test_i_helpers_share_issue_defaults():
    """Every i* convenience helper takes keyword-only after=None and
    timeout=None — the same deferred-execution knobs as issue()."""
    helpers = [CollectiveEngine.iallreduce, CollectiveEngine.ireduce_scatter,
               CollectiveEngine.iallgather, CollectiveEngine.ibcast,
               CollectiveEngine.ireduce, CollectiveEngine.ialltoall,
               CollectiveEngine.icollective]
    for fn in helpers:
        params = inspect.signature(fn).parameters
        for knob in ("after", "timeout"):
            p = params[knob]
            assert p.kind == inspect.Parameter.KEYWORD_ONLY, fn.__name__
            assert p.default is None, fn.__name__


def test_issue_and_helpers_accept_identical_shapes(eng8):
    """The contract in practice: the engine surface and the queue
    surface take the same call, including after=/timeout=."""
    x = np.zeros((64,), np.float32)
    r1 = eng8.issue("allreduce", x, "x", timeout=1.0)
    r2 = eng8.iallreduce(np.zeros((64,), np.float32), "x",
                         after=[r1], timeout=2.0)
    assert r2.deps == (r1,) and r2.timeout == 2.0
    seq = eng8.queue
    r3 = seq.issue("allreduce", np.zeros((64,), np.float32), "x",
                   after=[r2], timeout=3.0)
    assert r3.deps == (r2,) and r3.timeout == 3.0
    seq.clear()


# -- PricingEnv: one bundle, neutral default, shimmed past ---------------------

def _program(eng, nbytes):
    comm = eng.comm("x")
    choice = eng.selector.choose("allreduce", nbytes, comm)
    return choice.program, comm


def test_default_env_is_bitwise_neutral(eng8):
    prog, comm = _program(eng8, 1 << 20)
    assert prog.cost(1 << 20, comm) == \
        prog.cost(1 << 20, comm, env=PricingEnv())
    assert prog.cost_terms(1 << 20, comm) == \
        prog.cost_terms(1 << 20, comm, env=PricingEnv())


def test_bare_kwargs_shim_prices_identically(eng8):
    prog, comm = _program(eng8, 1 << 20)
    tier = TIERS["tcp-like"]
    assert prog.cost(1 << 20, comm, tier=tier, drop_prob=0.1) == \
        prog.cost(1 << 20, comm, env=PricingEnv(tier=tier, drop_prob=0.1))
    seq = Sequencer(eng8)
    seq.issue("allreduce", np.zeros((1 << 16,), np.float32), "x")
    assert seq.makespan("x", tier=tier, drop_prob=0.1) == \
        seq.makespan("x", env=PricingEnv(tier=tier, drop_prob=0.1))
    seq.clear()


def test_mixing_env_and_bare_kwargs_raises(eng8):
    prog, comm = _program(eng8, 1 << 16)
    env = PricingEnv(tier=TIERS["tcp-like"])
    with pytest.raises(TypeError):
        prog.cost(1 << 16, comm, tier=TIERS["udp-like"], env=env)
    with pytest.raises(TypeError):
        prog.cost(1 << 16, comm, drop_prob=0.5, env=env)
    seq = Sequencer(eng8)
    seq.issue("allreduce", np.zeros((1 << 12,), np.float32), "x")
    with pytest.raises(TypeError):
        seq.makespan("x", tier=TIERS["udp-like"], env=env)
    seq.clear()
    with pytest.raises(TypeError):
        resolve_env(env, tier=TIERS["udp-like"])


def test_resolve_env_wraps_bare_kwargs():
    tier = TIERS["rdma-like"]
    env = resolve_env(None, tier=tier, drop_prob=0.2)
    assert env == PricingEnv(tier=tier, drop_prob=0.2)
    same = PricingEnv(drop_prob=0.1)
    assert resolve_env(same) is same


def test_env_comm_overrides_positional(eng8):
    prog, comm = _program(eng8, 1 << 20)
    slow = Communicator(axis="x", size=8, is_dcn=True)
    assert prog.cost(1 << 20, comm, env=PricingEnv(comm=slow)) == \
        prog.cost(1 << 20, slow)
    assert prog.cost(1 << 20, slow) > prog.cost(1 << 20, comm)


def test_selector_env_carries_eager_cap_and_lead_dim(mesh8):
    """The selector's per-call pricing knobs ride the env: an
    eager_max_bytes override and the alltoall lead_dim clamp each price
    identically to their pre-env spellings."""
    eng = CollectiveEngine(mesh8)
    comm = eng.comm("x")
    capped = Selector(eager_max_bytes=0.0)
    via_ctor = capped.choose("allreduce", 1 << 10, comm)
    via_env = Selector().choose("allreduce", 1 << 10, comm,
                                env=PricingEnv(eager_max_bytes=0.0))
    assert (via_ctor.protocol, via_ctor.predicted_s) == \
        (via_env.protocol, via_env.predicted_s)
    assert via_env.protocol == "rendezvous"  # cap 0 rejects eager
    by_kwarg = Selector().choose("alltoall", 1 << 18, comm, lead_dim=64)
    by_env = Selector().choose("alltoall", 1 << 18, comm,
                               env=PricingEnv(lead_dim=64))
    assert (by_kwarg.algorithm, by_kwarg.segments,
            by_kwarg.predicted_s) == \
        (by_env.algorithm, by_env.segments, by_env.predicted_s)


# -- rank-id-aware degraded communicators -------------------------------------

def test_without_ranks_keeps_global_ids():
    comm = Communicator(axis="x", size=4)
    assert comm.global_ranks == (0, 1, 2, 3)
    d = comm.without_ranks([1])
    assert d.size == 3 and d.global_ranks == (0, 2, 3)
    # chained mid-mesh failures compose through the rank table:
    # local rank 1 of the degraded group is global rank 2
    dd = d.without_ranks([1])
    assert dd.global_ranks == (0, 3)
    with pytest.raises(ValueError):
        dd.without_ranks([0, 1])  # cannot remove every rank


def test_shrunk_and_rank_table_validation():
    comm = Communicator(axis="x", size=4, ranks=(0, 2, 3, 5))
    assert comm.shrunk(2).global_ranks == (0, 2)
    with pytest.raises(ValueError):
        Communicator(axis="x", size=3, ranks=(0, 1))
    # factor() rebuilds identity-mapped level comms
    prod = Communicator(axis="x", size=8).factor(2)
    assert prod.outer.ranks is None and prod.inner.ranks is None
