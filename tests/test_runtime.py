"""Fault tolerance: failure recovery exactness, elastic reshard,
checkpoint manager semantics, straggler watchdog."""
import os

import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.configs.base import ParallelConfig
from repro.data import DataConfig, make_loader
from repro.optim import adamw
from repro.runtime import FailureInjector, StragglerWatchdog, Trainer, TrainerConfig


@pytest.fixture()
def tmp_ckpt(tmp_path):
    return str(tmp_path / "ckpt")


def _trainer(mesh, ckpt_dir, total=10, injector=None, seed=1):
    cfg = reduced_config(get_config("smollm-360m"))
    pcfg = ParallelConfig(backend="microcode", remat="none")
    dcfg = DataConfig(global_batch=4, seq_len=16, seed=seed)
    return Trainer(cfg, pcfg, mesh, adamw.AdamWConfig(lr=1e-3), dcfg,
                   TrainerConfig(total_steps=total, ckpt_dir=ckpt_dir,
                                 ckpt_every=4), injector=injector)


def test_failure_recovery_exact(tmp_path, mesh222):
    ref_dir, rec_dir = str(tmp_path / "a"), str(tmp_path / "b")
    log_ref = _trainer(mesh222, ref_dir, total=10).run()
    t = _trainer(mesh222, rec_dir, total=10,
                 injector=FailureInjector(fail_at=(5,)))
    log_rec = t.run()
    events = [r for r in log_rec if "event" in r]
    assert len(events) == 1 and events[0]["event"] == "failure"
    ref = {r["step"]: r["ce_mean"] for r in log_ref if "step" in r}
    rec = {r["step"]: r["ce_mean"] for r in log_rec if "step" in r}
    for s in rec:
        assert abs(ref[s] - rec[s]) < 1e-5, f"divergence at step {s}"


def test_rank_failure_shrink_and_continue(tmp_path, mesh222):
    """Dead rank during grad sync: the trainer shrinks the data axis to
    the survivors, replans, and continues from IN-MEMORY state — no
    checkpoint restore, no lost pre-failure steps."""
    t = _trainer(mesh222, str(tmp_path / "d"), total=8,
                 injector=FailureInjector(rank_fail_at=((4, 1),)))
    # ckpt_every beyond the run: recovery cannot lean on a restore
    t.tcfg.ckpt_every = 100
    log = t.run()
    events = [r for r in log if "event" in r]
    assert len(events) == 1 and events[0]["event"] == "rank_failure"
    assert events[0]["rank"] == 1 and events[0]["axis"] == "data"
    steps = [r["step"] for r in log if "step" in r]
    assert steps == list(range(8))  # every step ran exactly once
    assert dict(t.mesh.shape)["data"] == 1  # data axis shrunk 2 -> 1
    # post-failure metrics are real numbers from the degraded mesh
    post = [r for r in log if r.get("step", -1) >= 4]
    assert all(np.isfinite(r["ce_mean"]) for r in post)


def test_rank_failure_nonprefix_survivor_keeps_shard(tmp_path, mesh222):
    """Rank 0 of the data axis dies — a mid-mesh failure in the sense
    that the SURVIVORS are not a prefix of the original ranks. The
    rank-id-aware remap must keep global rank 1 as the survivor — its
    own device column, its own shard — where the old count-only shrink
    would have handed it rank 0's slot. (The model's hardcoded 'data'
    FSDP specs need the shrunk axis to divide d_model, so the axis goes
    2 -> 1 here; the deeper chained {0,2,3} mid-mesh case is covered at
    Communicator level in test_api_surface.py.)"""
    orig_devices = np.asarray(mesh222.devices)
    t = _trainer(mesh222, str(tmp_path / "m"), total=8,
                 injector=FailureInjector(rank_fail_at=((4, 0),)))
    t.tcfg.ckpt_every = 100
    log = t.run()
    events = [r for r in log if "event" in r]
    assert len(events) == 1 and events[0]["event"] == "rank_failure"
    # the event records WHICH global ranks survive, from the degraded
    # communicator's rank table
    assert events[0]["survivors"] == [1]
    assert t._axis_comms["data"].global_ranks == (1,)
    # the dead POSITION was deleted, not the tail: the survivor keeps
    # its own physical devices
    want = np.delete(orig_devices, 0, axis=1)
    np.testing.assert_array_equal(np.asarray(t.mesh.devices), want)
    assert dict(t.mesh.shape)["data"] == 1
    steps = [r["step"] for r in log if "step" in r]
    assert steps == list(range(8))
    post = [r for r in log if r.get("step", -1) >= 4]
    assert post and all(np.isfinite(r["ce_mean"]) for r in post)


def test_rank_failure_no_survivors_reraises(tmp_path, mesh111):
    # data axis already 1: nothing to shrink onto -> the failure
    # propagates (after max_restarts) instead of silently looping
    t = _trainer(mesh111, str(tmp_path / "e"), total=6,
                 injector=FailureInjector(rank_fail_at=((2, 0),)))
    t.tcfg.ckpt_every = 100
    from repro.runtime import RankFailure
    with pytest.raises(RankFailure):
        t.run()


def test_elastic_reshard_resume(tmp_path, mesh222, mesh111):
    d = str(tmp_path / "c")
    _trainer(mesh222, d, total=6).run()
    # resume the same checkpoint on a different mesh
    t2 = _trainer(mesh111, d, total=8)
    log2 = t2.run()
    steps = [r["step"] for r in log2 if "step" in r]
    assert steps and steps[0] >= 4  # resumed, not restarted


def test_checkpoint_atomic_commit(tmp_path):
    from repro.checkpoint import CheckpointManager, latest_step
    import numpy as np
    d = str(tmp_path / "d")
    mgr = CheckpointManager(d, keep=2)
    tree = {"w": np.arange(6.0).reshape(2, 3)}
    for step in (1, 2, 3):
        mgr.save(step, tree, blocking=True)
    assert latest_step(d) == 3
    # keep=2 garbage-collects step 1
    assert not os.path.exists(os.path.join(d, "step_000000001"))
    # a dir without COMMIT is ignored
    os.makedirs(os.path.join(d, "step_000000009"))
    assert latest_step(d) == 3


def test_straggler_watchdog():
    wd = StragglerWatchdog(threshold=3.0, patience=2, warmup=3)
    flagged = []
    for i in range(20):
        z = wd.observe(i, 0.1)
        assert z is None
    for i in range(20, 23):
        z = wd.observe(i, 5.0)  # massive straggle
        if z is not None:
            flagged.append((i, z))
    assert flagged, "watchdog must flag a persistent straggler"


def test_data_loader_resume_determinism():
    cfg = reduced_config(get_config("smollm-360m"))
    dcfg = DataConfig(global_batch=4, seq_len=8, seed=7)
    l1 = make_loader(dcfg, cfg, start_step=0)
    batches = {}
    for _ in range(5):
        s, b = next(l1)
        batches[s] = b["tokens"].copy()
    l1.close()
    l2 = make_loader(dcfg, cfg, start_step=3)
    s, b = next(l2)
    l2.close()
    assert s == 3
    np.testing.assert_array_equal(b["tokens"], batches[3])


def test_memmap_source(tmp_path):
    cfg = reduced_config(get_config("smollm-360m"))
    toks = np.arange(4 * 9 * 10, dtype=np.int32) % cfg.vocab_size
    path = str(tmp_path / "corpus.bin")
    toks.tofile(path)
    dcfg = DataConfig(global_batch=4, seq_len=8, seed=0, source="memmap",
                      memmap_path=path)
    loader = make_loader(dcfg, cfg)
    s, b = next(loader)
    loader.close()
    assert b["tokens"].shape == (4, 8)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
