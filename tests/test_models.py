"""Model component tests: flash attention, SSD scan, MoE, decode paths."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (
    chunked_attention, decode_attention, flash_attention,
)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("window", [0, 24])
def test_flash_matches_chunked(rng, causal, window):
    B, S, H, KV, hd = 2, 64, 6, 2, 16
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    ref = chunked_attention(q, k, v, causal=causal, window=window,
                            q_block=16, kv_block=32)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          q_block=16, kv_block=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_flash_grad_matches(rng):
    B, S, H, KV, hd = 1, 32, 4, 2, 8
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)

    def l_ref(q, k, v):
        return (chunked_attention(q, k, v, causal=True, q_block=8,
                                  kv_block=16) ** 2).sum()

    def l_fl(q, k, v):
        return (flash_attention(q, k, v, causal=True, q_block=8,
                                kv_block=16) ** 2).sum()

    gr = jax.grad(l_ref, argnums=(0, 1, 2))(q, k, v)
    gf = jax.grad(l_fl, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gr, gf):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-4)


def test_decode_attention_matches_softmax(rng):
    B, H, KV, hd, S = 2, 4, 2, 8, 16
    q = jnp.asarray(rng.normal(size=(B, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    pos = 9
    out = decode_attention(q, k, v, slot_positions=jnp.arange(S),
                           cur_pos=jnp.int32(pos))
    s = np.einsum("bhd,bshd->bhs", np.asarray(q), np.asarray(k)) / np.sqrt(hd)
    s[:, :, pos + 1:] = -1e30
    p = np.exp(s - s.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    ref = np.einsum("bhs,bshd->bhd", p, np.asarray(v))
    np.testing.assert_allclose(np.asarray(out), ref, atol=1e-4)


def test_ssd_matches_naive_recurrence(rng):
    """Chunked SSD == step-by-step linear recurrence."""
    from repro.models.ssm import _ssd_chunked
    B, S, H, P_, N = 1, 32, 2, 4, 8
    xh = jnp.asarray(rng.normal(size=(B, S, H, P_)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(B, S, H)), jnp.float32)
    a_neg = -jnp.asarray(rng.uniform(0.5, 2.0, size=(H,)), jnp.float32)
    b_in = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
    c_in = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
    y, h_final = _ssd_chunked(xh, dt, a_neg, b_in, c_in, chunk=8)

    # naive recurrence
    h = np.zeros((B, H, N, P_), np.float64)
    ys = []
    for t in range(S):
        a = np.exp(np.asarray(dt)[:, t] * np.asarray(a_neg)[None])  # (B,H)
        upd = np.einsum("bn,bh,bhp->bhnp", np.asarray(b_in)[:, t],
                        np.asarray(dt)[:, t], np.asarray(xh)[:, t])
        h = a[..., None, None] * h + upd
        ys.append(np.einsum("bn,bhnp->bhp", np.asarray(c_in)[:, t], h))
    ref = np.stack(ys, 1)
    np.testing.assert_allclose(np.asarray(y), ref, atol=1e-3)
    np.testing.assert_allclose(np.asarray(h_final), h, atol=1e-3)


def test_moe_block_matches_dense_reference(rng, mesh111):
    """Single rank, huge capacity: MoE == per-token dense expert mixture."""
    from jax.sharding import PartitionSpec as P
    from repro.configs import get_config, reduced_config
    from repro.configs.base import ParallelConfig
    from repro.models import mlp as mlp_mod
    from repro.models.common import Builder
    from repro.parallel.ops import ParCtx
    from repro.core.engine import CollectiveEngine

    cfg = reduced_config(get_config("mixtral-8x7b"))
    pcfg = ParallelConfig(moe_capacity_factor=64.0)
    eng = CollectiveEngine(mesh111, backend="microcode")
    ctx = ParCtx(engine=eng, pcfg=pcfg, mesh=mesh111)
    b = Builder("init", key=jax.random.PRNGKey(0), dtype=jnp.float32)
    params = mlp_mod.moe_params(b, cfg, 1)
    x = jnp.asarray(rng.normal(size=(2, 8, cfg.d_model)), jnp.float32)

    def fn(p, xx):
        y, _ = mlp_mod.moe_block(p, xx, cfg, ctx, 64.0)
        return y

    g = jax.jit(jax.shard_map(
        fn, mesh=mesh111, in_specs=(P(), P()), out_specs=P(),
        check_vma=False))
    out = np.asarray(g(params, x))

    # dense reference
    xt = np.asarray(x).reshape(-1, cfg.d_model)
    router = np.asarray(params["router"])
    logits = xt @ router
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    k = cfg.experts_per_token
    ref = np.zeros_like(xt)
    w1, w3, w2 = (np.asarray(params[n]) for n in ("w1", "w3", "w2"))
    for t in range(xt.shape[0]):
        top = np.argsort(-probs[t])[:k]
        gates = probs[t][top] / probs[t][top].sum()
        for e, gate in zip(top, gates):
            h = (xt[t] @ w1[e])
            h = h / (1 + np.exp(-h)) * (xt[t] @ w3[e])
            ref[t] += gate * (h @ w2[e])
    np.testing.assert_allclose(out.reshape(-1, cfg.d_model), ref,
                               atol=2e-3, rtol=1e-2)


def test_rolling_cache_slot_positions():
    from repro.models.serve import _slot_and_positions
    W, pos = 8, jnp.int32(11)
    slot, slot_pos = _slot_and_positions(W, True, pos, W, 0, False)
    assert int(slot) == 3
    sp = np.asarray(slot_pos)
    # slots hold positions 4..11, each p at slot p % 8
    for i in range(W):
        assert sp[i] == pos - ((pos - i) % W)
        assert sp[i] % W == i and 4 <= sp[i] <= 11
