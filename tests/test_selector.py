"""Algorithm/protocol selector behaviour (paper Table 1 / Fig 12)."""
import pytest

from repro.core import Communicator, Selector


def test_small_message_prefers_low_latency():
    sel = Selector()
    comm = Communicator(axis="x", size=8)
    c = sel.choose("allreduce", 1024, comm)
    assert c.algorithm in ("recursive_doubling",), c
    # latency-optimal: log(n) steps
    assert c.schedule.n_steps() == 3


def test_large_message_prefers_bandwidth_optimal():
    sel = Selector()
    comm = Communicator(axis="x", size=8)
    c = sel.choose("allreduce", 64 << 20, comm)
    assert c.algorithm in ("ring", "bidi_ring", "halving_doubling")
    assert c.schedule.bytes_on_wire(1.0) <= 2.0  # <= 2(n-1)/n + eps


def test_eager_only_below_rx_pool():
    sel = Selector(eager_max_bytes=4096)
    comm = Communicator(axis="x", size=8)
    small = sel.choose("bcast", 1024, comm)
    large = sel.choose("bcast", 1 << 20, comm)
    assert large.protocol == "rendezvous"
    assert small.predicted_s <= large.predicted_s


def test_runtime_tuning_override():
    sel = Selector()
    comm = Communicator(axis="x", size=8)
    auto = sel.choose("allreduce", 1 << 20, comm)
    sel.set_tuning("allreduce", "recursive_doubling")
    tuned = sel.choose("allreduce", 1 << 20, comm)
    assert tuned.algorithm == "recursive_doubling"
    assert auto.algorithm != "recursive_doubling"


def test_reduce_switches_algorithm_with_size():
    """Fig 12: all-to-one for small messages, tree for large."""
    sel = Selector()
    comm = Communicator(axis="x", size=8)
    small = sel.choose("reduce", 8 << 10, comm)
    large = sel.choose("reduce", 8 << 20, comm)
    assert small.predicted_s < large.predicted_s
    assert large.algorithm == "binomial_tree"


def test_nonpow2_excludes_hypercube():
    sel = Selector()
    comm = Communicator(axis="x", size=6)
    for size in (1024, 1 << 20):
        c = sel.choose("allreduce", size, comm)
        assert c.algorithm in ("ring", "bidi_ring")


# -- tuning-table semantics ---------------------------------------------------

def test_tuning_last_set_rule_wins():
    """Overlapping tuning rules: the most recently set one applies."""
    sel = Selector()
    comm = Communicator(axis="x", size=8)
    sel.set_tuning("allreduce", "ring")
    sel.set_tuning("allreduce", "recursive_doubling")
    assert sel.choose("allreduce", 1 << 20, comm).algorithm == \
        "recursive_doubling"
    # a later, narrower rule shadows it inside its byte range only
    sel.set_tuning("allreduce", "halving_doubling", lo_bytes=1 << 22)
    assert sel.choose("allreduce", 1 << 20, comm).algorithm == \
        "recursive_doubling"
    assert sel.choose("allreduce", 1 << 23, comm).algorithm == \
        "halving_doubling"


def test_tuning_nranks_filter():
    """nranks-scoped rules apply only to matching communicator sizes."""
    sel = Selector()
    sel.set_tuning("allreduce", "recursive_doubling", nranks=4)
    c8 = sel.choose("allreduce", 64 << 20, Communicator(axis="x", size=8))
    c4 = sel.choose("allreduce", 64 << 20, Communicator(axis="x", size=4))
    assert c4.algorithm == "recursive_doubling"
    assert c8.algorithm != "recursive_doubling"


def test_tuning_pins_segment_count():
    sel = Selector()
    comm = Communicator(axis="x", size=8)
    auto = sel.choose("allreduce", 64 << 20, comm)
    assert auto.segments > 1
    sel.set_tuning("allreduce", auto.algorithm, segments=1)
    pinned = sel.choose("allreduce", 64 << 20, comm)
    assert pinned.algorithm == auto.algorithm
    assert pinned.segments == 1
    assert pinned.predicted_s > auto.predicted_s  # pipelining was winning


def test_eager_cutoff_exact_boundary():
    """eager admissible up to eager_max_bytes inclusive, not beyond."""
    sel = Selector(eager_max_bytes=4096)
    comm = Communicator(axis="x", size=8)
    assert sel._protocol_overhead("eager", 4096, comm) is not None
    assert sel._protocol_overhead("eager", 4097, comm) is None
    assert sel._protocol_overhead("rendezvous", 1 << 30, comm) == \
        comm.hw.rendezvous_rtt


def test_pow2_only_filtering_on_nonpow2_comm():
    """Candidate enumeration drops pow2-only generators on n=6."""
    sel = Selector()
    algos6 = {a for a, _ in sel.candidates("allreduce",
                                           Communicator(axis="x", size=6))}
    algos8 = {a for a, _ in sel.candidates("allreduce",
                                           Communicator(axis="x", size=8))}
    assert algos6 == {"ring", "bidi_ring"}
    assert algos8 == {"ring", "bidi_ring", "recursive_doubling",
                      "halving_doubling"}


# -- memoization --------------------------------------------------------------

def test_choose_is_memoized_zero_generator_calls():
    """Second identical choose() runs no generators and returns the same
    Choice object."""
    sel = Selector()
    comm = Communicator(axis="x", size=8)
    first = sel.choose("allreduce", 1 << 20, comm)
    gens_after_first = sel.stats["gen_calls"]
    assert gens_after_first > 0
    second = sel.choose("allreduce", 1 << 20, comm)
    assert second is first
    assert sel.stats["gen_calls"] == gens_after_first  # zero new invocations
    assert sel.stats["cache_hits"] == 1
    # a different message size is a different cache entry
    sel.choose("allreduce", 1 << 21, comm)
    assert sel.stats["gen_calls"] > gens_after_first


def test_choose_cache_keys_on_elem_bytes():
    """Codec pricing depends on the element width (wire bytes per elem /
    elem_bytes): a choose() at a different elem_bytes must not be served
    a stale memoized Choice priced for another width."""
    sel = Selector()
    comm = Communicator(axis="x", size=8)
    c4 = sel.choose("allreduce", 4 << 20, comm, codec="int8", elem_bytes=4)
    c2 = sel.choose("allreduce", 4 << 20, comm, codec="int8", elem_bytes=2)
    assert sel.stats["cache_hits"] == 0  # different width, different entry
    assert c2.predicted_s != c4.predicted_s  # 2-byte wires compress 2x less
    again = sel.choose("allreduce", 4 << 20, comm, codec="int8",
                       elem_bytes=4)
    assert again is c4  # same width still hits the cache
    assert sel.stats["cache_hits"] == 1


def test_set_tuning_invalidates_choose_cache():
    sel = Selector()
    comm = Communicator(axis="x", size=8)
    auto = sel.choose("allreduce", 1 << 20, comm)
    sel.set_tuning("allreduce", "recursive_doubling")
    tuned = sel.choose("allreduce", 1 << 20, comm)
    assert tuned.algorithm == "recursive_doubling"
    assert auto.algorithm != tuned.algorithm


# -- per-fabric segmentation floors (ICI vs DCN) ------------------------------

def test_dcn_axis_prices_its_own_segment_floor():
    """The 10 us DCN alpha + its own min_segment_bytes shift the segment
    optimum: at equal message size the pod axis admits fewer segments and
    chooses a smaller count than the ICI axis."""
    sel = Selector()
    ici = Communicator(axis="data", size=8, is_dcn=False)
    dcn = Communicator(axis="pod", size=8, is_dcn=True)
    assert dcn.min_segment_bytes > ici.min_segment_bytes
    assert dcn.hop_latency > ici.hop_latency

    from repro.core import algorithms as A
    sched = A.ring_allreduce(ici)
    msg = 4 << 20  # per-step chunk = 512 KiB: many ICI segments, few DCN
    adm_ici = sel.admissible_segments(sched, msg, ici)
    adm_dcn = sel.admissible_segments(sched, msg, dcn)
    assert max(adm_ici) > max(adm_dcn)

    c_ici = sel.choose("allreduce", msg, ici)
    c_dcn = sel.choose("allreduce", msg, dcn)
    assert c_ici.segments > c_dcn.segments


def test_compressed_pricing_admits_fewer_segments():
    """Codec wires shrink per-segment bytes, so the same message admits
    fewer segment counts under compression (the Rx floor is on wire
    bytes)."""
    sel = Selector()
    comm = Communicator(axis="x", size=8)
    from repro.core import algorithms as A
    sched = A.ring_allreduce(comm)
    msg = 1 << 20
    plain = sel.admissible_segments(sched, msg, comm)
    packed = sel.admissible_segments(sched, msg, comm, codec="int8")
    assert max(packed) < max(plain)
    ch = sel.choose("allreduce", msg, comm, codec="int8")
    assert ch.codec == "int8" and ch.compressed


# -- lossless tuning-table round-trip -----------------------------------------

def test_table_reports_segments_and_codec():
    sel = Selector()
    comm = Communicator(axis="x", size=8)
    rows = sel.table_rows("allreduce", comm)
    assert {r["msg_bytes"] for r in rows} == set(
        Selector.DEFAULT_TABLE_SIZES)
    big = next(r for r in rows if r["msg_bytes"] == 1 << 27)
    assert big["segments"] > 1           # large messages pipeline
    assert big["compressed"] is False
    assert all({"algorithm", "protocol", "segments", "codec",
                "nranks"} <= set(r) for r in rows)


@pytest.mark.parametrize("codec", [None, "int8"])
def test_table_round_trip_is_lossless(codec):
    """table_rows -> apply_table on a fresh selector reproduces every
    bucket's (algorithm, segments) exactly — nothing is dropped on the
    way through benchmark output and back."""
    src = Selector()
    comm = Communicator(axis="x", size=8)
    rows = src.table_rows("allreduce", comm, codec=codec)

    dst = Selector()
    dst.apply_table(rows)
    for r in rows:
        c = dst.choose("allreduce", r["msg_bytes"], comm, codec=codec)
        assert c.algorithm == r["algorithm"], r
        assert c.segments == r["segments"], r


def test_compressed_table_does_not_leak_into_uncompressed_choose():
    """Tuning entries carry the codec they were measured under: a table
    priced on int8 wires must not override uncompressed selection."""
    comm = Communicator(axis="x", size=8)
    baseline = Selector().choose("allreduce", 1 << 24, comm)
    sel = Selector()
    sel.apply_table(sel.table_rows("allreduce", comm, codec="int8"))
    plain = sel.choose("allreduce", 1 << 24, comm)
    assert (plain.algorithm, plain.segments) == \
        (baseline.algorithm, baseline.segments)


# -- custom-collective candidates ---------------------------------------------

def _pow2_only_gen(comm):
    if not comm.is_pow2:
        raise ValueError("needs power-of-two ranks")
    from repro.core import algorithms as A
    return A.ring_allreduce(comm)


def test_inapplicable_custom_generator_is_skipped_not_fatal():
    """A registered generator that raises for this communicator (e.g.
    pow2-only) must be skipped by the auto sweep, like the built-ins'
    pow2 filter — not crash the whole choose()."""
    from repro.core import plugins
    from repro.core import algorithms as A
    plugins.register_collective("myred", _pow2_only_gen, algorithm="pow2")
    plugins.register_collective(
        "myred", lambda comm: A.ring_allreduce(comm), algorithm="ring")
    try:
        sel = Selector()
        c = sel.choose("myred", 1 << 20, Communicator(axis="x", size=6))
        assert c.algorithm == "ring"
        c8 = sel.choose("myred", 1 << 10, Communicator(axis="x", size=8))
        assert c8.algorithm in ("pow2", "ring")
    finally:
        plugins.unregister_collective("myred")


def test_registry_changes_invalidate_choose_cache():
    """Registering a cheaper algorithm after a choose() must be visible
    on the next identical choose (no stale registry picks)."""
    from repro.core import plugins
    from repro.core import algorithms as A
    comm = Communicator(axis="x", size=8)
    sel = Selector()
    plugins.register_collective(
        "myred2", lambda comm: A.ring_reduce(comm), algorithm="slow_ring")
    try:
        first = sel.choose("myred2", 1 << 20, comm)
        assert first.algorithm == "slow_ring"
        plugins.register_collective(
            "myred2", lambda comm: A.ring_allreduce(comm), algorithm="ring")
        second = sel.choose("myred2", 1 << 20, comm)
        assert second.algorithm == "ring"  # cheaper newcomer wins
        plugins.unregister_collective("myred2", "ring")
        third = sel.choose("myred2", 1 << 20, comm)
        assert third.algorithm == "slow_ring"
    finally:
        plugins.unregister_collective("myred2")


def test_choice_segments_always_executable_on_indivisible_payload():
    """ROADMAP "prices requested k" item, closed: every candidate
    segment count is clamped through `fit_segments` on the padded chunk
    grid BEFORE pricing, so `Choice.segments` is exactly the count the
    executor's trace-time clamp will admit — never a priced fiction the
    data plane then shrinks."""
    from repro.core.program import fit_segments
    sel = Selector()
    comm = Communicator(axis="x", size=8)
    # 3^8 fp32 elements per chunk: no power-of-two count divides it, so
    # the old selector would price (and "choose") k=2 for the streamed
    # ring while the executor silently ran k=1
    msg = 8 * 6561 * 4
    c = sel.choose("allreduce", msg, comm)
    csize = (msg // 4) // 8
    assert csize % c.segments == 0           # executable as priced
    assert c.segments == fit_segments(csize, c.segments)


def test_tuned_segment_pin_clamped_to_executable_count():
    """A tuning-table segment pin on an indivisible payload prices the
    count the executor will actually run (the largest admissible
    divisor), keeping cost and execution in agreement for pinned
    deployments too."""
    sel = Selector()
    comm = Communicator(axis="x", size=8)
    msg = 8 * 6561 * 4
    sel.set_tuning("allreduce", "ring", segments=4)
    c = sel.choose("allreduce", msg, comm)
    assert c.algorithm == "ring"
    assert c.segments == 3                   # fit_segments(6561, 4) == 3


def test_divisible_payload_choices_unchanged_by_clamp():
    """Power-of-two payloads (every benchmark sweep point) admit the
    full candidate ladder: the clamp is the identity there."""
    sel = Selector()
    comm = Communicator(axis="x", size=8)
    c = sel.choose("allreduce", 1 << 20, comm)
    csize = ((1 << 20) // 4) // 8
    assert csize % c.segments == 0
    assert c.segments > 1  # large streamed message still segments


def test_gather_shard_clamp_uses_shard_grid():
    """Regression: allgather/gather price the per-rank SHARD but execute
    on the nranks*shard buffer whose chunk IS one shard — the clamp must
    fit candidates against the shard, not shard/chunks (which would
    wrongly collapse the ladder for non-power-of-two shards)."""
    from repro.core import algorithms as A
    sel = Selector()
    comm = Communicator(axis="x", size=8)
    sched = A.ring_allgather(comm)
    # 24-element fp32 shard: the shard grid admits 2, 4, and 8; the
    # wrong shard/chunks grid (3 elements) would collapse to (1, 3)
    assert sel.fit_candidate_segments(sched, 24 * 4, (1, 2, 4, 8)) == \
        (1, 2, 4, 8)
