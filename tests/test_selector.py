"""Algorithm/protocol selector behaviour (paper Table 1 / Fig 12)."""
from repro.core import Communicator, Selector


def test_small_message_prefers_low_latency():
    sel = Selector()
    comm = Communicator(axis="x", size=8)
    c = sel.choose("allreduce", 1024, comm)
    assert c.algorithm in ("recursive_doubling",), c
    # latency-optimal: log(n) steps
    assert c.schedule.n_steps() == 3


def test_large_message_prefers_bandwidth_optimal():
    sel = Selector()
    comm = Communicator(axis="x", size=8)
    c = sel.choose("allreduce", 64 << 20, comm)
    assert c.algorithm in ("ring", "bidi_ring", "halving_doubling")
    assert c.schedule.bytes_on_wire(1.0) <= 2.0  # <= 2(n-1)/n + eps


def test_eager_only_below_rx_pool():
    sel = Selector(eager_max_bytes=4096)
    comm = Communicator(axis="x", size=8)
    small = sel.choose("bcast", 1024, comm)
    large = sel.choose("bcast", 1 << 20, comm)
    assert large.protocol == "rendezvous"
    assert small.predicted_s <= large.predicted_s


def test_runtime_tuning_override():
    sel = Selector()
    comm = Communicator(axis="x", size=8)
    auto = sel.choose("allreduce", 1 << 20, comm)
    sel.set_tuning("allreduce", "recursive_doubling")
    tuned = sel.choose("allreduce", 1 << 20, comm)
    assert tuned.algorithm == "recursive_doubling"
    assert auto.algorithm != "recursive_doubling"


def test_reduce_switches_algorithm_with_size():
    """Fig 12: all-to-one for small messages, tree for large."""
    sel = Selector()
    comm = Communicator(axis="x", size=8)
    small = sel.choose("reduce", 8 << 10, comm)
    large = sel.choose("reduce", 8 << 20, comm)
    assert small.predicted_s < large.predicted_s
    assert large.algorithm == "binomial_tree"


def test_nonpow2_excludes_hypercube():
    sel = Selector()
    comm = Communicator(axis="x", size=6)
    for size in (1024, 1 << 20):
        c = sel.choose("allreduce", size, comm)
        assert c.algorithm in ("ring", "bidi_ring")
