"""Algorithm/protocol selector behaviour (paper Table 1 / Fig 12)."""
from repro.core import Communicator, Selector


def test_small_message_prefers_low_latency():
    sel = Selector()
    comm = Communicator(axis="x", size=8)
    c = sel.choose("allreduce", 1024, comm)
    assert c.algorithm in ("recursive_doubling",), c
    # latency-optimal: log(n) steps
    assert c.schedule.n_steps() == 3


def test_large_message_prefers_bandwidth_optimal():
    sel = Selector()
    comm = Communicator(axis="x", size=8)
    c = sel.choose("allreduce", 64 << 20, comm)
    assert c.algorithm in ("ring", "bidi_ring", "halving_doubling")
    assert c.schedule.bytes_on_wire(1.0) <= 2.0  # <= 2(n-1)/n + eps


def test_eager_only_below_rx_pool():
    sel = Selector(eager_max_bytes=4096)
    comm = Communicator(axis="x", size=8)
    small = sel.choose("bcast", 1024, comm)
    large = sel.choose("bcast", 1 << 20, comm)
    assert large.protocol == "rendezvous"
    assert small.predicted_s <= large.predicted_s


def test_runtime_tuning_override():
    sel = Selector()
    comm = Communicator(axis="x", size=8)
    auto = sel.choose("allreduce", 1 << 20, comm)
    sel.set_tuning("allreduce", "recursive_doubling")
    tuned = sel.choose("allreduce", 1 << 20, comm)
    assert tuned.algorithm == "recursive_doubling"
    assert auto.algorithm != "recursive_doubling"


def test_reduce_switches_algorithm_with_size():
    """Fig 12: all-to-one for small messages, tree for large."""
    sel = Selector()
    comm = Communicator(axis="x", size=8)
    small = sel.choose("reduce", 8 << 10, comm)
    large = sel.choose("reduce", 8 << 20, comm)
    assert small.predicted_s < large.predicted_s
    assert large.algorithm == "binomial_tree"


def test_nonpow2_excludes_hypercube():
    sel = Selector()
    comm = Communicator(axis="x", size=6)
    for size in (1024, 1 << 20):
        c = sel.choose("allreduce", size, comm)
        assert c.algorithm in ("ring", "bidi_ring")


# -- tuning-table semantics ---------------------------------------------------

def test_tuning_last_set_rule_wins():
    """Overlapping tuning rules: the most recently set one applies."""
    sel = Selector()
    comm = Communicator(axis="x", size=8)
    sel.set_tuning("allreduce", "ring")
    sel.set_tuning("allreduce", "recursive_doubling")
    assert sel.choose("allreduce", 1 << 20, comm).algorithm == \
        "recursive_doubling"
    # a later, narrower rule shadows it inside its byte range only
    sel.set_tuning("allreduce", "halving_doubling", lo_bytes=1 << 22)
    assert sel.choose("allreduce", 1 << 20, comm).algorithm == \
        "recursive_doubling"
    assert sel.choose("allreduce", 1 << 23, comm).algorithm == \
        "halving_doubling"


def test_tuning_nranks_filter():
    """nranks-scoped rules apply only to matching communicator sizes."""
    sel = Selector()
    sel.set_tuning("allreduce", "recursive_doubling", nranks=4)
    c8 = sel.choose("allreduce", 64 << 20, Communicator(axis="x", size=8))
    c4 = sel.choose("allreduce", 64 << 20, Communicator(axis="x", size=4))
    assert c4.algorithm == "recursive_doubling"
    assert c8.algorithm != "recursive_doubling"


def test_tuning_pins_segment_count():
    sel = Selector()
    comm = Communicator(axis="x", size=8)
    auto = sel.choose("allreduce", 64 << 20, comm)
    assert auto.segments > 1
    sel.set_tuning("allreduce", auto.algorithm, segments=1)
    pinned = sel.choose("allreduce", 64 << 20, comm)
    assert pinned.algorithm == auto.algorithm
    assert pinned.segments == 1
    assert pinned.predicted_s > auto.predicted_s  # pipelining was winning


def test_eager_cutoff_exact_boundary():
    """eager admissible up to eager_max_bytes inclusive, not beyond."""
    sel = Selector(eager_max_bytes=4096)
    comm = Communicator(axis="x", size=8)
    assert sel._protocol_overhead("eager", 4096, comm) is not None
    assert sel._protocol_overhead("eager", 4097, comm) is None
    assert sel._protocol_overhead("rendezvous", 1 << 30, comm) == \
        comm.hw.rendezvous_rtt


def test_pow2_only_filtering_on_nonpow2_comm():
    """Candidate enumeration drops pow2-only generators on n=6."""
    sel = Selector()
    algos6 = {a for a, _ in sel.candidates("allreduce",
                                           Communicator(axis="x", size=6))}
    algos8 = {a for a, _ in sel.candidates("allreduce",
                                           Communicator(axis="x", size=8))}
    assert algos6 == {"ring", "bidi_ring"}
    assert algos8 == {"ring", "bidi_ring", "recursive_doubling",
                      "halving_doubling"}


# -- memoization --------------------------------------------------------------

def test_choose_is_memoized_zero_generator_calls():
    """Second identical choose() runs no generators and returns the same
    Choice object."""
    sel = Selector()
    comm = Communicator(axis="x", size=8)
    first = sel.choose("allreduce", 1 << 20, comm)
    gens_after_first = sel.stats["gen_calls"]
    assert gens_after_first > 0
    second = sel.choose("allreduce", 1 << 20, comm)
    assert second is first
    assert sel.stats["gen_calls"] == gens_after_first  # zero new invocations
    assert sel.stats["cache_hits"] == 1
    # a different message size is a different cache entry
    sel.choose("allreduce", 1 << 21, comm)
    assert sel.stats["gen_calls"] > gens_after_first


def test_set_tuning_invalidates_choose_cache():
    sel = Selector()
    comm = Communicator(axis="x", size=8)
    auto = sel.choose("allreduce", 1 << 20, comm)
    sel.set_tuning("allreduce", "recursive_doubling")
    tuned = sel.choose("allreduce", 1 << 20, comm)
    assert tuned.algorithm == "recursive_doubling"
    assert auto.algorithm != tuned.algorithm
