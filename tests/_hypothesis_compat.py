"""Fallback for the `hypothesis` dependency when it isn't installed.

The container image pins the jax toolchain but does not ship hypothesis,
and the suite must run without network installs. When hypothesis is
available we re-export it untouched; otherwise a tiny deterministic
sampler runs each property test over `max_examples` pseudo-random draws —
weaker than real shrinking/coverage, but it keeps the structural
invariants exercised on every CI run.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
except ImportError:
    import functools
    import random

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw  # callable(rng) -> value

        def draw(self, rng):
            return self._draw(rng)

    class _DataObject:
        def __init__(self, rng):
            self._rng = rng

        def draw(self, strategy, label=None):
            return strategy.draw(self._rng)

    class _Strategies:
        @staticmethod
        def sampled_from(seq):
            items = list(seq)
            return _Strategy(lambda rng: items[rng.randrange(len(items))])

        @staticmethod
        def integers(min_value=0, max_value=1 << 30):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            return _Strategy(
                lambda rng: min_value + (max_value - min_value) * rng.random())

        @staticmethod
        def data():
            return _Strategy(lambda rng: _DataObject(rng))

    st = _Strategies()

    def settings(max_examples=10, deadline=None, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            import inspect

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                rng = random.Random(0)
                for _ in range(getattr(fn, "_max_examples", 10)):
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    fn(*args, **dict(kwargs, **drawn))

            # pytest must see only the non-drawn params (fixtures): drop
            # the __wrapped__ signature pass-through and publish a reduced
            # signature instead.
            del wrapper.__wrapped__
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(parameters=[
                p for name, p in sig.parameters.items()
                if name not in strategies])
            return wrapper
        return deco
