"""Program-level pricing: `Program.cost` is the one cost model.

1. Pricing parity: the program walk reproduces the retired schedule-walk
   `predict_time` (tests/golden_pricing.py) EXACTLY on every registry
   algorithm x segment count x codec — the pricing refactor moved the
   model onto the compiled artifact, not the numbers.
2. The optimization passes (STREAM fusion, stacked receives) realize the
   overlap the model already priced: they must not change the price.
3. Per-fabric floors: segment counts that would cut an exchange's wire
   payload below the Rx floor are clamped in the walk (the schedule walk
   priced them as if the Rx buffers were infinite).
4. The selector's hot path prices the compiled program (Choice.program)
   and `Schedule` has no pricing method left to walk.
"""
import inspect
import math

import pytest

import golden_pricing as GP
from repro.core import Communicator, Selector
from repro.core import algorithms as A
from repro.core import simulator as sim
from repro.core.schedule import Schedule
from repro.core.hw_spec import ACCL_CLUSTER
from repro.core.program import compile_schedule

COMM8 = Communicator(axis="x", size=8)
COMM6 = Communicator(axis="x", size=6)

ALL_ALGOS = sorted({(c, a) for (c, a) in A.GENERATORS})


def _gen(coll, algo, comm):
    gen = A.GENERATORS[(coll, algo)]
    kw = {"root": 1} if "root" in inspect.signature(gen).parameters else {}
    return gen(comm, **kw)


def _wire_scale(codec, elem_bytes=4):
    if codec is None:
        return 1.0
    from repro.core import plugins
    return plugins.get_codec(codec).wire_bytes_per_elem / elem_bytes


# -- 1. pricing parity with the retired schedule walk -------------------------

@pytest.mark.parametrize("coll,algo", ALL_ALGOS,
                         ids=[f"{c}-{a}" for c, a in ALL_ALGOS])
@pytest.mark.parametrize("codec", [None, "int8"])
def test_cost_matches_golden_predict_time(coll, algo, codec):
    """Every algorithm, every admissible segment count, both codecs:
    program walk == schedule walk, exactly. Message sizes keep every
    per-segment wire payload above the ICI floor so the (new) floor
    clamp never fires — the regime the old model priced."""
    sched = _gen(coll, algo, COMM8)
    for msg in (4 << 20, 64 << 20):
        for k in (1, 2, 4, 8):
            want = GP.predict_time(sched, msg, COMM8.hop_latency,
                                   COMM8.link_bw, segments=k,
                                   wire_scale=_wire_scale(codec))
            got = compile_schedule(sched, segments=k, codec=codec).cost(
                msg, COMM8)
            assert math.isclose(want, got, rel_tol=1e-12), (msg, k)


@pytest.mark.parametrize("coll,algo",
                         [("allreduce", "ring"), ("allreduce", "bidi_ring"),
                          ("reduce", "ring")])
def test_cost_parity_nonpow2_and_other_fabric(coll, algo):
    """Parity holds off the 8-rank/TPU happy path too."""
    accl = Communicator(axis="x", size=6, hw=ACCL_CLUSTER)
    sched = _gen(coll, algo, accl)
    for k in (1, 4):
        want = GP.predict_time(sched, 16 << 20, accl.hop_latency,
                               accl.link_bw, segments=k)
        got = compile_schedule(sched, segments=k).cost(16 << 20, accl)
        assert math.isclose(want, got, rel_tol=1e-12)


# -- 2. the passes do not move the price --------------------------------------

@pytest.mark.parametrize("coll,algo",
                         [("allreduce", "ring"), ("allreduce", "bidi_ring"),
                          ("reduce", "ring"), ("allgather", "ring")])
def test_stream_fusion_is_price_neutral(coll, algo):
    """STREAM realizes the cross-step overlap the fill/drain model was
    already pricing — fused and unfused programs cost the same."""
    sched = _gen(coll, algo, COMM8)
    for k in (2, 8):
        fused = compile_schedule(sched, segments=k)
        plain = compile_schedule(sched, segments=k, stream=False)
        assert fused.ops != plain.ops  # the pass actually fired
        assert fused.cost(8 << 20, COMM8) == plain.cost(8 << 20, COMM8)


def test_stacked_recv_is_price_neutral():
    sched = A.linear_alltoall(COMM8)
    stacked = compile_schedule(sched)
    plain = compile_schedule(sched, stacked=False)
    assert stacked.ops != plain.ops
    assert stacked.cost(8 << 20, COMM8) == plain.cost(8 << 20, COMM8)


# -- 3. per-fabric segment floors in the walk ---------------------------------

def test_cost_clamps_sub_floor_segments():
    """A pinned segment count that cuts the wire below the fabric floor
    prices at the clamped count — the Rx buffers cannot hold thinner
    segments, so the walk must not credit them. On DCN (256 KiB floor) a
    1 MiB ring step (128 KiB chunks) admits no segmentation at all."""
    dcn = Communicator(axis="pod", size=8, is_dcn=True)
    sched = A.ring_allreduce(dcn)
    msg = 1 << 20
    k8 = compile_schedule(sched, segments=8).cost(msg, dcn)
    k1 = compile_schedule(sched, segments=1).cost(msg, dcn)
    assert k8 == k1  # clamped all the way back to unsegmented
    # same program on ICI (8 KiB floor): k=8 keeps its fill/drain credit
    ici = Communicator(axis="x", size=8)
    assert compile_schedule(sched, segments=8).cost(msg, ici) < \
        compile_schedule(sched, segments=1).cost(msg, ici)


def test_cost_floor_partial_clamp_monotone():
    """Between the extremes the clamp is partial: the price of an
    over-segmented program sits between the admissible optimum and the
    unsegmented baseline."""
    dcn = Communicator(axis="pod", size=8, is_dcn=True)
    sched = A.ring_allreduce(dcn)
    msg = 16 << 20  # 2 MiB steps: floor admits k <= 8
    c4 = compile_schedule(sched, segments=4).cost(msg, dcn)
    c32 = compile_schedule(sched, segments=32).cost(msg, dcn)
    c8 = compile_schedule(sched, segments=8).cost(msg, dcn)
    c1 = compile_schedule(sched, segments=1).cost(msg, dcn)
    assert c8 == c32  # 32 clamps to the floor count, 8
    assert c4 < c1 and c8 < c1


# -- 4. the selector prices the compiled artifact -----------------------------

def test_schedule_has_no_pricing_walk():
    """The schedule-walk pricer is retired (mirrors the CI grep guard):
    cost lives on the Program alone."""
    assert not hasattr(Schedule, "predict_time")


def test_choice_carries_the_priced_program():
    """choose() attaches the exact compiled program it priced, and the
    price decomposes as program cost + protocol overhead."""
    sel = Selector()
    for coll, msg in (("allreduce", 4 << 20), ("reduce", 8 << 10)):
        c = sel.choose(coll, msg, COMM8)
        assert c.program is not None
        assert c.program.segments == c.segments
        ov = sel._protocol_overhead(c.protocol, msg, COMM8)
        assert math.isclose(c.predicted_s,
                            c.program.cost(msg, COMM8) + ov, rel_tol=1e-12)


def test_priced_program_is_the_executed_program():
    """The engine's memoized compile of the chosen schedule returns THE
    program object the selector priced — one artifact for cost and
    execution, compiled once."""
    sel = Selector()
    c = sel.choose("allreduce", 4 << 20, COMM8)
    executed = c.schedule.compile(codec=c.codec)
    assert executed is c.program


def test_simulator_returns_the_cost_it_executes():
    """simulate_with_cost prices the same compiled program it ran."""
    import numpy as np
    sched = A.ring_allreduce(COMM8)
    xs = [np.full((16,), float(r), np.float32) for r in range(8)]
    bufs, t = sim.simulate_with_cost(sched, xs, COMM8, segments=4)
    for b in bufs:
        np.testing.assert_allclose(b, np.full((16,), 28.0), atol=1e-5)
    assert t == compile_schedule(sched, segments=4).cost(
        xs[0].nbytes, COMM8)


def test_compile_rejects_zero_segments():
    with pytest.raises(ValueError):
        compile_schedule(A.ring_reduce_scatter(COMM8), segments=0)
