"""Program-level pricing: `Program.cost` is the one cost model.

1. Split-model pricing against the goldens (tests/golden_pricing.py):
   k=1 programs and k>1 programs that fuse into ONE cross-step region
   still reproduce the retired schedule-walk `predict_time` EXACTLY —
   the credit is earned there. SEG_LOOP-only programs reproduce the
   serialized `predict_time_segloop` EXACTLY and intentionally price
   ABOVE the old walk (the old model over-credited them); multi-region
   and mixed programs sit strictly between the two goldens, with the
   ring-allreduce divergence pinned to its closed form.
2. The passes: STREAM/STREAM_CHAIN fusion now EARNS the cross-step
   credit (fused prices below unfused); stacked receives stay neutral.
3. Per-fabric floors: segment counts that would cut an exchange's wire
   payload below the Rx floor are clamped in the walk (the schedule walk
   priced them as if the Rx buffers were infinite).
4. The selector's hot path prices the compiled program (Choice.program),
   `Schedule` has no pricing method left to walk, and the simulator and
   engine agree on the cost of the program they both execute.
"""
import inspect
import math

import numpy as np
import pytest

import golden_pricing as GP
from repro.core import Communicator, Selector
from repro.core import algorithms as A
from repro.core import simulator as sim
from repro.core.schedule import Schedule
from repro.core.hw_spec import ACCL_CLUSTER
from repro.core.program import Stream, StreamChain, compile_schedule

COMM8 = Communicator(axis="x", size=8)

ALL_ALGOS = sorted({(c, a) for (c, a) in A.GENERATORS})


def _gen(coll, algo, comm):
    gen = A.GENERATORS[(coll, algo)]
    kw = {"root": 1} if "root" in inspect.signature(gen).parameters else {}
    return gen(comm, **kw)


def _wire_scale(codec, elem_bytes=4):
    if codec is None:
        return 1.0
    from repro.core import plugins
    return plugins.get_codec(codec).wire_bytes_per_elem / elem_bytes


def _regions(prog):
    return [op for op in prog.ops if isinstance(op, (Stream, StreamChain))]


def _loose_exchanges(prog):
    """Exchanges priced OUTSIDE any cross-step region (serialized)."""
    return [t for t in prog.exchange_terms() if t[3] is None]


# -- 1. split-model pricing against the goldens -------------------------------

@pytest.mark.parametrize("coll,algo", ALL_ALGOS,
                         ids=[f"{c}-{a}" for c, a in ALL_ALGOS])
@pytest.mark.parametrize("codec", [None, "int8"])
def test_cost_against_goldens_scoped(coll, algo, codec):
    """Every algorithm x segment count x codec, scoped by what the
    compiled program can actually execute. Message sizes keep every
    per-segment wire payload above the ICI floor so the floor clamp
    never fires — the regime the old model priced."""
    sched = _gen(coll, algo, COMM8)
    for msg in (4 << 20, 64 << 20):
        for k in (1, 2, 4, 8):
            old = GP.predict_time(sched, msg, COMM8.hop_latency,
                                  COMM8.link_bw, segments=k,
                                  wire_scale=_wire_scale(codec))
            serial = GP.predict_time_segloop(
                sched, msg, COMM8.hop_latency, COMM8.link_bw, segments=k,
                wire_scale=_wire_scale(codec))
            prog = compile_schedule(sched, segments=k, codec=codec)
            got = prog.cost(msg, COMM8)
            regions = _regions(prog)
            loose = _loose_exchanges(prog)
            if k == 1 or (len(regions) == 1 and not loose):
                # the whole program is one cross-step pipeline: the old
                # credit is earned in full, parity survives exactly
                assert math.isclose(got, old, rel_tol=1e-12), (msg, k)
            elif not regions:
                # SEG_LOOP-only: serialized steps, honest price ABOVE
                # the old walk's cross-step credit
                assert math.isclose(got, serial, rel_tol=1e-12), (msg, k)
                assert got > old, (msg, k)
            else:
                # multi-region (ring allreduce: RS + AG streams) or
                # mixed: part of the credit is earned, never all of it
                assert old < got < serial, (msg, k)


def test_ring_allreduce_divergence_is_the_extra_drain():
    """The intentional ring-allreduce divergence, pinned exactly: its RS
    and AG phases stream as TWO regions with a barrier between them, so
    the program pays one extra (k-1)*t_seg drain over the old
    single-pipeline walk."""
    sched = A.ring_allreduce(COMM8)
    msg = 8 << 20
    for k in (2, 8):
        old = GP.predict_time(sched, msg, COMM8.hop_latency,
                              COMM8.link_bw, segments=k)
        got = compile_schedule(sched, segments=k).cost(msg, COMM8)
        t_seg = COMM8.hop_latency + (msg / 8) / (k * COMM8.link_bw)
        assert math.isclose(got, old + (k - 1) * t_seg, rel_tol=1e-12)


@pytest.mark.parametrize("k", [3, 4, 8])
def test_recursive_halving_earns_full_parity_via_chain(k):
    """The SEL_RANGE overlap proof admits recursive halving at k >= 3:
    the whole schedule fuses into ONE STREAM_CHAIN and wins back exactly
    the price the old walk always granted it. At k = 2 the proof fails
    (the head segment reaches into the missing tail write), the program
    stays SEG_LOOP-only, and the price is the honest serialized one."""
    sched = A.recursive_halving_reduce_scatter(COMM8)
    msg = 16 << 20
    prog = compile_schedule(sched, segments=k)
    assert [type(op) for op in prog.ops] == [StreamChain]
    old = GP.predict_time(sched, msg, COMM8.hop_latency, COMM8.link_bw,
                          segments=k)
    assert math.isclose(prog.cost(msg, COMM8), old, rel_tol=1e-12)

    k2 = compile_schedule(sched, segments=2)
    assert not _regions(k2)
    serial = GP.predict_time_segloop(sched, msg, COMM8.hop_latency,
                                     COMM8.link_bw, segments=2)
    assert math.isclose(k2.cost(msg, COMM8), serial, rel_tol=1e-12)


def test_cost_parity_nonpow2_and_other_fabric():
    """Single-region parity holds off the 8-rank/TPU happy path too."""
    accl = Communicator(axis="x", size=6, hw=ACCL_CLUSTER)
    for coll, algo in (("reduce_scatter", "ring"), ("reduce", "ring")):
        sched = _gen(coll, algo, accl)
        for k in (1, 4):
            want = GP.predict_time(sched, 16 << 20, accl.hop_latency,
                                   accl.link_bw, segments=k)
            got = compile_schedule(sched, segments=k).cost(16 << 20, accl)
            assert math.isclose(want, got, rel_tol=1e-12)


# -- 2. the passes and the price ----------------------------------------------

@pytest.mark.parametrize("coll,algo",
                         [("allreduce", "ring"), ("allreduce", "bidi_ring"),
                          ("reduce", "ring"), ("allgather", "ring"),
                          ("reduce_scatter", "recursive_halving"),
                          ("allreduce", "halving_doubling")])
def test_stream_fusion_earns_the_credit(coll, algo):
    """The split model prices the fused and unfused forms differently —
    only the program that actually keeps the wire busy across step
    boundaries gets the cross-step credit. The unfused form prices at
    the serialized golden model."""
    sched = _gen(coll, algo, COMM8)
    for k in (4, 8):
        fused = compile_schedule(sched, segments=k)
        plain = compile_schedule(sched, segments=k, stream=False)
        assert _regions(fused) and not _regions(plain)
        assert fused.cost(8 << 20, COMM8) < plain.cost(8 << 20, COMM8)
        serial = GP.predict_time_segloop(
            sched, 8 << 20, COMM8.hop_latency, COMM8.link_bw, segments=k)
        assert math.isclose(plain.cost(8 << 20, COMM8), serial,
                            rel_tol=1e-12)


def test_stacked_recv_is_price_neutral():
    sched = A.linear_alltoall(COMM8)
    stacked = compile_schedule(sched)
    plain = compile_schedule(sched, stacked=False)
    assert stacked.ops != plain.ops
    assert stacked.cost(8 << 20, COMM8) == plain.cost(8 << 20, COMM8)


# -- 3. per-fabric segment floors in the walk ---------------------------------

def test_cost_clamps_sub_floor_segments():
    """A pinned segment count that cuts the wire below the fabric floor
    prices at the clamped count — the Rx buffers cannot hold thinner
    segments, so the walk must not credit them. On DCN (256 KiB floor) a
    1 MiB ring step (128 KiB chunks) admits no segmentation at all."""
    dcn = Communicator(axis="pod", size=8, is_dcn=True)
    sched = A.ring_allreduce(dcn)
    msg = 1 << 20
    k8 = compile_schedule(sched, segments=8).cost(msg, dcn)
    k1 = compile_schedule(sched, segments=1).cost(msg, dcn)
    assert k8 == k1  # clamped all the way back to unsegmented
    # same program on ICI (8 KiB floor): k=8 keeps its fill/drain credit
    ici = Communicator(axis="x", size=8)
    assert compile_schedule(sched, segments=8).cost(msg, ici) < \
        compile_schedule(sched, segments=1).cost(msg, ici)


def test_cost_floor_partial_clamp_monotone():
    """Between the extremes the clamp is partial: the price of an
    over-segmented program sits between the admissible optimum and the
    unsegmented baseline."""
    dcn = Communicator(axis="pod", size=8, is_dcn=True)
    sched = A.ring_allreduce(dcn)
    msg = 16 << 20  # 2 MiB steps: floor admits k <= 8
    c4 = compile_schedule(sched, segments=4).cost(msg, dcn)
    c32 = compile_schedule(sched, segments=32).cost(msg, dcn)
    c8 = compile_schedule(sched, segments=8).cost(msg, dcn)
    c1 = compile_schedule(sched, segments=1).cost(msg, dcn)
    assert c8 == c32  # 32 clamps to the floor count, 8
    assert c4 < c1 and c8 < c1


# -- 4. the selector prices the compiled artifact -----------------------------

def test_schedule_has_no_pricing_walk():
    """The schedule-walk pricer is retired (mirrors the CI grep guard):
    cost lives on the Program alone."""
    assert not hasattr(Schedule, "predict_time")


def test_choice_carries_the_priced_program():
    """choose() attaches the exact compiled program it priced, and the
    price decomposes as program cost + protocol overhead."""
    sel = Selector()
    for coll, msg in (("allreduce", 4 << 20), ("reduce", 8 << 10)):
        c = sel.choose(coll, msg, COMM8)
        assert c.program is not None
        assert c.program.segments == c.segments
        ov = sel._protocol_overhead(c.protocol, msg, COMM8)
        assert math.isclose(c.predicted_s,
                            c.program.cost(msg, COMM8) + ov, rel_tol=1e-12)


def test_priced_program_is_the_executed_program():
    """The engine's memoized compile of the chosen schedule returns THE
    program object the selector priced — one artifact for cost and
    execution, compiled once."""
    sel = Selector()
    c = sel.choose("allreduce", 4 << 20, COMM8)
    executed = c.schedule.compile(codec=c.codec)
    assert executed is c.program


def test_simulator_returns_the_cost_it_executes():
    """simulate_with_cost prices the same compiled program it ran."""
    sched = A.ring_allreduce(COMM8)
    xs = [np.full((16,), float(r), np.float32) for r in range(8)]
    bufs, t = sim.simulate_with_cost(sched, xs, COMM8, segments=4)
    for b in bufs:
        np.testing.assert_allclose(b, np.full((16,), 28.0), atol=1e-5)
    assert t == compile_schedule(sched, segments=4).cost(
        xs[0].nbytes, COMM8)


@pytest.mark.parametrize("gen", [A.ring_allreduce,
                                 A.recursive_halving_reduce_scatter],
                         ids=["ring", "recursive_halving"])
def test_simulator_and_engine_agree_on_cost(gen):
    """The simulator's reported cost is the cost of the engine-side
    artifact: `simulate_with_cost` and the selector's `price_program`
    walk the SAME memoized compile, so model evaluation and execution
    can never quote different numbers for one program."""
    sched = gen(COMM8)
    xs = [np.arange(64, dtype=np.float32) + r for r in range(8)]
    for k in (1, 4):
        _bufs, t = sim.simulate_with_cost(sched, xs, COMM8, segments=k)
        engine_prog = sched.with_segments(k).compile()
        assert t == engine_prog.cost(xs[0].nbytes, COMM8)
        sel = Selector()
        priced = sel.price_program(engine_prog, "rendezvous",
                                   xs[0].nbytes, COMM8)
        assert math.isclose(
            priced, t + COMM8.hw.rendezvous_rtt, rel_tol=1e-12)


def test_streamed_and_segloop_costs_disagree_where_the_model_says():
    """The split model is visible through simulate_with_cost: the same
    schedule executed streamed vs stream=False returns identical buffers
    but different costs — only the streamed program earns the cross-step
    credit. (Identical costs here would mean the old, dishonest model.)"""
    sched = A.ring_reduce_scatter(COMM8)
    # large enough that the per-segment wire payload clears the Rx floor
    xs = [np.arange(1 << 16, dtype=np.float32) + r for r in range(8)]
    fused_bufs, t_fused = sim.simulate_with_cost(sched, xs, COMM8,
                                                 segments=4)
    plain_bufs, t_plain = sim.simulate_with_cost(sched, xs, COMM8,
                                                 segments=4, stream=False)
    for a, b in zip(fused_bufs, plain_bufs):
        np.testing.assert_array_equal(a, b)
    assert t_fused < t_plain
    assert t_plain == compile_schedule(sched, segments=4,
                                       stream=False).cost(
        xs[0].nbytes, COMM8)


def test_compile_rejects_zero_segments():
    with pytest.raises(ValueError):
        compile_schedule(A.ring_reduce_scatter(COMM8), segments=0)
