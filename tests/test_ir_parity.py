"""The micro-op IR contract, end to end.

1. Oracle parity: EVERY algorithm in `core/algorithms.py` x {unsegmented,
   segmented} x {fp32, int8 codec}, executed by the jax engine through
   `execute_program`, against `simulator.oracle` on 2–8 ranks.
2. Simulator parity: the numpy executor runs the SAME compiled Program and
   must match the engine (the "bus functional model" property).
3. Program structure: rings compile to rolled LOOPs (the memory-safety
   contract), trees/hypercubes unroll, bruck segments its masked steps.
4. The legacy per-algorithm lowerings stay deleted (grep guard, mirrored
   in CI).
5. `register_collective`: an out-of-tree schedule lowers through the same
   selector + executor (the "new collectives without re-synthesis" path).
"""
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import CollectiveEngine, Schedule, Sel, Step, plugins
from repro.core import algorithms as A
from repro.core import simulator as sim
from repro.core.program import Copy, Loop, SegLoop, compile_schedule
from repro.core.schedule import SEL_MASK
from repro.core.topology import Communicator, make_mesh

_MESHES = {}


def _env(n):
    if n not in _MESHES:
        mesh = make_mesh((n,), ("x",))
        _MESHES[n] = (CollectiveEngine(mesh, backend="microcode"), mesh)
    return _MESHES[n]


def _run(mesh, fn, x):
    g = jax.jit(jax.shard_map(fn, mesh=mesh, in_specs=P("x"),
                              out_specs=P("x"), check_vma=False))
    return np.asarray(g(jnp.asarray(x)))


def _pow2_only(coll, algo):
    from repro.core.selector import _POW2_ONLY
    return (coll, algo) in _POW2_ONLY


# every (collective, algorithm) the generator registry knows
ALL_ALGOS = sorted({(c, a) for (c, a) in A.GENERATORS})


def _engine_call(eng, coll, algo, segments):
    kw = {"algorithm": algo}
    if segments is not None:
        kw["segments"] = segments

    def fn(xs):
        x = xs[0]
        if coll == "allreduce":
            return eng.allreduce(x, "x", **kw)[None]
        if coll == "reduce_scatter":
            return eng.reduce_scatter(x, "x", **kw)[None]
        if coll == "allgather":
            return eng.allgather(x, "x", **kw)[None]
        if coll == "bcast":
            return eng.bcast(x, "x", root=1, **kw)[None]
        if coll == "reduce":
            return eng.reduce(x, "x", root=1, **kw)[None]
        if coll == "gather":
            kw.pop("segments", None)
            return eng.gather(x, "x", root=1, **kw)[None]
        if coll == "alltoall":
            n = eng.mesh.shape["x"]
            return eng.alltoall(x.reshape(n, -1), "x",
                                **kw).reshape(1, -1)
        raise ValueError(coll)
    return fn


def _check(coll, n, out, X):
    """Assert engine output against the numpy oracle, per collective."""
    flat = X.reshape(n, -1)
    if coll == "allreduce":
        for r in range(n):
            np.testing.assert_allclose(out[r], flat.sum(0), atol=1e-4)
    elif coll == "reduce_scatter":
        cs = flat.shape[1] // n
        ref = sim.oracle("reduce_scatter", list(flat))
        for r in range(n):
            np.testing.assert_allclose(out[r], ref[r * cs:(r + 1) * cs],
                                       atol=1e-4)
    elif coll == "allgather":
        np.testing.assert_allclose(out[0], flat.reshape(-1), atol=0)
    elif coll == "bcast":
        for r in range(n):
            np.testing.assert_allclose(out[r], flat[1])
    elif coll == "reduce":
        np.testing.assert_allclose(out[1], flat.sum(0), atol=1e-4)
    elif coll == "gather":
        np.testing.assert_allclose(out[1], flat.reshape(-1))
    elif coll == "alltoall":
        refs = sim.oracle("alltoall", list(flat))
        for r in range(n):
            np.testing.assert_allclose(out[r], refs[r])
    else:
        raise ValueError(coll)


@pytest.mark.parametrize("coll,algo", ALL_ALGOS,
                         ids=[f"{c}-{a}" for c, a in ALL_ALGOS])
@pytest.mark.parametrize("n", [3, 8])
def test_engine_matches_oracle(coll, algo, n):
    if _pow2_only(coll, algo) and n & (n - 1):
        pytest.skip("pow2-only generator")
    eng, mesh = _env(n)
    X = np.random.default_rng(n).normal(
        size=(n, n * 8)).astype(np.float32)
    out = _run(mesh, _engine_call(eng, coll, algo, None), X)
    _check(coll, n, out, X)


@pytest.mark.parametrize("coll,algo", ALL_ALGOS,
                         ids=[f"{c}-{a}" for c, a in ALL_ALGOS])
def test_engine_matches_oracle_segmented(coll, algo):
    """Segmented execution (k=4): same oracle, and bitwise-equal to the
    unsegmented run — segmentation cuts elementwise combines into
    disjoint pieces, it must never change values."""
    n = 8
    eng, mesh = _env(n)
    X = np.random.default_rng(21).normal(
        size=(n, n * 8)).astype(np.float32)
    base = _run(mesh, _engine_call(eng, coll, algo, 1), X)
    seg = _run(mesh, _engine_call(eng, coll, algo, 4), X)
    np.testing.assert_array_equal(seg, base)
    _check(coll, n, seg, X)


_CODEC_ALGOS = [(c, a) for (c, a) in ALL_ALGOS
                if c in ("allreduce", "reduce_scatter")]


@pytest.mark.parametrize("coll,algo", _CODEC_ALGOS,
                         ids=[f"{c}-{a}" for c, a in _CODEC_ALGOS])
@pytest.mark.parametrize("segments", [1, 4])
def test_engine_codec_matches_oracle(coll, algo, segments):
    """int8-compressed wires x {unsegmented, segmented} stay within
    quantization tolerance of the oracle, and segmented == unsegmented
    bitwise (per-segment scale reuse)."""
    n = 8
    eng, mesh = _env(n)
    # payload sized so each chunk is whole scale blocks (scale reuse)
    X = (np.random.default_rng(5).normal(size=(n, 4096)) * 30).astype(
        np.float32)

    def call(k):
        def fn(xs):
            x = xs[0]
            m = getattr(eng, coll)
            return m(x, "x", algorithm=algo, compression="int8",
                     segments=k)[None]
        return fn

    out = _run(mesh, call(segments), X)
    base = _run(mesh, call(1), X)
    np.testing.assert_array_equal(out, base)
    flat = X.reshape(n, -1)
    ref = flat.sum(0)
    if coll == "allreduce":
        got = out[0]
        ref_r = ref
    else:
        cs = flat.shape[1] // n
        got = out[0]
        ref_r = ref[:cs]
    rel = np.abs(got - ref_r).max() / np.abs(ref_r).max()
    assert rel < 0.05, (coll, algo, segments, rel)


@pytest.mark.parametrize("coll,algo", ALL_ALGOS,
                         ids=[f"{c}-{a}" for c, a in ALL_ALGOS])
@pytest.mark.parametrize("segments", [1, 4])
def test_simulator_runs_same_program(coll, algo, segments):
    """The numpy executor runs the same compiled Program and agrees with
    the oracle — so what the simulator validates IS the engine's path."""
    n = 8
    comm = Communicator(axis="x", size=n)
    gen = A.GENERATORS[(coll, algo)]
    import inspect
    kw = {}
    if "root" in inspect.signature(gen).parameters:
        kw["root"] = 1
    sched = gen(comm, **kw)
    rng = np.random.default_rng(33)
    chunks = sched.chunks
    xs = [rng.normal(size=(chunks * 4,)).astype(np.float32)
          for _ in range(n)]
    if coll in ("allgather", "gather"):
        # engine-style buffer prep: own shard at the owned slot
        data = [rng.normal(size=(4,)).astype(np.float32) for _ in range(n)]
        xs = []
        for r in range(n):
            buf = np.zeros((n * 4,), np.float32)
            slot = r if sched.chunk_coords == "absolute" else (r - 1) % n
            buf[slot * 4:(slot + 1) * 4] = data[r]
            xs.append(buf)
    out = sim.simulate(sched, xs, segments=segments)
    if coll == "allreduce":
        ref = sim.oracle("allreduce", xs)
        for r in range(n):
            np.testing.assert_allclose(out[r], ref, atol=1e-4)
    elif coll == "reduce_scatter":
        ref = sim.oracle("reduce_scatter", xs)
        cs = xs[0].shape[0] // n
        for r in range(n):
            own = sched.owned_chunk(r)
            np.testing.assert_allclose(
                out[r][own * cs:(own + 1) * cs],
                ref[own * cs:(own + 1) * cs], atol=1e-4)
    elif coll == "allgather":
        ref = np.concatenate(data)
        for r in range(n):
            np.testing.assert_allclose(out[r], ref)
    elif coll == "gather":
        ref = np.concatenate(data)
        got = out[1]
        if sched.chunk_coords == "relative":
            got = np.roll(got.reshape(n, -1), 1, axis=0).reshape(-1)
        np.testing.assert_allclose(got, ref)
    elif coll == "bcast":
        for r in range(n):
            np.testing.assert_allclose(out[r], xs[1])
    elif coll == "reduce":
        np.testing.assert_allclose(out[1], sim.oracle("allreduce", xs),
                                   atol=1e-4)
    elif coll == "alltoall":
        refs = sim.oracle("alltoall", xs)
        for r in range(n):
            np.testing.assert_allclose(out[r], refs[r])


# -- program structure: the compilation contract ------------------------------

def test_ring_compiles_to_rolled_loops():
    """O(n)-step rings MUST coalesce into LOOP micro-ops (one lax.scan,
    one live buffer) — the memory-safety property the hand-written loops
    existed for."""
    comm = Communicator(axis="x", size=8)
    prog = compile_schedule(A.ring_allreduce(comm))
    loops = [op for op in prog.ops if isinstance(op, Loop)]
    assert len(loops) == 2  # RS phase + AG phase
    assert all(lp.trip == 7 and lp.period == 1 for lp in loops)
    assert len(prog.ops) == 2  # nothing unrolled

    prog = compile_schedule(A.bidi_ring_allreduce(comm))
    loops = [op for op in prog.ops if isinstance(op, Loop)]
    assert len(loops) == 2
    assert all(lp.trip == 7 and lp.period == 2 for lp in loops)

    prog = compile_schedule(A.ring_reduce(comm))  # relay='received'
    assert len(prog.ops) == 1 and isinstance(prog.ops[0], Loop)


def test_trees_unroll_and_bruck_segments_masks():
    comm = Communicator(axis="x", size=8)
    prog = compile_schedule(A.binomial_tree_bcast(comm))
    assert not any(isinstance(op, Loop) for op in prog.ops)  # log n steps

    prog = compile_schedule(A.bruck_alltoall(comm), segments=4)
    assert isinstance(prog.ops[0], Copy) and prog.ops[0].kind == "bruck_pre"
    assert isinstance(prog.ops[-1], Copy) \
        and prog.ops[-1].kind == "bruck_post"
    segs = [op for op in prog.ops if isinstance(op, SegLoop)]
    assert len(segs) == 3  # all log2(8) masked phases segment
    assert all(op.body[-1].sel.kind == SEL_MASK for op in segs)


def test_compile_is_memoized():
    comm = Communicator(axis="x", size=8)
    sched = A.ring_allreduce(comm)
    assert sched.compile() is sched.compile()
    assert sched.compile(segments=4) is not sched.compile()


# -- no resurrection of the per-algorithm lowerings ---------------------------

def test_legacy_loop_lowerings_stay_deleted():
    """Mirror of the CI grep guard: the retired entry points must not
    reappear in the engine source (golden copies live under tests/)."""
    src = pathlib.Path(__file__).resolve().parent.parent / "src" / "repro"
    banned = ("ring_reduce_scatter_loop", "ring_allgather_loop",
              "ring_allreduce_loop", "bidi_ring_allreduce_loop",
              "linear_alltoall_collect", "interpret_schedule")
    hits = []
    for path in src.rglob("*.py"):
        text = path.read_text()
        hits += [(str(path), name) for name in banned if name in text]
    assert not hits, f"legacy data-plane entry points resurfaced: {hits}"


# -- register_collective: new collectives without re-synthesis ----------------

def _ring_shift_exchange(comm, op="add"):
    """Out-of-tree demo schedule: every rank combines its +1 ring
    neighbour's contribution into its buffer (one step)."""
    n = comm.size
    return Schedule(
        name="shift_exchange", collective="shift_exchange", nranks=n,
        steps=(Step(perm=tuple(comm.ring_perm(1)), op=op,
                    send_sel=Sel.all(), recv_sel=Sel.all(),
                    bytes_frac=1.0, uniform=True),),
        chunks=1, result="full", relay="original",
    )


def test_register_collective_runs_through_executor():
    plugins.register_collective("shift_exchange", _ring_shift_exchange,
                                algorithm="ring_shift")
    try:
        eng, mesh = _env(8)
        X = np.random.default_rng(7).normal(size=(8, 16)).astype(np.float32)
        out = _run(mesh, lambda xs: eng.collective(
            "shift_exchange", xs[0], "x")[None], X)
        for r in range(8):
            np.testing.assert_allclose(out[r], X[r] + X[(r - 1) % 8],
                                       atol=1e-6)
        # the selector priced it like a built-in
        ch = eng.selector.choose("shift_exchange", X[0].nbytes,
                                 eng.comm("x"))
        assert ch.algorithm == "ring_shift"
        # and the simulator executes the same compiled program
        sched = _ring_shift_exchange(Communicator(axis="x", size=8))
        outs = sim.simulate(sched, list(X))
        for r in range(8):
            np.testing.assert_allclose(outs[r], X[r] + X[(r - 1) % 8],
                                       atol=1e-6)
    finally:
        plugins.unregister_collective("shift_exchange")
