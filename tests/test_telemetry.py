"""Unified telemetry (core/telemetry.py).

Four contracts:

  * tracer semantics — process-default no-op, `use()` scoping, spans /
    instants / counters, and a Chrome trace export whose control-plane
    spans are well-nested and whose per-track timestamps are monotone;
  * the `MetricsRegistry` behind every legacy `.stats` view stays
    read-compatible (mapping equality with plain dicts, live reads);
  * `MeshMakespan.timeline()` reconstructs the composed makespan
    BITWISE — the max interval end equals `mesh_makespan_s` with `==`,
    across single-queue, shared-link, disjoint-fabric, dep-chained, and
    tiered-fault scenarios;
  * observability is read-only: enabling a tracer changes no priced or
    simulated bit (pricing never reads the tracer).
"""
import importlib.util
import json
import pathlib
import types

import numpy as np
import pytest

from repro.core import (
    CollectiveEngine, FaultPlan, FaultyTransport, MeshMakespan, PricingEnv,
    Selector, TIERS, TransportTimeout, telemetry,
)
from repro.core.sequencer import Request, Sequencer

_ROOT = pathlib.Path(__file__).resolve().parent.parent


def _load_script(name):
    spec = importlib.util.spec_from_file_location(
        name, _ROOT / "scripts" / f"{name}.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


@pytest.fixture()
def eng8(mesh8):
    return CollectiveEngine(mesh8)


@pytest.fixture()
def eng222(mesh222):
    return CollectiveEngine(mesh222)


def _fill(seq, axis, nbytes, n=4, collective="allreduce"):
    for _ in range(n):
        seq.issue(collective, np.zeros((nbytes // 4,), np.float32), axis)


def _feeds(reqs, seed, n=8):
    rng = np.random.default_rng(seed)
    return {r: [rng.integers(-20, 20, size=r.operand.shape)
                .astype(r.dtype) for _ in range(n)]
            for r in reqs if not isinstance(r.operand, Request)}


# --------------------------------------------------------------------------
# Tracer semantics
# --------------------------------------------------------------------------

def test_default_tracer_is_noop():
    tr = telemetry.current()
    assert tr is telemetry.NULL and not tr.enabled
    with tr.span("x", a=1) as sp:   # all free no-ops, never raise
        sp.add(b=2)
    tr.instant("x")
    tr.counter("c", 1)
    tr.interval("i", "track", 0.0, 1.0)
    tr.ingest_timeline({"queues": [], "requests": [], "links": []})


def test_use_scoping_nests_and_restores():
    outer, inner = telemetry.Tracer(), telemetry.Tracer()
    assert telemetry.current() is telemetry.NULL
    with telemetry.use(outer):
        assert telemetry.current() is outer
        with telemetry.use(inner):
            assert telemetry.current() is inner
        assert telemetry.current() is outer
    assert telemetry.current() is telemetry.NULL


def test_span_records_args_exceptions_and_snapshot():
    tr = telemetry.Tracer()
    with tr.span("work", track="t", phase="a") as sp:
        sp.add(outcome="ok")
    with pytest.raises(RuntimeError):
        with tr.span("work", track="t"):
            raise RuntimeError("boom")
    tr.instant("mark", track="t", detail=1)
    tr.counter("depth", 3, track="t")
    snap = tr.snapshot()
    assert snap["span.work.count"] == 2
    assert snap["instant.mark.count"] == 1
    assert snap["counter.depth"] == 3
    failed = [e for e in tr._events
              if e["type"] == "span" and "error" in e["args"]]
    assert len(failed) == 1 and failed[0]["args"]["error"] == "RuntimeError"


# --------------------------------------------------------------------------
# Chrome trace-event schema validation
# --------------------------------------------------------------------------

def _validate_chrome_trace(doc):
    """Schema checks: pid/tid/ts present and monotone per track, every
    used track named by thread_name metadata, and control-plane spans
    well-nested per track (virtual-clock intervals are occupancy
    windows, which legitimately overlap)."""
    assert isinstance(doc["traceEvents"], list)
    per_track = {}
    named = set()
    for ev in doc["traceEvents"]:
        assert ev["ph"] in ("X", "i", "C", "M")
        if ev["ph"] == "M":
            if ev["name"] == "thread_name":
                named.add((ev["pid"], ev["tid"]))
            continue
        assert isinstance(ev["pid"], int) and isinstance(ev["tid"], int)
        assert isinstance(ev["ts"], (int, float))
        if ev["ph"] == "X":
            assert ev["dur"] >= 0
        per_track.setdefault((ev["pid"], ev["tid"]), []).append(ev)
    assert set(per_track) <= named, "unnamed tracks in trace"
    for (pid, _tid), evs in per_track.items():
        last = None
        stack = []  # open span end times (well-nestedness check)
        for ev in evs:
            assert last is None or ev["ts"] >= last, \
                "timestamps not monotone within a track"
            last = ev["ts"]
            if ev["ph"] != "X" or pid != telemetry.CONTROL_PID:
                continue
            start, end = ev["ts"], ev["ts"] + ev["dur"]
            while stack and stack[-1] <= start:
                stack.pop()
            if stack:
                assert end <= stack[-1], "partially-overlapping spans"
            stack.append(end)


def test_control_plane_spans_validate_and_carry_margin(eng8):
    with telemetry.use(telemetry.Tracer()) as tr:
        sel = Selector()
        sel.choose("allreduce", 1 << 18, eng8.comm("x"))
        sel.choose("allreduce", 1 << 18, eng8.comm("x"))   # memoized
    doc = tr.to_chrome_trace()
    _validate_chrome_trace(doc)
    snap = tr.snapshot()
    assert snap["span.selector.choose.count"] == 1
    assert snap["instant.selector.cache_hit.count"] == 1
    assert snap["span.compile.count"] >= 1
    ev = next(e for e in doc["traceEvents"]
              if e.get("ph") == "X" and e["name"] == "selector.choose")
    assert ev["args"]["candidates_priced"] > 1
    assert ev["args"]["algorithm"] and ev["args"]["protocol"]
    # the margin is winner-to-runner-up, never negative without tuning
    assert ev["args"]["margin_s"] is None or ev["args"]["margin_s"] >= 0.0


def test_compile_span_records_fusion_passes(eng8):
    from repro.core import program as program_mod
    sched = eng8._cached_schedule("allreduce", "ring",
                                  eng8.comm("x"), 0, "add")
    program_mod._COMPILE_CACHE.pop((sched, 4, None, True, True), None)
    with telemetry.use(telemetry.Tracer()) as tr:
        program_mod.compile_schedule(sched, segments=4)
        program_mod.compile_schedule(sched, segments=4)   # memoized now
    snap = tr.snapshot()
    assert snap["span.compile.count"] == 1
    assert snap["instant.compile.cache_hit.count"] == 1
    ev = next(e for e in tr.to_chrome_trace()["traceEvents"]
              if e.get("ph") == "X" and e["name"] == "compile")
    passes = {p["pass"]: p for p in ev["args"]["passes"]}
    assert set(passes) == {"fuse_streams", "fuse_chains",
                           "fuse_stacked_recv"}
    assert passes["fuse_streams"]["ran"] is True
    assert passes["fuse_stacked_recv"] == {
        "pass": "fuse_stacked_recv", "ran": False, "reason": "segments > 1"}
    for rec in passes.values():
        if rec["ran"] and not rec["accepted"]:
            assert rec["reason"] == "no fusible run"
    assert ev["args"]["verify"] in ("off", "structural", "full")


def test_transport_retry_and_timeout_markers():
    with telemetry.use(telemetry.Tracer()) as tr:
        t = FaultyTransport(plan=FaultPlan(drops=frozenset({(0, 0, 1)})),
                            tier=TIERS["tcp-like"])
        t.deliver(0, 1)    # first attempt drops; the tier retransmits
    ev = next(e for e in tr.to_chrome_trace()["traceEvents"]
              if e.get("name") == "transport.retry")
    assert ev["args"] == {"src": 0, "dst": 1, "exchange": 0, "retries": 1,
                          "backoff_s": ev["args"]["backoff_s"],
                          "tier": "tcp-like"}
    assert ev["args"]["backoff_s"] > 0.0
    with telemetry.use(telemetry.Tracer()) as tr:
        t = FaultyTransport(plan=FaultPlan(drops=frozenset({(0, 0, 1)})),
                            tier=TIERS["udp-like"])   # no retries
        with pytest.raises(TransportTimeout):
            t.deliver(0, 1)
    assert tr.snapshot()["instant.transport.timeout.count"] == 1


# --------------------------------------------------------------------------
# MetricsRegistry + read-compatible .stats views
# --------------------------------------------------------------------------

def test_metrics_registry_counters_gauges_records():
    reg = telemetry.MetricsRegistry()
    reg.counter("n")
    view = reg.view()
    assert view == {"n": 0}            # mapping equality with plain dicts
    reg.inc("n")
    reg.inc("n", 2)
    assert view["n"] == 3              # views are live, not copies
    reg.set("g", 1.5)
    assert dict(view) == {"n": 3, "g": 1.5}
    view["g"] = 2.5                    # out-of-tree write-through shim
    assert reg.get("g") == 2.5
    assert reg.record(step=0, loss=1.0) == {"step": 0, "loss": 1.0}
    assert reg.records() == [{"step": 0, "loss": 1.0}]
    assert reg.snapshot() == {"n": 3, "g": 2.5}
    assert view.get("missing") is None and len(view) == 2


def test_component_stats_views_read_compatible(eng8):
    assert eng8.stats == {"gen_calls": 0, "sched_cache_hits": 0}
    assert eng8.selector.stats == {"choose_calls": 0, "cache_hits": 0,
                                   "gen_calls": 0}
    seq = Sequencer(eng8)
    assert seq.stats == {"issued": 0, "executed": 0,
                         "coalesced_buckets": 0, "coalesced_requests": 0}
    _fill(seq, "x", 1 << 16, n=2)
    assert seq.stats["issued"] == 2 and seq.metrics.get("issued") == 2
    seq.clear()


# --------------------------------------------------------------------------
# The timeline invariant: max interval end == mesh_makespan_s, bitwise
# --------------------------------------------------------------------------

def _max_end(tl):
    return max(iv["end_s"] for part in ("queues", "requests", "links")
               for iv in tl[part])


def test_timeline_bitwise_single_queue(eng8):
    seq = Sequencer(eng8)
    _fill(seq, "x", 1 << 20)
    mm = MeshMakespan.of(seq)
    tl = mm.timeline()
    assert _max_end(tl) == tl["end_s"] == mm.total() == seq.makespan("x")
    seq.clear()


def test_timeline_bitwise_shared_link(eng8):
    a, b = Sequencer(eng8), Sequencer(eng8)
    _fill(a, "x", 1 << 22, n=4)
    _fill(b, "x", 1 << 22, n=4)
    mm = MeshMakespan().add(a, "x").add(b, "x")
    tl = mm.timeline()
    assert _max_end(tl) == tl["end_s"] == mm.total()
    # shared-link serialization is visible: the ICI link track carries
    # both queues' wire windows back to back
    wire = [iv for iv in tl["links"] if iv["name"] == "wire"]
    assert len(wire) == 8
    a.clear()
    b.clear()


def test_timeline_bitwise_disjoint_fabrics(eng222):
    a, b = Sequencer(eng222), Sequencer(eng222)
    _fill(a, "data", 1 << 18, n=3)
    _fill(b, "model", 1 << 18, n=3)
    mm = MeshMakespan().add(a, "data").add(b, "model")
    tl = mm.timeline()
    assert _max_end(tl) == tl["end_s"] == mm.total()
    assert {iv["link"][:2][0] for iv in tl["links"]} == {"ici"}
    assert len({iv["track"] for iv in tl["links"]
                if iv["name"] == "wire"}) == 2   # two independent links
    a.clear()
    b.clear()


def test_timeline_bitwise_dep_chain(eng8):
    seq = Sequencer(eng8)
    r = seq.issue("reduce_scatter", np.zeros((1 << 18,), np.float32), "x")
    seq.issue("allgather", r, "x")
    mm = MeshMakespan.of(seq)
    tl = mm.timeline()
    assert _max_end(tl) == tl["end_s"] == mm.total() == seq.makespan("x")
    # the dependent request starts exactly at its dependency's chain end
    first, second = tl["requests"]
    assert second["start_s"] == first["end_s"] > 0.0
    seq.clear()


def test_timeline_bitwise_faulty_tier(eng8):
    env = PricingEnv(tier=TIERS["tcp-like"], drop_prob=0.1)
    seq = Sequencer(eng8)
    _fill(seq, "x", 1 << 18)
    mm = MeshMakespan.of(seq, env)
    tl = mm.timeline()
    assert _max_end(tl) == tl["end_s"] == mm.total() \
        == seq.makespan("x", env=env)
    seq.clear()


def test_timeline_ingest_exports_valid_trace(eng222):
    seq = Sequencer(eng222)
    r = seq.issue("reduce_scatter", np.zeros((1 << 16,), np.float32),
                  "data")
    seq.issue("allgather", r, "data")
    _fill(seq, "model", 1 << 16, n=2)
    tl = MeshMakespan.of(seq).timeline()
    tr = telemetry.Tracer()
    tr.ingest_timeline(tl)
    doc = tr.to_chrome_trace()
    _validate_chrome_trace(doc)
    names = {m["args"]["name"] for m in doc["traceEvents"]
             if m.get("ph") == "M" and m["name"] == "thread_name"}
    assert any(n.startswith("queue:") for n in names)
    assert any(n.startswith("link:") for n in names)
    seq.clear()


# --------------------------------------------------------------------------
# simulate_drain trace: validate + round-trip through trace_report.py
# --------------------------------------------------------------------------

def test_simulate_drain_trace_validates_and_round_trips(eng8, tmp_path):
    seq = Sequencer(eng8)
    with telemetry.use(telemetry.Tracer()) as tr:
        reqs = [seq.issue("allreduce", np.zeros((256,), np.float32), "x",
                          algorithm="ring") for _ in range(2)]
        seq.simulate_drain(
            _feeds(reqs, seed=3),
            fault_plan=FaultPlan(drops=frozenset({(0, 0, 1)})),
            tier=TIERS["tcp-like"])
    doc = tr.to_chrome_trace()
    _validate_chrome_trace(doc)
    names = [e.get("name") for e in doc["traceEvents"]]
    assert "request.issued" in names and "request.done" in names
    assert "transport.retry" in names    # the injected drop, recovered
    path = tmp_path / "trace.json"
    path.write_text(json.dumps(doc))
    report = _load_script("trace_report")
    rep = report.summarize(report.load_events(str(path)))
    assert rep["virtual_end_s"] > 0.0
    assert rep["links"], "per-link utilization missing"
    assert all(0.0 < d["utilization"] <= 1.0 for d in rep["links"].values())
    assert len(rep["requests"]) == 2
    for r in rep["requests"]:
        assert r["status"] == "DONE"
        assert r["wire_s"] > 0.0 and r["lat_s"] > 0.0
        assert r["queue_wait_s"] >= 0.0 and r["dep_stall_s"] >= 0.0
    # second ring serialized behind the first: nonzero queue wait, and
    # offenders come back sorted by it
    assert rep["requests"][1]["queue_wait_s"] > 0.0
    waits = [r["queue_wait_s"] for r in rep["offenders"]]
    assert waits == sorted(waits, reverse=True)
    # the CLI itself runs on the same file (text and JSON modes)
    assert report.main([str(path), "--top", "3"]) == 0
    assert report.main([str(path), "--json"]) == 0


def test_simulate_drain_trace_attributes_dep_stall(eng8):
    seq = Sequencer(eng8)
    r = seq.issue("reduce_scatter", np.zeros((256,), np.float32), "x")
    seq.issue("allgather", r, "x")
    with telemetry.use(telemetry.Tracer()) as tr:
        seq.simulate_drain(_feeds([r], seed=5))
    reqs = [e for e in tr.to_chrome_trace()["traceEvents"]
            if e.get("ph") == "X" and e.get("name") == "request"]
    assert len(reqs) == 2
    dep = reqs[1]["args"]
    assert dep["dep_stall_s"] > 0.0       # waited on the reduce_scatter
    assert dep["queue_wait_s"] == 0.0     # dispatched as soon as ready
    assert dep["status"] == "DONE"


def test_simulate_drain_timeout_traced_as_terminal(eng8):
    seq = Sequencer(eng8)
    r = seq.issue("allreduce", np.zeros((1 << 20,), np.float32), "x",
                  timeout=1e-12)
    with telemetry.use(telemetry.Tracer()) as tr:
        seq.simulate_drain(_feeds([r], seed=6))
    assert r.status == Request.TIMED_OUT
    events = tr.to_chrome_trace()["traceEvents"]
    iv = next(e for e in events
              if e.get("ph") == "X" and e.get("name") == "request")
    assert iv["args"]["status"] == "TIMED_OUT"
    term = next(e for e in events if e.get("name") == "request.terminal")
    assert term["args"]["status"] == Request.TIMED_OUT


# --------------------------------------------------------------------------
# Read-only guarantee: tracing changes no priced or simulated bit
# --------------------------------------------------------------------------

def test_tracing_is_read_only_for_selection_and_pricing(eng8):
    comm = eng8.comm("x")
    base = Selector().choose("allreduce", 1 << 20, comm)
    with telemetry.use(telemetry.Tracer()):
        traced = Selector().choose("allreduce", 1 << 20, comm)
    assert traced.predicted_s == base.predicted_s
    assert (traced.algorithm, traced.protocol, traced.segments) \
        == (base.algorithm, base.protocol, base.segments)

    seq = Sequencer(eng8)
    _fill(seq, "x", 1 << 20)
    ref_makespan = seq.makespan("x")
    ref_report = MeshMakespan.of(seq).report()
    with telemetry.use(telemetry.Tracer()):
        assert seq.makespan("x") == ref_makespan
        assert MeshMakespan.of(seq).report() == ref_report
    seq.clear()


def test_tracing_is_read_only_for_simulate_drain(eng8):
    def run():
        seq = Sequencer(eng8)
        reqs = [seq.issue("allreduce", np.zeros((128,), np.float32), "x",
                          algorithm="ring") for _ in range(2)]
        return reqs, seq.simulate_drain(_feeds(reqs, seed=7))

    ref_reqs, ref = run()
    with telemetry.use(telemetry.Tracer()):
        reqs, out = run()
    for rr, r in zip(ref_reqs, reqs):
        for a, b in zip(ref[rr], out[r]):
            np.testing.assert_array_equal(a, b)


# --------------------------------------------------------------------------
# Trainer._queue_stats: both paths are explicit
# --------------------------------------------------------------------------

def _trainer_queue_stats(engine):
    from repro.runtime.trainer import Trainer
    stub = types.SimpleNamespace(ts=types.SimpleNamespace(
        ctx=types.SimpleNamespace(engine=engine)))
    return Trainer._queue_stats(stub)


def test_trainer_queue_stats_no_queue_is_explicit_none(eng8):
    assert eng8._queue is None
    assert _trainer_queue_stats(eng8) == {
        "queue_issued": None, "queue_coalesced": None,
        "grad_sync_makespan_s": None}


def test_trainer_queue_stats_with_live_queue(eng8):
    _fill(eng8.queue, "x", 1 << 16, n=2)
    eng8.metrics.set("grad_sync_makespan_s", 1.25)
    assert _trainer_queue_stats(eng8) == {
        "queue_issued": 2, "queue_coalesced": 0,
        "grad_sync_makespan_s": 1.25}
    eng8.queue.clear()
