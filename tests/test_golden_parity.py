"""Bitwise parity: the unified micro-op executor vs the retired loops.

The five hand-written ring/linear lowerings deleted from core/engine.py
live on in tests/golden_loops.py as frozen oracles. Every (algorithm,
segments, codec) cell here asserts the compiled-IR data plane reproduces
the old outputs EXACTLY — the refactor moved the code, not the numbers.

One documented exception: the old loops decompressed codec wires at send
time, so their SEGMENTED compressed numerics depended on XLA fusion
context (segment counts changed results at the ulp level — the very
ROADMAP defect this refactor fixes). The new executor decompresses at
combine time, making every segment count bitwise-equal to k=1; segmented
codec cells therefore compare against the old UNSEGMENTED loop, which is
the numerics both paths agree on.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

import golden_loops as G
from repro.core import CollectiveEngine


@pytest.fixture(scope="module")
def env():
    from repro.core.topology import make_mesh
    mesh = make_mesh((8,), ("x",))
    eng = CollectiveEngine(mesh, backend="microcode")
    return eng, mesh, eng.comm("x")


def _run(mesh, fn, *xs, in_specs=None, out_specs=P("x")):
    in_specs = in_specs or tuple(P("x") for _ in xs)
    g = jax.jit(jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_vma=False))
    return np.asarray(g(*[jnp.asarray(x) for x in xs]))


# 8 ranks x 2048 elems: csize 256 = one int8 scale block per chunk at k=1,
# so segmented codec cells stay scale-block aligned
X = np.random.default_rng(11).normal(size=(8, 2048)).astype(np.float32)


@pytest.mark.parametrize("segments", [1, 2, 4, 8])
@pytest.mark.parametrize("codec", [None, "int8", "bf16"])
def test_ring_allreduce_matches_golden_loop(env, segments, codec):
    eng, mesh, comm = env
    old = _run(mesh, lambda v: G.ring_allreduce_loop(
        v[0].reshape(8, -1), "x", comm, compression=codec,
        segments=1 if codec else segments).reshape(1, -1), X)
    new = _run(mesh, lambda v: eng.allreduce(
        v[0], "x", algorithm="ring", compression=codec,
        segments=segments)[None], X)
    np.testing.assert_array_equal(new, old)


@pytest.mark.parametrize("segments", [1, 4])
@pytest.mark.parametrize("codec", [None, "int8"])
def test_bidi_ring_allreduce_matches_golden_loop(env, segments, codec):
    eng, mesh, comm = env
    old = _run(mesh, lambda v: G.bidi_ring_allreduce_loop(
        v[0].reshape(16, -1), "x", comm, compression=codec,
        segments=1 if codec else segments).reshape(1, -1), X)
    new = _run(mesh, lambda v: eng.allreduce(
        v[0], "x", algorithm="bidi_ring", compression=codec,
        segments=segments)[None], X)
    np.testing.assert_array_equal(new, old)


@pytest.mark.parametrize("segments", [1, 2, 8])
@pytest.mark.parametrize("op", ["add", "max"])
def test_ring_reduce_scatter_matches_golden_loop(env, segments, op):
    eng, mesh, comm = env
    old = _run(mesh, lambda v: G.ring_reduce_scatter_loop(
        v[0].reshape(8, -1), "x", comm, op=op, segments=segments)[None], X)
    new = _run(mesh, lambda v: eng.reduce_scatter(
        v[0], "x", op=op, algorithm="ring", segments=segments)[None], X)
    np.testing.assert_array_equal(new, old)


@pytest.mark.parametrize("segments", [1, 4])
def test_ring_allgather_matches_golden_loop(env, segments):
    eng, mesh, comm = env
    old = _run(mesh, lambda v: G.ring_allgather_loop(
        v[0], "x", comm, segments=segments).reshape(1, -1), X)
    new = _run(mesh, lambda v: eng.allgather(
        v[0], "x", algorithm="ring", segments=segments)[None], X)
    np.testing.assert_array_equal(new, old)


def test_linear_alltoall_matches_golden_collect(env):
    eng, mesh, comm = env
    old = _run(mesh, lambda v: G.linear_alltoall_collect(
        v[0].reshape(8, -1), "x", comm).reshape(1, -1), X)
    new = _run(mesh, lambda v: eng.alltoall(
        v[0].reshape(8, -1), "x", algorithm="linear").reshape(1, -1), X)
    np.testing.assert_array_equal(new, old)


def test_segmented_codec_now_matches_unsegmented(env):
    """The defect the refactor fixes, asserted from the golden side: the
    old loop's segmented codec output drifted from its own unsegmented
    output (send-time decompression, fusion-context dependent), while the
    new executor's segmented output equals unsegmented exactly."""
    eng, mesh, comm = env
    big = np.random.default_rng(12).normal(size=(8, 1 << 15)).astype(
        np.float32)
    new_k1 = _run(mesh, lambda v: eng.allreduce(
        v[0], "x", algorithm="ring", compression="int8",
        segments=1)[None], big)
    new_k8 = _run(mesh, lambda v: eng.allreduce(
        v[0], "x", algorithm="ring", compression="int8",
        segments=8)[None], big)
    np.testing.assert_array_equal(new_k8, new_k1)
    # and both agree with the old unsegmented loop bitwise
    old_k1 = _run(mesh, lambda v: G.ring_allreduce_loop(
        v[0].reshape(8, -1), "x", comm, compression="int8",
        segments=1).reshape(1, -1), big)
    np.testing.assert_array_equal(new_k1, old_k1)
