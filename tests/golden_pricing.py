"""Frozen copy of the retired `Schedule.predict_time` schedule walk.

PR 3 moved pricing onto the compiled micro-op Program (`Program.cost`);
the schedule-walk pricer was deleted from src/ (CI greps against its
resurrection). This verbatim copy is the golden oracle for the pricing
parity property test: for every program the old model could price —
uniform segmentation, per-segment wire payloads above the fabric floor —
the program walk must return the identical number.
"""


def predict_time(schedule, msg_bytes: float, hop_latency: float,
                 link_bw: float, segments=None,
                 wire_scale: float = 1.0) -> float:
    """alpha-beta time with wire segmentation (the retired schedule walk).

    Unsegmented (k=1): sum over steps of (alpha + step_bytes / bw).
    Segmented (k>1): pipeline fill/drain, sum_i t_i + (k-1) * max_i t_i
    with t_i = alpha + step_bytes_i / (k * bw), over overlap_factor.
    `wire_scale` prices compressed wires on combine steps only.
    """
    k = int(segments if segments is not None else schedule.segments)
    if k < 1:
        raise ValueError(f"segments must be >= 1, got {k}")
    total, t_max = 0.0, 0.0
    for s in schedule.steps:
        scale = wire_scale if s.op != "copy" else 1.0
        t = hop_latency + (msg_bytes * s.bytes_frac * scale) / (
            k * link_bw)
        total += t
        t_max = max(t_max, t)
    return (total + (k - 1) * t_max) / schedule.overlap_factor
