"""Frozen copy of the retired `Schedule.predict_time` schedule walk.

PR 3 moved pricing onto the compiled micro-op Program (`Program.cost`);
the schedule-walk pricer was deleted from src/ (CI greps against its
resurrection). This verbatim copy is the golden oracle for the pricing
parity property test. Since the split pricing model (PR 4), the scope of
exact parity is deliberately narrower: `predict_time` granted the
cross-step fill/drain credit to EVERY segmented schedule, so

  * k = 1 programs and k > 1 programs that fuse into a single cross-step
    STREAM / STREAM_CHAIN region still match it exactly (the credit is
    earned there), while
  * programs with SEG_LOOP-only exchanges intentionally price ABOVE it —
    their honest model is `predict_time_segloop` below.

`tests/test_program_cost.py` asserts both the surviving parity and the
intentional divergence, per algorithm x segments x codec.
"""


def predict_time(schedule, msg_bytes: float, hop_latency: float,
                 link_bw: float, segments=None,
                 wire_scale: float = 1.0) -> float:
    """alpha-beta time with wire segmentation (the retired schedule walk).

    Unsegmented (k=1): sum over steps of (alpha + step_bytes / bw).
    Segmented (k>1): pipeline fill/drain, sum_i t_i + (k-1) * max_i t_i
    with t_i = alpha + step_bytes_i / (k * bw), over overlap_factor.
    `wire_scale` prices compressed wires on combine steps only.
    """
    k = int(segments if segments is not None else schedule.segments)
    if k < 1:
        raise ValueError(f"segments must be >= 1, got {k}")
    total, t_max = 0.0, 0.0
    for s in schedule.steps:
        scale = wire_scale if s.op != "copy" else 1.0
        t = hop_latency + (msg_bytes * s.bytes_frac * scale) / (
            k * link_bw)
        total += t
        t_max = max(t_max, t)
    return (total + (k - 1) * t_max) / schedule.overlap_factor


def predict_time_segloop(schedule, msg_bytes: float, hop_latency: float,
                         link_bw: float, segments=None,
                         wire_scale: float = 1.0,
                         min_segment_bytes: float = 0.0) -> float:
    """The honest serialized model for SEG_LOOP-only programs (PR 4).

    Each step pipelines only within itself (the SEG_LOOP scan carry is a
    per-step barrier) and the steps serialize: a k-segment step pays
    k * t_seg = k_eff * alpha + step_bytes / bw, with the segment count
    clamped where the per-segment wire payload would fall below the Rx
    floor. At k = 1 this coincides with `predict_time`; at k > 1 it is
    never cheaper than k = 1.
    """
    k = int(segments if segments is not None else schedule.segments)
    if k < 1:
        raise ValueError(f"segments must be >= 1, got {k}")
    total = 0.0
    for s in schedule.steps:
        scale = wire_scale if s.op != "copy" else 1.0
        wire = msg_bytes * s.bytes_frac * scale
        k_eff = k
        while k_eff > 1 and wire / k_eff < min_segment_bytes:
            k_eff -= 1
        total += k_eff * hop_latency + wire / link_bw
    return total / schedule.overlap_factor
