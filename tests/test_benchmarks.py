"""The benchmark harness's machine-readable output (BENCH_collectives.json).

Runs only the model-based segment sweep (no device timing) so this stays
fast; the full `python -m benchmarks.run` exercises the same writer.
"""
import json

import pytest


@pytest.fixture(scope="module")
def sweep_results(tmp_path_factory):
    from benchmarks import run as bench_run
    path = tmp_path_factory.mktemp("bench") / "BENCH_collectives.json"
    returned = bench_run.main(["--only", "seg_sweep", "--json", str(path)])
    on_disk = json.loads(path.read_text())
    return returned, on_disk


def test_json_written_and_matches_returned(sweep_results):
    returned, on_disk = sweep_results
    assert on_disk["rows"] == returned["rows"]
    assert on_disk["segment_sweep"] == returned["segment_sweep"]
    assert {"jax", "backend", "device_count"} <= set(on_disk["meta"])


def test_sweep_schema(sweep_results):
    _, on_disk = sweep_results
    sweep = on_disk["segment_sweep"]
    assert sweep
    required = {"collective", "algorithm", "protocol", "nranks", "msg_bytes",
                "segments", "predicted_s", "selected"}
    for entry in sweep:
        assert required <= set(entry)
    # every (schedule, size) curve includes the 1-segment baseline
    curves = {(e["collective"], e["algorithm"], e["msg_bytes"])
              for e in sweep}
    for key in curves:
        ks = {e["segments"] for e in sweep
              if (e["collective"], e["algorithm"], e["msg_bytes"]) == key}
        assert 1 in ks and len(ks) > 1


def test_sweep_covers_newly_segmentable_schedules(sweep_results):
    """The sweep must track the tree/masked/recursive schedules that the
    micro-op executor made segmentable, not just the ring family."""
    _, on_disk = sweep_results
    algos = {(e["collective"], e["algorithm"])
             for e in on_disk["segment_sweep"]}
    assert {("reduce", "binomial_tree"), ("alltoall", "bruck"),
            ("allreduce", "halving_doubling"),
            ("reduce", "ring")} <= algos


def test_sweep_pipelining_dominates_at_1mib(sweep_results):
    """Acceptance: predicted time strictly dominates the 1-segment
    baseline for every message >= 1 MiB."""
    _, on_disk = sweep_results
    curves: dict = {}
    for e in on_disk["segment_sweep"]:
        curves.setdefault(
            (e["collective"], e["algorithm"], e["msg_bytes"]), {})[
            e["segments"]] = e["predicted_s"]
    checked = 0
    for (coll, algo, nbytes), times in curves.items():
        if nbytes < 1 << 20:
            continue
        checked += 1
        assert min(times.values()) < times[1], (coll, algo, nbytes)
    assert checked >= 3  # sweep must actually cover >= 1 MiB messages
