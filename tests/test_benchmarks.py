"""The benchmark harness's machine-readable output (BENCH_collectives.json)
and the CI perf gate over it (scripts/check_bench.py).

Runs only the model-based segment sweep (no device timing) so this stays
fast; the full `python -m benchmarks.run` exercises the same writer.
"""
import importlib.util
import json
import pathlib

import pytest


def _load_check_bench():
    path = (pathlib.Path(__file__).resolve().parent.parent / "scripts"
            / "check_bench.py")
    spec = importlib.util.spec_from_file_location("check_bench", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


@pytest.fixture(scope="module")
def sweep_results(tmp_path_factory):
    # --quick = the exact CI bench-gate subset (fig12 + seg_sweep +
    # queue_sweep), so the committed baseline is checked over every
    # gated section, not just the segment sweep
    from benchmarks import run as bench_run
    path = tmp_path_factory.mktemp("bench") / "BENCH_collectives.json"
    returned = bench_run.main(["--quick", "--json", str(path)])
    on_disk = json.loads(path.read_text())
    return returned, on_disk


def test_json_written_and_matches_returned(sweep_results):
    returned, on_disk = sweep_results
    assert on_disk["rows"] == returned["rows"]
    assert on_disk["segment_sweep"] == returned["segment_sweep"]
    assert on_disk["queue_sweep"] == returned["queue_sweep"]
    assert on_disk["hier_sweep"] == returned["hier_sweep"]
    assert on_disk["contention_sweep"] == returned["contention_sweep"]
    assert {"jax", "backend", "device_count"} <= set(on_disk["meta"])


def test_sweep_schema(sweep_results):
    _, on_disk = sweep_results
    sweep = on_disk["segment_sweep"]
    assert sweep
    required = {"collective", "algorithm", "protocol", "nranks", "msg_bytes",
                "segments", "predicted_s", "selected"}
    for entry in sweep:
        assert required <= set(entry)
    # every (schedule, size) curve includes the 1-segment baseline
    curves = {(e["collective"], e["algorithm"], e["msg_bytes"])
              for e in sweep}
    for key in curves:
        ks = {e["segments"] for e in sweep
              if (e["collective"], e["algorithm"], e["msg_bytes"]) == key}
        assert 1 in ks and len(ks) > 1


def test_sweep_covers_newly_segmentable_schedules(sweep_results):
    """The sweep must track the tree/masked/recursive schedules that the
    micro-op executor made segmentable, not just the ring family."""
    _, on_disk = sweep_results
    algos = {(e["collective"], e["algorithm"])
             for e in on_disk["segment_sweep"]}
    assert {("reduce", "binomial_tree"), ("alltoall", "bruck"),
            ("allreduce", "halving_doubling"),
            ("reduce", "ring")} <= algos


def test_sweep_pipelining_dominates_at_1mib_iff_streamed(sweep_results):
    """Acceptance, split-model form: for every message >= 1 MiB,
    predicted time strictly dominates the 1-segment baseline EXACTLY on
    the curves whose program cross-step streams; SEG_LOOP-only curves
    are serialized and their best count is the unsegmented baseline."""
    _, on_disk = sweep_results
    curves: dict = {}
    streamed: dict = {}
    for e in on_disk["segment_sweep"]:
        key = (e["collective"], e["algorithm"], e["msg_bytes"])
        curves.setdefault(key, {})[e["segments"]] = e["predicted_s"]
        streamed[key] = streamed.get(key, False) or e["streamed"]
    dominating, serialized = 0, 0
    for (coll, algo, nbytes), times in curves.items():
        if nbytes < 1 << 20:
            continue
        if streamed[(coll, algo, nbytes)]:
            dominating += 1
            assert min(times.values()) < times[1], (coll, algo, nbytes)
        else:
            serialized += 1
            assert min(times.values()) == times[1], (coll, algo, nbytes)
    assert dominating >= 3  # sweep must cover streamed >= 1 MiB curves
    assert serialized >= 1  # ... and the honestly-serialized ones


def test_sweep_marks_streamed_programs(sweep_results):
    """Sweep points carry whether the compiled program cross-step
    streams: rings at k > 1 do, recursive halving/doubling now does via
    the SEL_RANGE chain (the acceptance bit: previously non-streamable
    schedules showing streamed=true), unrolled trees never do."""
    _, on_disk = sweep_results
    sweep = on_disk["segment_sweep"]
    assert all("streamed" in e for e in sweep)
    assert any(e["streamed"] for e in sweep
               if e["algorithm"] in ("ring", "bidi_ring")
               and e["segments"] > 1)
    assert any(e["streamed"] for e in sweep
               if e["algorithm"] == "halving_doubling"
               and e["segments"] >= 4)
    assert any(e["streamed"] for e in sweep
               if e["algorithm"] == "recursive_halving"
               and e["segments"] >= 4)
    assert not any(e["streamed"] for e in sweep
                   if e["algorithm"] == "binomial_tree")
    assert not any(e["streamed"] for e in sweep if e["segments"] == 1)


# -- the queue sweep (offload request-queue makespan model) -------------------

def test_queue_sweep_schema(sweep_results):
    _, on_disk = sweep_results
    queue = on_disk["queue_sweep"]
    assert queue
    required = {"collective", "nranks", "msg_bytes", "requests",
                "makespan_s", "serial_s", "coalesced"}
    for entry in queue:
        assert required <= set(entry)
    # every size curve includes the 1-request baseline and deeper queues
    sizes = {e["msg_bytes"] for e in queue}
    for s in sizes:
        reqs = {e["requests"] for e in queue if e["msg_bytes"] == s}
        assert 1 in reqs and max(reqs) >= 4


def test_queue_makespan_beats_serial_iff_overlap(sweep_results):
    """Acceptance (queue form): a queue of >= 4 independent same-axis
    collectives prices strictly below the serial-blocking sum; a single
    request gets no credit (makespan == its own blocking cost)."""
    _, on_disk = sweep_results
    deep = 0
    for e in on_disk["queue_sweep"]:
        if e["requests"] == 1:
            assert e["makespan_s"] == pytest.approx(e["serial_s"],
                                                    rel=1e-9)
        else:
            assert e["makespan_s"] < e["serial_s"], e
            if e["requests"] >= 4:
                deep += 1
    assert deep >= 2


def test_queue_sweep_small_requests_coalesce(sweep_results):
    """Tiny same-(op, dtype) reductions fold into one bucketed program
    (the paper's many-small-calls offload win); large requests never
    bucket."""
    _, on_disk = sweep_results
    queue = on_disk["queue_sweep"]
    assert any(e["coalesced"] for e in queue
               if e["msg_bytes"] <= 64 * 1024 and e["requests"] > 1)
    assert not any(e["coalesced"] for e in queue
                   if e["msg_bytes"] > 64 * 1024)
    assert not any(e["coalesced"] for e in queue if e["requests"] == 1)


# -- the hier sweep (two-level cross-fabric allreduce model) ------------------

def test_hier_sweep_schema(sweep_results):
    _, on_disk = sweep_results
    hier = on_disk["hier_sweep"]
    assert hier
    required = {"collective", "nranks", "pod_size", "msg_bytes", "flat_s",
                "flat_algorithm", "hier_s", "hier_algorithm", "speedup",
                "dcn_ratio"}
    for entry in hier:
        assert required <= set(entry)
        assert entry["hier_algorithm"].startswith("hierarchical:")
    # both pod counts sweep the full size ladder
    for pod in (2, 4):
        sizes = {e["msg_bytes"] for e in hier if e["pod_size"] == pod}
        assert min(sizes) <= 1 << 16 and max(sizes) >= 1 << 26


def test_hier_sweep_hier_wins_at_bandwidth_sizes(sweep_results):
    """Acceptance (bench form): the two-level composition prices strictly
    below the best flat algorithm from 64 KiB through 16 MiB at both pod
    counts, and always moves fewer bytes over DCN."""
    _, on_disk = sweep_results
    checked = 0
    for e in on_disk["hier_sweep"]:
        assert e["dcn_ratio"] < 1.0, e
        if 1 << 16 <= e["msg_bytes"] <= 16 << 20:
            assert e["hier_s"] < e["flat_s"], e
            checked += 1
    assert checked >= 8


def test_check_bench_gates_hier_metrics(sweep_results, tmp_path):
    """hier_sweep points gate like queue points: a drifted hier_s (or
    flat_s) fails the build until the baseline is refreshed."""
    _, on_disk = sweep_results
    baseline = (pathlib.Path(__file__).resolve().parent.parent
                / "benchmarks" / "baseline.json")
    cb = _load_check_bench()
    for metric in ("hier_s", "flat_s"):
        drifted = json.loads(json.dumps(on_disk))
        drifted["hier_sweep"][0][metric] *= 1.25
        results = tmp_path / f"hier_drift_{metric}.json"
        results.write_text(json.dumps(drifted))
        assert cb.main([str(results), "--baseline", str(baseline)]) == 1


# -- the contention sweep (mesh-level shared-fabric makespan) -----------------

def test_contention_sweep_schema(sweep_results):
    _, on_disk = sweep_results
    cont = on_disk["contention_sweep"]
    assert cont
    required = {"collective", "nranks", "queues", "mode", "msg_bytes",
                "requests", "mesh_s", "max_queue_s", "ratio"}
    for entry in cont:
        assert required <= set(entry)
        assert entry["mode"] in ("shared", "disjoint")
    # both modes sweep every (queue count, size) grid point
    for mode in ("shared", "disjoint"):
        pts = {(e["queues"], e["msg_bytes"]) for e in cont
               if e["mode"] == mode}
        assert {q for q, _ in pts} == {1, 2, 4}
        assert min(s for _, s in pts) <= 1 << 16
        assert max(s for _, s in pts) >= 1 << 24


def test_contention_single_queue_matches_sequencer(sweep_results):
    """Acceptance (bench form, single-queue): one queue composes to
    exactly its own isolated makespan — the mesh view is bitwise free
    when there is nothing to contend with."""
    _, on_disk = sweep_results
    ones = [e for e in on_disk["contention_sweep"] if e["queues"] == 1]
    assert ones
    for e in ones:
        assert e["mesh_s"] == e["max_queue_s"]
        assert e["ratio"] == 1.0


def test_contention_shared_fabric_serializes(sweep_results):
    """Acceptance (bench form, shared): at the bandwidth-dominated
    16 MiB point, two queues on one fabric price >= 1.9x one queue and
    never above the serial sum; four queues >= 3.5x."""
    _, on_disk = sweep_results
    cont = on_disk["contention_sweep"]

    def pt(q, mode, nbytes=1 << 24):
        (e,) = [x for x in cont if x["queues"] == q and x["mode"] == mode
                and x["msg_bytes"] == nbytes]
        return e

    one = pt(1, "shared")
    two, four = pt(2, "shared"), pt(4, "shared")
    assert two["mesh_s"] >= 1.9 * one["mesh_s"]
    assert two["mesh_s"] <= 2.0 * one["mesh_s"]
    assert four["mesh_s"] >= 3.5 * one["mesh_s"]


def test_contention_disjoint_fabrics_stay_independent(sweep_results):
    """Acceptance (bench form, disjoint): two queues on different
    fabrics (ICI data axis vs the DCN pod axis) track the SLOWER queue
    — within [max, 1.05 * max] at every size."""
    _, on_disk = sweep_results
    pts = [e for e in on_disk["contention_sweep"]
           if e["queues"] == 2 and e["mode"] == "disjoint"]
    assert pts
    for e in pts:
        assert e["max_queue_s"] <= e["mesh_s"] <= 1.05 * e["max_queue_s"]


def test_check_bench_gates_contention_metrics(sweep_results, tmp_path):
    """contention_sweep points gate like the others: a drifted mesh_s
    (or max_queue_s) fails the build until the baseline is refreshed."""
    _, on_disk = sweep_results
    baseline = (pathlib.Path(__file__).resolve().parent.parent
                / "benchmarks" / "baseline.json")
    cb = _load_check_bench()
    for metric in ("mesh_s", "max_queue_s"):
        drifted = json.loads(json.dumps(on_disk))
        drifted["contention_sweep"][0][metric] *= 1.25
        results = tmp_path / f"contention_drift_{metric}.json"
        results.write_text(json.dumps(drifted))
        assert cb.main([str(results), "--baseline", str(baseline)]) == 1


# -- observability neutrality -------------------------------------------------

def test_quick_sweep_bitwise_identical_with_tracer_enabled(sweep_results):
    """The bench guard for the telemetry layer: re-running the exact CI
    gate subset under an enabled Tracer reproduces every gated number
    bitwise. The tracer is an observer — pricing never reads it — so
    `--trace` in the CI bench job cannot perturb the baseline gate."""
    from benchmarks import run as bench_run
    from repro.core import telemetry
    _, untraced = sweep_results
    with telemetry.use(telemetry.Tracer()) as tr:
        traced = bench_run.main(["--quick", "--json", ""])
    assert tr._events, "tracer recorded nothing — instrumentation gone?"
    for section in ("rows", "segment_sweep", "queue_sweep", "fault_sweep",
                    "hier_sweep", "contention_sweep"):
        assert traced[section] == untraced[section], section


# -- the CI perf gate (scripts/check_bench.py) --------------------------------

def test_check_bench_passes_against_committed_baseline(sweep_results,
                                                       tmp_path):
    """The deterministic sweep must reproduce benchmarks/baseline.json —
    the exact check the CI bench job runs. If this fails after an
    intentional cost-model change, refresh the baseline (see
    benchmarks/README.md)."""
    _, on_disk = sweep_results
    results = tmp_path / "fresh.json"
    results.write_text(json.dumps(on_disk))
    baseline = (pathlib.Path(__file__).resolve().parent.parent
                / "benchmarks" / "baseline.json")
    cb = _load_check_bench()
    assert cb.main([str(results), "--baseline", str(baseline)]) == 0


def test_check_bench_fails_on_model_drift(sweep_results, tmp_path):
    """>10% predicted-time drift on any baseline point fails the gate."""
    _, on_disk = sweep_results
    drifted = json.loads(json.dumps(on_disk))
    drifted["segment_sweep"][0]["predicted_s"] *= 1.25
    results = tmp_path / "drifted.json"
    results.write_text(json.dumps(drifted))
    baseline = (pathlib.Path(__file__).resolve().parent.parent
                / "benchmarks" / "baseline.json")
    cb = _load_check_bench()
    assert cb.main([str(results), "--baseline", str(baseline)]) == 1


def test_check_bench_gates_queue_metrics(sweep_results, tmp_path):
    """queue_sweep points gate like sweep points: a drifted makespan_s
    (or serial_s) fails the build until the baseline is refreshed."""
    _, on_disk = sweep_results
    baseline = (pathlib.Path(__file__).resolve().parent.parent
                / "benchmarks" / "baseline.json")
    cb = _load_check_bench()
    for metric in ("makespan_s", "serial_s"):
        drifted = json.loads(json.dumps(on_disk))
        drifted["queue_sweep"][0][metric] *= 1.25
        results = tmp_path / f"queue_drift_{metric}.json"
        results.write_text(json.dumps(drifted))
        assert cb.main([str(results), "--baseline", str(baseline)]) == 1


def test_check_bench_fails_on_missing_points(sweep_results, tmp_path):
    """A sweep that silently drops baseline coverage fails the gate."""
    _, on_disk = sweep_results
    truncated = {"meta": on_disk["meta"],
                 "segment_sweep": on_disk["segment_sweep"][:10]}
    results = tmp_path / "truncated.json"
    results.write_text(json.dumps(truncated))
    baseline = (pathlib.Path(__file__).resolve().parent.parent
                / "benchmarks" / "baseline.json")
    cb = _load_check_bench()
    assert cb.main([str(results), "--baseline", str(baseline)]) == 1


def test_check_bench_fails_on_extra_points(sweep_results, tmp_path):
    """Both directions gate: a sweep that silently GROWS coverage (new
    keys absent from the reviewed baseline) fails too — new curves must
    land via an explicit baseline refresh."""
    _, on_disk = sweep_results
    grown = json.loads(json.dumps(on_disk))
    novel = dict(grown["segment_sweep"][0])
    novel["collective"] = "never_reviewed"
    grown["segment_sweep"].append(novel)
    results = tmp_path / "grown.json"
    results.write_text(json.dumps(grown))
    baseline = (pathlib.Path(__file__).resolve().parent.parent
                / "benchmarks" / "baseline.json")
    cb = _load_check_bench()
    assert cb.main([str(results), "--baseline", str(baseline)]) == 1


def test_check_bench_zero_baseline_point_still_gates(sweep_results,
                                                     tmp_path, capsys):
    """A zero/near-zero baseline predicted_s must not blow up (or pass
    via division weirdness): the epsilon floor turns it into a huge
    finite drift that fails the gate."""
    _, on_disk = sweep_results
    zeroed = json.loads(json.dumps(on_disk))
    zeroed["segment_sweep"][0]["predicted_s"] = 0.0
    baseline = tmp_path / "zero_base.json"
    baseline.write_text(json.dumps(
        {"meta": {}, "segment_sweep": zeroed["segment_sweep"]}))
    results = tmp_path / "fresh.json"
    results.write_text(json.dumps(on_disk))
    cb = _load_check_bench()
    assert cb.main([str(results), "--baseline", str(baseline)]) == 1
    out = capsys.readouterr().out
    assert "DRIFT" in out and "nan" not in out and "inf" not in out


def test_check_bench_top_truncates_drift_list(sweep_results, tmp_path,
                                              capsys):
    """--top N prints only the N worst-drifting points (largest |drift|
    first) plus a count of the rest — the CI log summary."""
    _, on_disk = sweep_results
    drifted = json.loads(json.dumps(on_disk))
    for i, e in enumerate(drifted["segment_sweep"][:5]):
        e["predicted_s"] *= 2.0 + i  # ascending drifts, worst last
    results = tmp_path / "drifted.json"
    results.write_text(json.dumps(drifted))
    baseline = (pathlib.Path(__file__).resolve().parent.parent
                / "benchmarks" / "baseline.json")
    cb = _load_check_bench()
    assert cb.main([str(results), "--baseline", str(baseline),
                    "--top", "2"]) == 1
    out = capsys.readouterr().out
    assert out.count("DRIFT") == 2
    assert "3 more drifted points" in out
    # the worst drift (6x -> +500.0%) leads the truncated list
    head = out.split("DRIFT")[1]
    assert "(+500.0%)" in head


def test_check_bench_write_baseline_round_trip(sweep_results, tmp_path):
    """--write-baseline emits a file the checker then passes against —
    the documented refresh procedure."""
    _, on_disk = sweep_results
    results = tmp_path / "fresh.json"
    results.write_text(json.dumps(on_disk))
    new_base = tmp_path / "baseline.json"
    cb = _load_check_bench()
    assert cb.main([str(results), "--write-baseline", str(new_base)]) == 0
    assert cb.main([str(results), "--baseline", str(new_base)]) == 0
