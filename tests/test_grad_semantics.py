"""Regression tests for the shard_map autodiff contracts the framework
relies on (see parallel/ops.py docstring)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import CollectiveEngine
from repro.core.topology import make_mesh


@pytest.fixture(scope="module")
def setup():
    mesh = make_mesh((4,), ("m",))
    eng = CollectiveEngine(mesh, backend="microcode")
    rng = np.random.default_rng(0)
    X = rng.normal(size=(6, 8)).astype(np.float32)
    W = rng.normal(size=(8, 4)).astype(np.float32)
    return mesh, eng, X, W


def test_psum_transpose_gives_tp_factor(setup):
    """Row-parallel grads come out tp x true grad (uniform) for BOTH
    native psum and the microcode ring — hence the 1/tp loss scale."""
    mesh, eng, X, W = setup

    def loss_ref(w):
        return ((X @ w) ** 2).sum()

    gref = np.asarray(jax.grad(loss_ref)(jnp.asarray(W)))
    Xs = X.reshape(6, 4, 2).transpose(1, 0, 2)
    Ws = W.reshape(4, 2, 4)

    for fn in (lambda x, w: ((jax.lax.psum(x @ w, "m")) ** 2).sum(),
               lambda x, w: ((eng.allreduce(x @ w, "m",
                                            algorithm="ring")) ** 2).sum()):
        g = jax.jit(jax.shard_map(
            jax.grad(fn, argnums=1), mesh=mesh,
            in_specs=(P("m"), P("m")), out_specs=P("m"),
            check_vma=False))(jnp.asarray(Xs), jnp.asarray(Ws))
        ratio = np.asarray(g).reshape(8, 4) / gref
        np.testing.assert_allclose(ratio, 4.0, rtol=1e-4)


def test_fsdp_gather_vjp_is_data_summed_shard(setup):
    """engine.allgather's VJP returns the data-summed gradient shard."""
    mesh, eng, _, W = setup
    rng = np.random.default_rng(1)
    Xb = rng.normal(size=(12, 8)).astype(np.float32)

    def loss_ref(w):
        return ((Xb @ w) ** 2).sum()

    gref = np.asarray(jax.grad(loss_ref)(jnp.asarray(W)))

    def local(x, w_shard):
        w = eng.allgather(w_shard, "m", algorithm="ring").reshape(8, 4)
        return ((x @ w) ** 2).sum()

    g = jax.jit(jax.shard_map(
        jax.grad(local, argnums=1), mesh=mesh,
        in_specs=(P("m"), P("m", None)), out_specs=P("m", None),
        check_vma=False))(jnp.asarray(Xb), jnp.asarray(W))
    np.testing.assert_allclose(np.asarray(g), gref, atol=1e-3)


def test_replicated_param_needs_explicit_psum(setup):
    """Per-rank grads of a replicated param sum to the true gradient —
    the grad_sync rule (psum over axes missing from the spec)."""
    mesh, eng, _, W = setup
    rng = np.random.default_rng(2)
    Xb = rng.normal(size=(12, 8)).astype(np.float32)

    def loss_ref(w):
        return ((Xb @ w) ** 2).sum()

    gref = np.asarray(jax.grad(loss_ref)(jnp.asarray(W)))

    def local(x, w):
        return ((x @ w) ** 2).sum()

    g = jax.jit(jax.shard_map(
        lambda x, w: jax.grad(local, argnums=1)(x, w)[None],
        mesh=mesh, in_specs=(P("m"), P()), out_specs=P("m"),
        check_vma=False))(jnp.asarray(Xb), jnp.asarray(W))
    np.testing.assert_allclose(np.asarray(g).sum(0), gref, atol=1e-3)


def test_grad_sync_bucketing(mesh222):
    """grad_sync psums exactly the axes missing from each spec."""
    from repro.configs import get_config, reduced_config
    from repro.parallel import stages
    from repro.parallel.ops import spec_axes
    cfg = reduced_config(get_config("qwen3-0.6b"))
    specs = stages.param_specs(cfg, 2)
    flat = jax.tree.flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, P))[0]
    for path, spec in flat:
        axes = spec_axes(spec)
        # every param must be synced over 'pod' (never sharded there)
        assert "pod" not in axes, path
