"""Invariants of the split pricing model (PR 4), swept property-style.

(a) No SEG_LOOP-only compiled program ever receives the cross-step
    (k-1)*max fill/drain term: its price is EXACTLY the serialized
    per-step golden model (`golden_pricing.predict_time_segloop`),
    floor clamps included.
(b) For non-streamable programs, segmentation never pays: cost at k > 1
    is >= cost at k = 1 at every message size, including sub-segment-
    floor sizes where the Rx clamp fires (equality once fully clamped).
(c) SEL_RANGE streamed programs are bitwise-equal to their unfused form
    across {range-selector ring, recursive halving} x {fp32, int8} —
    the credit the model grants them is a wire reorder, not a numeric
    change.
"""
import inspect
import math

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

import golden_pricing as GP
from repro.core import CollectiveEngine, Communicator
from repro.core import algorithms as A
from repro.core.engine import execute_program
from repro.core.program import Stream, StreamChain, compile_schedule
from repro.core.schedule import Schedule, Sel, Step
from repro.core.topology import make_mesh

COMM8 = Communicator(axis="x", size=8)
DCN8 = Communicator(axis="pod", size=8, is_dcn=True)

ALL_ALGOS = sorted({(c, a) for (c, a) in A.GENERATORS})

#: sizes straddling the fabric floors: 64 KiB ring chunks sit BELOW the
#: 8 KiB-per-segment ICI floor at k >= 2, 64 MiB sits far above it
SIZES = (64 << 10, 1 << 20, 64 << 20)
SEGMENTS = (2, 4, 8, 32)


def _gen(coll, algo, comm=COMM8):
    gen = A.GENERATORS[(coll, algo)]
    kw = {"root": 1} if "root" in inspect.signature(gen).parameters else {}
    return gen(comm, **kw)


def _streams(prog):
    return [op for op in prog.ops if isinstance(op, (Stream, StreamChain))]


# -- (a) SEG_LOOP-only programs never get the cross-step credit ---------------

@pytest.mark.parametrize("coll,algo", ALL_ALGOS,
                         ids=[f"{c}-{a}" for c, a in ALL_ALGOS])
@pytest.mark.parametrize("comm", [COMM8, DCN8], ids=["ici", "dcn"])
def test_segloop_only_programs_price_serialized(coll, algo, comm):
    """Wherever no fusion pass fired, the price is the serialized
    within-step model — bit-exactly, so no residue of the old global
    (k-1)*max term can hide in the walk."""
    sched = _gen(coll, algo)
    for msg in SIZES:
        for k in SEGMENTS:
            prog = compile_schedule(sched, segments=k)
            if _streams(prog):
                continue
            want = GP.predict_time_segloop(
                sched, msg, comm.hop_latency, comm.link_bw, segments=k,
                min_segment_bytes=comm.min_segment_bytes)
            assert math.isclose(prog.cost(msg, comm), want,
                                rel_tol=1e-12), (coll, algo, msg, k)


def test_forced_unfused_programs_price_serialized():
    """stream=False makes EVERY program SEG_LOOP-only — including the
    rings — and the serialized invariant must hold there too."""
    for coll, algo in ALL_ALGOS:
        sched = _gen(coll, algo)
        for k in (2, 8):
            prog = compile_schedule(sched, segments=k, stream=False)
            assert not _streams(prog)
            want = GP.predict_time_segloop(
                sched, 4 << 20, COMM8.hop_latency, COMM8.link_bw,
                segments=k, min_segment_bytes=COMM8.min_segment_bytes)
            assert math.isclose(prog.cost(4 << 20, COMM8), want,
                                rel_tol=1e-12), (coll, algo, k)


# -- (b) segmentation never pays without streaming ----------------------------

@pytest.mark.parametrize("coll,algo", ALL_ALGOS,
                         ids=[f"{c}-{a}" for c, a in ALL_ALGOS])
def test_non_streamable_k_gt_1_never_beats_k1(coll, algo):
    """k > 1 only adds per-segment alpha when execution cannot overlap
    across steps; sub-floor sizes clamp back toward k = 1 (equality),
    never below it. Swept on the unfused compile so the invariant also
    covers the algorithms whose fused form streams."""
    sched = _gen(coll, algo)
    for comm in (COMM8, DCN8):
        for msg in (1 << 10, 8 << 10) + SIZES:  # incl. sub-floor sizes
            base = compile_schedule(sched, segments=1).cost(msg, comm)
            for k in SEGMENTS:
                prog = compile_schedule(sched, segments=k, stream=False)
                assert prog.cost(msg, comm) >= base, (coll, algo, msg, k)
            fused = compile_schedule(sched, segments=8)
            if not _streams(fused):
                assert fused.cost(msg, comm) >= base, (coll, algo, msg)


# -- (c) SEL_RANGE streamed programs are bitwise-equal to unfused -------------

def _range_ring_reduce_scatter(comm):
    """The chunk ring written with SEL_RANGE selectors — streams through
    the region proof as a uniform RANGE run."""
    n = comm.size
    perm = tuple(comm.ring_perm(1))
    send = Sel.range(lambda r, s: ((r - s - 1) % n, 1))
    recv = Sel.range(lambda r, s: ((r - s - 2) % n, 1))
    steps = tuple(
        Step(perm=perm, op="add", send_sel=send, recv_sel=recv,
             bytes_frac=1.0 / n, uniform=True)
        for _ in range(n - 1))
    return Schedule(name="range_ring", collective="reduce_scatter",
                    nranks=n, steps=steps, chunks=n, result="shard",
                    owned_chunk=lambda r: r)


@pytest.fixture(scope="module")
def env():
    mesh = make_mesh((8,), ("x",))
    return CollectiveEngine(mesh, backend="microcode"), mesh


def _run_prog(mesh, prog, X):
    g = jax.jit(jax.shard_map(
        lambda v: execute_program(prog, v[0], "x")[None],
        mesh=mesh, in_specs=P("x"), out_specs=P("x"), check_vma=False))
    return np.asarray(g(jax.numpy.asarray(X)))


# chunk size 2048: whole int8 scale blocks at every k used here
XR = np.random.default_rng(17).normal(size=(8, 16384)).astype(np.float32)


@pytest.mark.parametrize("name,gen", [
    ("range_ring", _range_ring_reduce_scatter),
    ("recursive_halving", A.recursive_halving_reduce_scatter),
])
@pytest.mark.parametrize("codec", [None, "int8"])
def test_sel_range_streamed_bitwise_equals_unfused(env, name, gen, codec):
    _eng, mesh = env
    sched = gen(COMM8)
    for k in (4, 8):
        fused = compile_schedule(sched, segments=k, codec=codec)
        plain = compile_schedule(sched, segments=k, codec=codec,
                                 stream=False)
        assert _streams(fused), (name, k)
        assert not _streams(plain)
        np.testing.assert_array_equal(_run_prog(mesh, fused, XR),
                                      _run_prog(mesh, plain, XR))
