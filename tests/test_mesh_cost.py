"""Mesh-level contention-aware makespan (core/mesh_cost.MeshMakespan)
over the physical-link capacity map (topology.FabricOccupancy).

The contention invariants the model must keep (ISSUE acceptance):

  * a single queue composes BITWISE equal to `Sequencer.makespan` —
    the mesh view never reprices what the queue view already priced;
  * two saturating queues on ONE fabric price ~the serial sum (within
    [0.95 * serial, serial]), >= 1.9x one queue at bandwidth sizes;
  * queues on DISJOINT fabrics stay independent: the composition tracks
    the slower queue (<= 1.05x max), never below it;
  * fault tiers compose monotonically at mesh level, and drop_prob=0 is
    bitwise-identical to fault-free.
"""
import numpy as np
import pytest

from repro.core import (
    CollectiveEngine, Communicator, FabricOccupancy, MeshMakespan,
    PricingEnv, TIERS,
)
from repro.core.sequencer import Sequencer


@pytest.fixture()
def eng8(mesh8):
    return CollectiveEngine(mesh8)


@pytest.fixture()
def eng222(mesh222):
    return CollectiveEngine(mesh222)


def _fill(seq, axis, nbytes, n=4, collective="allreduce"):
    for _ in range(n):
        seq.issue(collective, np.zeros((nbytes // 4,), np.float32), axis)


# -- the capacity map ---------------------------------------------------------

def test_fabric_occupancy_links(eng222):
    occ = FabricOccupancy()
    ici = eng222.comm("data")
    dcn = eng222.comm("pod")
    assert not ici.is_dcn and dcn.is_dcn
    assert occ.link_key(ici) == ("ici", "data")
    # every DCN axis funnels through the chip's one shared uplink
    assert occ.link_key(dcn) == FabricOccupancy.DCN_UPLINK
    assert occ.canonical(("dcn", "pod")) == FabricOccupancy.DCN_UPLINK
    assert occ.canonical(("ici", "model")) == ("ici", "model")
    assert occ.capacity(("ici", "data")) == occ.hw.ici_link_bw
    assert occ.capacity(FabricOccupancy.DCN_UPLINK) == occ.hw.dcn_bw
    ports = occ.ports()
    assert ports["ici"] == occ.hw.ici_links_per_chip and ports["dcn"] == 1


# -- single queue: the composition is a no-op ---------------------------------

def test_single_queue_bitwise_equals_sequencer_makespan(eng8):
    seq = Sequencer(eng8)
    _fill(seq, "x", 1 << 20)
    assert MeshMakespan.of(seq).total() == seq.makespan("x")
    seq.clear()


def test_single_queue_bitwise_with_tier_env(eng8):
    env = PricingEnv(tier=TIERS["tcp-like"], drop_prob=0.1)
    seq = Sequencer(eng8)
    _fill(seq, "x", 1 << 18)
    assert MeshMakespan.of(seq, env).total() == seq.makespan("x", env=env)
    seq.clear()


def test_single_queue_bitwise_hierarchical_tuple_axis(eng222):
    """A two-axis issue_multi folds into ONE tuple-axis request whose
    program crosses both fabrics; the mesh composition must still return
    the queue's own price bitwise (multi-link programs make the link
    term strictly smaller than the full queue makespan)."""
    seq = Sequencer(eng222)
    for _ in range(3):
        seq.issue_multi(np.zeros((1 << 16,), np.float32), ["pod", "data"])
    (axis,) = seq.axes_outstanding()
    assert isinstance(axis, tuple)  # the folded two-level request
    assert MeshMakespan.of(seq).total() == seq.makespan(axis)
    seq.clear()


def test_single_queue_bitwise_with_dep_chain(eng8):
    seq = Sequencer(eng8)
    r = seq.issue("reduce_scatter", np.zeros((1 << 18,), np.float32), "x")
    seq.issue("allgather", r, "x")
    assert MeshMakespan.of(seq).total() == seq.makespan("x")
    seq.clear()


# -- shared fabric: wire serializes -------------------------------------------

def test_two_shared_queues_price_near_serial(eng8):
    """Two saturating queues on the SAME ICI axis: the link term pushes
    the composition to ~the serial sum of the two isolated makespans
    (alpha still hides, so it lands just under), and >= 1.9x one queue
    at bandwidth-dominated depths (8 x 16 MiB per queue: the hidden
    alpha is ONE request's latency credit, fixed while wire scales, so
    shallower/smaller queues sit further from serial — the 4-request
    1 MiB point composes at ~1.6x, by design)."""
    nbytes = 1 << 24
    a, b = Sequencer(eng8), Sequencer(eng8)
    _fill(a, "x", nbytes, n=8)
    _fill(b, "x", nbytes, n=8)
    ms_a, ms_b = a.makespan("x"), b.makespan("x")
    total = MeshMakespan().add(a, "x").add(b, "x").total()
    serial = ms_a + ms_b
    assert 0.95 * serial <= total <= serial
    assert total >= 1.9 * ms_a
    a.clear(), b.clear()


def test_shared_contention_grows_with_queue_count(eng8):
    nbytes = 1 << 24
    totals = []
    for q in (1, 2, 4):
        seqs = []
        mm = MeshMakespan()
        for _ in range(q):
            s = Sequencer(eng8)
            _fill(s, "x", nbytes, n=8)
            seqs.append(s)
            mm.add(s, "x")
        totals.append(mm.total())
        for s in seqs:
            s.clear()
    assert totals[0] < totals[1] < totals[2]
    assert totals[2] >= 3.5 * totals[0]  # 4 queues ~4x, alpha hides


# -- disjoint fabrics: independent --------------------------------------------

def test_disjoint_fabrics_track_the_slower_queue(eng222):
    """One queue on the ICI data axis, one on the DCN pod axis: no
    shared physical link, so the composition is the slower queue (up to
    the cross-queue alpha term), never the sum."""
    d, p = Sequencer(eng222), Sequencer(eng222)
    _fill(d, "data", 1 << 22)
    _fill(p, "pod", 1 << 22)
    md, mp = d.makespan("data"), p.makespan("pod")
    total = MeshMakespan().add(d, "data").add(p, "pod").total()
    assert max(md, mp) <= total <= 1.05 * max(md, mp)
    assert total < 0.75 * (md + mp)  # nowhere near serialized
    d.clear(), p.clear()


def test_two_dcn_queues_share_the_uplink(eng222):
    """Queues on DIFFERENT pod-crossing axes still contend: all DCN
    keys canonicalize to the one chip uplink."""
    a, b = Sequencer(eng222), Sequencer(eng222)
    _fill(a, "pod", 1 << 24, n=8)
    _fill(b, "pod", 1 << 24, n=8)
    ms = a.makespan("pod")
    total = MeshMakespan().add(a, "pod").add(b, "pod").total()
    assert total >= 1.9 * ms
    rep = MeshMakespan().add(a, "pod").add(b, "pod").report()
    assert set(rep["links"]) == {FabricOccupancy.DCN_UPLINK}
    a.clear(), b.clear()


# -- cross-queue dependency chains --------------------------------------------

def test_issue_multi_chain_prices_as_one_dag(eng222):
    """A 3-axis issue_multi spans three queues (RS on data -> folded
    ("model","pod") middle -> AG on data) chained by dataflow deps; the
    mesh view serializes the chain's full costs across queues, so it
    prices strictly above any single queue's isolated makespan."""
    seq = Sequencer(eng222)
    seq.issue_multi(np.zeros((1 << 18,), np.float32),
                    ["data", "pod", "model"])
    axes = seq.axes_outstanding()
    assert len(axes) == 2  # "data" + the folded tuple axis
    rep = MeshMakespan.of(seq).report()
    per_queue = max(q["makespan_s"] for q in rep["queues"])
    assert rep["chain_s"] > per_queue
    assert rep["mesh_makespan_s"] >= rep["chain_s"]
    seq.clear()


# -- fault tiers at mesh level ------------------------------------------------

def test_mesh_tier_pricing_monotone_and_neutral_at_zero(eng8):
    a, b = Sequencer(eng8), Sequencer(eng8)
    _fill(a, "x", 1 << 20)
    _fill(b, "x", 1 << 20)

    def total(env=None):
        return MeshMakespan().add(a, "x", env).add(b, "x", env).total()

    base = total()
    tiered = [total(PricingEnv(tier=TIERS["tcp-like"], drop_prob=p))
              for p in (0.0, 0.1, 0.3)]
    assert tiered[0] == base  # p=0 is bitwise fault-free
    assert base < tiered[1] < tiered[2]
    a.clear(), b.clear()


# -- report structure ---------------------------------------------------------

def test_report_exposes_terms(eng8):
    a, b = Sequencer(eng8), Sequencer(eng8)
    _fill(a, "x", 1 << 20, n=2)
    _fill(b, "x", 1 << 20, n=2)
    rep = MeshMakespan().add(a, "x").add(b, "x").report()
    assert {"mesh_makespan_s", "chain_s", "queues", "links"} <= set(rep)
    assert len(rep["queues"]) == 2
    assert all(q["items"] == 2 and q["makespan_s"] > 0
               for q in rep["queues"])
    link = rep["links"][("ici", "x")]
    assert link["busy_s"] > 0 and link["capacity_Bps"] > 0
    assert rep["mesh_makespan_s"] >= max(q["makespan_s"]
                                         for q in rep["queues"])
    a.clear(), b.clear()


def test_empty_composition_is_zero():
    assert MeshMakespan().total() == 0.0


def test_custom_comm_via_env(eng8):
    """`PricingEnv.comm` reprices a queue on a hypothetical fabric
    without an engine rebuild — the what-if hook the old comm= kwarg
    provided."""
    seq = Sequencer(eng8)
    _fill(seq, "x", 1 << 20)
    slow = Communicator(axis="x", size=8, is_dcn=True)  # DCN-priced links
    env = PricingEnv(comm=slow)
    assert MeshMakespan.of(seq, env).total() == seq.makespan("x", env=env)
    assert seq.makespan("x", env=env) > seq.makespan("x")
    seq.clear()
