"""Hierarchical cross-fabric collectives (two-level IR programs).

1. Composition parity: every hierarchical composition (allreduce /
   reduce_scatter / allgather / bcast x every inter algorithm) executed
   by the numpy simulator against `simulator.oracle`, on pow2 AND
   non-pow2 intra sizes, {add, max}, {unsegmented, segmented}, and the
   int8 wire codec.
2. Engine parity: the SAME programs executed by the jax engine over a
   real (pod x data) mesh — two-axis ppermutes — match the oracle
   bitwise on integer-valued floats, including the sequential flat
   fallback and the non-zero-root bcast fallback.
3. Pricing invariants: the priced DCN wire bytes of a two-level
   allreduce are EXACTLY 1/ici_size of what the flat per-axis approach
   puts on DCN; the selector picks a hierarchical composition at the
   sizes the issue pins, delegates at degenerate pod sizes, and
   round-trips hierarchical picks through the tuning table.
4. Per-fabric eager caps: a DCN communicator rejects eager at sizes the
   ICI pool still accepts (and an explicit override still wins).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import CollectiveEngine
from repro.core import algorithms as A
from repro.core import hierarchical as H
from repro.core import simulator as sim
from repro.core.selector import Selector
from repro.core.topology import Communicator, make_mesh

COLLECTIVES = ("allreduce", "reduce_scatter", "allgather", "bcast")

# (pod, intra) grids: pow2 x pow2, non-pow2 intra, non-pow2 both
GRIDS = [(2, 2), (2, 3), (4, 3), (3, 2)]


def _pc(P_, M_):
    """(pod=P_ on DCN) x (intra=M_ on ICI) product communicator."""
    return Communicator(axis="pod", size=P_ * M_, is_dcn=True).factor(P_)


def _int_inputs(n, size, seed=0, lo=-8, hi=9):
    """Integer-valued fp32 payloads: add-reductions are exact regardless
    of summation order, so parity checks can be bitwise."""
    rng = np.random.default_rng(seed)
    return [rng.integers(lo, hi, size=size).astype(np.float32)
            for _ in range(n)]


def _sim_run(coll, comm, inter, op="add", segments=None, codec=None,
             per_chunk=12, seed=0):
    sched = H.hierarchical_schedule(coll, comm, intra="ring", inter=inter,
                                    op=op)
    prog = sched.compile(segments=segments, codec=codec)
    n = comm.size
    size = sched.chunks * per_chunk
    inputs = _int_inputs(n, size, seed=seed)
    outs = sim.run_collective(coll, sched, prog, inputs)
    return sched, inputs, outs


# --------------------------------------------------------------------------
# 1. Composition parity in the numpy simulator
# --------------------------------------------------------------------------

@pytest.mark.parametrize("Pp,M", GRIDS, ids=[f"{p}x{m}" for p, m in GRIDS])
@pytest.mark.parametrize("coll", COLLECTIVES)
@pytest.mark.parametrize("segments", [None, 3])
def test_sim_parity(Pp, M, coll, segments):
    """Every composition x every admissible inter algorithm matches the
    oracle exactly (integer-valued fp32)."""
    comm = _pc(Pp, M)
    n = comm.size
    inters = H.inter_candidates(coll, Pp)
    assert inters, (coll, Pp)
    for inter in inters:
        sched, inputs, outs = _sim_run(coll, comm, inter,
                                       segments=segments)
        ref = sim.oracle(coll, inputs)
        if coll == "allreduce":
            for r in range(n):
                np.testing.assert_array_equal(outs[r], ref)
        elif coll == "reduce_scatter":
            csize = inputs[0].size // n
            for r in range(n):
                own = int(sched.owned_chunk(r))
                np.testing.assert_array_equal(
                    outs[r], ref[own * csize:(own + 1) * csize])
        elif coll == "allgather":
            for r in range(n):
                np.testing.assert_array_equal(outs[r], ref)
        else:  # bcast
            for r in range(n):
                np.testing.assert_array_equal(outs[r], inputs[0])


@pytest.mark.parametrize("Pp,M", [(2, 3), (4, 4)])
@pytest.mark.parametrize("op", ["add", "max"])
def test_sim_parity_ops(Pp, M, op):
    """Reducing compositions honour the op at both levels."""
    comm = _pc(Pp, M)
    n = comm.size
    for coll in ("allreduce", "reduce_scatter"):
        for inter in H.inter_candidates(coll, Pp):
            sched, inputs, outs = _sim_run(coll, comm, inter, op=op)
            ref = sim.oracle(coll, inputs, op=op)
            if coll == "allreduce":
                for r in range(n):
                    np.testing.assert_array_equal(outs[r], ref)
            else:
                csize = inputs[0].size // n
                for r in range(n):
                    own = int(sched.owned_chunk(r))
                    np.testing.assert_array_equal(
                        outs[r], ref[own * csize:(own + 1) * csize])


def test_hier_bcast_nonzero_root_raises():
    """The hierarchical bcast lowering is root-0 only (the engine falls
    back to the sequential per-axis path for other roots)."""
    with pytest.raises(ValueError):
        H.hier_bcast(_pc(2, 4), root=1)


def test_degenerate_levels_rejected():
    """Compositions need >= 2 ranks at BOTH levels (the selector
    delegates degenerate products to the live level instead)."""
    with pytest.raises(ValueError):
        H.hierarchical_schedule("allreduce", _pc(1, 4))
    with pytest.raises(ValueError):
        H.hierarchical_schedule("allreduce", _pc(4, 1))


# --------------------------------------------------------------------------
# 2. Pricing invariants + selector behaviour
# --------------------------------------------------------------------------

def test_dcn_wire_bytes_exactly_one_over_ici_size():
    """The headline claim, asserted exactly: a two-level allreduce puts
    1/ici_size of the flat approach's bytes on DCN. Both sides pinned to
    ring so the per-rank scaling (2(P-1)/P) cancels."""
    Pp, M = 4, 4
    comm = _pc(Pp, M)
    msg = float(1 << 20)
    hier = H.hierarchical_schedule("allreduce", comm,
                                   intra="ring", inter="ring").compile()
    got = hier.fabric_wire_bytes(msg, comm)
    # flat: the whole message allreduced over the pod axis rides DCN
    flat = A.GENERATORS[("allreduce", "ring")](comm.outer).compile()
    want = flat.fabric_wire_bytes(msg, comm.outer)
    assert want["dcn"] > 0
    assert got["dcn"] == want["dcn"] / M
    # and the ICI side carries the intra RS + AG (2(M-1)/M per rank)
    assert got["ici"] == pytest.approx(2.0 * (M - 1) / M * msg)


def test_flat_program_prices_identically_on_product():
    """A flat (level=None) program priced over the ProductComm resolves
    every exchange to the bottleneck fabric — bitwise the same cost as
    pricing over the equivalent flat DCN communicator."""
    comm = _pc(4, 4)
    msg = float(1 << 20)
    prog = A.GENERATORS[("allreduce", "ring")](comm.flat).compile()
    assert prog.cost(msg, comm) == prog.cost(msg, comm.flat)
    fb = prog.fabric_wire_bytes(msg, comm)
    assert fb["ici"] == 0.0 and fb["dcn"] > 0


@pytest.mark.parametrize("msg", [1 << 20, 16 << 20],
                         ids=["1MiB", "16MiB"])
def test_selector_picks_hierarchical(msg):
    """Acceptance: on (pod=4 x data=4) TPU_V5E the selector picks a
    hierarchical composition for allreduce at >= 1 MiB."""
    comm = _pc(4, 4)
    c = Selector().choose("allreduce", msg, comm)
    assert c.algorithm.startswith("hierarchical:"), c.algorithm
    assert c.predicted_s > 0
    assert c.program is not None and c.program.level_sizes is not None


@pytest.mark.parametrize("coll", COLLECTIVES)
def test_selector_all_compositions_available(coll):
    """Every composable collective has a hierarchical candidate that can
    win at bandwidth-bound sizes on pod=4 x data=4."""
    c = Selector().choose(coll, 1 << 20, _pc(4, 4))
    assert c.algorithm.startswith("hierarchical:"), (coll, c.algorithm)


def test_selector_delegates_degenerate_pod():
    """pod_size == 1: nothing crosses DCN, so the choice must be a flat
    (non-hierarchical) algorithm — same as choosing over the inner comm."""
    comm = _pc(1, 8)
    c = Selector().choose("allreduce", 1 << 20, comm)
    assert not c.algorithm.startswith("hierarchical:")
    inner = Selector().choose("allreduce", 1 << 20, comm.inner)
    assert (c.algorithm, c.segments) == (inner.algorithm, inner.segments)
    assert c.predicted_s == inner.predicted_s


def test_selector_hier_beats_flat_at_bandwidth_sizes():
    """The hierarchical pick is strictly cheaper than the best flat
    candidate priced over the same product (the reason it wins)."""
    comm = _pc(4, 4)
    sel = Selector()
    c = sel.choose("allreduce", 1 << 20, comm)
    # price the best flat candidate by pinning the hierarchical family out
    flat_best = min(
        sel.price_program(
            A.GENERATORS[("allreduce", a)](comm.flat).compile(),
            "rendezvous", float(1 << 20), comm)
        for a in ("ring", "bidi_ring", "recursive_doubling")
    )
    assert c.predicted_s < flat_best


def test_table_round_trip_with_hierarchical_names():
    """table_rows -> apply_table reproduces hierarchical picks exactly."""
    comm = _pc(4, 4)
    sizes = (1 << 14, 1 << 20, 16 << 20)
    rows = Selector().table_rows("allreduce", comm, sizes=sizes)
    assert any(r["algorithm"].startswith("hierarchical:") for r in rows)
    fresh = Selector()
    fresh.apply_table(rows)
    for r in rows:
        c = fresh.choose("allreduce", r["msg_bytes"], comm)
        assert (c.algorithm, c.segments) == (r["algorithm"], r["segments"])


def test_dcn_rejects_eager_above_its_own_cap():
    """Per-fabric Rx pools: 48 KiB eager fits the ICI pool (64 KiB cap)
    but NOT the DCN pool (32 KiB cap); an explicit override beats both."""
    ici = Communicator(axis="data", size=4, is_dcn=False)
    dcn = Communicator(axis="pod", size=4, is_dcn=True)
    sel = Selector()
    msg = 48 * 1024
    assert sel._protocol_overhead("eager", msg, ici) is not None
    assert sel._protocol_overhead("eager", msg, dcn) is None
    pinned = Selector(eager_max_bytes=4096)
    assert pinned._protocol_overhead("eager", msg, ici) is None
    assert pinned._protocol_overhead("eager", 2048, dcn) is not None


# --------------------------------------------------------------------------
# 3. Engine parity: two-axis execution on a (pod x data) host mesh
# --------------------------------------------------------------------------

_ENVS = {}


def _env(Pp, M):
    if (Pp, M) not in _ENVS:
        mesh = make_mesh((Pp, M), ("pod", "data"))
        _ENVS[(Pp, M)] = (CollectiveEngine(mesh, backend="microcode"),
                          mesh)
    return _ENVS[(Pp, M)]


def _run2(Pp, M, fn):
    """Run `fn(eng, rank)` under shard_map; rows of the result are the
    per-rank outputs in inner-major flat-rank order."""
    eng, mesh = _env(Pp, M)

    def body():
        r = lax.axis_index("data") * Pp + lax.axis_index("pod")
        return fn(eng, r)[None]

    g = jax.jit(jax.shard_map(body, mesh=mesh, in_specs=(),
                              out_specs=P(("data", "pod")),
                              check_vma=False))
    return np.asarray(g())


def _rank_x(r, L):
    """Deterministic integer-valued fp32 payload for flat rank r."""
    base = jnp.arange(L, dtype=jnp.float32)
    return (base % 13.0) * (r + 1.0) - 3.0 * r


def _np_inputs(n, L):
    base = np.arange(L, dtype=np.float32)
    return [(base % 13.0) * (r + 1.0) - 3.0 * r for r in range(n)]


@pytest.mark.parametrize("Pp,M", [(2, 4), (2, 3)],
                         ids=["2x4", "2x3"])
@pytest.mark.parametrize("op", ["add", "max"])
def test_engine_allreduce_hier(Pp, M, op):
    n = Pp * M
    L = 96
    out = _run2(Pp, M, lambda eng, r: eng.allreduce(
        _rank_x(r, L), ("pod", "data"), op=op,
        algorithm="hierarchical:ring+ring"))
    ref = sim.oracle("allreduce", _np_inputs(n, L), op=op)
    for r in range(n):
        np.testing.assert_array_equal(out[r], ref)


def test_engine_reduce_scatter_hier():
    Pp, M = 2, 4
    n = Pp * M
    L = 96
    out = _run2(Pp, M, lambda eng, r: eng.reduce_scatter(
        _rank_x(r, L), ("pod", "data"),
        algorithm="hierarchical:ring+ring"))
    ref = sim.oracle("reduce_scatter", _np_inputs(n, L))
    cs = L // n
    for r in range(n):
        np.testing.assert_array_equal(out[r],
                                      ref[r * cs:(r + 1) * cs])


def test_engine_allgather_hier():
    Pp, M = 2, 4
    n = Pp * M
    L = 24
    out = _run2(Pp, M, lambda eng, r: eng.allgather(
        _rank_x(r, L), ("pod", "data"),
        algorithm="hierarchical:ring+ring"))
    ref = sim.oracle("allgather", _np_inputs(n, L))
    for r in range(n):
        np.testing.assert_array_equal(out[r], ref)


def test_engine_bcast_hier():
    Pp, M = 2, 4
    n = Pp * M
    L = 48
    out = _run2(Pp, M, lambda eng, r: eng.bcast(
        _rank_x(r, L), ("pod", "data"),
        algorithm="hierarchical:ring+binomial_tree"))
    ref = np.asarray(_np_inputs(n, L)[0])
    for r in range(n):
        np.testing.assert_array_equal(out[r], ref)


def test_engine_bcast_nonzero_root_falls_back():
    """root != 0 takes the sequential per-axis fallback and still
    broadcasts the right rank's buffer."""
    Pp, M = 2, 4
    n = Pp * M
    L = 48
    root = 3
    out = _run2(Pp, M, lambda eng, r: eng.bcast(
        _rank_x(r, L), ("pod", "data"), root=root))
    ref = np.asarray(_np_inputs(n, L)[root])
    for r in range(n):
        np.testing.assert_array_equal(out[r], ref)


def test_engine_flat_algorithm_sequential_fallback():
    """An explicit flat algorithm over a product axis executes the
    sequential per-axis composition — still exact."""
    Pp, M = 2, 4
    n = Pp * M
    L = 96
    out = _run2(Pp, M, lambda eng, r: eng.allreduce(
        _rank_x(r, L), ("pod", "data"), algorithm="ring"))
    ref = sim.oracle("allreduce", _np_inputs(n, L))
    for r in range(n):
        np.testing.assert_array_equal(out[r], ref)


def test_engine_codec_hier():
    """int8 wires through the two-axis path: segmented == unsegmented
    bitwise, within quantization tolerance of the oracle."""
    Pp, M = 2, 4
    n = Pp * M
    L = 4096
    rng = np.random.default_rng(7)
    X = (rng.normal(size=(n, L)) * 30).astype(np.float32)

    def call(k):
        def fn(eng, r):
            x = jnp.asarray(X)[r]
            return eng.allreduce(x, ("pod", "data"),
                                 algorithm="hierarchical:ring+ring",
                                 compression="int8", segments=k)
        return fn

    out = _run2(Pp, M, call(4))
    base = _run2(Pp, M, call(1))
    np.testing.assert_array_equal(out, base)
    ref = X.sum(0)
    rel = np.abs(out[0] - ref).max() / np.abs(ref).max()
    assert rel < 0.05, rel


def test_allreduce_multi_two_axes_folds_to_product():
    """allreduce_multi over two axes issues ONE product-communicator
    call (the selector resolves it; the result is exact)."""
    Pp, M = 2, 4
    n = Pp * M
    L = 96
    eng, _ = _env(Pp, M)
    eng.trace_log.clear()
    out = _run2(Pp, M, lambda e, r: e.allreduce_multi(
        _rank_x(r, L), ("data", "pod")))
    ref = sim.oracle("allreduce", _np_inputs(n, L))
    for r in range(n):
        np.testing.assert_array_equal(out[r], ref)
    # one trace entry, tuple axis, resolved (not per-axis ring x2)
    entries = [t for t in eng.trace_log if t[0] == "allreduce"]
    assert len(entries) == 1
    assert entries[0][2] == ("pod", "data")


def test_sequencer_issue_multi_two_axes():
    """The offload queue folds a two-axis gradient sync into one
    product-communicator request; wait() returns the exact sum."""
    Pp, M = 2, 4
    n = Pp * M
    L = 96
    eng, mesh = _env(Pp, M)

    def body():
        r = lax.axis_index("data") * Pp + lax.axis_index("pod")
        req = eng.queue.issue_multi(_rank_x(r, L), ("data", "pod"))
        return req.wait()[None]

    g = jax.jit(jax.shard_map(body, mesh=mesh, in_specs=(),
                              out_specs=P(("data", "pod")),
                              check_vma=False))
    out = np.asarray(g())
    ref = sim.oracle("allreduce", _np_inputs(n, L))
    for r in range(n):
        np.testing.assert_array_equal(out[r], ref)
