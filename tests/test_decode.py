"""Decode-vs-forward parity: teacher-forced decode over caches reproduces
the training forward's per-position greedy predictions exactly (exercises
cache writes, rolling SWA windows, SSM state recurrence, flash-combine)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, reduced_config
from repro.configs.base import ParallelConfig
from repro.models import lm as lm_mod
from repro.parallel import stages

B, S = 4, 16


@pytest.mark.parametrize("arch_id", ["qwen3-0.6b", "mixtral-8x7b",
                                     "mamba2-1.3b", "hymba-1.5b"])
def test_decode_matches_forward(arch_id, rng, mesh222):
    mesh = mesh222
    cfg = reduced_config(get_config(arch_id))
    pcfg = ParallelConfig(backend="microcode", remat="none",
                          moe_capacity_factor=16.0)
    params = stages.init_params(cfg, mesh, 2, seed=0)
    tokens = rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32)

    ctx = stages.make_ctx(cfg, pcfg, mesh)
    specs = stages.param_specs(cfg, 2)
    bspec = lm_mod.batch_specs(cfg, "prefill")

    def fwd(p, batch):
        x, _ = lm_mod.forward(p, batch, cfg, ctx)
        return jnp.stack([lm_mod.lm_head_sample(p, x[:, i], cfg, ctx)
                          for i in range(S)], axis=1)

    gfwd = jax.jit(jax.shard_map(fwd, mesh=mesh, in_specs=(specs, bspec),
                                 out_specs=P(("pod", "data")),
                                 check_vma=False))
    ref = np.asarray(gfwd(params, {"tokens": jnp.asarray(tokens)}))

    dstep, _, _, _ = stages.build_decode_step(cfg, pcfg, mesh, s_max=S,
                                              global_batch=B)
    cache = stages.init_cache(cfg, pcfg, mesh, 2, B, S)
    preds = []
    for t in range(S):
        nxt, cache = dstep(params, cache,
                           jnp.asarray(tokens[:, t:t + 1]), jnp.int32(t))
        preds.append(np.asarray(nxt))
    dec = np.stack(preds, axis=1)
    agreement = (dec == ref).mean()
    assert agreement == 1.0, f"{arch_id}: decode/forward agreement {agreement}"


def test_whisper_decode_with_cross_cache(rng, mesh222):
    cfg = reduced_config(get_config("whisper-medium"))
    pcfg = ParallelConfig(backend="microcode", remat="none")
    params = stages.init_params(cfg, mesh222, 2, seed=0)
    dstep, _, _, _ = stages.build_decode_step(cfg, pcfg, mesh222, s_max=8,
                                              global_batch=4, s_enc=12)
    cache = stages.init_cache(cfg, pcfg, mesh222, 2, 4, 8, s_enc=12)
    tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 1)), jnp.int32)
    for t in range(3):
        nxt, cache = dstep(params, cache, tok, jnp.int32(t))
        tok = np.asarray(nxt)[:, None].astype(np.int32)
        assert np.isfinite(np.asarray(nxt)).all()
        assert (np.asarray(nxt) < cfg.vocab_size).all()


def test_prefill_emits_caches(rng, mesh222):
    cfg = reduced_config(get_config("qwen3-0.6b"))
    pcfg = ParallelConfig(backend="microcode", remat="none")
    params = stages.init_params(cfg, mesh222, 2, seed=0)
    pf, ctx, _, _ = stages.build_prefill(cfg, pcfg, mesh222,
                                         global_batch=4, seq_len=16)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (4, 16)), jnp.int32)}
    nxt, caches = pf(params, batch)
    assert np.asarray(nxt).shape == (4,)
    k, v = caches  # layer-stacked (L, B, S/tp-or-S, KV, hd)
    assert np.asarray(k).shape[0] == cfg.n_layers
    assert np.isfinite(np.asarray(k)).all()


def test_int8_kv_cache_close_to_bf16(rng, mesh222):
    """Beyond-paper: int8 KV cache (unary plugin on cache storage)."""
    cfg = reduced_config(get_config("qwen3-0.6b"))
    params = stages.init_params(cfg, mesh222, 2, seed=0)
    tokens = rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32)
    preds = {}
    for kv in ("param", "int8"):
        pcfg = ParallelConfig(backend="microcode", remat="none",
                              kv_cache_dtype=kv)
        dstep, _, _, _ = stages.build_decode_step(cfg, pcfg, mesh222,
                                                  s_max=S, global_batch=B)
        cache = stages.init_cache(cfg, pcfg, mesh222, 2, B, S)
        out = []
        for t in range(S):
            nxt, cache = dstep(params, cache,
                               jnp.asarray(tokens[:, t:t + 1]), jnp.int32(t))
            out.append(np.asarray(nxt))
        preds[kv] = np.stack(out, 1)
    agree = (preds["param"] == preds["int8"]).mean()
    assert agree > 0.85, agree


def test_prefill_decode_handoff(rng, mesh222):
    """ServeSession: prefill caches convert into decode layout exactly
    (incl. SWA rolling-window placement); generation matches the pure
    teacher-forced decode path token-for-token."""
    from repro.runtime.serve_session import ServeSession
    s_p, n_new = 8, 6
    cfg = reduced_config(get_config("mixtral-8x7b"))
    pcfg = ParallelConfig(backend="microcode", remat="none",
                          moe_capacity_factor=16.0)
    params = stages.init_params(cfg, mesh222, 2, seed=0)
    prompt = rng.integers(0, cfg.vocab_size, (B, s_p)).astype(np.int32)
    sess = ServeSession(cfg, pcfg, mesh222, 2, B, s_p, s_p + n_new)
    gen = sess.generate(params, jnp.asarray(prompt), n_new)

    dstep, _, _, _ = stages.build_decode_step(cfg, pcfg, mesh222,
                                              s_max=s_p + n_new,
                                              global_batch=B)
    cache = stages.init_cache(cfg, pcfg, mesh222, 2, B, s_p + n_new)
    tok = jnp.asarray(prompt[:, :1])
    ref = []
    for t in range(s_p + n_new - 1):
        nxt, cache = dstep(params, cache, tok, jnp.int32(t))
        if t + 1 < s_p:
            tok = jnp.asarray(prompt[:, t + 1:t + 2])
        else:
            ref.append(np.asarray(nxt))
            tok = jnp.asarray(np.asarray(nxt)[:, None], jnp.int32)
    ref = np.stack(ref, 1)
    assert (gen[:, :ref.shape[1]] == ref).all()
