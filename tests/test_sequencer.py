"""The collective offload sequencer (core/sequencer.py): non-blocking
requests, per-communicator FIFO + dependency edges, coalescing, and the
queue-level makespan model — the CCLO request-queue subsystem."""
import types

import numpy as np
import pytest

import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import (
    CollectiveEngine, Communicator, Schedule, Sel, Selector, Step,
    register_collective, unregister_collective, simulator,
)
from repro.core.sequencer import Sequencer
from tests._hypothesis_compat import given, settings, st


@pytest.fixture(scope="module")
def engines(mesh8):
    return CollectiveEngine(mesh8, backend="microcode")


# --------------------------------------------------------------------------
# Bitwise parity: issued == blocking, out-of-order wait() and drain()
# --------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [np.float32, np.int8])
def test_issued_collectives_bitwise_equal_blocking(engines, rng, dtype):
    """Every built-in collective issued through the queue equals its
    blocking counterpart bit-for-bit, with waits out of FIFO order and
    the stragglers left to drain()."""
    eng = engines

    def queued(a, b, c, d, e, f, h):
        r1 = eng.iallreduce(a, "x")
        r2 = eng.ireduce_scatter(b, "x")
        r3 = eng.iallgather(c, "x")
        r4 = eng.ibcast(d, "x", root=2)
        r5 = eng.ialltoall(e, "x")
        r6 = eng.ireduce(f, "x", op="max")
        r7 = eng.issue("gather", h, "x", root=1)
        out3, out1 = r3.wait(), r1.wait()   # out of issue order
        eng.queue.drain("x")                # the stragglers via drain
        return (out1, r2.result, out3, r4.result, r5.result, r6.result,
                r7.result)

    def blocking(a, b, c, d, e, f, h):
        return (eng.allreduce(a, "x"), eng.reduce_scatter(b, "x"),
                eng.allgather(c, "x"), eng.bcast(d, "x", root=2),
                eng.alltoall(e, "x"), eng.reduce(f, "x", op="max"),
                eng.gather(h, "x", root=1))

    def draw(shape):
        return jnp.asarray(
            rng.integers(-40, 40, size=shape).astype(dtype))

    args = (draw((8, 48)), draw((8, 64)), draw((8, 16)), draw((8, 24)),
            draw((64, 6)), draw((8, 32)), draw((8, 12)))
    specs = (P("x"),) * 7
    outs = (P(), P("x"), P("x"), P(), P("x"), P(), P("x"))
    got = eng.run(queued, in_specs=specs, out_specs=outs)(*args)
    want = eng.run(blocking, in_specs=specs, out_specs=outs)(*args)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def _linear_scatter(comm, root: int = 0) -> Schedule:
    n = comm.size
    steps = tuple(
        Step(perm=((root, (root + i + 1) % n),), op="copy",
             send_sel=Sel.chunk(lambda r, s, i=i: (root + i + 1) % n),
             recv_sel=Sel.chunk(lambda r, s, i=i: (root + i + 1) % n),
             bytes_frac=1.0 / n, mask_recv=True)
        for i in range(n - 1))
    return Schedule(name="linear", collective="qscatter", nranks=n,
                    steps=steps, chunks=n, result="shard",
                    owned_chunk=lambda r: r, relay="original")


@pytest.mark.parametrize("dtype", [np.float32, np.int8])
def test_issued_plugin_collective_bitwise_equal_blocking(engines, rng,
                                                         dtype):
    """Out-of-tree (plugin-registered) collectives ride the queue like
    built-ins: icollective == blocking collective, bit-for-bit."""
    eng = engines
    register_collective("qscatter", _linear_scatter, algorithm="linear")
    try:
        def queued(s):
            r = eng.icollective("qscatter", s, "x", algorithm="linear")
            return r.wait()

        def blocking(s):
            return eng.collective("qscatter", s, "x", algorithm="linear")

        data = jnp.asarray(
            rng.integers(-40, 40, size=(8, 16)).astype(dtype))
        got = eng.run(queued, in_specs=P("x"), out_specs=P("x"))(data)
        want = eng.run(blocking, in_specs=P("x"), out_specs=P("x"))(data)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    finally:
        unregister_collective("qscatter")


def test_coalesced_queue_bitwise_equal_blocking_in_engine(engines, rng):
    """Small same-(op, dtype) reductions coalesce into ONE bucketed
    program inside a traced drain — and still match the blocking calls
    bit-for-bit (the ORDER_SAFE eligibility rule)."""
    eng = engines
    before = eng.queue.stats["coalesced_buckets"]

    def queued(a, b, c):
        rs = [eng.iallreduce(v, "x", algorithm="recursive_doubling")
              for v in (a, b, c)]
        return rs[2].wait(), rs[0].wait(), rs[1].wait()

    def blocking(a, b, c):
        o = [eng.allreduce(v, "x", algorithm="recursive_doubling")
             for v in (a, b, c)]
        return o[2], o[0], o[1]

    args = tuple(jnp.asarray(rng.normal(size=(8, n)), jnp.float32)
                 for n in (40, 8, 24))
    specs = (P("x"),) * 3
    got = eng.run(queued, in_specs=specs, out_specs=(P(),) * 3)(*args)
    want = eng.run(blocking, in_specs=specs, out_specs=(P(),) * 3)(*args)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    assert eng.queue.stats["coalesced_buckets"] > before


def test_itree_allreduce_matches_blocking(mesh222, rng):
    """The trainer's queued gradient path (issue-all-then-wait tickets)
    is bitwise-identical to the blocking tree_allreduce."""
    eng = CollectiveEngine(mesh222)
    tree = {"w": jnp.asarray(rng.normal(size=(2, 2, 2, 6)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(2, 2, 2, 3)), jnp.float32)}
    spec = {"w": P("pod", "data", "model"), "b": P("pod", "data", "model")}

    got = eng.run(lambda t: eng.itree_allreduce(t, ("data", "pod")).wait(),
                  in_specs=(spec,), out_specs=spec)(tree)
    want = eng.run(lambda t: eng.tree_allreduce(t, ("data", "pod")),
                   in_specs=(spec,), out_specs=spec)(tree)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(got[k]),
                                      np.asarray(want[k]))


# --------------------------------------------------------------------------
# FIFO + dependency ordering (property test)
# --------------------------------------------------------------------------

class _FakeEngine:
    """Duck-typed engine that records drain order instead of executing;
    enough surface for the sequencer (comm sizes, selector, methods)."""

    backend = "microcode"

    def __init__(self, axes):
        self.mesh = types.SimpleNamespace(shape=dict(axes))
        self.selector = Selector()
        self.log = []

    def comm(self, axis):
        return Communicator(axis=axis, size=self.mesh.shape[axis])

    def _run(self, x, axis, **_kw):
        return np.asarray(x)

    allreduce = reduce_scatter = allgather = bcast = reduce = _run
    gather = alltoall = _run

    def collective(self, name, x, axis, **_kw):
        return np.asarray(x)


class _TracingSequencer(Sequencer):
    """Records the order requests complete (deps recurse inside
    `_run_item`, so completion order IS execution order)."""

    def __init__(self, engine, **kw):
        super().__init__(engine, **kw)
        self.order = []

    def _finish(self, r, result):
        super()._finish(r, result)
        self.order.append(r)


@settings(max_examples=25, deadline=None)
@given(data=st.data())
def test_fifo_and_dependency_order_never_violated(data):
    """Property: whatever the wait order, (a) requests on one
    communicator execute in issue order (FIFO), (b) every dependency —
    inferred from buffer identity, explicit `after=`, or a Request
    operand — executes before its dependent."""
    eng = _FakeEngine({"x": 8, "y": 4})
    seq = _TracingSequencer(eng, coalesce_bytes=0)  # ordering only
    reqs = []
    n_req = data.draw(st.integers(min_value=2, max_value=10))
    arrays = []
    for _ in range(n_req):
        axis = ("x", "y")[data.draw(st.integers(0, 1))]
        kind = data.draw(st.integers(0, 3)) if reqs else 0
        after = None
        if kind == 1 and arrays:  # same-buffer conflict
            x = arrays[data.draw(st.integers(0, len(arrays) - 1))]
        elif kind == 2:           # request-operand chaining
            x = reqs[data.draw(st.integers(0, len(reqs) - 1))]
        else:
            x = np.zeros((data.draw(st.integers(1, 8)) * 8,), np.float32)
            arrays.append(x)
            if kind == 3:         # explicit after= edge
                after = (reqs[data.draw(st.integers(0, len(reqs) - 1))],)
        reqs.append(seq.issue("allreduce", x, axis, after=after))
    # wait a random subset in a random order, then drain the rest
    n_waits = data.draw(st.integers(0, n_req))
    for _ in range(n_waits):
        reqs[data.draw(st.integers(0, n_req - 1))].wait()
    seq.drain()

    assert len(seq.order) == n_req
    done_at = {r: i for i, r in enumerate(seq.order)}
    for axis in ("x", "y"):
        issued = [r for r in reqs if r.axis == axis]
        executed = sorted(issued, key=lambda r: done_at[r])
        assert executed == issued  # per-communicator FIFO
    for r in reqs:
        for d in r.deps:
            assert done_at[d] < done_at[r]
        if isinstance(r.operand, type(reqs[0])):
            assert done_at[r.operand] < done_at[r]


# --------------------------------------------------------------------------
# Coalescing (property test): bitwise-equal to uncoalesced issues
# --------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(data=st.data())
def test_coalesced_buckets_bitwise_equal_uncoalesced(engines, data):
    """Property: a coalesced bucket's per-request results are bitwise
    identical to issuing each request alone — for fp32 (non-associative
    adds: only true because the bucket algorithm's elementwise combine
    order is position-independent) and int8 (wrapping adds)."""
    eng = engines
    n = 8
    dtype = (np.float32, np.int8)[data.draw(st.integers(0, 1))]
    op = ("add", "max")[data.draw(st.integers(0, 1))]
    m = data.draw(st.integers(2, 4))
    sizes = [data.draw(st.integers(1, 40)) for _ in range(m)]
    seed = data.draw(st.integers(0, 1 << 16))
    prng = np.random.default_rng(seed)

    seq = Sequencer(eng)
    feeds, reqs = {}, []
    for sz in sizes:
        x = np.zeros((sz,), dtype)
        r = seq.issue("allreduce", x, "x", op=op,
                      algorithm="recursive_doubling")
        feeds[r] = [prng.integers(-50, 50, size=(sz,)).astype(dtype)
                    for _ in range(n)]
        reqs.append(r)
    plan = seq.plan("x")
    assert len(plan) == 1 and plan[0].coalesced  # the bucket formed
    got = seq.simulate_drain(feeds)

    comm = eng.comm("x")
    sched = eng._cached_schedule("allreduce", "recursive_doubling",
                                 comm, 0, op)
    prog = sched.compile()
    for r in reqs:
        want = simulator.run_collective("allreduce", sched, prog,
                                        feeds[r])
        for rank in range(n):
            np.testing.assert_array_equal(got[r][rank], want[rank])


def test_conflicting_requests_do_not_coalesce(engines, rng):
    """Same-buffer conflicts carry a dependency edge, which excludes the
    dependent request from any bucket (members must be independent)."""
    eng = engines
    seq = Sequencer(eng)
    x = np.zeros((16,), np.float32)
    r1 = seq.issue("allreduce", x, "x", algorithm="recursive_doubling")
    r2 = seq.issue("allreduce", x, "x", algorithm="recursive_doubling")
    assert r2.deps == (r1,)
    plan = seq.plan("x")
    assert all(not it.coalesced for it in plan)
    seq.clear()


def test_large_or_mixed_requests_do_not_coalesce(engines):
    eng = engines
    seq = Sequencer(eng)
    seq.issue("allreduce", np.zeros((1 << 18,), np.float32), "x")
    seq.issue("allreduce", np.zeros((1 << 18,), np.float32), "x")
    assert all(not it.coalesced for it in seq.plan("x"))  # > cap
    seq.clear()
    seq.issue("allreduce", np.zeros((16,), np.float32), "x")
    seq.issue("allreduce", np.zeros((16,), np.int8), "x")
    assert all(not it.coalesced for it in seq.plan("x"))  # dtype split
    seq.clear()
    # ring is NOT order-safe (per-chunk combine order): explicit rings
    # never bucket even when tiny
    seq.issue("allreduce", np.zeros((16,), np.float32), "x",
              algorithm="ring")
    seq.issue("allreduce", np.zeros((16,), np.float32), "x",
              algorithm="ring")
    assert all(not it.coalesced for it in seq.plan("x"))
    seq.clear()


# --------------------------------------------------------------------------
# Makespan: the queue-level pricing model
# --------------------------------------------------------------------------

def test_cost_terms_decomposes_cost(engines):
    """Program.cost_terms is an exact split of Program.cost (latency
    half + wire half) for every algorithm/segment shape the queue
    prices."""
    comm = Communicator(axis="x", size=8)
    sel = Selector()
    for coll, nbytes in (("allreduce", 1 << 20), ("allreduce", 4096),
                         ("reduce_scatter", 1 << 22),
                         ("allgather", 1 << 16)):
        choice = sel.choose(coll, nbytes, comm)
        prog = choice.program
        lat, wire = prog.cost_terms(nbytes, comm)
        assert lat > 0 and wire > 0
        assert lat + wire == pytest.approx(prog.cost(nbytes, comm),
                                           rel=1e-12)


def test_makespan_of_independent_queue_strictly_below_serial(engines,
                                                             rng):
    """Acceptance: a queue of >= 4 independent same-axis collectives
    prices strictly below the sum of blocking Program.costs, and the
    simulator-executed drain is bitwise-equal to the blocking sequence."""
    eng = engines
    n = 8
    seq = Sequencer(eng)
    feeds, reqs = {}, []
    for _ in range(4):
        x = np.zeros((1 << 16,), np.float32)  # > coalesce cap: no bucket
        r = seq.issue("allreduce", x, "x")
        feeds[r] = [rng.normal(size=(1 << 16,)).astype(np.float32)
                    for _ in range(n)]
        reqs.append(r)
    assert all(not it.coalesced for it in seq.plan("x"))
    comm = eng.comm("x")
    makespan = seq.makespan("x")
    serial = seq.serial_cost("x")
    # the serial reference really is the sum of blocking Program.costs
    choice = eng.selector.choose("allreduce", 4 << 16, comm, elem_bytes=4)
    assert serial == pytest.approx(
        4 * choice.program.cost(4 << 16, comm), rel=1e-12)
    assert makespan < serial
    assert makespan >= choice.program.cost(4 << 16, comm)  # >= one call

    got = seq.simulate_drain(feeds)
    sched, prog = choice.schedule, choice.program
    for r in reqs:
        want = simulator.run_collective("allreduce", sched, prog,
                                        feeds[r])
        for rank in range(n):
            np.testing.assert_array_equal(got[r][rank], want[rank])


def test_makespan_dependency_chain_gets_no_credit(engines):
    """A fully serial chain (each request consuming the previous one's
    result) prices as the sum of full costs — the queue model never
    grants overlap a dependency forbids."""
    eng = engines
    seq = Sequencer(eng)
    r = seq.issue("allreduce", np.zeros((1 << 16,), np.float32), "x")
    for _ in range(3):
        r = seq.issue("allreduce", r, "x")
    assert seq.makespan("x") == pytest.approx(seq.serial_cost("x"),
                                              rel=1e-9)
    seq.clear()


def test_after_override_never_drops_dataflow_edges(engines):
    """Regression: `after=` overrides the buffer-identity inference
    only — a Request operand is a structural dataflow edge the drain
    must serialize, so the makespan may not price it away."""
    eng = engines
    seq = Sequencer(eng)
    r1 = seq.issue("allreduce", np.zeros((1 << 18,), np.float32), "x")
    r2 = seq.issue("allreduce", r1, "x", after=[])
    assert r1 in r2.deps
    assert seq.makespan("x") == pytest.approx(seq.serial_cost("x"),
                                              rel=1e-9)
    seq.clear()


def test_makespan_coalesced_bucket_prices_one_program(engines):
    """Tiny requests coalesce: the queue's makespan equals ONE bucketed
    program's cost, far below the m-alpha serial sum."""
    eng = engines
    seq = Sequencer(eng)
    for _ in range(6):
        seq.issue("allreduce", np.zeros((64,), np.float32), "x")
    plan = seq.plan("x")
    assert len(plan) == 1 and plan[0].coalesced
    comm = eng.comm("x")
    choice = eng.selector.choose("allreduce", 6 * 64 * 4, comm,
                                 elem_bytes=4)
    assert seq.makespan("x") == pytest.approx(
        choice.program.cost(6 * 64 * 4, comm), rel=1e-12)
    assert seq.makespan("x") < seq.serial_cost("x")
    seq.clear()


def test_empty_and_single_request_makespan(engines):
    eng = engines
    seq = Sequencer(eng)
    assert seq.makespan("x") == 0.0
    seq.issue("allreduce", np.zeros((1 << 16,), np.float32), "x")
    assert seq.makespan("x") == pytest.approx(seq.serial_cost("x"),
                                              rel=1e-9)
    seq.clear()


def test_simulate_drain_honours_op_and_root_under_auto(engines, rng):
    """Regression: an auto-algorithm request with op='max' (or a nonzero
    root) must simulate the schedule REBUILT for that op/root — not the
    selector's op='add'/root=0 pricing schedule (the engine drain always
    did this via _resolve; the simulator path must match)."""
    eng = engines
    n = 8
    seq = Sequencer(eng)
    x = np.zeros((32,), np.float32)
    r = seq.issue("allreduce", x, "x", op="max")
    feeds = {r: [rng.normal(size=(32,)).astype(np.float32)
                 for _ in range(n)]}
    got = seq.simulate_drain(feeds)
    want = np.max(np.stack(feeds[r]), axis=0)
    for rank in range(n):
        np.testing.assert_allclose(got[r][rank], want, rtol=1e-6)

    seq2 = Sequencer(eng)
    y = np.zeros((24,), np.float32)
    r2 = seq2.issue("bcast", y, "x", root=3)
    feeds2 = {r2: [rng.normal(size=(24,)).astype(np.float32)
                   for _ in range(n)]}
    got2 = seq2.simulate_drain(feeds2)
    for rank in range(n):
        np.testing.assert_array_equal(got2[r2][rank], feeds2[r2][3])


def test_issue_records_static_result_shapes(engines):
    eng = engines
    seq = Sequencer(eng)
    r1 = seq.issue("reduce_scatter", np.zeros((64,), np.float32), "x")
    assert r1.shape == (8,)
    r2 = seq.issue("allgather", r1, "x")
    assert r2.shape == (64,)
    assert r2.deps == (r1,)
    with pytest.raises(ValueError):
        _ = r2.result  # not materialized yet
    seq.clear()
