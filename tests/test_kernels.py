"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (ref.py),
with hypothesis property tests where invariants exist."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref


@pytest.mark.parametrize("shape", [(8,), (1000, 7), (3, 5, 64), (4096,)])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_fused_add(rng, shape, dtype):
    x = jnp.asarray(rng.normal(size=shape), dtype)
    y = jnp.asarray(rng.normal(size=shape), dtype)
    out = ops.fused_add(x, y)
    np.testing.assert_allclose(
        np.asarray(out, np.float32),
        np.asarray(ref.fused_combine(x, y), np.float32), atol=1e-2)


@pytest.mark.parametrize("op", ["add", "max", "min", "mul"])
def test_fused_combine_ops(rng, op):
    x = jnp.asarray(rng.normal(size=(257, 3)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(257, 3)), jnp.float32)
    out = ops.fused_combine(x, y, op=op)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.fused_combine(x, y, op)),
                               atol=1e-5)


@pytest.mark.parametrize("n", [256, 1000, 100_000])
def test_quantize_roundtrip(rng, n):
    flat = jnp.asarray(rng.normal(size=(n,)) * 13, jnp.float32)
    q, s = ops.quantize_int8(flat)
    assert q.dtype == jnp.int8
    back = np.asarray(ops.dequantize_int8(q, s))[:n]
    rel = np.abs(back - np.asarray(flat)).max() / (
        np.abs(np.asarray(flat)).max() + 1e-9)
    assert rel < 0.01


@given(scale=st.floats(1e-3, 1e3), seed=st.integers(0, 2 ** 16))
@settings(max_examples=10, deadline=None)
def test_quantize_scale_invariance(scale, seed):
    """Quantization is (nearly) scale-equivariant: codes may shift by at
    most one step (fp32 division rounding moves .5 boundaries), scales
    scale exactly."""
    r = np.random.default_rng(seed)
    flat = jnp.asarray(r.normal(size=(512,)), jnp.float32)
    q1, s1 = ops.quantize_int8(flat)
    q2, s2 = ops.quantize_int8(flat * scale)
    diff = np.abs(np.asarray(q1, np.int32)[:512]
                  - np.asarray(q2, np.int32)[:512])
    assert diff.max() <= 1, diff.max()
    real_blocks = 512 // 256  # beyond these, scales are the clamp floor
    np.testing.assert_allclose(np.asarray(s2)[:real_blocks],
                               np.asarray(s1)[:real_blocks] * scale,
                               rtol=1e-4)


@pytest.mark.parametrize("m,k,n", [(300, 200, 100), (512, 512, 512),
                                   (64, 384, 128), (1, 128, 1),
                                   (257, 129, 65)])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_matmul(rng, m, k, n, dtype):
    a = jnp.asarray(rng.normal(size=(m, k)), dtype)
    b = jnp.asarray(rng.normal(size=(k, n)), dtype)
    out = ops.matmul(a, b)
    expect = ref.matmul(a, b)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=2e-2 if dtype != np.float32 else 1e-3,
                               rtol=2e-2)


@pytest.mark.parametrize("v,d,b", [(100, 32, 16), (1000, 96, 64),
                                   (37, 128, 5)])
def test_embedding_gather(rng, v, d, b):
    table = jnp.asarray(rng.normal(size=(v, d)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, v, size=(b,)), jnp.int32)
    out = ops.embedding_gather(table, idx)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.gather_rows(table, idx)))


def test_vmem_block_alignment():
    """Kernel block shapes stay MXU/VPU aligned and within VMEM budget."""
    from repro.core.hw_spec import TPU_V5E
    from repro.kernels import fused_reduce as fr
    from repro.kernels import matmul as mm
    assert fr.LANES % 128 == 0
    # matmul working set: x-tile + y-tile + fp32 acc must fit VMEM
    ws = (mm.DEFAULT_BM * mm.DEFAULT_BK * 2 + mm.DEFAULT_BK * mm.DEFAULT_BN
          * 2 + mm.DEFAULT_BM * mm.DEFAULT_BN * 4)
    assert ws < TPU_V5E.vmem_bytes
    for d in (mm.DEFAULT_BM, mm.DEFAULT_BN, mm.DEFAULT_BK):
        assert d % 128 == 0
