"""Static verifier (core/verify.py): builtin sweep + mutation matrix.

Two halves:

  1. `test_builtin_programs_all_verify` — every built-in algorithm x
     rank count x segments x codec x hierarchical composition compiles
     AND fully verifies (the sweep the CI verify lane runs; set
     VERIFY_EXHAUSTIVE=1 to widen the grid). The sweep was clean when
     the verifier landed — this test pins that fact.

  2. Mutation matrix — for each rule id, a minimally broken
     schedule/program that the owning pass (and ONLY that pass) rejects,
     with the rule id asserted. This is the verifier's own regression
     net: a pass that silently stops firing fails here.
"""
import dataclasses
import os

import numpy as np
import pytest

from repro.core import algorithms, hierarchical, plugins, verify
from repro.core.program import (
    Copy, Compress, Decompress, Program, RecvCombine, Send, StreamChain,
)
from repro.core.schedule import Schedule, Sel, Step
from repro.core.sequencer import DrainModeError, Sequencer
from repro.core.topology import Communicator, ProductComm
from repro.core.verify import RULES, VerifyError, verify_program


def _comm(n):
    return Communicator(axis="x", size=n)


def _pcomm(P, M):
    return ProductComm(
        outer=Communicator(axis="pod", size=P, is_dcn=True),
        inner=Communicator(axis="x", size=M))


# --------------------------------------------------------------------------
# 1. The exhaustive built-in sweep (CI verify lane)
# --------------------------------------------------------------------------

def test_builtin_programs_all_verify():
    """Every built-in collective program passes full verification.

    The sweep that landed with the verifier found NO latent IR
    inconsistency in the existing lowerings; this test pins that."""
    exhaustive = bool(os.environ.get("VERIFY_EXHAUSTIVE"))
    sizes = (2, 3, 4, 5, 8) + ((16, 12) if exhaustive else ())
    seg_grid = (1, 2, 4) + ((8,) if exhaustive else ())
    codecs = (None, "bf16", "int8")
    checked = 0
    for (coll, algo), gen in algorithms.GENERATORS.items():
        for n in sizes:
            try:
                sched = gen(_comm(n))
            except ValueError:
                continue  # pow2-only generator on a non-pow2 size
            for segments in seg_grid:
                for codec in codecs:
                    sched.compile(segments=segments, codec=codec,
                                  verify="full")
                    checked += 1
    shapes = ((2, 2), (2, 4), (4, 2), (3, 4)) + \
        (((4, 4), (2, 8)) if exhaustive else ())
    for coll in ("allreduce", "reduce_scatter", "allgather", "bcast"):
        for P, M in shapes:
            for inter in hierarchical.inter_candidates(coll, P):
                try:
                    sched = hierarchical.hierarchical_schedule(
                        coll, _pcomm(P, M), intra="ring", inter=inter)
                except ValueError:
                    continue
                for segments in (1, 2):
                    for codec in (None, "int8"):
                        sched.compile(segments=segments, codec=codec,
                                      verify="full")
                        checked += 1
    assert checked > 500  # the sweep actually swept


def test_verification_is_bitwise_neutral():
    """Verification never alters the compiled artifact: compiling with
    verify='off' and verify='full' yields identical programs (and the
    memoized compile returns the same object)."""
    sched = algorithms.ring_allreduce(_comm(8))
    p_off = sched.compile(verify="off")
    p_full = sched.compile(verify="full")
    assert p_off is p_full  # same cache entry, upgraded in place
    fresh = algorithms.ring_allreduce(_comm(8))
    assert fresh.compile(verify="full").describe() == p_off.describe()


def test_bad_verify_level_rejected():
    sched = algorithms.ring_allreduce(_comm(4))
    with pytest.raises(ValueError, match="verify must be one of"):
        sched.compile(verify="paranoid")
    with pytest.raises(ValueError, match="verify level"):
        verify_program(sched.compile(verify="off"), sched, level="nope")


# --------------------------------------------------------------------------
# 2. Mutation matrix — one minimally broken program per rule id
# --------------------------------------------------------------------------

_PASSES = {
    "structural": lambda p, s: verify.structural_pass(p),
    "exchange": lambda p, s: verify.exchange_pass(p, full=True),
    "deadlock": lambda p, s: verify.deadlock_pass(p),
    "level": lambda p, s: verify.level_pass(p),
    "dataflow": lambda p, s: verify.dataflow_pass(p, s),
    "stream": lambda p, s: verify.stream_pass(p),
}


def _assert_only_pass(prog, sched, owning_pass, rule):
    """The owning pass rejects with `rule`; every other pass accepts."""
    for name, fn in _PASSES.items():
        if name == owning_pass:
            with pytest.raises(VerifyError) as ei:
                fn(prog, sched)
            assert ei.value.rule == rule, (
                f"{owning_pass} raised {ei.value.rule}, wanted {rule}")
        else:
            fn(prog, sched)  # must not raise
    # and the front door reports the same rule
    with pytest.raises(VerifyError) as ei:
        verify_program(prog, sched, level="full")
    assert ei.value.rule == rule
    assert rule in RULES


def test_mutation_dropped_recv_xm_unmatched():
    """Dropped pair on an unmasked exchange -> XM_UNMATCHED_RECV only
    (an allreduce keeps the dataflow walk clean: full-buffer init)."""
    sched = algorithms.recursive_doubling_allreduce(_comm(4))
    s0 = sched.steps[0]
    mut = dataclasses.replace(
        sched, steps=(dataclasses.replace(s0, perm=s0.perm[:-1]),)
        + sched.steps[1:])
    prog = mut.compile(verify="off")
    _assert_only_pass(prog, mut, "exchange", "XM_UNMATCHED_RECV")
    err = pytest.raises(VerifyError, verify_program, prog, mut).value
    assert err.rank == 2 and "receive nothing" in str(err)


def test_mutation_dsts_drift_xm_dsts_mismatch():
    sched = algorithms.binomial_tree_bcast(_comm(4))
    prog = sched.compile(verify="off")
    # tamper the compiled RecvCombine.dsts out from under the perm
    def bad(op):
        if isinstance(op, RecvCombine) and op.dsts is not None:
            return dataclasses.replace(op, dsts=op.dsts + (3,) if 3 not in
                                       op.dsts else op.dsts[:-1])
        return op
    ops = tuple(bad(o) for o in prog.ops)
    mut = dataclasses.replace(prog, ops=ops)
    with pytest.raises(VerifyError) as ei:
        verify.exchange_pass(mut, full=False)
    assert ei.value.rule == "XM_DSTS_MISMATCH"


def test_mutation_byte_count_mismatch():
    """Send region of 1 chunk against a 2-chunk receive window."""
    n = 4
    perm = tuple(_comm(n).ring_perm(1))
    sched = Schedule(
        name="mut", collective="allreduce", nranks=n, chunks=n,
        result="full",
        steps=(Step(perm=perm, op="copy",
                    send_sel=Sel.chunk(lambda r, s: r),
                    recv_sel=Sel.range(lambda r, s: ((r - 1) % (n - 1), 2)),
                    bytes_frac=1.0 / n),))
    prog = sched.compile(verify="off")
    _assert_only_pass(prog, sched, "exchange", "XM_BYTES_MISMATCH")


def test_mutation_bytes_frac_drift():
    sched = algorithms.ring_reduce_scatter(_comm(4))
    mut = dataclasses.replace(
        sched, steps=tuple(dataclasses.replace(s, bytes_frac=1.0)
                           for s in sched.steps))
    prog = mut.compile(verify="off")
    _assert_only_pass(prog, mut, "exchange", "XM_BYTES_FRAC")


def test_mutation_codec_mismatch_scale_block():
    perm = ((0, 1), (1, 0))
    body = (Copy("load", Sel.all(), step=0), Compress("int8"),
            Send(perm, bytes_frac=1.0), Decompress("bf16"),
            RecvCombine("add", Sel.all(), step=0))
    prog = Program(name="mut", collective="allreduce", nranks=2, chunks=1,
                   relay="buffer", segments=1, codec="int8", ops=body)
    _assert_only_pass(prog, None, "exchange", "XM_SCALE_BLOCK")


def test_mutation_self_send_deadlock():
    sched = algorithms.recursive_doubling_allreduce(_comm(4))
    s0 = sched.steps[0]
    mut = dataclasses.replace(
        sched, steps=(dataclasses.replace(
            s0, perm=((0, 0), (2, 3), (3, 2)), mask_recv=True),)
        + sched.steps[1:])
    prog = mut.compile(verify="off")
    _assert_only_pass(prog, mut, "deadlock", "DL_SELF_SEND")


def test_mutation_read_before_write():
    """Allgather wiring a neighbour's chunk the rank never received."""
    n = 4
    perm = tuple(_comm(n).ring_perm(1))
    sched = Schedule(
        name="mut", collective="allgather", nranks=n, chunks=n,
        result="full",
        steps=tuple(
            Step(perm=perm, op="copy",
                 send_sel=Sel.chunk(lambda r, s: (r + 1) % n),
                 recv_sel=Sel.chunk(lambda r, s: r),
                 bytes_frac=1.0 / n, uniform=True)
            for _ in range(n - 1)))
    prog = sched.compile(verify="off")
    _assert_only_pass(prog, sched, "dataflow", "DF_READ_BEFORE_WRITE")


def test_mutation_combine_into_unwritten():
    n = 4
    perm = tuple(_comm(n).ring_perm(1))
    sched = Schedule(
        name="mut", collective="allgather", nranks=n, chunks=n,
        result="full",
        steps=(Step(perm=perm, op="add",
                    send_sel=Sel.chunk(lambda r, s: r),
                    recv_sel=Sel.chunk(lambda r, s: (r - 1) % n),
                    bytes_frac=1.0 / n),))
    prog = sched.compile(verify="off")
    _assert_only_pass(prog, sched, "dataflow", "DF_COMBINE_UNWRITTEN")


def test_mutation_double_write():
    """Two steps re-delivering the same chunk to the same rank."""
    n = 4
    perm = tuple(_comm(n).ring_perm(1))
    step = Step(perm=perm, op="copy",
                send_sel=Sel.chunk(lambda r, s: r),
                recv_sel=Sel.chunk(lambda r, s: (r - 1) % n),
                bytes_frac=1.0 / n)
    sched = Schedule(name="mut", collective="allgather", nranks=n,
                     chunks=n, result="full", steps=(step, step))
    prog = sched.compile(verify="off")
    _assert_only_pass(prog, sched, "dataflow", "DF_DOUBLE_WRITE")


def test_mutation_truncated_ring_coverage():
    sched = algorithms.ring_allgather(_comm(4))
    mut = dataclasses.replace(sched, steps=sched.steps[:-1])
    prog = mut.compile(verify="off")
    _assert_only_pass(prog, mut, "dataflow", "DF_COVERAGE")


def _tagged_allreduce(P=2, M=2, level_perm=((0, 1), (1, 0)),
                      level_sizes="auto"):
    """Flat-rank allreduce step carrying intra-level tags (the shape
    `hierarchical._remap_phase` emits)."""
    perm = hierarchical._expand_intra_perm(level_perm, P)
    if level_sizes == "auto":
        level_sizes = (("inter", P), ("intra", M))
    step = Step(perm=perm, op="add", send_sel=Sel.all(),
                recv_sel=Sel.all(), bytes_frac=1.0,
                level="intra", level_perm=level_perm)
    return Schedule(name="tagged", collective="allreduce", nranks=P * M,
                    steps=(step,), chunks=1, result="full",
                    level_sizes=level_sizes)


def test_tagged_schedule_verifies_clean():
    sched = _tagged_allreduce()
    verify_program(sched.compile(verify="off"), sched, level="full")


def test_mutation_orphan_level_tag():
    """A level-tagged step in a program with no level_sizes."""
    sched = _tagged_allreduce(level_sizes=None)
    prog = sched.compile(verify="off")
    _assert_only_pass(prog, sched, "level", "LV_ORPHAN_LEVEL")


def test_mutation_level_perm_out_of_range():
    """Valid flat perm, but the level_perm annotation names a local rank
    outside the level — only the level pass can see this."""
    good = _tagged_allreduce()
    s0 = good.steps[0]
    mut = dataclasses.replace(
        good, steps=(dataclasses.replace(s0, level_perm=((0, 1), (1, 5))),))
    prog = mut.compile(verify="off")
    _assert_only_pass(prog, mut, "level", "LV_PERM_MISMATCH")


def test_mutation_level_perm_wrong_expansion():
    """level_perm disagrees with the flat perm the simulator executes."""
    good = _tagged_allreduce()
    s0 = good.steps[0]
    mut = dataclasses.replace(
        good, steps=(dataclasses.replace(s0, level_perm=((1, 0), (0, 1))),))
    prog = mut.compile(verify="off")
    _assert_only_pass(prog, mut, "level", "LV_PERM_MISMATCH")


def test_mutation_unsafe_stream_chain():
    """Hand-built STREAM_CHAIN whose head/tail segments collide — the
    proof `fuse_chains` would never have accepted."""
    perm = ((0, 1), (1, 0))
    chunks = 6

    def body(load_off, comb_off, step):
        return (Copy("load", Sel.range(lambda r, s, o=load_off: (o, 2)),
                     step=step),
                Send(perm, bytes_frac=2.0 / chunks),
                RecvCombine("copy",
                            Sel.range(lambda r, s, o=comb_off: (o, 2)),
                            step=step))

    # wave 2's payload head [1, 2) overlaps wave 1's combine tail [1, 2)
    chain = StreamChain(segments=2, bodies=(body(2, 0, 0), body(1, 4, 1)))
    prog = Program(name="mut", collective="custom", nranks=2,
                   chunks=chunks, relay="buffer", segments=2, codec=None,
                   ops=(chain,))
    _assert_only_pass(prog, None, "stream", "DF_STREAM_UNSAFE")


def test_rule_ids_structural_and_bounds():
    """Shape and bounds defects report their ST_* rules (these fire from
    the shared IR walk, so no single-pass isolation applies)."""
    torn = Program(name="mut", collective="allreduce", nranks=2, chunks=1,
                   relay="buffer", segments=1, codec=None,
                   ops=(Copy("load", Sel.all(), step=0),
                        Send(((0, 1), (1, 0)))))
    err = pytest.raises(VerifyError, verify_program, torn, None).value
    assert err.rule == "ST_BODY_SHAPE"

    n = 4
    sched = Schedule(
        name="mut", collective="allgather", nranks=n, chunks=n,
        result="full",
        steps=(Step(perm=tuple(_comm(n).ring_perm(1)), op="copy",
                    send_sel=Sel.chunk(lambda r, s: r + n),
                    recv_sel=Sel.chunk(lambda r, s: (r - 1) % n),
                    bytes_frac=1.0 / n),))
    err = pytest.raises(VerifyError, verify_program,
                        sched.compile(verify="off"), sched).value
    assert err.rule == "ST_SEL_BOUNDS"
    # structural mode never evaluates selectors: same program passes
    verify_program(sched.compile(verify="off"), sched, level="structural")

    dup = algorithms.recursive_doubling_allreduce(_comm(4))
    s0 = dup.steps[0]
    mutd = dataclasses.replace(
        dup, steps=(dataclasses.replace(
            s0, perm=((0, 1), (1, 0), (2, 1), (3, 2)), mask_recv=True),)
        + dup.steps[1:])
    err = pytest.raises(VerifyError, verify_program,
                        mutd.compile(verify="off"), mutd).value
    assert err.rule == "ST_PERM_DUP"


def test_verify_error_carries_addressing():
    sched = algorithms.ring_allgather(_comm(4))
    mut = dataclasses.replace(sched, steps=sched.steps[:-1])
    err = pytest.raises(VerifyError, verify_program,
                        mut.compile(verify="off"), mut).value
    assert err.rule == "DF_COVERAGE"
    assert err.rank is not None
    assert "[DF_COVERAGE]" in str(err)
    assert isinstance(err, ValueError)  # plugs into existing handlers


def test_rules_table_covers_every_pass():
    passes = {p for p, _ in RULES.values()}
    assert passes == {"structural", "exchange", "deadlock", "level",
                      "dataflow"}
    assert all(desc for _, desc in RULES.values())


# --------------------------------------------------------------------------
# Sequencer choke point: dep-cycle pass + drain-mode guard (PR 5 item)
# --------------------------------------------------------------------------

def test_request_dag_cycle_rejected(mesh8):
    from repro.core.engine import CollectiveEngine
    eng = CollectiveEngine(mesh8, backend="microcode")
    seq = Sequencer(eng)
    x1 = np.zeros((8,), np.float32)
    x2 = np.zeros((8,), np.float32)
    r1 = seq.issue("allreduce", x1, "x")
    r2 = seq.issue("allreduce", x2, "x", after=(r1,))
    verify.check_request_dag([r1, r2])  # acyclic by construction
    r1.deps = (r2,)  # tamper a cycle in
    with pytest.raises(VerifyError) as ei:
        verify.check_request_dag([r1, r2])
    assert ei.value.rule == "DL_DEP_CYCLE"
    with pytest.raises(VerifyError):
        seq.drain()
    # a dep outside the outstanding set (already done) is not an edge
    r1.deps = ()
    verify.check_request_dag([r1, r2])


def test_simulate_drain_checks_dag(mesh8):
    from repro.core.engine import CollectiveEngine
    eng = CollectiveEngine(mesh8, backend="microcode")
    seq = Sequencer(eng)
    x1 = np.zeros((8,), np.float32)
    r1 = seq.issue("allreduce", x1, "x")
    r2 = seq.issue("allreduce", r1, "x")
    r1.deps = (r2,)
    with pytest.raises(VerifyError) as ei:
        seq.simulate_drain({r1: [np.zeros((8,), np.float32)] * 8})
    assert ei.value.rule == "DL_DEP_CYCLE"


def test_drain_mode_engine_then_simulator_raises(mesh8):
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.core.engine import CollectiveEngine
    eng = CollectiveEngine(mesh8, backend="microcode")

    def queued(a):
        return eng.iallreduce(a, "x").wait()  # engine drain claims queue

    eng.run(queued, in_specs=P("x"), out_specs=P())(
        jnp.zeros((8, 8), jnp.float32))
    seq = eng.queue
    r2 = seq.issue("allreduce", np.zeros((8,), np.float32), "x")
    with pytest.raises(DrainModeError, match="engine"):
        seq.simulate_drain({r2: [np.zeros((8,), np.float32)] * 8})
    assert not r2._done  # typed error, no silent partial drain


def test_drain_mode_simulator_then_engine_raises(mesh8):
    from repro.core.engine import CollectiveEngine
    eng = CollectiveEngine(mesh8, backend="microcode")
    seq = Sequencer(eng)
    x = np.zeros((8,), np.float32)
    r1 = seq.issue("allreduce", x, "x")
    seq.simulate_drain({r1: [np.ones((8,), np.float32)] * 8})
    r2 = seq.issue("allreduce", np.zeros((8,), np.float32), "x")
    with pytest.raises(DrainModeError, match="simulator"):
        r2.wait()
    with pytest.raises(DrainModeError, match="simulator"):
        seq.drain()


# --------------------------------------------------------------------------
# Registration choke point: probe-grid verification
# --------------------------------------------------------------------------

def _good_scatter(comm, root: int = 0):
    n = comm.size
    steps = tuple(
        Step(perm=((root, (root + i + 1) % n),), op="copy",
             send_sel=Sel.chunk(lambda r, s, i=i: (root + i + 1) % n),
             recv_sel=Sel.chunk(lambda r, s, i=i: (root + i + 1) % n),
             bytes_frac=1.0 / n, mask_recv=True)
        for i in range(n - 1))
    return Schedule(name="linear", collective="vscatter", nranks=n,
                    steps=steps, chunks=n, result="shard",
                    owned_chunk=lambda r: r, relay="original")


def _broken_scatter(comm, root: int = 0):
    n = comm.size
    sched = _good_scatter(comm, root)
    # receive window twice the payload: a byte-count mismatch on the wire
    steps = tuple(
        dataclasses.replace(
            s, recv_sel=Sel.range(lambda r, s_, i=i: ((root + i + 1) % n, 1)
                                  if (root + i + 1) % n == n - 1
                                  else ((root + i + 1) % n, 2)))
        for i, s in enumerate(sched.steps))
    return dataclasses.replace(sched, steps=steps)


def test_register_collective_accepts_verified_schedule():
    try:
        plugins.register_collective("vscatter", _good_scatter)
        assert plugins.custom_generator("vscatter", "custom") is not None
    finally:
        plugins.unregister_collective("vscatter")


def test_register_collective_rejects_broken_schedule():
    before = plugins.registry_version()
    with pytest.raises(VerifyError) as ei:
        plugins.register_collective("wscatter", _broken_scatter)
    msg = str(ei.value)
    assert ei.value.rule == "XM_BYTES_MISMATCH"
    assert "cannot register collective 'wscatter'" in msg
    assert "probe nranks=" in msg  # the failing probe point is named
    assert plugins.custom_generator("wscatter", "custom") is None
    assert plugins.registry_version() == before  # registry untouched


def test_register_collective_verify_optout():
    try:
        plugins.register_collective("wscatter2", _broken_scatter,
                                    verify=False)
        assert plugins.custom_generator("wscatter2", "custom") is not None
    finally:
        plugins.unregister_collective("wscatter2")
