"""Cross-step segment streaming + the stacked-receive peephole.

The STREAM micro-op closes the model/execution gap: `SEG_LOOP` pipelines
within a step (the scan carry is a per-step barrier) while the cost model
prices hop-to-hop overlap; `fuse_streams` rewrites eligible uniform runs
into ONE skewed scan that sends step s+1's segment 0 before step s's tail
combine. Contract: streamed programs are BITWISE-equal to their unfused
form, across {fp32, int8} x {ring, bidi-ring, relay}, and the selector's
auto picks carry the streamed program wherever the model predicts a win.

STACKED_RECV is the ROADMAP peephole: relay='original' copy schedules
(explicit algorithm='linear' all-to-all) collapse n-1 full-buffer
update-slices into one chunk scatter — also bitwise-equal.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import CollectiveEngine, Communicator, Selector
from repro.core import algorithms as A
from repro.core import simulator as sim
from repro.core.engine import execute_program
from repro.core.program import (
    Loop, SegLoop, StackedRecv, Stream, compile_schedule,
)
from repro.core.topology import make_mesh

COMM8 = Communicator(axis="x", size=8)


@pytest.fixture(scope="module")
def env():
    mesh = make_mesh((8,), ("x",))
    return CollectiveEngine(mesh, backend="microcode"), mesh


def _run_prog(mesh, prog, X):
    g = jax.jit(jax.shard_map(
        lambda v: execute_program(prog, v[0], "x")[None],
        mesh=mesh, in_specs=P("x"), out_specs=P("x"), check_vma=False))
    return np.asarray(g(jnp.asarray(X)))


# scale-block-aligned payload: 2048/8 ranks = 256-elem chunks, whole int8
# scale blocks at every segment count the tests use
X = np.random.default_rng(3).normal(size=(8, 2048)).astype(np.float32)
# larger buffer for the bitwise parity cells: every chunk (bidi: 1/16 of
# the buffer) splits into whole 256-elem int8 scale blocks at k <= 8, so
# the streams really stream rather than clamping back to k=1
XL = np.random.default_rng(4).normal(size=(8, 16384)).astype(np.float32)


# -- compilation structure ----------------------------------------------------

def test_uniform_segmented_runs_compile_to_streams():
    """Rings at k>1 stream; at k=1 they stay rolled LOOPs; trees and
    masked schedules keep their unrolled SEG_LOOP form."""
    prog = compile_schedule(A.ring_allreduce(COMM8), segments=8)
    assert [type(op) for op in prog.ops] == [Stream, Stream]  # RS + AG
    assert all(op.trip == 7 and op.segments == 8 for op in prog.ops)

    prog = compile_schedule(A.bidi_ring_allreduce(COMM8), segments=4)
    assert [type(op) for op in prog.ops] == [Stream, Stream]
    assert all(op.period == 2 for op in prog.ops)

    prog = compile_schedule(A.ring_reduce(COMM8), segments=4)
    assert [type(op) for op in prog.ops] == [Stream]  # relay='received'

    assert not any(
        isinstance(op, Stream)
        for op in compile_schedule(A.ring_allreduce(COMM8)).ops)
    assert not any(
        isinstance(op, Stream)
        for op in compile_schedule(A.binomial_tree_reduce(COMM8),
                                   segments=4).ops)
    assert not any(
        isinstance(op, Stream)
        for op in compile_schedule(A.bruck_alltoall(COMM8),
                                   segments=4).ops)


def test_stream_pass_can_be_disabled():
    prog = compile_schedule(A.ring_allreduce(COMM8), segments=8,
                            stream=False)
    assert [type(op) for op in prog.ops] == [Loop, Loop]
    assert all(isinstance(slot[0], SegLoop)
               for op in prog.ops for slot in op.slots)


# -- bitwise parity: streamed == unfused --------------------------------------

_PARITY_CELLS = [
    ("ring", A.ring_allreduce, 4), ("ring", A.ring_allreduce, 8),
    ("bidi_ring", A.bidi_ring_allreduce, 4),
    ("relay", A.ring_reduce, 4),
]


@pytest.mark.parametrize("name,gen,k", _PARITY_CELLS,
                         ids=[f"{n}-k{k}" for n, _g, k in _PARITY_CELLS])
@pytest.mark.parametrize("codec", [None, "int8"])
def test_streamed_bitwise_equals_unfused(env, name, gen, k, codec):
    """{fp32, int8} x {ring, bidi-ring, relay}: the fused pipeline must
    reproduce the per-step order exactly — streaming reorders the wire,
    never the numbers."""
    _eng, mesh = env
    sched = gen(COMM8)
    fused = compile_schedule(sched, segments=k, codec=codec)
    plain = compile_schedule(sched, segments=k, codec=codec, stream=False)
    assert any(isinstance(op, Stream) for op in fused.ops)
    assert not any(isinstance(op, Stream) for op in plain.ops)
    np.testing.assert_array_equal(_run_prog(mesh, fused, XL),
                                  _run_prog(mesh, plain, XL))


def test_streamed_copy_ring_bitwise(env):
    """The copy family streams too (ring allgather): bitwise vs unfused,
    and correct against the gathered oracle."""
    _eng, mesh = env
    sched = A.ring_allgather(COMM8)
    fused = compile_schedule(sched, segments=8)
    plain = compile_schedule(sched, segments=8, stream=False)
    assert any(isinstance(op, Stream) for op in fused.ops)
    buf = np.zeros((8, 8 * 256), np.float32)
    for r in range(8):
        buf[r, r * 256:(r + 1) * 256] = X[r, :256]
    a, b = _run_prog(mesh, fused, buf), _run_prog(mesh, plain, buf)
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(
        a[0], np.concatenate([X[r, :256] for r in range(8)]))


def test_simulator_executes_streamed_programs(env):
    """The numpy executor runs the SAME streamed program the engine runs
    and agrees with it exactly (fp32 sums are order-identical)."""
    _eng, mesh = env
    prog = compile_schedule(A.ring_allreduce(COMM8), segments=4)
    got = sim.execute_program(prog, [x.copy() for x in X])
    eng_out = _run_prog(mesh, prog, X)
    for r in range(8):
        np.testing.assert_array_equal(got[r], eng_out[r])


def test_stream_degenerates_safely_on_indivisible_payload(env):
    """A requested segment count the payload cannot honour clamps at
    trace time (fit_segments) — down to plain rolled execution when
    nothing divides."""
    _eng, mesh = env
    sched = A.ring_allreduce(COMM8)
    prog = compile_schedule(sched, segments=8)
    # chunk size 7 elements: no segment count > 1 divides it
    Y = np.random.default_rng(5).normal(size=(8, 8 * 7)).astype(np.float32)
    a = _run_prog(mesh, prog, Y)
    b = _run_prog(mesh, compile_schedule(sched, segments=1), Y)
    np.testing.assert_array_equal(a, b)
    for r in range(8):
        np.testing.assert_allclose(a[r], Y.sum(0), atol=1e-4)


# -- the selector picks the streamed program ----------------------------------

def test_selector_auto_pick_streams_at_1mib():
    """Acceptance: wherever the cost model predicts a segmented win at
    >= 1 MiB, the chosen program actually cross-step streams."""
    sel = Selector()
    for coll in ("allreduce", "reduce_scatter"):
        c = sel.choose(coll, 4 << 20, COMM8)
        assert c.segments > 1
        assert any(isinstance(op, Stream) for op in c.program.ops), coll


def test_copy_collectives_auto_segment_only_when_streamed():
    """Streaming unlocked copy-only segmentation where it is real: ring
    allgather (a uniform run) now auto-segments, while bcast trees and
    all-to-all (unrolled — nothing streams) still pick k=1."""
    sel = Selector()
    ag = sel.choose("allgather", 64 << 20, COMM8)
    assert ag.segments > 1
    assert any(isinstance(op, Stream) for op in ag.program.ops)
    for coll in ("bcast", "alltoall"):
        c = sel.choose(coll, 64 << 20, COMM8)
        assert c.segments == 1, (coll, c.algorithm)
        assert not any(isinstance(op, Stream) for op in c.program.ops)


def test_engine_auto_allreduce_executes_streamed(env):
    """End to end through the engine API: a large auto allreduce lowers
    through the streamed program and still matches the oracle."""
    eng, mesh = env
    big = np.random.default_rng(9).normal(
        size=(8, 1 << 18)).astype(np.float32)  # 1 MiB message per rank
    choice = eng.selector.choose("allreduce", big[0].nbytes,
                                 eng.comm("x"))
    assert choice.segments > 1
    assert any(isinstance(op, Stream) for op in choice.program.ops)
    g = jax.jit(jax.shard_map(
        lambda v: eng.allreduce(v[0], "x", algorithm="auto")[None],
        mesh=mesh, in_specs=P("x"), out_specs=P("x"), check_vma=False))
    out = np.asarray(g(jnp.asarray(big)))
    np.testing.assert_allclose(out[0], big.sum(0), atol=1e-3)


# -- stacked-receive peephole -------------------------------------------------

def test_linear_alltoall_compiles_to_one_stacked_recv():
    prog = compile_schedule(A.linear_alltoall(COMM8))
    assert [type(op) for op in prog.ops] == [StackedRecv]
    assert len(prog.ops[0].bodies) == 7  # n-1 stacked exchanges
    # the peephole leaves segmented compilations alone
    seg = compile_schedule(A.linear_alltoall(COMM8), segments=4)
    assert not any(isinstance(op, StackedRecv) for op in seg.ops)


def test_stacked_recv_bitwise_equals_unrolled(env):
    _eng, mesh = env
    sched = A.linear_alltoall(COMM8)
    stacked = compile_schedule(sched)
    plain = compile_schedule(sched, stacked=False)
    np.testing.assert_array_equal(_run_prog(mesh, stacked, X),
                                  _run_prog(mesh, plain, X))


def test_stacked_recv_simulator_matches_oracle():
    prog = compile_schedule(A.linear_alltoall(COMM8))
    got = sim.execute_program(prog, [x.copy() for x in X])
    refs = sim.oracle("alltoall", list(X))
    for r in range(8):
        np.testing.assert_array_equal(got[r], refs[r])


def test_stacked_recv_not_applied_to_masked_runs():
    """all_to_one gather masks receivers (single pair per step): the
    peephole must leave it alone — non-destinations keep their data."""
    prog = compile_schedule(A.all_to_one_gather(COMM8))
    assert not any(isinstance(op, StackedRecv) for op in prog.ops)
