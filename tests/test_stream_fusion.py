"""Cross-step segment streaming + the stacked-receive peephole.

The STREAM micro-op closes the model/execution gap: `SEG_LOOP` pipelines
within a step (the scan carry is a per-step barrier) while the cost model
prices hop-to-hop overlap; `fuse_streams` rewrites eligible uniform runs
into ONE skewed scan that sends step s+1's segment 0 before step s's tail
combine. Contract: streamed programs are BITWISE-equal to their unfused
form, across {fp32, int8} x {ring, bidi-ring, relay}, and the selector's
auto picks carry the streamed program wherever the model predicts a win.

STACKED_RECV is the ROADMAP peephole: relay='original' copy schedules
(explicit algorithm='linear' all-to-all) collapse n-1 full-buffer
update-slices into one chunk scatter — also bitwise-equal.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import CollectiveEngine, Communicator, Selector
from repro.core import algorithms as A
from repro.core import simulator as sim
from repro.core.engine import execute_program
from repro.core.program import (
    Loop, SegLoop, StackedRecv, Stream, StreamChain, compile_schedule,
)
from repro.core.schedule import Schedule, Sel, Step
from repro.core.topology import make_mesh

COMM8 = Communicator(axis="x", size=8)


@pytest.fixture(scope="module")
def env():
    mesh = make_mesh((8,), ("x",))
    return CollectiveEngine(mesh, backend="microcode"), mesh


def _run_prog(mesh, prog, X):
    g = jax.jit(jax.shard_map(
        lambda v: execute_program(prog, v[0], "x")[None],
        mesh=mesh, in_specs=P("x"), out_specs=P("x"), check_vma=False))
    return np.asarray(g(jnp.asarray(X)))


# scale-block-aligned payload: 2048/8 ranks = 256-elem chunks, whole int8
# scale blocks at every segment count the tests use
X = np.random.default_rng(3).normal(size=(8, 2048)).astype(np.float32)
# larger buffer for the bitwise parity cells: every chunk (bidi: 1/16 of
# the buffer) splits into whole 256-elem int8 scale blocks at k <= 8, so
# the streams really stream rather than clamping back to k=1
XL = np.random.default_rng(4).normal(size=(8, 16384)).astype(np.float32)


# -- compilation structure ----------------------------------------------------

def test_uniform_segmented_runs_compile_to_streams():
    """Rings at k>1 stream; at k=1 they stay rolled LOOPs; trees and
    masked schedules keep their unrolled SEG_LOOP form."""
    prog = compile_schedule(A.ring_allreduce(COMM8), segments=8)
    assert [type(op) for op in prog.ops] == [Stream, Stream]  # RS + AG
    assert all(op.trip == 7 and op.segments == 8 for op in prog.ops)

    prog = compile_schedule(A.bidi_ring_allreduce(COMM8), segments=4)
    assert [type(op) for op in prog.ops] == [Stream, Stream]
    assert all(op.period == 2 for op in prog.ops)

    prog = compile_schedule(A.ring_reduce(COMM8), segments=4)
    assert [type(op) for op in prog.ops] == [Stream]  # relay='received'

    assert not any(
        isinstance(op, Stream)
        for op in compile_schedule(A.ring_allreduce(COMM8)).ops)
    assert not any(
        isinstance(op, Stream)
        for op in compile_schedule(A.binomial_tree_reduce(COMM8),
                                   segments=4).ops)
    assert not any(
        isinstance(op, Stream)
        for op in compile_schedule(A.bruck_alltoall(COMM8),
                                   segments=4).ops)


def test_stream_pass_can_be_disabled():
    prog = compile_schedule(A.ring_allreduce(COMM8), segments=8,
                            stream=False)
    assert [type(op) for op in prog.ops] == [Loop, Loop]
    assert all(isinstance(slot[0], SegLoop)
               for op in prog.ops for slot in op.slots)


# -- bitwise parity: streamed == unfused --------------------------------------

_PARITY_CELLS = [
    ("ring", A.ring_allreduce, 4), ("ring", A.ring_allreduce, 8),
    ("bidi_ring", A.bidi_ring_allreduce, 4),
    ("relay", A.ring_reduce, 4),
]


@pytest.mark.parametrize("name,gen,k", _PARITY_CELLS,
                         ids=[f"{n}-k{k}" for n, _g, k in _PARITY_CELLS])
@pytest.mark.parametrize("codec", [None, "int8"])
def test_streamed_bitwise_equals_unfused(env, name, gen, k, codec):
    """{fp32, int8} x {ring, bidi-ring, relay}: the fused pipeline must
    reproduce the per-step order exactly — streaming reorders the wire,
    never the numbers."""
    _eng, mesh = env
    sched = gen(COMM8)
    fused = compile_schedule(sched, segments=k, codec=codec)
    plain = compile_schedule(sched, segments=k, codec=codec, stream=False)
    assert any(isinstance(op, Stream) for op in fused.ops)
    assert not any(isinstance(op, Stream) for op in plain.ops)
    np.testing.assert_array_equal(_run_prog(mesh, fused, XL),
                                  _run_prog(mesh, plain, XL))


def test_streamed_copy_ring_bitwise(env):
    """The copy family streams too (ring allgather): bitwise vs unfused,
    and correct against the gathered oracle."""
    _eng, mesh = env
    sched = A.ring_allgather(COMM8)
    fused = compile_schedule(sched, segments=8)
    plain = compile_schedule(sched, segments=8, stream=False)
    assert any(isinstance(op, Stream) for op in fused.ops)
    buf = np.zeros((8, 8 * 256), np.float32)
    for r in range(8):
        buf[r, r * 256:(r + 1) * 256] = X[r, :256]
    a, b = _run_prog(mesh, fused, buf), _run_prog(mesh, plain, buf)
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(
        a[0], np.concatenate([X[r, :256] for r in range(8)]))


def test_simulator_executes_streamed_programs(env):
    """The numpy executor runs the SAME streamed program the engine runs
    and agrees with it exactly (fp32 sums are order-identical)."""
    _eng, mesh = env
    prog = compile_schedule(A.ring_allreduce(COMM8), segments=4)
    got = sim.execute_program(prog, [x.copy() for x in X])
    eng_out = _run_prog(mesh, prog, X)
    for r in range(8):
        np.testing.assert_array_equal(got[r], eng_out[r])


def test_stream_degenerates_safely_on_indivisible_payload(env):
    """A requested segment count the payload cannot honour clamps at
    trace time (fit_segments) — down to plain rolled execution when
    nothing divides."""
    _eng, mesh = env
    sched = A.ring_allreduce(COMM8)
    prog = compile_schedule(sched, segments=8)
    # chunk size 7 elements: no segment count > 1 divides it
    Y = np.random.default_rng(5).normal(size=(8, 8 * 7)).astype(np.float32)
    a = _run_prog(mesh, prog, Y)
    b = _run_prog(mesh, compile_schedule(sched, segments=1), Y)
    np.testing.assert_array_equal(a, b)
    for r in range(8):
        np.testing.assert_allclose(a[r], Y.sum(0), atol=1e-4)


# -- the selector picks the streamed program ----------------------------------

def test_selector_auto_pick_streams_at_1mib():
    """Acceptance: wherever the cost model predicts a segmented win at
    >= 1 MiB, the chosen program actually cross-step streams."""
    sel = Selector()
    for coll in ("allreduce", "reduce_scatter"):
        c = sel.choose(coll, 4 << 20, COMM8)
        assert c.segments > 1
        assert any(isinstance(op, Stream) for op in c.program.ops), coll


def test_copy_collectives_auto_segment_only_when_streamed():
    """Copy-only segmentation follows the compiled artifact: ring
    allgather streams (uniform run) and linear all-to-all now chains
    (relay='original' payloads are immutable, so the region proof is
    trivial) — both auto-segment; bcast trees mask receivers, nothing
    streams, and the selector keeps k=1."""
    sel = Selector()
    ag = sel.choose("allgather", 64 << 20, COMM8)
    assert ag.segments > 1
    assert any(isinstance(op, Stream) for op in ag.program.ops)
    a2a = sel.choose("alltoall", 64 << 20, COMM8)
    assert a2a.algorithm == "linear" and a2a.segments > 1
    assert any(isinstance(op, StreamChain) for op in a2a.program.ops)
    c = sel.choose("bcast", 64 << 20, COMM8)
    assert c.segments == 1, c.algorithm
    assert not any(isinstance(op, (Stream, StreamChain))
                   for op in c.program.ops)


def test_engine_auto_allreduce_executes_streamed(env):
    """End to end through the engine API: a large auto allreduce lowers
    through the streamed program and still matches the oracle."""
    eng, mesh = env
    big = np.random.default_rng(9).normal(
        size=(8, 1 << 18)).astype(np.float32)  # 1 MiB message per rank
    choice = eng.selector.choose("allreduce", big[0].nbytes,
                                 eng.comm("x"))
    assert choice.segments > 1
    assert any(isinstance(op, Stream) for op in choice.program.ops)
    g = jax.jit(jax.shard_map(
        lambda v: eng.allreduce(v[0], "x", algorithm="auto")[None],
        mesh=mesh, in_specs=P("x"), out_specs=P("x"), check_vma=False))
    out = np.asarray(g(jnp.asarray(big)))
    np.testing.assert_allclose(out[0], big.sum(0), atol=1e-3)


# -- STREAM_CHAIN: the SEL_RANGE region-overlap proof -------------------------

def test_recursive_schedules_compile_to_chains():
    """Non-uniform log-step schedules chain when (and only when) the
    per-rank region proof holds: recursive halving/doubling at k >= 3,
    the full Rabenseifner allreduce as ONE chain across its RS/AG
    boundary, linear all-to-all at any k (immutable payloads). The
    SEL_ALL hypercube allreduce overlaps send/recv and must never
    chain, and k = 2 halving genuinely fails the proof."""
    for gen, m in ((A.recursive_halving_reduce_scatter, 3),
                   (A.recursive_doubling_allgather, 3),
                   (A.halving_doubling_allreduce, 6)):
        prog = compile_schedule(gen(COMM8), segments=4)
        assert [type(op) for op in prog.ops] == [StreamChain], gen
        assert len(prog.ops[0].bodies) == m
    prog = compile_schedule(A.linear_alltoall(COMM8), segments=2)
    assert [type(op) for op in prog.ops] == [StreamChain]
    assert len(prog.ops[0].bodies) == 7

    # k=2: halving's upper-half head segment reaches into the missing
    # tail write — the proof rejects, the program stays SEG_LOOP-only
    k2 = compile_schedule(A.recursive_halving_reduce_scatter(COMM8),
                          segments=2)
    assert not any(isinstance(op, StreamChain) for op in k2.ops)
    # full-buffer hypercube steps read what the previous step wrote
    rd = compile_schedule(A.recursive_doubling_allreduce(COMM8),
                          segments=4)
    assert not any(isinstance(op, (Stream, StreamChain)) for op in rd.ops)


def test_chain_pass_can_be_disabled():
    prog = compile_schedule(A.recursive_halving_reduce_scatter(COMM8),
                            segments=4, stream=False)
    assert all(isinstance(op, SegLoop) for op in prog.ops)


_CHAIN_CELLS = [
    ("recursive_halving", A.recursive_halving_reduce_scatter, 4),
    ("recursive_halving", A.recursive_halving_reduce_scatter, 8),
    ("halving_doubling", A.halving_doubling_allreduce, 4),
    ("recursive_doubling_ag", A.recursive_doubling_allgather, 4),
]


@pytest.mark.parametrize("name,gen,k", _CHAIN_CELLS,
                         ids=[f"{n}-k{k}" for n, _g, k in _CHAIN_CELLS])
@pytest.mark.parametrize("codec", [None, "int8"])
def test_chained_bitwise_equals_unfused(env, name, gen, k, codec):
    """{fp32, int8} x {recursive halving, Rabenseifner, recursive
    doubling}: the chained pipeline must reproduce the per-step order
    exactly — the SEL_RANGE proof licenses a wire reorder, never a
    numeric change."""
    _eng, mesh = env
    sched = gen(COMM8)
    if codec is not None and all(s.op == "copy" for s in sched.steps):
        pytest.skip("codecs compress combine wires only")
    fused = compile_schedule(sched, segments=k, codec=codec)
    plain = compile_schedule(sched, segments=k, codec=codec, stream=False)
    assert any(isinstance(op, StreamChain) for op in fused.ops)
    assert not any(isinstance(op, StreamChain) for op in plain.ops)
    np.testing.assert_array_equal(_run_prog(mesh, fused, XL),
                                  _run_prog(mesh, plain, XL))


def test_chained_alltoall_bitwise(env):
    _eng, mesh = env
    sched = A.linear_alltoall(COMM8)
    fused = compile_schedule(sched, segments=4)
    plain = compile_schedule(sched, segments=4, stream=False)
    assert any(isinstance(op, StreamChain) for op in fused.ops)
    np.testing.assert_array_equal(_run_prog(mesh, fused, X),
                                  _run_prog(mesh, plain, X))
    refs = sim.oracle("alltoall", list(X))
    got = _run_prog(mesh, fused, X)
    for r in range(8):
        np.testing.assert_array_equal(got[r], refs[r])


def test_simulator_executes_chained_programs(env):
    """The numpy executor runs the SAME chained program the engine runs
    and agrees with it exactly."""
    _eng, mesh = env
    prog = compile_schedule(A.halving_doubling_allreduce(COMM8),
                            segments=4)
    assert any(isinstance(op, StreamChain) for op in prog.ops)
    got = sim.execute_program(prog, [x.copy() for x in X])
    eng_out = _run_prog(mesh, prog, X)
    for r in range(8):
        np.testing.assert_array_equal(got[r], eng_out[r])


def test_chain_clamp_falls_back_bitwise(env):
    """A payload that forces trace-time segment clamping can invalidate
    the compile-time proof (recursive halving's last steps clamp toward
    k=2/k=1 on tiny chunks): the executor re-verifies and falls back to
    per-step execution — still bitwise-equal, never wrong."""
    _eng, mesh = env
    sched = A.recursive_halving_reduce_scatter(COMM8)
    prog = compile_schedule(sched, segments=4)
    assert any(isinstance(op, StreamChain) for op in prog.ops)
    Y = np.random.default_rng(11).normal(size=(8, 8)).astype(np.float32)
    a = _run_prog(mesh, prog, Y)  # csize=1: every step clamps
    b = _run_prog(mesh, compile_schedule(sched, segments=1), Y)
    np.testing.assert_array_equal(a, b)


def _range_ring_reduce_scatter(comm):
    """The chunk ring expressed through SEL_RANGE selectors — a uniform
    SEL_RANGE run, the shape the ROADMAP said could not stream before
    the region proof existed."""
    n = comm.size
    perm = tuple(comm.ring_perm(1))
    send = Sel.range(lambda r, s: ((r - s - 1) % n, 1))
    recv = Sel.range(lambda r, s: ((r - s - 2) % n, 1))
    steps = tuple(
        Step(perm=perm, op="add", send_sel=send, recv_sel=recv,
             bytes_frac=1.0 / n, uniform=True)
        for _ in range(n - 1))
    return Schedule(name="range_ring", collective="reduce_scatter",
                    nranks=n, steps=steps, chunks=n, result="shard",
                    owned_chunk=lambda r: r)


def test_uniform_sel_range_run_streams(env):
    """A uniform SEL_RANGE run coalesces into a LOOP and now streams via
    the region proof (previously only chunk/chunk and relay-register
    payloads were eligible) — bitwise-equal to the unfused form and to
    the chunk-selector ring."""
    _eng, mesh = env
    sched = _range_ring_reduce_scatter(COMM8)
    fused = compile_schedule(sched, segments=4)
    assert [type(op) for op in fused.ops] == [Stream]
    plain = compile_schedule(sched, segments=4, stream=False)
    a, b = _run_prog(mesh, fused, X), _run_prog(mesh, plain, X)
    np.testing.assert_array_equal(a, b)
    chunk_ring = _run_prog(
        mesh, compile_schedule(A.ring_reduce_scatter(COMM8), segments=4),
        X)
    np.testing.assert_array_equal(a, chunk_ring)


def _k_sensitive_range_run(comm):
    """Uniform SEL_RANGE run whose region proof PASSES at k=4 but FAILS
    at k=2: step s+1's payload starts 2 chunks into step s's 4-chunk
    combine region, so the head segment is 1 chunk at k=4 (disjoint from
    the 1-chunk tail) but 2 chunks at k=2 (covering the missing tail
    write)."""
    n = comm.size
    perm = tuple(comm.ring_perm(1))
    send = Sel.range(lambda r, s: (6 * s, 4))
    recv = Sel.range(lambda r, s: (6 * s + 4, 4))
    steps = tuple(
        Step(perm=perm, op="add", send_sel=send, recv_sel=recv,
             bytes_frac=4.0 / 16, uniform=True)
        for _ in range(2))
    return Schedule(name="krange", collective="custom", nranks=n,
                    steps=steps, chunks=16, result="full")


def test_stream_clamp_reruns_range_proof(env):
    """A SEL_RANGE stream proven at the requested k must re-prove itself
    when trace-time clamping admits a smaller count (the proof is
    k-dependent). Here the int8 scale-block constraint clamps k=4 to
    k=2 — exactly the count the proof rejects — and the executor must
    drop to the rolled per-step form instead of executing the unproven
    wave order."""
    _eng, mesh = env
    sched = _k_sensitive_range_run(COMM8)
    fused = compile_schedule(sched, segments=4, codec="int8")
    assert any(isinstance(op, Stream) for op in fused.ops)  # k=4 proven
    assert not any(isinstance(op, Stream)
                   for op in compile_schedule(sched, segments=2,
                                              codec="int8").ops)
    plain = compile_schedule(sched, segments=4, codec="int8", stream=False)
    # chunk size 128 elems: 4-chunk payload = 512, whole 256-elem scale
    # blocks only at k=2 — fit_segments clamps the proven k=4 down
    Y = (np.random.default_rng(3).normal(size=(8, 16 * 128)) * 20
         ).astype(np.float32)
    np.testing.assert_array_equal(_run_prog(mesh, fused, Y),
                                  _run_prog(mesh, plain, Y))


# -- stacked-receive peephole -------------------------------------------------

def test_linear_alltoall_compiles_to_one_stacked_recv():
    prog = compile_schedule(A.linear_alltoall(COMM8))
    assert [type(op) for op in prog.ops] == [StackedRecv]
    assert len(prog.ops[0].bodies) == 7  # n-1 stacked exchanges
    # the peephole leaves segmented compilations alone
    seg = compile_schedule(A.linear_alltoall(COMM8), segments=4)
    assert not any(isinstance(op, StackedRecv) for op in seg.ops)


def test_stacked_recv_bitwise_equals_unrolled(env):
    _eng, mesh = env
    sched = A.linear_alltoall(COMM8)
    stacked = compile_schedule(sched)
    plain = compile_schedule(sched, stacked=False)
    np.testing.assert_array_equal(_run_prog(mesh, stacked, X),
                                  _run_prog(mesh, plain, X))


def test_stacked_recv_simulator_matches_oracle():
    prog = compile_schedule(A.linear_alltoall(COMM8))
    got = sim.execute_program(prog, [x.copy() for x in X])
    refs = sim.oracle("alltoall", list(X))
    for r in range(8):
        np.testing.assert_array_equal(got[r], refs[r])


def test_stacked_recv_not_applied_to_masked_runs():
    """all_to_one gather masks receivers (single pair per step): the
    peephole must leave it alone — non-destinations keep their data."""
    prog = compile_schedule(A.all_to_one_gather(COMM8))
    assert not any(isinstance(op, StackedRecv) for op in prog.ops)
