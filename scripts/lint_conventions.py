#!/usr/bin/env python3
"""AST-based convention linter for in-tree source (stdlib-only).

Replaces the CI grep guards with real syntax-aware rules — greps can
be fooled by multi-line calls (a bare `tier=` on a call's continuation
line) and false-positive on docstrings mentioning a retired name; an
AST visitor sees neither problem.

Rules (each failure prints `path:line: RULE message`):

  LC001 resurrected-legacy
        References in src/ to retired data-plane / pricing entry points.
        The data plane is ONE executor (`engine.execute_program` over a
        compiled `Program`) and pricing is ONE program walk
        (`Program.cost`); the pre-IR per-algorithm lowerings and the
        schedule-walk pricer live only under tests/ as golden oracles.

  LC002 bare-pricing-kwargs
        In-src *calls* to cost/cost_terms/makespan/price_program passing
        the deprecated bare `tier=` / `drop_prob=` kwargs instead of
        `env=PricingEnv(...)`. (Definition sites keep the kwargs — they
        are the out-of-tree deprecation shim.)

  LC003 schedule-direct-execution
        `execute_program(...)` whose program argument is produced by
        anything other than `compile()` / `compile_schedule(...)` inline
        — e.g. `execute_program(gen(comm), ...)` or
        `execute_program(Schedule(...), ...)` — i.e. executing a
        Schedule while skipping the compiler (and with it the static
        verifier). Passing an already-compiled variable is fine.

  LC004 side-channel-telemetry
        Direct writes through a `.stats[...]` subscript (the legacy
        ad-hoc dicts — emit through `MetricsRegistry.inc()/.set()`; the
        `.stats` views stay read-compatible) and bare `print(` calls in
        src/ (telemetry goes through `core/telemetry.py`, user output
        through the launch CLIs). Exempt: `core/telemetry.py` itself and
        everything under `launch/` (the CLI surface).

Usage: python scripts/lint_conventions.py PATH [PATH ...]
Exits 1 if any violation is found. Self-tested by tests/test_lint.py.
"""
from __future__ import annotations

import ast
import pathlib
import sys
from typing import List, NamedTuple

LEGACY_NAMES = frozenset({
    "interpret_schedule",
    "ring_reduce_scatter_loop",
    "ring_allgather_loop",
    "ring_allreduce_loop",
    "bidi_ring_allreduce_loop",
    "linear_alltoall_collect",
    "predict_time",
})
LEGACY_KWARGS = frozenset({"wire_scale"})

PRICING_FNS = frozenset({"cost", "cost_terms", "makespan", "price_program"})
BARE_PRICING_KWARGS = frozenset({"tier", "drop_prob"})

EXECUTORS = frozenset({"execute_program"})
COMPILERS = frozenset({"compile", "compile_schedule"})

#: LC004 does not apply to the telemetry module itself or the CLI layer
LC004_EXEMPT_FILES = frozenset({"telemetry.py"})
LC004_EXEMPT_DIRS = frozenset({"launch"})


def _lc004_exempt(path: str) -> bool:
    p = pathlib.PurePath(path)
    return p.name in LC004_EXEMPT_FILES \
        or bool(LC004_EXEMPT_DIRS & set(p.parts))


def _stats_subscript(target: ast.expr) -> bool:
    """True for a `<expr>.stats[...]` subscript target."""
    return (isinstance(target, ast.Subscript)
            and isinstance(target.value, ast.Attribute)
            and target.value.attr == "stats")


class Violation(NamedTuple):
    path: str
    line: int
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


def _callee_name(func: ast.expr):
    """Trailing name of a call target: `f(...)` -> "f", `a.b.f(...)` ->
    "f"; None for anything fancier (subscripts, lambdas, calls)."""
    if isinstance(func, ast.Name):
        return func.id
    if isinstance(func, ast.Attribute):
        return func.attr
    return None


def check_source(text: str, path: str) -> List[Violation]:
    out: List[Violation] = []
    tree = ast.parse(text, filename=path)
    lc004 = not _lc004_exempt(path)
    for node in ast.walk(tree):
        if lc004:
            targets = ()
            if isinstance(node, ast.Assign):
                targets = node.targets
            elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
                targets = (node.target,)
            for t in targets:
                if _stats_subscript(t):
                    out.append(Violation(
                        path, node.lineno, "LC004",
                        "direct write through a `.stats[...]` view — "
                        "emit through MetricsRegistry "
                        "(.inc()/.set(); core/telemetry.py)"))
            if isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Name) \
                    and node.func.id == "print":
                out.append(Violation(
                    path, node.lineno, "LC004",
                    "bare print() in library code — telemetry goes "
                    "through core/telemetry.py (CLI output belongs "
                    "under launch/)"))
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and node.name in LEGACY_NAMES:
            out.append(Violation(
                path, node.lineno, "LC001",
                f"definition of retired entry point {node.name!r}"))
        elif isinstance(node, ast.Name) and node.id in LEGACY_NAMES:
            out.append(Violation(
                path, node.lineno, "LC001",
                f"reference to retired entry point {node.id!r}"))
        elif isinstance(node, ast.Attribute) and node.attr in LEGACY_NAMES:
            out.append(Violation(
                path, node.lineno, "LC001",
                f"reference to retired entry point {node.attr!r}"))
        elif isinstance(node, ast.keyword) and node.arg in LEGACY_KWARGS:
            out.append(Violation(
                path, node.lineno, "LC001",
                f"retired keyword argument {node.arg!r}="))
        if not isinstance(node, ast.Call):
            continue
        name = _callee_name(node.func)
        if name in PRICING_FNS:
            bare = sorted(kw.arg for kw in node.keywords
                          if kw.arg in BARE_PRICING_KWARGS)
            if bare:
                out.append(Violation(
                    path, node.lineno, "LC002",
                    f"call to {name}() with deprecated bare kwarg(s) "
                    f"{bare} — pricing parameters travel in "
                    f"env=PricingEnv(...)"))
        if name in EXECUTORS and node.args:
            first = node.args[0]
            if isinstance(first, ast.Call):
                inner = _callee_name(first.func)
                if inner not in COMPILERS:
                    out.append(Violation(
                        path, node.lineno, "LC003",
                        f"{name}() called on {inner or 'an expression'}"
                        f"(...) — execute compiled programs only "
                        f"(Schedule.compile() / compile_schedule()), "
                        f"never a raw Schedule"))
    return out


def check_paths(paths) -> List[Violation]:
    out: List[Violation] = []
    for p in paths:
        p = pathlib.Path(p)
        files = sorted(p.rglob("*.py")) if p.is_dir() else [p]
        for f in files:
            out.extend(check_source(f.read_text(), str(f)))
    return out


def main(argv) -> int:
    if not argv:
        print(__doc__.strip().splitlines()[0])
        print("usage: lint_conventions.py PATH [PATH ...]")
        return 2
    violations = check_paths(argv)
    for v in violations:
        print(v)
    if violations:
        print(f"{len(violations)} convention violation(s)")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
