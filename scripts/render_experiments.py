"""Splice generated tables into EXPERIMENTS.md at the placeholder markers."""
import json
import os
import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")
from benchmarks.roofline import load_results, fmt_table  # noqa: E402

PEAK = 197e12


def perf_row(path):
    with open(path) as f:
        r = json.load(f)
    if r.get("status") != "OK":
        return None
    t = r["roofline"]
    floor = t["t_memory_floor_s"]
    step = max(t["t_compute_s"], floor, t["t_collective_s"])
    mfu = r["model_flops"] / (r["chips"] * PEAK * step) if step else 0
    return {
        "variant": r.get("variant", "?"),
        "comp": t["t_compute_s"] * 1e3,
        "mem": floor * 1e3,
        "coll": t["t_collective_s"] * 1e3,
        "mfu": mfu,
        "fits": r["fits_hbm"],
        "peak": r["memory"]["peak_bytes_est"] / 1e9,
    }


def variant_table(arch, shape, variants, mesh="single"):
    out = ["variant | t_comp(ms) | t_mem(ms) | t_coll(ms) | MFU | fits | peak(GB)",
           "--- | --- | --- | --- | --- | --- | ---"]
    for v in variants:
        p = f"results/dryrun/{arch}_{shape}_{mesh}_{v}.json"
        if not os.path.exists(p):
            continue
        r = perf_row(p)
        if r is None:
            out.append(f"{v} | FAILED | | | | |")
            continue
        out.append(f"{v} | {r['comp']:.0f} | {r['mem']:.1f} | "
                   f"{r['coll']:.0f} | **{r['mfu']:.3f}** | "
                   f"{'yes' if r['fits'] else 'no'} | {r['peak']:.1f}")
    return "\n".join(out)


def main():
    with open("EXPERIMENTS.md") as f:
        doc = f.read()

    # dry-run + roofline tables
    single = fmt_table(load_results(mesh="single"))
    multi = fmt_table(load_results(mesh="multi"))
    n_ok = {m: sum(1 for r in load_results(mesh=m)
                   if r.get("status") == "OK") for m in ("single", "multi")}
    n_skip = {m: sum(1 for r in load_results(mesh=m)
                     if str(r.get("status", "")).startswith("SKIP"))
              for m in ("single", "multi")}
    dry = (f"Single-pod 16x16: **{n_ok['single']} OK + {n_skip['single']} "
           f"SKIP(full-attn) of 40 cells**; multi-pod 2x16x16: "
           f"**{n_ok['multi']} OK + {n_skip['multi']} SKIP of 40** — zero "
           "failures.\n\n### Single-pod (16x16 = 256 chips)\n\n" + single
           + "\n\n### Multi-pod (2x16x16 = 512 chips)\n\n" + multi)
    doc = doc.replace("<!-- DRYRUN-TABLES -->", dry)
    doc = doc.replace("<!-- ROOFLINE-TABLES -->",
                      "(tables above; per-cell JSONs in results/dryrun/)")

    perf = []
    perf.append("#### Cell 1: qwen3-0.6b x train_4k (worst roofline fraction)\n")
    perf.append(variant_table("qwen3-0.6b", "train_4k",
                              ["base", "native", "sp_dots", "spf",
                               "spf_tp2", "tp1", "spf_tp2_mb2",
                               "spf_tp2_mb2_names"]))
    perf.append("\n#### Cell 2: qwen3-moe-30b-a3b x train_4k (most collective-bound)\n")
    perf.append(variant_table("qwen3-moe-30b-a3b", "train_4k",
                              ["base", "native", "sp", "sp_cap1", "spf",
                               "spf_tp8", "spf_tp8_mb8",
                               "spf_tp8_mb8_names"]))
    perf.append("\n#### Cell 3: internvl2-26b x train_4k (most paper-representative)\n")
    perf.append(variant_table("internvl2-26b", "train_4k",
                              ["base", "native", "sp", "sp_cm", "spf",
                               "spf_tp8", "spf_tp4", "spf_tp8_names",
                               "spf_tp8_mb8", "spf_tp8_mb8_names"]))
    perf.append("\n#### Transfer: the recipe on every other train cell\n")
    for arch, vs in (("mamba2-1.3b", ["spf_tp2"]),
                     ("hymba-1.5b", ["spf_tp4"]),
                     ("smollm-360m", ["spf_tp4"]),
                     ("whisper-medium", ["spf_tp4"]),
                     ("qwen3-14b", ["spf_tp8", "spf_tp8_mb8_names"]),
                     ("stablelm-12b", ["spf_tp8", "spf_tp8_mb8_names"]),
                     ("mixtral-8x7b", ["spf_tp8", "spf_tp8_names",
                                       "spf_tp8_mb8_names"])):
        perf.append(f"**{arch} train_4k**\n")
        perf.append(variant_table(arch, "train_4k", ["base"] + vs))
        perf.append("")
    perf.append("\n#### Transfer: TP-retile on collective-bound prefill cells (tp=8, data=32=batch)\n")
    for arch in ("smollm-360m", "qwen3-0.6b", "mamba2-1.3b", "hymba-1.5b",
                 "whisper-medium"):
        perf.append(f"**{arch} prefill_32k**\n")
        perf.append(variant_table(arch, "prefill_32k", ["base", "tp8"]))
        perf.append("")
    perf.append("\n#### Paper Table 2 DLRM at full scale (100 tables x 4M rows x 32 = 51 GB)\n")
    perf.append(variant_table("dlrm", "serve_b1024", ["base"]))
    perf.append("")
    perf.append(variant_table("dlrm", "serve_b1024", ["base"], mesh="multi"))
    perf.append("(embedding tables shard to 3.2 GB/chip over the model axis — "
                "the paper's single-FPGA-HBM-capacity argument, realized on "
                "the production mesh; the serve step is memory/gather-bound "
                "as the paper observes for embedding-dominated inference.)")
    perf.append("\n#### Decode memory (int8 KV cache, beyond-paper)\n")
    for arch in ("internvl2-26b", "qwen3-14b", "mixtral-8x7b"):
        perf.append(f"**{arch} decode_32k**\n")
        perf.append(variant_table(arch, "decode_32k", ["base", "kv8"]))
        perf.append("")
    perf.append("**hymba-1.5b long_500k**\n")
    perf.append(variant_table("hymba-1.5b", "long_500k", ["base", "kv8"]))
    perf.append("\n#### Multi-pod gradient compression (DCN bytes)\n")
    perf.append(variant_table("internvl2-26b", "train_4k",
                              ["base", "sp", "sp_int8"], mesh="multi"))
    perf.append("")
    perf.append(variant_table("qwen3-moe-30b-a3b", "train_4k",
                              ["base", "sp", "sp_int8"], mesh="multi"))
    doc = doc.replace("<!-- PERF-TABLES -->", "\n".join(perf))

    with open("EXPERIMENTS.md", "w") as f:
        f.write(doc)
    print("rendered EXPERIMENTS.md")


if __name__ == "__main__":
    main()
