#!/usr/bin/env python
"""CI perf gate: fail when the predicted-time model drifts from baseline.

Compares the `segment_sweep`, `queue_sweep`, `fault_sweep` AND
`hier_sweep` records of a fresh benchmark run (the deterministic
`python -m benchmarks.run --quick` output) against the committed
baseline in benchmarks/baseline.json — sweep points gate `predicted_s`,
queue points gate BOTH `makespan_s` (the sequencer's queue-level overlap
model) and `serial_s` (the blocking reference it is measured against),
fault points gate the retransmission-priced `makespan_s` per
(tier, drop_rate), hier points gate BOTH `hier_s` (the two-level
cross-fabric composition) and `flat_s` (the all-DCN flat reference) —
so the modeled hierarchical speedup is pinned from both sides —
and contention points gate BOTH `mesh_s` (the mesh-level shared-fabric
composition from MeshMakespan) and `max_queue_s` (the slowest queue
priced alone), pinning the contention model from both sides too.
The gate is symmetric:

  * every baseline point must still exist (MISSING fails — coverage must
    not silently shrink),
  * every fresh point must exist in the baseline (EXTRA fails — coverage
    must not silently grow past what was reviewed),
  * every shared point's `predicted_s` must be within --tolerance
    (default 10%) of the recorded value, with the relative drift computed
    against max(|baseline|, --epsilon) so a zero/near-zero baseline point
    cannot divide the gate away.

A failure means the cost model changed without the baseline being
refreshed — a silent perf-model regression. On failure the worst
offenders print first; --top N truncates the list to the N largest
absolute drifts (the CI bench job uses --top 10, so baseline-refresh PRs
show the biggest movements at the top of the workflow log).

Refreshing the baseline after an INTENTIONAL model change:

    PYTHONPATH=src python -m benchmarks.run --quick --json /tmp/bench.json
    PYTHONPATH=src python scripts/check_bench.py /tmp/bench.json \
        --write-baseline benchmarks/baseline.json

and commit the result alongside the model change (see benchmarks/README).
"""
from __future__ import annotations

import argparse
import json
import sys


def _key(e: dict) -> tuple:
    return (e["collective"], e["algorithm"], int(e["nranks"]),
            int(e["msg_bytes"]), int(e["segments"]))


def _queue_key(e: dict) -> tuple:
    return (e["collective"], int(e["nranks"]), int(e["msg_bytes"]),
            int(e["requests"]))


def _fault_key(e: dict) -> tuple:
    return (e["collective"], int(e["nranks"]), int(e["msg_bytes"]),
            e["tier"], float(e["drop_rate"]))


def _hier_key(e: dict) -> tuple:
    return (e["collective"], int(e["nranks"]), int(e["pod_size"]),
            int(e["msg_bytes"]))


def _contention_key(e: dict) -> tuple:
    return (e["collective"], int(e["nranks"]), int(e["queues"]),
            e["mode"], int(e["msg_bytes"]))


def _sweep(path: str) -> dict:
    """Every gated point of a results file, one flat dict: segment-sweep
    points keyed ('seg', ...) -> predicted_s, queue-sweep points keyed
    ('queue', ..., metric) with one entry per gated metric."""
    with open(path) as f:
        data = json.load(f)
    sweep = data.get("segment_sweep", [])
    if not sweep:
        raise SystemExit(f"{path}: no segment_sweep records — "
                         f"was the run aborted?")
    pts = {("seg",) + _key(e): float(e["predicted_s"]) for e in sweep}
    for e in data.get("queue_sweep", []):
        base = ("queue",) + _queue_key(e)
        pts[base + ("makespan_s",)] = float(e["makespan_s"])
        pts[base + ("serial_s",)] = float(e["serial_s"])
    for e in data.get("fault_sweep", []):
        pts[("fault",) + _fault_key(e)] = float(e["makespan_s"])
    for e in data.get("hier_sweep", []):
        base = ("hier",) + _hier_key(e)
        pts[base + ("hier_s",)] = float(e["hier_s"])
        pts[base + ("flat_s",)] = float(e["flat_s"])
    for e in data.get("contention_sweep", []):
        base = ("contention",) + _contention_key(e)
        pts[base + ("mesh_s",)] = float(e["mesh_s"])
        pts[base + ("max_queue_s",)] = float(e["max_queue_s"])
    return pts


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python scripts/check_bench.py", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("results", nargs="?", default="BENCH_collectives.json",
                    help="fresh benchmark JSON (default: "
                         "BENCH_collectives.json)")
    ap.add_argument("--baseline", default="benchmarks/baseline.json",
                    help="committed baseline (default: "
                         "benchmarks/baseline.json)")
    ap.add_argument("--tolerance", type=float, default=0.10,
                    help="max relative predicted_s drift (default 0.10)")
    ap.add_argument("--epsilon", type=float, default=1e-12,
                    help="absolute floor (seconds) for the drift "
                         "denominator, so zero/near-zero baseline points "
                         "still gate (default 1e-12)")
    ap.add_argument("--top", type=int, default=None, metavar="N",
                    help="on failure, print only the N worst-drifting "
                         "sweep points (default: all)")
    ap.add_argument("--write-baseline", metavar="PATH", default=None,
                    help="write the results' sweep as a new baseline "
                         "instead of checking")
    args = ap.parse_args(argv)

    new = _sweep(args.results)
    if args.write_baseline:
        with open(args.results) as f:
            data = json.load(f)
        out = {"meta": data.get("meta", {}),
               "segment_sweep": data["segment_sweep"],
               "queue_sweep": data.get("queue_sweep", []),
               "fault_sweep": data.get("fault_sweep", []),
               "hier_sweep": data.get("hier_sweep", []),
               "contention_sweep": data.get("contention_sweep", [])}
        with open(args.write_baseline, "w") as f:
            json.dump(out, f, indent=1)
        print(f"wrote {args.write_baseline}: {len(new)} sweep points")
        return 0

    base = _sweep(args.baseline)
    missing = sorted(set(base) - set(new))
    extra = sorted(set(new) - set(base))
    fails = []
    for key, b in sorted(base.items()):
        n = new.get(key)
        if n is None:
            continue
        drift = (n - b) / max(abs(b), args.epsilon)
        if abs(drift) > args.tolerance:
            fails.append((key, b, n, drift))
    fails.sort(key=lambda f: abs(f[3]), reverse=True)

    print(f"check_bench: {len(base)} baseline points, "
          f"{len(new)} fresh points, tolerance {args.tolerance:.0%}")
    for key in missing:
        print(f"  MISSING  {key} — baseline point not produced by the run")
    for key in extra:
        print(f"  EXTRA    {key} — new sweep point absent from the "
              f"baseline")
    shown = fails if args.top is None else fails[:max(args.top, 0)]
    for key, b, n, drift in shown:
        print(f"  DRIFT    {key}: {b:.3e}s -> {n:.3e}s ({drift:+.1%})")
    if len(shown) < len(fails):
        print(f"  ... and {len(fails) - len(shown)} more drifted points "
              f"(re-run without --top for the full list)")
    if missing or extra or fails:
        print(f"FAIL: {len(missing)} missing, {len(extra)} extra, "
              f"{len(fails)} drifted — refresh benchmarks/baseline.json "
              f"if the model change is intentional (see --write-baseline)")
        return 1
    print("OK: predicted-time model matches the committed baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
