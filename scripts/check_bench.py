#!/usr/bin/env python
"""CI perf gate: fail when the predicted-time model drifts from baseline.

Compares the `segment_sweep` records of a fresh benchmark run (the
deterministic `python -m benchmarks.run --quick` output) against the
committed baseline in benchmarks/baseline.json. Every (collective,
algorithm, nranks, msg_bytes, segments) point present in the baseline must
still exist and its `predicted_s` must be within --tolerance (default 10%)
of the recorded value — a larger drift means the cost model changed
without the baseline being refreshed, i.e. a silent perf-model regression.

Refreshing the baseline after an INTENTIONAL model change:

    PYTHONPATH=src python -m benchmarks.run --quick --json /tmp/bench.json
    PYTHONPATH=src python scripts/check_bench.py /tmp/bench.json \
        --write-baseline benchmarks/baseline.json

and commit the result alongside the model change (see benchmarks/README).
"""
from __future__ import annotations

import argparse
import json
import sys


def _key(e: dict) -> tuple:
    return (e["collective"], e["algorithm"], int(e["nranks"]),
            int(e["msg_bytes"]), int(e["segments"]))


def _sweep(path: str) -> dict:
    with open(path) as f:
        data = json.load(f)
    sweep = data.get("segment_sweep", [])
    if not sweep:
        raise SystemExit(f"{path}: no segment_sweep records — "
                         f"was the run aborted?")
    return {_key(e): float(e["predicted_s"]) for e in sweep}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python scripts/check_bench.py", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("results", nargs="?", default="BENCH_collectives.json",
                    help="fresh benchmark JSON (default: "
                         "BENCH_collectives.json)")
    ap.add_argument("--baseline", default="benchmarks/baseline.json",
                    help="committed baseline (default: "
                         "benchmarks/baseline.json)")
    ap.add_argument("--tolerance", type=float, default=0.10,
                    help="max relative predicted_s drift (default 0.10)")
    ap.add_argument("--write-baseline", metavar="PATH", default=None,
                    help="write the results' sweep as a new baseline "
                         "instead of checking")
    args = ap.parse_args(argv)

    new = _sweep(args.results)
    if args.write_baseline:
        with open(args.results) as f:
            data = json.load(f)
        out = {"meta": data.get("meta", {}),
               "segment_sweep": data["segment_sweep"]}
        with open(args.write_baseline, "w") as f:
            json.dump(out, f, indent=1)
        print(f"wrote {args.write_baseline}: {len(new)} sweep points")
        return 0

    base = _sweep(args.baseline)
    missing = sorted(set(base) - set(new))
    fails = []
    for key, b in sorted(base.items()):
        n = new.get(key)
        if n is None:
            continue
        drift = (n - b) / b
        if abs(drift) > args.tolerance:
            fails.append((key, b, n, drift))

    print(f"check_bench: {len(base)} baseline points, "
          f"{len(new)} fresh points, tolerance {args.tolerance:.0%}")
    for key in missing:
        print(f"  MISSING  {key} — baseline point not produced by the run")
    for key, b, n, drift in fails:
        print(f"  DRIFT    {key}: {b:.3e}s -> {n:.3e}s ({drift:+.1%})")
    if missing or fails:
        print(f"FAIL: {len(missing)} missing, {len(fails)} drifted — "
              f"refresh benchmarks/baseline.json if the model change is "
              f"intentional (see --write-baseline)")
        return 1
    print("OK: predicted-time model matches the committed baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
