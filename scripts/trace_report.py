#!/usr/bin/env python
"""Summarize a Chrome trace-event JSON emitted by `core/telemetry.py`.

Usage:
    python scripts/trace_report.py TRACE.json [--top N] [--json]

Reads the `{"traceEvents": [...]}` file a `Tracer.to_chrome_trace()`
produced (e.g. `python -m benchmarks.run --quick --trace TRACE.json`, or
any `simulate_drain` / `MeshMakespan.timeline()` run under
`telemetry.use(...)`) and prints:

  * **per-link utilization** — busy seconds per physical-link track on
    the virtual clock, as a fraction of the trace's virtual end;
  * **per-request wait/wire/stall split** — each drained request's
    queue-wait, dependency-stall, wire, and latency seconds;
  * **top-N serialization offenders** — the requests that spent longest
    blocked behind unrelated queue items (the queue-wait column, which
    is exactly the time a priority scheduler could reclaim);
  * **control-plane summary** — span/instant counts per name (selector
    choices, compiles + cache hits, retries).

`--json` emits the same summary as one JSON object (CI smoke uses it).
Stdlib-only; no repro import needed to read a trace.
"""
from __future__ import annotations

import argparse
import json
import sys

#: telemetry.py's pid assignment (see CONTROL_PID / VIRTUAL_PID there)
CONTROL_PID = 1
VIRTUAL_PID = 2
US = 1e6   # virtual-clock events are exported as priced-seconds * 1e6


def load_events(path: str) -> list:
    with open(path) as fh:
        doc = json.load(fh)
    events = doc["traceEvents"] if isinstance(doc, dict) else doc
    if not isinstance(events, list):
        raise SystemExit(f"{path}: not a Chrome trace-event file")
    return events


def track_names(events: list) -> dict:
    """(pid, tid) -> track name, from the "M" thread_name metadata."""
    names = {}
    for ev in events:
        if ev.get("ph") == "M" and ev.get("name") == "thread_name":
            names[(ev["pid"], ev["tid"])] = ev["args"]["name"]
    return names


def summarize(events: list, top: int = 10) -> dict:
    names = track_names(events)
    end_us = 0.0
    links: dict = {}      # track -> busy_us
    requests: list = []
    control: dict = {}    # "span:<name>" / "instant:<name>" -> count
    for ev in events:
        ph = ev.get("ph")
        if ph == "M":
            continue
        pid = ev.get("pid")
        track = names.get((pid, ev.get("tid")), "?")
        if pid == VIRTUAL_PID:
            if ph == "X":
                t1 = float(ev["ts"]) + float(ev.get("dur", 0.0))
                end_us = max(end_us, t1)
                if track.startswith("link:"):
                    links[track] = links.get(track, 0.0) \
                        + float(ev.get("dur", 0.0))
                elif ev.get("name") == "request":
                    a = ev.get("args", {})
                    requests.append({
                        "rids": a.get("rids", []),
                        "track": track,
                        "start_s": float(ev["ts"]) / US,
                        "end_s": t1 / US,
                        "queue_wait_s": a.get("queue_wait_s"),
                        "dep_stall_s": a.get("dep_stall_s"),
                        "wire_s": a.get("wire_s"),
                        "lat_s": a.get("lat_s"),
                        "retries": a.get("retries"),
                        "backoff_s": a.get("backoff_s"),
                        "status": a.get("status"),
                    })
        elif pid == CONTROL_PID:
            kind = {"X": "span", "i": "instant", "C": "counter"}.get(ph)
            if kind is not None:
                key = f"{kind}:{ev.get('name')}"
                control[key] = control.get(key, 0) + 1
    end_s = end_us / US
    link_util = {
        t: {"busy_s": busy / US,
            "utilization": (busy / end_us) if end_us > 0 else 0.0}
        for t, busy in sorted(links.items())
    }
    offenders = sorted(
        (r for r in requests if r.get("queue_wait_s") is not None),
        key=lambda r: r["queue_wait_s"], reverse=True)[:top]
    return {
        "virtual_end_s": end_s,
        "links": link_util,
        "requests": requests,
        "offenders": offenders,
        "control": dict(sorted(control.items())),
    }


def _fmt_s(v) -> str:
    return "-" if v is None else f"{v:.3e}"


def print_report(rep: dict, stream=sys.stdout) -> None:
    w = stream.write
    w(f"virtual clock end: {rep['virtual_end_s']:.6e} s\n\n")
    if rep["links"]:
        w("per-link utilization (virtual clock):\n")
        for track, d in rep["links"].items():
            w(f"  {track:<28} busy {d['busy_s']:.3e} s"
              f"  util {d['utilization']:6.1%}\n")
        w("\n")
    if rep["requests"]:
        w("per-request split (queue-wait / dep-stall / wire / alpha):\n")
        for r in rep["requests"]:
            rids = "+".join(str(i) for i in r["rids"]) or "?"
            w(f"  rid {rids:<8} {r['track']:<16}"
              f" wait {_fmt_s(r['queue_wait_s'])}"
              f" stall {_fmt_s(r['dep_stall_s'])}"
              f" wire {_fmt_s(r['wire_s'])}"
              f" alpha {_fmt_s(r['lat_s'])}"
              f"  {r['status'] or ''}\n")
        w("\n")
    if rep["offenders"]:
        w("top serialization offenders (by queue-wait):\n")
        for r in rep["offenders"]:
            rids = "+".join(str(i) for i in r["rids"]) or "?"
            w(f"  rid {rids:<8} {r['track']:<16}"
              f" waited {_fmt_s(r['queue_wait_s'])} s\n")
        w("\n")
    if rep["control"]:
        w("control plane:\n")
        for key, n in rep["control"].items():
            w(f"  {key:<40} x{n}\n")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Summarize a telemetry Chrome trace")
    ap.add_argument("trace", help="trace JSON (Tracer.to_chrome_trace())")
    ap.add_argument("--top", type=int, default=10,
                    help="serialization offenders to list (default 10)")
    ap.add_argument("--json", action="store_true",
                    help="emit the summary as JSON instead of text")
    args = ap.parse_args(argv)
    rep = summarize(load_events(args.trace), top=args.top)
    if args.json:
        json.dump(rep, sys.stdout, indent=2)
        sys.stdout.write("\n")
    else:
        print_report(rep)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
