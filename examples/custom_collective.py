"""A new collective WITHOUT touching the engine — ACCL+'s core promise.

In ACCL+ (§4.2) collectives are software-defined microprograms over a
fixed set of DMA/packetizer primitives, so a new collective is new uC
firmware — no circuit re-synthesis. This repo reproduces that contract:
a collective is a `Schedule` (pure data + rank closures); the engine
compiles it to the micro-op IR and executes it through the same
`execute_program` data plane as every built-in.

This example registers `scatter` — MPI_Scatter, which the built-in table
does not provide — entirely out of tree, with two algorithms:

  linear         root sends chunk j straight to rank j (n-1 steps)
  binomial_tree  recursive halving of the root's range (log2 n steps)

and shows the full stack working on it: selector pricing + auto choice,
numpy-simulator validation against an oracle, and segmented execution.

  python examples/custom_collective.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import (
    CollectiveEngine, Communicator, Schedule, Sel, Step,
    register_collective, simulator,
)
from repro.core.topology import make_mesh


# --------------------------------------------------------------------------
# The "firmware": two scatter schedules, written like any in-tree generator
# --------------------------------------------------------------------------

def linear_scatter(comm: Communicator, root: int = 0) -> Schedule:
    """Root sends chunk j of its buffer straight to rank j (n-1 steps).

    relay='original': every step wires the root's untouched input. Each
    non-root rank receives exactly once (mask_recv keeps the others')."""
    n = comm.size
    steps = tuple(
        Step(perm=((root, (root + i + 1) % n),), op="copy",
             send_sel=Sel.chunk(lambda r, s, i=i: (root + i + 1) % n),
             recv_sel=Sel.chunk(lambda r, s, i=i: (root + i + 1) % n),
             bytes_frac=1.0 / n, mask_recv=True)
        for i in range(n - 1)
    )
    return Schedule(
        name="linear", collective="scatter", nranks=n, steps=steps,
        chunks=n, result="shard", owned_chunk=lambda r: r,
        relay="original",
    )


def binomial_tree_scatter(comm: Communicator, root: int = 0) -> Schedule:
    """Each round halves the chunk range a holder forwards: log2(n) steps,
    moving (n/2 + n/4 + ...) chunks total — the rendezvous variant."""
    n = comm.size
    k = comm.log2_size
    if (1 << k) != n:
        raise ValueError("binomial_tree_scatter needs power-of-two ranks")
    steps = []
    for j in range(k):
        half = n >> (j + 1)  # chunks forwarded per pair this round
        pairs = tuple(
            ((root + m * 2 * half) % n, (root + m * 2 * half + half) % n)
            for m in range(1 << j)
        )

        def rng(r, s, half=half, root=root, n=n):
            # both ends of a pair name the receiver's range (rel | half)
            rel = (r - root) % n
            return ((rel | half), half)

        steps.append(Step(
            perm=pairs, op="copy",
            send_sel=Sel.range(rng), recv_sel=Sel.range(rng),
            bytes_frac=half / n, mask_recv=True,
        ))
    return Schedule(
        name="binomial_tree", collective="scatter", nranks=n,
        steps=tuple(steps), chunks=n, result="shard",
        owned_chunk=lambda r: r, relay="buffer",
    )


def main():
    # -- register: this is ALL it takes to deploy a new collective ----------
    register_collective("scatter", linear_scatter, algorithm="linear",
                        protocols=("eager", "rendezvous"))
    register_collective("scatter", binomial_tree_scatter,
                        algorithm="binomial_tree",
                        protocols=("rendezvous",))

    # -- validate the microprogram in the numpy simulator first -------------
    n = 8
    comm = Communicator(axis="x", size=n)
    rng = np.random.default_rng(0)
    full = rng.normal(size=(n * 4,)).astype(np.float32)
    inputs = [full.copy() if r == 0 else np.zeros_like(full)
              for r in range(n)]
    for gen in (linear_scatter, binomial_tree_scatter):
        outs = simulator.simulate(gen(comm), inputs)
        for r in range(n):
            np.testing.assert_allclose(outs[r][r * 4:(r + 1) * 4],
                                       full[r * 4:(r + 1) * 4])
        print(f"simulator: {gen.__name__} == oracle on {n} ranks")

    # -- the selector prices it next to nothing else ------------------------
    eng = CollectiveEngine(make_mesh((n,), ("x",)), backend="microcode")
    for size in (1 << 10, 1 << 22):
        c = eng.selector.choose("scatter", size, comm)
        print(f"selector: scatter {size >> 10:5d}KB -> "
              f"{c.algorithm:14s}/{c.protocol:10s} "
              f"segments={c.segments} "
              f"predicted {c.predicted_s * 1e6:7.1f}us")

    # -- and the engine runs it through the same execute_program path -------
    def program(shard):
        # every rank contributes its shard; only root's buffer matters
        return eng.collective("scatter", shard, "x", algorithm="auto")

    g = eng.run(program, in_specs=P("x"), out_specs=P("x"))
    data = rng.normal(size=(n, 16)).astype(np.float32)
    out = np.asarray(g(jax.numpy.asarray(data)))
    # rank r's returned shard is chunk r of rank-0's (the root's) input
    csize = data[0].size // n
    for r in range(n):
        np.testing.assert_allclose(
            out[r * (16 // n):(r + 1) * (16 // n)].reshape(-1)[:csize],
            data[0].reshape(-1)[r * csize:(r + 1) * csize], atol=1e-6)
    print("engine:   scatter(auto) through execute_program matches root's "
          "chunks")

    # segmented execution works on it too — no extra code
    out_seg = np.asarray(eng.run(
        lambda s: eng.collective("scatter", s, "x", algorithm="linear",
                                 segments=4),
        in_specs=P("x"), out_specs=P("x"))(jax.numpy.asarray(data)))
    base = np.asarray(eng.run(
        lambda s: eng.collective("scatter", s, "x", algorithm="linear",
                                 segments=1),
        in_specs=P("x"), out_specs=P("x"))(jax.numpy.asarray(data)))
    np.testing.assert_array_equal(out_seg, base)
    print("engine:   segmented scatter bitwise-equal to unsegmented")


if __name__ == "__main__":
    main()
