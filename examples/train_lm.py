"""End-to-end training driver: a ~100M-param LM for a few hundred steps.

Uses the full production stack — data pipeline, AdamW, checkpointing,
fault-tolerant trainer, collective engine for every collective — on the
8-virtual-device simulation mesh. The config is smollm-360m narrowed to
~100M params (depth/width cut, real vocab).

  python examples/train_lm.py --steps 300
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse  # noqa: E402
import dataclasses  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.configs.base import ParallelConfig  # noqa: E402
from repro.core.topology import make_mesh  # noqa: E402
from repro.data import DataConfig  # noqa: E402
from repro.optim import adamw  # noqa: E402
from repro.optim.schedules import cosine_warmup  # noqa: E402
from repro.runtime import Trainer, TrainerConfig  # noqa: E402


def lm_100m():
    base = get_config("smollm-360m")
    return dataclasses.replace(
        base, n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
        head_dim=64, d_ff=2048, vocab_size=49152,
        param_dtype="float32", compute_dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    ap.add_argument("--backend", default="microcode",
                    choices=("microcode", "native"))
    ap.add_argument("--compress", default="", choices=("", "int8", "bf16"))
    args = ap.parse_args()

    cfg = lm_100m()
    print(f"params: {cfg.n_params()/1e6:.1f}M")
    mesh = make_mesh((1, 4, 2), ("pod", "data", "model"))
    pcfg = ParallelConfig(backend=args.backend, remat="none",
                          grad_compression=args.compress or None)
    trainer = Trainer(
        cfg, pcfg, mesh,
        adamw.AdamWConfig(lr=3e-4, weight_decay=0.01),
        DataConfig(global_batch=args.batch, seq_len=args.seq, seed=0),
        TrainerConfig(total_steps=args.steps, ckpt_dir=args.ckpt,
                      ckpt_every=100, log_every=20),
        lr_schedule=lambda s: cosine_warmup(s, 50, args.steps))
    log = trainer.run()
    for rec in log:
        if "step" in rec and rec["step"] % 20 == 0:
            print(f"step {rec['step']:4d}  ce {rec['ce_mean']:.4f}  "
                  f"gnorm {rec['grad_norm']:.3f}  {rec['dt']*1e3:.0f} ms")
    final = [r for r in log if "step" in r][-1]
    print(f"final: step {final['step']} ce {final['ce_mean']:.4f}")
    assert final["ce_mean"] < log[0]["ce_mean"], "training did not improve"


if __name__ == "__main__":
    main()
