"""Paper use case 1 (Fig. 16): distributed vector-matrix multiply with the
weight matrix column-partitioned across ranks and the partial products
combined by an engine `reduce` — the collective-offload-engine role.

  python examples/distributed_vecmat.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.core import CollectiveEngine  # noqa: E402
from repro.core.topology import make_mesh  # noqa: E402


def main():
    mesh = make_mesh((8,), ("x",))
    engine = CollectiveEngine(mesh, backend="microcode")
    rng = np.random.default_rng(0)

    from repro.core import Communicator
    from repro.core import algorithms as A
    from repro.core.hw_spec import ACCL_CLUSTER
    # NOTE: the 8 "devices" share one physical core here, so measured
    # speedup cannot exceed 1; the model column is the paper-cluster
    # prediction (compute / 8 + binomial-tree reduce).
    print("size,single_us,dist_us,measured_x,model_8rank_x")
    for size in (512, 1024, 2048, 4096):
        w = jnp.asarray(rng.normal(size=(size, size)), jnp.float32)
        x = jnp.asarray(rng.normal(size=(size,)), jnp.float32)

        single = jax.jit(lambda a, b: a @ b)
        single(x, w).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(20):
            y_ref = single(x, w)
        y_ref.block_until_ready()
        us_single = (time.perf_counter() - t0) / 20 * 1e6

        # rank r holds rows chunk r of W and the matching slice of x
        def dist(xs, ws):
            partial = xs @ ws           # (size,) partial product
            return engine.reduce(partial, "x", algorithm="binomial_tree")

        g = jax.jit(jax.shard_map(dist, mesh=mesh,
                                  in_specs=(P("x"), P("x", None)),
                                  out_specs=P(), check_vma=False))
        y = g(x, w)
        jax.block_until_ready(y)
        t0 = time.perf_counter()
        for _ in range(20):
            y = g(x, w)
        jax.block_until_ready(y)
        us_dist = (time.perf_counter() - t0) / 20 * 1e6

        err = float(jnp.abs(y - y_ref).max())
        assert err < 1e-2, err
        t_single = 2 * size * size / 50e9
        accl_comm = Communicator(axis="x", size=8, hw=ACCL_CLUSTER)
        sched = A.binomial_tree_reduce(accl_comm)
        # program-level pricing: cost the compiled micro-op program, the
        # same artifact the engine executes (PR 3)
        t_red = sched.compile().cost(size * 4, accl_comm)
        model = t_single / (t_single / 8 + t_red)
        print(f"{size},{us_single:.1f},{us_dist:.1f},"
              f"{us_single/us_dist:.2f},{model:.2f}")


if __name__ == "__main__":
    main()
