"""Paper use case 1 (Fig. 16) as the OFFLOAD demo: distributed
vector-matrix multiply with the weight matrix row-partitioned across
ranks and the partial products combined by engine `reduce` requests —
issued NON-BLOCKING into the CCLO-style request queue.

The offload pattern (the paper's second headline role): the caller tiles
the output, computes tile t+1 on the MXU while tile t's partial
reduction drains from the queue, and only materializes results at the
end. `Sequencer.makespan` prices the drained queue — independent tile
reductions overlap their per-hop latency on the shared link — against
the serial sum of blocking `Program.cost`s.

  python examples/distributed_vecmat.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.core import CollectiveEngine, Communicator  # noqa: E402
from repro.core.hw_spec import ACCL_CLUSTER  # noqa: E402
from repro.core.topology import make_mesh  # noqa: E402

TILES = 4  # output tiles in flight: tile t+1 computes while t drains


def main():
    mesh = make_mesh((8,), ("x",))
    engine = CollectiveEngine(mesh, backend="microcode")
    rng = np.random.default_rng(0)

    # NOTE: the 8 "devices" share one physical core here, so measured
    # speedup cannot exceed 1; the model columns are the paper-cluster
    # prediction (compute / 8 + the reduction: serial-blocking vs the
    # queue's makespan).
    print("size,single_us,dist_us,measured_x,model_blocking_x,"
          "model_offload_x,overlap_x")
    for size in (512, 1024, 2048, 4096):
        w = jnp.asarray(rng.normal(size=(size, size)), jnp.float32)
        x = jnp.asarray(rng.normal(size=(size,)), jnp.float32)

        single = jax.jit(lambda a, b: a @ b)
        single(x, w).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(20):
            y_ref = single(x, w)
        y_ref.block_until_ready()
        us_single = (time.perf_counter() - t0) / 20 * 1e6

        # rank r holds rows chunk r of W and the matching slice of x.
        # Each output tile's partial product is ISSUED as a non-blocking
        # reduce; the next tile's matmul runs while it drains.
        tile = size // TILES

        def dist(xs, ws):
            reqs = []
            for t in range(TILES):
                partial = xs @ ws[:, t * tile:(t + 1) * tile]
                reqs.append(engine.ireduce(partial, "x",
                                           algorithm="binomial_tree"))
            # materialize: FIFO drain of the outstanding tile reductions
            return jnp.concatenate([r.wait() for r in reqs])

        g = jax.jit(jax.shard_map(dist, mesh=mesh,
                                  in_specs=(P("x"), P("x", None)),
                                  out_specs=P(), check_vma=False))
        y = g(x, w)
        jax.block_until_ready(y)
        t0 = time.perf_counter()
        for _ in range(20):
            y = g(x, w)
        jax.block_until_ready(y)
        us_dist = (time.perf_counter() - t0) / 20 * 1e6

        err = float(jnp.abs(y - y_ref).max())
        assert err < 1e-2, err

        # queue-level model on the paper cluster: price the SAME request
        # pattern (one binomial-tree reduce per tile) via the sequencer,
        # without executing anything
        accl_comm = Communicator(axis="x", size=8, hw=ACCL_CLUSTER)
        seq = engine.queue
        for t in range(TILES):
            seq.issue("reduce", np.zeros((tile,), np.float32), "x",
                      algorithm="binomial_tree")
        t_queue = seq.makespan("x", comm=accl_comm)
        t_serial = seq.serial_cost("x", comm=accl_comm)
        seq.clear()  # model-only queue: drop without executing

        t_single = 2 * size * size / 50e9
        model_blocking = t_single / (t_single / 8 + t_serial)
        model_offload = t_single / (t_single / 8 + t_queue)
        print(f"{size},{us_single:.1f},{us_dist:.1f},"
              f"{us_single/us_dist:.2f},{model_blocking:.2f},"
              f"{model_offload:.2f},{t_serial/t_queue:.2f}")
        assert t_queue < t_serial, (
            "independent tile reductions must overlap in the makespan")


if __name__ == "__main__":
    main()
