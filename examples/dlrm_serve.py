"""Paper use case 2 (Fig. 17): distributed DLRM inference serving.

Embedding tables shard over the model axis (the HBM-capacity argument),
FC1 is checkerboard-decomposed, partial embedding vectors and FC1 partial
products travel through the collective engine. Serves batched requests and
reports latency/throughput vs the single-device baseline.

  python examples/dlrm_serve.py --batches 20
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.configs.base import ParallelConfig  # noqa: E402
from repro.configs.dlrm import DLRMConfig  # noqa: E402
from repro.core import CollectiveEngine  # noqa: E402
from repro.core.topology import make_mesh  # noqa: E402
from repro.models import dlrm as dlrm_mod  # noqa: E402
from repro.models.common import Builder  # noqa: E402
from repro.parallel.ops import ParCtx  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batches", type=int, default=20)
    ap.add_argument("--batch-size", type=int, default=32)
    ap.add_argument("--tables", type=int, default=32)
    ap.add_argument("--rows", type=int, default=50_000)
    args = ap.parse_args()

    cfg = DLRMConfig(n_tables=args.tables, emb_dim=32,
                     rows_per_table=args.rows, fc_dims=(2048, 512, 256))
    mesh = make_mesh((1, 1, 8), ("pod", "data", "model"))
    engine = CollectiveEngine(mesh, backend="microcode")
    ctx = ParCtx(engine=engine, pcfg=ParallelConfig(), mesh=mesh)

    b = Builder("init", key=jax.random.PRNGKey(0), dtype=jnp.float32)
    params = dlrm_mod.dlrm_params(b, cfg, 8)
    specs = dlrm_mod.dlrm_specs(cfg, 8)
    emb_gb = args.tables * args.rows * 32 * 4 / 2**30
    print(f"tables: {args.tables} x {args.rows} rows "
          f"({emb_gb:.2f} GiB embeddings, sharded 8-way)")

    serve = jax.jit(jax.shard_map(
        lambda p, i: dlrm_mod.dlrm_forward(p, i, ctx),
        mesh=mesh, in_specs=(specs, P(None, None)),
        out_specs=P(None, None), check_vma=False))
    ref = jax.jit(dlrm_mod.dlrm_reference)

    rng = np.random.default_rng(0)
    reqs = [jnp.asarray(rng.integers(0, args.rows,
                                     (args.batch_size, args.tables)),
                        jnp.int32) for _ in range(args.batches)]
    # warmup + correctness
    out = serve(params, reqs[0])
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref(params, reqs[0])),
                               atol=1e-2, rtol=1e-2)

    for name, fn in (("distributed", lambda r: serve(params, r)),
                     ("single_node", lambda r: ref(params, r))):
        fn(reqs[0]).block_until_ready()
        t0 = time.perf_counter()
        for r in reqs:
            out = fn(r)
        out.block_until_ready()
        dt = time.perf_counter() - t0
        lat = dt / args.batches * 1e3
        tput = args.batches * args.batch_size / dt
        print(f"{name:12s} latency {lat:7.2f} ms/batch   "
              f"throughput {tput:9.0f} q/s")


if __name__ == "__main__":
    main()
