"""Quickstart: the collective engine's two APIs on a simulated cluster.

Runs on 8 virtual CPU devices — the ACCL+ simulation-platform analogue.

  python examples/quickstart.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import CollectiveEngine, Communicator, Selector
from repro.core.topology import make_mesh


def main():
    mesh = make_mesh((8,), ("x",))
    engine = CollectiveEngine(mesh, backend="microcode")

    # ---- MPI-like API (paper Listing 1): buffers in, buffers out ----------
    x = jnp.asarray(np.random.default_rng(0).normal(size=(8 * 1024,)),
                    jnp.float32)

    def program(shard):
        total = engine.allreduce(shard, "x", algorithm="ring")
        biggest = engine.allreduce(shard, "x", op="max",
                                   algorithm="recursive_doubling")
        root_view = engine.gather(shard, "x", root=0,
                                  algorithm="binomial_tree")
        return total[:4], biggest[:4], root_view[:4]

    g = engine.run(program, in_specs=P("x"), out_specs=P(None))
    total, biggest, root_view = g(x)
    print("allreduce[:4]      ", np.asarray(total))
    print("max-reduce[:4]     ", np.asarray(biggest))
    print("gather@root[:4]    ", np.asarray(root_view))

    # ---- Streaming API (paper Listing 2): compute fused with comm ---------
    rows = jnp.asarray(np.random.default_rng(1).normal(size=(8 * 32, 16)),
                       jnp.float32)          # row-sharded activations
    w = jnp.asarray(np.random.default_rng(2).normal(size=(16, 64)),
                    jnp.float32)

    def streaming(shard, w):
        # each ring step multiplies a shard while the next is on the wire
        return engine.allgather_matmul(shard, w, "x")

    g2 = engine.run(streaming, in_specs=(P("x"), P()), out_specs=P(None))
    y = g2(rows, w)
    np.testing.assert_allclose(np.asarray(y), np.asarray(rows) @ w,
                               atol=1e-4)
    print("streaming collective matmul:", y.shape, "(matches rows @ w)")

    # ---- Runtime algorithm selection (the paper's firmware tuning) --------
    sel = Selector()
    comm = Communicator(axis="x", size=8)
    for size in (1 << 10, 1 << 17, 1 << 24):
        c = sel.choose("allreduce", size, comm)
        print(f"selector: allreduce {size >> 10:6d}KB -> "
              f"{c.algorithm:18s}/{c.protocol:10s} "
              f"predicted {c.predicted_s * 1e6:8.1f}us on TPU ICI")
    # pin an algorithm at runtime, no code/recompile of the model needed
    sel.set_tuning("allreduce", "bidi_ring", lo_bytes=1 << 20)
    c = sel.choose("allreduce", 1 << 24, comm)
    print("after set_tuning:", c.algorithm)


if __name__ == "__main__":
    main()
