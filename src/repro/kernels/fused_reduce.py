"""Binary streaming plugin kernel: fused combine(+cast) in one VMEM pass.

ACCL+'s arithmetic plugin sits in the collective datapath and combines the
arriving network stream with the local operand at line rate. The TPU
analogue: when a ring-step chunk lands in HBM, the combine (add/max/...)
plus any dtype cast should be one fused VMEM-resident pass — two HBM reads,
one HBM write, no intermediate materialization.

Target: TPU VPU (8x128 lanes). Tiles are (block_rows, 128)-aligned; the
last axis must be a multiple of 128 (ops.py pads).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VPU-native tile: 8 sublanes x 128 lanes; block_rows rows of 128 lanes.
DEFAULT_BLOCK_ROWS = 256
LANES = 128

_COMBINE = {
    "add": lambda a, b: a + b,
    "max": jnp.maximum,
    "min": jnp.minimum,
    "mul": jnp.multiply,
}


def _kernel(x_ref, y_ref, o_ref, *, op: str, acc_dtype):
    x = x_ref[...].astype(acc_dtype)
    y = y_ref[...].astype(acc_dtype)
    o_ref[...] = _COMBINE[op](x, y).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("op", "out_dtype", "block_rows",
                                             "interpret"))
def fused_combine(x, y, *, op: str = "add", out_dtype=None,
                  block_rows: int = DEFAULT_BLOCK_ROWS,
                  interpret: bool = True):
    """Elementwise combine of two (rows, 128k)-shaped arrays.

    Accumulates in fp32 regardless of input dtype (the plugin's cast), then
    casts to `out_dtype` (default: x.dtype) on the way out.
    """
    assert x.shape == y.shape and x.ndim == 2, (x.shape, y.shape)
    rows, cols = x.shape
    assert cols % LANES == 0, f"cols {cols} must be 128-aligned (ops.py pads)"
    assert rows % block_rows == 0, f"rows {rows} % {block_rows}"
    out_dtype = out_dtype or x.dtype
    grid = (rows // block_rows,)
    spec = pl.BlockSpec((block_rows, cols), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_kernel, op=op, acc_dtype=jnp.float32),
        out_shape=jax.ShapeDtypeStruct((rows, cols), out_dtype),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        interpret=interpret,
    )(x, y)
