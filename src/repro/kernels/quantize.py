"""Unary streaming plugin kernel: per-block int8 quantize / dequantize.

ACCL+'s unary plugins compress/encrypt in-flight data. Ours is the
compressed-gradient codec: symmetric per-block int8 with one fp32 scale per
QUANT_BLOCK elements (4x wire-byte reduction for fp32 gradients, matching
core/plugins.py wire format).

Layout: flat input reshaped to (n_blocks, QUANT_BLOCK); each Pallas grid
step quantizes BLOCK_ROWS blocks resident in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

QUANT_BLOCK = 256   # elements per scale (== plugins.QUANT_BLOCK)
BLOCK_ROWS = 128    # quant blocks per grid step


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)               # (rows, QUANT_BLOCK)
    scale = jnp.max(jnp.abs(x), axis=1) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x / scale[:, None]), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


def _dequant_kernel(q_ref, s_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)
    o_ref[...] = q * s_ref[...][:, None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantize_blocks(x2d, *, interpret: bool = True):
    """(n_blocks, QUANT_BLOCK) fp -> (int8 payload, fp32 scales)."""
    rows, cols = x2d.shape
    assert cols == QUANT_BLOCK and rows % BLOCK_ROWS == 0, (rows, cols)
    grid = (rows // BLOCK_ROWS,)
    return pl.pallas_call(
        _quant_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((rows, cols), jnp.int8),
            jax.ShapeDtypeStruct((rows,), jnp.float32),
        ),
        grid=grid,
        in_specs=[pl.BlockSpec((BLOCK_ROWS, cols), lambda i: (i, 0))],
        out_specs=(
            pl.BlockSpec((BLOCK_ROWS, cols), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_ROWS,), lambda i: (i,)),
        ),
        interpret=interpret,
    )(x2d)


@functools.partial(jax.jit, static_argnames=("interpret",))
def dequantize_blocks(q2d, scales, *, interpret: bool = True):
    """(n_blocks, QUANT_BLOCK) int8 + (n_blocks,) scales -> fp32."""
    rows, cols = q2d.shape
    assert cols == QUANT_BLOCK and rows % BLOCK_ROWS == 0, (rows, cols)
    grid = (rows // BLOCK_ROWS,)
    return pl.pallas_call(
        _dequant_kernel,
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, cols), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_ROWS,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, cols), lambda i: (i, 0)),
        interpret=interpret,
    )(q2d, scales)
