"""Pallas TPU kernels for the compute hot-spots ACCL+ optimizes in hardware:

  fused_reduce      binary streaming plugin (combine + cast, one VMEM pass)
  quantize          unary streaming plugin (per-block int8 codec)
  matmul            MXU-tiled matmul (DLRM FC shards, collective-matmul step)
  embedding_gather  DLRM sparse lookup via scalar-prefetch DMA

Each kernel: <name>.py (pl.pallas_call + BlockSpec), ref.py oracle,
ops.py public wrapper (padding + interpret-mode selection).
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
