"""Pure-jnp oracles for every Pallas kernel (the correctness references)."""
from __future__ import annotations

import jax.numpy as jnp

QUANT_BLOCK = 256


def fused_combine(x, y, op: str = "add", out_dtype=None):
    out_dtype = out_dtype or x.dtype
    a = x.astype(jnp.float32)
    b = y.astype(jnp.float32)
    f = {"add": lambda p, q: p + q, "max": jnp.maximum,
         "min": jnp.minimum, "mul": jnp.multiply}[op]
    return f(a, b).astype(out_dtype)


def quantize_blocks(x2d):
    x = x2d.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x), axis=1) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_blocks(q2d, scales):
    return q2d.astype(jnp.float32) * scales[:, None]


def matmul(x, y, out_dtype=None):
    out_dtype = out_dtype or x.dtype
    return jnp.dot(x, y, preferred_element_type=jnp.float32).astype(out_dtype)


def gather_rows(table, indices):
    return jnp.take(table, indices, axis=0)
