"""Tiled MXU matmul — the DLRM FC / collective-matmul compute step.

The paper's DLRM FC layers are the compute hot-spot it distributes
(checkerboard decomposition, §6.1); each rank's local shard product is
exactly this kernel. It is also the per-step compute of the streaming
collective matmul (engine.allgather_matmul / matmul_reduce_scatter).

MXU mapping: (bm, bk) x (bk, bn) tiles, all multiples of 128, fp32
accumulator held in a VMEM scratch across the K grid dimension (innermost),
cast on the final K step. Grid order (m, n, k) keeps the accumulator live
for exactly one (m, n) tile at a time.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU memory-space hints; interpret mode accepts plain scratch too
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    _VMEM = None

DEFAULT_BM = 256
DEFAULT_BN = 256
DEFAULT_BK = 256


def _kernel(x_ref, y_ref, o_ref, acc_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "out_dtype",
                                             "interpret"))
def matmul_tiled(x, y, *, bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                 bk: int = DEFAULT_BK, out_dtype=None,
                 interpret: bool = True):
    """x: (M, K), y: (K, N); M % bm == K % bk == N % bn == 0 (ops.py pads)."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, (x.shape, y.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    out_dtype = out_dtype or x.dtype
    grid = (m // bm, n // bn, k // bk)
    scratch = [_VMEM((bm, bn), jnp.float32)] if _VMEM is not None else [
        pl.BlockSpec(memory_space=None)]  # pragma: no cover
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        scratch_shapes=scratch,
        interpret=interpret,
    )(x, y)
