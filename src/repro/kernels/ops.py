"""Public jit'd wrappers around the Pallas kernels.

Handles shape padding/alignment so callers can pass arbitrary shapes, and
selects interpret mode automatically (interpret=True on CPU — the
validation path; compiled Mosaic on real TPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import embedding_gather as _eg
from repro.kernels import fused_reduce as _fr
from repro.kernels import matmul as _mm
from repro.kernels import quantize as _qz

LANES = 128


@functools.cache
def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_dim(x, dim: int, mult: int):
    pad = (-x.shape[dim]) % mult
    if pad == 0:
        return x, x.shape[dim]
    widths = [(0, 0)] * x.ndim
    widths[dim] = (0, pad)
    return jnp.pad(x, widths), x.shape[dim]


def fused_add(x, y, out_dtype=None):
    """Streaming binary plugin: x + y (fp32 accumulate, fused cast)."""
    shape = x.shape
    flat_x = x.reshape(-1)
    flat_y = y.reshape(-1)
    flat_x, n = _pad_dim(flat_x, 0, _fr.DEFAULT_BLOCK_ROWS * LANES)
    flat_y, _ = _pad_dim(flat_y, 0, _fr.DEFAULT_BLOCK_ROWS * LANES)
    x2 = flat_x.reshape(-1, LANES)
    y2 = flat_y.reshape(-1, LANES)
    out = _fr.fused_combine(x2, y2, op="add", out_dtype=out_dtype,
                            interpret=_interpret())
    return out.reshape(-1)[:n].reshape(shape)


def fused_combine(x, y, op: str = "add", out_dtype=None):
    shape = x.shape
    flat_x, n = _pad_dim(x.reshape(-1), 0, _fr.DEFAULT_BLOCK_ROWS * LANES)
    flat_y, _ = _pad_dim(y.reshape(-1), 0, _fr.DEFAULT_BLOCK_ROWS * LANES)
    out = _fr.fused_combine(flat_x.reshape(-1, LANES),
                            flat_y.reshape(-1, LANES), op=op,
                            out_dtype=out_dtype, interpret=_interpret())
    return out.reshape(-1)[:n].reshape(shape)


def quantize_int8(flat):
    """flat (N,) fp -> (payload int8 (Np,), scales fp32 (Np/256,)).

    Np is N padded to QUANT_BLOCK*BLOCK_ROWS; decompress slices back.
    """
    flat, _ = _pad_dim(flat.reshape(-1), 0,
                       _qz.QUANT_BLOCK * _qz.BLOCK_ROWS)
    q, s = _qz.quantize_blocks(flat.reshape(-1, _qz.QUANT_BLOCK),
                               interpret=_interpret())
    return q.reshape(-1), s


def dequantize_int8(payload, scales):
    out = _qz.dequantize_blocks(payload.reshape(-1, _qz.QUANT_BLOCK), scales,
                                interpret=_interpret())
    return out.reshape(-1)


def matmul(x, y, out_dtype=None, bm=None, bn=None, bk=None):
    """General (M,K)@(K,N) with automatic 128-alignment padding."""
    m, k = x.shape
    _, n = y.shape
    bm = bm or min(_mm.DEFAULT_BM, _ceil_mult(m, LANES))
    bn = bn or min(_mm.DEFAULT_BN, _ceil_mult(n, LANES))
    bk = bk or min(_mm.DEFAULT_BK, _ceil_mult(k, LANES))
    xp, _ = _pad_dim(x, 0, bm)
    xp, _ = _pad_dim(xp, 1, bk)
    yp, _ = _pad_dim(y, 0, bk)
    yp, _ = _pad_dim(yp, 1, bn)
    out = _mm.matmul_tiled(xp, yp, bm=bm, bn=bn, bk=bk,
                           out_dtype=out_dtype, interpret=_interpret())
    return out[:m, :n]


def _ceil_mult(v: int, mult: int) -> int:
    return ((v + mult - 1) // mult) * mult


def embedding_gather(table, indices):
    """(V, D) table, (B,) int indices -> (B, D); pads D to 128."""
    tp, d = _pad_dim(table, 1, LANES)
    out = _eg.gather_rows(tp, indices, interpret=_interpret())
    return out[:, :d]
