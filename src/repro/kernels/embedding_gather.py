"""DLRM embedding lookup — scalar-prefetch gather from an HBM-resident table.

The paper's DLRM embedding layers are "memory-bound ... accessed via
indexes, resulting in multiple random memory accesses" (§6). FPGA solutions
spread tables over HBM channels for parallel access; the TPU analogue is a
Pallas kernel whose *grid* is driven by the indices (scalar prefetch): each
grid step DMAs exactly one (1, D) table row HBM->VMEM, so the sparse access
pattern never materializes an intermediate one-hot or full-table read.

D must be 128-aligned (DLRM vectors are 32-wide in the paper; ops.py pads
the table's last dim).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _HAVE_TPU_GRID = True
except Exception:  # pragma: no cover
    _HAVE_TPU_GRID = False


def _kernel(idx_ref, table_ref, o_ref):
    # The index_map already steered this block to row idx_ref[i]; plain copy.
    o_ref[...] = table_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_rows(table, indices, *, interpret: bool = True):
    """table: (V, D) fp; indices: (B,) int32 -> (B, D).

    Scalar-prefetched indices drive the table BlockSpec's index_map, one
    row per grid step.
    """
    v, d = table.shape
    (b,) = indices.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, idx_ref: (idx_ref[i], 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda i, idx_ref: (i, 0)),
    )
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((b, d), table.dtype),
        grid_spec=grid_spec,
        interpret=interpret,
    )(indices.astype(jnp.int32), table)
