import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512" + (
    (" " + os.environ["XLA_FLAGS"]) if "XLA_FLAGS" in os.environ else "")

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any other import (jax locks the device
count on first init); 512 virtual host devices back the production meshes
(16x16 single-pod, 2x16x16 multi-pod).

Per cell this produces, without allocating any real tensor:
  * compiled.memory_analysis()  -> bytes/device (fits-in-HBM check),
  * compiled.cost_analysis()    -> per-device FLOPs / bytes,
  * parsed collective traffic   -> wire/DCN bytes (launch/analysis.py),
  * the three roofline terms + dominant bottleneck.

Usage:
  python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k
  python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k --multi-pod
  python -m repro.launch.dryrun --all [--multi-pod] [--jobs-file f.json]

--all orchestrates one subprocess per cell (fresh XLA, resumable: cells
with an existing result JSON are skipped).
"""
import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import subprocess  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ARCH_IDS, SHAPES, get_config  # noqa: E402
from repro.configs.base import ParallelConfig  # noqa: E402
from repro.core.hw_spec import TPU_V5E  # noqa: E402
from repro.launch import analysis  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models.common import dt  # noqa: E402
from repro.optim import adamw  # noqa: E402
from repro.parallel import stages  # noqa: E402

WHISPER_S_ENC = 1500  # 30 s of audio frames (decode cross-attention cache)


def sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def input_specs(cfg, shape_cfg, mesh, pcfg, kind: str):
    """ShapeDtypeStruct stand-ins for every model input of the cell."""
    b, s = shape_cfg.global_batch, shape_cfg.seq_len
    dp = stages.dp_axes(mesh, b)
    cdt = dt(cfg.param_dtype)
    if kind in ("train", "prefill"):
        out = {"tokens": sds((b, s), jnp.int32, mesh, P(dp, None))}
        if kind == "train":
            out["labels"] = sds((b, s), jnp.int32, mesh, P(dp, None))
        if cfg.family == "vlm":
            out["vis_embed"] = sds((b, cfg.n_vis_tokens, cfg.d_model), cdt,
                                   mesh, P(dp, None, None))
        if cfg.encoder_layers:
            out["frames"] = sds((b, s, cfg.d_model), cdt, mesh,
                                P(dp, None, None))
        return out
    if kind == "decode":
        return {"tokens": sds((b, 1), jnp.int32, mesh, P(dp, None)),
                "pos": jax.ShapeDtypeStruct((), jnp.int32)}
    raise ValueError(kind)


def opt_shapes_from(params_shapes):
    def leaf(sd):
        mk = lambda: jax.ShapeDtypeStruct(  # noqa: E731
            sd.shape, jnp.float32, sharding=sd.sharding)
        return {"master": mk(), "m": mk(), "v": mk()}
    leaves = jax.tree.map(
        leaf, params_shapes,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    return {"leaves": leaves, "count": jax.ShapeDtypeStruct((), jnp.int32)}


def pcfg_from_args(args, backend=None) -> ParallelConfig:
    return ParallelConfig(
        backend=backend or args.backend,
        sequence_parallel=args.sp,
        collective_matmul=args.collective_matmul,
        remat=args.remat,
        grad_compression=args.compress or None,
        attn_q_block=args.q_block,
        attn_kv_block=args.kv_block,
        moe_capacity_factor=args.capacity,
        scan_layers=not args.no_scan,
        decode_seq_shard=not args.no_seq_shard,
        kv_cache_dtype=args.kv_cache,
        microbatches=args.microbatches,
    )


def run_cell(arch_id: str, shape_id: str, multi_pod: bool,
             pcfg: ParallelConfig, variant: str = "base", tp: int = 16):
    t_start = time.time()
    cfg = get_config(arch_id)
    shape_cfg = SHAPES[shape_id]
    mesh = make_production_mesh(multi_pod=multi_pod, tp=tp)
    chips = mesh.size
    pod_size = 256 if multi_pod else 0
    result = {
        "arch": arch_id, "shape": shape_id,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "chips": chips, "backend": pcfg.backend, "variant": variant,
        "kind": shape_cfg.kind,
    }

    if shape_cfg.kind == "decode" and shape_cfg.seq_len >= 500_000 \
            and not cfg.is_subquadratic:
        result["status"] = "SKIP(full-attn)"
        return result

    tp = mesh.shape["model"]
    serve = shape_cfg.kind != "train"
    pshapes = stages.param_shapes(cfg, mesh, tp, serve=serve)
    s_enc = WHISPER_S_ENC if cfg.encoder_layers else 0

    if shape_cfg.kind == "train":
        ts = stages.build_train_step(cfg, pcfg, mesh,
                                     adamw.AdamWConfig())
        batch = input_specs(cfg, shape_cfg, mesh, pcfg, "train")
        oshapes = opt_shapes_from(pshapes)
        lowered = ts.fn.lower(pshapes, oshapes, batch,
                              jax.ShapeDtypeStruct((), jnp.int32))
    elif shape_cfg.kind == "prefill":
        pf, ctx, _, _ = stages.build_prefill(
            cfg, pcfg, mesh, shape_cfg.global_batch, shape_cfg.seq_len)
        batch = input_specs(cfg, shape_cfg, mesh, pcfg, "prefill")
        lowered = pf.lower(pshapes, batch)
    else:  # decode
        dstep, ctx, _, _ = stages.build_decode_step(
            cfg, pcfg, mesh, s_max=shape_cfg.seq_len,
            global_batch=shape_cfg.global_batch, s_enc=s_enc)
        cshapes = stages.cache_shapes(
            cfg, pcfg, mesh, tp, shape_cfg.global_batch,
            shape_cfg.seq_len, s_enc=s_enc,
            dp=stages.dp_axes(mesh, shape_cfg.global_batch))
        io = input_specs(cfg, shape_cfg, mesh, pcfg, "decode")
        lowered = dstep.lower(pshapes, cshapes, io["tokens"], io["pos"])

    result["t_lower_s"] = round(time.time() - t_start, 2)
    n_active = cfg.n_active_params()
    tokens = shape_cfg.global_batch * (
        shape_cfg.seq_len if shape_cfg.kind != "decode" else 1)
    mult = 6 if shape_cfg.kind == "train" else 2
    return _finish(result, lowered, chips, pod_size,
                   mult * n_active * tokens, t_start)


def _finish(result, lowered, chips, pod_size, model_flops, t_start):
    t0 = time.time()
    compiled = lowered.compile()
    result["t_compile_s"] = round(time.time() - t0, 2)

    mem = compiled.memory_analysis()
    result["memory"] = {
        "argument_bytes": mem.argument_size_in_bytes,
        "output_bytes": mem.output_size_in_bytes,
        "temp_bytes": mem.temp_size_in_bytes,
        "alias_bytes": mem.alias_size_in_bytes,
        "peak_bytes_est": mem.argument_size_in_bytes
        + mem.output_size_in_bytes + mem.temp_size_in_bytes
        - mem.alias_size_in_bytes,
    }
    result["fits_hbm"] = result["memory"]["peak_bytes_est"] \
        < TPU_V5E.hbm_bytes
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # jax 0.4.x returns [dict]
        cost = cost[0]
    text = compiled.as_text()
    hlo = analysis.analyze_hlo(text, pod_size)
    terms = analysis.roofline_terms(cost, mem, hlo, TPU_V5E, chips)
    result["roofline"] = terms
    result["model_flops"] = model_flops
    gf = terms["global_flops"]
    result["model_flops_ratio"] = model_flops / gf if gf else None
    # scoring roofline: compute / memory-floor / collective (the artifact
    # t_memory_s includes XLA-CPU fusion-boundary rematerialization traffic
    # a TPU backend would keep in VMEM; it is reported as a diagnostic)
    step_time = max(terms["t_compute_s"], terms["t_memory_floor_s"],
                    terms["t_collective_s"])
    result["roofline_step_time_s"] = step_time
    result["roofline_mfu"] = model_flops / (
        chips * TPU_V5E.peak_flops_bf16 * step_time) if step_time else None
    step_art = max(terms["t_compute_s"], terms["t_memory_s"],
                   terms["t_collective_s"])
    result["roofline_mfu_artifact"] = model_flops / (
        chips * TPU_V5E.peak_flops_bf16 * step_art) if step_art else None
    result["hlo_bytes"] = len(text)
    result["status"] = "OK"
    result["t_total_s"] = round(time.time() - t_start, 2)
    return result


def run_dlrm_cell(multi_pod: bool, pcfg: ParallelConfig,
                  variant: str = "base", batch: int = 1024):
    """Paper Table 2 at full scale: 100 tables x 4M rows x 32 (51 GB fp32),
    sharded over the model axis; FC stack checkerboard-decomposed."""
    import dataclasses as _dc
    from jax.sharding import NamedSharding
    from repro.configs.dlrm import CONFIG as dcfg
    from repro.models import dlrm as dlrm_mod
    from repro.models.common import Builder
    from repro.parallel.ops import ParCtx
    from repro.core.engine import CollectiveEngine
    from repro.core.compat import shard_map

    t_start = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    pod_size = 256 if multi_pod else 0
    tp = mesh.shape["model"]
    result = {"arch": "dlrm", "shape": f"serve_b{batch}",
              "mesh": "x".join(str(x) for x in mesh.devices.shape),
              "chips": chips, "backend": pcfg.backend,
              "variant": variant, "kind": "serve"}
    pcfg = _dc.replace(pcfg, serving=True)
    engine = CollectiveEngine(mesh, backend=pcfg.backend)
    ctx = ParCtx(engine=engine, pcfg=pcfg, mesh=mesh)
    specs = dlrm_mod.dlrm_specs(dcfg, tp)
    b = Builder("shape", mesh=mesh, dtype=jnp.float32)
    pshapes = dlrm_mod.dlrm_params(b, dcfg, tp)
    dp = stages.dp_axes(mesh, batch)
    idx = sds((batch, dcfg.n_tables), jnp.int32, mesh, P(dp, None))
    fn = jax.jit(shard_map(
        lambda p, i: dlrm_mod.dlrm_forward(p, i, ctx),
        mesh=mesh, in_specs=(specs, P(dp, None)),
        out_specs=P(dp, None), check_vma=False))
    lowered = fn.lower(pshapes, idx)
    result["t_lower_s"] = round(time.time() - t_start, 2)
    # FC flops (2*b*in*out summed) + embedding gather bytes dominate
    dims = (dcfg.n_tables * dcfg.emb_dim,) + tuple(dcfg.fc_dims) \
        + (dcfg.out_dim,)
    flops = sum(2 * batch * dims[i] * dims[i + 1]
                for i in range(len(dims) - 1))
    return _finish(result, lowered, chips, pod_size, flops, t_start)


def all_cells():
    for arch_id in ARCH_IDS:
        for shape_id in SHAPES:
            yield arch_id, shape_id


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch",
                    choices=sorted(ARCH_IDS) + ["dlrm"])
    ap.add_argument("--shape", choices=sorted(SHAPES),
                    default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--backend", default="microcode",
                    choices=("microcode", "native"))
    ap.add_argument("--variant", default="base")
    ap.add_argument("--results", default="results/dryrun")
    ap.add_argument("--sp", action="store_true")
    ap.add_argument("--collective-matmul", action="store_true")
    ap.add_argument("--remat", default="full",
                    choices=("none", "full", "dots", "names"))
    ap.add_argument("--compress", default="")
    ap.add_argument("--q-block", type=int, default=512)
    ap.add_argument("--kv-block", type=int, default=1024)
    ap.add_argument("--capacity", type=float, default=1.25)
    ap.add_argument("--no-scan", action="store_true")
    ap.add_argument("--no-seq-shard", action="store_true")
    ap.add_argument("--kv-cache", default="param", choices=("param", "int8"))
    ap.add_argument("--tp", type=int, default=16)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--timeout", type=int, default=3600)
    args = ap.parse_args()
    os.makedirs(args.results, exist_ok=True)

    if args.all:
        failures = []
        for arch_id, shape_id in all_cells():
            tag = "multi" if args.multi_pod else "single"
            name = f"{arch_id}_{shape_id}_{tag}_{args.variant}.json"
            path = os.path.join(args.results, name)
            if os.path.exists(path):
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch_id, "--shape", shape_id,
                   "--backend", args.backend, "--variant", args.variant,
                   "--results", args.results, "--remat", args.remat]
            if args.multi_pod:
                cmd.append("--multi-pod")
            for flag, on in [("--sp", args.sp),
                             ("--collective-matmul", args.collective_matmul),
                             ("--no-scan", args.no_scan),
                             ("--no-seq-shard", args.no_seq_shard)]:
                if on:
                    cmd.append(flag)
            if args.compress:
                cmd += ["--compress", args.compress]
            print(f"[dryrun] {name} ...", flush=True)
            try:
                subprocess.run(cmd, check=True, timeout=args.timeout)
            except Exception as e:  # noqa: BLE001
                failures.append((name, str(e)))
                with open(path, "w") as f:
                    json.dump({"arch": arch_id, "shape": shape_id,
                               "status": f"DRIVER_FAIL: {e}"}, f)
        print(f"[dryrun] done; {len(failures)} failures")
        for n, e in failures:
            print("  FAIL", n, e)
        return

    assert args.arch and (args.shape or args.arch == "dlrm"), \
        "--arch and --shape (or --all)"
    pcfg = pcfg_from_args(args)
    tag = "multi" if args.multi_pod else "single"
    shape_tag = args.shape or "serve_b1024"
    name = f"{args.arch}_{shape_tag}_{tag}_{args.variant}.json"
    path = os.path.join(args.results, name)
    try:
        if args.arch == "dlrm":
            result = run_dlrm_cell(args.multi_pod, pcfg, args.variant)
        else:
            result = run_cell(args.arch, args.shape, args.multi_pod, pcfg,
                              args.variant, tp=args.tp)
    except Exception as e:  # noqa: BLE001
        result = {"arch": args.arch, "shape": args.shape,
                  "status": f"FAIL: {type(e).__name__}: {e}",
                  "traceback": traceback.format_exc()[-4000:]}
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    print(json.dumps({k: v for k, v in result.items()
                      if k not in ("traceback", "roofline")}, indent=1))
    if "roofline" in result:
        print(json.dumps(result["roofline"], indent=1))


if __name__ == "__main__":
    main()
