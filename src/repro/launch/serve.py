"""Serving launcher: prefill + greedy decode over the sharded caches.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b \
      --prompt-len 16 --gen 8 --devices 8
"""
import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--tp", type=int, default=2)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--backend", default="microcode")
    args = ap.parse_args()

    os.environ.setdefault(
        "XLA_FLAGS",
        f"--xla_force_host_platform_device_count={args.devices}")

    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config, reduced_config
    from repro.configs.base import ParallelConfig
    from repro.launch.mesh import make_mesh_for
    from repro.parallel import stages

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    mesh = make_mesh_for(args.devices, tp=args.tp)
    pcfg = ParallelConfig(backend=args.backend,
                          moe_capacity_factor=8.0)
    s_max = args.prompt_len + args.gen
    params = stages.init_params(cfg, mesh, args.tp, seed=0)
    dstep, _, _, _ = stages.build_decode_step(
        cfg, pcfg, mesh, s_max=s_max, global_batch=args.batch)
    cache = stages.init_cache(cfg, pcfg, mesh, args.tp, args.batch, s_max)

    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size,
                          (args.batch, args.prompt_len)).astype(np.int32)
    # teacher-forced prompt consumption, then free-running generation
    # (decode-only path exercises the same program serving uses per token)
    seqs = [prompt[:, i] for i in range(args.prompt_len)]
    tok = jnp.asarray(prompt[:, :1])
    for t in range(args.prompt_len + args.gen - 1):
        nxt, cache = dstep(params, cache, tok, jnp.int32(t))
        if t + 1 < args.prompt_len:
            tok = jnp.asarray(prompt[:, t + 1:t + 2])
        else:
            seqs.append(np.asarray(nxt))
            tok = nxt[:, None].astype(jnp.int32)
    out = np.stack(seqs, axis=1)
    print("generated (batch x tokens):")
    print(out)


if __name__ == "__main__":
    main()
