"""Production mesh definitions.

A function, not a module-level constant: importing this module must never
touch jax device state (the dry-run sets the virtual device count before
any jax initialization).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False, tp: int = 16):
    """16x16 chips per pod; the multi-pod mesh adds a 2-pod DCN axis.

    `tp` retiles the same 256 chips/pod between the data and model axes
    (TP degree is a per-architecture tunable: small models want tp<=2,
    MoE wants tp ~ expert granularity; see EXPERIMENTS §Perf)."""
    per_pod = 256
    assert per_pod % tp == 0
    shape = (2, per_pod // tp, tp) if multi_pod else (per_pod // tp, tp)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    try:
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    except TypeError:  # older jax
        return jax.make_mesh(shape, axes)


def make_mesh_for(devices: int, tp: int = None):
    """Smoke/bench meshes on whatever devices exist (1..8 host CPUs)."""
    tp = tp or (2 if devices % 2 == 0 else 1)
    dp = devices // tp
    try:
        return jax.make_mesh(
            (1, dp, tp), ("pod", "data", "model"),
            axis_types=(jax.sharding.AxisType.Auto,) * 3)
    except TypeError:
        return jax.make_mesh((1, dp, tp), ("pod", "data", "model"))
