"""Loop-aware static analysis of compiled HLO.

XLA's cost_analysis() counts while-loop bodies ONCE (verified empirically:
a 10-trip scanned matmul reports 1 iteration of flops), and it reports no
collective traffic at all. Since every layer stack here is a lax.scan and
every ring collective a rolled loop, naive numbers are off by ~n_layers x
ring_steps. This module parses the optimized HLO text into computations,
builds the call graph (while bodies x trip count, fusions, reducers),
propagates execution multiplicities from ENTRY, and accumulates:

  flops        2 * result_elems * contracted_elems per dot (x multiplicity)
  bytes        operand+result bytes of thread-level instructions (fusion
               internals excluded, matching cost_analysis conventions)
  collectives  per-op wire bytes under a ring execution model, with DCN
               attribution for pod-spanning replica groups

Validated against hand-counted schedules and against cost_analysis on
loop-free programs in tests/test_analysis.py.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*"
    r"((?:\([^)]*\))|(?:[a-z0-9]+\[[^\]]*\]\S*))\s+"
    r"([\w\-]+)\(")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# no HBM traffic / bookkeeping only
_NO_BYTES = {"parameter", "constant", "get-tuple-element", "tuple",
             "bitcast", "after-all", "iota", "partition-id", "replica-id"}


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _type_elems(type_str: str) -> int:
    total = 0
    for _, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n
    return total


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    operands: tuple
    line: str


def _balanced_args(line: str, start: int) -> str:
    depth = 0
    for i in range(start, len(line)):
        if line[i] == "(":
            depth += 1
        elif line[i] == ")":
            depth -= 1
            if depth == 0:
                return line[start + 1:i]
    return line[start + 1:]


def parse_module(text: str):
    """-> (comps: {name: {iname: Instr}}, entry_name)."""
    comps: dict = {}
    entry = None
    depth = 0
    header: list = []
    cur: Optional[str] = None
    for line in text.splitlines():
        delta = line.count("{") - line.count("}")
        if depth == 0:
            header.append(line)
            if delta > 0:
                htext = " ".join(header)
                m = re.search(r"(ENTRY\s+)?%([\w\.\-]+)\s*\(", htext)
                cur = m.group(2) if m else f"__anon{len(comps)}"
                if m and m.group(1):
                    entry = cur
                comps[cur] = {}
                header = []
                depth = delta
            continue
        depth += delta
        if depth <= 0:
            cur, depth = None, 0
            continue
        m = _INSTR_RE.search(line)
        if m and cur is not None:
            name, type_str, opcode = m.group(1), m.group(2), m.group(3)
            args = _balanced_args(line, line.index("(", m.end(3) - 1))
            operands = tuple(re.findall(r"%([\w\.\-]+)", args))
            comps[cur][name] = Instr(name, type_str, opcode, operands, line)
    if entry is None and comps:
        entry = list(comps)[-1]
    return comps, entry


def _trip_count(cond_instrs: dict) -> int:
    best = 1
    for ins in cond_instrs.values():
        for m in re.finditer(r"constant\((\d+)\)", ins.line):
            v = int(m.group(1))
            if 1 < v <= 10_000_000:
                best = max(best, v)
    return best


def _call_edges(instrs: dict):
    """yields (callee, kind) with kind in {'while','flow','apply'}."""
    for ins in instrs.values():
        line = ins.line
        if ins.opcode == "while":
            mb = re.search(r"body=%?([\w\.\-]+)", line)
            mc = re.search(r"condition=%?([\w\.\-]+)", line)
            if mb:
                yield mb.group(1), "while", (mc.group(1) if mc else None)
        elif ins.opcode == "conditional":
            for m in re.finditer(r"(?:branch_computations=\{([^}]*)\}|"
                                 r"(?:true|false)_computation=%?([\w\.\-]+))",
                                 line):
                blob = m.group(1) or m.group(2) or ""
                for name in re.findall(r"%?([\w\.\-]+)", blob):
                    yield name, "flow", None
        elif ins.opcode in ("call", "async-start", "custom-call"):
            m = re.search(r"(?:to_apply|called_computations=\{)"
                          r"=?%?([\w\.\-]+)", line)
            if m:
                yield m.group(1), "flow", None
        else:
            m = re.search(r"calls=%?([\w\.\-]+)", line)
            if m:
                yield m.group(1), "apply", None
            m2 = re.search(r"to_apply=%?([\w\.\-]+)", line)
            if m2:
                yield m2.group(1), "apply", None


def multiplicities(comps: dict, entry: str):
    """Execution count per computation, propagating loop trip counts."""
    mult = {name: 0 for name in comps}
    mult[entry] = 1
    # topological-ish: iterate until fixpoint (call graph is a DAG)
    for _ in range(64):
        changed = False
        for name, instrs in comps.items():
            base = mult.get(name, 0)
            if base == 0:
                continue
            for callee, kind, cond in _call_edges(instrs):
                if callee not in comps:
                    continue
                if kind == "while":
                    trip = _trip_count(comps.get(cond, {})) if cond else 1
                    inc = base * trip
                    if cond and mult.get(cond, 0) < base * (trip + 1):
                        mult[cond] = base * (trip + 1)
                        changed = True
                else:
                    inc = base
                if mult.get(callee, 0) < inc:
                    mult[callee] = inc
                    changed = True
        if not changed:
            break
    return mult


def _dot_flops(ins: Instr, table: dict) -> float:
    out_elems = _type_elems(ins.type_str)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.line)
    if not m or not ins.operands:
        return 2.0 * out_elems  # degenerate
    lhs = table.get(ins.operands[0])
    if lhs is None:
        return 2.0 * out_elems
    dims_m = _SHAPE_RE.search(lhs.type_str)
    if not dims_m:
        return 2.0 * out_elems
    lhs_dims = [int(d) for d in dims_m.group(2).split(",") if d]
    k = 1
    for idx in (int(i) for i in m.group(1).split(",") if i):
        if idx < len(lhs_dims):
            k *= lhs_dims[idx]
    return 2.0 * out_elems * k


def _group_info(line: str, pod_size: int):
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        ids = [int(x) for x in m.group(1).split(",") if x.strip()]
        gs = max(len(ids), 1)
        spans = pod_size and len({i // pod_size for i in ids}) > 1
        return gs, bool(spans)
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[", line)
    if m:
        s = int(m.group(2))
        return s, bool(pod_size and s > pod_size)
    return 1, False


@dataclasses.dataclass
class HloStats:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    coll_ops: float = 0.0
    coll_wire_bytes: float = 0.0
    coll_dcn_bytes: float = 0.0
    coll_by_kind: dict = dataclasses.field(default_factory=dict)
    loops: int = 0


_SLICE_OPS = {"dynamic-slice", "slice", "gather"}


def _instr_bytes(ins: Instr, instrs: dict, comps: dict) -> float:
    """HBM traffic of one thread-level instruction, slice-aware.

    Loop bodies reference full carried buffers; actual traffic for a
    (dynamic-)slice is the slice, and an in-place dynamic-update-slice
    writes only the update region. Fusions are charged by inspecting their
    called computation: parameters that are immediately sliced inside count
    at slice size, and a DUS root writes only its update.
    """
    op = ins.opcode
    rb = _type_bytes(ins.type_str)
    if op in _SLICE_OPS:
        return 2.0 * rb
    if op == "dynamic-update-slice":
        upd = instrs.get(ins.operands[1]) if len(ins.operands) > 1 else None
        ub = _type_bytes(upd.type_str) if upd else rb
        return 2.0 * ub
    if op == "while":
        return 0.0  # carries pass by reference; body traffic counted inside
    if op == "fusion":
        m = re.search(r"calls=%?([\w\.\-]+)", ins.line)
        body = comps.get(m.group(1)) if m else None
        if body is None:
            ob = sum(_type_bytes(instrs[o].type_str)
                     for o in ins.operands if o in instrs)
            return rb + ob
        # map parameter index -> effective read size
        param_eff: dict = {}
        root_dus_update = None
        by_name = body
        for bins in by_name.values():
            if bins.opcode == "parameter":
                pm = re.search(r"parameter\((\d+)\)", bins.line)
                if pm:
                    param_eff[bins.name] = (int(pm.group(1)),
                                            _type_bytes(bins.type_str))
            if bins.opcode in _SLICE_OPS and bins.operands:
                src = bins.operands[0]
                if src in param_eff:
                    idx, _ = param_eff[src]
                    param_eff[src] = (idx, _type_bytes(bins.type_str))
            if bins.opcode == "dynamic-update-slice" \
                    and "ROOT" in bins.line and len(bins.operands) > 1:
                upd = by_name.get(bins.operands[1])
                if upd is not None:
                    root_dus_update = _type_bytes(upd.type_str)
        reads = sum(sz for (_, sz) in param_eff.values())
        writes = root_dus_update if root_dus_update is not None else rb
        if root_dus_update is not None:
            # in-place DUS: the untouched region is neither read nor written
            reads = min(reads, root_dus_update * 2 + sum(
                sz for (_, sz) in param_eff.values()
                if sz < rb))
        return reads + writes
    ob = sum(_type_bytes(instrs[o].type_str)
             for o in ins.operands if o in instrs)
    return rb + ob


def analyze_hlo(text: str, pod_size: int = 0) -> HloStats:
    comps, entry = parse_module(text)
    mult = multiplicities(comps, entry)
    # computations reached via 'apply' (fusion internals, reducers): flops
    # count, bytes do not (the calling instruction carries the traffic).
    applied = set()
    for name, instrs in comps.items():
        for callee, kind, _ in _call_edges(instrs):
            if kind == "apply":
                applied.add(callee)

    st = HloStats()
    for name, instrs in comps.items():
        m = mult.get(name, 0)
        if m == 0:
            continue
        is_applied = name in applied
        for ins in instrs.values():
            if ins.opcode == "while":
                st.loops += 1
            if ins.opcode in ("dot", "convolution"):
                st.flops += m * _dot_flops(ins, instrs)
            if not is_applied and ins.opcode not in _NO_BYTES:
                st.bytes_accessed += m * _instr_bytes(ins, instrs, comps)
            if ins.opcode in COLLECTIVES or any(
                    ins.opcode == c + "-start" for c in COLLECTIVES):
                kind = ins.opcode.replace("-start", "")
                rb = _type_bytes(ins.type_str)
                gs, spans = _group_info(ins.line, pod_size)
                if kind == "collective-permute":
                    wire = rb
                    pairs = re.search(r"source_target_pairs=\{([^}]*)\}",
                                      ins.line)
                    if pairs and pod_size:
                        ids = [int(x) for x in
                               re.findall(r"\d+", pairs.group(1))]
                        spans = any(a // pod_size != b // pod_size
                                    for a, b in zip(ids[::2], ids[1::2]))
                elif gs <= 1:
                    continue
                elif kind == "all-gather":
                    wire = rb * (gs - 1) / gs
                elif kind == "reduce-scatter":
                    wire = rb * (gs - 1)
                elif kind == "all-reduce":
                    wire = 2 * rb * (gs - 1) / gs
                else:  # all-to-all
                    wire = rb * (gs - 1) / gs
                st.coll_ops += m
                st.coll_wire_bytes += m * wire
                if spans:
                    st.coll_dcn_bytes += m * wire
                k = st.coll_by_kind.setdefault(kind, [0.0, 0.0])
                k[0] += m
                k[1] += m * wire
    return st


# Backwards-compatible wrapper used by earlier code/tests.
@dataclasses.dataclass
class CollectiveStats:
    ops: float = 0.0
    operand_bytes: float = 0.0
    wire_bytes: float = 0.0
    dcn_bytes: float = 0.0
    by_kind: dict = dataclasses.field(default_factory=dict)


def parse_collectives(text: str, n_devices: int,
                      pod_size: int = 0) -> CollectiveStats:
    st = analyze_hlo(text, pod_size)
    return CollectiveStats(
        ops=st.coll_ops, operand_bytes=0.0, wire_bytes=st.coll_wire_bytes,
        dcn_bytes=st.coll_dcn_bytes,
        by_kind={k: [v[0], v[1]] for k, v in st.coll_by_kind.items()})


# --------------------------------------------------------------------------
# Roofline terms
# --------------------------------------------------------------------------

def roofline_terms(cost: dict, mem, hlo: HloStats, hw, chips: int):
    """Three-term roofline from per-device compiled artifacts.

    flops/bytes use the loop-aware analyzer; raw cost_analysis values ride
    along for reference (they undercount loop bodies). t_memory_floor is
    the touch-every-assigned-byte-once bound (args+outputs+temp arena) —
    the artifact's HBM traffic lower bound; the gap between it and
    t_memory is re-materialization traffic (XLA-CPU fusion boundaries; a
    TPU backend / the Pallas kernels keep those tiles in VMEM).
    """
    flops_dev = hlo.flops
    bytes_dev = hlo.bytes_accessed
    t_compute = flops_dev / hw.peak_flops_bf16
    t_memory = bytes_dev / hw.hbm_bw
    arena = (mem.argument_size_in_bytes + mem.output_size_in_bytes
             + mem.temp_size_in_bytes) if mem is not None else 0
    t_memory_floor = arena / hw.hbm_bw
    ici_bw = hw.ici_link_bw * hw.ici_links_per_chip
    t_coll_ici = (hlo.coll_wire_bytes - hlo.coll_dcn_bytes) / ici_bw
    t_coll_dcn = hlo.coll_dcn_bytes / hw.dcn_bw
    t_collective = t_coll_ici + t_coll_dcn
    dominant = max(
        [("compute", t_compute), ("memory", t_memory),
         ("collective", t_collective)], key=lambda kv: kv[1])[0]
    return {
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "coll_wire_bytes_per_device": hlo.coll_wire_bytes,
        "coll_dcn_bytes_per_device": hlo.coll_dcn_bytes,
        "coll_ops": hlo.coll_ops,
        "coll_by_kind": {k: {"ops": v[0], "wire_bytes": v[1]}
                         for k, v in hlo.coll_by_kind.items()},
        "raw_cost_flops": float(cost.get("flops", 0.0)),
        "raw_cost_bytes": float(cost.get("bytes accessed", 0.0)),
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_memory_floor_s": t_memory_floor,
        "t_collective_s": t_collective,
        "dominant": dominant,
        "global_flops": flops_dev * chips,
        "n_loops": hlo.loops,
    }
