# NOTE: do not import repro.launch.dryrun here — it sets XLA device-count
# flags at import time and must only be imported as a fresh __main__.
from repro.launch.mesh import make_production_mesh, make_mesh_for

__all__ = ["make_production_mesh", "make_mesh_for"]
