"""Production training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
      --steps 100 --batch 8 --seq 64 --devices 8

On a real pod this process runs per host (jax.distributed.initialize is
called when JAX_COORDINATOR is set); in this container it runs on virtual
host devices. Arch/shape/parallelism knobs mirror the dry-run's.
"""
import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--tp", type=int, default=2)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--backend", default="microcode")
    ap.add_argument("--sp", action="store_true")
    ap.add_argument("--compress", default="")
    ap.add_argument("--remat", default="none")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    os.environ.setdefault(
        "XLA_FLAGS",
        f"--xla_force_host_platform_device_count={args.devices}")
    if os.environ.get("JAX_COORDINATOR"):
        import jax
        jax.distributed.initialize()  # multi-host pod entry point

    from repro.configs import get_config, reduced_config
    from repro.configs.base import ParallelConfig
    from repro.data import DataConfig
    from repro.launch.mesh import make_mesh_for
    from repro.optim import adamw
    from repro.optim.schedules import cosine_warmup
    from repro.runtime import Trainer, TrainerConfig

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    mesh = make_mesh_for(args.devices, tp=args.tp)
    pcfg = ParallelConfig(backend=args.backend, sequence_parallel=args.sp,
                          remat=args.remat,
                          grad_compression=args.compress or None)
    trainer = Trainer(
        cfg, pcfg, mesh, adamw.AdamWConfig(lr=args.lr),
        DataConfig(global_batch=args.batch, seq_len=args.seq,
                   seed=args.seed),
        TrainerConfig(total_steps=args.steps, ckpt_dir=args.ckpt,
                      ckpt_every=args.ckpt_every),
        lr_schedule=lambda s: cosine_warmup(s, 20, args.steps))
    log = trainer.run()
    for rec in log:
        if "step" in rec and rec["step"] % 10 == 0:
            print(f"step {rec['step']:5d}  ce {rec['ce_mean']:.4f}  "
                  f"{rec['dt']*1e3:.0f} ms")
    if trainer.watchdog.events:
        print("straggler events:", trainer.watchdog.events)


if __name__ == "__main__":
    main()
