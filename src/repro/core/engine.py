"""CollectiveEngine — the CCLO: executes microcode schedules on a TPU mesh.

Mirrors the ACCL+ hardware split (§4.4):

  control plane  = Python at trace time: the selector picks an algorithm,
                   the generator emits a Schedule (microcode), the compiler
                   lowers it to a micro-op Program — the uC + DMP.
  data plane     = ONE executor, `execute_program`, interpreting the fixed
                   micro-op set (core/program.py) as XLA: `collective-
                   permute` ops (Tx/Rx systems), dynamic slices (RxBuf
                   manager placement), combine ops / codecs (streaming
                   plugins).

Every collective — ring, tree, hypercube, masked, compressed, segmented —
lowers through the same executor; there are no per-algorithm hand-written
lowerings. That is the paper's property: new collectives are new
microprograms, not new circuits. Uniform step runs (rings) execute as one
rolled lax.scan (the LOOP micro-op), keeping O(n)-step schedules at O(1)
live buffers; segmented uniform runs execute as ONE skewed scan over
segment waves (the STREAM micro-op — the CCLO's hop-to-hop pipelining,
§4.4.3); O(log n) schedules (trees, hypercubes) unroll.

All MPI-like methods are called *inside* a `shard_map` region (the engine's
H2H role inside train/serve steps) or via `run()` which wraps one for
standalone use (the F2F role). `backend='native'` lowers to XLA's built-in
collectives instead — the "software MPI" baseline of the paper's figures.
"""
from __future__ import annotations

import dataclasses
import functools
import inspect
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax import lax
from repro.core.compat import shard_map

from repro.core import hierarchical, plugins, telemetry
from repro.core.algorithms import GENERATORS
from repro.core.program import (
    SRC_BUFFER, SRC_ORIGINAL, Copy, Compress, Decompress, Loop, Program,
    RecvCombine, SegLoop, Send, StackedRecv, Stream, StreamChain,
    _overlaps, _regions_stream_safe, fit_segments, split_exchange,
)
from repro.core.schedule import (
    SEL_ALL, SEL_CHUNK, SEL_MASK, SEL_RANGE, Schedule, Sel,
)
from repro.core.selector import Selector
from repro.core.topology import (
    Communicator, ProductComm, axis_comm, product_comm,
)
from repro.core.hw_spec import HwSpec, TPU_V5E


# --------------------------------------------------------------------------
# Region helpers (RxBuf manager placement)
# --------------------------------------------------------------------------

def _select(buf, chunks: int, sel: Sel, rank, s_idx):
    csize = buf.shape[0] // chunks
    if sel.kind == SEL_ALL:
        return buf
    if sel.kind == SEL_CHUNK:
        idx = sel.fn(rank, s_idx)
        return lax.dynamic_slice_in_dim(buf, idx * csize, csize, 0)
    if sel.kind == SEL_RANGE:
        off, length = sel.fn(rank, s_idx)
        return lax.dynamic_slice_in_dim(buf, off * csize, int(length) * csize, 0)
    if sel.kind == SEL_MASK:
        idxs = sel.fn(rank, s_idx)
        return jnp.concatenate(
            [buf[j * csize:(j + 1) * csize] for j in idxs], axis=0)
    raise ValueError(sel.kind)


def _recv_region(buf, chunks: int, sel: Sel, rank, s_idx):
    """(view, elem_offset, mask_idxs) of the region `recv_sel` writes.

    elem_offset is None for SEL_ALL (the whole buffer); mask_idxs is the
    static chunk-index tuple for SEL_MASK (the view is their gathered
    concatenation) and None otherwise."""
    csize = buf.shape[0] // chunks
    if sel.kind == SEL_MASK:
        idxs = sel.fn(rank, s_idx)
        view = jnp.concatenate(
            [buf[j * csize:(j + 1) * csize] for j in idxs], axis=0)
        return view, None, tuple(idxs)
    if sel.kind == SEL_ALL:
        return buf, None, None
    if sel.kind == SEL_CHUNK:
        off = sel.fn(rank, s_idx) * csize
    else:
        off = sel.fn(rank, s_idx)[0] * csize
    return _select(buf, chunks, sel, rank, s_idx), off, None


def _apply_write(buf, chunks: int, off, mask_idxs, new_val):
    """Write a combined region value back (inverse of `_recv_region`)."""
    if mask_idxs is not None:
        csize = buf.shape[0] // chunks
        for k, j in enumerate(mask_idxs):
            buf = buf.at[j * csize:(j + 1) * csize].set(
                new_val[k * csize:(k + 1) * csize])
        return buf
    if off is None:
        return new_val
    return lax.dynamic_update_slice_in_dim(buf, new_val, off, 0)


def _chunk_roll(buf, chunks: int, shift, reverse: bool = False):
    """Local chunk rotation (the Bruck pre/post COPY micro-ops)."""
    csize = buf.shape[0] // chunks
    grp = buf.reshape((chunks, csize) + buf.shape[1:])
    if reverse:
        grp = grp[::-1]
    grp = jnp.roll(grp, shift, axis=0)
    return grp.reshape(buf.shape)


# --------------------------------------------------------------------------
# Wire pipeline (SEG_LOOP / COMPRESS / SEND / DECOMPRESS)
# --------------------------------------------------------------------------

def _fit_segments(seg_len: int, segments) -> int:
    """Largest k <= segments that divides seg_len (>= 1); see
    `program.fit_segments` (this alias keeps the historical name used by
    the streaming fusions and tests)."""
    return fit_segments(seg_len, segments)


def _split_wire(mid_ops: tuple):
    """Split the wire micro-ops at the SEND: ([COMPRESS?, SEND], [DECOMPRESS?]).

    The send half runs at transmit time; the decompress half runs at
    *consume* time, directly feeding the combine plugin. Keeping the
    dequantize multiply adjacent to the combine add in every context —
    straight-line k=1, inside the SEG_LOOP scan body, and the pipeline
    tail — means XLA's FMA contraction fires identically everywhere, so
    segmented codec wires stay bitwise-equal to unsegmented ones (the
    per-segment scale-reuse guarantee). It also shrinks the pipeline's
    in-flight state to the compressed wire format.
    """
    for i, op in enumerate(mid_ops):
        if isinstance(op, Send):
            return mid_ops[:i + 1], mid_ops[i + 1:]
    raise ValueError("exchange without a SEND op")


def _send_axis(op: Send, axis):
    """(mesh axis, permutation) one SEND ppermutes on.

    A flat execution passes `axis` as the axis NAME and every SEND uses
    its flat-rank perm. A two-level execution passes a dict
    {"inter": outer_axis, "intra": inner_axis}: each SEND then permutes
    its level-local perm on its level's own mesh axis (a single-axis
    ppermute replicates across the orthogonal axis — exactly the
    per-pod / per-slot replication the composed schedule encodes in its
    flat perms)."""
    if isinstance(axis, dict):
        if op.level is None:
            raise ValueError(
                "flat (level=None) SEND inside a two-axis execution — "
                "only hierarchical programs run on an axis dict")
        return axis[op.level], op.level_perm
    return axis, op.perm


def _send_chain(send_ops: tuple, seg, axis, use_pallas: bool):
    """[COMPRESS?] SEND — payload in, (possibly compressed) arrival out."""
    cur = seg
    for op in send_ops:
        if isinstance(op, Compress):
            cur = plugins.get_codec(op.codec).compress(
                cur, use_pallas=use_pallas)
        elif isinstance(op, Send):
            ax, perm = _send_axis(op, axis)
            cur = jax.tree.map(
                lambda leaf, a=ax, p=perm: lax.ppermute(leaf, a, p), cur)
        else:
            raise ValueError(f"bad send op {op}")
    return cur


def _recv_chain(dec_ops: tuple, wire, shape, dtype, use_pallas: bool):
    """[DECOMPRESS?] — arrived wire format in, payload-dtype segment out."""
    cur = wire
    for op in dec_ops:
        if isinstance(op, Decompress):
            cur = plugins.get_codec(op.codec).decompress(
                cur, shape, dtype, use_pallas=use_pallas)
        else:
            raise ValueError(f"bad recv op {op}")
    return cur


def _pipelined_exchange(payload, send, consume, segments: int,
                        collect_raw: bool = False):
    """Double-buffered segmented exchange: the ACCL+ Rx-buffer pipeline.

    Splits `payload` (leading dim divisible by `segments`) into segments,
    puts segment 0 on the wire, then runs an inner lax.scan whose body
    launches segment s+1 with `send` while `consume(s, incoming_s)`
    combines/places the segment already in flight — so the wire and the
    combine plugin run concurrently, exactly the §4.4.3 Tx/Rx pipelining.

    send:    seg -> in-flight seg (the transmit chain; may be a compressed
             wire-format pytree).
    consume: (seg_index, in-flight seg) -> output seg when `collect_raw`
             is False, else (output seg, raw decompressed arrival). Must
             be jax-traceable with a traced index; decompression happens
             here so the dequantize feeds the combine directly in every
             context (see `_split_wire`).
    Returns (outputs, raw_incomings) stacked back to the full step payload;
    raw_incomings is None unless `collect_raw` (relay='received' needs the
    uncombined arrivals as the next step's payload).
    """
    k = int(segments)
    if k <= 1:
        res = consume(0, send(payload))
        return res if collect_raw else (res, None)
    pay = payload.reshape((k, payload.shape[0] // k) + payload.shape[1:])
    inflight = send(pay[0])

    def seg_body(carry, i):
        nxt = send(pay[i + 1])          # segment i+1 rides the wire ...
        out = consume(i, carry)         # ... while segment i is combined
        return nxt, out

    last, outs = lax.scan(seg_body, inflight, jnp.arange(k - 1))
    tail = consume(k - 1, last)

    def _stack(stacked, tail_leaf):
        return jnp.concatenate(
            [stacked.reshape((-1,) + stacked.shape[2:]), tail_leaf], axis=0)

    if not collect_raw:
        return _stack(outs, tail), None
    return _stack(outs[0], tail[0]), _stack(outs[1], tail[1])


# --------------------------------------------------------------------------
# The executor (the DMP): one path for every collective
# --------------------------------------------------------------------------

def _codec_block(mid_ops: tuple) -> int:
    for op in mid_ops:
        if isinstance(op, Compress):
            return plugins.get_codec(op.codec).block_elems
    return 1


def _exchange_update(body: tuple, k_req: int, buf, orig, prev, chunks: int,
                     rank, step, axis: str, use_pallas: bool):
    """Compute one exchange's region update WITHOUT writing it.

    body = (Copy('load'), [Compress], Send, [Decompress], RecvCombine).
    Returns (off, mask_idxs, new_val, raw_incoming) — the caller applies
    the write (immediately for unrolled steps, deferred to iteration end
    inside a LOOP)."""
    load, recv = body[0], body[-1]
    send_ops, dec_ops = _split_wire(body[1:-1])
    src = {"buffer": buf, "original": orig, "received": prev}[load.source]
    payload = _select(src, chunks, load.sel, rank, step)
    view, off, mask_idxs = _recv_region(buf, chunks, recv.sel, rank, step)

    k = 1
    if k_req > 1 and view.shape[0] == payload.shape[0]:
        row_elems = max(1, payload.size // max(1, payload.shape[0]))
        # per-segment scale reuse: segment boundaries never straddle a
        # codec scale block, so segmented codec wires stay bitwise equal
        # to unsegmented ones
        k = fit_segments(payload.shape[0], k_req, row_elems,
                         _codec_block(send_ops))

    comb = functools.partial(plugins.combine, recv.op,
                             use_pallas=use_pallas)
    is_dst = None
    if recv.dsts is not None:
        is_dst = jnp.any(rank == jnp.asarray(recv.dsts))

    seg_shape = ((payload.shape[0] // k,) + payload.shape[1:])
    tgt = view.reshape((k, -1) + view.shape[1:])

    def send(seg):
        return _send_chain(send_ops, seg, axis, use_pallas)

    def consume(i, wire):
        inc = _recv_chain(dec_ops, wire, seg_shape, payload.dtype,
                          use_pallas)
        out = comb(tgt[i], inc.astype(buf.dtype))
        return (out, inc) if recv.track_recv else out

    new_val, raw = _pipelined_exchange(payload, send, consume, k,
                                       collect_raw=recv.track_recv)
    new_val = new_val.reshape(view.shape)
    if raw is not None:
        raw = raw.reshape(payload.shape)
    if is_dst is not None:
        new_val = jnp.where(is_dst, new_val, view)
    return off, mask_idxs, new_val, raw


def _exec_loop(loop: Loop, buf, orig, prev, chunks: int, rank, axis: str,
               use_pallas: bool):
    """Rolled execution of a uniform step run — ONE lax.scan, one live
    buffer. Slot payloads and combine targets read the iteration-start
    buffer (region writes land at iteration end), so the slots' permutes
    carry no intra-iteration data dependency and XLA schedules them on
    independent links concurrently (the bidirectional ring)."""
    track = any(split_exchange(s)[0][-1].track_recv for s in loop.slots)
    carry0 = (buf, prev) if track else buf

    def body(carry, i):
        b, pv = carry if track else (carry, prev)
        writes = []
        new_prev = pv
        for slot, seq in enumerate(loop.slots):
            step = loop.base + i * loop.period + slot
            ops, k_req = split_exchange(seq)
            off, mask_idxs, new_val, raw = _exchange_update(
                ops, k_req, b, orig, pv, chunks, rank, step, axis,
                use_pallas)
            writes.append((off, mask_idxs, new_val))
            if raw is not None:
                new_prev = raw
        for off, mask_idxs, new_val in writes:
            b = _apply_write(b, chunks, off, mask_idxs, new_val)
        return ((b, new_prev) if track else b), None

    out, _ = lax.scan(body, carry0, jnp.arange(loop.trip))
    return out if track else (out, prev)


def _exec_stream(st: Stream, buf, orig, prev, chunks: int, nranks: int,
                 rank, axis: str, use_pallas: bool):
    """Cross-step segment streaming: ONE skewed scan over trip*k waves.

    Wave g holds segment (iteration g//k, segment g%k) in flight for every
    slot: the wave body first launches wave g+1's payloads (read from the
    pre-consume carry) and then combines wave g's arrivals — so step s+1's
    segment 0 rides the wire before step s's tail segment combines, the
    hop-to-hop pipelining SEG_LOOP's per-step scan barrier cannot reach.
    Segment g+1's payload depends at most on segment g+1-k's combine
    (k >= 2 keeps that strictly in the past), and eligible region shapes
    (see `program._stream_eligible`) make the single out-of-order tail
    send read only untouched data — the streamed program is bitwise-equal
    to its unfused form.
    """
    csize = buf.shape[0] // chunks
    parts = []
    for body in st.slots:
        load, recv = body[0], body[-1]
        send_ops, dec_ops = _split_wire(body[1:-1])
        parts.append((load, send_ops, dec_ops, recv))

    # Static segment fit — the same clamp as the unfused SEG_LOOP path,
    # applied jointly so every slot streams at one wave rate.
    k = st.segments
    pay_len = None
    for (load, send_ops, _dec, recv) in parts:
        src0 = {"buffer": buf, "original": orig, "received": prev}[
            load.source]
        pay0 = _select(src0, chunks, load.sel, rank, st.base)
        row_elems = max(1, pay0.size // max(1, pay0.shape[0]))
        k = min(k, fit_segments(pay0.shape[0], k, row_elems,
                                _codec_block(send_ops)))
        if pay_len is None:
            pay_len = pay0.shape[0]
        elif pay_len != pay0.shape[0]:
            k = 1  # slots disagree on the wave size: stream degenerates
    if k >= 2 and k != st.segments and any(
            SEL_RANGE in (b[0].sel.kind, b[-1].sel.kind)
            for b in st.slots):
        # SEL_RANGE eligibility was PROVEN at the requested segment
        # count, and the proof is k-dependent (the head segment grows as
        # k shrinks): a trace-time clamp must re-run it at the admitted
        # count — the chunk/original/received rules hold at any k >= 2
        # and need no re-proof. Range runs are period-1 by eligibility.
        load0, recv0 = st.slots[0][0], st.slots[0][-1]
        seq = [(load0.sel, recv0.sel, load0.source, st.base + i)
               for i in range(st.trip)]
        if not _regions_stream_safe(seq, k, nranks):
            k = 1  # unproven at the clamped count: drop to rolled form
    if k < 2:
        loop = Loop(base=st.base, trip=st.trip, period=st.period,
                    slots=tuple((SegLoop(st.segments, b),)
                                for b in st.slots))
        return _exec_loop(loop, buf, orig, prev, chunks, rank, axis,
                          use_pallas)
    seg_len = pay_len // k
    dtype = buf.dtype

    def send_wave(m, b, pv, i, j):
        load, send_ops, _dec, _recv = parts[m]
        src = {"buffer": b, "original": orig, "received": pv}[load.source]
        step = st.base + i * st.period + m
        region = _select(src, chunks, load.sel, rank, step)
        seg = lax.dynamic_slice_in_dim(region, j * seg_len, seg_len, 0)
        return _send_chain(send_ops, seg, axis, use_pallas)

    def consume_wave(m, b, pv, wire, i, j):
        _load, _send, dec_ops, recv = parts[m]
        step = st.base + i * st.period + m
        if recv.sel.kind == SEL_ALL:
            off = j * seg_len
        elif recv.sel.kind == SEL_CHUNK:
            off = recv.sel.fn(rank, step) * csize + j * seg_len
        else:  # SEL_RANGE (proven by _regions_stream_safe)
            off = recv.sel.fn(rank, step)[0] * csize + j * seg_len
        tgt = lax.dynamic_slice_in_dim(b, off, seg_len, 0)
        inc = _recv_chain(dec_ops, wire, (seg_len,) + b.shape[1:], dtype,
                          use_pallas)
        out = plugins.combine(recv.op, tgt, inc.astype(dtype),
                              use_pallas=use_pallas)
        b = lax.dynamic_update_slice_in_dim(b, out, off, 0)
        if recv.track_recv:
            pv = lax.dynamic_update_slice_in_dim(pv, inc, j * seg_len, 0)
        return b, pv

    nslots = len(parts)
    waves = st.trip * k
    infl0 = tuple(send_wave(m, buf, prev, 0, 0) for m in range(nslots))

    def wave(carry, g):
        b, pv, infl = carry
        i, j = g // k, g % k
        i1, j1 = (g + 1) // k, (g + 1) % k
        # launch wave g+1 from the pre-consume state, THEN combine wave g
        nxt = tuple(send_wave(m, b, pv, i1, j1) for m in range(nslots))
        for m in range(nslots):
            b, pv = consume_wave(m, b, pv, infl[m], i, j)
        return (b, pv, nxt), None

    (buf, prev, infl), _ = lax.scan(wave, (buf, prev, infl0),
                                    jnp.arange(waves - 1))
    for m in range(nslots):  # drain: the tail segment of the last step
        buf, prev = consume_wave(m, buf, prev, infl[m], st.trip - 1, k - 1)
    return buf, prev


def _chain_elem_off(sel: Sel, r, step, csize: int):
    """Element offset of a contiguous (chunk/range) selector region."""
    if sel.kind == SEL_CHUNK:
        return sel.fn(r, step) * csize
    return sel.fn(r, step)[0] * csize


def _chain_clamp_safe(plan, csize: int, nranks: int) -> bool:
    """Re-verify the region-overlap proof at the segment counts the
    payloads ACTUALLY admit (element units, per concrete rank).

    `fuse_chains` proved the chain at the requested segment count;
    `fit_segments` may have clamped a step's count down at trace time
    (indivisible payload, codec scale blocks), which changes the wave
    schedule — e.g. a clamp to k=2 re-creates the head/tail overlap the
    compile-time proof excluded. Payloads read from the immutable
    original buffer skip the read-side checks, as in the compiler pass.
    """
    try:
        for r in range(nranks):
            regions = []
            for (load, _s_ops, _d_ops, recv, pay, k) in plan:
                step = load.step
                s_off = int(_chain_elem_off(load.sel, r, step, csize))
                r_off = int(_chain_elem_off(recv.sel, r, step, csize))
                if load.source == SRC_BUFFER and _overlaps(
                        s_off, s_off + pay, r_off, r_off + pay):
                    return False
                regions.append((load.source, s_off, pay, k, r_off))
            for i in range(1, len(regions)):
                source, s_off, pay, k, _r_off = regions[i]
                if source != SRC_BUFFER:
                    continue
                _src0, _so0, pay0, k0, r_off0 = regions[i - 1]
                if _overlaps(s_off, s_off + pay // k,
                             r_off0 + pay0 - pay0 // k0, r_off0 + pay0):
                    return False
    except Exception:
        return False
    return True


def _exec_chain(ch: StreamChain, buf, orig, prev, chunks: int, nranks: int,
                rank, axis: str, use_pallas: bool):
    """Cross-step segment streaming over distinct unrolled steps: the
    wave sequence [(step, segment)] executed with a skew of one — wave
    w+1's payload goes on the wire (read from the pre-combine buffer)
    before wave w's arrival runs through the combine plugin, so step
    s+1's head segment crosses the Tx/Rx system during step s's tail
    combine. Unrolled (log-step runs are short); each step keeps its own
    admitted segment count, and if trace-time clamping invalidates the
    compile-time region proof the chain falls back to per-step SEG_LOOP
    execution — bitwise-equal either way.
    """
    csize = buf.shape[0] // chunks
    row_elems = 1
    for d in buf.shape[1:]:
        row_elems *= int(d)
    plan = []
    for body in ch.bodies:
        load, recv = body[0], body[-1]
        send_ops, dec_ops = _split_wire(body[1:-1])
        ln = 1 if load.sel.kind == SEL_CHUNK \
            else int(load.sel.fn(0, load.step)[1])
        pay = ln * csize
        k = fit_segments(pay, ch.segments, row_elems,
                         _codec_block(send_ops))
        plan.append((load, send_ops, dec_ops, recv, pay, k))

    if not _chain_clamp_safe(plan, csize, nranks):
        for body in ch.bodies:  # per-step fallback: plain SEG_LOOP order
            off, mask_idxs, new_val, _raw = _exchange_update(
                body, ch.segments, buf, orig, prev, chunks, rank,
                body[0].step, axis, use_pallas)
            buf = _apply_write(buf, chunks, off, mask_idxs, new_val)
        return buf

    dtype = buf.dtype
    waves = [(s, j) for s in range(len(plan))
             for j in range(plan[s][5])]

    def send_wave(b, s, j):
        load, send_ops, _dec, _recv, pay, k = plan[s]
        src = orig if load.source == SRC_ORIGINAL else b
        off = _chain_elem_off(load.sel, rank, load.step, csize)
        seg = lax.dynamic_slice_in_dim(src, off + j * (pay // k),
                                       pay // k, 0)
        return _send_chain(send_ops, seg, axis, use_pallas)

    def consume_wave(b, wire, s, j):
        _load, _send, dec_ops, recv, pay, k = plan[s]
        seg = pay // k
        off = _chain_elem_off(recv.sel, rank, recv.step, csize) + j * seg
        tgt = lax.dynamic_slice_in_dim(b, off, seg, 0)
        inc = _recv_chain(dec_ops, wire, (seg,) + b.shape[1:], dtype,
                          use_pallas)
        out = plugins.combine(recv.op, tgt, inc.astype(dtype),
                              use_pallas=use_pallas)
        return lax.dynamic_update_slice_in_dim(b, out, off, 0)

    inflight = send_wave(buf, *waves[0])
    for w, (s, j) in enumerate(waves):
        # launch wave w+1 from the pre-consume buffer, THEN combine w
        nxt = send_wave(buf, *waves[w + 1]) if w + 1 < len(waves) else None
        buf = consume_wave(buf, inflight, s, j)
        inflight = nxt
    return buf


def _exec_stacked(op: StackedRecv, buf, orig, chunks: int, rank, axis: str):
    """Stacked-receive peephole: issue every relay='original' permute,
    stack the arrivals, and write them back with ONE chunk scatter
    instead of a chain of full-buffer dynamic-update-slices."""
    csize = buf.shape[0] // chunks
    arrivals, idxs = [], []
    for (load, send, recv) in op.bodies:
        payload = _select(orig, chunks, load.sel, rank, load.step)
        ax, perm = _send_axis(send, axis)
        arrivals.append(lax.ppermute(payload, ax, perm))
        idxs.append(jnp.asarray(recv.sel.fn(rank, recv.step), jnp.int32))
    stacked = jnp.stack(arrivals, axis=0)
    pos = jnp.stack(idxs)
    grp = buf.reshape((chunks, csize) + buf.shape[1:])
    grp = grp.at[pos].set(stacked.astype(buf.dtype))
    return grp.reshape(buf.shape)


def execute_program(prog: Program, buf, axis, *,
                    use_pallas: bool = False):
    """Execute a compiled micro-op Program on the local shard `buf` inside
    shard_map. `buf` leading dim must be divisible by prog.chunks; returns
    the final buffer (meaning depends on the schedule's `result`).

    `axis` is the mesh axis name for flat programs, or a dict
    {"inter": outer_axis, "intra": inner_axis} for two-level hierarchical
    programs: the flat rank is then composed inner-major
    (intra_index * pod_size + pod_index, matching the schedule's rank
    map) and every SEND ppermutes its level-local perm on its level's
    own mesh axis.

    This is the single data plane: every collective the engine issues —
    whatever the algorithm, codec, or segment count — runs through here.
    """
    if buf.shape[0] % prog.chunks:
        raise ValueError(
            f"buffer leading dim {buf.shape[0]} not divisible by "
            f"{prog.chunks} chunks")
    if isinstance(axis, dict):
        sizes = dict(prog.level_sizes or ())
        if "inter" not in sizes:
            raise ValueError(
                "two-axis execution needs a hierarchical program "
                "(prog.level_sizes is unset)")
        rank = (lax.axis_index(axis["intra"]) * sizes["inter"]
                + lax.axis_index(axis["inter"]))
    else:
        rank = lax.axis_index(axis)
    ops = prog.ops
    i = 0
    if ops and isinstance(ops[0], Copy) and ops[0].kind == "bruck_pre":
        buf = _chunk_roll(buf, prog.chunks, -rank)
        i = 1
    orig = buf
    prev = buf  # relay='received': step 0 forwards the original input

    while i < len(ops):
        op = ops[i]
        if isinstance(op, Loop):
            buf, prev = _exec_loop(op, buf, orig, prev, prog.chunks, rank,
                                   axis, use_pallas)
            i += 1
        elif isinstance(op, Stream):
            buf, prev = _exec_stream(op, buf, orig, prev, prog.chunks,
                                     prog.nranks, rank, axis, use_pallas)
            i += 1
        elif isinstance(op, StreamChain):
            buf = _exec_chain(op, buf, orig, prev, prog.chunks,
                              prog.nranks, rank, axis, use_pallas)
            i += 1
        elif isinstance(op, StackedRecv):
            buf = _exec_stacked(op, buf, orig, prog.chunks, rank, axis)
            i += 1
        elif isinstance(op, Copy) and op.kind == "bruck_post":
            buf = _chunk_roll(buf, prog.chunks, rank + 1, reverse=True)
            i += 1
        elif isinstance(op, SegLoop) or (
                isinstance(op, Copy) and op.kind == "load"):
            if isinstance(op, SegLoop):
                body, k_req = op.body, op.segments
                i += 1
            else:
                j = i
                while not isinstance(ops[j], RecvCombine):
                    j += 1
                body, k_req = ops[i:j + 1], 1
                i = j + 1
            step = body[0].step
            off, mask_idxs, new_val, raw = _exchange_update(
                body, k_req, buf, orig, prev, prog.chunks, rank, step,
                axis, use_pallas)
            buf = _apply_write(buf, prog.chunks, off, mask_idxs, new_val)
            if raw is not None:
                prev = raw
        else:
            raise ValueError(f"unexpected micro-op {op}")
    return buf


# --------------------------------------------------------------------------
# Engine
# --------------------------------------------------------------------------

def _bucket_leaves(leaves, cap: int) -> list:
    """dtype-grouped, size-capped buckets over leaf indices — the ONE
    bucketing rule both `tree_allreduce` and `itree_allreduce` apply
    (grad_sync asserts the two paths bitwise-identical, so the rule must
    not fork)."""
    groups: dict = {}
    for i, leaf in enumerate(leaves):
        groups.setdefault(jnp.dtype(leaf.dtype), []).append(i)
    buckets: list[list[int]] = []
    for dtype, idxs in groups.items():
        cur, cur_bytes = [], 0
        for i in idxs:
            nbytes = leaves[i].size * dtype.itemsize
            if cur and cur_bytes + nbytes > cap:
                buckets.append(cur)
                cur, cur_bytes = [], 0
            cur.append(i)
            cur_bytes += nbytes
        if cur:
            buckets.append(cur)
    return buckets


def _fuse_bucket(leaves, idxs):
    return (leaves[idxs[0]].reshape(-1) if len(idxs) == 1
            else jnp.concatenate([leaves[i].reshape(-1) for i in idxs]))


def _scatter_bucket(leaves, idxs, buf, out) -> None:
    off = 0
    for i in idxs:
        leaf = leaves[i]
        out[i] = buf[off:off + leaf.size].reshape(leaf.shape)
        off += leaf.size


@dataclasses.dataclass
class _TreeTicket:
    """Handle for an in-flight `itree_allreduce`: the bucket requests
    sit in the engine's queue until `wait()` drains them and scatters
    the fused buffers back into the tree."""

    treedef: object
    leaves: list
    plan: list                      # [(leaf indices, Request), ...]

    def wait(self):
        out: list = [None] * len(self.leaves)
        for idxs, req in self.plan:
            _scatter_bucket(self.leaves, idxs, req.wait(), out)
        return jax.tree.unflatten(self.treedef, out)


def _flatten_pad(x, mult: int):
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % mult
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat, x.shape, x.size


def _find_generator(collective: str, algorithm: str):
    gen = GENERATORS.get((collective, algorithm))
    if gen is None:
        gen = plugins.custom_generator(collective, algorithm)
    if gen is None:
        raise KeyError(
            f"no generator for ({collective!r}, {algorithm!r}); "
            f"register one via plugins.register_collective")
    return gen


def _gen_schedule(collective: str, algorithm: str, comm,
                  root: int = 0, op: str = "add") -> Schedule:
    levels = hierarchical.parse_hier_name(algorithm) \
        if isinstance(algorithm, str) else None
    if levels is not None:
        if not isinstance(comm, ProductComm):
            raise ValueError(
                f"{algorithm!r} needs a two-axis (ProductComm) "
                f"communicator, got {comm!r}")
        intra, inter = levels
        return hierarchical.hierarchical_schedule(
            collective, comm, intra=intra, inter=inter, root=root, op=op)
    if isinstance(comm, ProductComm):
        # a flat algorithm requested over the product group: generate over
        # the equivalent flat communicator — the engine executes it
        # sequentially per axis (level_sizes stays None)
        comm = comm.flat
    gen = _find_generator(collective, algorithm)
    params = inspect.signature(gen).parameters
    kw = {}
    if "root" in params:
        kw["root"] = root
    if "op" in params:
        kw["op"] = op
    return gen(comm, **kw)


def _engine_metrics() -> telemetry.MetricsRegistry:
    reg = telemetry.MetricsRegistry()
    reg.counter("gen_calls")
    reg.counter("sched_cache_hits")
    return reg


@dataclasses.dataclass
class CollectiveEngine:
    """ACCL+ CCLO analogue over a jax mesh.

    backend: 'microcode' (our schedules — the CCLO) or 'native' (XLA
    built-ins — the software-MPI baseline role).
    """

    mesh: jax.sharding.Mesh
    backend: str = "microcode"
    hw: HwSpec = TPU_V5E
    selector: Selector = dataclasses.field(default_factory=Selector)
    use_pallas: bool = False
    # static-verifier level applied to every program this engine compiles
    # ("off" | "structural" | "full"; None = REPRO_VERIFY env default) —
    # see core/verify.py
    verify: Optional[str] = None
    # trace-time log of issued collectives (for tests / EXPERIMENTS tables)
    trace_log: list = dataclasses.field(default_factory=list)
    # trace-time schedule cache: (collective, algorithm, n, root, op) ->
    # Schedule. Repeated collectives in a training step hit this instead of
    # re-running the generator (the uC caches compiled microcode).
    _sched_cache: dict = dataclasses.field(default_factory=dict)
    # control-plane telemetry, asserted on by tests (`stats` below is
    # the read-compatible mapping view over this registry)
    metrics: telemetry.MetricsRegistry = dataclasses.field(
        default_factory=_engine_metrics)
    # lazily created request queue (core/sequencer.py) — the CCLO's
    # offload command queue behind the non-blocking `issue` API
    _queue: object = dataclasses.field(default=None, repr=False)

    # -- infrastructure ------------------------------------------------------
    def comm(self, axis):
        """Communicator for one mesh axis, or a `ProductComm` for a
        two-axis tuple (outer pod-crossing axis first)."""
        if isinstance(axis, tuple):
            outer_ax, inner_ax = axis
            return product_comm(self.mesh, outer_ax, inner_ax, self.hw)
        return axis_comm(self.mesh, axis, self.hw)

    def _axis_size(self, axis) -> int:
        if isinstance(axis, tuple):
            n = 1
            for a in axis:
                n *= self.mesh.shape[a]
            return n
        return self.mesh.shape[axis]

    def _product_rank(self, axis: tuple):
        """Flat inner-major rank inside shard_map: intra * P + pod."""
        outer_ax, inner_ax = axis
        return (lax.axis_index(inner_ax) * self.mesh.shape[outer_ax]
                + lax.axis_index(outer_ax))

    @property
    def queue(self):
        """The engine's `Sequencer` (created on first use)."""
        if self._queue is None:
            from repro.core.sequencer import Sequencer
            self._queue = Sequencer(self)
        return self._queue

    @property
    def stats(self) -> telemetry.StatsView:
        """Read-compatible mapping view over `metrics` (legacy name)."""
        return self.metrics.view()

    def _cached_schedule(self, collective: str, algorithm: str,
                         comm, root: int, op: str) -> Schedule:
        # a product communicator keys on its level split, not just the
        # flat rank count — a 4x4 product and a flat 16 must not collide
        shape = ((comm.outer.size, comm.inner.size)
                 if isinstance(comm, ProductComm) else comm.size)
        key = (collective, algorithm, shape, root, op)
        sched = self._sched_cache.get(key)
        if sched is not None:
            self.metrics.inc("sched_cache_hits")
            return sched
        self.metrics.inc("gen_calls")
        sched = _gen_schedule(collective, algorithm, comm, root, op)
        self._sched_cache[key] = sched
        return sched

    def _resolve(self, collective: str, x, axis: str, algorithm: str,
                 root: int = 0, op: str = "add",
                 segments: Optional[int] = None,
                 compression: Optional[str] = None) -> Schedule:
        """Pick algorithm + segment count; return the (cached) schedule.

        The returned schedule carries the chosen segment count in
        `.segments` (caller-supplied `segments` overrides the selector).
        `compression` feeds the selector's compressed-wire pricing: the
        beta term shrinks by the codec's wire ratio and the segment sweep
        prices compressed-segmented variants.
        """
        comm = self.comm(axis)
        if algorithm in (None, "auto"):
            # alltoall executes on the caller's 2-D leading-dim grid, so
            # the selector clamps candidate segments on rows, not the
            # flat element count (priced k == executed k)
            lead = int(x.shape[0]) if collective == "alltoall" \
                and getattr(x, "ndim", 0) else None
            choice = self.selector.choose(
                collective, x.size * x.dtype.itemsize, comm,
                codec=compression, elem_bytes=x.dtype.itemsize,
                lead_dim=lead)
            algorithm = choice.algorithm
            if segments is None:
                segments = choice.segments
            if root == 0 and op == "add":
                # the auto pick already generated exactly this schedule —
                # don't run the generator a second time
                sched = choice.schedule
            else:
                sched = self._cached_schedule(collective, algorithm, comm,
                                              root, op)
        else:
            sched = self._cached_schedule(collective, algorithm, comm,
                                          root, op)
        sched = sched.with_segments(segments if segments else 1)
        self.trace_log.append((collective, algorithm, axis,
                               int(x.size * x.dtype.itemsize)))
        return sched

    def _execute(self, sched: Schedule, buf, axis,
                 compression: Optional[str] = None):
        """Compile (memoized) and run through the one data plane."""
        prog = sched.compile(codec=compression, verify=self.verify)
        if isinstance(axis, tuple):
            outer_ax, inner_ax = axis
            axis = {"inter": outer_ax, "intra": inner_ax}
        return execute_program(prog, buf, axis, use_pallas=self.use_pallas)

    def run(self, fn, in_specs, out_specs):
        """shard_map wrapper for standalone (F2F-style) engine programs."""
        return jax.jit(shard_map(
            fn, mesh=self.mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False))

    # -- two-axis (hierarchical) dispatch ------------------------------------
    def _sequential_product(self, collective: str, x, axis: tuple, *,
                            op: str = "add", root: int = 0,
                            compression: Optional[str] = None):
        """Per-axis composition over (outer, inner): the fallback the
        engine executes when a FLAT algorithm wins the product pricing
        (or the backend is native) — one single-axis collective per
        level, each re-resolved on its own fabric."""
        outer_ax, inner_ax = axis
        P = self.mesh.shape[outer_ax]
        if collective == "allreduce":
            M = self.mesh.shape[inner_ax]
            flat, shape, size = _flatten_pad(x, M)
            shard = self.reduce_scatter(flat, inner_ax, op=op,
                                        compression=compression)
            shard = self.allreduce(shard, outer_ax, op=op,
                                   compression=compression)
            full = self.allgather(shard, inner_ax)
            return full[:size].reshape(shape)
        if collective == "reduce_scatter":
            # inner-major rank map: slice r of (RS inner -> RS outer) is
            # exactly flat slice r = intra * P + pod
            shard = self.reduce_scatter(x, inner_ax, op=op,
                                        compression=compression)
            return self.reduce_scatter(shard, outer_ax, op=op,
                                       compression=compression)
        if collective == "allgather":
            part = self.allgather(x, outer_ax)
            return self.allgather(part, inner_ax)
        if collective == "bcast":
            # inner first: after it every member of the root's pod
            # (pod index root % P) holds the data; the outer bcast then
            # fans each intra slot's copy across pods
            y = self.bcast(x, inner_ax, root=root // P)
            return self.bcast(y, outer_ax, root=root % P)
        raise ValueError(f"no two-axis composition for {collective!r}")

    def _product_collective(self, collective: str, x, axis: tuple, *,
                            op: str = "add", root: int = 0,
                            algorithm: str = "auto",
                            compression: Optional[str] = None,
                            segments: Optional[int] = None):
        """Collective over a two-axis (outer, inner) product group.

        Resolves against the `ProductComm`: a hierarchical pick executes
        as ONE two-level program (intra steps ppermute on the inner mesh
        axis, inter steps on the outer one — DCN carries 1/ici_size of
        the bytes); a flat pick executes as the sequential per-axis
        composition it was priced against. A size-1 level degenerates to
        the ordinary single-axis path.
        """
        outer_ax, inner_ax = axis

        def single(ax):
            if collective == "allreduce":
                return self.allreduce(x, ax, op=op, algorithm=algorithm,
                                      compression=compression,
                                      segments=segments)
            if collective == "reduce_scatter":
                return self.reduce_scatter(x, ax, op=op,
                                           algorithm=algorithm,
                                           compression=compression,
                                           segments=segments)
            if collective == "allgather":
                return self.allgather(x, ax, algorithm=algorithm,
                                      segments=segments)
            return self.bcast(x, ax, root=root, algorithm=algorithm,
                              segments=segments)

        if self.mesh.shape[outer_ax] == 1:
            return single(inner_ax)
        if self.mesh.shape[inner_ax] == 1:
            return single(outer_ax)
        if self.backend == "native" and algorithm in (None, "auto"):
            return self._sequential_product(collective, x, axis, op=op,
                                            root=root,
                                            compression=compression)
        if collective == "bcast" and root != 0:
            # the two-level bcast composition is root=0 only (see
            # hierarchical.hier_bcast); other roots run per axis
            return self._sequential_product("bcast", x, axis, root=root)
        sched = self._resolve(collective, x, axis, algorithm, root=root,
                              op=op, segments=segments,
                              compression=compression)
        if sched.level_sizes is None:
            return self._sequential_product(collective, x, axis, op=op,
                                            root=root,
                                            compression=compression)
        if collective == "reduce_scatter":
            if x.size % sched.chunks:
                raise ValueError(
                    f"reduce_scatter size {x.size} % {sched.chunks} != 0")
            flat = x.reshape(-1)
            out = self._execute(sched, flat, axis, compression)
            rank = self._product_rank(axis)
            csize = flat.shape[0] // sched.chunks
            own = sched.owned_chunk(rank)
            return lax.dynamic_slice_in_dim(out, own * csize, csize, 0)
        if collective == "allgather":
            n = self._axis_size(axis)
            flat = x.reshape(-1)
            rank = self._product_rank(axis)
            buf = jnp.zeros((n * flat.shape[0],), flat.dtype)
            buf = lax.dynamic_update_slice_in_dim(
                buf, flat, rank * flat.shape[0], 0)
            return self._execute(sched, buf, axis)
        # allreduce / bcast: full result, chunk-padded like the flat path
        flat, shape, size = _flatten_pad(x, sched.chunks)
        out = self._execute(sched, flat, axis, compression)
        return out[:size].reshape(shape)

    # -- MPI-like API (paper Listing 1) --------------------------------------
    def allreduce(self, x, axis, op: str = "add",
                  algorithm: str = "auto",
                  compression: Optional[str] = None,
                  segments: Optional[int] = None):
        if isinstance(axis, tuple):
            return self._product_collective(
                "allreduce", x, axis, op=op, algorithm=algorithm,
                compression=compression, segments=segments)
        n = self.mesh.shape[axis]
        if n == 1:
            return x
        if self.backend == "native" and algorithm in (None, "auto"):
            if op == "add":
                return lax.psum(x, axis)
            if op == "max":
                return lax.pmax(x, axis)
            if op == "min":
                return lax.pmin(x, axis)
        sched = self._resolve("allreduce", x, axis, algorithm, op=op,
                              segments=segments, compression=compression)
        # Padding stays a function of chunks alone so the chunk layout —
        # and hence the elementwise reduction order — is identical at
        # every segment count (uncompressed segmented lowerings are
        # bitwise-equal to unsegmented ones; compressed ones too, by the
        # scale-block alignment clamp in the executor).
        flat, shape, size = _flatten_pad(x, sched.chunks)
        out = self._execute(sched, flat, axis, compression)
        return out[:size].reshape(shape)

    def reduce_scatter(self, x, axis, op: str = "add",
                       algorithm: str = "auto",
                       compression: Optional[str] = None,
                       segments: Optional[int] = None):
        """Tiled semantics on the flattened array: rank r gets slice r of
        the reduction. Input size must be divisible by the rank count."""
        if isinstance(axis, tuple):
            return self._product_collective(
                "reduce_scatter", x, axis, op=op, algorithm=algorithm,
                compression=compression, segments=segments)
        n = self.mesh.shape[axis]
        if n == 1:
            return x
        if x.size % n:
            raise ValueError(f"reduce_scatter size {x.size} % {n} != 0")
        if self.backend == "native" and algorithm in (None, "auto"):
            return lax.psum_scatter(x.reshape(n, -1), axis,
                                    scatter_dimension=0,
                                    tiled=False).reshape(-1)
        sched = self._resolve("reduce_scatter", x, axis, algorithm, op=op,
                              segments=segments, compression=compression)
        flat = x.reshape(-1)
        out = self._execute(sched, flat, axis, compression)
        rank = lax.axis_index(axis)
        csize = flat.shape[0] // n
        own = sched.owned_chunk(rank)
        return lax.dynamic_slice_in_dim(out, own * csize, csize, 0)

    def allgather(self, x, axis, algorithm: str = "auto",
                  segments: Optional[int] = None):
        """Tiled: returns concat of every rank's flat x (own shard at
        position rank)."""
        if isinstance(axis, tuple):
            return self._product_collective(
                "allgather", x, axis, algorithm=algorithm,
                segments=segments)
        n = self.mesh.shape[axis]
        if n == 1:
            return x.reshape(-1)
        if self.backend == "native" and algorithm in (None, "auto"):
            return lax.all_gather(x.reshape(-1), axis, axis=0,
                                  tiled=True)
        sched = self._resolve("allgather", x, axis, algorithm,
                              segments=segments)
        flat = x.reshape(-1)
        rank = lax.axis_index(axis)
        buf = jnp.zeros((n * flat.shape[0],), flat.dtype)
        buf = lax.dynamic_update_slice_in_dim(
            buf, flat, rank * flat.shape[0], 0)
        return self._execute(sched, buf, axis)

    def bcast(self, x, axis, root: int = 0, algorithm: str = "auto",
              segments: Optional[int] = None):
        if isinstance(axis, tuple):
            return self._product_collective(
                "bcast", x, axis, root=root, algorithm=algorithm,
                segments=segments)
        n = self.mesh.shape[axis]
        if n == 1:
            return x
        if self.backend == "native" and algorithm in (None, "auto"):
            full = lax.all_gather(x, axis)
            return full[root]
        sched = self._resolve("bcast", x, axis, algorithm, root=root,
                              segments=segments)
        flat, shape, size = _flatten_pad(x, sched.chunks)
        out = self._execute(sched, flat, axis)
        return out[:size].reshape(shape)

    def reduce(self, x, axis: str, root: int = 0, op: str = "add",
               algorithm: str = "auto", segments: Optional[int] = None):
        """MPI semantics: result meaningful at `root` only (other ranks may
        hold partial reductions, depending on the algorithm)."""
        n = self.mesh.shape[axis]
        if n == 1:
            return x
        if self.backend == "native" and algorithm in (None, "auto"):
            return lax.psum(x, axis)
        sched = self._resolve("reduce", x, axis, algorithm, root=root,
                              op=op, segments=segments)
        flat, shape, size = _flatten_pad(x, sched.chunks)
        out = self._execute(sched, flat, axis)
        return out[:size].reshape(shape)

    def gather(self, x, axis: str, root: int = 0, algorithm: str = "auto"):
        """Root ends with concat of all ranks' flat x (others undefined)."""
        n = self.mesh.shape[axis]
        if n == 1:
            return x.reshape(-1)
        if self.backend == "native" and algorithm in (None, "auto"):
            return lax.all_gather(x.reshape(-1), axis, axis=0, tiled=True)
        sched = self._resolve("gather", x, axis, algorithm, root=root)
        flat = x.reshape(-1)
        rank = lax.axis_index(axis)
        buf = jnp.zeros((n * flat.shape[0],), flat.dtype)
        own_slot = rank if sched.chunk_coords == "absolute" else (rank - root) % n
        buf = lax.dynamic_update_slice_in_dim(
            buf, flat, own_slot * flat.shape[0], 0)
        out = self._execute(sched, buf, axis)
        if sched.chunk_coords == "relative":
            grp = out.reshape((n, flat.shape[0]))
            out = jnp.roll(grp, root, axis=0).reshape(-1)
        return out

    def alltoall(self, x, axis: str, algorithm: str = "auto",
                 segments: Optional[int] = None):
        """Tiled on leading dim: block j of the output came from rank j."""
        n = self.mesh.shape[axis]
        if n == 1:
            return x
        if x.shape[0] % n:
            raise ValueError(f"alltoall dim0 {x.shape[0]} % {n} != 0")
        if self.backend == "native" and algorithm in (None, "auto"):
            return lax.all_to_all(x, axis, split_axis=0, concat_axis=0,
                                  tiled=True)
        sched = self._resolve("alltoall", x, axis, algorithm,
                              segments=segments)
        return self._execute(sched, x, axis)

    def collective(self, name: str, x, axis: str, *,
                   algorithm: str = "auto", root: int = 0, op: str = "add",
                   compression: Optional[str] = None,
                   segments: Optional[int] = None):
        """Run a collective registered via `plugins.register_collective`.

        The paper's "new collectives without re-synthesis" path: an
        out-of-tree schedule generator lowers through the same selector,
        compiler, and `execute_program` data plane as the built-ins (see
        examples/custom_collective.py). Result convention follows the
        schedule: 'shard' returns this rank's owned chunk, anything else
        the full (trimmed) buffer.
        """
        n = self.mesh.shape[axis]
        if n == 1:
            return x
        sched = self._resolve(name, x, axis, algorithm, root=root, op=op,
                              segments=segments, compression=compression)
        if sched.result == "shard" and x.size % sched.chunks:
            # a shard result returns one raw chunk — padding would hand
            # some rank silent zeros (reduce_scatter applies the same rule)
            raise ValueError(
                f"{name} returns shards: input size {x.size} must be "
                f"divisible by {sched.chunks} chunks")
        flat, shape, size = _flatten_pad(x, sched.chunks)
        out = self._execute(sched, flat, axis, compression)
        if sched.result == "shard":
            rank = lax.axis_index(axis)
            csize = flat.shape[0] // sched.chunks
            own = sched.owned_chunk(rank)
            return lax.dynamic_slice_in_dim(out, own * csize, csize, 0)
        return out[:size].reshape(shape)

    def send_recv(self, x, axis: str, shift: int = 1):
        """Neighbour exchange along a ring (the paper's send/recv pair)."""
        comm = self.comm(axis)
        return lax.ppermute(x, axis, comm.ring_perm(shift))

    def barrier(self, axis: str):
        """1-element allreduce, like the paper's barrier collective."""
        return self.allreduce(jnp.zeros((1,), jnp.float32), axis,
                              algorithm="auto")

    def nop(self):
        """Engine invocation NOP (fig8 latency benchmark)."""
        return jnp.zeros((), jnp.int32)

    # -- non-blocking request API (the collective offload queue) -------------
    #
    # SIGNATURE CONTRACT: `CollectiveEngine.issue` / `issue_multi` are
    # thin delegates of `Sequencer.issue` / `Sequencer.issue_multi` and
    # accept the identical public call shapes — same parameter order,
    # same `after=None` / `timeout=None` keyword-only defaults (the
    # sequencer's `_pre`/`_post`/`_shape` hooks are private plumbing the
    # engine surface does not expose). The `i*` helpers fix the
    # collective name and otherwise take `issue`'s keywords. Asserted by
    # `tests/test_api_surface.py`.
    def issue(self, collective: str, x, axis: str, *, after=None,
              timeout: Optional[float] = None, **kwargs):
        """Enqueue a collective without executing it; returns a `Request`
        handle immediately (the CCLO request-queue contract — paper use
        case 1). `x` may be an array or another `Request` (a dependency
        edge: this call consumes that request's result). Materialize
        with `Request.wait()` or `engine.queue.drain()`; the queue keeps
        per-communicator FIFO order, infers conflict edges from buffer
        identity (override with `after=`), enforces `timeout` (virtual
        seconds) on the simulated drain's clock, and coalesces
        consecutive small same-(op, dtype) reductions into one bucketed
        program — see `core/sequencer.py`. Remaining keywords are those
        of the blocking method (`op`, `root`, `algorithm`,
        `compression`, `segments`).
        """
        return self.queue.issue(collective, x, axis, after=after,
                                timeout=timeout, **kwargs)

    def issue_multi(self, x, axes, op: str = "add",
                    algorithm: str = "auto",
                    compression: Optional[str] = None):
        """Non-blocking `allreduce_multi`: the hierarchical multi-axis
        allreduce as queued work (`Sequencer.issue_multi` — two live
        axes fold into one tuple-axis request; more chain RS ->
        recurse -> AG with dependency edges)."""
        return self.queue.issue_multi(x, axes, op=op, algorithm=algorithm,
                                      compression=compression)

    def iallreduce(self, x, axis: str, *, after=None,
                   timeout: Optional[float] = None, **kwargs):
        """Non-blocking `allreduce` (MPI_Iallreduce analogue)."""
        return self.issue("allreduce", x, axis, after=after,
                          timeout=timeout, **kwargs)

    def ireduce_scatter(self, x, axis: str, *, after=None,
                        timeout: Optional[float] = None, **kwargs):
        """Non-blocking `reduce_scatter`."""
        return self.issue("reduce_scatter", x, axis, after=after,
                          timeout=timeout, **kwargs)

    def iallgather(self, x, axis: str, *, after=None,
                   timeout: Optional[float] = None, **kwargs):
        """Non-blocking `allgather`."""
        return self.issue("allgather", x, axis, after=after,
                          timeout=timeout, **kwargs)

    def ibcast(self, x, axis: str, *, after=None,
               timeout: Optional[float] = None, **kwargs):
        """Non-blocking `bcast`."""
        return self.issue("bcast", x, axis, after=after,
                          timeout=timeout, **kwargs)

    def ireduce(self, x, axis: str, *, after=None,
                timeout: Optional[float] = None, **kwargs):
        """Non-blocking `reduce`."""
        return self.issue("reduce", x, axis, after=after,
                          timeout=timeout, **kwargs)

    def ialltoall(self, x, axis: str, *, after=None,
                  timeout: Optional[float] = None, **kwargs):
        """Non-blocking `alltoall`."""
        return self.issue("alltoall", x, axis, after=after,
                          timeout=timeout, **kwargs)

    def icollective(self, name: str, x, axis: str, *, after=None,
                    timeout: Optional[float] = None, **kwargs):
        """Non-blocking plugin-registered collective (`collective`)."""
        return self.issue(name, x, axis, after=after,
                          timeout=timeout, **kwargs)

    # -- hierarchical multi-axis collectives (multi-pod path) ----------------
    def allreduce_multi(self, x, axes: Sequence[str], op: str = "add",
                        algorithm: str = "auto",
                        compression: Optional[str] = None):
        """Hierarchical allreduce over several axes, fastest axis first.

        RS over axes[0] -> recurse over the rest on 1/n of the bytes -> AG
        back over axes[0]. Across pods this sends only 1/|data| of the
        gradient bytes over DCN — the multi-pod collective optimization.
        (The pod axis prices its own segment floor: see
        `HwSpec.dcn_min_segment_bytes`.)
        """
        axes = [a for a in axes if self.mesh.shape[a] > 1]
        if not axes:
            return x
        if len(axes) == 1:
            return self.allreduce(x, axes[0], op=op, algorithm=algorithm,
                                  compression=compression)
        if len(axes) == 2:
            # two-level case: ONE hierarchical program over the
            # (outer x inner) product replaces the RS/recurse/AG
            # sandwich (axes are ordered fastest first, so the slow
            # pod-crossing axis is the last one)
            return self.allreduce(x, (axes[1], axes[0]), op=op,
                                  algorithm=algorithm,
                                  compression=compression)
        n0 = self.mesh.shape[axes[0]]
        flat, shape, size = _flatten_pad(x, n0)
        shard = self.reduce_scatter(flat, axes[0], op=op,
                                    algorithm=algorithm,
                                    compression=compression)
        shard = self.allreduce_multi(shard, axes[1:], op=op,
                                     algorithm=algorithm,
                                     compression=compression)
        full = self.allgather(shard, axes[0], algorithm=algorithm)
        return full[:size].reshape(shape)

    # -- streaming API (paper Listing 2): compute fused with communication ---
    def _matmul(self, a, b, out_dtype=None):
        out_dtype = out_dtype or a.dtype
        if self.use_pallas:
            from repro.kernels import ops as kops
            return kops.matmul(a, b).astype(out_dtype)
        return jnp.dot(a, b,
                       preferred_element_type=jnp.float32).astype(out_dtype)

    def allgather_matmul(self, x, w, axis: str, segments: int = 1):
        """y = allgather(x, rows) @ w without staging the gathered buffer.

        Each ring step multiplies the resident shard while the next shard is
        on the wire — the streaming collective of Listing 2, fused with the
        MXU consumer. x: (m, k) local rows; w: (k, p); out: (n*m, p).

        With segments > 1 the shard is row-split into independent segment
        pipelines: segment j's matmul at step s+1 depends only on segment
        j's ppermute at step s, so a late segment never stalls the MXU on
        the rest of the shard.
        """
        n = self.mesh.shape[axis]
        if n == 1:
            return self._matmul(x, w)
        comm = self.comm(axis)
        rank = lax.axis_index(axis)
        m = x.shape[0]
        segs = _fit_segments(m, segments)
        out = jnp.zeros((n * m, w.shape[-1]), x.dtype)
        # resident shard kept as per-segment arrays — never concatenated,
        # so each segment's wire/compute chain stays independent
        parts = list(jnp.split(x, segs, axis=0)) if segs > 1 else [x]
        sub = m // segs
        for s in range(n):
            for j, part in enumerate(parts):
                seg_out = self._matmul(part, w)
                out = lax.dynamic_update_slice_in_dim(
                    out, seg_out, ((rank - s) % n) * m + j * sub, 0)
            if s < n - 1:
                parts = [lax.ppermute(p, axis, comm.ring_perm(1))
                         for p in parts]
        self.trace_log.append(("allgather_matmul", "ring", axis,
                               int(x.size * x.dtype.itemsize)))
        return out

    def matmul_reduce_scatter(self, x, w, axis: str, segments: int = 1):
        """Row-sharded output of (x @ w) with the partial-sum reduction
        streamed around the ring. x: (m, k_local); w: (k_local, p);
        out: (m/n, p) — rank r holds row-chunk r, fully summed.

        segments > 1 splits the rotating accumulator into independent
        row-segment pipelines (wire of segment j overlaps the adds of the
        other segments)."""
        n = self.mesh.shape[axis]
        partial = self._matmul(x, w)
        if n == 1:
            return partial
        comm = self.comm(axis)
        rank = lax.axis_index(axis)
        m = partial.shape[0]
        if m % n:
            raise ValueError(f"matmul_reduce_scatter rows {m} % {n} != 0")
        c = m // n
        segs = _fit_segments(c, segments)
        sub = c // segs
        accs = [lax.dynamic_slice_in_dim(
            partial, ((rank - 1) % n) * c + j * sub, sub, 0)
            for j in range(segs)]
        for s in range(1, n):
            accs = [lax.ppermute(a, axis, comm.ring_perm(1)) for a in accs]
            accs = [a + lax.dynamic_slice_in_dim(
                partial, ((rank - 1 - s) % n) * c + j * sub, sub, 0)
                for j, a in enumerate(accs)]
        self.trace_log.append(("matmul_reduce_scatter", "ring", axis,
                               int(partial.size * partial.dtype.itemsize)))
        return accs[0] if segs == 1 else jnp.concatenate(accs, axis=0)

    def ring_attention(self, q, k, v, axis: str, *, causal: bool = True,
                       scale: Optional[float] = None, segments: int = 1):
        """Context-parallel attention: the streaming API generalized.

        q, k, v: (B, S_local, H, hd) — the SEQUENCE is sharded over `axis`
        (heads replicated across it). KV blocks rotate around the ring
        while each rank flash-accumulates attention for its local queries:
        data streams through compute without ever materializing the
        gathered sequence (paper Listing 2, applied to attention).

        Inference/prefill form (no custom VJP). Returns (B, S_local, H, hd).
        """
        n = self.mesh.shape[axis]
        b, sl, h, hd = q.shape
        if scale is None:
            scale = 1.0 / (hd ** 0.5)
        if n == 1:
            kv = k.shape[2]
            qr = q.reshape(b, sl, kv, h // kv, hd)
            s = jnp.einsum("bqkgh,bskh->bkgqs", qr, k,
                           preferred_element_type=jnp.float32) * scale
            if causal:
                mask = jnp.tril(jnp.ones((sl, sl), bool))
                s = jnp.where(mask[None, None, None], s, -1e30)
            p = jax.nn.softmax(s, axis=-1)
            out = jnp.einsum("bkgqs,bskh->bkgqh", p.astype(v.dtype), v)
            return out.transpose(0, 3, 1, 2, 4).reshape(b, sl, h, hd)

        comm = self.comm(axis)
        rank = lax.axis_index(axis)
        kv = k.shape[2]
        g = h // kv
        qr = q.reshape(b, sl, kv, g, hd)
        q_pos = rank * sl + jnp.arange(sl)

        m0 = jnp.full((b, kv, g, sl), -1e30, jnp.float32)
        l0 = jnp.zeros((b, kv, g, sl), jnp.float32)
        a0 = jnp.zeros((b, kv, g, sl, hd), jnp.float32)

        def accumulate(carry, kv_blk, owner, seg_off=0):
            m, l, acc = carry
            kb, vb = kv_blk
            k_pos = owner * sl + seg_off + jnp.arange(kb.shape[1])
            s = jnp.einsum("bqkgh,bskh->bkgqs", qr, kb,
                           preferred_element_type=jnp.float32) * scale
            if causal:
                mask = k_pos[None, :] <= q_pos[:, None]
                s = jnp.where(mask[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(-1)
            pv = jnp.einsum("bkgqs,bskh->bkgqh", p.astype(vb.dtype), vb,
                            preferred_element_type=jnp.float32)
            return m_new, l, acc * corr[..., None] + pv

        # KV blocks rotate as independent sequence segments: segment j's
        # flash-accumulate at step s+1 depends only on segment j's
        # ppermute at step s (online softmax is exact under any block
        # split, so segmentation leaves the math unchanged).
        segs = _fit_segments(sl, segments)
        sub = sl // segs
        k_parts = list(jnp.split(k, segs, axis=1)) if segs > 1 else [k]
        v_parts = list(jnp.split(v, segs, axis=1)) if segs > 1 else [v]

        carry = (m0, l0, a0)
        for j in range(segs):
            carry = accumulate(carry, (k_parts[j], v_parts[j]), rank,
                               seg_off=j * sub)
        for step in range(1, n):
            # next block rides the wire while the current one computes
            k_parts = [lax.ppermute(p, axis, comm.ring_perm(1))
                       for p in k_parts]
            v_parts = [lax.ppermute(p, axis, comm.ring_perm(1))
                       for p in v_parts]
            owner = (rank - step) % n
            for j in range(segs):
                carry = accumulate(carry, (k_parts[j], v_parts[j]), owner,
                                   seg_off=j * sub)
        m, l, acc = carry
        out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
        self.trace_log.append(("ring_attention", "ring", axis,
                               int(k.size * k.dtype.itemsize)))
        return out.transpose(0, 3, 1, 2, 4).reshape(b, sl, h, hd)

    # -- gradient-bucket collectives (offload-engine H2H role) ---------------
    #: default gradient-bucket cap; sized so a bucket fills the segmented
    #: ring pipeline without monopolizing HBM for the fused buffer.
    BUCKET_BYTES = 4 << 20

    def tree_allreduce(self, tree, axes: Sequence[str], op: str = "add",
                       compression: Optional[str] = None,
                       algorithm: str = "auto",
                       bucket_bytes: Optional[int] = None):
        """Bucketed pytree allreduce: fused collectives over leaf groups.

        Leaves are grouped by dtype (wire bytes stay native — a bf16
        gradient ships 2 bytes/elem, no blanket fp32 upcast) and packed
        into buckets of at most `bucket_bytes` each. Concatenating leaves
        amortizes the alpha term; capping the bucket keeps several
        collectives in flight so buckets pipeline through the segmented
        rings instead of serializing behind one giant fused buffer.
        """
        leaves, treedef = jax.tree.flatten(tree)
        if not leaves:
            return tree
        cap = bucket_bytes if bucket_bytes is not None else self.BUCKET_BYTES
        out: list = [None] * len(leaves)
        for idxs in _bucket_leaves(leaves, cap):
            buf = self.allreduce_multi(_fuse_bucket(leaves, idxs), axes,
                                       op=op, algorithm=algorithm,
                                       compression=compression)
            _scatter_bucket(leaves, idxs, buf, out)
        return jax.tree.unflatten(treedef, out)

    def itree_allreduce(self, tree, axes: Sequence[str], op: str = "add",
                        compression: Optional[str] = None,
                        algorithm: str = "auto",
                        bucket_bytes: Optional[int] = None):
        """Non-blocking `tree_allreduce`: every bucket's hierarchical
        allreduce is ISSUED into the request queue up front and a ticket
        is returned; `ticket.wait()` drains the requests and rebuilds
        the tree. Because a caller can collect several tickets before
        waiting any (the trainer's gradient sync does exactly this), all
        buckets across all calls sit in the queue together — small
        same-dtype buckets coalesce into one program and the makespan
        model prices their drain as one overlapped queue instead of a
        blocking sequence."""
        leaves, treedef = jax.tree.flatten(tree)
        if not leaves:
            return _TreeTicket(treedef=treedef, leaves=[], plan=[])
        cap = bucket_bytes if bucket_bytes is not None else self.BUCKET_BYTES
        plan = []
        for idxs in _bucket_leaves(leaves, cap):
            req = self.queue.issue_multi(_fuse_bucket(leaves, idxs), axes,
                                         op=op, algorithm=algorithm,
                                         compression=compression)
            plan.append((idxs, req))
        return _TreeTicket(treedef=treedef, leaves=leaves, plan=plan)
