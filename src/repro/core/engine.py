"""CollectiveEngine — the CCLO: executes microcode schedules on a TPU mesh.

Mirrors the ACCL+ hardware split (§4.4):

  control plane  = Python at trace time: the selector picks an algorithm,
                   the generator emits a Schedule (microcode), this module
                   interprets it — the uC + DMP.
  data plane     = the lowered XLA program: `collective-permute` ops (Tx/Rx
                   systems), dynamic slices (RxBuf manager placement),
                   combine ops / codecs (streaming plugins).

All MPI-like methods are called *inside* a `shard_map` region (the engine's
H2H role inside train/serve steps) or via `run()` which wraps one for
standalone use (the F2F role). `backend='native'` lowers to XLA's built-in
collectives instead — the "software MPI" baseline of the paper's figures.
"""
from __future__ import annotations

import dataclasses
import functools
import inspect
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core.compat import shard_map

from repro.core import plugins
from repro.core.algorithms import GENERATORS
from repro.core.schedule import (
    SEL_ALL, SEL_CHUNK, SEL_MASK, SEL_RANGE, Schedule, Sel,
)
from repro.core.selector import Selector
from repro.core.topology import Communicator, axis_comm
from repro.core.hw_spec import HwSpec, TPU_V5E


# --------------------------------------------------------------------------
# Schedule interpreter (the DMP)
# --------------------------------------------------------------------------

def _select(buf, chunks: int, sel: Sel, rank, s_idx: int):
    csize = buf.shape[0] // chunks
    if sel.kind == SEL_ALL:
        return buf
    if sel.kind == SEL_CHUNK:
        idx = sel.fn(rank, s_idx)
        return lax.dynamic_slice_in_dim(buf, idx * csize, csize, 0)
    if sel.kind == SEL_RANGE:
        off, length = sel.fn(rank, s_idx)
        return lax.dynamic_slice_in_dim(buf, off * csize, int(length) * csize, 0)
    if sel.kind == SEL_MASK:
        idxs = sel.fn(rank, s_idx)
        return jnp.concatenate(
            [buf[j * csize:(j + 1) * csize] for j in idxs], axis=0)
    raise ValueError(sel.kind)


def _place(buf, chunks: int, sel: Sel, rank, s_idx: int, incoming, op: str,
           is_dst, use_pallas: bool):
    csize = buf.shape[0] // chunks
    comb = functools.partial(plugins.combine, op, use_pallas=use_pallas)
    if sel.kind == SEL_ALL:
        new = comb(buf, incoming.astype(buf.dtype))
        return jnp.where(is_dst, new, buf) if is_dst is not None else new
    if sel.kind in (SEL_CHUNK, SEL_RANGE):
        if sel.kind == SEL_CHUNK:
            off, length = sel.fn(rank, s_idx), 1
        else:
            off, length = sel.fn(rank, s_idx)
        view = lax.dynamic_slice_in_dim(buf, off * csize, int(length) * csize, 0)
        new = comb(view, incoming.astype(buf.dtype))
        if is_dst is not None:
            new = jnp.where(is_dst, new, view)
        return lax.dynamic_update_slice_in_dim(buf, new, off * csize, 0)
    if sel.kind == SEL_MASK:
        idxs = sel.fn(rank, s_idx)
        for k, j in enumerate(idxs):
            view = buf[j * csize:(j + 1) * csize]
            new = comb(view, incoming[k * csize:(k + 1) * csize].astype(buf.dtype))
            if is_dst is not None:
                new = jnp.where(is_dst, new, view)
            buf = buf.at[j * csize:(j + 1) * csize].set(new)
        return buf
    raise ValueError(sel.kind)


def _recv_region(buf, chunks: int, sel: Sel, rank, s_idx: int):
    """(view, elem_offset) of the region `recv_sel` will write.

    The view is exactly `_select`'s slice (one decode path for both the
    segmented and unsegmented interpreter); elem_offset is None for
    SEL_ALL (whole buffer). SEL_MASK selectors are not contiguous regions
    and return (None, None)."""
    if sel.kind not in (SEL_ALL, SEL_CHUNK, SEL_RANGE):
        return None, None
    csize = buf.shape[0] // chunks
    if sel.kind == SEL_ALL:
        off = None
    elif sel.kind == SEL_CHUNK:
        off = sel.fn(rank, s_idx) * csize
    else:
        off = sel.fn(rank, s_idx)[0] * csize
    return _select(buf, chunks, sel, rank, s_idx), off


def interpret_schedule(schedule: Schedule, buf, axis: str, *,
                       compression: Optional[str] = None,
                       use_pallas: bool = False,
                       segments: Optional[int] = None):
    """Execute `schedule` on the local shard `buf` inside shard_map.

    `buf` leading dim must be divisible by schedule.chunks. Returns the
    final buffer (meaning depends on schedule.result).

    `segments` (default: the schedule's own knob) pipelines each step's
    wire payload through Rx-buffer-sized segments: segment s+1 is
    ppermuted while segment s runs through the combine plugin. Steps the
    segmented datapath cannot express (mask selectors, relay-of-received
    schedules, indivisible payloads) fall back to whole-payload moves.
    """
    n = schedule.nranks
    rank = lax.axis_index(axis)
    codec = plugins.get_codec(compression) if compression else None
    csize = buf.shape[0] // schedule.chunks
    k_req = schedule.segments if segments is None else int(segments)

    if schedule.pre_rotate == "bruck":
        grp = buf.reshape((schedule.chunks, csize) + buf.shape[1:])
        grp = jnp.roll(grp, -rank, axis=0)
        buf = grp.reshape(buf.shape)

    x0 = buf
    last_recv = buf  # relay='received': step 0 forwards the original input

    for s_idx, step in enumerate(schedule.steps):
        src_store = {"buffer": buf, "original": x0,
                     "received": last_recv}[schedule.relay]
        payload = _select(src_store, schedule.chunks, step.send_sel, rank, s_idx)

        is_dst = None
        if step.mask_recv:
            dsts = jnp.asarray([d for (_, d) in step.perm])
            is_dst = jnp.any(rank == dsts)

        view, off = (None, None)
        if (k_req > 1 and schedule.relay != "received"
                and step.send_sel.kind != SEL_MASK
                and step.recv_sel.kind != SEL_MASK):
            view, off = _recv_region(buf, schedule.chunks, step.recv_sel,
                                     rank, s_idx)
        k = (_fit_segments(payload.shape[0], k_req)
             if view is not None and view.shape[0] == payload.shape[0] else 1)

        if k > 1:
            # segmented datapath: pipeline wire + combine per segment
            tgt = view.reshape((k, -1) + view.shape[1:])
            comb = functools.partial(plugins.combine, step.op,
                                     use_pallas=use_pallas)

            def send(seg):
                if codec is None:
                    return lax.ppermute(seg, axis, step.perm)
                wire = codec.compress(seg, use_pallas=use_pallas)
                wire = jax.tree.map(
                    lambda leaf: lax.ppermute(leaf, axis, step.perm), wire)
                return codec.decompress(wire, seg.shape, seg.dtype,
                                        use_pallas=use_pallas)

            def consume(i, incoming):
                return comb(tgt[i], incoming.astype(buf.dtype))

            new = _pipelined_exchange(payload, send, consume, k)
            new = new.reshape(view.shape)
            if is_dst is not None:
                new = jnp.where(is_dst, new, view)
            if off is None:
                buf = new
            else:
                buf = lax.dynamic_update_slice_in_dim(buf, new, off, 0)
            continue

        if codec is not None:
            wire = codec.compress(payload, use_pallas=use_pallas)
            wire = jax.tree.map(
                lambda leaf: lax.ppermute(leaf, axis, step.perm), wire)
            incoming = codec.decompress(wire, payload.shape, payload.dtype,
                                        use_pallas=use_pallas)
        else:
            incoming = lax.ppermute(payload, axis, step.perm)

        buf = _place(buf, schedule.chunks, step.recv_sel, rank, s_idx,
                     incoming, step.op, is_dst, use_pallas)
        if schedule.relay == "received":
            last_recv = incoming

    if schedule.post_rotate == "bruck":
        grp = buf.reshape((schedule.chunks, csize) + buf.shape[1:])
        grp = jnp.roll(grp[::-1], rank + 1, axis=0)
        buf = grp.reshape(buf.shape)
    return buf


# --------------------------------------------------------------------------
# Looped ring lowerings (the memory-safe hot path)
#
# Unrolling a 16-rank ring produces 15 full-buffer dynamic-update-slice
# chains per collective; XLA's buffer assignment cannot always alias them
# and the arena explodes. Rolled lax.scan bodies keep ONE live buffer
# (loop-carried, updated in place) and are reverse-differentiable — the VJP
# of a scanned ring is another scanned ring.
# --------------------------------------------------------------------------

def _maybe_codec(compression):
    return plugins.get_codec(compression) if compression else None


def _ring_send(payload, axis, comm, codec, use_pallas, shape_dtype, shift=1):
    if codec is None:
        return lax.ppermute(payload, axis, comm.ring_perm(shift))
    wire = codec.compress(payload, use_pallas=use_pallas)
    wire = jax.tree.map(lambda l: lax.ppermute(l, axis, comm.ring_perm(shift)),
                        wire)
    return codec.decompress(wire, payload.shape, shape_dtype,
                            use_pallas=use_pallas)


def _fit_segments(seg_len: int, segments) -> int:
    """Largest k <= segments that divides seg_len (>= 1).

    Segment counts come from the selector as a preference; the data plane
    clamps to a divisor of the payload length so segments stay equal-sized
    (halving mirrors the pow2 candidate ladder)."""
    k = max(1, int(segments or 1))
    k = min(k, max(1, seg_len))
    while k > 1 and seg_len % k:
        k -= 1
    return k


def _pipelined_exchange(payload, send, consume, segments: int):
    """Double-buffered segmented exchange: the ACCL+ Rx-buffer pipeline.

    Splits `payload` (leading dim divisible by `segments`) into segments,
    puts segment 0 on the wire, then runs an inner lax.scan whose body
    launches segment s+1 with `send` while `consume(s, incoming_s)`
    combines/places the segment already in flight — so the wire and the
    combine plugin run concurrently, exactly the §4.4.3 Tx/Rx pipelining.

    send:    seg -> incoming seg (ppermute, optionally through a codec).
    consume: (seg_index, incoming seg) -> output seg (must be jax-traceable
             with a traced index).
    Returns the concatenated consumed segments, shaped like `payload`'s
    consume output stacked back to the full step payload.
    """
    k = int(segments)
    if k <= 1:
        return consume(0, send(payload))
    pay = payload.reshape((k, payload.shape[0] // k) + payload.shape[1:])
    inflight = send(pay[0])

    def seg_body(carry, i):
        nxt = send(pay[i + 1])          # segment i+1 rides the wire ...
        out = consume(i, carry)         # ... while segment i is combined
        return nxt, out

    last, outs = lax.scan(seg_body, inflight, jnp.arange(k - 1))
    tail = consume(k - 1, last)
    flat = jnp.concatenate(
        [outs.reshape((-1,) + outs.shape[2:]), tail], axis=0)
    return flat


def ring_reduce_scatter_loop(x2d, axis, comm: Communicator, op="add",
                             compression=None, use_pallas=False,
                             segments: int = 1):
    """x2d: (n, csize); returns rank's fully-reduced row (csize,).

    Canonical chunk ownership (rank r ends with row r), one scan. With
    segments > 1 each ring step's chunk is cut into Rx-buffer-sized
    segments pipelined through the wire/combine stages."""
    n = comm.size
    rank = lax.axis_index(axis)
    codec = _maybe_codec(compression)
    segs = _fit_segments(x2d.shape[1], segments)

    def body(buf, s):
        send_idx = (rank - s - 1) % n
        recv_idx = (rank - s - 2) % n
        payload = buf[send_idx]
        tgt = buf[recv_idx].reshape((segs, -1) + buf.shape[2:])

        def send(seg):
            return _ring_send(seg, axis, comm, codec, use_pallas, buf.dtype)

        def consume(i, incoming):
            return plugins.combine(op, tgt[i], incoming.astype(buf.dtype),
                                   use_pallas=use_pallas)

        new_val = _pipelined_exchange(payload, send, consume, segs)
        buf = lax.dynamic_update_index_in_dim(
            buf, new_val.reshape(buf.shape[1:]), recv_idx, 0)
        return buf, None

    buf, _ = lax.scan(body, x2d, jnp.arange(n - 1))
    return buf[rank]


def ring_allgather_loop(shard, axis, comm: Communicator, segments: int = 1):
    """shard: (csize, ...); returns (n, csize, ...) rows in rank order."""
    n = comm.size
    rank = lax.axis_index(axis)
    buf = jnp.zeros((n,) + shard.shape, shard.dtype)
    buf = lax.dynamic_update_index_in_dim(buf, shard, rank, 0)
    segs = _fit_segments(shard.shape[0] if shard.ndim else 1, segments)

    def body(buf, s):
        send_idx = (rank - s) % n
        recv_idx = (rank - s - 1) % n

        def send(seg):
            return lax.ppermute(seg, axis, comm.ring_perm(1))

        incoming = _pipelined_exchange(buf[send_idx], send,
                                       lambda i, seg: seg, segs)
        buf = lax.dynamic_update_index_in_dim(
            buf, incoming.reshape(buf.shape[1:]), recv_idx, 0)
        return buf, None

    buf, _ = lax.scan(body, buf, jnp.arange(n - 1))
    return buf


def ring_allreduce_loop(x2d, axis, comm: Communicator, op="add",
                        compression=None, use_pallas=False,
                        segments: int = 1):
    """x2d: (n, csize) -> (n, csize) fully reduced (RS loop + AG loop).

    Only the RS phase segments: the AG phase is copy-only, so cutting it
    up would add per-segment alpha with no combine work to overlap (the
    same rule Selector.admissible_segments applies to pure allgathers)."""
    shard = ring_reduce_scatter_loop(x2d, axis, comm, op, compression,
                                     use_pallas, segments=segments)
    return ring_allgather_loop(shard, axis, comm, segments=1)


def bidi_ring_allreduce_loop(x2d, axis, comm: Communicator, op="add",
                             compression=None, use_pallas=False,
                             segments: int = 1):
    """x2d: (2n, csize): rows [0,n) ride the +1 ring, [n,2n) the -1 ring.

    Both directions advance in the same scan iteration — two independent
    ppermutes per step use both ICI directions concurrently. With
    segments > 1 both directions' chunks are additionally cut into
    pipelined segments (the two directional pipelines stay independent)."""
    n = comm.size
    rank = lax.axis_index(axis)
    codec = _maybe_codec(compression)
    segs = _fit_segments(x2d.shape[1], segments)

    def _dir_new_row(buf, send_idx, recv_idx, shift, combine_op):
        """New value for `recv_idx`'s row, read entirely from the pre-step
        buffer — the two directions' exchanges stay data-independent so
        XLA schedules their ppermutes on both ICI directions concurrently.

        Copy-only exchanges (the AG phase, combine_op=None) never
        segment: there is no combine work to overlap."""
        k = segs if combine_op is not None else 1
        payload = buf[send_idx]
        tgt = buf[recv_idx].reshape((k, -1) + buf.shape[2:])
        # compression applies to the RS phase only (as in the uni ring:
        # the AG phase relays already-reduced chunks uncompressed)
        cdc = codec if combine_op is not None else None

        def send(seg):
            return _ring_send(seg, axis, comm, cdc, use_pallas, buf.dtype,
                              shift=shift)

        def consume(i, incoming):
            inc = incoming.astype(buf.dtype)
            if combine_op is None:
                return inc
            return plugins.combine(combine_op, tgt[i], inc,
                                   use_pallas=use_pallas)

        new_val = _pipelined_exchange(payload, send, consume, k)
        return new_val.reshape(buf.shape[1:])

    def rs_body(buf, s):
        cw_send, cw_recv = (rank - s - 1) % n, (rank - s - 2) % n
        ccw_send, ccw_recv = n + (rank + s + 1) % n, n + (rank + s + 2) % n
        new_c = _dir_new_row(buf, cw_send, cw_recv, 1, op)
        new_w = _dir_new_row(buf, ccw_send, ccw_recv, -1, op)
        buf = lax.dynamic_update_index_in_dim(buf, new_c, cw_recv, 0)
        buf = lax.dynamic_update_index_in_dim(buf, new_w, ccw_recv, 0)
        return buf, None

    def ag_body(buf, s):
        cw_send, cw_recv = (rank - s) % n, (rank - s - 1) % n
        ccw_send, ccw_recv = n + (rank + s) % n, n + (rank + s + 1) % n
        new_c = _dir_new_row(buf, cw_send, cw_recv, 1, None)
        new_w = _dir_new_row(buf, ccw_send, ccw_recv, -1, None)
        buf = lax.dynamic_update_index_in_dim(buf, new_c, cw_recv, 0)
        buf = lax.dynamic_update_index_in_dim(buf, new_w, ccw_recv, 0)
        return buf, None

    buf, _ = lax.scan(rs_body, x2d, jnp.arange(n - 1))
    buf, _ = lax.scan(ag_body, buf, jnp.arange(n - 1))
    return buf


def linear_alltoall_collect(x2d, axis, comm: Communicator):
    """x2d: (n, csize): row j -> rank j. No update-slice chains: receives
    stack into (n-1, csize) and one gather reorders them."""
    n = comm.size
    rank = lax.axis_index(axis)
    received = []
    for s in range(1, n):
        payload = x2d[(rank + s) % n]
        received.append(lax.ppermute(payload, axis, comm.ring_perm(s)))
    stacked = jnp.stack([x2d[rank]] + received)   # slot s = from rank r-s
    src_slot = (rank - jnp.arange(n)) % n         # out[j] = from rank j
    return jnp.take(stacked, src_slot, axis=0)


# --------------------------------------------------------------------------
# Engine
# --------------------------------------------------------------------------

def _flatten_pad(x, mult: int):
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % mult
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat, x.shape, x.size


def _gen_schedule(collective: str, algorithm: str, comm: Communicator,
                  root: int = 0, op: str = "add") -> Schedule:
    gen = GENERATORS[(collective, algorithm)]
    params = inspect.signature(gen).parameters
    kw = {}
    if "root" in params:
        kw["root"] = root
    if "op" in params:
        kw["op"] = op
    return gen(comm, **kw)


@dataclasses.dataclass
class CollectiveEngine:
    """ACCL+ CCLO analogue over a jax mesh.

    backend: 'microcode' (our schedules — the CCLO) or 'native' (XLA
    built-ins — the software-MPI baseline role).
    """

    mesh: jax.sharding.Mesh
    backend: str = "microcode"
    hw: HwSpec = TPU_V5E
    selector: Selector = dataclasses.field(default_factory=Selector)
    use_pallas: bool = False
    # trace-time log of issued collectives (for tests / EXPERIMENTS tables)
    trace_log: list = dataclasses.field(default_factory=list)
    # trace-time schedule cache: (collective, algorithm, n, root, op) ->
    # Schedule. Repeated collectives in a training step hit this instead of
    # re-running the generator (the uC caches compiled microcode).
    _sched_cache: dict = dataclasses.field(default_factory=dict)
    # control-plane telemetry, asserted on by tests
    stats: dict = dataclasses.field(
        default_factory=lambda: {"gen_calls": 0, "sched_cache_hits": 0})

    # -- infrastructure ------------------------------------------------------
    def comm(self, axis: str) -> Communicator:
        return axis_comm(self.mesh, axis, self.hw)

    def _cached_schedule(self, collective: str, algorithm: str,
                         comm: Communicator, root: int, op: str) -> Schedule:
        key = (collective, algorithm, comm.size, root, op)
        sched = self._sched_cache.get(key)
        if sched is not None:
            self.stats["sched_cache_hits"] += 1
            return sched
        self.stats["gen_calls"] += 1
        sched = _gen_schedule(collective, algorithm, comm, root, op)
        self._sched_cache[key] = sched
        return sched

    def _resolve(self, collective: str, x, axis: str, algorithm: str,
                 root: int = 0, op: str = "add",
                 segments: Optional[int] = None) -> Schedule:
        """Pick algorithm + segment count; return the (cached) schedule.

        The returned schedule carries the chosen segment count in
        `.segments` (caller-supplied `segments` overrides the selector).
        """
        comm = self.comm(axis)
        if algorithm in (None, "auto"):
            choice = self.selector.choose(
                collective, x.size * x.dtype.itemsize, comm)
            algorithm = choice.algorithm
            if segments is None:
                segments = choice.segments
            if root == 0 and op == "add":
                # the auto pick already generated exactly this schedule —
                # don't run the generator a second time
                sched = choice.schedule
            else:
                sched = self._cached_schedule(collective, algorithm, comm,
                                              root, op)
        else:
            sched = self._cached_schedule(collective, algorithm, comm,
                                          root, op)
        sched = sched.with_segments(segments if segments else 1)
        self.trace_log.append((collective, algorithm, axis,
                               int(x.size * x.dtype.itemsize)))
        return sched

    def run(self, fn, in_specs, out_specs):
        """shard_map wrapper for standalone (F2F-style) engine programs."""
        return jax.jit(shard_map(
            fn, mesh=self.mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False))

    # -- MPI-like API (paper Listing 1) --------------------------------------
    def allreduce(self, x, axis: str, op: str = "add",
                  algorithm: str = "auto",
                  compression: Optional[str] = None,
                  segments: Optional[int] = None):
        n = self.mesh.shape[axis]
        if n == 1:
            return x
        if self.backend == "native" and algorithm in (None, "auto"):
            if op == "add":
                return lax.psum(x, axis)
            if op == "max":
                return lax.pmax(x, axis)
            if op == "min":
                return lax.pmin(x, axis)
        if compression is not None and segments is None:
            # codecs quantize per wire payload, so auto-segmenting would
            # silently change numerics (per-segment scale blocks); only
            # segment compressed wires when the caller asks for it
            segments = 1
        sched = self._resolve("allreduce", x, axis, algorithm, op=op,
                              segments=segments)
        comm = self.comm(axis)
        if sched.name in ("ring", "bidi_ring"):
            # memory-safe rolled-loop lowering. Padding stays a function of
            # chunks alone so the chunk layout — and hence the elementwise
            # reduction order — is identical at every segment count
            # (uncompressed segmented lowerings are bitwise-equal to
            # unsegmented ones); the loops clamp segments to a divisor of
            # the chunk size.
            chunks = n if sched.name == "ring" else 2 * n
            flat, shape, size = _flatten_pad(x, chunks)
            x2d = flat.reshape(chunks, -1)
            fn = ring_allreduce_loop if sched.name == "ring" \
                else bidi_ring_allreduce_loop
            out = fn(x2d, axis, comm, op=op, compression=compression,
                     use_pallas=self.use_pallas, segments=sched.segments)
            return out.reshape(-1)[:size].reshape(shape)
        flat, shape, size = _flatten_pad(x, sched.chunks)
        out = interpret_schedule(sched, flat, axis, compression=compression,
                                 use_pallas=self.use_pallas)
        return out[:size].reshape(shape)

    def reduce_scatter(self, x, axis: str, op: str = "add",
                       algorithm: str = "auto",
                       compression: Optional[str] = None,
                       segments: Optional[int] = None):
        """Tiled semantics on the flattened array: rank r gets slice r of
        the reduction. Input size must be divisible by the rank count."""
        n = self.mesh.shape[axis]
        if n == 1:
            return x
        if x.size % n:
            raise ValueError(f"reduce_scatter size {x.size} % {n} != 0")
        if self.backend == "native" and algorithm in (None, "auto"):
            return lax.psum_scatter(x.reshape(n, -1), axis,
                                    scatter_dimension=0,
                                    tiled=False).reshape(-1)
        if compression is not None and segments is None:
            segments = 1  # see allreduce: codecs quantize per wire payload
        sched = self._resolve("reduce_scatter", x, axis, algorithm, op=op,
                              segments=segments)
        if sched.name == "ring":
            return ring_reduce_scatter_loop(
                x.reshape(n, -1), axis, self.comm(axis), op=op,
                compression=compression,
                use_pallas=self.use_pallas,
                segments=sched.segments).reshape(-1)
        flat = x.reshape(-1)
        out = interpret_schedule(sched, flat, axis, compression=compression,
                                 use_pallas=self.use_pallas)
        rank = lax.axis_index(axis)
        csize = flat.shape[0] // n
        own = sched.owned_chunk(rank)
        return lax.dynamic_slice_in_dim(out, own * csize, csize, 0)

    def allgather(self, x, axis: str, algorithm: str = "auto",
                  segments: Optional[int] = None):
        """Tiled: returns concat of every rank's flat x (own shard at
        position rank)."""
        n = self.mesh.shape[axis]
        if n == 1:
            return x.reshape(-1)
        if self.backend == "native" and algorithm in (None, "auto"):
            return lax.all_gather(x.reshape(-1), axis, axis=0,
                                  tiled=True)
        sched = self._resolve("allgather", x, axis, algorithm,
                              segments=segments)
        if sched.name == "ring":
            return ring_allgather_loop(
                x.reshape(-1), axis, self.comm(axis),
                segments=sched.segments).reshape(-1)
        flat = x.reshape(-1)
        rank = lax.axis_index(axis)
        buf = jnp.zeros((n * flat.shape[0],), flat.dtype)
        buf = lax.dynamic_update_slice_in_dim(
            buf, flat, rank * flat.shape[0], 0)
        out = interpret_schedule(sched, buf, axis,
                                 use_pallas=self.use_pallas)
        return out

    def bcast(self, x, axis: str, root: int = 0, algorithm: str = "auto"):
        n = self.mesh.shape[axis]
        if n == 1:
            return x
        if self.backend == "native" and algorithm in (None, "auto"):
            full = lax.all_gather(x, axis)
            return full[root]
        sched = self._resolve("bcast", x, axis, algorithm, root=root)
        flat, shape, size = _flatten_pad(x, sched.chunks)
        out = interpret_schedule(sched, flat, axis,
                                 use_pallas=self.use_pallas)
        return out[:size].reshape(shape)

    def reduce(self, x, axis: str, root: int = 0, op: str = "add",
               algorithm: str = "auto"):
        """MPI semantics: result meaningful at `root` only (other ranks may
        hold partial reductions, depending on the algorithm)."""
        n = self.mesh.shape[axis]
        if n == 1:
            return x
        if self.backend == "native" and algorithm in (None, "auto"):
            return lax.psum(x, axis)
        sched = self._resolve("reduce", x, axis, algorithm, root=root, op=op)
        flat, shape, size = _flatten_pad(x, sched.chunks)
        out = interpret_schedule(sched, flat, axis,
                                 use_pallas=self.use_pallas)
        return out[:size].reshape(shape)

    def gather(self, x, axis: str, root: int = 0, algorithm: str = "auto"):
        """Root ends with concat of all ranks' flat x (others undefined)."""
        n = self.mesh.shape[axis]
        if n == 1:
            return x.reshape(-1)
        if self.backend == "native" and algorithm in (None, "auto"):
            return lax.all_gather(x.reshape(-1), axis, axis=0, tiled=True)
        sched = self._resolve("gather", x, axis, algorithm, root=root)
        flat = x.reshape(-1)
        rank = lax.axis_index(axis)
        buf = jnp.zeros((n * flat.shape[0],), flat.dtype)
        own_slot = rank if sched.chunk_coords == "absolute" else (rank - root) % n
        buf = lax.dynamic_update_slice_in_dim(
            buf, flat, own_slot * flat.shape[0], 0)
        out = interpret_schedule(sched, buf, axis,
                                 use_pallas=self.use_pallas)
        if sched.chunk_coords == "relative":
            grp = out.reshape((n, flat.shape[0]))
            out = jnp.roll(grp, root, axis=0).reshape(-1)
        return out

    def alltoall(self, x, axis: str, algorithm: str = "auto"):
        """Tiled on leading dim: block j of the output came from rank j."""
        n = self.mesh.shape[axis]
        if n == 1:
            return x
        if x.shape[0] % n:
            raise ValueError(f"alltoall dim0 {x.shape[0]} % {n} != 0")
        if self.backend == "native" and algorithm in (None, "auto"):
            return lax.all_to_all(x, axis, split_axis=0, concat_axis=0,
                                  tiled=True)
        sched = self._resolve("alltoall", x, axis, algorithm)
        if sched.name == "linear":
            x2d = x.reshape(n, -1)
            out = linear_alltoall_collect(x2d, axis, self.comm(axis))
            return out.reshape(x.shape)
        out = interpret_schedule(sched, x, axis, use_pallas=self.use_pallas)
        return out

    def send_recv(self, x, axis: str, shift: int = 1):
        """Neighbour exchange along a ring (the paper's send/recv pair)."""
        comm = self.comm(axis)
        return lax.ppermute(x, axis, comm.ring_perm(shift))

    def barrier(self, axis: str):
        """1-element allreduce, like the paper's barrier collective."""
        return self.allreduce(jnp.zeros((1,), jnp.float32), axis,
                              algorithm="auto")

    def nop(self):
        """Engine invocation NOP (fig8 latency benchmark)."""
        return jnp.zeros((), jnp.int32)

    # -- hierarchical multi-axis collectives (multi-pod path) ----------------
    def allreduce_multi(self, x, axes: Sequence[str], op: str = "add",
                        algorithm: str = "auto",
                        compression: Optional[str] = None):
        """Hierarchical allreduce over several axes, fastest axis first.

        RS over axes[0] -> recurse over the rest on 1/n of the bytes -> AG
        back over axes[0]. Across pods this sends only 1/|data| of the
        gradient bytes over DCN — the multi-pod collective optimization.
        """
        axes = [a for a in axes if self.mesh.shape[a] > 1]
        if not axes:
            return x
        if len(axes) == 1:
            return self.allreduce(x, axes[0], op=op, algorithm=algorithm,
                                  compression=compression)
        n0 = self.mesh.shape[axes[0]]
        flat, shape, size = _flatten_pad(x, n0)
        shard = self.reduce_scatter(flat, axes[0], op=op,
                                    algorithm=algorithm,
                                    compression=compression)
        shard = self.allreduce_multi(shard, axes[1:], op=op,
                                     algorithm=algorithm,
                                     compression=compression)
        full = self.allgather(shard, axes[0], algorithm=algorithm)
        return full[:size].reshape(shape)

    # -- streaming API (paper Listing 2): compute fused with communication ---
    def _matmul(self, a, b, out_dtype=None):
        out_dtype = out_dtype or a.dtype
        if self.use_pallas:
            from repro.kernels import ops as kops
            return kops.matmul(a, b).astype(out_dtype)
        return jnp.dot(a, b,
                       preferred_element_type=jnp.float32).astype(out_dtype)

    def allgather_matmul(self, x, w, axis: str, segments: int = 1):
        """y = allgather(x, rows) @ w without staging the gathered buffer.

        Each ring step multiplies the resident shard while the next shard is
        on the wire — the streaming collective of Listing 2, fused with the
        MXU consumer. x: (m, k) local rows; w: (k, p); out: (n*m, p).

        With segments > 1 the shard is row-split into independent segment
        pipelines: segment j's matmul at step s+1 depends only on segment
        j's ppermute at step s, so a late segment never stalls the MXU on
        the rest of the shard.
        """
        n = self.mesh.shape[axis]
        if n == 1:
            return self._matmul(x, w)
        comm = self.comm(axis)
        rank = lax.axis_index(axis)
        m = x.shape[0]
        segs = _fit_segments(m, segments)
        out = jnp.zeros((n * m, w.shape[-1]), x.dtype)
        # resident shard kept as per-segment arrays — never concatenated,
        # so each segment's wire/compute chain stays independent
        parts = list(jnp.split(x, segs, axis=0)) if segs > 1 else [x]
        sub = m // segs
        for s in range(n):
            for j, part in enumerate(parts):
                seg_out = self._matmul(part, w)
                out = lax.dynamic_update_slice_in_dim(
                    out, seg_out, ((rank - s) % n) * m + j * sub, 0)
            if s < n - 1:
                parts = [lax.ppermute(p, axis, comm.ring_perm(1))
                         for p in parts]
        self.trace_log.append(("allgather_matmul", "ring", axis,
                               int(x.size * x.dtype.itemsize)))
        return out

    def matmul_reduce_scatter(self, x, w, axis: str, segments: int = 1):
        """Row-sharded output of (x @ w) with the partial-sum reduction
        streamed around the ring. x: (m, k_local); w: (k_local, p);
        out: (m/n, p) — rank r holds row-chunk r, fully summed.

        segments > 1 splits the rotating accumulator into independent
        row-segment pipelines (wire of segment j overlaps the adds of the
        other segments)."""
        n = self.mesh.shape[axis]
        partial = self._matmul(x, w)
        if n == 1:
            return partial
        comm = self.comm(axis)
        rank = lax.axis_index(axis)
        m = partial.shape[0]
        if m % n:
            raise ValueError(f"matmul_reduce_scatter rows {m} % {n} != 0")
        c = m // n
        segs = _fit_segments(c, segments)
        sub = c // segs
        accs = [lax.dynamic_slice_in_dim(
            partial, ((rank - 1) % n) * c + j * sub, sub, 0)
            for j in range(segs)]
        for s in range(1, n):
            accs = [lax.ppermute(a, axis, comm.ring_perm(1)) for a in accs]
            accs = [a + lax.dynamic_slice_in_dim(
                partial, ((rank - 1 - s) % n) * c + j * sub, sub, 0)
                for j, a in enumerate(accs)]
        self.trace_log.append(("matmul_reduce_scatter", "ring", axis,
                               int(partial.size * partial.dtype.itemsize)))
        return accs[0] if segs == 1 else jnp.concatenate(accs, axis=0)

    def ring_attention(self, q, k, v, axis: str, *, causal: bool = True,
                       scale: Optional[float] = None, segments: int = 1):
        """Context-parallel attention: the streaming API generalized.

        q, k, v: (B, S_local, H, hd) — the SEQUENCE is sharded over `axis`
        (heads replicated across it). KV blocks rotate around the ring
        while each rank flash-accumulates attention for its local queries:
        data streams through compute without ever materializing the
        gathered sequence (paper Listing 2, applied to attention).

        Inference/prefill form (no custom VJP). Returns (B, S_local, H, hd).
        """
        n = self.mesh.shape[axis]
        b, sl, h, hd = q.shape
        if scale is None:
            scale = 1.0 / (hd ** 0.5)
        if n == 1:
            kv = k.shape[2]
            qr = q.reshape(b, sl, kv, h // kv, hd)
            s = jnp.einsum("bqkgh,bskh->bkgqs", qr, k,
                           preferred_element_type=jnp.float32) * scale
            if causal:
                mask = jnp.tril(jnp.ones((sl, sl), bool))
                s = jnp.where(mask[None, None, None], s, -1e30)
            p = jax.nn.softmax(s, axis=-1)
            out = jnp.einsum("bkgqs,bskh->bkgqh", p.astype(v.dtype), v)
            return out.transpose(0, 3, 1, 2, 4).reshape(b, sl, h, hd)

        comm = self.comm(axis)
        rank = lax.axis_index(axis)
        kv = k.shape[2]
        g = h // kv
        qr = q.reshape(b, sl, kv, g, hd)
        q_pos = rank * sl + jnp.arange(sl)

        m0 = jnp.full((b, kv, g, sl), -1e30, jnp.float32)
        l0 = jnp.zeros((b, kv, g, sl), jnp.float32)
        a0 = jnp.zeros((b, kv, g, sl, hd), jnp.float32)

        def accumulate(carry, kv_blk, owner, seg_off=0):
            m, l, acc = carry
            kb, vb = kv_blk
            k_pos = owner * sl + seg_off + jnp.arange(kb.shape[1])
            s = jnp.einsum("bqkgh,bskh->bkgqs", qr, kb,
                           preferred_element_type=jnp.float32) * scale
            if causal:
                mask = k_pos[None, :] <= q_pos[:, None]
                s = jnp.where(mask[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(-1)
            pv = jnp.einsum("bkgqs,bskh->bkgqh", p.astype(vb.dtype), vb,
                            preferred_element_type=jnp.float32)
            return m_new, l, acc * corr[..., None] + pv

        # KV blocks rotate as independent sequence segments: segment j's
        # flash-accumulate at step s+1 depends only on segment j's
        # ppermute at step s (online softmax is exact under any block
        # split, so segmentation leaves the math unchanged).
        segs = _fit_segments(sl, segments)
        sub = sl // segs
        k_parts = list(jnp.split(k, segs, axis=1)) if segs > 1 else [k]
        v_parts = list(jnp.split(v, segs, axis=1)) if segs > 1 else [v]

        carry = (m0, l0, a0)
        for j in range(segs):
            carry = accumulate(carry, (k_parts[j], v_parts[j]), rank,
                               seg_off=j * sub)
        for step in range(1, n):
            # next block rides the wire while the current one computes
            k_parts = [lax.ppermute(p, axis, comm.ring_perm(1))
                       for p in k_parts]
            v_parts = [lax.ppermute(p, axis, comm.ring_perm(1))
                       for p in v_parts]
            owner = (rank - step) % n
            for j in range(segs):
                carry = accumulate(carry, (k_parts[j], v_parts[j]), owner,
                                   seg_off=j * sub)
        m, l, acc = carry
        out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
        self.trace_log.append(("ring_attention", "ring", axis,
                               int(k.size * k.dtype.itemsize)))
        return out.transpose(0, 3, 1, 2, 4).reshape(b, sl, h, hd)

    # -- gradient-bucket collectives (offload-engine H2H role) ---------------
    #: default gradient-bucket cap; sized so a bucket fills the segmented
    #: ring pipeline without monopolizing HBM for the fused buffer.
    BUCKET_BYTES = 4 << 20

    def tree_allreduce(self, tree, axes: Sequence[str], op: str = "add",
                       compression: Optional[str] = None,
                       algorithm: str = "auto",
                       bucket_bytes: Optional[int] = None):
        """Bucketed pytree allreduce: fused collectives over leaf groups.

        Leaves are grouped by dtype (wire bytes stay native — a bf16
        gradient ships 2 bytes/elem, no blanket fp32 upcast) and packed
        into buckets of at most `bucket_bytes` each. Concatenating leaves
        amortizes the alpha term; capping the bucket keeps several
        collectives in flight so buckets pipeline through the segmented
        rings instead of serializing behind one giant fused buffer.
        """
        leaves, treedef = jax.tree.flatten(tree)
        if not leaves:
            return tree
        cap = bucket_bytes if bucket_bytes is not None else self.BUCKET_BYTES

        # dtype-grouped, size-capped buckets over leaf indices
        groups: dict = {}
        for i, leaf in enumerate(leaves):
            groups.setdefault(jnp.dtype(leaf.dtype), []).append(i)
        buckets: list[list[int]] = []
        for dtype, idxs in groups.items():
            cur, cur_bytes = [], 0
            for i in idxs:
                nbytes = leaves[i].size * dtype.itemsize
                if cur and cur_bytes + nbytes > cap:
                    buckets.append(cur)
                    cur, cur_bytes = [], 0
                cur.append(i)
                cur_bytes += nbytes
            if cur:
                buckets.append(cur)

        out: list = [None] * len(leaves)
        for idxs in buckets:
            buf = (leaves[idxs[0]].reshape(-1) if len(idxs) == 1
                   else jnp.concatenate([leaves[i].reshape(-1)
                                         for i in idxs]))
            buf = self.allreduce_multi(buf, axes, op=op,
                                       algorithm=algorithm,
                                       compression=compression)
            off = 0
            for i in idxs:
                leaf = leaves[i]
                out[i] = buf[off:off + leaf.size].reshape(leaf.shape)
                off += leaf.size
        return jax.tree.unflatten(treedef, out)
