"""CollectiveEngine — the CCLO: executes microcode schedules on a TPU mesh.

Mirrors the ACCL+ hardware split (§4.4):

  control plane  = Python at trace time: the selector picks an algorithm,
                   the generator emits a Schedule (microcode), this module
                   interprets it — the uC + DMP.
  data plane     = the lowered XLA program: `collective-permute` ops (Tx/Rx
                   systems), dynamic slices (RxBuf manager placement),
                   combine ops / codecs (streaming plugins).

All MPI-like methods are called *inside* a `shard_map` region (the engine's
H2H role inside train/serve steps) or via `run()` which wraps one for
standalone use (the F2F role). `backend='native'` lowers to XLA's built-in
collectives instead — the "software MPI" baseline of the paper's figures.
"""
from __future__ import annotations

import dataclasses
import functools
import inspect
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax import lax, shard_map
from jax.sharding import PartitionSpec as P

from repro.core import plugins
from repro.core.algorithms import GENERATORS
from repro.core.schedule import (
    SEL_ALL, SEL_CHUNK, SEL_MASK, SEL_RANGE, Schedule, Sel,
)
from repro.core.selector import Selector
from repro.core.topology import Communicator, axis_comm
from repro.core.hw_spec import HwSpec, TPU_V5E


# --------------------------------------------------------------------------
# Schedule interpreter (the DMP)
# --------------------------------------------------------------------------

def _select(buf, chunks: int, sel: Sel, rank, s_idx: int):
    csize = buf.shape[0] // chunks
    if sel.kind == SEL_ALL:
        return buf
    if sel.kind == SEL_CHUNK:
        idx = sel.fn(rank, s_idx)
        return lax.dynamic_slice_in_dim(buf, idx * csize, csize, 0)
    if sel.kind == SEL_RANGE:
        off, length = sel.fn(rank, s_idx)
        return lax.dynamic_slice_in_dim(buf, off * csize, int(length) * csize, 0)
    if sel.kind == SEL_MASK:
        idxs = sel.fn(rank, s_idx)
        return jnp.concatenate(
            [buf[j * csize:(j + 1) * csize] for j in idxs], axis=0)
    raise ValueError(sel.kind)


def _place(buf, chunks: int, sel: Sel, rank, s_idx: int, incoming, op: str,
           is_dst, use_pallas: bool):
    csize = buf.shape[0] // chunks
    comb = functools.partial(plugins.combine, op, use_pallas=use_pallas)
    if sel.kind == SEL_ALL:
        new = comb(buf, incoming.astype(buf.dtype))
        return jnp.where(is_dst, new, buf) if is_dst is not None else new
    if sel.kind in (SEL_CHUNK, SEL_RANGE):
        if sel.kind == SEL_CHUNK:
            off, length = sel.fn(rank, s_idx), 1
        else:
            off, length = sel.fn(rank, s_idx)
        view = lax.dynamic_slice_in_dim(buf, off * csize, int(length) * csize, 0)
        new = comb(view, incoming.astype(buf.dtype))
        if is_dst is not None:
            new = jnp.where(is_dst, new, view)
        return lax.dynamic_update_slice_in_dim(buf, new, off * csize, 0)
    if sel.kind == SEL_MASK:
        idxs = sel.fn(rank, s_idx)
        for k, j in enumerate(idxs):
            view = buf[j * csize:(j + 1) * csize]
            new = comb(view, incoming[k * csize:(k + 1) * csize].astype(buf.dtype))
            if is_dst is not None:
                new = jnp.where(is_dst, new, view)
            buf = buf.at[j * csize:(j + 1) * csize].set(new)
        return buf
    raise ValueError(sel.kind)


def interpret_schedule(schedule: Schedule, buf, axis: str, *,
                       compression: Optional[str] = None,
                       use_pallas: bool = False):
    """Execute `schedule` on the local shard `buf` inside shard_map.

    `buf` leading dim must be divisible by schedule.chunks. Returns the
    final buffer (meaning depends on schedule.result).
    """
    n = schedule.nranks
    rank = lax.axis_index(axis)
    codec = plugins.get_codec(compression) if compression else None
    csize = buf.shape[0] // schedule.chunks

    if schedule.pre_rotate == "bruck":
        grp = buf.reshape((schedule.chunks, csize) + buf.shape[1:])
        grp = jnp.roll(grp, -rank, axis=0)
        buf = grp.reshape(buf.shape)

    x0 = buf
    last_recv = buf  # relay='received': step 0 forwards the original input

    for s_idx, step in enumerate(schedule.steps):
        src_store = {"buffer": buf, "original": x0,
                     "received": last_recv}[schedule.relay]
        payload = _select(src_store, schedule.chunks, step.send_sel, rank, s_idx)

        if codec is not None:
            wire = codec.compress(payload, use_pallas=use_pallas)
            wire = jax.tree.map(
                lambda leaf: lax.ppermute(leaf, axis, step.perm), wire)
            incoming = codec.decompress(wire, payload.shape, payload.dtype,
                                        use_pallas=use_pallas)
        else:
            incoming = lax.ppermute(payload, axis, step.perm)

        is_dst = None
        if step.mask_recv:
            dsts = jnp.asarray([d for (_, d) in step.perm])
            is_dst = jnp.any(rank == dsts)
        buf = _place(buf, schedule.chunks, step.recv_sel, rank, s_idx,
                     incoming, step.op, is_dst, use_pallas)
        if schedule.relay == "received":
            last_recv = incoming

    if schedule.post_rotate == "bruck":
        grp = buf.reshape((schedule.chunks, csize) + buf.shape[1:])
        grp = jnp.roll(grp[::-1], rank + 1, axis=0)
        buf = grp.reshape(buf.shape)
    return buf


# --------------------------------------------------------------------------
# Looped ring lowerings (the memory-safe hot path)
#
# Unrolling a 16-rank ring produces 15 full-buffer dynamic-update-slice
# chains per collective; XLA's buffer assignment cannot always alias them
# and the arena explodes. Rolled lax.scan bodies keep ONE live buffer
# (loop-carried, updated in place) and are reverse-differentiable — the VJP
# of a scanned ring is another scanned ring.
# --------------------------------------------------------------------------

def _maybe_codec(compression):
    return plugins.get_codec(compression) if compression else None


def _ring_send(payload, axis, comm, codec, use_pallas, shape_dtype):
    if codec is None:
        return lax.ppermute(payload, axis, comm.ring_perm(1))
    wire = codec.compress(payload, use_pallas=use_pallas)
    wire = jax.tree.map(lambda l: lax.ppermute(l, axis, comm.ring_perm(1)),
                        wire)
    return codec.decompress(wire, payload.shape, shape_dtype,
                            use_pallas=use_pallas)


def ring_reduce_scatter_loop(x2d, axis, comm: Communicator, op="add",
                             compression=None, use_pallas=False):
    """x2d: (n, csize); returns rank's fully-reduced row (csize,).

    Canonical chunk ownership (rank r ends with row r), one scan."""
    n = comm.size
    rank = lax.axis_index(axis)
    codec = _maybe_codec(compression)

    def body(buf, s):
        send_idx = (rank - s - 1) % n
        recv_idx = (rank - s - 2) % n
        payload = buf[send_idx]
        incoming = _ring_send(payload, axis, comm, codec, use_pallas,
                              buf.dtype)
        new_val = plugins.combine(op, buf[recv_idx],
                                  incoming.astype(buf.dtype),
                                  use_pallas=use_pallas)
        buf = lax.dynamic_update_index_in_dim(buf, new_val, recv_idx, 0)
        return buf, None

    buf, _ = lax.scan(body, x2d, jnp.arange(n - 1))
    return buf[rank]


def ring_allgather_loop(shard, axis, comm: Communicator):
    """shard: (csize, ...); returns (n, csize, ...) rows in rank order."""
    n = comm.size
    rank = lax.axis_index(axis)
    buf = jnp.zeros((n,) + shard.shape, shard.dtype)
    buf = lax.dynamic_update_index_in_dim(buf, shard, rank, 0)

    def body(buf, s):
        send_idx = (rank - s) % n
        recv_idx = (rank - s - 1) % n
        incoming = lax.ppermute(buf[send_idx], axis, comm.ring_perm(1))
        buf = lax.dynamic_update_index_in_dim(buf, incoming, recv_idx, 0)
        return buf, None

    buf, _ = lax.scan(body, buf, jnp.arange(n - 1))
    return buf


def ring_allreduce_loop(x2d, axis, comm: Communicator, op="add",
                        compression=None, use_pallas=False):
    """x2d: (n, csize) -> (n, csize) fully reduced (RS loop + AG loop)."""
    shard = ring_reduce_scatter_loop(x2d, axis, comm, op, compression,
                                     use_pallas)
    return ring_allgather_loop(shard, axis, comm)


def bidi_ring_allreduce_loop(x2d, axis, comm: Communicator, op="add",
                             compression=None, use_pallas=False):
    """x2d: (2n, csize): rows [0,n) ride the +1 ring, [n,2n) the -1 ring.

    Both directions advance in the same scan iteration — two independent
    ppermutes per step use both ICI directions concurrently."""
    n = comm.size
    rank = lax.axis_index(axis)
    codec = _maybe_codec(compression)

    def rs_body(buf, s):
        cw_send, cw_recv = (rank - s - 1) % n, (rank - s - 2) % n
        ccw_send, ccw_recv = n + (rank + s + 1) % n, n + (rank + s + 2) % n
        pc = buf[cw_send]
        pw = buf[ccw_send]
        if codec is None:
            inc_c = lax.ppermute(pc, axis, comm.ring_perm(1))
            inc_w = lax.ppermute(pw, axis, comm.ring_perm(-1))
        else:
            wc = codec.compress(pc, use_pallas=use_pallas)
            ww = codec.compress(pw, use_pallas=use_pallas)
            wc = jax.tree.map(
                lambda l: lax.ppermute(l, axis, comm.ring_perm(1)), wc)
            ww = jax.tree.map(
                lambda l: lax.ppermute(l, axis, comm.ring_perm(-1)), ww)
            inc_c = codec.decompress(wc, pc.shape, buf.dtype,
                                     use_pallas=use_pallas)
            inc_w = codec.decompress(ww, pw.shape, buf.dtype,
                                     use_pallas=use_pallas)
        buf = lax.dynamic_update_index_in_dim(
            buf, plugins.combine(op, buf[cw_recv], inc_c.astype(buf.dtype)),
            cw_recv, 0)
        buf = lax.dynamic_update_index_in_dim(
            buf, plugins.combine(op, buf[ccw_recv], inc_w.astype(buf.dtype)),
            ccw_recv, 0)
        return buf, None

    def ag_body(buf, s):
        cw_send, cw_recv = (rank - s) % n, (rank - s - 1) % n
        ccw_send, ccw_recv = n + (rank + s) % n, n + (rank + s + 1) % n
        inc_c = lax.ppermute(buf[cw_send], axis, comm.ring_perm(1))
        inc_w = lax.ppermute(buf[ccw_send], axis, comm.ring_perm(-1))
        buf = lax.dynamic_update_index_in_dim(buf, inc_c, cw_recv, 0)
        buf = lax.dynamic_update_index_in_dim(buf, inc_w, ccw_recv, 0)
        return buf, None

    buf, _ = lax.scan(rs_body, x2d, jnp.arange(n - 1))
    buf, _ = lax.scan(ag_body, buf, jnp.arange(n - 1))
    return buf


def linear_alltoall_collect(x2d, axis, comm: Communicator):
    """x2d: (n, csize): row j -> rank j. No update-slice chains: receives
    stack into (n-1, csize) and one gather reorders them."""
    n = comm.size
    rank = lax.axis_index(axis)
    received = []
    for s in range(1, n):
        payload = x2d[(rank + s) % n]
        received.append(lax.ppermute(payload, axis, comm.ring_perm(s)))
    stacked = jnp.stack([x2d[rank]] + received)   # slot s = from rank r-s
    src_slot = (rank - jnp.arange(n)) % n         # out[j] = from rank j
    return jnp.take(stacked, src_slot, axis=0)


# --------------------------------------------------------------------------
# Engine
# --------------------------------------------------------------------------

def _flatten_pad(x, mult: int):
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % mult
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat, x.shape, x.size


def _gen_schedule(collective: str, algorithm: str, comm: Communicator,
                  root: int = 0, op: str = "add") -> Schedule:
    gen = GENERATORS[(collective, algorithm)]
    params = inspect.signature(gen).parameters
    kw = {}
    if "root" in params:
        kw["root"] = root
    if "op" in params:
        kw["op"] = op
    return gen(comm, **kw)


@dataclasses.dataclass
class CollectiveEngine:
    """ACCL+ CCLO analogue over a jax mesh.

    backend: 'microcode' (our schedules — the CCLO) or 'native' (XLA
    built-ins — the software-MPI baseline role).
    """

    mesh: jax.sharding.Mesh
    backend: str = "microcode"
    hw: HwSpec = TPU_V5E
    selector: Selector = dataclasses.field(default_factory=Selector)
    use_pallas: bool = False
    # trace-time log of issued collectives (for tests / EXPERIMENTS tables)
    trace_log: list = dataclasses.field(default_factory=list)

    # -- infrastructure ------------------------------------------------------
    def comm(self, axis: str) -> Communicator:
        return axis_comm(self.mesh, axis, self.hw)

    def _resolve(self, collective: str, x, axis: str, algorithm: str,
                 root: int = 0, op: str = "add") -> Schedule:
        comm = self.comm(axis)
        if algorithm in (None, "auto"):
            choice = self.selector.choose(
                collective, x.size * x.dtype.itemsize, comm)
            sched = choice.schedule
            # regenerate with root/op if the auto pick ignored them
            sched = _gen_schedule(collective, choice.algorithm, comm, root, op)
            algorithm = choice.algorithm
        else:
            sched = _gen_schedule(collective, algorithm, comm, root, op)
        self.trace_log.append((collective, algorithm, axis,
                               int(x.size * x.dtype.itemsize)))
        return sched

    def run(self, fn, in_specs, out_specs):
        """shard_map wrapper for standalone (F2F-style) engine programs."""
        return jax.jit(shard_map(
            fn, mesh=self.mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False))

    # -- MPI-like API (paper Listing 1) --------------------------------------
    def allreduce(self, x, axis: str, op: str = "add",
                  algorithm: str = "auto",
                  compression: Optional[str] = None):
        n = self.mesh.shape[axis]
        if n == 1:
            return x
        if self.backend == "native" and algorithm in (None, "auto"):
            if op == "add":
                return lax.psum(x, axis)
            if op == "max":
                return lax.pmax(x, axis)
            if op == "min":
                return lax.pmin(x, axis)
        sched = self._resolve("allreduce", x, axis, algorithm, op=op)
        comm = self.comm(axis)
        if sched.name in ("ring", "bidi_ring"):
            # memory-safe rolled-loop lowering
            chunks = n if sched.name == "ring" else 2 * n
            flat, shape, size = _flatten_pad(x, chunks)
            x2d = flat.reshape(chunks, -1)
            fn = ring_allreduce_loop if sched.name == "ring" \
                else bidi_ring_allreduce_loop
            out = fn(x2d, axis, comm, op=op, compression=compression,
                     use_pallas=self.use_pallas)
            return out.reshape(-1)[:size].reshape(shape)
        flat, shape, size = _flatten_pad(x, sched.chunks)
        out = interpret_schedule(sched, flat, axis, compression=compression,
                                 use_pallas=self.use_pallas)
        return out[:size].reshape(shape)

    def reduce_scatter(self, x, axis: str, op: str = "add",
                       algorithm: str = "auto",
                       compression: Optional[str] = None):
        """Tiled semantics on the flattened array: rank r gets slice r of
        the reduction. Input size must be divisible by the rank count."""
        n = self.mesh.shape[axis]
        if n == 1:
            return x
        if x.size % n:
            raise ValueError(f"reduce_scatter size {x.size} % {n} != 0")
        if self.backend == "native" and algorithm in (None, "auto"):
            return lax.psum_scatter(x.reshape(n, -1), axis,
                                    scatter_dimension=0,
                                    tiled=False).reshape(-1)
        sched = self._resolve("reduce_scatter", x, axis, algorithm, op=op)
        if sched.name == "ring":
            return ring_reduce_scatter_loop(
                x.reshape(n, -1), axis, self.comm(axis), op=op,
                compression=compression,
                use_pallas=self.use_pallas).reshape(-1)
        flat = x.reshape(-1)
        out = interpret_schedule(sched, flat, axis, compression=compression,
                                 use_pallas=self.use_pallas)
        rank = lax.axis_index(axis)
        csize = flat.shape[0] // n
        own = sched.owned_chunk(rank)
        return lax.dynamic_slice_in_dim(out, own * csize, csize, 0)

    def allgather(self, x, axis: str, algorithm: str = "auto"):
        """Tiled: returns concat of every rank's flat x (own shard at
        position rank)."""
        n = self.mesh.shape[axis]
        if n == 1:
            return x.reshape(-1)
        if self.backend == "native" and algorithm in (None, "auto"):
            return lax.all_gather(x.reshape(-1), axis, axis=0,
                                  tiled=True)
        sched = self._resolve("allgather", x, axis, algorithm)
        if sched.name == "ring":
            return ring_allgather_loop(x.reshape(-1), axis,
                                       self.comm(axis)).reshape(-1)
        flat = x.reshape(-1)
        rank = lax.axis_index(axis)
        buf = jnp.zeros((n * flat.shape[0],), flat.dtype)
        buf = lax.dynamic_update_slice_in_dim(
            buf, flat, rank * flat.shape[0], 0)
        out = interpret_schedule(sched, buf, axis,
                                 use_pallas=self.use_pallas)
        return out

    def bcast(self, x, axis: str, root: int = 0, algorithm: str = "auto"):
        n = self.mesh.shape[axis]
        if n == 1:
            return x
        if self.backend == "native" and algorithm in (None, "auto"):
            full = lax.all_gather(x, axis)
            return full[root]
        sched = self._resolve("bcast", x, axis, algorithm, root=root)
        flat, shape, size = _flatten_pad(x, sched.chunks)
        out = interpret_schedule(sched, flat, axis,
                                 use_pallas=self.use_pallas)
        return out[:size].reshape(shape)

    def reduce(self, x, axis: str, root: int = 0, op: str = "add",
               algorithm: str = "auto"):
        """MPI semantics: result meaningful at `root` only (other ranks may
        hold partial reductions, depending on the algorithm)."""
        n = self.mesh.shape[axis]
        if n == 1:
            return x
        if self.backend == "native" and algorithm in (None, "auto"):
            return lax.psum(x, axis)
        sched = self._resolve("reduce", x, axis, algorithm, root=root, op=op)
        flat, shape, size = _flatten_pad(x, sched.chunks)
        out = interpret_schedule(sched, flat, axis,
                                 use_pallas=self.use_pallas)
        return out[:size].reshape(shape)

    def gather(self, x, axis: str, root: int = 0, algorithm: str = "auto"):
        """Root ends with concat of all ranks' flat x (others undefined)."""
        n = self.mesh.shape[axis]
        if n == 1:
            return x.reshape(-1)
        if self.backend == "native" and algorithm in (None, "auto"):
            return lax.all_gather(x.reshape(-1), axis, axis=0, tiled=True)
        sched = self._resolve("gather", x, axis, algorithm, root=root)
        flat = x.reshape(-1)
        rank = lax.axis_index(axis)
        buf = jnp.zeros((n * flat.shape[0],), flat.dtype)
        own_slot = rank if sched.chunk_coords == "absolute" else (rank - root) % n
        buf = lax.dynamic_update_slice_in_dim(
            buf, flat, own_slot * flat.shape[0], 0)
        out = interpret_schedule(sched, buf, axis,
                                 use_pallas=self.use_pallas)
        if sched.chunk_coords == "relative":
            grp = out.reshape((n, flat.shape[0]))
            out = jnp.roll(grp, root, axis=0).reshape(-1)
        return out

    def alltoall(self, x, axis: str, algorithm: str = "auto"):
        """Tiled on leading dim: block j of the output came from rank j."""
        n = self.mesh.shape[axis]
        if n == 1:
            return x
        if x.shape[0] % n:
            raise ValueError(f"alltoall dim0 {x.shape[0]} % {n} != 0")
        if self.backend == "native" and algorithm in (None, "auto"):
            return lax.all_to_all(x, axis, split_axis=0, concat_axis=0,
                                  tiled=True)
        sched = self._resolve("alltoall", x, axis, algorithm)
        if sched.name == "linear":
            x2d = x.reshape(n, -1)
            out = linear_alltoall_collect(x2d, axis, self.comm(axis))
            return out.reshape(x.shape)
        out = interpret_schedule(sched, x, axis, use_pallas=self.use_pallas)
        return out

    def send_recv(self, x, axis: str, shift: int = 1):
        """Neighbour exchange along a ring (the paper's send/recv pair)."""
        comm = self.comm(axis)
        return lax.ppermute(x, axis, comm.ring_perm(shift))

    def barrier(self, axis: str):
        """1-element allreduce, like the paper's barrier collective."""
        return self.allreduce(jnp.zeros((1,), jnp.float32), axis,
                              algorithm="auto")

    def nop(self):
        """Engine invocation NOP (fig8 latency benchmark)."""
        return jnp.zeros((), jnp.int32)

    # -- hierarchical multi-axis collectives (multi-pod path) ----------------
    def allreduce_multi(self, x, axes: Sequence[str], op: str = "add",
                        algorithm: str = "auto",
                        compression: Optional[str] = None):
        """Hierarchical allreduce over several axes, fastest axis first.

        RS over axes[0] -> recurse over the rest on 1/n of the bytes -> AG
        back over axes[0]. Across pods this sends only 1/|data| of the
        gradient bytes over DCN — the multi-pod collective optimization.
        """
        axes = [a for a in axes if self.mesh.shape[a] > 1]
        if not axes:
            return x
        if len(axes) == 1:
            return self.allreduce(x, axes[0], op=op, algorithm=algorithm,
                                  compression=compression)
        n0 = self.mesh.shape[axes[0]]
        flat, shape, size = _flatten_pad(x, n0)
        shard = self.reduce_scatter(flat, axes[0], op=op,
                                    algorithm=algorithm,
                                    compression=compression)
        shard = self.allreduce_multi(shard, axes[1:], op=op,
                                     algorithm=algorithm,
                                     compression=compression)
        full = self.allgather(shard, axes[0], algorithm=algorithm)
        return full[:size].reshape(shape)

    # -- streaming API (paper Listing 2): compute fused with communication ---
    def _matmul(self, a, b, out_dtype=None):
        out_dtype = out_dtype or a.dtype
        if self.use_pallas:
            from repro.kernels import ops as kops
            return kops.matmul(a, b).astype(out_dtype)
        return jnp.dot(a, b,
                       preferred_element_type=jnp.float32).astype(out_dtype)

    def allgather_matmul(self, x, w, axis: str):
        """y = allgather(x, rows) @ w without staging the gathered buffer.

        Each ring step multiplies the resident shard while the next shard is
        on the wire — the streaming collective of Listing 2, fused with the
        MXU consumer. x: (m, k) local rows; w: (k, p); out: (n*m, p).
        """
        n = self.mesh.shape[axis]
        if n == 1:
            return self._matmul(x, w)
        comm = self.comm(axis)
        rank = lax.axis_index(axis)
        m = x.shape[0]
        out = jnp.zeros((n * m, w.shape[-1]), x.dtype)
        cur = x
        for s in range(n):
            seg = self._matmul(cur, w)
            out = lax.dynamic_update_slice_in_dim(
                out, seg, ((rank - s) % n) * m, 0)
            if s < n - 1:
                cur = lax.ppermute(cur, axis, comm.ring_perm(1))
        self.trace_log.append(("allgather_matmul", "ring", axis,
                               int(x.size * x.dtype.itemsize)))
        return out

    def matmul_reduce_scatter(self, x, w, axis: str):
        """Row-sharded output of (x @ w) with the partial-sum reduction
        streamed around the ring. x: (m, k_local); w: (k_local, p);
        out: (m/n, p) — rank r holds row-chunk r, fully summed."""
        n = self.mesh.shape[axis]
        partial = self._matmul(x, w)
        if n == 1:
            return partial
        comm = self.comm(axis)
        rank = lax.axis_index(axis)
        m = partial.shape[0]
        if m % n:
            raise ValueError(f"matmul_reduce_scatter rows {m} % {n} != 0")
        c = m // n
        acc = lax.dynamic_slice_in_dim(partial, ((rank - 1) % n) * c, c, 0)
        for s in range(1, n):
            acc = lax.ppermute(acc, axis, comm.ring_perm(1))
            acc = acc + lax.dynamic_slice_in_dim(
                partial, ((rank - 1 - s) % n) * c, c, 0)
        self.trace_log.append(("matmul_reduce_scatter", "ring", axis,
                               int(partial.size * partial.dtype.itemsize)))
        return acc

    def ring_attention(self, q, k, v, axis: str, *, causal: bool = True,
                       scale: Optional[float] = None):
        """Context-parallel attention: the streaming API generalized.

        q, k, v: (B, S_local, H, hd) — the SEQUENCE is sharded over `axis`
        (heads replicated across it). KV blocks rotate around the ring
        while each rank flash-accumulates attention for its local queries:
        data streams through compute without ever materializing the
        gathered sequence (paper Listing 2, applied to attention).

        Inference/prefill form (no custom VJP). Returns (B, S_local, H, hd).
        """
        n = self.mesh.shape[axis]
        b, sl, h, hd = q.shape
        if scale is None:
            scale = 1.0 / (hd ** 0.5)
        if n == 1:
            kv = k.shape[2]
            qr = q.reshape(b, sl, kv, h // kv, hd)
            s = jnp.einsum("bqkgh,bskh->bkgqs", qr, k,
                           preferred_element_type=jnp.float32) * scale
            if causal:
                mask = jnp.tril(jnp.ones((sl, sl), bool))
                s = jnp.where(mask[None, None, None], s, -1e30)
            p = jax.nn.softmax(s, axis=-1)
            out = jnp.einsum("bkgqs,bskh->bkgqh", p.astype(v.dtype), v)
            return out.transpose(0, 3, 1, 2, 4).reshape(b, sl, h, hd)

        comm = self.comm(axis)
        rank = lax.axis_index(axis)
        kv = k.shape[2]
        g = h // kv
        qr = q.reshape(b, sl, kv, g, hd)
        q_pos = rank * sl + jnp.arange(sl)

        m0 = jnp.full((b, kv, g, sl), -1e30, jnp.float32)
        l0 = jnp.zeros((b, kv, g, sl), jnp.float32)
        a0 = jnp.zeros((b, kv, g, sl, hd), jnp.float32)

        def accumulate(carry, kv_blk, owner):
            m, l, acc = carry
            kb, vb = kv_blk
            k_pos = owner * sl + jnp.arange(sl)
            s = jnp.einsum("bqkgh,bskh->bkgqs", qr, kb,
                           preferred_element_type=jnp.float32) * scale
            if causal:
                mask = k_pos[None, :] <= q_pos[:, None]
                s = jnp.where(mask[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(-1)
            pv = jnp.einsum("bkgqs,bskh->bkgqh", p.astype(vb.dtype), vb,
                            preferred_element_type=jnp.float32)
            return m_new, l, acc * corr[..., None] + pv

        carry = accumulate((m0, l0, a0), (k, v), rank)
        cur_k, cur_v = k, v
        for step in range(1, n):
            # next block is on the wire while the current one computes
            cur_k = lax.ppermute(cur_k, axis, comm.ring_perm(1))
            cur_v = lax.ppermute(cur_v, axis, comm.ring_perm(1))
            owner = (rank - step) % n
            carry = accumulate(carry, (cur_k, cur_v), owner)
        m, l, acc = carry
        out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
        self.trace_log.append(("ring_attention", "ring", axis,
                               int(k.size * k.dtype.itemsize)))
        return out.transpose(0, 3, 1, 2, 4).reshape(b, sl, h, hd)

    # -- gradient-bucket collectives (offload-engine H2H role) ---------------
    def tree_allreduce(self, tree, axes: Sequence[str], op: str = "add",
                       compression: Optional[str] = None,
                       algorithm: str = "auto"):
        """Bucketed pytree allreduce: one fused collective for all leaves.

        Flattening every gradient into a single buffer amortizes the alpha
        term across the whole pytree (gradient bucketing).
        """
        leaves, treedef = jax.tree.flatten(tree)
        if not leaves:
            return tree
        sizes = [l.size for l in leaves]
        shapes = [l.shape for l in leaves]
        dtypes = [l.dtype for l in leaves]
        buf = jnp.concatenate([l.reshape(-1).astype(jnp.float32)
                               for l in leaves])
        buf = self.allreduce_multi(buf, axes, op=op, algorithm=algorithm,
                                   compression=compression)
        outs, off = [], 0
        for size, shape, dtype in zip(sizes, shapes, dtypes):
            outs.append(buf[off:off + size].reshape(shape).astype(dtype))
            off += size
        return jax.tree.unflatten(treedef, outs)
