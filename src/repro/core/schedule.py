"""Microcode schedule IR — the ACCL+ DMP instruction stream, as data.

In ACCL+ a collective algorithm lives in uC *firmware*: the uC emits
microcode instructions to the Data Movement Processor, each with two operand
slots (data into the CCLO: from memory / from network) and one result slot
(data out: to memory / to network / through an arithmetic plugin).

Here a collective algorithm is a `Schedule`: an ordered list of `Step`s.
Each step is one DMP instruction burst across all ranks:

  operand slot 0  = the local chunk selected by `send_sel`   (memory -> engine)
  operand slot 1  = the chunk arriving over `perm`           (network -> engine)
  plugin          = `op` (copy/add/max/min/mul, or compressed variants)
  result slot     = `recv_sel` placement back into the local buffer

Because the selection must be SPMD-uniform code but rank-dependent data,
selectors are tiny closures `(rank_tracer, step_index) -> chunk index` (or
`(offset, length)` ranges) evaluated on the traced `lax.axis_index` value.
The schedule itself — permutation pairs, op, byte volumes — is plain data,
inspectable and costable without tracing anything. That is the property the
paper gets from firmware: the algorithm can be swapped without touching the
datapath (here: without touching model code).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

# Combine ops the arithmetic plugin supports (binary streaming plugins).
COMBINE_OPS = ("copy", "add", "max", "min", "mul")

# Selector kinds.
SEL_CHUNK = "chunk"   # fn(rank, step) -> chunk index (single chunk of n)
SEL_RANGE = "range"   # fn(rank, step) -> (chunk_offset, n_chunks)
SEL_MASK = "mask"     # fn(rank, step) -> static tuple of chunk indices
SEL_ALL = "all"       # whole buffer


@dataclasses.dataclass(frozen=True)
class Sel:
    """Chunk selector: which slice of the local buffer a slot touches."""

    kind: str
    fn: Optional[Callable] = None  # (rank, step) -> idx | (off, len) | mask

    @staticmethod
    def all() -> "Sel":
        return Sel(SEL_ALL)

    @staticmethod
    def chunk(fn: Callable) -> "Sel":
        return Sel(SEL_CHUNK, fn)

    @staticmethod
    def range(fn: Callable) -> "Sel":
        return Sel(SEL_RANGE, fn)

    @staticmethod
    def mask(fn: Callable) -> "Sel":
        return Sel(SEL_MASK, fn)


@dataclasses.dataclass(frozen=True)
class Step:
    """One DMP instruction burst (all ranks move in parallel).

    perm:      (src, dst) pairs executed as one collective-permute.
    op:        arithmetic-plugin combine applied at the receiver.
    send_sel:  operand slot 0 — what each rank puts on the wire.
    recv_sel:  result slot   — where the arriving chunk lands locally.
    bytes_frac: fraction of the full buffer this step moves per rank
               (for the alpha-beta cost model; 1/n for chunked rings).
    mask_recv: if True, ranks not appearing as a dst keep their old data
               (ppermute delivers zeros to non-destinations; trees need
               the mask, rings where everyone receives do not).
    uniform:   the selector closures are pure arithmetic in
               (rank, step_index) — valid under a *traced* step index —
               and shared (by object identity) across the run of equal
               steps. The IR compiler rolls such runs into a LOOP micro-op
               (one lax.scan) instead of unrolling them, keeping O(n)-step
               rings at O(1) live buffers.
    segmentable: wire-segmentation eligibility. None = infer from the
               selector kinds (contiguous all/chunk/range regions segment;
               mask regions do not). True = force-allow: the algorithm
               asserts send/recv masks are identical so the gathered
               payload can be cut into wire segments and scattered back.
               False = never segment this step.
    """

    perm: tuple
    op: str = "copy"
    send_sel: Sel = dataclasses.field(default_factory=Sel.all)
    recv_sel: Sel = dataclasses.field(default_factory=Sel.all)
    bytes_frac: float = 1.0
    mask_recv: bool = False
    uniform: bool = False
    segmentable: Optional[bool] = None
    # Hierarchical (two-level) schedules tag each step with the level it
    # runs on ("intra" = inner/ICI group, "inter" = outer/DCN group) and
    # the permutation in that level's own rank space. The cost walk prices
    # the exchange on `comm.level_comm(level)`'s fabric; the engine
    # ppermutes `level_perm` on the level's own mesh axis. Flat schedules
    # leave both None.
    level: Optional[str] = None
    level_perm: Optional[tuple] = None

    def __post_init__(self):
        if self.op not in COMBINE_OPS:
            raise ValueError(f"unknown combine op {self.op!r}")

    def signature(self) -> tuple:
        """Loop-coalescing identity: steps with equal signatures execute
        the same micro-ops and differ only in the step index."""
        return (self.perm, self.op, self.send_sel, self.recv_sel,
                self.mask_recv, self.uniform, self.segmentable,
                self.level, self.level_perm)


@dataclasses.dataclass(frozen=True)
class Schedule:
    """A complete collective algorithm for `nranks` ranks.

    `chunks` is the number of equal chunks the buffer is divided into
    (1 = unchunked). `result` documents what the buffer holds afterwards
    ('full' = every rank has the collective result, 'shard' = rank r holds
    chunk owned(r), 'root' = only the root's buffer is meaningful).
    """

    name: str
    collective: str
    nranks: int
    steps: tuple  # tuple[Step, ...]
    chunks: int = 1
    result: str = "full"
    # rank -> which chunk index that rank owns in 'shard' results.
    owned_chunk: Optional[Callable] = None
    # What each rank puts on the wire: 'buffer' (its accumulator — rings,
    # trees), 'received' (relay of last arrival — eager ring reduce),
    # 'original' (its untouched input — all-to-one, linear a2a).
    relay: str = "buffer"
    # >1 when steps use independent links concurrently (bidirectional ring).
    overlap_factor: float = 1.0
    # Local chunk rotations around the wire phase (Bruck all-to-all).
    pre_rotate: Optional[str] = None
    post_rotate: Optional[str] = None
    # Chunk-index coordinate system: 'absolute' (chunk j = rank j's slot) or
    # 'relative' (chunk j = rank (root+j)%n's slot — binomial gather).
    chunk_coords: str = "absolute"
    # Wire segmentation: each step's payload is split into this many
    # Rx-buffer-sized segments and pipelined (segment s+1 rides the wire
    # while segment s is combined — ACCL+ §4.4.3). 1 = unsegmented.
    segments: int = 1
    # Two-level hierarchical schedules record the level rank counts here,
    # e.g. {"inter": pod_size, "intra": ici_size}; None for flat.
    level_sizes: Optional[tuple] = None

    # ---- static cost terms (selector + EXPERIMENTS tables) ---------------
    def n_steps(self) -> int:
        return len(self.steps)

    def bytes_on_wire(self, msg_bytes: float) -> float:
        """Per-rank bytes sent over the whole schedule."""
        return float(msg_bytes) * sum(s.bytes_frac for s in self.steps)

    def with_segments(self, segments: int) -> "Schedule":
        """Copy of this schedule with the segmentation knob set."""
        if segments == self.segments:
            return self
        return dataclasses.replace(self, segments=int(segments))

    def compile(self, segments: Optional[int] = None,
                codec: Optional[str] = None, stream: bool = True,
                stacked: bool = True, verify: Optional[str] = None):
        """Lower this schedule to a micro-op `Program` (core/program.py).

        The program is the single artifact of BOTH execution and cost:
        `engine.execute_program` (XLA) and `simulator.execute_program`
        (numpy) run it, and `Program.cost` prices it (there is no
        schedule-walk pricing any more). `segments` overrides the
        schedule's own knob; `codec` names a wire compressor from
        `plugins.CODECS`; `stream`/`stacked` gate the optimization
        passes (tests hold the unfused program as a bitwise reference);
        `verify` sets the static-verifier level ("off" | "structural" |
        "full", None = REPRO_VERIFY env var — see `core/verify.py`).
        """
        from repro.core import program as prog  # local: avoid import cycle
        return prog.compile_schedule(self, segments=segments, codec=codec,
                                     stream=stream, stacked=stacked,
                                     verify=verify)

    def validate(self) -> None:
        """Structural checks (the 'firmware assembler')."""
        for i, s in enumerate(self.steps):
            seen_src, seen_dst = set(), set()
            for src, dst in s.perm:
                if not (0 <= src < self.nranks and 0 <= dst < self.nranks):
                    raise ValueError(f"step {i}: pair ({src},{dst}) out of range")
                if src in seen_src or dst in seen_dst:
                    raise ValueError(f"step {i}: duplicate src/dst in perm")
                seen_src.add(src)
                seen_dst.add(dst)
        if self.result == "shard" and self.owned_chunk is None:
            raise ValueError("shard-result schedule needs owned_chunk map")
