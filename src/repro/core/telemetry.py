"""Unified telemetry: the virtual-clock tracer and the metrics registry.

This repo prices everything it executes — `Program.cost_terms`,
`Sequencer.makespan`, `MeshMakespan` over `FabricOccupancy` — but until
this module it surfaced almost none of it: control-plane counters lived
in four ad-hoc dicts and the priced per-link/per-request schedule was
collapsed to one scalar. Two primitives fix that:

:class:`Tracer`
    Spans + instant events + typed counters on TWO clocks:

    * the **control-plane tick clock** — a deterministic monotone
      counter stamping trace-time work (selector choices, compiles,
      engine drains).  No wall clock is ever consulted, so traces are
      bit-reproducible;
    * the **virtual clock** — priced seconds.  `interval()` records
      per-request and per-link occupancy windows (`simulate_drain`,
      `MeshMakespan.timeline()`), the same numbers the makespan model
      composes.

    `to_chrome_trace()` exports Chrome trace-event JSON (one track per
    queue, one per physical link, retry/fault instants as markers —
    loadable in Perfetto or ui.perfetto.dev); `snapshot()` flattens the
    event stream into a dict for asserts and logs.

:class:`MetricsRegistry`
    Typed counters/gauges plus structured per-step records.  The
    scattered `Selector.stats` / `Sequencer.stats` / `engine.stats`
    dicts are now read-compatible :class:`StatsView` mappings over a
    registry — existing `stats["issued"]` reads keep working, but
    writers go through `inc()`/`set()` (rule LC004 in
    `scripts/lint_conventions.py` flags new direct `.stats[...] =`
    writes).

Zero overhead when off: the process-default tracer is :data:`NULL`,
whose methods are no-ops and whose `span()` returns a shared null
context manager.  Instrumented code guards argument assembly with
`tracer.enabled`.  **Pricing never reads the tracer** — enabling
tracing cannot change a priced or executed bit (regression-gated by
tests/test_telemetry.py and the bench baseline).

Scoping::

    from repro.core import telemetry
    with telemetry.use(telemetry.Tracer()) as tr:
        ...  # everything issued/priced/drained here is recorded
    trace = tr.to_chrome_trace()

This module is stdlib-only and imports nothing from `repro` — every
core module may import it without cycles.
"""
from __future__ import annotations

import contextlib
from collections.abc import Mapping
from typing import Iterator, Optional

__all__ = [
    "Tracer", "NullTracer", "MetricsRegistry", "StatsView",
    "NULL", "current", "use", "axis_label",
]


def axis_label(axis) -> str:
    """Human-readable track label for an axis key (str or tuple)."""
    if isinstance(axis, tuple):
        return "+".join(str(a) for a in axis)
    return str(axis)


# ---------------------------------------------------------------------------
# Tracer
# ---------------------------------------------------------------------------

#: pid of the control-plane track group (tick clock: 1 tick == 1 "us").
CONTROL_PID = 1
#: pid of the virtual-clock track group (priced seconds, exported as us).
VIRTUAL_PID = 2


class _NullSpan:
    """Shared no-op span: entering, exiting, and annotating cost nothing."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def add(self, **args) -> None:
        pass


_NULL_SPAN = _NullSpan()


class NullTracer:
    """The process-default tracer: every method is a no-op.

    `enabled` is False so instrumentation can skip argument assembly
    entirely; calling the methods anyway is still safe and free of
    side effects.
    """

    enabled = False

    def span(self, name: str, track: str = "control", **args) -> _NullSpan:
        return _NULL_SPAN

    def instant(self, name: str, track: str = "control",
                ts_s: Optional[float] = None, **args) -> None:
        pass

    def counter(self, name: str, value, track: str = "control") -> None:
        pass

    def interval(self, name: str, track: str, start_s: float, end_s: float,
                 **args) -> None:
        pass

    def ingest_timeline(self, timeline: dict) -> None:
        pass


#: The shared disabled tracer (the process default).
NULL = NullTracer()


class _Span:
    """Context manager recording one control-plane span ("X" event)."""

    __slots__ = ("_tracer", "name", "track", "args", "_start")

    def __init__(self, tracer: "Tracer", name: str, track: str, args: dict):
        self._tracer = tracer
        self.name = name
        self.track = track
        self.args = args
        self._start = 0

    def add(self, **args) -> None:
        """Attach more args to the span (e.g. the outcome, post-hoc)."""
        self.args.update(args)

    def __enter__(self) -> "_Span":
        self._start = self._tracer._next_tick()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        end = self._tracer._next_tick()
        if exc_type is not None:
            self.args.setdefault("error", exc_type.__name__)
        self._tracer._events.append({
            "type": "span", "name": self.name, "track": self.track,
            "pid": CONTROL_PID, "ts": self._start,
            "dur": end - self._start, "args": self.args,
        })
        return False


class Tracer:
    """Recording tracer: spans, instants, counters, virtual intervals.

    All timestamps are deterministic — the control-plane tick counter
    and the priced virtual clock — so two identical runs produce
    identical traces.  See the module docstring for the event model.
    """

    enabled = True

    def __init__(self):
        self._events: list = []
        self._tick = 0
        # (pid, track) -> tid, assigned in first-use order
        self._tids: dict = {}
        self._installed_prev = []  # `with tracer:` scoping stack

    def _next_tick(self) -> int:
        self._tick += 1
        return self._tick

    # -- recording ----------------------------------------------------------
    def span(self, name: str, track: str = "control", **args) -> _Span:
        """Open a control-plane span; use as a context manager.  The
        returned span's `add(**args)` attaches outcome fields before it
        closes.  Spans on one track are well-nested by construction
        (context-manager discipline + a global monotone tick clock)."""
        return _Span(self, name, track, dict(args))

    def instant(self, name: str, track: str = "control",
                ts_s: Optional[float] = None, **args) -> None:
        """A marker: tick-clocked by default, or pinned to the virtual
        clock when `ts_s` (priced seconds) is given."""
        if ts_s is None:
            self._events.append({
                "type": "instant", "name": name, "track": track,
                "pid": CONTROL_PID, "ts": self._next_tick(), "args": args,
            })
        else:
            self._events.append({
                "type": "instant", "name": name, "track": track,
                "pid": VIRTUAL_PID, "ts": float(ts_s), "args": args,
            })

    def counter(self, name: str, value, track: str = "control") -> None:
        """A typed counter sample (Chrome "C" event)."""
        self._events.append({
            "type": "counter", "name": name, "track": track,
            "pid": CONTROL_PID, "ts": self._next_tick(),
            "args": {name: value},
        })

    def interval(self, name: str, track: str, start_s: float, end_s: float,
                 **args) -> None:
        """A virtual-clock occupancy window (priced seconds): one
        request on a queue track, or one program's wire seconds on a
        physical-link track."""
        self._events.append({
            "type": "interval", "name": name, "track": track,
            "pid": VIRTUAL_PID, "ts": float(start_s),
            "dur": float(end_s) - float(start_s), "args": args,
        })

    def ingest_timeline(self, timeline: dict) -> None:
        """Record a `MeshMakespan.timeline()` as virtual-clock intervals:
        per-queue drain windows, chain-placed per-request windows, and
        serialized per-link busy windows (+ the trailing alpha term)."""
        for q in timeline.get("queues", ()):
            self.interval("drain", q["track"], q["start_s"], q["end_s"],
                          axis=axis_label(q["axis"]))
        for r in timeline.get("requests", ()):
            self.interval(r.get("name", "request"), r["track"],
                          r["start_s"], r["end_s"], rids=r["rids"],
                          full_s=r["full_s"], lat_s=r["lat_s"],
                          wire_s=r["wire_s"], coalesced=r["coalesced"])
        for lk in timeline.get("links", ()):
            self.interval(lk.get("name", "wire"), lk["track"],
                          lk["start_s"], lk["end_s"])

    # -- scoping ------------------------------------------------------------
    def __enter__(self) -> "Tracer":
        global _ACTIVE
        self._installed_prev.append(_ACTIVE)
        _ACTIVE = self
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        global _ACTIVE
        _ACTIVE = self._installed_prev.pop()
        return False

    # -- export -------------------------------------------------------------
    def _tid(self, pid: int, track: str) -> int:
        key = (pid, track)
        tid = self._tids.get(key)
        if tid is None:
            tid = len(self._tids) + 1
            self._tids[key] = tid
        return tid

    def to_chrome_trace(self) -> dict:
        """Chrome trace-event JSON (the `{"traceEvents": [...]}` form).

        Control-plane events live under pid 1 (1 tick == 1 us), virtual-
        clock events under pid 2 (1 priced second == 1e6 us).  Each
        track is a named thread; events are sorted by (pid, tid, ts) so
        per-track timestamps are monotone.  Load the file in Perfetto
        (ui.perfetto.dev) or chrome://tracing, or summarize it with
        `scripts/trace_report.py`.
        """
        events = []
        for ev in self._events:
            pid = ev["pid"]
            tid = self._tid(pid, ev["track"])
            ts = float(ev["ts"]) if pid == CONTROL_PID \
                else float(ev["ts"]) * 1e6
            if ev["type"] in ("span", "interval"):
                dur = float(ev["dur"]) if pid == CONTROL_PID \
                    else float(ev["dur"]) * 1e6
                events.append({"ph": "X", "name": ev["name"], "cat": "repro",
                               "pid": pid, "tid": tid, "ts": ts, "dur": dur,
                               "args": ev["args"]})
            elif ev["type"] == "instant":
                events.append({"ph": "i", "name": ev["name"], "cat": "repro",
                               "pid": pid, "tid": tid, "ts": ts, "s": "t",
                               "args": ev["args"]})
            else:  # counter
                events.append({"ph": "C", "name": ev["name"], "cat": "repro",
                               "pid": pid, "tid": tid, "ts": ts,
                               "args": ev["args"]})
        events.sort(key=lambda e: (e["pid"], e["tid"], e["ts"],
                                   -e.get("dur", 0.0)))
        meta = [
            {"ph": "M", "name": "process_name", "pid": CONTROL_PID, "tid": 0,
             "args": {"name": "control-plane (ticks)"}},
            {"ph": "M", "name": "process_name", "pid": VIRTUAL_PID, "tid": 0,
             "args": {"name": "virtual-clock (priced seconds)"}},
        ]
        for (pid, track), tid in sorted(self._tids.items(),
                                        key=lambda kv: kv[1]):
            meta.append({"ph": "M", "name": "thread_name", "pid": pid,
                         "tid": tid, "args": {"name": track}})
        return {"traceEvents": meta + events, "displayTimeUnit": "ms"}

    def snapshot(self) -> dict:
        """Flat summary of the event stream: per-name span/interval
        counts and total durations, instant counts, last counter
        values, and the total event count."""
        out: dict = {"events": len(self._events)}
        for ev in self._events:
            if ev["type"] in ("span", "interval"):
                k = f"{ev['type']}.{ev['name']}.count"
                out[k] = out.get(k, 0) + 1
                kd = f"{ev['type']}.{ev['name']}.total"
                out[kd] = out.get(kd, 0.0) + float(ev["dur"])
            elif ev["type"] == "instant":
                k = f"instant.{ev['name']}.count"
                out[k] = out.get(k, 0) + 1
            else:
                out[f"counter.{ev['name']}"] = ev["args"][ev["name"]]
        return out


# ---------------------------------------------------------------------------
# Process-default tracer + scoping
# ---------------------------------------------------------------------------

_ACTIVE = NULL


def current():
    """The tracer instrumentation should record to right now (the
    :data:`NULL` no-op tracer unless a `use()` / `with tracer:` scope is
    active)."""
    return _ACTIVE


@contextlib.contextmanager
def use(tracer):
    """Install `tracer` as the process tracer for the dynamic extent of
    the `with` block (restores the previous one on exit)."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = tracer
    try:
        yield tracer
    finally:
        _ACTIVE = prev


# ---------------------------------------------------------------------------
# MetricsRegistry
# ---------------------------------------------------------------------------

class StatsView(Mapping):
    """Live read-compatible mapping over a :class:`MetricsRegistry`.

    Drop-in for the legacy ad-hoc `.stats` dicts: supports `[]`,
    `.get`, iteration, `len`, and equality with plain dicts.  Writing
    through the view delegates to `registry.set` (an out-of-tree
    back-compat shim — in-tree code emits through the registry, and
    LC004 flags new direct `.stats[...] =` writes in src/).
    """

    __slots__ = ("_reg",)

    def __init__(self, registry: "MetricsRegistry"):
        self._reg = registry

    def __getitem__(self, name: str):
        return self._reg._values[name]

    def __iter__(self) -> Iterator[str]:
        return iter(self._reg._values)

    def __len__(self) -> int:
        return len(self._reg._values)

    def __setitem__(self, name: str, value) -> None:
        self._reg.set(name, value)

    def __repr__(self) -> str:
        return f"StatsView({dict(self._reg._values)!r})"


class MetricsRegistry:
    """Typed counters/gauges + structured records, behind mapping views.

    `counter(name)` declares a monotone counter (so the key is present,
    at 0, before the first `inc` — tests read counters on fresh
    objects); `set(name, value)` writes a gauge, declaring it on first
    write.  `record(**fields)` appends one structured record (the
    trainer emits one per step).  `view()` returns the live
    :class:`StatsView` components expose as `.stats`.
    """

    __slots__ = ("_values", "_kinds", "_records")

    def __init__(self):
        self._values: dict = {}
        self._kinds: dict = {}
        self._records: list = []

    def __repr__(self) -> str:
        return f"MetricsRegistry({self._values!r})"

    # -- counters / gauges ---------------------------------------------------
    def counter(self, name: str, value=0) -> None:
        """Declare (or reset) a monotone counter."""
        self._kinds[name] = "counter"
        self._values[name] = value

    def inc(self, name: str, delta=1):
        """Increment a counter (declared on first use); returns the new
        value."""
        val = self._values.get(name, 0) + delta
        self._kinds.setdefault(name, "counter")
        self._values[name] = val
        return val

    def set(self, name: str, value) -> None:
        """Write a gauge (declared on first write)."""
        self._kinds.setdefault(name, "gauge")
        self._values[name] = value

    def get(self, name: str, default=None):
        return self._values.get(name, default)

    def discard(self, name: str) -> None:
        """Remove a metric entirely (its key disappears from views)."""
        self._values.pop(name, None)
        self._kinds.pop(name, None)

    # -- structured records --------------------------------------------------
    def record(self, **fields) -> dict:
        """Append one structured record (e.g. a per-step metrics row);
        returns it."""
        rec = dict(fields)
        self._records.append(rec)
        return rec

    def records(self) -> list:
        return list(self._records)

    # -- views ---------------------------------------------------------------
    def snapshot(self) -> dict:
        """A flat copy of every metric value."""
        return dict(self._values)

    def view(self) -> StatsView:
        return StatsView(self)
