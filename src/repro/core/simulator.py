"""Rank-level numpy simulator — the ACCL+ ZMQ simulation platform analogue.

Executes a `Schedule` functionally over explicit per-rank buffers, with no
jax involved. Used for:
  * algorithm validation (tests compare against numpy oracles),
  * schedule debugging without tracing/compiling,
  * the latency *model* evaluation in the fig10/fig12 benchmarks.

The semantics here are the reference the jax engine (core/engine.py) must
match — the simulator is the "bus functional model of the CCLO".
"""
from __future__ import annotations

import numpy as np

from repro.core.schedule import (
    SEL_ALL, SEL_CHUNK, SEL_MASK, SEL_RANGE, Schedule, Sel,
)

_COMBINE = {
    "copy": lambda old, new: new,
    "add": lambda old, new: old + new,
    "max": np.maximum,
    "min": np.minimum,
    "mul": lambda old, new: old * new,
}


def _chunk_view(buf: np.ndarray, chunks: int, idx: int, length: int = 1):
    """Slice chunks [idx, idx+length) of the flat leading dim."""
    csize = buf.shape[0] // chunks
    return buf[idx * csize:(idx + length) * csize]


def _select(buf: np.ndarray, chunks: int, sel: Sel, rank: int, step: int):
    if sel.kind == SEL_ALL:
        return buf.copy()
    if sel.kind == SEL_CHUNK:
        return _chunk_view(buf, chunks, int(sel.fn(rank, step))).copy()
    if sel.kind == SEL_RANGE:
        off, length = sel.fn(rank, step)
        return _chunk_view(buf, chunks, int(off), int(length)).copy()
    if sel.kind == SEL_MASK:
        idxs = sel.fn(rank, step)
        return np.concatenate(
            [_chunk_view(buf, chunks, int(j)) for j in idxs], axis=0)
    raise ValueError(sel.kind)


def _place(buf: np.ndarray, chunks: int, sel: Sel, rank: int, step: int,
           incoming: np.ndarray, op: str) -> None:
    fn = _COMBINE[op]
    if sel.kind == SEL_ALL:
        buf[...] = fn(buf, incoming)
        return
    if sel.kind == SEL_CHUNK:
        view = _chunk_view(buf, chunks, int(sel.fn(rank, step)))
        view[...] = fn(view, incoming)
        return
    if sel.kind == SEL_RANGE:
        off, length = sel.fn(rank, step)
        view = _chunk_view(buf, chunks, int(off), int(length))
        view[...] = fn(view, incoming)
        return
    if sel.kind == SEL_MASK:
        idxs = sel.fn(rank, step)
        csize = buf.shape[0] // chunks
        for k, j in enumerate(idxs):
            view = _chunk_view(buf, chunks, int(j))
            view[...] = fn(view, incoming[k * csize:(k + 1) * csize])
        return
    raise ValueError(sel.kind)


def _bruck_pre(bufs, n):
    """Rank r rotates chunks so chunk j holds data destined to (r+j)%n."""
    out = []
    for r, b in enumerate(bufs):
        csize = b.shape[0] // n
        parts = [b[((j + r) % n) * csize:(((j + r) % n) + 1) * csize]
                 for j in range(n)]
        out.append(np.concatenate(parts, axis=0))
    return out


def _bruck_post(bufs, n):
    """After the phases chunk j holds data from rank (r-j)%n; rearrange so
    chunk j holds data from rank j."""
    out = []
    for r, b in enumerate(bufs):
        csize = b.shape[0] // n
        parts = [b[((r - j) % n) * csize:(((r - j) % n) + 1) * csize]
                 for j in range(n)]
        out.append(np.concatenate(parts, axis=0))
    return out


def simulate(schedule: Schedule, inputs: list[np.ndarray]) -> list[np.ndarray]:
    """Run `schedule` over per-rank buffers; returns final per-rank buffers."""
    n = schedule.nranks
    assert len(inputs) == n, f"need {n} rank buffers"
    for b in inputs:
        if b.shape[0] % schedule.chunks:
            raise ValueError(
                f"leading dim {b.shape[0]} not divisible by {schedule.chunks}")
    schedule.validate()

    bufs = [np.array(b, copy=True) for b in inputs]
    if schedule.pre_rotate == "bruck":
        bufs = _bruck_pre(bufs, n)
    originals = [b.copy() for b in bufs]
    last_recv: list[np.ndarray | None] = [None] * n

    for s_idx, step in enumerate(schedule.steps):
        src_of = {dst: src for (src, dst) in step.perm}
        # 1. every listed src places its payload on the wire
        wire = {}
        for (src, dst) in step.perm:
            if schedule.relay == "original":
                payload_src = originals[src]
            elif schedule.relay == "received" and last_recv[src] is not None:
                payload_src = last_recv[src]
            else:
                payload_src = bufs[src]
            wire[dst] = _select(payload_src, schedule.chunks, step.send_sel,
                                src, s_idx)
        # 2. destinations combine
        new_recv = list(last_recv)
        for dst, payload in wire.items():
            _place(bufs[dst], schedule.chunks, step.recv_sel, dst, s_idx,
                   payload, step.op)
            new_recv[dst] = payload
        # non-destinations: mask_recv means keep state; rings always receive
        if not step.mask_recv:
            missing = set(range(n)) - set(wire.keys())
            if missing:
                raise ValueError(
                    f"step {s_idx}: ranks {missing} receive nothing but "
                    f"mask_recv=False")
        last_recv = new_recv

    if schedule.post_rotate == "bruck":
        bufs = _bruck_post(bufs, n)
    return bufs


# ---------------------------------------------------------------------------
# Numpy oracles (what each collective should produce)
# ---------------------------------------------------------------------------

def oracle(collective: str, inputs: list[np.ndarray], op: str = "add",
           root: int = 0):
    """Reference results, rank-indexed. For 'shard' results, returns the
    full reduction; callers slice per owned_chunk."""
    n = len(inputs)
    stack = np.stack(inputs)
    if collective in ("allreduce", "reduce", "reduce_scatter"):
        red = {"add": np.sum, "max": np.max, "min": np.min,
               "mul": np.prod}[op](stack, axis=0)
        return red
    if collective in ("allgather", "gather"):
        return np.concatenate(inputs, axis=0)
    if collective == "bcast":
        return inputs[root]
    if collective == "alltoall":
        # chunk j of rank r's output = chunk r of rank j's input
        csize = inputs[0].shape[0] // n
        return [
            np.concatenate([inputs[j][r * csize:(r + 1) * csize]
                            for j in range(n)], axis=0)
            for r in range(n)
        ]
    raise ValueError(collective)
