"""Rank-level numpy simulator — the ACCL+ ZMQ simulation platform analogue.

Executes the SAME micro-op `Program` the jax engine runs (a `Schedule` is
first compiled through `core/program.py`), over explicit per-rank buffers,
with no jax involved. Used for:
  * algorithm validation (tests compare against numpy oracles),
  * schedule/IR debugging without tracing/compiling,
  * the latency *model* evaluation in the fig10/fig12 benchmarks.

Because both executors interpret one compiled artifact, oracle parity here
covers the real engine code path (LOOP coalescing, SEG_LOOP segmentation,
Bruck rotations) — the simulator is the "bus functional model of the CCLO",
not a parallel reimplementation of the algorithms.

Wire codecs are jax-side plugins; the simulator executes uncompressed
programs only (compile with codec=None, the default).
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.program import (
    Copy, Compress, Decompress, Loop, Program, RecvCombine, SegLoop, Send,
    StackedRecv, Stream, StreamChain, compile_schedule, fit_segments,
    split_exchange,
)
from repro.core.schedule import (
    SEL_ALL, SEL_CHUNK, SEL_MASK, SEL_RANGE, Schedule, Sel,
)

_COMBINE = {
    "copy": lambda old, new: new,
    "add": lambda old, new: old + new,
    "max": np.maximum,
    "min": np.minimum,
    "mul": lambda old, new: old * new,
}


def _chunk_view(buf: np.ndarray, chunks: int, idx: int, length: int = 1):
    """Slice chunks [idx, idx+length) of the flat leading dim."""
    csize = buf.shape[0] // chunks
    return buf[idx * csize:(idx + length) * csize]


def _select(buf: np.ndarray, chunks: int, sel: Sel, rank: int, step: int):
    if sel.kind == SEL_ALL:
        return buf.copy()
    if sel.kind == SEL_CHUNK:
        return _chunk_view(buf, chunks, int(sel.fn(rank, step))).copy()
    if sel.kind == SEL_RANGE:
        off, length = sel.fn(rank, step)
        return _chunk_view(buf, chunks, int(off), int(length)).copy()
    if sel.kind == SEL_MASK:
        idxs = sel.fn(rank, step)
        return np.concatenate(
            [_chunk_view(buf, chunks, int(j)) for j in idxs], axis=0)
    raise ValueError(sel.kind)


def _recv_region(buf: np.ndarray, chunks: int, sel: Sel, rank: int,
                 step: int):
    """(view_copy, elem_offset, mask_idxs) mirroring the engine's helper."""
    csize = buf.shape[0] // chunks
    if sel.kind == SEL_MASK:
        idxs = tuple(int(j) for j in sel.fn(rank, step))
        view = np.concatenate(
            [_chunk_view(buf, chunks, j) for j in idxs], axis=0)
        return view, None, idxs
    if sel.kind == SEL_ALL:
        return buf.copy(), None, None
    if sel.kind == SEL_CHUNK:
        off = int(sel.fn(rank, step)) * csize
        length = csize
    else:
        o, ln = sel.fn(rank, step)
        off, length = int(o) * csize, int(ln) * csize
    return buf[off:off + length].copy(), off, None


def _apply_write(buf: np.ndarray, chunks: int, off, mask_idxs,
                 new_val: np.ndarray) -> None:
    if mask_idxs is not None:
        csize = buf.shape[0] // chunks
        for k, j in enumerate(mask_idxs):
            buf[j * csize:(j + 1) * csize] = \
                new_val[k * csize:(k + 1) * csize]
        return
    if off is None:
        buf[...] = new_val
        return
    buf[off:off + new_val.shape[0]] = new_val


def _bruck_pre(bufs, n):
    """Rank r rotates chunks so chunk j holds data destined to (r+j)%n."""
    out = []
    for r, b in enumerate(bufs):
        csize = b.shape[0] // n
        parts = [b[((j + r) % n) * csize:(((j + r) % n) + 1) * csize]
                 for j in range(n)]
        out.append(np.concatenate(parts, axis=0))
    return out


def _bruck_post(bufs, n):
    """After the phases chunk j holds data from rank (r-j)%n; rearrange so
    chunk j holds data from rank j."""
    out = []
    for r, b in enumerate(bufs):
        csize = b.shape[0] // n
        parts = [b[((r - j) % n) * csize:(((r - j) % n) + 1) * csize]
                 for j in range(n)]
        out.append(np.concatenate(parts, axis=0))
    return out


# --------------------------------------------------------------------------
# Program execution
# --------------------------------------------------------------------------

class _State:
    """Per-run registers: buffers plus the relay sources."""

    def __init__(self, bufs):
        self.bufs = bufs
        self.origs = [b.copy() for b in bufs]
        self.prevs = [b.copy() for b in bufs]  # relay='received' step 0

    def source(self, which: str):
        return {"buffer": self.bufs, "original": self.origs,
                "received": self.prevs}[which]


def _exchange_writes(body: tuple, k_req: int, state: _State, chunks: int,
                     step: int, read_bufs, transport=None) -> list:
    """One exchange across all ranks, two-phase: every rank's payload and
    combine target are read from `read_bufs` (the pre-step state), then the
    region writes are returned for the caller to apply.

    Mirrors the engine's `_exchange_update` + deferred `_apply_write`,
    including SEG_LOOP's per-segment combine granularity, so numerics
    match the XLA executor exactly.

    `transport` (a `faults.FaultyTransport`) is consulted once per
    (src, dst) wire crossing BEFORE any write is staged: a delivery that
    survives its retry budget retransmits the identical payload (so the
    final buffers are bitwise-equal to the fault-free run), and a
    terminal loss raises a typed error while every buffer still holds
    its pre-exchange state — no partial writes, no silent corruption.
    Returns [(rank, off, mask_idxs, new_val, raw_or_None), ...].
    """
    load, recv = body[0], body[-1]
    for op in body[1:-1]:
        if isinstance(op, (Compress, Decompress)):
            raise NotImplementedError(
                "the numpy simulator executes uncompressed programs only")
    send_op = next(op for op in body[1:-1] if isinstance(op, Send))

    n = len(state.bufs)
    srcs = state.source(load.source)
    payloads = {r: _select(srcs[r] if load.source != "buffer"
                           else read_bufs[r], chunks, load.sel, r, step)
                for r in range(n)}
    wire = {dst: payloads[src] for (src, dst) in send_op.perm}

    if transport is not None:
        for (src, dst) in send_op.perm:
            transport.deliver(src, dst)
        transport.advance()

    if recv.dsts is None:
        missing = set(range(n)) - set(wire.keys())
        if missing:
            raise ValueError(
                f"step {step}: ranks {missing} receive nothing but "
                f"mask_recv=False")

    writes = []
    for dst in range(n):
        incoming = wire.get(dst)
        if incoming is None:
            continue  # masked non-destination keeps its state
        view, off, mask_idxs = _recv_region(read_bufs[dst], chunks,
                                            recv.sel, dst, step)
        comb = _COMBINE[recv.op]
        k = 1
        if k_req > 1 and view.shape[0] == payloads[dst].shape[0]:
            row_elems = max(1, view.size // max(1, view.shape[0]))
            k = fit_segments(view.shape[0], k_req, row_elems)
        if k > 1:
            seg = view.shape[0] // k
            new_val = np.concatenate(
                [comb(view[i * seg:(i + 1) * seg],
                      incoming[i * seg:(i + 1) * seg].astype(view.dtype))
                 for i in range(k)], axis=0)
        else:
            new_val = comb(view, incoming.astype(view.dtype))
        raw = incoming if recv.track_recv else None
        writes.append((dst, off, mask_idxs, np.asarray(new_val), raw))
    return writes


def _apply(state: _State, chunks: int, writes: list) -> None:
    for rank, off, mask_idxs, new_val, raw in writes:
        _apply_write(state.bufs[rank], chunks, off, mask_idxs, new_val)
        if raw is not None:
            state.prevs[rank] = np.array(raw, copy=True)


def execute_program(prog: Program, inputs: list, transport=None) -> list:
    """Run a compiled Program over per-rank buffers; returns final buffers.

    `transport` (optional `faults.FaultyTransport`) injects the fault
    plan at every wire crossing; see `_exchange_writes`.
    """
    n = prog.nranks
    assert len(inputs) == n, f"need {n} rank buffers"
    for b in inputs:
        if b.shape[0] % prog.chunks:
            raise ValueError(
                f"leading dim {b.shape[0]} not divisible by {prog.chunks}")

    bufs = [np.array(b, copy=True) for b in inputs]
    ops = prog.ops
    i = 0
    if ops and isinstance(ops[0], Copy) and ops[0].kind == "bruck_pre":
        bufs = _bruck_pre(bufs, prog.chunks)
        i = 1
    state = _State(bufs)

    while i < len(ops):
        op = ops[i]
        if isinstance(op, Stream):
            # The stream's wave order is value-identical to the per-step
            # order by construction (that is exactly what fuse_streams
            # proves before emitting one) — the bus-functional model
            # executes the unfused equivalent, segment granularity
            # included, so streamed programs validate through the same
            # two-phase path.
            op = Loop(base=op.base, trip=op.trip, period=op.period,
                      slots=tuple((SegLoop(op.segments, b),)
                                  for b in op.slots))
        if isinstance(op, StreamChain):
            # the chain's wave order is value-identical to the per-step
            # order — that is exactly what fuse_chains' region-overlap
            # proof establishes — so the bus-functional model executes
            # the unfused per-step equivalent, segment granularity
            # included.
            for body in op.bodies:
                writes = _exchange_writes(body, op.segments, state,
                                          prog.chunks, body[0].step,
                                          state.bufs, transport)
                _apply(state, prog.chunks, writes)
            i += 1
            continue
        if isinstance(op, StackedRecv):
            # stacked receives are write-disjoint: applying them in step
            # order reproduces the engine's one-scatter result exactly
            for body in op.bodies:
                writes = _exchange_writes(body, 1, state, prog.chunks,
                                          body[0].step, state.bufs,
                                          transport)
                _apply(state, prog.chunks, writes)
            i += 1
        elif isinstance(op, Loop):
            for it in range(op.trip):
                # two-phase like the engine's LOOP: all slots read the
                # iteration-start buffers, writes land at iteration end
                snap = [b.copy() for b in state.bufs]
                writes = []
                for slot, seq in enumerate(op.slots):
                    step = op.base + it * op.period + slot
                    body, k_req = split_exchange(seq)
                    writes.extend(_exchange_writes(body, k_req, state,
                                                   prog.chunks, step, snap,
                                                   transport))
                _apply(state, prog.chunks, writes)
            i += 1
        elif isinstance(op, Copy) and op.kind == "bruck_post":
            state.bufs = _bruck_post(state.bufs, prog.chunks)
            i += 1
        elif isinstance(op, SegLoop) or (
                isinstance(op, Copy) and op.kind == "load"):
            if isinstance(op, SegLoop):
                body, k_req = op.body, op.segments
                i += 1
            else:
                j = i
                while not isinstance(ops[j], RecvCombine):
                    j += 1
                body, k_req = ops[i:j + 1], 1
                i = j + 1
            step = body[0].step
            writes = _exchange_writes(body, k_req, state, prog.chunks,
                                      step, state.bufs, transport)
            _apply(state, prog.chunks, writes)
        else:
            raise ValueError(f"unexpected micro-op {op}")
    return state.bufs


def simulate(schedule: Schedule, inputs: list,
             segments: Optional[int] = None, stream: bool = True,
             stacked: bool = True, transport=None) -> list:
    """Compile `schedule` to its micro-op program and run it over per-rank
    buffers; returns final per-rank buffers. `segments` overrides the
    schedule's wire-segmentation knob; `stream`/`stacked` gate the
    optimization passes exactly as in `Schedule.compile`. `transport`
    (optional `faults.FaultyTransport`) injects fabric faults."""
    schedule.validate()
    prog = compile_schedule(schedule, segments=segments, stream=stream,
                            stacked=stacked)
    return execute_program(prog, inputs, transport)


def simulate_with_cost(schedule: Schedule, inputs: list, comm,
                       segments: Optional[int] = None,
                       elem_bytes: int = 4, stream: bool = True,
                       stacked: bool = True) -> tuple:
    """`simulate`, plus the predicted seconds of the SAME compiled program
    (`Program.cost`) — the simulator returns the split-model cost of
    exactly the program it executed, the fig10/fig12 model-evaluation
    contract. A streamed compile and a `stream=False` compile of the same
    schedule execute to identical buffers but price differently: only the
    streamed program earns the cross-step fill/drain credit."""
    schedule.validate()
    prog = compile_schedule(schedule, segments=segments, stream=stream,
                            stacked=stacked)
    bufs = execute_program(prog, inputs)
    msg_bytes = inputs[0].size * inputs[0].itemsize
    return bufs, prog.cost(msg_bytes, comm, elem_bytes=elem_bytes)


def _flatten_pad(x: np.ndarray, mult: int):
    """numpy mirror of the engine's `_flatten_pad` staging copy."""
    flat = np.asarray(x).reshape(-1)
    pad = (-flat.shape[0]) % mult
    if pad:
        flat = np.concatenate([flat, np.zeros((pad,), flat.dtype)])
    return flat, x.shape, x.size


def run_collective(collective: str, schedule: Schedule, prog: Program,
                   inputs: list, root: int = 0, transport=None) -> list:
    """Execute one ENGINE-CONVENTION collective call over per-rank numpy
    buffers: the same flatten/pad staging, result trimming, and
    shard/root slicing the `CollectiveEngine` wrappers apply around
    `execute_program`, so a simulated call is comparable element-for-
    element with the jax engine's return value. Used by the sequencer's
    `simulate_drain` to validate queue drains against the same compiled
    program the makespan model prices. Returns per-rank results."""
    n = prog.nranks
    if len(inputs) != n:
        raise ValueError(f"need {n} rank buffers, got {len(inputs)}")
    if collective == "alltoall":
        arrs = [np.asarray(b) for b in inputs]
        if arrs[0].shape[0] % n:
            raise ValueError(
                f"alltoall dim0 {arrs[0].shape[0]} % {n} != 0")
        return execute_program(prog, arrs, transport)
    if collective == "reduce_scatter":
        flats = [np.asarray(b).reshape(-1) for b in inputs]
        if flats[0].size % n:
            raise ValueError(
                f"reduce_scatter size {flats[0].size} % {n} != 0")
        outs = execute_program(prog, flats, transport)
        csize = flats[0].shape[0] // n
        return [outs[r][int(schedule.owned_chunk(r)) * csize:
                        (int(schedule.owned_chunk(r)) + 1) * csize]
                for r in range(n)]
    if collective in ("allgather", "gather"):
        flats = [np.asarray(b).reshape(-1) for b in inputs]
        fl = flats[0].shape[0]
        bufs = []
        for r in range(n):
            slot = r if (collective == "allgather"
                         or schedule.chunk_coords == "absolute") \
                else (r - root) % n
            buf = np.zeros((n * fl,), flats[r].dtype)
            buf[slot * fl:(slot + 1) * fl] = flats[r]
            bufs.append(buf)
        outs = execute_program(prog, bufs, transport)
        if collective == "gather" and schedule.chunk_coords == "relative":
            outs = [np.roll(o.reshape(n, fl), root, axis=0).reshape(-1)
                    for o in outs]
        return outs
    # allreduce / reduce / bcast / custom collectives: pad to the chunk
    # grid, run, then trim (full results) or slice the owned chunk
    staged = [_flatten_pad(b, prog.chunks) for b in inputs]
    outs = execute_program(prog, [s[0] for s in staged], transport)
    if schedule.result == "shard":
        if staged[0][2] % prog.chunks:
            raise ValueError(
                f"{collective} returns shards: input size {staged[0][2]} "
                f"must be divisible by {prog.chunks} chunks")
        csize = staged[0][0].shape[0] // prog.chunks
        return [outs[r][int(schedule.owned_chunk(r)) * csize:
                        (int(schedule.owned_chunk(r)) + 1) * csize]
                for r in range(n)]
    return [outs[r][:staged[r][2]].reshape(staged[r][1])
            for r in range(n)]


# ---------------------------------------------------------------------------
# Numpy oracles (what each collective should produce)
# ---------------------------------------------------------------------------

def oracle(collective: str, inputs: list, op: str = "add",
           root: int = 0):
    """Reference results, rank-indexed. For 'shard' results, returns the
    full reduction; callers slice per owned_chunk."""
    n = len(inputs)
    stack = np.stack(inputs)
    if collective in ("allreduce", "reduce", "reduce_scatter"):
        red = {"add": np.sum, "max": np.max, "min": np.min,
               "mul": np.prod}[op](stack, axis=0)
        return red
    if collective in ("allgather", "gather"):
        return np.concatenate(inputs, axis=0)
    if collective == "bcast":
        return inputs[root]
    if collective == "alltoall":
        # chunk j of rank r's output = chunk r of rank j's input
        csize = inputs[0].shape[0] // n
        return [
            np.concatenate([inputs[j][r * csize:(r + 1) * csize]
                            for j in range(n)], axis=0)
            for r in range(n)
        ]
    raise ValueError(collective)
