"""Mesh-level contention-aware makespan (the shared-engine cost view).

`Sequencer.makespan` prices ONE communicator's queue in isolation. Real
training/serving steps run grad-sync, pipeline p2p, and offloaded app
collectives concurrently over the same chips and fabrics — ACCL+'s whole
premise is the engine as a *shared* offload resource — and per-queue
isolation prices two saturating queues on one fabric as if they ran 2x
parallel. `MeshMakespan` composes ALL queues over the physical links
(`topology.FabricOccupancy`):

  mesh = max( max over queues of the queue's own makespan,
              max over GLOBAL dependency chains of sum(full_i),
              max over physical links of sum(wire on that link)
                  + max over items of latency_i )

  * Per-queue term: each queue still prices at least its own pipelined
    drain (`Sequencer._compose`) — composition never discounts below a
    queue running alone, and a single-queue mesh makespan is BITWISE
    equal to `Sequencer.makespan`.
  * Global chain term: dependency chains crossing communicators (e.g.
    `issue_multi`'s RS -> recurse -> AG over `("pod", "data")`) price as
    one DAG — full costs serialize along the chain exactly as within one
    queue, instead of each axis's FIFO pretending the other is free.
  * Link term: wire seconds attributed per physical link by
    `Program.cost_terms(per_link=True)` SERIALIZE when queues share the
    link (two saturating same-fabric queues price ~the serial sum), and
    stay independent on disjoint fabrics (the busiest link bounds).
    Queued alpha still hides: only the single largest item latency is
    added, the same credit the per-queue model grants.

All prices come from `Sequencer._priced_plan` — the same compiled
programs, the same `PricingEnv` — so the composition never re-walks a
program. Nothing here mutates queue state: composing is a read.
"""
from __future__ import annotations

from typing import Optional

from repro.core.pricing import PricingEnv
from repro.core.topology import FabricOccupancy


class MeshMakespan:
    """Composes many sequencer queues' prices over shared fabric links.

    Usage::

        mm = MeshMakespan()
        mm.add(seq_a, "data", env)      # one call per (queue, axis)
        mm.add(seq_b, "data", env)
        total = mm.total()              # contention-aware seconds

    or, for every outstanding axis of one sequencer::

        total = MeshMakespan.of(seq, env).total()
    """

    def __init__(self, occupancy: Optional[FabricOccupancy] = None):
        self.occupancy = occupancy if occupancy is not None \
            else FabricOccupancy()
        self._queues: list = []    # (sequencer, axis, env)

    def add(self, seq, axis, env: Optional[PricingEnv] = None
            ) -> "MeshMakespan":
        """Register one communicator queue; returns self for chaining."""
        self._queues.append((seq, axis,
                             env if env is not None else PricingEnv()))
        return self

    @classmethod
    def of(cls, seq, env: Optional[PricingEnv] = None,
           occupancy: Optional[FabricOccupancy] = None) -> "MeshMakespan":
        """Every outstanding axis of `seq` (cross-axis chains included),
        in first-issue order."""
        mm = cls(occupancy=occupancy)
        for axis in seq.axes_outstanding():
            mm.add(seq, axis, env)
        return mm

    def report(self) -> dict:
        """The composition, with its terms exposed for telemetry.

        {"mesh_makespan_s", "chain_s", "queues": [...], "links": {...}}
        — `queues` holds each registered queue's isolated makespan,
        `links` the per-physical-link busy seconds and capacity.
        """
        occ = self.occupancy
        queues = []
        entries = []   # (min_rid, item, full_s, lat_s, links)
        for seq, axis, env in self._queues:
            _comm, items, recs = seq._priced_plan(axis, env)
            own = seq._compose(items, recs) if items else 0.0
            queues.append({"axis": axis, "items": len(items),
                           "makespan_s": own})
            for it, (full, lat, _wire, links) in zip(items, recs):
                entries.append((min(r.rid for r in it.requests),
                                it, full, lat, links))
        # global dependency DAG: items in issue order, chains serialize
        # full costs across queues (the within-queue recurrence, widened)
        entries.sort(key=lambda e: e[0])
        pos = {r: i for i, e in enumerate(entries) for r in e[1].requests}
        chain = [0.0] * len(entries)
        for i, (_rid, it, full, _lat, _links) in enumerate(entries):
            best = 0.0
            for r in it.requests:
                for d in r.deps:
                    j = pos.get(d)
                    if j is not None and j < i:
                        best = max(best, chain[j])
            chain[i] = best + full
        # per-physical-link busy time: wire serializes on a shared link
        busy: dict = {}
        for _rid, _it, _full, _lat, links in entries:
            for key, w in links.items():
                ck = occ.canonical(key)
                busy[ck] = busy.get(ck, 0.0) + w
        max_lat = max((e[3] for e in entries), default=0.0)
        link_term = max(busy.values(), default=0.0) + max_lat
        terms = [q["makespan_s"] for q in queues]
        terms.append(max(chain, default=0.0))
        terms.append(link_term)
        return {
            "mesh_makespan_s": max(terms, default=0.0),
            "chain_s": max(chain, default=0.0),
            "queues": queues,
            "links": {k: {"busy_s": v, "capacity_Bps": occ.capacity(k)}
                      for k, v in busy.items()},
        }

    def total(self) -> float:
        """Contention-aware seconds to drain every registered queue."""
        return self.report()["mesh_makespan_s"]
