"""Mesh-level contention-aware makespan (the shared-engine cost view).

`Sequencer.makespan` prices ONE communicator's queue in isolation. Real
training/serving steps run grad-sync, pipeline p2p, and offloaded app
collectives concurrently over the same chips and fabrics — ACCL+'s whole
premise is the engine as a *shared* offload resource — and per-queue
isolation prices two saturating queues on one fabric as if they ran 2x
parallel. `MeshMakespan` composes ALL queues over the physical links
(`topology.FabricOccupancy`):

  mesh = max( max over queues of the queue's own makespan,
              max over GLOBAL dependency chains of sum(full_i),
              max over physical links of sum(wire on that link)
                  + max over items of latency_i )

  * Per-queue term: each queue still prices at least its own pipelined
    drain (`Sequencer._compose`) — composition never discounts below a
    queue running alone, and a single-queue mesh makespan is BITWISE
    equal to `Sequencer.makespan`.
  * Global chain term: dependency chains crossing communicators (e.g.
    `issue_multi`'s RS -> recurse -> AG over `("pod", "data")`) price as
    one DAG — full costs serialize along the chain exactly as within one
    queue, instead of each axis's FIFO pretending the other is free.
  * Link term: wire seconds attributed per physical link by
    `Program.cost_terms(per_link=True)` SERIALIZE when queues share the
    link (two saturating same-fabric queues price ~the serial sum), and
    stay independent on disjoint fabrics (the busiest link bounds).
    Queued alpha still hides: only the single largest item latency is
    added, the same credit the per-queue model grants.

All prices come from `Sequencer._priced_plan` — the same compiled
programs, the same `PricingEnv` — so the composition never re-walks a
program. Nothing here mutates queue state: composing is a read.
"""
from __future__ import annotations

from typing import Optional

from repro.core.pricing import PricingEnv
from repro.core.topology import FabricOccupancy


class MeshMakespan:
    """Composes many sequencer queues' prices over shared fabric links.

    Usage::

        mm = MeshMakespan()
        mm.add(seq_a, "data", env)      # one call per (queue, axis)
        mm.add(seq_b, "data", env)
        total = mm.total()              # contention-aware seconds

    or, for every outstanding axis of one sequencer::

        total = MeshMakespan.of(seq, env).total()
    """

    def __init__(self, occupancy: Optional[FabricOccupancy] = None):
        self.occupancy = occupancy if occupancy is not None \
            else FabricOccupancy()
        self._queues: list = []    # (sequencer, axis, env)

    def add(self, seq, axis, env: Optional[PricingEnv] = None
            ) -> "MeshMakespan":
        """Register one communicator queue; returns self for chaining."""
        self._queues.append((seq, axis,
                             env if env is not None else PricingEnv()))
        return self

    @classmethod
    def of(cls, seq, env: Optional[PricingEnv] = None,
           occupancy: Optional[FabricOccupancy] = None) -> "MeshMakespan":
        """Every outstanding axis of `seq` (cross-axis chains included),
        in first-issue order."""
        mm = cls(occupancy=occupancy)
        for axis in seq.axes_outstanding():
            mm.add(seq, axis, env)
        return mm

    def _composed(self) -> dict:
        """The full composition state, computed once.

        Every float here is produced by the exact operation sequence the
        original `report()` used — `report()` and `timeline()` are both
        thin views over this, so the timeline's last interval end equals
        `mesh_makespan_s` *bitwise*, not approximately.
        """
        occ = self.occupancy
        queues = []
        entries = []   # (min_rid, item, full_s, lat_s, wire_s, links, axis)
        for seq, axis, env in self._queues:
            _comm, items, recs = seq._priced_plan(axis, env)
            own = seq._compose(items, recs) if items else 0.0
            queues.append({"axis": axis, "items": len(items),
                           "makespan_s": own})
            for it, (full, lat, wire, links) in zip(items, recs):
                entries.append((min(r.rid for r in it.requests),
                                it, full, lat, wire, links, axis))
        # global dependency DAG: items in issue order, chains serialize
        # full costs across queues (the within-queue recurrence, widened)
        entries.sort(key=lambda e: e[0])
        pos = {r: i for i, e in enumerate(entries) for r in e[1].requests}
        chain = [0.0] * len(entries)
        starts = [0.0] * len(entries)
        for i, (_rid, it, full, _lat, _w, _links, _ax) in enumerate(entries):
            best = 0.0
            for r in it.requests:
                for d in r.deps:
                    j = pos.get(d)
                    if j is not None and j < i:
                        best = max(best, chain[j])
            starts[i] = best
            chain[i] = best + full
        # per-physical-link busy time: wire serializes on a shared link.
        # The cursor intervals ARE the accumulation: each item's window on
        # a link is [busy-so-far, busy-so-far + w], so the last window's
        # end is the final busy value, bitwise.
        busy: dict = {}
        link_iv = []   # (canonical_key, start_s, end_s, entry_index)
        for i, (_rid, _it, _full, _lat, _w, links, _ax) in \
                enumerate(entries):
            for key, w in links.items():
                ck = occ.canonical(key)
                start = busy.get(ck, 0.0)
                busy[ck] = start + w
                link_iv.append((ck, start, busy[ck], i))
        max_lat = max((e[3] for e in entries), default=0.0)
        link_term = max(busy.values(), default=0.0) + max_lat
        terms = [q["makespan_s"] for q in queues]
        terms.append(max(chain, default=0.0))
        terms.append(link_term)
        return {
            "mesh": max(terms, default=0.0),
            "chain": chain, "starts": starts, "entries": entries,
            "queues": queues, "busy": busy, "link_iv": link_iv,
            "max_lat": max_lat, "link_term": link_term,
        }

    def report(self) -> dict:
        """The composition, with its terms exposed for telemetry.

        {"mesh_makespan_s", "chain_s", "queues": [...], "links": {...}}
        — `queues` holds each registered queue's isolated makespan,
        `links` the per-physical-link busy seconds and capacity.
        """
        c = self._composed()
        occ = self.occupancy
        return {
            "mesh_makespan_s": c["mesh"],
            "chain_s": max(c["chain"], default=0.0),
            "queues": c["queues"],
            "links": {k: {"busy_s": v, "capacity_Bps": occ.capacity(k)}
                      for k, v in c["busy"].items()},
        }

    def timeline(self) -> dict:
        """Expand the composed makespan into virtual-clock intervals.

        Returns `{"end_s", "queues", "requests", "links"}` where every
        interval is `{"name", "track", "start_s", "end_s", ...}`:

        * one **queue** interval per registered queue ([0, own
          makespan]) on track `queue:<axis>`;
        * one **request** interval per plan item, chain-placed
          ([chain start, chain start + full]) with its wait/wire/lat
          split and coalesced flag;
        * one **link** interval per (item, physical link) — wire
          seconds serialized on the link's cursor — plus one trailing
          `alpha` interval on the busiest link for the queued-latency
          credit the link term adds.

        Feed it to `Tracer.ingest_timeline()` for Perfetto export.  The
        maximum `end_s` over all intervals equals
        `report()["mesh_makespan_s"]` **bitwise** (regression-gated in
        tests/test_telemetry.py): both are views over `_composed()`,
        which performs the float arithmetic exactly once.
        """
        from repro.core.telemetry import axis_label
        c = self._composed()
        queues = []
        for q in c["queues"]:
            queues.append({"name": "drain", "axis": q["axis"],
                           "track": f"queue:{axis_label(q['axis'])}",
                           "start_s": 0.0, "end_s": q["makespan_s"]})
        requests = []
        for i, (_rid, it, full, lat, wire, _links, axis) in \
                enumerate(c["entries"]):
            requests.append({
                "name": "request", "axis": axis,
                "track": f"queue:{axis_label(axis)}",
                "start_s": c["starts"][i], "end_s": c["chain"][i],
                "rids": [r.rid for r in it.requests],
                "full_s": full, "lat_s": lat, "wire_s": wire,
                "coalesced": len(it.requests) > 1,
            })
        links = []
        for ck, start, end, i in c["link_iv"]:
            links.append({
                "name": "wire", "link": ck,
                "track": "link:" + "/".join(str(p) for p in ck),
                "start_s": start, "end_s": end,
                "rids": [r.rid for r in c["entries"][i][1].requests],
            })
        if c["busy"]:
            # the queued-alpha credit: one max-latency term after the
            # busiest link drains, ending exactly at link_term
            busiest = max(c["busy"], key=lambda k: c["busy"][k])
            links.append({
                "name": "alpha", "link": busiest,
                "track": "link:" + "/".join(str(p) for p in busiest),
                "start_s": c["busy"][busiest], "end_s": c["link_term"],
                "rids": [],
            })
        return {"end_s": c["mesh"], "queues": queues,
                "requests": requests, "links": links}

    def total(self) -> float:
        """Contention-aware seconds to drain every registered queue."""
        return self.report()["mesh_makespan_s"]
