"""repro.core — the ACCL+ collective engine, TPU/JAX-native.

Public API:
    CollectiveEngine     the CCLO: MPI-like + streaming collectives
    execute_program      the one data plane: runs a compiled micro-op Program
    Selector             runtime-tunable algorithm/protocol selection
    Communicator         rank group over a mesh axis
    Schedule/Step/Sel    microcode IR (compiles to a Program)
    Program              the micro-op IR (core/program.py)
    Sequencer/Request    the collective offload queue (engine.issue(...))
    PricingEnv           the one bundle of pricing parameters (env=)
    MeshMakespan         contention-aware composition of many queues
    FabricOccupancy      per-chip physical-link capacity map
    FaultPlan/ReliabilityTier  fabric fault model + protocol tiers
    register_collective  out-of-tree collectives, no engine changes needed
    Tracer/MetricsRegistry  unified telemetry (core/telemetry.py):
                         virtual-clock traces + the stats registry
"""
from repro.core import compat  # installs the jax.shard_map polyfill first
from repro.core.engine import CollectiveEngine, execute_program
from repro.core.faults import (
    FaultPlan, FaultyTransport, PeerFailedError, ReliabilityTier, TIERS,
    TransportError, TransportTimeout,
)
from repro.core.mesh_cost import MeshMakespan
from repro.core.pricing import PricingEnv, resolve_env
from repro.core.program import Program, compile_schedule
from repro.core.plugins import register_collective, unregister_collective
from repro.core.selector import Selector, Choice
from repro.core.sequencer import Request, RequestCancelled, Sequencer
from repro.core.topology import (
    Communicator, FabricOccupancy, axis_comm, make_mesh,
)
from repro.core.schedule import Schedule, Step, Sel
from repro.core.hw_spec import HwSpec, TPU_V5E, ACCL_CLUSTER
from repro.core.telemetry import MetricsRegistry, NullTracer, StatsView, \
    Tracer
from repro.core import algorithms, faults, mesh_cost, plugins, pricing, \
    program, sequencer, simulator, telemetry

__all__ = [
    "CollectiveEngine", "execute_program", "Program", "compile_schedule",
    "register_collective", "unregister_collective", "Selector", "Choice",
    "Request", "RequestCancelled", "Sequencer",
    "PricingEnv", "resolve_env", "MeshMakespan", "FabricOccupancy",
    "FaultPlan", "FaultyTransport", "ReliabilityTier", "TIERS",
    "TransportError", "TransportTimeout", "PeerFailedError",
    "Communicator", "axis_comm", "make_mesh", "Schedule", "Step", "Sel",
    "HwSpec", "TPU_V5E", "ACCL_CLUSTER",
    "Tracer", "NullTracer", "MetricsRegistry", "StatsView",
    "algorithms", "faults",
    "mesh_cost", "plugins", "pricing", "program", "sequencer", "simulator",
    "telemetry", "compat",
]
