"""repro.core — the ACCL+ collective engine, TPU/JAX-native.

Public API:
    CollectiveEngine   the CCLO: MPI-like + streaming collectives
    Selector           runtime-tunable algorithm/protocol selection
    Communicator       rank group over a mesh axis
    Schedule/Step/Sel  microcode IR
"""
from repro.core import compat  # installs the jax.shard_map polyfill first
from repro.core.engine import CollectiveEngine, interpret_schedule
from repro.core.selector import Selector, Choice
from repro.core.topology import Communicator, axis_comm, make_mesh
from repro.core.schedule import Schedule, Step, Sel
from repro.core.hw_spec import HwSpec, TPU_V5E, ACCL_CLUSTER
from repro.core import algorithms, plugins, simulator

__all__ = [
    "CollectiveEngine", "interpret_schedule", "Selector", "Choice",
    "Communicator", "axis_comm", "make_mesh", "Schedule", "Step", "Sel",
    "HwSpec", "TPU_V5E", "ACCL_CLUSTER", "algorithms", "plugins", "simulator",
    "compat",
]
