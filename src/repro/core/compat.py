"""jax version compatibility shims.

The repo targets the modern `jax.shard_map(..., check_vma=...)` API, but the
pinned toolchain ships jax 0.4.x where shard_map still lives at
`jax.experimental.shard_map.shard_map` and the replication-check kwarg is
named `check_rep`. This module resolves whichever implementation exists and
normalizes the kwarg rename, then installs the wrapper as `jax.shard_map`
when the attribute is missing so call sites written against the modern API
(tests, benchmarks, examples) run unchanged on either version.
"""
from __future__ import annotations

import functools
import inspect

import jax

_NATIVE = getattr(jax, "shard_map", None)

if _NATIVE is not None:
    _IMPL = _NATIVE
else:
    from jax.experimental.shard_map import shard_map as _IMPL  # type: ignore

_IMPL_PARAMS = inspect.signature(_IMPL).parameters
# Which replication-check kwarg the resolved implementation understands.
_CHECK_KW = ("check_vma" if "check_vma" in _IMPL_PARAMS
             else "check_rep" if "check_rep" in _IMPL_PARAMS
             else None)


@functools.wraps(_IMPL)
def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None,
              check_rep=None, **kwargs):
    """`jax.shard_map` with the `check_vma`/`check_rep` rename absorbed.

    Accepts either kwarg spelling (first non-None wins) and forwards it
    under whatever name the installed jax understands; drops it entirely
    on versions with neither.
    """
    check = check_vma if check_vma is not None else check_rep
    if check is not None and _CHECK_KW is not None:
        kwargs[_CHECK_KW] = check
    return _IMPL(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                 **kwargs)


if _NATIVE is None:
    # Polyfill: let `jax.shard_map(...)` / `from jax import shard_map`
    # call sites work on 0.4.x once repro is imported.
    jax.shard_map = shard_map


if not hasattr(jax.tree, "flatten_with_path"):
    # jax 0.4.x keeps the *_with_path helpers in jax.tree_util only.
    import jax.tree_util as _tu

    def _flatten_with_path(tree, is_leaf=None):
        return _tu.tree_flatten_with_path(tree, is_leaf=is_leaf)

    def _map_with_path(f, tree, *rest, is_leaf=None):
        return _tu.tree_map_with_path(f, tree, *rest, is_leaf=is_leaf)

    jax.tree.flatten_with_path = _flatten_with_path
    jax.tree.map_with_path = _map_with_path
