"""Mesh topology: the TPU analogue of ACCL+'s communicator.

ACCL+ builds a `communicator` (rank list + session/queue-pair table held in
CCLO configuration memory). On TPU, the communicator is a named mesh axis.
This module owns:

  * the production mesh axes ("pod", "data", "model"),
  * rank-neighbour maps for schedule generation (rings, trees, hypercubes),
  * the physical-cost view of an axis (ICI vs DCN) used by the selector.

Schedule generators (core/algorithms.py) are expressed over a `Communicator`,
which knows only rank count and hop costs — exactly the information the
ACCL+ uC firmware reads from configuration memory.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax

from repro.core.hw_spec import HwSpec, TPU_V5E


def make_mesh(shape: Sequence[int], axes: Sequence[str]):
    """Mesh constructor with stable axis_types across jax versions."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(
                tuple(shape), tuple(axes),
                axis_types=(axis_type.Auto,) * len(tuple(axes)),
            )
        except TypeError:  # jax with AxisType but no axis_types kwarg
            pass
    return jax.make_mesh(tuple(shape), tuple(axes))


@dataclasses.dataclass(frozen=True)
class Communicator:
    """Rank group over one mesh axis (ACCL+ communicator analogue).

    `axis` is the shard_map axis name collectives run over; `size` its rank
    count. `is_dcn` marks pod-crossing axes (slower links) for the cost
    model. Hardware constants ride along so the selector can price
    schedules without global state.
    """

    axis: str
    size: int
    is_dcn: bool = False
    hw: HwSpec = TPU_V5E

    @property
    def link_bw(self) -> float:
        return self.hw.dcn_bw if self.is_dcn else self.hw.ici_link_bw

    @property
    def hop_latency(self) -> float:
        return self.hw.dcn_hop_latency if self.is_dcn else self.hw.ici_hop_latency

    @property
    def min_segment_bytes(self) -> float:
        """Per-fabric Rx-buffer floor for wire segmentation: the 10 us DCN
        alpha prices a far larger segment optimum than the ICI one."""
        return (self.hw.dcn_min_segment_bytes if self.is_dcn
                else self.hw.ici_min_segment_bytes)

    @property
    def eager_max_bytes(self) -> float:
        """Per-fabric eager-protocol cutoff (Rx staging-pool capacity)."""
        return (self.hw.dcn_eager_max_bytes if self.is_dcn
                else self.hw.ici_eager_max_bytes)

    def level_comm(self, level) -> "Communicator":
        """The communicator that prices exchanges tagged `level`.

        A flat communicator has one fabric, so every level resolves to
        itself; `ProductComm` overrides this to route "intra" exchanges to
        the inner (ICI) communicator and "inter" ones to the outer (DCN)
        communicator. `Program._cost_walk` calls this per exchange.
        """
        return self

    # -- neighbour maps used by schedule generators ------------------------
    def ring_perm(self, step: int = 1) -> list[tuple[int, int]]:
        """src->dst pairs rotating by `step` (bidirectional rings use ±1)."""
        n = self.size
        return [(i, (i + step) % n) for i in range(n)]

    def hypercube_perm(self, dim: int) -> list[tuple[int, int]]:
        """Pairwise exchange partners at hypercube dimension `dim`."""
        n = self.size
        if n & (n - 1):
            raise ValueError(f"hypercube needs power-of-two ranks, got {n}")
        return [(i, i ^ (1 << dim)) for i in range(n)]

    def tree_rounds(self, root: int = 0) -> list[list[tuple[int, int]]]:
        """Binomial-tree rounds of (src, dst) for broadcast from `root`.

        Round k doubles the informed set: ranks with id < 2^k (relative to
        root) send to id + 2^k. log2(n) rounds, n need not be a power of 2.
        """
        n = self.size
        rounds: list[list[tuple[int, int]]] = []
        informed = 1
        while informed < n:
            pairs = []
            for i in range(min(informed, n - informed)):
                src = (root + i) % n
                dst = (root + i + informed) % n
                pairs.append((src, dst))
            rounds.append(pairs)
            informed *= 2
        return rounds

    @property
    def log2_size(self) -> int:
        return int(math.log2(self.size))

    @property
    def is_pow2(self) -> bool:
        return self.size & (self.size - 1) == 0

    # -- graceful degradation ----------------------------------------------
    def shrunk(self, size: int) -> "Communicator":
        """The degraded communicator after ranks died: same axis and
        fabric, `size` survivors renumbered 0..size-1 (ACCL+ rebuilds
        the communicator's rank table in configuration memory; here the
        survivor list lives with the caller and the selector replans
        every queued collective against this smaller group)."""
        if not 1 <= int(size) <= self.size:
            raise ValueError(
                f"cannot shrink {self.size}-rank communicator to {size}")
        return dataclasses.replace(self, size=int(size))

    def without_ranks(self, dead) -> "Communicator":
        """`shrunk` keyed by the dead rank ids instead of the count."""
        dead = {int(r) for r in dead}
        bad = dead - set(range(self.size))
        if bad:
            raise ValueError(f"ranks {sorted(bad)} not in communicator")
        return self.shrunk(self.size - len(dead))

    # -- hierarchical factoring --------------------------------------------
    def factor(self, pod_size: int) -> "ProductComm":
        """Factor a flat communicator into a (pod x intra-pod) product.

        The outer level keeps this communicator's fabric (typically DCN)
        at `pod_size` ranks; the inner level is the remaining ICI group.
        Flat rank r maps inner-major: r = intra_rank * pod_size + pod_rank,
        so contiguous chunk ranges stay contiguous at both levels.
        """
        pod_size = int(pod_size)
        if pod_size < 1 or self.size % pod_size:
            raise ValueError(
                f"cannot factor {self.size} ranks into pods of {pod_size}")
        outer = dataclasses.replace(self, size=pod_size)
        inner = Communicator(
            axis=self.axis, size=self.size // pod_size,
            is_dcn=False, hw=self.hw,
        )
        return ProductComm(outer=outer, inner=inner)


@dataclasses.dataclass(frozen=True)
class ProductComm:
    """A two-level (outer x inner) product communicator.

    `outer` is the slow pod-crossing level (usually DCN), `inner` the
    fast intra-pod level (ICI). Flat rank numbering is inner-major:

        r = intra_rank * P + pod_rank      (P = outer.size)

    so every contiguous coarse chunk [i*P, (i+1)*P) belongs to intra
    rank i's pod-local shard. Delegating scalar properties report the
    outer (bottleneck) fabric so flat candidates priced over this comm
    see the slow link; `level_comm` routes per-exchange pricing to the
    correct level.
    """

    outer: Communicator
    inner: Communicator

    @property
    def size(self) -> int:
        return self.outer.size * self.inner.size

    @property
    def axis(self) -> str:
        return self.outer.axis

    @property
    def hw(self) -> HwSpec:
        return self.outer.hw

    # Bottleneck view: a flat algorithm over the product group crosses
    # the pod boundary, so price its links on the outer fabric.
    @property
    def is_dcn(self) -> bool:
        return self.outer.is_dcn

    @property
    def link_bw(self) -> float:
        return self.outer.link_bw

    @property
    def hop_latency(self) -> float:
        return self.outer.hop_latency

    @property
    def min_segment_bytes(self) -> float:
        return self.outer.min_segment_bytes

    @property
    def eager_max_bytes(self) -> float:
        return self.outer.eager_max_bytes

    @property
    def flat(self) -> Communicator:
        """The equivalent single-level communicator (bottleneck fabric)."""
        return Communicator(
            axis=self.outer.axis, size=self.size,
            is_dcn=self.outer.is_dcn, hw=self.outer.hw,
        )

    def level_comm(self, level) -> Communicator:
        if level == "intra":
            return self.inner
        if level == "inter":
            return self.outer
        return self.flat

    @property
    def is_pow2(self) -> bool:
        return self.size & (self.size - 1) == 0


def axis_comm(mesh, axis: str, hw: HwSpec = TPU_V5E) -> Communicator:
    """Build a Communicator for one axis of a jax Mesh.

    The axis→fabric map lives in `HwSpec.dcn_axes` (default: "pod"), so
    renamed or multiple pod-crossing axes price on DCN without editing
    this function.
    """
    return Communicator(
        axis=axis,
        size=mesh.shape[axis],
        is_dcn=(axis in hw.dcn_axes),
        hw=hw,
    )


def product_comm(mesh, outer_axis: str, inner_axis: str,
                 hw: HwSpec = TPU_V5E) -> ProductComm:
    """Product communicator over two mesh axes (outer = pod-crossing)."""
    return ProductComm(
        outer=axis_comm(mesh, outer_axis, hw),
        inner=axis_comm(mesh, inner_axis, hw),
    )
