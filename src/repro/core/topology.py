"""Mesh topology: the TPU analogue of ACCL+'s communicator.

ACCL+ builds a `communicator` (rank list + session/queue-pair table held in
CCLO configuration memory). On TPU, the communicator is a named mesh axis.
This module owns:

  * the production mesh axes ("pod", "data", "model"),
  * rank-neighbour maps for schedule generation (rings, trees, hypercubes),
  * the physical-cost view of an axis (ICI vs DCN) used by the selector.

Schedule generators (core/algorithms.py) are expressed over a `Communicator`,
which knows only rank count and hop costs — exactly the information the
ACCL+ uC firmware reads from configuration memory.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import jax

from repro.core.hw_spec import HwSpec, TPU_V5E


def make_mesh(shape: Sequence[int], axes: Sequence[str]):
    """Mesh constructor with stable axis_types across jax versions."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(
                tuple(shape), tuple(axes),
                axis_types=(axis_type.Auto,) * len(tuple(axes)),
            )
        except TypeError:  # jax with AxisType but no axis_types kwarg
            pass
    return jax.make_mesh(tuple(shape), tuple(axes))


@dataclasses.dataclass(frozen=True)
class Communicator:
    """Rank group over one mesh axis (ACCL+ communicator analogue).

    `axis` is the shard_map axis name collectives run over; `size` its rank
    count. `is_dcn` marks pod-crossing axes (slower links) for the cost
    model. Hardware constants ride along so the selector can price
    schedules without global state.

    `ranks` is the rank-id table (ACCL+ keeps exactly this list in CCLO
    configuration memory): local rank i is global rank `ranks[i]`. The
    default `None` means the identity mapping `0..size-1` — every
    pre-degradation communicator, so hashes/cache keys are unchanged.
    A degraded communicator built by `without_ranks` carries the
    surviving global ids, which need NOT be a prefix: survivor i keeps
    its global shard `ranks[i]` however mid-mesh the failure was.
    """

    axis: str
    size: int
    is_dcn: bool = False
    hw: HwSpec = TPU_V5E
    ranks: Optional[tuple] = None

    def __post_init__(self):
        if self.ranks is not None and len(self.ranks) != self.size:
            raise ValueError(
                f"rank table {self.ranks} does not match size {self.size}")

    @property
    def global_ranks(self) -> tuple:
        """Local -> global rank-id mapping (identity when undegraded)."""
        return self.ranks if self.ranks is not None \
            else tuple(range(self.size))

    @property
    def link_bw(self) -> float:
        return self.hw.dcn_bw if self.is_dcn else self.hw.ici_link_bw

    @property
    def hop_latency(self) -> float:
        return self.hw.dcn_hop_latency if self.is_dcn else self.hw.ici_hop_latency

    @property
    def min_segment_bytes(self) -> float:
        """Per-fabric Rx-buffer floor for wire segmentation: the 10 us DCN
        alpha prices a far larger segment optimum than the ICI one."""
        return (self.hw.dcn_min_segment_bytes if self.is_dcn
                else self.hw.ici_min_segment_bytes)

    @property
    def eager_max_bytes(self) -> float:
        """Per-fabric eager-protocol cutoff (Rx staging-pool capacity)."""
        return (self.hw.dcn_eager_max_bytes if self.is_dcn
                else self.hw.ici_eager_max_bytes)

    def level_comm(self, level) -> "Communicator":
        """The communicator that prices exchanges tagged `level`.

        A flat communicator has one fabric, so every level resolves to
        itself; `ProductComm` overrides this to route "intra" exchanges to
        the inner (ICI) communicator and "inter" ones to the outer (DCN)
        communicator. `Program._cost_walk` calls this per exchange.
        """
        return self

    # -- neighbour maps used by schedule generators ------------------------
    def ring_perm(self, step: int = 1) -> list[tuple[int, int]]:
        """src->dst pairs rotating by `step` (bidirectional rings use ±1)."""
        n = self.size
        return [(i, (i + step) % n) for i in range(n)]

    def hypercube_perm(self, dim: int) -> list[tuple[int, int]]:
        """Pairwise exchange partners at hypercube dimension `dim`."""
        n = self.size
        if n & (n - 1):
            raise ValueError(f"hypercube needs power-of-two ranks, got {n}")
        return [(i, i ^ (1 << dim)) for i in range(n)]

    def tree_rounds(self, root: int = 0) -> list[list[tuple[int, int]]]:
        """Binomial-tree rounds of (src, dst) for broadcast from `root`.

        Round k doubles the informed set: ranks with id < 2^k (relative to
        root) send to id + 2^k. log2(n) rounds, n need not be a power of 2.
        """
        n = self.size
        rounds: list[list[tuple[int, int]]] = []
        informed = 1
        while informed < n:
            pairs = []
            for i in range(min(informed, n - informed)):
                src = (root + i) % n
                dst = (root + i + informed) % n
                pairs.append((src, dst))
            rounds.append(pairs)
            informed *= 2
        return rounds

    @property
    def log2_size(self) -> int:
        return int(math.log2(self.size))

    @property
    def is_pow2(self) -> bool:
        return self.size & (self.size - 1) == 0

    # -- graceful degradation ----------------------------------------------
    def shrunk(self, size: int) -> "Communicator":
        """The degraded communicator after ranks died, keyed by survivor
        COUNT: same axis and fabric, the first `size` entries of the
        rank table kept (ACCL+ rebuilds the communicator's rank table
        in configuration memory). For dead ranks identified by id —
        including mid-mesh, non-prefix failures — use `without_ranks`,
        which keeps every survivor's global id so its data shard stays
        addressable."""
        if not 1 <= int(size) <= self.size:
            raise ValueError(
                f"cannot shrink {self.size}-rank communicator to {size}")
        ranks = None if self.ranks is None else self.ranks[:int(size)]
        return dataclasses.replace(self, size=int(size), ranks=ranks)

    def without_ranks(self, dead) -> "Communicator":
        """The degraded communicator with the CURRENT-local ranks `dead`
        removed: survivors renumber to 0..n-1 but keep their global ids
        in `ranks`, so non-contiguous survivors keep their data shards."""
        dead = {int(r) for r in dead}
        bad = dead - set(range(self.size))
        if bad:
            raise ValueError(f"ranks {sorted(bad)} not in communicator")
        survivors = tuple(g for i, g in enumerate(self.global_ranks)
                          if i not in dead)
        if not survivors:
            raise ValueError("cannot remove every rank")
        return dataclasses.replace(self, size=len(survivors),
                                   ranks=survivors)

    # -- hierarchical factoring --------------------------------------------
    def factor(self, pod_size: int) -> "ProductComm":
        """Factor a flat communicator into a (pod x intra-pod) product.

        The outer level keeps this communicator's fabric (typically DCN)
        at `pod_size` ranks; the inner level is the remaining ICI group.
        Flat rank r maps inner-major: r = intra_rank * pod_size + pod_rank,
        so contiguous chunk ranges stay contiguous at both levels.
        """
        pod_size = int(pod_size)
        if pod_size < 1 or self.size % pod_size:
            raise ValueError(
                f"cannot factor {self.size} ranks into pods of {pod_size}")
        outer = dataclasses.replace(self, size=pod_size, ranks=None)
        inner = Communicator(
            axis=self.axis, size=self.size // pod_size,
            is_dcn=False, hw=self.hw,
        )
        return ProductComm(outer=outer, inner=inner)


@dataclasses.dataclass(frozen=True)
class ProductComm:
    """A two-level (outer x inner) product communicator.

    `outer` is the slow pod-crossing level (usually DCN), `inner` the
    fast intra-pod level (ICI). Flat rank numbering is inner-major:

        r = intra_rank * P + pod_rank      (P = outer.size)

    so every contiguous coarse chunk [i*P, (i+1)*P) belongs to intra
    rank i's pod-local shard. Delegating scalar properties report the
    outer (bottleneck) fabric so flat candidates priced over this comm
    see the slow link; `level_comm` routes per-exchange pricing to the
    correct level.
    """

    outer: Communicator
    inner: Communicator

    @property
    def size(self) -> int:
        return self.outer.size * self.inner.size

    @property
    def axis(self) -> str:
        return self.outer.axis

    @property
    def hw(self) -> HwSpec:
        return self.outer.hw

    # Bottleneck view: a flat algorithm over the product group crosses
    # the pod boundary, so price its links on the outer fabric.
    @property
    def is_dcn(self) -> bool:
        return self.outer.is_dcn

    @property
    def link_bw(self) -> float:
        return self.outer.link_bw

    @property
    def hop_latency(self) -> float:
        return self.outer.hop_latency

    @property
    def min_segment_bytes(self) -> float:
        return self.outer.min_segment_bytes

    @property
    def eager_max_bytes(self) -> float:
        return self.outer.eager_max_bytes

    @property
    def flat(self) -> Communicator:
        """The equivalent single-level communicator (bottleneck fabric)."""
        return Communicator(
            axis=self.outer.axis, size=self.size,
            is_dcn=self.outer.is_dcn, hw=self.outer.hw,
        )

    def level_comm(self, level) -> Communicator:
        if level == "intra":
            return self.inner
        if level == "inter":
            return self.outer
        return self.flat

    @property
    def is_pow2(self) -> bool:
        return self.size & (self.size - 1) == 0


def axis_comm(mesh, axis: str, hw: HwSpec = TPU_V5E) -> Communicator:
    """Build a Communicator for one axis of a jax Mesh.

    The axis→fabric map lives in `HwSpec.dcn_axes` (default: "pod"), so
    renamed or multiple pod-crossing axes price on DCN without editing
    this function.
    """
    return Communicator(
        axis=axis,
        size=mesh.shape[axis],
        is_dcn=(axis in hw.dcn_axes),
        hw=hw,
    )


def product_comm(mesh, outer_axis: str, inner_axis: str,
                 hw: HwSpec = TPU_V5E) -> ProductComm:
    """Product communicator over two mesh axes (outer = pod-crossing)."""
    return ProductComm(
        outer=axis_comm(mesh, outer_axis, hw),
        inner=axis_comm(mesh, inner_axis, hw),
    )


@dataclasses.dataclass(frozen=True)
class FabricOccupancy:
    """The per-chip physical-link capacity map for mesh-level pricing.

    `Program.cost_terms(per_link=True)` attributes each program's wire
    seconds to link keys `("ici"|"dcn", axis)` — the fabric and mesh
    axis its bytes cross. This model says which of those keys name the
    SAME physical resource, so `core/mesh_cost.py` can serialize wire
    time across queues that share a link while leaving disjoint fabrics
    independent:

      * ICI: each mesh axis rides its own torus direction (a chip has
        `hw.ici_links_per_chip` ports), so `("ici", "data")` and
        `("ici", "model")` are distinct links — queues on different ICI
        axes overlap.
      * DCN: every pod-crossing axis funnels through the chip's ONE
        shared uplink, so all `("dcn", *)` keys canonicalize to
        `DCN_UPLINK` — any two DCN queues contend.
    """

    hw: HwSpec = TPU_V5E

    DCN_UPLINK = ("dcn", "uplink")

    def link_key(self, comm) -> tuple:
        """The link a (flat) communicator's wire bytes occupy."""
        return self.canonical(
            ("dcn" if comm.is_dcn else "ici", comm.axis))

    def canonical(self, key: tuple) -> tuple:
        """Collapse link keys naming one physical resource: every DCN
        key is the shared uplink; ICI keys stay per-axis directions."""
        return self.DCN_UPLINK if key[0] == "dcn" else key

    def capacity(self, key: tuple) -> float:
        """Bytes/s the physical link behind `key` can carry."""
        return (self.hw.dcn_bw if key[0] == "dcn"
                else self.hw.ici_link_bw)

    def ports(self) -> dict:
        """Per-chip port counts by fabric (ICI torus directions + the
        DCN uplink)."""
        return {"ici": self.hw.ici_links_per_chip, "dcn": 1}
