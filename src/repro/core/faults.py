"""Fault model for the transport layer: fault plans, reliability tiers,
and the per-run transport state the simulator threads through a drain.

ACCL+ runs the same collectives over fabrics with very different
reliability contracts (best-effort UDP, retransmitting TCP, RDMA).  This
module reproduces that axis as data:

* :class:`FaultPlan` — a deterministic, seedable description of what the
  fabric does wrong: per-exchange segment drops (probabilistic or an
  explicit schedule), link flaps (a (src, dst) window of guaranteed
  loss), and ranks that die outright after exchange N.
* :class:`ReliabilityTier` — the protocol-side response: how many times
  a lost segment is retransmitted, with what (virtual) backoff, and the
  pricing surcharge honest `cost_terms` should carry for the tier.
* :class:`FaultyTransport` — the mutable per-run object the simulator
  consults at every wire crossing.  It owns the global exchange counter
  and the retry loop, and accumulates virtual retry/backoff time so the
  sequencer can enforce per-request timeouts without any wall-clock.

Everything here is deterministic: drop decisions hash ``(seed, exchange,
src, dst, attempt)`` through ``numpy``'s Philox-seeded generator, so the
same plan produces the same faults regardless of rank iteration order.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core import telemetry

__all__ = [
    "TransportError", "TransportTimeout", "PeerFailedError",
    "ReliabilityTier", "TIERS", "FaultPlan", "FaultyTransport",
]


# ---------------------------------------------------------------------------
# Typed failures
# ---------------------------------------------------------------------------

class TransportError(RuntimeError):
    """Base class for typed transport failures (never a hang)."""


class TransportTimeout(TransportError):
    """A segment exhausted its retry budget (or a request its timeout)."""

    def __init__(self, msg, *, src=None, dst=None, exchange=None):
        super().__init__(msg)
        self.src, self.dst, self.exchange = src, dst, exchange


class PeerFailedError(TransportError):
    """A peer rank died; the collective cannot complete as planned."""

    def __init__(self, msg, *, rank, exchange=None):
        super().__init__(msg)
        self.rank, self.exchange = rank, exchange


# ---------------------------------------------------------------------------
# Reliability tiers
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ReliabilityTier:
    """Protocol-side reliability contract, mirroring ACCL+'s fabric tiers.

    ``max_retries`` bounds retransmissions per segment; ``backoff_base``
    seconds double (``backoff_factor``) per attempt up to ``backoff_cap``.
    All time here is *virtual* — it feeds the priced makespan and the
    simulated per-request timeout, never a wall clock.
    """

    name: str
    max_retries: int
    backoff_base: float = 2e-6
    backoff_factor: float = 2.0
    backoff_cap: float = 1e-3

    def backoff(self, attempt: int) -> float:
        """Virtual seconds to wait before retry number ``attempt`` (1-based)."""
        if attempt <= 0:
            return 0.0
        return min(self.backoff_base * self.backoff_factor ** (attempt - 1),
                   self.backoff_cap)

    def backoff_schedule(self, n: int | None = None) -> tuple:
        """The deterministic backoff sequence for ``n`` retries."""
        n = self.max_retries if n is None else n
        return tuple(self.backoff(a) for a in range(1, n + 1))

    def expected_transmissions(self, drop_prob: float) -> float:
        """E[wire crossings per segment] under i.i.d. drop probability.

        Truncated geometric: with R retries allowed, the segment is sent
        ``1 + min(failures, R)`` times, so E = (1 - p^(R+1)) / (1 - p).
        """
        p = float(drop_prob)
        if p <= 0.0:
            return 1.0
        if p >= 1.0:
            return float(self.max_retries + 1)
        return (1.0 - p ** (self.max_retries + 1)) / (1.0 - p)

    def expected_backoff(self, drop_prob: float) -> float:
        """E[virtual backoff seconds per segment] under drop prob ``p``."""
        p = float(drop_prob)
        if p <= 0.0:
            return 0.0
        # Retry a happens iff the first a transmissions all dropped.
        return sum(self.backoff(a) * min(p, 1.0) ** a
                   for a in range(1, self.max_retries + 1))


#: Named tiers after the three ACCL+ fabric protocols.  ``udp-like`` is
#: fire-and-forget (one shot, loss is terminal); ``tcp-like`` retransmits
#: with exponential backoff; ``rdma-like`` assumes a lossless fabric with
#: a tight retry bound for the rare corrupt segment.
TIERS = {
    "udp-like": ReliabilityTier("udp-like", max_retries=0),
    "tcp-like": ReliabilityTier("tcp-like", max_retries=5,
                                backoff_base=2e-6, backoff_cap=1e-3),
    "rdma-like": ReliabilityTier("rdma-like", max_retries=2,
                                 backoff_base=1e-6, backoff_cap=1e-5),
}


# ---------------------------------------------------------------------------
# Fault plans
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FaultPlan:
    """Deterministic, seedable description of fabric misbehaviour.

    * ``drop_prob`` — i.i.d. probability that any (exchange, src, dst,
      attempt) wire crossing drops its segment.
    * ``drops`` — explicit schedule of ``(exchange, src, dst)`` first-
      attempt drops (retries of a scheduled drop go through, so a
      retrying tier always recovers from these).
    * ``flaps`` — ``(src, dst, start, end)`` windows (end exclusive, in
      global exchange numbers) during which the link loses everything.
    * ``dead`` — ``(rank, after_exchange)`` pairs: the rank fails after
      that many exchanges have completed and never speaks again.
    """

    seed: int = 0
    drop_prob: float = 0.0
    drops: frozenset = frozenset()
    flaps: tuple = ()
    dead: tuple = ()

    def dead_at(self, exchange: int):
        """Ranks that are dead once the global exchange counter is ``exchange``."""
        return frozenset(r for (r, after) in self.dead if exchange >= after)

    def link_flapped(self, src: int, dst: int, exchange: int) -> bool:
        return any(s == src and d == dst and start <= exchange < end
                   for (s, d, start, end) in self.flaps)

    def drops_segment(self, exchange: int, src: int, dst: int,
                      attempt: int) -> bool:
        """Deterministic drop decision for one wire crossing attempt.

        Keyed on the full coordinate so the outcome is independent of
        the order ranks are simulated in, and so retries re-roll.
        """
        if self.link_flapped(src, dst, exchange):
            return True
        if attempt == 0 and (exchange, src, dst) in self.drops:
            return True
        if self.drop_prob <= 0.0:
            return False
        rng = np.random.default_rng((self.seed, exchange, src, dst, attempt))
        return bool(rng.random() < self.drop_prob)


# ---------------------------------------------------------------------------
# Per-run transport state
# ---------------------------------------------------------------------------

@dataclass
class FaultyTransport:
    """Mutable transport state for one simulated drain.

    The simulator calls :meth:`deliver` once per (src, dst) pair at every
    exchange and :meth:`advance` once per exchange; this object applies
    the plan, runs the tier's retry loop, and accumulates virtual time.
    """

    plan: FaultPlan
    tier: ReliabilityTier = field(default_factory=lambda: TIERS["tcp-like"])
    exchange: int = 0
    retries: int = 0
    backoff_s: float = 0.0

    def check_rank(self, rank: int):
        """Raise :class:`PeerFailedError` if ``rank`` is dead right now."""
        if rank in self.plan.dead_at(self.exchange):
            raise PeerFailedError(
                f"rank {rank} dead at exchange {self.exchange}",
                rank=rank, exchange=self.exchange)

    def deliver(self, src: int, dst: int) -> None:
        """Decide the fate of one segment crossing src→dst.

        Returns normally iff the segment (eventually) arrives intact —
        the caller then writes the *original* payload, which is what
        makes retried runs bitwise-identical to fault-free ones.  Raises
        a typed error otherwise, before any buffer is written.
        """
        dead = self.plan.dead_at(self.exchange)
        for rank in (src, dst):
            if rank in dead:
                raise PeerFailedError(
                    f"rank {rank} dead at exchange {self.exchange}",
                    rank=rank, exchange=self.exchange)
        for attempt in range(self.tier.max_retries + 1):
            if not self.plan.drops_segment(self.exchange, src, dst, attempt):
                if attempt:
                    back = sum(self.tier.backoff(a)
                               for a in range(1, attempt + 1))
                    self.retries += attempt
                    self.backoff_s += back
                    tr = telemetry.current()
                    if tr.enabled:
                        tr.instant("transport.retry", track="transport",
                                   src=src, dst=dst,
                                   exchange=self.exchange,
                                   retries=attempt, backoff_s=back,
                                   tier=self.tier.name)
                return
        back = sum(self.tier.backoff(a)
                   for a in range(1, self.tier.max_retries + 1))
        self.retries += self.tier.max_retries
        self.backoff_s += back
        tr = telemetry.current()
        if tr.enabled:
            tr.instant("transport.timeout", track="transport",
                       src=src, dst=dst, exchange=self.exchange,
                       retries=self.tier.max_retries, backoff_s=back,
                       tier=self.tier.name)
        raise TransportTimeout(
            f"segment {src}->{dst} lost after "
            f"{self.tier.max_retries + 1} attempts at exchange {self.exchange}",
            src=src, dst=dst, exchange=self.exchange)

    def advance(self) -> None:
        """Bump the global exchange counter (one call per exchange round)."""
        self.exchange += 1

    def penalty_s(self, alpha: float) -> float:
        """Virtual seconds added by retries so far: resent-alpha + backoff."""
        return self.retries * alpha + self.backoff_s
