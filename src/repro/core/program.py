"""Micro-op IR — the fixed primitive set of the collective data plane.

ACCL+'s central design point (§4.2–4.4) is that collectives are software-
defined microprograms executed by ONE fixed engine over a small set of
DMA/packetizer primitives; new collectives deploy without re-synthesizing
the circuit. This module is that contract for our reproduction:

  Schedule  (algorithm layer: what moves where, pure data + rank closures)
     |  compile_schedule()                (the "firmware assembler")
     v
  Program   (this module: a linear list of micro-ops)
     |  engine.execute_program()          (XLA data plane)
     |  simulator.execute_program()       (numpy bus-functional model)

The primitive set:

  COPY          local DMA move: stage a selected region ("load"), or the
                Bruck pre/post chunk rotations.
  COMPRESS      unary streaming plugin: staged payload -> wire format.
  SEND          the Tx/Rx system crossing: ppermute every wire leaf.
  DECOMPRESS    wire format -> payload (receiver side of the codec).
  RECV_COMBINE  binary streaming plugin: combine the arrived payload into
                the local buffer region named by recv_sel.
  SEG_LOOP      Rx-buffer pipelining (§4.4.3): run one exchange's ops per
                wire segment, double-buffered — segment s+1 rides the wire
                while segment s runs through the combine plugin.
  LOOP          rolled execution of a uniform run of steps (one lax.scan
                in the XLA executor). This is what keeps O(n)-step rings
                at O(1) live buffers: unrolling a 16-rank ring produces 15
                full-buffer dynamic-update-slice chains whose arenas XLA
                cannot always alias.

Both executors run the same Program object, so oracle parity in the numpy
simulator covers the real code path, not a parallel reimplementation.

Per-segment scale reuse (codecs): block codecs (int8) quantize in fixed
element blocks. `fit_segments` only admits segment counts whose per-
segment flat length is a whole number of codec blocks, so every scale
block is computed from exactly the elements it would see unsegmented —
segmented compressed wires are bitwise-identical to unsegmented ones.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.schedule import (
    SEL_ALL, SEL_CHUNK, SEL_MASK, SEL_RANGE, Schedule, Sel, Step,
)

# Payload sources a COPY("load") may read (the schedule's relay modes).
SRC_BUFFER = "buffer"
SRC_ORIGINAL = "original"
SRC_RECEIVED = "received"


# --------------------------------------------------------------------------
# Micro-ops
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Copy:
    """Local DMA move. kind='load' stages `sel` of `source` as the wire
    payload; kind='bruck_pre'/'bruck_post' rotate the buffer's chunks."""

    kind: str                      # 'load' | 'bruck_pre' | 'bruck_post'
    sel: Optional[Sel] = None      # load only
    source: str = SRC_BUFFER       # load only
    step: Optional[int] = None     # static step index; None inside a LOOP


@dataclasses.dataclass(frozen=True)
class Compress:
    codec: str


@dataclasses.dataclass(frozen=True)
class Send:
    perm: tuple                    # (src, dst) pairs, one collective-permute


@dataclasses.dataclass(frozen=True)
class Decompress:
    codec: str


@dataclasses.dataclass(frozen=True)
class RecvCombine:
    op: str
    sel: Sel
    step: Optional[int] = None     # static step index; None inside a LOOP
    dsts: Optional[tuple] = None   # mask_recv: ranks that actually receive
    track_recv: bool = False       # relay='received': keep the raw arrival


@dataclasses.dataclass(frozen=True)
class SegLoop:
    """One exchange pipelined over `segments` wire segments.

    body = (Copy('load'), [Compress], Send, [Decompress], RecvCombine).
    The executor clamps `segments` to a divisor of the payload that keeps
    codec scale blocks intact (see `fit_segments`) and falls back to a
    single segment when the recv region cannot mirror the payload.
    """

    segments: int
    body: tuple


@dataclasses.dataclass(frozen=True)
class Loop:
    """`trip` iterations of `period` interleaved exchange slots.

    Iteration i, slot j executes the exchange for schedule step
    `base + i * period + j` with a *traced* step index. Semantics: every
    slot's payload and combine target are read from the iteration-start
    buffer and all region writes are applied at iteration end — uniform
    runs must therefore write disjoint regions within one iteration
    (rings do: each direction owns its chunk half), which is what lets
    XLA schedule the slots' permutes on independent links concurrently.
    """

    base: int
    trip: int
    period: int
    slots: tuple                   # tuple[tuple[micro-op, ...], ...]


@dataclasses.dataclass(frozen=True)
class Program:
    """A compiled collective: schedule metadata + linear micro-op list."""

    name: str
    collective: str
    nranks: int
    chunks: int
    relay: str
    segments: int
    codec: Optional[str]
    ops: tuple

    def describe(self) -> str:
        """One line per op — the firmware disassembly (tests, debugging)."""
        out = []
        for op in self.ops:
            if isinstance(op, Loop):
                inner = "; ".join(
                    ",".join(type(o).__name__ for o in slot)
                    for slot in op.slots)
                out.append(f"LOOP x{op.trip} period={op.period} [{inner}]")
            elif isinstance(op, SegLoop):
                inner = ",".join(type(o).__name__ for o in op.body)
                out.append(f"SEG_LOOP k={op.segments} [{inner}]")
            else:
                out.append(type(op).__name__.upper())
        return "\n".join(out)


# --------------------------------------------------------------------------
# Segment fitting (shared by both executors)
# --------------------------------------------------------------------------

def fit_segments(seg_len: int, segments, row_elems: int = 1,
                 block: int = 1) -> int:
    """Largest k <= segments that divides seg_len (>= 1), such that each
    segment's flat element count (seg_len/k * row_elems) is a whole number
    of codec `block`s.

    Segment counts come from the selector as a preference; the data plane
    clamps to a divisor of the payload length so segments stay equal-sized
    (halving mirrors the pow2 candidate ladder). The block constraint is
    the per-segment scale-reuse rule: a scale block never straddles a
    segment boundary, so segmented codec numerics == unsegmented.
    """
    k = max(1, int(segments or 1))
    k = min(k, max(1, seg_len))
    while k > 1 and (seg_len % k
                     or (seg_len // k * row_elems) % block):
        k -= 1
    return k


# --------------------------------------------------------------------------
# Compiler
# --------------------------------------------------------------------------

def _step_segmentable(step: Step, relay: str) -> bool:
    if step.segmentable is False:
        return False
    send_k, recv_k = step.send_sel.kind, step.recv_sel.kind
    if SEL_MASK in (send_k, recv_k):
        # non-contiguous regions segment only when the algorithm asserts
        # the send/recv masks are identical (Step.segmentable=True): the
        # gathered payload is then cut into wire segments and the combined
        # segments scattered back chunk-by-chunk.
        return bool(step.segmentable) and send_k == recv_k == SEL_MASK
    return True


def _exchange_ops(step: Step, relay: str, step_idx: Optional[int],
                  k_req: int, codec: Optional[str]) -> tuple:
    """The micro-op sequence for one schedule step."""
    ops = [Copy("load", sel=step.send_sel, source=relay, step=step_idx)]
    if codec is not None and step.op != "copy":
        # codecs compress the wire of combine exchanges (the RS phase);
        # copy-only relays ship already-reduced chunks uncompressed, the
        # same rule the hand-written rings applied.
        ops.append(Compress(codec))
        ops.append(Send(tuple(step.perm)))
        ops.append(Decompress(codec))
    else:
        ops.append(Send(tuple(step.perm)))
    dsts = tuple(sorted(d for (_s, d) in step.perm)) if step.mask_recv \
        else None
    ops.append(RecvCombine(op=step.op, sel=step.recv_sel, step=step_idx,
                           dsts=dsts, track_recv=(relay == SRC_RECEIVED)))
    seq = tuple(ops)
    if k_req > 1 and _step_segmentable(step, relay):
        return (SegLoop(k_req, seq),)
    return seq


def _detect_run(steps: tuple, i: int) -> Optional[tuple]:
    """Maximal uniform run at `steps[i:]` -> (trip, period) or None.

    A run of trip >= 2 iterations of `period` slots coalesces into a LOOP
    when every participating step is `uniform` (traceable step-indexed
    selectors shared across the run), does not mask receivers, and — for
    period > 1 — writes an offset region (chunk/range) so the deferred
    per-iteration writes stay well-defined.
    """
    for period in (1, 2):
        if i + 2 * period > len(steps):
            continue
        slots = steps[i:i + period]
        if not all(s.uniform and not s.mask_recv for s in slots):
            continue
        if period > 1 and any(s.recv_sel.kind not in (SEL_CHUNK, SEL_RANGE)
                              for s in slots):
            continue
        sigs = [s.signature() for s in slots]
        trip = 1
        while True:
            base = i + trip * period
            if base + period > len(steps):
                break
            if all(steps[base + j].signature() == sigs[j]
                   for j in range(period)):
                trip += 1
            else:
                break
        if trip >= 2:
            return trip, period
    return None


def split_exchange(node) -> tuple:
    """(body, k_req) of an exchange node — a SegLoop (possibly the sole
    element of a LOOP slot tuple) or a plain micro-op tuple. The one
    IR-shape helper both executors use to walk a Program."""
    if isinstance(node, tuple) and len(node) == 1 \
            and isinstance(node[0], SegLoop):
        node = node[0]
    if isinstance(node, SegLoop):
        return node.body, node.segments
    return node, 1


# Schedules hash their Sel closures by identity, so freshly generated
# (structurally identical) schedules never share entries: bound the cache
# so long-lived processes compiling transient schedules (benchmark loops,
# simulator harnesses) don't grow it without limit. Steady-state engine
# use hits via the upstream schedule caches, far below this bound.
_COMPILE_CACHE: dict = {}
_COMPILE_CACHE_MAX = 512


def compile_schedule(schedule: Schedule, segments: Optional[int] = None,
                     codec: Optional[str] = None) -> Program:
    """Lower a Schedule to a Program (memoized — compilation is trace-time
    control-plane work, like the uC caching assembled microcode)."""
    k_req = int(segments if segments is not None else schedule.segments)
    if k_req < 1:
        raise ValueError(f"segments must be >= 1, got {k_req}")
    key = (schedule, k_req, codec)
    hit = _COMPILE_CACHE.get(key)
    if hit is not None:
        return hit

    ops: list = []
    if schedule.pre_rotate == "bruck":
        ops.append(Copy("bruck_pre"))
    steps = schedule.steps
    i = 0
    while i < len(steps):
        run = _detect_run(steps, i)
        if run is not None:
            trip, period = run
            slot_ops = tuple(
                _exchange_ops(steps[i + j], schedule.relay, None, k_req,
                              codec)
                for j in range(period))
            ops.append(Loop(base=i, trip=trip, period=period,
                            slots=slot_ops))
            i += trip * period
        else:
            ops.extend(_exchange_ops(steps[i], schedule.relay, i, k_req,
                                     codec))
            i += 1
    if schedule.post_rotate == "bruck":
        ops.append(Copy("bruck_post"))

    prog = Program(
        name=schedule.name, collective=schedule.collective,
        nranks=schedule.nranks, chunks=schedule.chunks,
        relay=schedule.relay, segments=k_req, codec=codec,
        ops=tuple(ops))
    if len(_COMPILE_CACHE) >= _COMPILE_CACHE_MAX:
        _COMPILE_CACHE.pop(next(iter(_COMPILE_CACHE)))  # FIFO eviction
    _COMPILE_CACHE[key] = prog
    return prog
