"""Micro-op IR — the fixed primitive set of the collective data plane.

ACCL+'s central design point (§4.2–4.4) is that collectives are software-
defined microprograms executed by ONE fixed engine over a small set of
DMA/packetizer primitives; new collectives deploy without re-synthesizing
the circuit. This module is that contract for our reproduction:

  Schedule  (algorithm layer: what moves where, pure data + rank closures)
     |  compile_schedule()                (the "firmware assembler")
     v
  Program   (this module: a linear list of micro-ops)
     |  engine.execute_program()          (XLA data plane)
     |  simulator.execute_program()       (numpy bus-functional model)

The primitive set:

  COPY          local DMA move: stage a selected region ("load"), or the
                Bruck pre/post chunk rotations.
  COMPRESS      unary streaming plugin: staged payload -> wire format.
  SEND          the Tx/Rx system crossing: ppermute every wire leaf.
  DECOMPRESS    wire format -> payload (receiver side of the codec).
  RECV_COMBINE  binary streaming plugin: combine the arrived payload into
                the local buffer region named by recv_sel.
  SEG_LOOP      Rx-buffer pipelining (§4.4.3): run one exchange's ops per
                wire segment, double-buffered — segment s+1 rides the wire
                while segment s runs through the combine plugin.
  LOOP          rolled execution of a uniform run of steps (one lax.scan
                in the XLA executor). This is what keeps O(n)-step rings
                at O(1) live buffers: unrolling a 16-rank ring produces 15
                full-buffer dynamic-update-slice chains whose arenas XLA
                cannot always alias.
  STREAM        cross-step segment streaming (§4.4.3, the CCLO's hop-to-hop
                pipelining): a uniform run of segmented exchanges fused
                into ONE skewed software pipeline — step s+1's segment 0
                rides the wire before step s's tail segment combines. The
                `fuse_streams` pass rewrites eligible LOOPs of SEG_LOOP
                slots into this; it is bitwise-equal to the unfused form.
  STREAM_CHAIN  the same hop-to-hop pipeline over a run of DISTINCT
                unrolled segmented steps (recursive halving/doubling,
                linear all-to-all): the `fuse_chains` pass proves, per
                rank and per step boundary, that the out-of-order head
                segment never reads a region the previous step's missing
                tail write would have changed (the SEL_RANGE region-
                overlap proof), then chains the steps into one wave
                pipeline — also bitwise-equal to the unfused form.
  STACKED_RECV  the stacked-receive peephole: a run of relay='original'
                copy exchanges (explicit linear all-to-all) whose arrivals
                are written back with ONE chunk scatter instead of n-1
                full-buffer dynamic-update-slices.

Both executors run the same Program object, so oracle parity in the numpy
simulator covers the real code path, not a parallel reimplementation.

The Program is also the unit of COST: `Program.cost(msg_bytes, comm)`
walks the compiled ops (LOOP trip counts, per-op codec wire bytes,
per-fabric alpha and Rx segment floors) under a SPLIT pipelining model:

  * exchanges inside a STREAM / STREAM_CHAIN region earn the cross-step
    fill/drain credit — per region, sum_i t_i + (k - 1) * max_i t_i with
    t_i = alpha + wire_i / (k * bw) — because the executor really does
    send step s+1's head segment before step s's tail combine there;
  * every other exchange (SEG_LOOP, rolled-but-unstreamed LOOP slots,
    unrolled steps) pipelines only WITHIN its step — the SEG_LOOP scan
    carry is a per-step barrier — so it is priced serialized:
    k * t_seg = k * alpha + wire / bw per step, never cheaper than
    unsegmented.

The selector therefore stops auto-picking segmentation where execution
cannot cash the overlap; the credit is earned exactly where a fusion pass
proved the reorder safe. The schedule-walk `predict_time` is retired.

Per-segment scale reuse (codecs): block codecs (int8) quantize in fixed
element blocks. `fit_segments` only admits segment counts whose per-
segment flat length is a whole number of codec blocks, so every scale
block is computed from exactly the elements it would see unsegmented —
segmented compressed wires are bitwise-identical to unsegmented ones.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core import telemetry
from repro.core.schedule import (
    SEL_ALL, SEL_CHUNK, SEL_MASK, SEL_RANGE, Schedule, Sel, Step,
)

# Payload sources a COPY("load") may read (the schedule's relay modes).
SRC_BUFFER = "buffer"
SRC_ORIGINAL = "original"
SRC_RECEIVED = "received"


# --------------------------------------------------------------------------
# Micro-ops
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Copy:
    """Local DMA move. kind='load' stages `sel` of `source` as the wire
    payload; kind='bruck_pre'/'bruck_post' rotate the buffer's chunks."""

    kind: str                      # 'load' | 'bruck_pre' | 'bruck_post'
    sel: Optional[Sel] = None      # load only
    source: str = SRC_BUFFER       # load only
    step: Optional[int] = None     # static step index; None inside a LOOP


@dataclasses.dataclass(frozen=True)
class Compress:
    codec: str


@dataclasses.dataclass(frozen=True)
class Send:
    perm: tuple                    # (src, dst) pairs, one collective-permute
    # fraction of the full message this crossing moves per rank — the
    # static cost term the alpha-beta walk (`Program.cost`) prices.
    bytes_frac: float = 1.0
    # Two-level programs: which level's fabric this crossing rides
    # ("intra" | "inter", None = the communicator's own fabric) and the
    # permutation in that level's rank space (the engine ppermutes this
    # on the level's own mesh axis; `perm` stays the flat-rank pairs the
    # simulator executes).
    level: Optional[str] = None
    level_perm: Optional[tuple] = None


@dataclasses.dataclass(frozen=True)
class Decompress:
    codec: str


@dataclasses.dataclass(frozen=True)
class RecvCombine:
    op: str
    sel: Sel
    step: Optional[int] = None     # static step index; None inside a LOOP
    dsts: Optional[tuple] = None   # mask_recv: ranks that actually receive
    track_recv: bool = False       # relay='received': keep the raw arrival


@dataclasses.dataclass(frozen=True)
class SegLoop:
    """One exchange pipelined over `segments` wire segments.

    body = (Copy('load'), [Compress], Send, [Decompress], RecvCombine).
    The executor clamps `segments` to a divisor of the payload that keeps
    codec scale blocks intact (see `fit_segments`) and falls back to a
    single segment when the recv region cannot mirror the payload.
    """

    segments: int
    body: tuple


@dataclasses.dataclass(frozen=True)
class Loop:
    """`trip` iterations of `period` interleaved exchange slots.

    Iteration i, slot j executes the exchange for schedule step
    `base + i * period + j` with a *traced* step index. Semantics: every
    slot's payload and combine target are read from the iteration-start
    buffer and all region writes are applied at iteration end — uniform
    runs must therefore write disjoint regions within one iteration
    (rings do: each direction owns its chunk half), which is what lets
    XLA schedule the slots' permutes on independent links concurrently.
    """

    base: int
    trip: int
    period: int
    slots: tuple                   # tuple[tuple[micro-op, ...], ...]


@dataclasses.dataclass(frozen=True)
class Stream:
    """Cross-step segment streaming: a uniform run of `trip` iterations of
    `period` segmented exchanges fused into one skewed software pipeline.

    Each slot's body is the PLAIN (unsegmented) exchange tuple — the
    segment count lives on the Stream. Execution order is by segment
    wave g = iteration * segments + segment: wave g's arrivals combine
    while wave g+1's payloads are already on the wire, so step s+1's
    segment 0 crosses the Tx/Rx system before step s's tail combine —
    the hop-to-hop pipelining of the CCLO (§4.4.3) that SEG_LOOP alone
    cannot reach (its scan carry is a per-step barrier).

    `fuse_streams` only emits a Stream when the wave order is provably
    value-identical to the per-step order (chunk-aligned regions, or
    payloads read from the immutable original / the relay register), so
    streamed programs are bitwise-equal to their unfused form.
    """

    base: int
    trip: int
    period: int
    segments: int
    slots: tuple                   # tuple[tuple[micro-op, ...], ...]


@dataclasses.dataclass(frozen=True)
class StreamChain:
    """Cross-step segment streaming over a run of DISTINCT unrolled steps.

    Where STREAM fuses a *uniform* run (one slot body, a traced step
    index), STREAM_CHAIN fuses a run of unrolled segmented exchanges that
    differ per step — recursive halving/doubling's shrinking/growing
    SEL_RANGE windows, linear all-to-all's per-step ring shifts. Each
    body is the PLAIN (unsegmented) exchange tuple with its static step
    index; the segment count lives on the chain. Execution order is the
    wave sequence [(step, segment)] in step-major order with a skew of
    one: wave w+1's payload goes on the wire before wave w's combine, so
    step s+1's segment 0 crosses the Tx/Rx system while step s's tail
    segment is still in the combine plugin.

    `fuse_chains` only emits a chain when the compile-time region-overlap
    proof holds for EVERY rank: each step's payload region is disjoint
    from its own combine region, and the head segment of step s+1's
    payload is disjoint from the tail segment of step s's combine region
    (the only write the skew leaves unapplied). The executor re-verifies
    the proof at trace time against the segment counts the payload
    actually admits and falls back to per-step execution when clamping
    invalidated it — streamed chains are bitwise-equal to their unfused
    form.
    """

    segments: int
    bodies: tuple                  # tuple[tuple[micro-op, ...], ...]


@dataclasses.dataclass(frozen=True)
class StackedRecv:
    """A run of relay='original' copy exchanges with one stacked write.

    Every body is a plain (Copy('load'), Send, RecvCombine) triple whose
    payload reads the immutable original buffer, so all sends are
    independent of the receive order: the executor issues every permute,
    stacks the arrivals, and scatters them into the chunk grid in ONE
    gather-style update instead of n-1 full-buffer update-slices (the
    retired hand-written linear all-to-all's trick, now a compiler
    peephole). The pass verifies the receive chunks are distinct per
    rank, so the scatter is write-disjoint.
    """

    bodies: tuple                  # tuple[(Copy, Send, RecvCombine), ...]


@dataclasses.dataclass(frozen=True)
class Program:
    """A compiled collective: schedule metadata + linear micro-op list."""

    name: str
    collective: str
    nranks: int
    chunks: int
    relay: str
    segments: int
    codec: Optional[str]
    ops: tuple
    # >1 when uniform slots use independent links concurrently (bidi ring);
    # carried from the schedule so the cost walk needs no schedule access.
    overlap_factor: float = 1.0
    # Two-level programs: (("inter", P), ("intra", M)) level rank counts,
    # carried from the schedule; None for flat programs.
    level_sizes: Optional[tuple] = None

    def describe(self) -> str:
        """One line per op — the firmware disassembly (tests, debugging)."""
        out = []
        for op in self.ops:
            if isinstance(op, Loop):
                inner = "; ".join(
                    ",".join(type(o).__name__ for o in slot)
                    for slot in op.slots)
                out.append(f"LOOP x{op.trip} period={op.period} [{inner}]")
            elif isinstance(op, Stream):
                inner = "; ".join(
                    ",".join(type(o).__name__ for o in slot)
                    for slot in op.slots)
                out.append(f"STREAM x{op.trip} k={op.segments} "
                           f"period={op.period} [{inner}]")
            elif isinstance(op, StreamChain):
                out.append(f"STREAM_CHAIN k={op.segments} "
                           f"m={len(op.bodies)}")
            elif isinstance(op, StackedRecv):
                out.append(f"STACKED_RECV m={len(op.bodies)}")
            elif isinstance(op, SegLoop):
                inner = ",".join(type(o).__name__ for o in op.body)
                out.append(f"SEG_LOOP k={op.segments} [{inner}]")
            else:
                out.append(type(op).__name__.upper())
        return "\n".join(out)

    # ---- program-level pricing (the alpha-beta walk) ---------------------
    def exchange_terms(self):
        """Yield (multiplicity, segments, body, region) per wire exchange.

        The one IR-shape walk `cost` prices: LOOP/STREAM slots repeat
        `trip` times, SEG_LOOP carries its segment count, stacked and
        unrolled exchanges run once. `region` identifies the cross-step
        pipelining region the exchange belongs to — the index of its
        STREAM / STREAM_CHAIN op, or None for exchanges whose pipeline
        has a per-step barrier (SEG_LOOP, unstreamed LOOP slots, unrolled
        and stacked exchanges). Bruck pre/post rotations are local DMA
        and free, matching the retired schedule-walk model.
        """
        ops = self.ops
        i = 0
        while i < len(ops):
            op = ops[i]
            if isinstance(op, Loop):
                for slot in op.slots:
                    body, k = split_exchange(slot)
                    yield op.trip, k, body, None
                i += 1
            elif isinstance(op, Stream):
                for body in op.slots:
                    yield op.trip, op.segments, body, i
                i += 1
            elif isinstance(op, StreamChain):
                for body in op.bodies:
                    yield 1, op.segments, body, i
                i += 1
            elif isinstance(op, StackedRecv):
                for body in op.bodies:
                    yield 1, 1, body, None
                i += 1
            elif isinstance(op, SegLoop):
                yield 1, op.segments, op.body, None
                i += 1
            elif isinstance(op, Copy) and op.kind != "load":
                i += 1
            else:
                j = i
                while not isinstance(ops[j], RecvCombine):
                    j += 1
                yield 1, 1, tuple(ops[i:j + 1]), None
                i = j + 1

    def cost(self, msg_bytes: float, comm, elem_bytes: int = 4,
             tier=None, drop_prob: float = 0.0, env=None) -> float:
        """Predicted seconds for THIS compiled program on `comm`'s fabric.

        The SPLIT pipelining model, priced off the ops that will actually
        execute. Every exchange's per-segment time is
        t = alpha + wire_bytes / (k_eff * bw); then

          * exchanges inside a STREAM / STREAM_CHAIN region contribute
            mult * t and the region drains once in (k - 1) * max t over
            its exchanges — the cross-step fill/drain credit, earned
            because the executor keeps the wire busy across step
            boundaries there;
          * every other exchange pipelines only within its own step (the
            SEG_LOOP scan carry is a per-step barrier), so it contributes
            the serialized mult * k_eff * t = mult * (k_eff * alpha +
            wire / bw) — at k > 1 that is never cheaper than unsegmented,
            so the selector cannot be lured into segmentation the data
            plane cannot cash.

        The total divides by `overlap_factor` when slots ride independent
        links. Wire bytes come from each SEND's `bytes_frac`, scaled by
        the codec ratio when the exchange COMPRESSes (copy phases ship
        uncompressed — visible directly in the ops). `comm` supplies the
        per-fabric alpha, bandwidth, and Rx segment floor: a segment
        count that would cut an exchange's wire payload below the floor
        is clamped, so sub-floor tuning pins price what the Rx buffers
        can hold.

        For a k=1 program, and for any k>1 program that fuses into a
        single cross-step region, this walk returns the identical number
        to the retired schedule-walk `predict_time` — asserted (with the
        intentional divergences) by the golden pricing tests.

        A `pricing.PricingEnv` (`env=`) is the preferred way to carry
        the reliability surcharge (and a comm override): `env.tier` /
        `env.drop_prob` scale every alpha and wire term by the tier's
        expected transmissions under that loss rate and add the expected
        exponential backoff per wire crossing. The bare `tier=` /
        `drop_prob=` kwargs are a deprecation shim with identical
        semantics; mixing them with `env=` raises. A default env (or
        `tier=None`) is bitwise-neutral — fault-free pricing unchanged.
        """
        if env is not None:
            comm, tier, drop_prob = env.apply(comm, tier, drop_prob)
        total, _lat, _wir, crossings, _links = \
            self._cost_walk(msg_bytes, comm, elem_bytes)
        total = total / self.overlap_factor
        if tier is not None:
            total = (total * tier.expected_transmissions(drop_prob)
                     + crossings * tier.expected_backoff(drop_prob))
        return total

    def cost_terms(self, msg_bytes: float, comm,
                   elem_bytes: int = 4, tier=None,
                   drop_prob: float = 0.0, env=None,
                   per_link: bool = False) -> tuple:
        """`cost` decomposed as (latency_s, wire_s).

        latency_s collects every per-hop alpha term of the walk; wire_s
        collects the bandwidth-occupancy terms (bytes / bw). Their sum is
        `cost` up to summation rounding (the same multiplicities, floors,
        and region drains apply to both halves, each already divided by
        `overlap_factor`). The queue-level makespan model
        (`core/sequencer.py`) composes these: wire occupancy of requests
        sharing one communicator's links serializes, while the alpha
        half of a QUEUED request hides behind the wire time of the one
        in flight.

        With `per_link=True` the return grows a third element: a dict
        attributing wire_s across the physical links the bytes cross —
        keys are `("ici"|"dcn", axis)` from the exchange's
        `level_comm`, values sum (over a single-link program, bitwise)
        to wire_s. The mesh-level composition (`core/mesh_cost.py`)
        serializes THESE per shared link across queues, so it never
        re-walks programs.

        A reliability tier (via `env=PricingEnv(tier=..., drop_prob=...)`
        or the deprecated bare kwargs) scales both halves — and every
        link's share — by the tier's expected transmissions; the
        expected backoff lands in the latency half (backoff occupies no
        wire). The default is bitwise-neutral.
        """
        if env is not None:
            comm, tier, drop_prob = env.apply(comm, tier, drop_prob)
        _total, lat, wire, crossings, links = \
            self._cost_walk(msg_bytes, comm, elem_bytes)
        lat = lat / self.overlap_factor
        wire = wire / self.overlap_factor
        links = {key: v / self.overlap_factor for key, v in links.items()}
        if tier is not None:
            e = tier.expected_transmissions(drop_prob)
            lat = lat * e + crossings * tier.expected_backoff(drop_prob)
            wire = wire * e
            links = {key: v * e for key, v in links.items()}
        if per_link:
            return lat, wire, links
        return lat, wire

    def _level_fabrics(self, comm) -> dict:
        """level tag -> (alpha, bw, floor, link) for this comm. A flat
        communicator resolves every level to itself (`level_comm`), so
        flat pricing is bitwise-unchanged; a `ProductComm` routes "intra"
        exchanges to the ICI group and "inter" ones to the DCN group.
        `link` is the physical-link attribution key — `("dcn"|"ici",
        axis)` — that `cost_terms(per_link=True)` reports wire seconds
        under (see `topology.FabricOccupancy` for canonicalization)."""
        fabrics = {}
        for level in (None, "intra", "inter"):
            c = comm.level_comm(level) if hasattr(comm, "level_comm") \
                else comm
            link = ("dcn" if c.is_dcn else "ici", c.axis)
            fabrics[level] = (c.hop_latency, c.link_bw,
                              c.min_segment_bytes, link)
        return fabrics

    def fabric_wire_bytes(self, msg_bytes: float, comm,
                          elem_bytes: int = 4) -> dict:
        """Per-fabric wire bytes per rank: {"ici": ..., "dcn": ...}.

        The honest byte accounting behind the hierarchical claim — the
        priced DCN bytes of a two-level allreduce are exactly
        flat / ici_size. Segmentation does not change wire bytes; codec
        compression does (same scaling as `cost`)."""
        out = {"ici": 0.0, "dcn": 0.0}
        for mult, _k, body, _region in self.exchange_terms():
            scale = 1.0
            send = None
            for op in body:
                if isinstance(op, Compress):
                    from repro.core import plugins  # lazy: keep IR jax-free
                    scale = (plugins.get_codec(op.codec).wire_bytes_per_elem
                             / float(elem_bytes))
                elif isinstance(op, Send):
                    send = op
            c = comm.level_comm(send.level) if hasattr(comm, "level_comm") \
                else comm
            fabric = "dcn" if c.is_dcn else "ici"
            out[fabric] += mult * float(msg_bytes) * send.bytes_frac * scale
        return out

    def _cost_walk(self, msg_bytes: float, comm, elem_bytes: int) -> tuple:
        """(total, latency, wire, crossings, links) over the ops. `total`
        accumulates in the exact historical order (golden parity is
        asserted bitwise); the split halves accumulate alongside it.
        `crossings` counts per-segment wire crossings (mult * k_eff) —
        the unit the retransmission surcharge is charged per. Each
        exchange prices on `comm.level_comm(send.level)`'s fabric, so a
        two-level program's intra steps ride ICI alpha/bandwidth/floor
        and its inter steps ride DCN's; flat programs (level=None)
        resolve to `comm` itself and price bitwise-identically to the
        single-fabric walk. `links` splits the wire half by physical
        link key (see `_level_fabrics`); it is a PARALLEL accumulator —
        the total/lat/wire float-op sequence is untouched, so adding it
        cannot perturb golden parity."""
        fabrics = self._level_fabrics(comm)
        total = 0.0
        lat = 0.0
        wir = 0.0
        crossings = 0.0
        links: dict = {}
        # region id -> [k_max, t_max, a_max, b_max, link_of_max]
        drains: dict = {}
        for mult, k, body, region in self.exchange_terms():
            scale = 1.0
            send = None
            for op in body:
                if isinstance(op, Compress):
                    from repro.core import plugins  # lazy: keep IR jax-free
                    scale = (plugins.get_codec(op.codec).wire_bytes_per_elem
                             / float(elem_bytes))
                elif isinstance(op, Send):
                    send = op
            alpha, bw, floor, link = fabrics[send.level]
            wire = float(msg_bytes) * send.bytes_frac * scale
            k_eff = int(k)
            while k_eff > 1 and wire / k_eff < floor:
                k_eff -= 1
            b = wire / (k_eff * bw)
            t = alpha + b
            crossings += mult * k_eff
            if region is not None:
                total += mult * t
                lat += mult * alpha
                wir += mult * b
                links[link] = links.get(link, 0.0) + mult * b
                d = drains.setdefault(region, [1, 0.0, 0.0, 0.0, link])
                d[0] = max(d[0], k_eff)
                if t > d[1]:
                    d[1], d[2], d[3], d[4] = t, alpha, b, link
            else:
                total += mult * k_eff * t
                lat += mult * k_eff * alpha
                wir += mult * k_eff * b
                links[link] = links.get(link, 0.0) + mult * k_eff * b
        total += sum((k_r - 1) * t_r
                     for k_r, t_r, _a, _b, _l in drains.values())
        lat += sum((k_r - 1) * a_r
                   for k_r, _t, a_r, _b, _l in drains.values())
        wir += sum((k_r - 1) * b_r
                   for k_r, _t, _a, b_r, _l in drains.values())
        drain_by_link: dict = {}
        for k_r, _t, _a, b_r, l_r in drains.values():
            drain_by_link.setdefault(l_r, []).append((k_r - 1) * b_r)
        for l_r, vals in drain_by_link.items():
            # sum-then-add mirrors wir's association, so a single-link
            # program's links[key] stays bitwise-equal to wir
            links[l_r] = links.get(l_r, 0.0) + sum(vals)
        return total, lat, wir, crossings, links


# --------------------------------------------------------------------------
# Segment fitting (shared by both executors)
# --------------------------------------------------------------------------

def fit_segments(seg_len: int, segments, row_elems: int = 1,
                 block: int = 1) -> int:
    """Largest k <= segments that divides seg_len (>= 1), such that each
    segment's flat element count (seg_len/k * row_elems) is a whole number
    of codec `block`s.

    Segment counts come from the selector as a preference; the data plane
    clamps to a divisor of the payload length so segments stay equal-sized
    (halving mirrors the pow2 candidate ladder). The block constraint is
    the per-segment scale-reuse rule: a scale block never straddles a
    segment boundary, so segmented codec numerics == unsegmented.
    """
    k = max(1, int(segments or 1))
    k = min(k, max(1, seg_len))
    while k > 1 and (seg_len % k
                     or (seg_len // k * row_elems) % block):
        k -= 1
    return k


# --------------------------------------------------------------------------
# Compiler
# --------------------------------------------------------------------------

def _step_segmentable(step: Step, relay: str) -> bool:
    if step.segmentable is False:
        return False
    send_k, recv_k = step.send_sel.kind, step.recv_sel.kind
    if SEL_MASK in (send_k, recv_k):
        # non-contiguous regions segment only when the algorithm asserts
        # the send/recv masks are identical (Step.segmentable=True): the
        # gathered payload is then cut into wire segments and the combined
        # segments scattered back chunk-by-chunk.
        return bool(step.segmentable) and send_k == recv_k == SEL_MASK
    return True


def _exchange_ops(step: Step, relay: str, step_idx: Optional[int],
                  k_req: int, codec: Optional[str]) -> tuple:
    """The micro-op sequence for one schedule step."""
    ops = [Copy("load", sel=step.send_sel, source=relay, step=step_idx)]
    send = Send(tuple(step.perm), bytes_frac=step.bytes_frac,
                level=step.level,
                level_perm=(tuple(step.level_perm)
                            if step.level_perm is not None else None))
    if codec is not None and step.op != "copy":
        # codecs compress the wire of combine exchanges (the RS phase);
        # copy-only relays ship already-reduced chunks uncompressed, the
        # same rule the hand-written rings applied.
        ops.append(Compress(codec))
        ops.append(send)
        ops.append(Decompress(codec))
    else:
        ops.append(send)
    dsts = tuple(sorted(d for (_s, d) in step.perm)) if step.mask_recv \
        else None
    ops.append(RecvCombine(op=step.op, sel=step.recv_sel, step=step_idx,
                           dsts=dsts, track_recv=(relay == SRC_RECEIVED)))
    seq = tuple(ops)
    if k_req > 1 and _step_segmentable(step, relay):
        return (SegLoop(k_req, seq),)
    return seq


def _detect_run(steps: tuple, i: int) -> Optional[tuple]:
    """Maximal uniform run at `steps[i:]` -> (trip, period) or None.

    A run of trip >= 2 iterations of `period` slots coalesces into a LOOP
    when every participating step is `uniform` (traceable step-indexed
    selectors shared across the run), does not mask receivers, and — for
    period > 1 — writes an offset region (chunk/range) so the deferred
    per-iteration writes stay well-defined.
    """
    for period in (1, 2):
        if i + 2 * period > len(steps):
            continue
        slots = steps[i:i + period]
        if not all(s.uniform and not s.mask_recv for s in slots):
            continue
        if period > 1 and any(s.recv_sel.kind not in (SEL_CHUNK, SEL_RANGE)
                              for s in slots):
            continue
        sigs = [s.signature() for s in slots]
        trip = 1
        while True:
            base = i + trip * period
            if base + period > len(steps):
                break
            if all(steps[base + j].signature() == sigs[j]
                   for j in range(period)):
                trip += 1
            else:
                break
        if trip >= 2:
            return trip, period
    return None


def split_exchange(node) -> tuple:
    """(body, k_req) of an exchange node — a SegLoop (possibly the sole
    element of a LOOP slot tuple) or a plain micro-op tuple. The one
    IR-shape helper both executors use to walk a Program."""
    if isinstance(node, tuple) and len(node) == 1 \
            and isinstance(node[0], SegLoop):
        node = node[0]
    if isinstance(node, SegLoop):
        return node.body, node.segments
    return node, 1


# --------------------------------------------------------------------------
# Optimization passes
# --------------------------------------------------------------------------

def _sel_region(sel: Sel, r: int, step: int):
    """Concrete (offset, length) in chunk units for a contiguous selector
    evaluated at a concrete rank/step. Selector closures are pure
    (rank, step) arithmetic, so they evaluate on plain ints at compile
    time; anything fancier raises and the caller opts out."""
    if sel.kind == SEL_CHUNK:
        return int(sel.fn(r, step)), 1
    if sel.kind == SEL_RANGE:
        off, length = sel.fn(r, step)
        return int(off), int(length)
    raise ValueError(f"non-contiguous selector {sel.kind}")


def _overlaps(a0, a1, b0, b1) -> bool:
    return max(a0, b0) < min(a1, b1)


def _regions_stream_safe(seq, k: int, nranks: int) -> bool:
    """The SEL_RANGE/SEL_CHUNK region-overlap proof for a step sequence.

    `seq` is [(send_sel, recv_sel, source, step), ...] in execution
    order. The skewed wave order differs from the per-step order in
    exactly one read: the HEAD segment of step s+1's payload is fetched
    while step s's TAIL segment is still uncombined (every earlier wave
    has landed, every later one has not happened). The reorder is
    value-invisible — hence streamable — iff for EVERY rank:

      1. each step's payload region is disjoint from its own combine
         region and of equal length (payloads never observe their own
         step's writes — the unfused executor reads the payload at step
         start), and
      2. the first 1/k of step s+1's payload region is disjoint from the
         last 1/k of step s's combine region (the one missing write).

    Payloads reading the immutable original buffer skip both read-side
    checks. Segment boundaries are exact rationals of the chunk grid
    (`Fraction`), so the proof never rounds. Recursive halving/doubling
    pass for k >= 3 and genuinely fail at k = 2, where the half-range
    head segment really does reach into the missing tail write.
    """
    from fractions import Fraction
    try:
        for r in range(nranks):
            regions = []
            for send_sel, recv_sel, source, step in seq:
                s_off, s_len = _sel_region(send_sel, r, step)
                r_off, r_len = _sel_region(recv_sel, r, step)
                if s_len != r_len:
                    # the executor mirrors the payload segmentation onto
                    # the combine region; unequal lengths cannot stream
                    return False
                if source == SRC_BUFFER and _overlaps(
                        s_off, s_off + s_len, r_off, r_off + r_len):
                    return False
                regions.append((source, s_off, s_len, r_off, r_len))
            for i in range(1, len(regions)):
                source, s_off, s_len, _ro, _rl = regions[i]
                if source != SRC_BUFFER:
                    continue  # immutable payload: no read-side hazard
                _src0, _so0, _sl0, r_off, r_len = regions[i - 1]
                head_end = s_off + Fraction(s_len, k)
                tail_start = r_off + Fraction(r_len * (k - 1), k)
                if _overlaps(Fraction(s_off), head_end,
                             tail_start, Fraction(r_off + r_len)):
                    return False
    except Exception:
        return False  # non-arithmetic closure: cannot prove, do not fuse
    return True


def _stream_eligible(loop: Loop, k_req: int, nranks: int) -> bool:
    """Can this uniform run execute as one cross-step segment stream?

    Wave order differs from per-step order in exactly one place: step
    s+1's segment 0 is sent before step s's tail segment (k-1) combines.
    That reordering is value-invisible when every payload either

      * reads the immutable original buffer (relay='original'),
      * reads the relay register (relay='received'), whose segment j was
        recorded k waves earlier,
      * reads whole chunks (SEL_CHUNK send AND recv): chunk regions are
        equal or disjoint, and equal regions slice into the same k
        segments — segment 0 never overlaps the missing tail write, or
      * reads contiguous chunk ranges (SEL_RANGE, period-1 runs only)
        whose concrete per-rank regions pass the region-overlap proof
        (`_regions_stream_safe`) across the whole run.

    mask_recv slots never coalesce into LOOPs; track_recv (the relay
    register) is a single shared register, so it streams only at
    period 1.
    """
    if k_req < 2 or loop.trip < 2:
        return False
    track = False
    needs_proof = False
    levels = set()
    for slot in loop.slots:
        if not (len(slot) == 1 and isinstance(slot[0], SegLoop)):
            return False
        seg = slot[0]
        if seg.segments != k_req:
            return False
        levels.add(next(o for o in seg.body
                        if isinstance(o, Send)).level)
        load, recv = seg.body[0], seg.body[-1]
        if recv.dsts is not None:
            return False
        track = track or recv.track_recv
        if recv.sel.kind not in (SEL_CHUNK, SEL_ALL, SEL_RANGE):
            return False
        if load.source == SRC_BUFFER:
            if not (load.sel.kind in (SEL_CHUNK, SEL_RANGE)
                    and recv.sel.kind in (SEL_CHUNK, SEL_RANGE)):
                return False
            if SEL_RANGE in (load.sel.kind, recv.sel.kind):
                needs_proof = True
        elif load.source == SRC_RECEIVED:
            if not (load.sel.kind == SEL_ALL and recv.sel.kind == SEL_ALL):
                return False
        else:  # SRC_ORIGINAL payloads never read mutable state
            if recv.sel.kind == SEL_RANGE:
                needs_proof = True
    if len(levels) > 1:
        # cross-step streaming only within one level: a region spanning
        # fabrics would earn a drain credit priced on one fabric while
        # its exchanges ride another
        return False
    if track and loop.period != 1:
        return False
    if needs_proof:
        if loop.period != 1 or track:
            return False  # multi-slot range interleavings are unproven
        body = loop.slots[0][0].body
        load, recv = body[0], body[-1]
        seq = [(load.sel, recv.sel, load.source, loop.base + i)
               for i in range(loop.trip)]
        return _regions_stream_safe(seq, k_req, nranks)
    return True


def fuse_streams(ops: tuple, k_req: int, nranks: int) -> tuple:
    """Rewrite eligible LOOPs of SEG_LOOP slots into STREAM micro-ops —
    the cross-step software pipeline the cost model credits."""
    out = []
    for op in ops:
        if isinstance(op, Loop) and _stream_eligible(op, k_req, nranks):
            out.append(Stream(
                base=op.base, trip=op.trip, period=op.period,
                segments=k_req,
                slots=tuple(slot[0].body for slot in op.slots)))
        else:
            out.append(op)
    return tuple(out)


def _chain_body_eligible(op, k_req: int) -> bool:
    """One unrolled segmented exchange `fuse_chains` may chain: static
    step index, contiguous send/recv regions, unmasked receivers, no
    relay register, payload from the buffer or the immutable original."""
    if not isinstance(op, SegLoop) or op.segments != k_req:
        return False
    load, recv = op.body[0], op.body[-1]
    return (isinstance(load, Copy) and load.kind == "load"
            and load.step is not None
            and load.source in (SRC_BUFFER, SRC_ORIGINAL)
            and load.sel.kind in (SEL_CHUNK, SEL_RANGE)
            and recv.sel.kind in (SEL_CHUNK, SEL_RANGE)
            and recv.dsts is None and not recv.track_recv)


def fuse_chains(ops: tuple, k_req: int, nranks: int) -> tuple:
    """Rewrite runs of >= 2 consecutive unrolled segmented exchanges into
    STREAM_CHAIN micro-ops when the region-overlap proof holds.

    This is what lets the non-uniform log-step schedules — recursive
    halving/doubling, whose windows shrink or grow each step and so never
    coalesce into LOOPs — earn the cross-step credit for real. A run is
    split at any step boundary the proof rejects (recursive halving at
    k = 2, where the head segment reaches into the missing tail write);
    sub-runs shorter than 2 keep their SEG_LOOP form.
    """
    def seq_of(body) -> tuple:
        load, recv = body[0], body[-1]
        return (load.sel, recv.sel, load.source, load.step)

    def level_of(body):
        return next(o for o in body if isinstance(o, Send)).level

    out: list = []
    i = 0
    while i < len(ops):
        if not _chain_body_eligible(ops[i], k_req):
            out.append(ops[i])
            i += 1
            continue
        # extend pairwise: each call proves both bodies' within-step
        # condition and the boundary between them, so an accepted run of
        # length >= 2 is fully proven — no whole-run re-check needed
        # (condition 2 only ever relates consecutive steps). Runs never
        # cross a level boundary: the chain's drain credit must price on
        # one fabric.
        run = [ops[i]]
        j = i + 1
        while (j < len(ops) and _chain_body_eligible(ops[j], k_req)
               and level_of(ops[j].body) == level_of(run[-1].body)
               and _regions_stream_safe(
                   [seq_of(run[-1].body), seq_of(ops[j].body)],
                   k_req, nranks)):
            run.append(ops[j])
            j += 1
        if len(run) >= 2:
            out.append(StreamChain(
                segments=k_req, bodies=tuple(op.body for op in run)))
            i = j
        else:
            out.append(run[0])
            i += 1
    return tuple(out)


def _stackable(body: tuple) -> bool:
    """One relay='original' copy exchange the peephole may stack."""
    if len(body) != 3:
        return False
    load, send, recv = body
    return (isinstance(load, Copy) and load.kind == "load"
            and load.source == SRC_ORIGINAL
            and load.sel.kind == SEL_CHUNK
            and isinstance(send, Send)
            and isinstance(recv, RecvCombine)
            and recv.op == "copy" and recv.sel.kind == SEL_CHUNK
            and recv.dsts is None and not recv.track_recv
            and load.step is not None)


def _distinct_recv_chunks(bodies: tuple, nranks: int) -> bool:
    """Every rank's receive chunks across the run must be pairwise
    distinct for the stacked scatter to be write-disjoint. Selector
    closures are pure (rank, step) arithmetic, so they evaluate on
    concrete ints at compile time; anything fancier opts out."""
    try:
        for r in range(nranks):
            idxs = [int(b[-1].sel.fn(r, b[-1].step)) for b in bodies]
            if len(set(idxs)) != len(idxs):
                return False
    except Exception:
        return False
    return True


def fuse_stacked_recv(ops: tuple, nranks: int) -> tuple:
    """The stacked-receive peephole: collapse runs of >= 2 consecutive
    relay='original' copy exchanges into one STACKED_RECV (the retired
    linear all-to-all lowering's one-gather write-back)."""
    out: list = []
    i = 0
    while i < len(ops):
        op = ops[i]
        run: list = []
        j = i
        while (j + 2 < len(ops) and isinstance(ops[j], Copy)
               and ops[j].kind == "load"
               and isinstance(ops[j + 2], RecvCombine)
               and _stackable(tuple(ops[j:j + 3]))):
            run.append(tuple(ops[j:j + 3]))
            j += 3
        if len(run) >= 2 and _distinct_recv_chunks(tuple(run), nranks):
            out.append(StackedRecv(bodies=tuple(run)))
            i = j
        else:
            out.append(op)
            i += 1
    return tuple(out)


# Schedules hash their Sel closures by identity, so freshly generated
# (structurally identical) schedules never share entries: bound the cache
# so long-lived processes compiling transient schedules (benchmark loops,
# simulator harnesses) don't grow it without limit. Steady-state engine
# use hits via the upstream schedule caches, far below this bound.
_COMPILE_CACHE: dict = {}
_COMPILE_CACHE_MAX = 512

# Verification achieved per compile-cache key ("structural" | "full") —
# a cache hit upgrades to a stronger level at most once, so always-on
# verification adds one dict lookup to the steady-state compile path.
_VERIFIED: dict = {}


def _verify_mode(explicit: Optional[str]) -> str:
    """Resolve the verification level: an explicit `verify=` argument
    wins; otherwise the REPRO_VERIFY env var (CI's verify lane sets
    "full"); default "structural" — the cheap selector-free rules run
    on every compile."""
    import os
    mode = explicit if explicit is not None \
        else os.environ.get("REPRO_VERIFY", "structural")
    from repro.core.verify import VERIFY_LEVELS
    if mode not in VERIFY_LEVELS:
        raise ValueError(
            f"verify must be one of {VERIFY_LEVELS}, got {mode!r}")
    return mode


def _ensure_verified(prog: Program, schedule: Schedule, mode: str,
                     key) -> None:
    if mode == "off":
        return
    done = _VERIFIED.setdefault(key, set())
    if mode in done or "full" in done:
        return
    from repro.core import verify as _verify
    _verify.verify_program(prog, schedule, level=mode)
    done.add(mode)


def compile_schedule(schedule: Schedule, segments: Optional[int] = None,
                     codec: Optional[str] = None, stream: bool = True,
                     stacked: bool = True,
                     verify: Optional[str] = None) -> Program:
    """Lower a Schedule to a Program (memoized — compilation is trace-time
    control-plane work, like the uC caching assembled microcode).

    Two optimization passes run by default; tests disable them to hold
    the unfused program as a bitwise reference:

      stream   fuse uniform runs of segmented exchanges into cross-step
               STREAM pipelines (`fuse_streams`) and proven runs of
               unrolled segmented exchanges into STREAM_CHAINs
               (`fuse_chains`) — only at segments > 1.
      stacked  collapse relay='original' copy runs into one STACKED_RECV
               scatter (`fuse_stacked_recv`) — only at segments == 1
               (segmented copy runs stream through `fuse_chains`).

    `verify` selects the static-verifier level applied to the compiled
    program ("off" | "structural" | "full"; None = REPRO_VERIFY env var,
    default "structural") — see `core/verify.py`. A program that fails
    verification raises `VerifyError` and is never cached.
    """
    k_req = int(segments if segments is not None else schedule.segments)
    if k_req < 1:
        raise ValueError(f"segments must be >= 1, got {k_req}")
    mode = _verify_mode(verify)
    key = (schedule, k_req, codec, bool(stream), bool(stacked))
    hit = _COMPILE_CACHE.get(key)
    tr = telemetry.current()
    if hit is not None:
        if tr.enabled:
            tr.instant("compile.cache_hit", track="compile",
                       schedule=schedule.name, segments=k_req, codec=codec)
        _ensure_verified(hit, schedule, mode, key)
        return hit

    with tr.span("compile", track="compile", schedule=schedule.name,
                 collective=schedule.collective, segments=k_req,
                 codec=codec) as sp:
        ops: list = []
        if schedule.pre_rotate == "bruck":
            ops.append(Copy("bruck_pre"))
        steps = schedule.steps
        i = 0
        while i < len(steps):
            run = _detect_run(steps, i)
            if run is not None:
                trip, period = run
                slot_ops = tuple(
                    _exchange_ops(steps[i + j], schedule.relay, None, k_req,
                                  codec)
                    for j in range(period))
                ops.append(Loop(base=i, trip=trip, period=period,
                                slots=slot_ops))
                i += trip * period
            else:
                ops.extend(_exchange_ops(steps[i], schedule.relay, i, k_req,
                                         codec))
                i += 1
        if schedule.post_rotate == "bruck":
            ops.append(Copy("bruck_post"))

        ops = tuple(ops)
        # fusion passes; when tracing, each pass records whether it ran
        # and whether it accepted (rewrote ops) or rejected, with reason
        passes = [] if tr.enabled else None
        if stream and k_req > 1:
            pre = len(ops)
            ops = fuse_streams(ops, k_req, schedule.nranks)
            if passes is not None:
                passes.append(_fusion_rec("fuse_streams", pre, len(ops)))
            pre = len(ops)
            ops = fuse_chains(ops, k_req, schedule.nranks)
            if passes is not None:
                passes.append(_fusion_rec("fuse_chains", pre, len(ops)))
        elif passes is not None:
            reason = "segments == 1" if k_req == 1 else "stream=False"
            passes.append({"pass": "fuse_streams", "ran": False,
                           "reason": reason})
            passes.append({"pass": "fuse_chains", "ran": False,
                           "reason": reason})
        if stacked and k_req == 1:
            pre = len(ops)
            ops = fuse_stacked_recv(ops, schedule.nranks)
            if passes is not None:
                passes.append(_fusion_rec("fuse_stacked_recv", pre,
                                          len(ops)))
        elif passes is not None:
            reason = "segments > 1" if k_req > 1 else "stacked=False"
            passes.append({"pass": "fuse_stacked_recv", "ran": False,
                           "reason": reason})

        prog = Program(
            name=schedule.name, collective=schedule.collective,
            nranks=schedule.nranks, chunks=schedule.chunks,
            relay=schedule.relay, segments=k_req, codec=codec,
            ops=ops, overlap_factor=schedule.overlap_factor,
            level_sizes=schedule.level_sizes)
        try:
            _ensure_verified(prog, schedule, mode, key)
        except Exception as e:
            if tr.enabled:
                tr.instant("compile.verify_failed", track="compile",
                           schedule=schedule.name, verify=mode,
                           error=type(e).__name__)
            raise
        if tr.enabled:
            sp.add(ops=len(ops), verify=mode, passes=passes)
        if len(_COMPILE_CACHE) >= _COMPILE_CACHE_MAX:
            evicted = next(iter(_COMPILE_CACHE))  # FIFO eviction
            _COMPILE_CACHE.pop(evicted)
            _VERIFIED.pop(evicted, None)
        _COMPILE_CACHE[key] = prog
    return prog


def _fusion_rec(name: str, pre: int, post: int) -> dict:
    """One fusion pass's span record: accepted iff it rewrote the ops."""
    rec = {"pass": name, "ran": True, "accepted": post != pre,
           "ops_before": pre, "ops_after": post}
    if post == pre:
        rec["reason"] = "no fusible run"
    return rec
