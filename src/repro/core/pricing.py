"""PricingEnv — the one bundle of pricing parameters.

Before this module, pricing knobs were scattered per call site:
`Program.cost`/`cost_terms` took bare `(tier=, drop_prob=)` kwargs,
`Sequencer.makespan` additionally took `comm=`, and `Selector` threaded
`eager_max_bytes`/`lead_dim` through its own constructor and `choose`
arguments. A mesh-level composition (`core/mesh_cost.py`) prices MANY
queues under ONE set of assumptions, so those assumptions need a value
that can be passed around, compared, and defaulted — this frozen
dataclass.

Everywhere pricing happens now accepts `env=` (a `PricingEnv`):

    Program.cost(nbytes, comm, env=env)
    Program.cost_terms(nbytes, comm, env=env)
    Sequencer.makespan(axis, env=env)
    Selector.choose(collective, nbytes, comm, env=env)

The old bare kwargs survive as a thin deprecation shim (existing callers
keep working bitwise-identically), but mixing them with `env=` raises —
two sources of truth for the same knob would make sweeps unreadable. A
default `PricingEnv()` is bitwise-neutral: every consumer prices exactly
as if no env had been passed. New in-src callers must use `env=`; CI
greps for bare `tier=`/`drop_prob=` at pricing call sites.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class PricingEnv:
    """Frozen pricing assumptions, shared by every pricing surface.

    comm             communicator override (None = the caller's own /
                     the engine's fabric for the priced axis)
    tier             `faults.ReliabilityTier` for the retransmission
                     surcharge (None = fault-free, bitwise-neutral)
    drop_prob        per-segment loss rate the tier prices against
    eager_max_bytes  eager-protocol cap override for the selector
                     (None = the communicator's per-fabric cap, or the
                     selector's own constructor override)
    lead_dim         alltoall leading-dim the selector clamps segment
                     candidates on (None = flat element grid)
    """

    comm: object = None
    tier: object = None
    drop_prob: float = 0.0
    eager_max_bytes: Optional[float] = None
    lead_dim: Optional[int] = None

    def apply(self, comm, tier=None, drop_prob: float = 0.0):
        """Fold this env over a pricing call's positional `comm` and its
        deprecated bare kwargs -> (comm, tier, drop_prob). Mixing an env
        with non-default bare kwargs is a TypeError (one source of
        truth)."""
        if tier is not None or drop_prob:
            raise TypeError(
                "pass pricing parameters through env=PricingEnv(...) OR "
                "the deprecated bare tier=/drop_prob= kwargs, not both")
        return (self.comm if self.comm is not None else comm,
                self.tier, self.drop_prob)


def resolve_env(env: Optional[PricingEnv] = None, *, comm=None, tier=None,
                drop_prob: float = 0.0) -> PricingEnv:
    """The deprecation shim: fold a call's bare kwargs into a
    `PricingEnv` when no env was passed; reject a mix of both."""
    if env is None:
        return PricingEnv(comm=comm, tier=tier, drop_prob=drop_prob)
    if comm is not None or tier is not None or drop_prob:
        raise TypeError(
            "pass pricing parameters through env=PricingEnv(...) OR the "
            "deprecated bare comm=/tier=/drop_prob= kwargs, not both")
    return env
