"""Two-level hierarchical lowerings — cross-fabric compositions in the IR.

The HwSpec prices ICI and DCN separately, but a flat algorithm over a
(pod x intra-pod) product group puts the FULL message on the slow
pod-crossing fabric. The ACCL+ position — and the headline of
"Optimizing Communication for Latency Sensitive HPC Applications on up
to 48 FPGAs Using ACCL" — is that the collective engine should compose
per-fabric primitives instead. This module does exactly that: it reuses
the existing per-level schedule generators (core/algorithms.py) and
rewrites them into ONE flat-rank `Schedule` whose steps alternate
levels, e.g. for allreduce:

  1. reduce-scatter WITHIN each pod on ICI       (level="intra")
  2. allreduce of the 1/ici_size shard ACROSS
     pods on DCN                                 (level="inter")
  3. allgather within each pod on ICI            (level="intra")

so the DCN carries exactly 1/ici_size of the bytes. The composed
schedule compiles through the ordinary `compile_schedule` pipeline;
each Send is tagged with its level, so `Program.cost` prices every
exchange on its own fabric (`Communicator.level_comm`) and the engine
ppermutes each level's permutation on that level's own mesh axis.

Rank mapping (inner-major): with P = outer(pod) size and M =
inner(intra) size, flat rank

    r = intra_rank * P + pod_rank     intra_rank = r // P   (which slot)
                                      pod_rank   = r % P    (which pod)

Pod p is the stride-P rank set {i*P + p : i in range(M)}; the inter
group at intra slot i is the contiguous block [i*P, (i+1)*P) — the P
peers holding the same intra slot, one per pod. Inner-major numbering
makes every region contiguous:
the buffer is cut into M*C fine chunks (C = the inter schedule's chunk
count), coarse chunk i = fine range [i*C, (i+1)*C) is intra rank i's
pod-local shard, and the inter phase runs entirely inside that range.
For reduce-scatter with C = P this lands rank r exactly on fine chunk
r — the canonical flat shard layout.
"""
from __future__ import annotations

from typing import Optional

from repro.core import algorithms
from repro.core.schedule import (
    SEL_ALL, SEL_CHUNK, SEL_RANGE, Schedule, Sel, Step,
)

# Inter-level (DCN) algorithm choices per collective; first entry is the
# default. Power-of-two-only families are filtered by the caller.
INTER_ALGOS = {
    "allreduce": ("ring", "recursive_doubling"),
    "reduce_scatter": ("ring", "recursive_halving"),
    "allgather": ("ring", "recursive_doubling"),
    "bcast": ("binomial_tree",),
}
INTER_POW2_ONLY = frozenset({"recursive_doubling", "recursive_halving"})
# Intra level is the bandwidth-optimal chunked ring (any rank count).
INTRA_ALGOS = ("ring",)

# The only level names a two-level program may carry; `Step.level` tags
# and `Schedule.level_sizes` entries outside this set are rejected here
# at composition time and by the static verifier (LV_ORPHAN_LEVEL) on
# every compiled program.
LEVELS = ("intra", "inter")


def hier_name(intra: str, inter: str) -> str:
    return f"hierarchical:{intra}+{inter}"


def parse_hier_name(name: str) -> Optional[tuple]:
    """"hierarchical:<intra>+<inter>" -> (intra, inter), else None."""
    if not name.startswith("hierarchical:"):
        return None
    body = name[len("hierarchical:"):]
    if "+" not in body:
        return None
    intra, inter = body.split("+", 1)
    return intra, inter


# --------------------------------------------------------------------------
# Level remapping: per-level schedules -> flat-rank steps
# --------------------------------------------------------------------------

def _wrap_intra_sel(sel: Sel, P: int, C: int, base: int) -> Sel:
    """Intra selector in coarse-chunk space -> fine-chunk space. The
    level rank is r // P; the level step is the global step minus the
    phase base. Coarse chunk c covers fine range [c*C, (c+1)*C)."""
    if sel.kind == SEL_ALL:
        return sel
    f = sel.fn
    if sel.kind == SEL_CHUNK:
        if C == 1:
            return Sel.chunk(lambda r, s, f=f: f(r // P, s - base))
        return Sel.range(lambda r, s, f=f: (f(r // P, s - base) * C, C))
    if sel.kind == SEL_RANGE:
        def g(r, s, f=f):
            off, length = f(r // P, s - base)
            return (off * C, length * C)
        return Sel.range(g)
    raise ValueError(f"cannot remap intra selector kind {sel.kind!r}")


def _wrap_inter_sel(sel: Sel, P: int, C: int, base: int) -> Sel:
    """Inter selector -> fine-chunk space. The level rank is r % P; the
    inter phase's whole buffer is this rank's coarse chunk, fine range
    [(r//P)*C, (r//P)*C + C)."""
    f = sel.fn
    if sel.kind == SEL_ALL:
        if C == 1:
            return Sel.chunk(lambda r, s: r // P)
        return Sel.range(lambda r, s: ((r // P) * C, C))
    if sel.kind == SEL_CHUNK:
        return Sel.chunk(lambda r, s, f=f: (r // P) * C + f(r % P, s - base))
    if sel.kind == SEL_RANGE:
        def g(r, s, f=f):
            off, length = f(r % P, s - base)
            return ((r // P) * C + off, length)
        return Sel.range(g)
    raise ValueError(f"cannot remap inter selector kind {sel.kind!r}")


def _expand_intra_perm(perm: tuple, P: int) -> tuple:
    """Level perm over intra ranks -> flat pairs, replicated per pod."""
    return tuple((s * P + p, d * P + p) for (s, d) in perm
                 for p in range(P))


def _expand_inter_perm(perm: tuple, P: int, M: int) -> tuple:
    """Level perm over pod ranks -> flat pairs, replicated per slot."""
    return tuple((i * P + s, i * P + d) for (s, d) in perm
                 for i in range(M))


def _remap_phase(steps: tuple, level: str, P: int, M: int, C: int,
                 base: int, frac_scale: float = 1.0) -> list:
    """Rewrite one per-level phase into flat-rank, fine-chunk steps.

    Wrapped selectors and expanded perms are shared by identity across
    the phase (memoized per source object), so uniform runs keep equal
    signatures and still coalesce into LOOP/STREAM micro-ops."""
    if level not in LEVELS:
        raise ValueError(f"unknown level {level!r}; must be one of {LEVELS}")
    wrap_sel = _wrap_intra_sel if level == "intra" else _wrap_inter_sel
    sel_memo: dict = {}
    perm_memo: dict = {}
    out = []
    for step in steps:
        if step.level is not None:
            raise ValueError("cannot nest hierarchical schedules")
        key = id(step.send_sel)
        if key not in sel_memo:
            sel_memo[key] = wrap_sel(step.send_sel, P, C, base)
        send_sel = sel_memo[key]
        key = id(step.recv_sel)
        if key not in sel_memo:
            sel_memo[key] = wrap_sel(step.recv_sel, P, C, base)
        recv_sel = sel_memo[key]
        if step.perm not in perm_memo:
            perm_memo[step.perm] = (
                _expand_intra_perm(step.perm, P) if level == "intra"
                else _expand_inter_perm(step.perm, P, M))
        out.append(Step(
            perm=perm_memo[step.perm], op=step.op,
            send_sel=send_sel, recv_sel=recv_sel,
            bytes_frac=step.bytes_frac * frac_scale,
            mask_recv=step.mask_recv, uniform=step.uniform,
            segmentable=step.segmentable,
            level=level, level_perm=step.perm,
        ))
    return out


def _levels(P: int, M: int) -> tuple:
    return (("inter", P), ("intra", M))


def _check_sizes(comm) -> tuple:
    P, M = comm.outer.size, comm.inner.size
    if P < 2 or M < 2:
        raise ValueError(
            f"hierarchical composition needs both levels >= 2 ranks, "
            f"got pod={P} intra={M} (use the flat algorithm)")
    return P, M


# --------------------------------------------------------------------------
# Compositions
# --------------------------------------------------------------------------

def hier_allreduce(comm, intra: str = "ring", inter: str = "ring",
                   op: str = "add") -> Schedule:
    """Intra RS (ICI) -> inter allreduce of the 1/M shard (DCN) ->
    intra AG (ICI). DCN bytes = inter algorithm's bytes on msg/M."""
    P, M = _check_sizes(comm)
    rs = algorithms.GENERATORS[("reduce_scatter", intra)](comm.inner, op=op)
    ar = algorithms.GENERATORS[("allreduce", inter)](comm.outer, op=op)
    ag = algorithms.GENERATORS[("allgather", intra)](comm.inner)
    C = ar.chunks
    n_rs, n_ar = len(rs.steps), len(ar.steps)
    steps = (
        _remap_phase(rs.steps, "intra", P, M, C, base=0)
        + _remap_phase(ar.steps, "inter", P, M, C, base=n_rs,
                       frac_scale=1.0 / M)
        + _remap_phase(ag.steps, "intra", P, M, C, base=n_rs + n_ar)
    )
    return Schedule(
        name=hier_name(intra, inter), collective="allreduce",
        nranks=P * M, steps=tuple(steps), chunks=M * C, result="full",
        level_sizes=_levels(P, M),
    )


def hier_reduce_scatter(comm, intra: str = "ring", inter: str = "ring",
                        op: str = "add") -> Schedule:
    """Intra RS (ICI) -> inter RS of the 1/M shard (DCN). With C = P
    inter chunks, rank r = i*P + p lands on fine chunk i*P + p = r —
    the canonical flat shard layout."""
    P, M = _check_sizes(comm)
    rs_i = algorithms.GENERATORS[("reduce_scatter", intra)](comm.inner,
                                                            op=op)
    rs_o = algorithms.GENERATORS[("reduce_scatter", inter)](comm.outer,
                                                            op=op)
    C = rs_o.chunks
    inter_owned = rs_o.owned_chunk
    steps = (
        _remap_phase(rs_i.steps, "intra", P, M, C, base=0)
        + _remap_phase(rs_o.steps, "inter", P, M, C,
                       base=len(rs_i.steps), frac_scale=1.0 / M)
    )
    return Schedule(
        name=hier_name(intra, inter), collective="reduce_scatter",
        nranks=P * M, steps=tuple(steps), chunks=M * C, result="shard",
        owned_chunk=lambda r: (r // P) * C + inter_owned(r % P),
        level_sizes=_levels(P, M),
    )


def hier_allgather(comm, intra: str = "ring",
                   inter: str = "ring") -> Schedule:
    """Inter AG of each rank's shard (DCN, fills this slot's coarse
    chunk) -> intra AG of the coarse chunks (ICI). DCN carries each
    rank's 1/n shard P-1 hops instead of the whole buffer."""
    P, M = _check_sizes(comm)
    ag_o = algorithms.GENERATORS[("allgather", inter)](comm.outer)
    ag_i = algorithms.GENERATORS[("allgather", intra)](comm.inner)
    C = ag_o.chunks
    steps = (
        _remap_phase(ag_o.steps, "inter", P, M, C, base=0,
                     frac_scale=1.0 / M)
        + _remap_phase(ag_i.steps, "intra", P, M, C,
                       base=len(ag_o.steps))
    )
    return Schedule(
        name=hier_name(intra, inter), collective="allgather",
        nranks=P * M, steps=tuple(steps), chunks=M * C, result="full",
        level_sizes=_levels(P, M),
    )


def hier_bcast(comm, intra: str = "ring", inter: str = "binomial_tree",
               root: int = 0) -> Schedule:
    """Intra scatter in the root's pod (ICI) -> inter bcast of each
    coarse chunk across pods (DCN) -> intra allgather everywhere (ICI).

    The root keeps its full buffer; every other rank of the root's pod
    receives one coarse chunk, each inter group relays its chunk to all
    pods, and the closing intra allgather rebuilds the full buffer in
    every pod (ranks that already hold a chunk are overwritten with
    bitwise-identical data). DCN carries 1/M of the bytes per tree
    edge instead of the full message.

    The scatter runs in EVERY pod (level perms execute as one ppermute
    on the intra mesh axis, replicated across pods): pods other than
    the root's scatter stale data, which the inter bcast — whose every
    non-root rank receives — then overwrites. Deterministic on both
    executors, bitwise-equal to the flat oracle after the final
    allgather.
    """
    P, M = _check_sizes(comm)
    if root != 0:
        # The scatter below hands coarse chunk j to pod-mate j of the
        # root's pod; a non-zero root would need a rotated chunk->rank
        # map on every phase. The engine's selector path only requests
        # root=0 programs; other roots fall back to flat algorithms.
        raise ValueError("hierarchical bcast supports root=0 only")
    bc = algorithms.GENERATORS[("bcast", inter)](comm.outer, root=0)
    ag = algorithms.GENERATORS[("allgather", intra)](comm.inner)
    C = bc.chunks  # 1: the inter phase relays whole coarse chunks
    # Phase 1 — intra scatter: intra rank 0 sends coarse chunk j to
    # pod-mate j, j = 1..M-1 (in the root's pod that is the real
    # payload; elsewhere it is overwritten by phase 2).
    scatter = [
        Step(perm=_expand_intra_perm(((0, j),), P), op="copy",
             send_sel=Sel.chunk(lambda r, s, j=j: j),
             recv_sel=Sel.chunk(lambda r, s, j=j: j),
             bytes_frac=1.0 / M, mask_recv=True,
             level="intra", level_perm=((0, j),))
        for j in range(1, M)
    ]
    steps = scatter + _remap_phase(
        bc.steps, "inter", P, M, C, base=len(scatter),
        frac_scale=1.0 / M,
    ) + _remap_phase(
        ag.steps, "intra", P, M, C,
        base=len(scatter) + len(bc.steps),
    )
    return Schedule(
        name=hier_name(intra, inter), collective="bcast",
        nranks=P * M, steps=tuple(steps), chunks=M * C, result="full",
        level_sizes=_levels(P, M),
    )


_COMPOSERS = {
    "allreduce": hier_allreduce,
    "reduce_scatter": hier_reduce_scatter,
    "allgather": hier_allgather,
    "bcast": hier_bcast,
}


def hierarchical_schedule(collective: str, comm, intra: str = "ring",
                          inter: str = "ring", root: int = 0,
                          op: str = "add") -> Schedule:
    """Compose the two-level schedule for `collective` over a
    `ProductComm`. The uniform entry point the engine's generator
    lookup and the selector's candidate family both use."""
    composer = _COMPOSERS.get(collective)
    if composer is None:
        raise ValueError(
            f"no hierarchical composition for {collective!r}")
    if collective == "allreduce" or collective == "reduce_scatter":
        return composer(comm, intra=intra, inter=inter, op=op)
    if collective == "bcast":
        return composer(comm, intra=intra, inter=inter, root=root)
    return composer(comm, intra=intra, inter=inter)


def inter_candidates(collective: str, outer_size: int) -> tuple:
    """Inter-level algorithm names admissible at this pod count."""
    names = INTER_ALGOS.get(collective, ())
    pow2 = outer_size & (outer_size - 1) == 0
    return tuple(n for n in names
                 if pow2 or n not in INTER_POW2_ONLY)
