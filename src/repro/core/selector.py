"""Algorithm & protocol selector — the runtime-tunable part of the firmware.

ACCL+ (§4.4.4): "The tuning of the algorithms for specific collective can be
done at runtime by setting configuration parameters to the CCLO engine and
we set these parameters according to our empirical experiment results."

We reproduce that: `Selector.choose()` COMPILES every registered
(algorithm, protocol, segments) candidate to its micro-op Program and
prices it with `Program.cost` (the alpha-beta walk over the exact ops the
engine will execute — stream fusion and peepholes included), picking the
cheapest. A user tuning table overrides the model (the paper's
"configuration parameters"), so deployments can pin choices measured on
their fabric — without touching any model code.

Protocol model (paper §4.4.3, adapted per DESIGN.md §5):
  eager       no handshake; receiver staging copy costs msg/eager_copy_bw.
              Only available while the message fits the Rx-buffer pool.
  rendezvous  +1 handshake RTT; zero-copy delivery.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core import algorithms as algos
from repro.core import hierarchical
from repro.core import plugins
from repro.core import telemetry
from repro.core.program import Program, Stream, StreamChain, fit_segments
from repro.core.schedule import Schedule
from repro.core.topology import Communicator, ProductComm

# Which algorithms may run under which protocol (paper Table 1 + [+] ours).
ALGO_PROTOCOLS = {
    ("bcast", "one_to_all"): ("eager", "rendezvous"),
    ("bcast", "binomial_tree"): ("rendezvous",),
    ("reduce", "ring"): ("eager",),
    ("reduce", "all_to_one"): ("rendezvous", "eager"),
    ("reduce", "binomial_tree"): ("rendezvous",),
    ("gather", "ring"): ("eager",),
    ("gather", "all_to_one"): ("rendezvous", "eager"),
    ("gather", "binomial_tree"): ("rendezvous",),
    ("alltoall", "linear"): ("eager", "rendezvous"),
    ("alltoall", "bruck"): ("eager",),
    ("allreduce", "recursive_doubling"): ("eager", "rendezvous"),
    ("allreduce", "ring"): ("rendezvous",),
    ("allreduce", "bidi_ring"): ("rendezvous",),
    ("allreduce", "halving_doubling"): ("rendezvous",),
    ("reduce_scatter", "ring"): ("rendezvous",),
    ("reduce_scatter", "recursive_halving"): ("rendezvous",),
    ("allgather", "ring"): ("eager", "rendezvous"),
    ("allgather", "recursive_doubling"): ("rendezvous",),
}

# (collective, algorithm) pairs whose generators require 2^k ranks.
_POW2_ONLY = {
    ("allreduce", "recursive_doubling"),
    ("allreduce", "halving_doubling"),
    ("reduce_scatter", "recursive_halving"),
    ("allgather", "recursive_doubling"),
    ("alltoall", "bruck"),
    ("gather", "binomial_tree"),
}


@dataclasses.dataclass(frozen=True)
class Choice:
    collective: str
    algorithm: str
    protocol: str
    predicted_s: float
    schedule: Schedule
    segments: int = 1
    codec: Optional[str] = None  # wire compressor the pricing assumed
    # the compiled artifact the price was computed FROM — the exact
    # micro-op program (stream-fused, peepholed) the engine will execute
    program: Optional[Program] = None

    @property
    def compressed(self) -> bool:
        return self.codec is not None


class Selector:
    """Prices schedules; honours a user tuning table first.

    Segmentation (ACCL+ §4.4.3): `choose` picks the wire segment count
    jointly with algorithm/protocol — each candidate schedule is priced at
    every admissible segment count and the cheapest (algo, proto, segments)
    triple wins. `choose` is memoized on (collective, msg_bytes, comm) so a
    training step that re-issues the same collective never re-runs the
    generators or the pricing sweep; `set_tuning` invalidates the cache.
    """

    #: segment counts the selector sweeps (1 = unsegmented baseline).
    DEFAULT_SEGMENT_CANDIDATES = (1, 2, 4, 8, 16, 32)

    def __init__(self, eager_max_bytes: Optional[int] = None,
                 segment_candidates: tuple = DEFAULT_SEGMENT_CANDIDATES,
                 min_segment_bytes: int = 8 * 1024):
        # None (default) = use the communicator's per-fabric cap
        # (`Communicator.eager_max_bytes`: the DCN Rx staging pool is
        # smaller than the ICI one). An explicit value overrides both —
        # the pre-per-fabric behaviour, kept for tests/tools that pin it.
        self.eager_max_bytes = eager_max_bytes
        self.segment_candidates = tuple(segment_candidates)
        # Rx-buffer floor: never cut a step's payload below this many bytes
        # (tiny segments are all alpha, and real Rx buffers have a floor).
        # This is the fallback when no communicator is given; with one, the
        # per-fabric floor applies (`Communicator.min_segment_bytes`) — the
        # 10 us DCN alpha prices a far larger floor than the ICI one.
        self.min_segment_bytes = min_segment_bytes
        # (collective, lo_bytes, hi_bytes, nranks_or_None, algorithm, segs)
        self._tuning: list[tuple] = []
        self._cache: dict = {}
        # generator/memoization telemetry, asserted on in tests; `stats`
        # is the read-compatible live view over the registry
        self.metrics = telemetry.MetricsRegistry()
        for _name in ("choose_calls", "cache_hits", "gen_calls"):
            self.metrics.counter(_name)
        self.stats = self.metrics.view()
        # last uncached choose: candidates priced + margin over runner-up
        self._last_priced = 0
        self._last_margin: Optional[float] = None

    #: set_tuning codec wildcard: the rule applies whatever codec the
    #: choose is pricing (the pre-codec-aware behaviour).
    ANY_CODEC = "any"

    # -- the paper's runtime configuration parameters ----------------------
    def set_tuning(self, collective: str, algorithm: str,
                   lo_bytes: int = 0, hi_bytes: int = 1 << 62,
                   nranks: Optional[int] = None,
                   segments: Optional[int] = None,
                   codec: Optional[str] = ANY_CODEC) -> None:
        """Pin an algorithm (and optionally segment count) for a bucket.

        `codec` scopes the rule: ANY_CODEC (default) matches every
        choose; None matches only uncompressed chooses; a codec name
        matches only chooses pricing that codec — so tables measured on
        compressed wires never leak into uncompressed selection.
        """
        self._tuning.append((collective, lo_bytes, hi_bytes, nranks,
                             algorithm, segments, codec))
        self._cache.clear()  # stale choices may no longer honour the table

    def _tuned(self, collective: str, msg_bytes: int, n: int,
               codec: Optional[str] = None
               ) -> tuple[Optional[str], Optional[int]]:
        """Last-set matching rule wins (algorithm, pinned segment count)."""
        for (c, lo, hi, nr, algo, segs, cdc) in reversed(self._tuning):
            if (c == collective and lo <= msg_bytes < hi
                    and (nr is None or nr == n)
                    and (cdc == self.ANY_CODEC or cdc == codec)):
                return algo, segs
        return None, None

    # -- pricing ------------------------------------------------------------
    def _protocol_overhead(self, protocol: str, msg_bytes: float,
                           comm: Communicator,
                           eager_cap: Optional[float] = None
                           ) -> Optional[float]:
        if protocol == "eager":
            # cap precedence: the pricing env's per-call override
            # (`PricingEnv.eager_max_bytes`), then the selector-level
            # constructor override, then the communicator's per-fabric
            # Rx staging pool (DCN comms reject eager at sizes the ICI
            # pool still accepts)
            cap = eager_cap
            if cap is None:
                cap = self.eager_max_bytes
            if cap is None:
                cap = comm.eager_max_bytes
            if msg_bytes > cap:
                return None  # Rx-buffer pool exceeded
            return msg_bytes / comm.hw.eager_copy_bw
        return comm.hw.rendezvous_rtt

    @staticmethod
    def _wire_scale(codec: Optional[str], elem_bytes: int) -> float:
        """Wire bytes per payload byte under `codec` (1.0 uncompressed)."""
        if codec is None:
            return 1.0
        return plugins.get_codec(codec).wire_bytes_per_elem / float(
            elem_bytes)

    def price_program(self, prog: Program, protocol: str, msg_bytes: float,
                      comm: Communicator, elem_bytes: int = 4,
                      eager_cap: Optional[float] = None) -> Optional[float]:
        """Protocol overhead + `Program.cost` — the hot-path pricer.

        The program IS the costed artifact: LOOP trip counts, SEG_LOOP /
        STREAM fill-drain, per-op codec wire bytes, and the fabric's
        alpha/segment floors are all read off the compiled ops, so the
        selector prices exactly what the engine will execute (the retired
        `predict_time` priced the schedule instead).
        """
        ov = self._protocol_overhead(protocol, msg_bytes, comm,
                                     eager_cap=eager_cap)
        if ov is None:
            return None
        return prog.cost(msg_bytes, comm, elem_bytes=elem_bytes) + ov

    def price(self, schedule: Schedule, protocol: str, msg_bytes: float,
              comm: Communicator, segments: int = 1,
              codec: Optional[str] = None, elem_bytes: int = 4,
              eager_cap: Optional[float] = None) -> Optional[float]:
        """Compile (memoized) then price — see `price_program`."""
        return self.price_program(
            schedule.compile(segments=segments, codec=codec), protocol,
            msg_bytes, comm, elem_bytes=elem_bytes, eager_cap=eager_cap)

    def admissible_segments(self, schedule: Schedule, msg_bytes: float,
                            comm: Optional[Communicator] = None,
                            codec: Optional[str] = None,
                            elem_bytes: int = 4) -> tuple:
        """Segment counts worth sweeping for this schedule/message.

        A step's per-segment *wire* payload must stay >= the fabric's
        segment floor (`Communicator.min_segment_bytes`: the DCN floor is
        far above the ICI one because of its 10 us alpha); k=1 is always
        admissible. Compressed wires shrink the per-segment bytes by the
        codec ratio, so they admit fewer segments at equal message size.
        Copy-only schedules have no combine work for SEG_LOOP to overlap,
        so a segment count is admissible for them only when the program
        compiled AT THAT COUNT cross-step streams the copies between hops
        (ring allgather's STREAM, linear all-to-all's and recursive
        doubling's STREAM_CHAIN; bcast trees never stream, so
        segmentation would only add per-segment alpha there). The probe
        is per count because stream eligibility is: recursive doubling's
        region-overlap proof admits k >= 3 but rejects k = 2. It reads
        the compiled artifact rather than hard-coding a schedule family.
        (A tuning-table entry can still pin segments explicitly. Combine
        schedules keep their full floor-admissible ladder: the split cost
        model already prices their non-streaming counts as serialized, so
        the sweep never picks one.)
        """
        if not schedule.steps:
            return (1,)
        floor = (comm.min_segment_bytes if comm is not None
                 else self.min_segment_bytes)
        scale = self._wire_scale(codec, elem_bytes)
        # the floor applies to the largest wire crossing that segments:
        # combine steps when present (copy phases ship uncompressed and
        # ride along), else the copy steps of a streamed copy schedule
        combine_bytes = [msg_bytes * s.bytes_frac * scale
                         for s in schedule.steps if s.op != "copy"]
        step_bytes = (max(combine_bytes) if combine_bytes
                      else max(msg_bytes * s.bytes_frac
                               for s in schedule.steps))
        out = [int(k) for k in self.segment_candidates
               if k == 1 or step_bytes / k >= floor]
        if all(s.op == "copy" for s in schedule.steps):
            out = [k for k in out
                   if k == 1 or any(
                       isinstance(op, (Stream, StreamChain))
                       for op in schedule.compile(segments=k).ops)]
        return tuple(out) or (1,)

    def fit_candidate_segments(self, schedule: Schedule, msg_bytes: int,
                               seg_space, codec: Optional[str] = None,
                               elem_bytes: int = 4,
                               lead_dim: Optional[int] = None) -> tuple:
        """Clamp candidate segment counts to what the executor will admit.

        The data plane clamps every requested count through
        `fit_segments` at trace time (divisor of the payload, whole codec
        scale blocks). Pricing a count the executor will then shrink
        would make `Choice.segments` a fiction — the engine would run
        fewer segments than were priced (the old ROADMAP "prices
        requested k" item). The engine flattens and pads the message to
        a multiple of `schedule.chunks`, so every contiguous payload is
        a whole multiple of the chunk size: a count that divides the
        chunk size divides every step's payload, and the executor admits
        it unchanged. Clamping here (duplicates dropped, order kept)
        makes the priced k and the executed k agree by construction.

        `alltoall` keeps its caller's 2-D shape, so its payload grid is
        leading-dim ROWS (`lead_dim / chunks` per chunk), not the flat
        element grid — callers pass `lead_dim` and the clamp runs on the
        row grid the executor will actually see, so an indivisible
        leading dim can no longer execute fewer segments than the priced
        `Choice.segments`.
        """
        elems = max(1, int(msg_bytes) // max(1, int(elem_bytes)))
        row_elems = 1
        if schedule.collective == "alltoall" and lead_dim:
            # the executor's fit_segments runs on payload rows: one
            # chunk of the caller's leading dim per exchange
            csize = max(1, int(lead_dim) // schedule.chunks)
            row_elems = max(1, elems // max(1, int(lead_dim)))
        elif schedule.collective in ("allgather", "gather"):
            # gathers price the per-rank SHARD (`msg_bytes`) but execute
            # on the nranks*shard buffer, whose chunk IS one shard — the
            # executable grid is the shard itself, not shard/chunks
            csize = elems
        else:
            csize = (elems + (-elems) % schedule.chunks) // schedule.chunks
        block = 1
        if codec is not None:
            block = plugins.get_codec(codec).block_elems
        out, seen = [], set()
        for k in seg_space:
            kf = fit_segments(csize, int(k), row_elems, block)
            if kf not in seen:
                seen.add(kf)
                out.append(kf)
        return tuple(out)

    def candidates(self, collective: str, comm: Communicator):
        if comm.size < 2:
            return
        for (coll, algo), gen in algos.GENERATORS.items():
            if coll != collective:
                continue
            if (coll, algo) in _POW2_ONLY and not comm.is_pow2:
                continue
            yield algo, gen
        # out-of-tree collectives (plugins.register_collective) price
        # through the exact same sweep as the built-in table
        for algo, gen, _protos in plugins.custom_candidates(collective):
            yield algo, gen

    def _protocols(self, collective: str, algo: str) -> tuple:
        protos = ALGO_PROTOCOLS.get((collective, algo))
        if protos is not None:
            return protos
        for c_algo, _gen, c_protos in plugins.custom_candidates(collective):
            if c_algo == algo:
                return c_protos
        return ("rendezvous",)

    def choose(self, collective: str, msg_bytes: int, comm: Communicator,
               codec: Optional[str] = None, elem_bytes: int = 4,
               lead_dim: Optional[int] = None, env=None) -> Choice:
        """Pick the cheapest (algorithm, protocol, segments) for a call.

        A `pricing.PricingEnv` (`env=`) threads the unified pricing
        knobs: `env.comm` overrides the positional comm, `env.lead_dim`
        fills `lead_dim` when not given, and `env.eager_max_bytes` caps
        the eager protocol for this call (precedence over the
        selector-level constructor override). The default env is
        bitwise-neutral.
        """
        self.metrics.inc("choose_calls")
        eager_cap = None
        if env is not None:
            if env.comm is not None:
                comm = env.comm
            if lead_dim is None:
                lead_dim = env.lead_dim
            eager_cap = env.eager_max_bytes
        # registry_version: (un)registering a custom collective must not
        # serve picks cached against the old candidate set; lead_dim is
        # part of the key because alltoall's executable segment grid is
        # its caller's leading dim, not just the byte count
        key = (collective, int(msg_bytes), comm, codec, int(elem_bytes),
               None if lead_dim is None else int(lead_dim), eager_cap,
               plugins.registry_version())
        hit = self._cache.get(key)
        tr = telemetry.current()
        if hit is not None:
            self.metrics.inc("cache_hits")
            if tr.enabled:
                tr.instant("selector.cache_hit", track="selector",
                           collective=collective, msg_bytes=int(msg_bytes),
                           algorithm=hit.algorithm, protocol=hit.protocol)
            return hit
        if tr.enabled:
            with tr.span("selector.choose", track="selector",
                         collective=collective, nranks=comm.size,
                         msg_bytes=int(msg_bytes), codec=codec) as sp:
                choice = self._choose_uncached(
                    collective, msg_bytes, comm, codec, elem_bytes,
                    lead_dim, eager_cap=eager_cap)
                sp.add(algorithm=choice.algorithm, protocol=choice.protocol,
                       segments=choice.segments,
                       predicted_s=choice.predicted_s,
                       candidates_priced=self._last_priced,
                       margin_s=self._last_margin)
        else:
            choice = self._choose_uncached(collective, msg_bytes, comm,
                                           codec, elem_bytes, lead_dim,
                                           eager_cap=eager_cap)
        self._cache[key] = choice
        return choice

    def _choose_uncached(self, collective: str, msg_bytes: int,
                         comm: Communicator, codec: Optional[str] = None,
                         elem_bytes: int = 4,
                         lead_dim: Optional[int] = None,
                         eager_cap: Optional[float] = None) -> Choice:
        if isinstance(comm, ProductComm):
            return self._choose_product(collective, msg_bytes, comm,
                                        codec, elem_bytes, lead_dim,
                                        eager_cap=eager_cap)
        tuned_algo, tuned_segs = self._tuned(collective, msg_bytes,
                                             comm.size, codec)
        custom_algos = {a for a, _g, _p
                        in plugins.custom_candidates(collective)}
        best: Optional[Choice] = None
        priced = 0
        second: Optional[float] = None
        for algo, gen in self.candidates(collective, comm):
            self.metrics.inc("gen_calls")
            try:
                sched = gen(comm)
            except ValueError:
                if algo in custom_algos:
                    # out-of-tree generators declare inapplicability to a
                    # communicator (e.g. pow2-only) by raising — skip,
                    # like the built-ins' _POW2_ONLY pre-filter
                    continue
                raise  # a built-in raising here is a bug, not a filter
            protos = self._protocols(collective, algo)
            seg_space = ((tuned_segs,) if tuned_algo == algo
                         and tuned_segs is not None
                         else self.admissible_segments(
                             sched, msg_bytes, comm, codec, elem_bytes))
            # price only counts the executor will actually run (the
            # trace-time fit_segments clamp, applied before pricing)
            seg_space = self.fit_candidate_segments(
                sched, msg_bytes, seg_space, codec, elem_bytes, lead_dim)
            tuned_best: Optional[Choice] = None
            for k in seg_space:
                # ONE compiled artifact per candidate: compiling through
                # the same Schedule instance the Choice carries means the
                # engine's memoized compile of choice.schedule returns
                # THIS program object — priced and executed artifacts are
                # identical, not merely equal
                sched_k = sched.with_segments(k)
                prog = sched_k.compile(codec=codec)
                for proto in protos:
                    t = self.price_program(prog, proto, msg_bytes, comm,
                                           elem_bytes=elem_bytes,
                                           eager_cap=eager_cap)
                    if t is None:
                        continue
                    priced += 1
                    cand = Choice(collective, algo, proto, t, sched_k,
                                  segments=k, codec=codec, program=prog)
                    if tuned_algo == algo:
                        if tuned_best is None or t < tuned_best.predicted_s:
                            tuned_best = cand
                    if best is None or t < best.predicted_s:
                        if best is not None and (second is None
                                                 or best.predicted_s < second):
                            second = best.predicted_s
                        best = cand
                    elif second is None or t < second:
                        second = t
            if tuned_best is not None:
                self._note_choice(priced, tuned_best, second)
                return tuned_best
        if best is None:
            raise ValueError(
                f"no applicable algorithm for {collective} over {comm}")
        self._note_choice(priced, best, second)
        return best

    def _note_choice(self, priced: int, winner: "Choice",
                     second: Optional[float]) -> None:
        """Stash candidates-priced / margin-over-runner-up for the
        `selector.choose` span (telemetry only — never read by pricing)."""
        self._last_priced = priced
        self._last_margin = (second - winner.predicted_s
                             if second is not None else None)

    def _choose_product(self, collective: str, msg_bytes: int,
                        comm: ProductComm, codec: Optional[str] = None,
                        elem_bytes: int = 4,
                        lead_dim: Optional[int] = None,
                        eager_cap: Optional[float] = None) -> Choice:
        """Two-level candidate family for a (pod x intra-pod) product.

        The `hierarchical:<intra>+<inter>` compositions are priced
        head-to-head against the flat algorithms over the product's
        bottleneck view (`ProductComm.flat`: full rank count, pod
        fabric). The hierarchical programs put 1/ici_size of the bytes
        on DCN, so they dominate from well below 1 MiB; the flat rows
        keep the comparison honest and remain the fallback the engine
        executes per axis when one is picked. A degenerate level
        (pod_size == 1 or intra == 1) delegates to the flat chooser
        over the one real level — flat wins by construction there.
        """
        if comm.outer.size < 2:
            return self._choose_uncached(collective, msg_bytes, comm.inner,
                                         codec, elem_bytes, lead_dim,
                                         eager_cap=eager_cap)
        if comm.inner.size < 2:
            return self._choose_uncached(collective, msg_bytes, comm.outer,
                                         codec, elem_bytes, lead_dim,
                                         eager_cap=eager_cap)
        if collective not in hierarchical.INTER_ALGOS:
            # no two-level composition (alltoall, reduce, gather):
            # price flat over the bottleneck view
            return self._choose_uncached(collective, msg_bytes, comm.flat,
                                         codec, elem_bytes, lead_dim,
                                         eager_cap=eager_cap)
        tuned_algo, tuned_segs = self._tuned(collective, msg_bytes,
                                             comm.size, codec)
        cands = []
        for intra in hierarchical.INTRA_ALGOS:
            for inter in hierarchical.inter_candidates(
                    collective, comm.outer.size):
                self.metrics.inc("gen_calls")
                sched = hierarchical.hierarchical_schedule(
                    collective, comm, intra=intra, inter=inter)
                # hierarchical programs span fabrics: rendezvous only
                # (per-region eager staging is not modeled)
                cands.append((sched.name, sched, ("rendezvous",), True))
        flat = comm.flat
        custom_algos = {a for a, _g, _p
                        in plugins.custom_candidates(collective)}
        for algo, gen in self.candidates(collective, flat):
            self.metrics.inc("gen_calls")
            try:
                sched = gen(flat)
            except ValueError:
                if algo in custom_algos:
                    continue
                raise
            cands.append((algo, sched, self._protocols(collective, algo),
                          False))
        best: Optional[Choice] = None
        priced = 0
        second: Optional[float] = None
        for algo, sched, protos, is_hier in cands:
            # per-level segment floors: a hierarchical candidate's ladder
            # comes from the inner (ICI) fabric — the cost walk and the
            # executor clamp each inter exchange to the DCN floor anyway
            floor_comm = comm.inner if is_hier else flat
            seg_space = ((tuned_segs,) if tuned_algo == algo
                         and tuned_segs is not None
                         else self.admissible_segments(
                             sched, msg_bytes, floor_comm, codec,
                             elem_bytes))
            seg_space = self.fit_candidate_segments(
                sched, msg_bytes, seg_space, codec, elem_bytes, lead_dim)
            tuned_best: Optional[Choice] = None
            for k in seg_space:
                sched_k = sched.with_segments(k)
                prog = sched_k.compile(codec=codec)
                for proto in protos:
                    t = self.price_program(prog, proto, msg_bytes, comm,
                                           elem_bytes=elem_bytes,
                                           eager_cap=eager_cap)
                    if t is None:
                        continue
                    priced += 1
                    cand = Choice(collective, algo, proto, t, sched_k,
                                  segments=k, codec=codec, program=prog)
                    if tuned_algo == algo:
                        if tuned_best is None or t < tuned_best.predicted_s:
                            tuned_best = cand
                    if best is None or t < best.predicted_s:
                        if best is not None and (second is None
                                                 or best.predicted_s < second):
                            second = best.predicted_s
                        best = cand
                    elif second is None or t < second:
                        second = t
            if tuned_best is not None:
                self._note_choice(priced, tuned_best, second)
                return tuned_best
        if best is None:
            raise ValueError(
                f"no applicable algorithm for {collective} over {comm}")
        self._note_choice(priced, best, second)
        return best

    # -- tuning-table artifacts (fig12 / EXPERIMENTS round-trips) -----------
    DEFAULT_TABLE_SIZES = (1 << 10, 1 << 13, 1 << 17, 1 << 20, 1 << 24,
                           1 << 27)

    def table(self, collective: str, comm: Communicator,
              sizes=DEFAULT_TABLE_SIZES, codec: Optional[str] = None,
              elem_bytes: int = 4):
        """Selection table — the fig12-style artifact for EXPERIMENTS.md.

        Each Choice carries the full tuning state for its size bucket:
        algorithm, protocol, chosen segment count, and the codec the
        pricing assumed (`Choice.compressed`) — so benchmark output and
        tuning-table round-trips are lossless (see `table_rows` /
        `apply_table`).
        """
        return {s: self.choose(collective, s, comm, codec=codec,
                               elem_bytes=elem_bytes) for s in sizes}

    def table_rows(self, collective: str, comm: Communicator,
                   sizes=DEFAULT_TABLE_SIZES, codec: Optional[str] = None,
                   elem_bytes: int = 4) -> list:
        """`table()` as JSON-ready rows (benchmark / EXPERIMENTS output)."""
        rows = []
        for size, c in self.table(collective, comm, sizes, codec,
                                  elem_bytes).items():
            rows.append({
                "collective": collective,
                "msg_bytes": int(size),
                "nranks": comm.size,
                "algorithm": c.algorithm,
                "protocol": c.protocol,
                "segments": int(c.segments),
                "compressed": c.compressed,
                "codec": c.codec,
                "predicted_s": float(c.predicted_s),
            })
        return rows

    def apply_table(self, rows) -> None:
        """Pin a `table_rows()` artifact back into the tuning table.

        The inverse of `table_rows`: every row becomes a size-bucketed
        tuning entry (algorithm AND segment count, scoped to its rank
        count AND the codec the table was priced under), so a selector
        seeded from a saved table reproduces the saved choices exactly —
        the lossless round-trip — without a compressed table leaking into
        uncompressed selection or vice versa.
        """
        # bucket within each (collective, nranks, codec) series — a mixed
        # artifact (several collectives' tables concatenated) must not
        # have one series' sizes truncating another's buckets
        series: dict = {}
        for r in rows:
            key = (r["collective"], r.get("nranks"), r.get("codec"))
            series.setdefault(key, []).append(r)
        for group in series.values():
            group = sorted(group, key=lambda r: int(r["msg_bytes"]))
            for i, r in enumerate(group):
                hi = (int(group[i + 1]["msg_bytes"]) if i + 1 < len(group)
                      else 1 << 62)
                self.set_tuning(r["collective"], r["algorithm"],
                                lo_bytes=int(r["msg_bytes"]), hi_bytes=hi,
                                nranks=r.get("nranks"),
                                segments=int(r["segments"]),
                                codec=r.get("codec"))
