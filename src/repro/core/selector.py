"""Algorithm & protocol selector — the runtime-tunable part of the firmware.

ACCL+ (§4.4.4): "The tuning of the algorithms for specific collective can be
done at runtime by setting configuration parameters to the CCLO engine and
we set these parameters according to our empirical experiment results."

We reproduce that: `Selector.choose()` prices every registered (algorithm,
protocol) pair for a (collective, message size, communicator) with the
alpha-beta model and picks the cheapest. A user tuning table overrides the
model (the paper's "configuration parameters"), so deployments can pin
choices measured on their fabric — without touching any model code.

Protocol model (paper §4.4.3, adapted per DESIGN.md §5):
  eager       no handshake; receiver staging copy costs msg/eager_copy_bw.
              Only available while the message fits the Rx-buffer pool.
  rendezvous  +1 handshake RTT; zero-copy delivery.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core import algorithms as algos
from repro.core.schedule import Schedule
from repro.core.topology import Communicator

# Which algorithms may run under which protocol (paper Table 1 + [+] ours).
ALGO_PROTOCOLS = {
    ("bcast", "one_to_all"): ("eager", "rendezvous"),
    ("bcast", "binomial_tree"): ("rendezvous",),
    ("reduce", "ring"): ("eager",),
    ("reduce", "all_to_one"): ("rendezvous", "eager"),
    ("reduce", "binomial_tree"): ("rendezvous",),
    ("gather", "ring"): ("eager",),
    ("gather", "all_to_one"): ("rendezvous", "eager"),
    ("gather", "binomial_tree"): ("rendezvous",),
    ("alltoall", "linear"): ("eager", "rendezvous"),
    ("alltoall", "bruck"): ("eager",),
    ("allreduce", "recursive_doubling"): ("eager", "rendezvous"),
    ("allreduce", "ring"): ("rendezvous",),
    ("allreduce", "bidi_ring"): ("rendezvous",),
    ("allreduce", "halving_doubling"): ("rendezvous",),
    ("reduce_scatter", "ring"): ("rendezvous",),
    ("reduce_scatter", "recursive_halving"): ("rendezvous",),
    ("allgather", "ring"): ("eager", "rendezvous"),
    ("allgather", "recursive_doubling"): ("rendezvous",),
}

# (collective, algorithm) pairs whose generators require 2^k ranks.
_POW2_ONLY = {
    ("allreduce", "recursive_doubling"),
    ("allreduce", "halving_doubling"),
    ("reduce_scatter", "recursive_halving"),
    ("allgather", "recursive_doubling"),
    ("alltoall", "bruck"),
    ("gather", "binomial_tree"),
}


@dataclasses.dataclass(frozen=True)
class Choice:
    collective: str
    algorithm: str
    protocol: str
    predicted_s: float
    schedule: Schedule


class Selector:
    """Prices schedules; honours a user tuning table first."""

    def __init__(self, eager_max_bytes: int = 64 * 1024):
        self.eager_max_bytes = eager_max_bytes
        # (collective, lo_bytes, hi_bytes, nranks_or_None) -> algorithm
        self._tuning: list[tuple] = []

    # -- the paper's runtime configuration parameters ----------------------
    def set_tuning(self, collective: str, algorithm: str,
                   lo_bytes: int = 0, hi_bytes: int = 1 << 62,
                   nranks: Optional[int] = None) -> None:
        self._tuning.append((collective, lo_bytes, hi_bytes, nranks, algorithm))

    def _tuned(self, collective: str, msg_bytes: int, n: int) -> Optional[str]:
        for (c, lo, hi, nr, algo) in reversed(self._tuning):
            if c == collective and lo <= msg_bytes < hi and (nr is None or nr == n):
                return algo
        return None

    # -- pricing ------------------------------------------------------------
    def _protocol_overhead(self, protocol: str, msg_bytes: float,
                           comm: Communicator) -> Optional[float]:
        if protocol == "eager":
            if msg_bytes > self.eager_max_bytes:
                return None  # Rx-buffer pool exceeded
            return msg_bytes / comm.hw.eager_copy_bw
        return comm.hw.rendezvous_rtt

    def price(self, schedule: Schedule, protocol: str, msg_bytes: float,
              comm: Communicator) -> Optional[float]:
        ov = self._protocol_overhead(protocol, msg_bytes, comm)
        if ov is None:
            return None
        return schedule.predict_time(msg_bytes, comm.hop_latency,
                                     comm.link_bw) + ov

    def candidates(self, collective: str, comm: Communicator):
        for (coll, algo), gen in algos.GENERATORS.items():
            if coll != collective:
                continue
            if (coll, algo) in _POW2_ONLY and not comm.is_pow2:
                continue
            if comm.size < 2:
                continue
            yield algo, gen

    def choose(self, collective: str, msg_bytes: int,
               comm: Communicator) -> Choice:
        tuned = self._tuned(collective, msg_bytes, comm.size)
        best: Optional[Choice] = None
        for algo, gen in self.candidates(collective, comm):
            sched = gen(comm)
            protos = ALGO_PROTOCOLS.get((collective, algo), ("rendezvous",))
            for proto in protos:
                t = self.price(sched, proto, msg_bytes, comm)
                if t is None:
                    continue
                cand = Choice(collective, algo, proto, t, sched)
                if tuned == algo:
                    return cand
                if best is None or t < best.predicted_s:
                    best = cand
        if best is None:
            raise ValueError(
                f"no applicable algorithm for {collective} over {comm}")
        return best

    def table(self, collective: str, comm: Communicator,
              sizes=(1 << 10, 1 << 13, 1 << 17, 1 << 20, 1 << 24, 1 << 27)):
        """Selection table — the fig12-style artifact for EXPERIMENTS.md."""
        return {s: self.choose(collective, s, comm) for s in sizes}
