"""Streaming plugins — ACCL+'s in-flight unary/binary operators (§4.4.2).

"Binary operations are typically utilized to implement reductions — sum,
max, etc. Unary operators may implement compression or encryption."

Binary plugins combine the arriving chunk with the local one; unary plugins
transform chunks on the wire. Our unary plugins are *compressors* used for
compressed gradient collectives (a distributed-optimization trick the
paper's plugin architecture anticipates): payloads shrink on the wire and
are decompressed at the consumer.

Every plugin has a pure-jnp implementation (the oracle) and, where it is a
compute hot-spot, a Pallas kernel (repro.kernels) selected by `use_pallas`.
A compressor returns a pytree of wire arrays so the engine can ppermute
each leaf.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

# --------------------------------------------------------------------------
# Binary plugins (combine ops)
# --------------------------------------------------------------------------

def _add(a, b):
    return a + b


BINARY_PLUGINS: dict[str, Callable] = {
    "copy": lambda old, new: new,
    "add": _add,
    "max": jnp.maximum,
    "min": jnp.minimum,
    "mul": jnp.multiply,
}


def combine(op: str, old, new, use_pallas: bool = False):
    """Apply a binary plugin. The Pallas path fuses combine+cast in VMEM."""
    if use_pallas and op == "add" and old.dtype == new.dtype and old.ndim >= 1:
        from repro.kernels import ops as kops
        return kops.fused_add(old, new)
    return BINARY_PLUGINS[op](old, new)


# --------------------------------------------------------------------------
# Unary plugins (compressors)
# --------------------------------------------------------------------------

class Compressed(NamedTuple):
    """Wire format: payload + per-block scales (empty for cast codecs)."""

    payload: jax.Array
    scale: jax.Array


QUANT_BLOCK = 256  # elements per int8 scale block


def _pad_to(x: jax.Array, mult: int) -> tuple[jax.Array, int]:
    n = x.shape[0]
    pad = (-n) % mult
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,) + x.shape[1:], x.dtype)])
    return x, n


def bf16_compress(x: jax.Array) -> Compressed:
    return Compressed(x.astype(jnp.bfloat16), jnp.zeros((0,), jnp.float32))


def bf16_decompress(c: Compressed, dtype) -> jax.Array:
    return c.payload.astype(dtype)


def int8_compress(x: jax.Array, use_pallas: bool = False) -> Compressed:
    """Per-block symmetric int8 quantization of a flat array."""
    if use_pallas:
        from repro.kernels import ops as kops
        q, s = kops.quantize_int8(x.reshape(-1))
        return Compressed(q, s)
    flat = x.reshape(-1)
    flat, _ = _pad_to(flat, QUANT_BLOCK)
    blocks = flat.reshape(-1, QUANT_BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale[:, None]), -127, 127).astype(jnp.int8)
    return Compressed(q.reshape(-1), scale.astype(jnp.float32))


def int8_decompress(c: Compressed, shape, dtype,
                    use_pallas: bool = False) -> jax.Array:
    if use_pallas:
        from repro.kernels import ops as kops
        flat = kops.dequantize_int8(c.payload, c.scale)
    else:
        blocks = c.payload.reshape(-1, QUANT_BLOCK).astype(jnp.float32)
        flat = (blocks * c.scale[:, None]).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape).astype(dtype)


class Codec(NamedTuple):
    compress: Callable
    decompress: Callable  # (Compressed, shape, dtype) -> array
    wire_bytes_per_elem: float
    # Scale-block granularity in elements. Wire segmentation only admits
    # segment sizes that are whole blocks (per-segment scale reuse): every
    # scale is computed from exactly the elements it would see
    # unsegmented, so segmented codec wires are bitwise-identical to
    # unsegmented ones. 1 = elementwise codec, any segmentation is exact.
    block_elems: int = 1


CODECS: dict[str, Codec] = {
    "bf16": Codec(
        lambda x, use_pallas=False: bf16_compress(x),
        lambda c, shape, dtype, use_pallas=False: bf16_decompress(c, dtype).reshape(shape),
        2.0,
        1,
    ),
    "int8": Codec(
        int8_compress,
        lambda c, shape, dtype, use_pallas=False: int8_decompress(
            c, shape, dtype, use_pallas),
        1.0 + 4.0 / QUANT_BLOCK,
        QUANT_BLOCK,
    ),
}


def get_codec(name: str) -> Codec:
    if name not in CODECS:
        raise ValueError(f"unknown codec {name!r}; have {sorted(CODECS)}")
    return CODECS[name]


# --------------------------------------------------------------------------
# Collective registry — "new collectives without re-synthesis" (§4.2)
# --------------------------------------------------------------------------
#
# In ACCL+ a new collective is new uC firmware: a new microprogram over the
# fixed DMA/packetizer primitive set, deployed without re-synthesizing the
# circuit. Here the analogue is a schedule generator registered at runtime:
# it lowers through the same compiler and `execute_program` data plane as
# every built-in, gets priced by the selector next to its sibling
# algorithms, and runs in the numpy simulator for validation. See
# examples/custom_collective.py for a worked out-of-tree example.

# name -> {algorithm -> (schedule_fn, protocols)}
CUSTOM_COLLECTIVES: dict[str, dict[str, tuple]] = {}
# bumped on every registry mutation; Selector choice caches key on it so
# (un)registering a collective invalidates stale picks
_REGISTRY_VERSION = 0


def registry_version() -> int:
    return _REGISTRY_VERSION


# Registration probe grid: the sizes x segments x codecs a user schedule
# generator must verify on BEFORE it enters the registry — the "no
# re-synthesis, still safe" property. Pow2 and non-pow2 sizes so both
# generator branches are exercised; int8 exercises the blocked-codec
# rules. Generators are free to ValueError on sizes they don't serve.
_PROBE_SIZES = (4, 5, 8)
_PROBE_SEGMENTS = (1, 4)
_PROBE_CODECS = (None, "int8")


def _probe_verify(name: str, algorithm: str, schedule_fn: Callable) -> None:
    """Compile + fully verify the generator across the probe grid.

    Raises `VerifyError` (chained, with the failing probe point named)
    so a broken user schedule is rejected at registration time with an
    actionable diagnostic instead of hanging the fabric at run time.
    """
    import inspect

    from repro.core.topology import Communicator
    from repro.core.verify import VerifyError, verify_program

    try:
        params = inspect.signature(schedule_fn).parameters
        extra_required = [
            p.name for p in list(params.values())[1:]
            if p.default is inspect.Parameter.empty
            and p.kind in (inspect.Parameter.POSITIONAL_OR_KEYWORD,
                           inspect.Parameter.KEYWORD_ONLY)]
    except (TypeError, ValueError):
        extra_required = None
    if extra_required:
        # Can't probe a generator whose extra arguments we can't supply;
        # it still verifies on every compile (structural) and under
        # REPRO_VERIFY=full.
        return
    for n in _PROBE_SIZES:
        comm = Communicator(axis="x", size=n)
        try:
            sched = schedule_fn(comm)
        except ValueError:
            continue  # generator declares it cannot serve this size
        for segments in _PROBE_SEGMENTS:
            for codec in _PROBE_CODECS:
                try:
                    prog = sched.compile(segments=segments, codec=codec,
                                         verify="off")
                    verify_program(prog, sched, level="full")
                except VerifyError as e:
                    raise VerifyError(
                        e.rule,
                        f"cannot register collective {name!r} "
                        f"(algorithm {algorithm!r}): verification failed "
                        f"at probe nranks={n} segments={segments} "
                        f"codec={codec!r}: {e}",
                        op_index=e.op_index, rank=e.rank,
                        step=e.step) from e


def register_collective(name: str, schedule_fn: Callable,
                        algorithm: str = "custom",
                        protocols: tuple = ("rendezvous",),
                        verify: bool = True) -> None:
    """Register an out-of-tree collective.

    schedule_fn(comm, **kwargs) -> Schedule; `root`/`op` keyword
    parameters are forwarded by the engine when the generator declares
    them. A generator that cannot serve a communicator (e.g. requires
    pow2 ranks) should raise ValueError — the selector skips it, like
    the built-ins' pow2 filter. Multiple algorithms may be registered
    under one collective name — the selector prices them all (under
    `protocols`) and `algorithm="auto"` picks the cheapest, exactly like
    the built-in table.

    Unless `verify=False`, the generator is compiled and FULLY verified
    (core/verify.py) across a probe grid of communicator sizes x
    segment counts x codecs before it enters the registry: a malformed
    schedule is rejected here, with rule/op/rank diagnostics, not
    discovered as wrong numerics or a hang at trace time.
    """
    global _REGISTRY_VERSION
    if not callable(schedule_fn):
        raise TypeError(f"schedule_fn for {name!r} must be callable")
    if verify:
        _probe_verify(name, algorithm, schedule_fn)
    CUSTOM_COLLECTIVES.setdefault(name, {})[algorithm] = (
        schedule_fn, tuple(protocols))
    _REGISTRY_VERSION += 1


def unregister_collective(name: str, algorithm: Optional[str] = None) -> None:
    """Remove a registered collective (all algorithms if none named)."""
    global _REGISTRY_VERSION
    if algorithm is None:
        CUSTOM_COLLECTIVES.pop(name, None)
    else:
        CUSTOM_COLLECTIVES.get(name, {}).pop(algorithm, None)
    _REGISTRY_VERSION += 1


def custom_generator(name: str, algorithm: str) -> Optional[Callable]:
    entry = CUSTOM_COLLECTIVES.get(name, {}).get(algorithm)
    return entry[0] if entry is not None else None


def custom_candidates(name: str):
    """(algorithm, schedule_fn, protocols) triples registered for `name`."""
    for algo, (fn, protos) in CUSTOM_COLLECTIVES.get(name, {}).items():
        yield algo, fn, protos
