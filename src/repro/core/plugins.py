"""Streaming plugins — ACCL+'s in-flight unary/binary operators (§4.4.2).

"Binary operations are typically utilized to implement reductions — sum,
max, etc. Unary operators may implement compression or encryption."

Binary plugins combine the arriving chunk with the local one; unary plugins
transform chunks on the wire. Our unary plugins are *compressors* used for
compressed gradient collectives (a distributed-optimization trick the
paper's plugin architecture anticipates): payloads shrink on the wire and
are decompressed at the consumer.

Every plugin has a pure-jnp implementation (the oracle) and, where it is a
compute hot-spot, a Pallas kernel (repro.kernels) selected by `use_pallas`.
A compressor returns a pytree of wire arrays so the engine can ppermute
each leaf.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

# --------------------------------------------------------------------------
# Binary plugins (combine ops)
# --------------------------------------------------------------------------

def _add(a, b):
    return a + b


BINARY_PLUGINS: dict[str, Callable] = {
    "copy": lambda old, new: new,
    "add": _add,
    "max": jnp.maximum,
    "min": jnp.minimum,
    "mul": jnp.multiply,
}


def combine(op: str, old, new, use_pallas: bool = False):
    """Apply a binary plugin. The Pallas path fuses combine+cast in VMEM."""
    if use_pallas and op == "add" and old.dtype == new.dtype and old.ndim >= 1:
        from repro.kernels import ops as kops
        return kops.fused_add(old, new)
    return BINARY_PLUGINS[op](old, new)


# --------------------------------------------------------------------------
# Unary plugins (compressors)
# --------------------------------------------------------------------------

class Compressed(NamedTuple):
    """Wire format: payload + per-block scales (empty for cast codecs)."""

    payload: jax.Array
    scale: jax.Array


QUANT_BLOCK = 256  # elements per int8 scale block


def _pad_to(x: jax.Array, mult: int) -> tuple[jax.Array, int]:
    n = x.shape[0]
    pad = (-n) % mult
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,) + x.shape[1:], x.dtype)])
    return x, n


def bf16_compress(x: jax.Array) -> Compressed:
    return Compressed(x.astype(jnp.bfloat16), jnp.zeros((0,), jnp.float32))


def bf16_decompress(c: Compressed, dtype) -> jax.Array:
    return c.payload.astype(dtype)


def int8_compress(x: jax.Array, use_pallas: bool = False) -> Compressed:
    """Per-block symmetric int8 quantization of a flat array."""
    if use_pallas:
        from repro.kernels import ops as kops
        q, s = kops.quantize_int8(x.reshape(-1))
        return Compressed(q, s)
    flat = x.reshape(-1)
    flat, _ = _pad_to(flat, QUANT_BLOCK)
    blocks = flat.reshape(-1, QUANT_BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale[:, None]), -127, 127).astype(jnp.int8)
    return Compressed(q.reshape(-1), scale.astype(jnp.float32))


def int8_decompress(c: Compressed, shape, dtype,
                    use_pallas: bool = False) -> jax.Array:
    if use_pallas:
        from repro.kernels import ops as kops
        flat = kops.dequantize_int8(c.payload, c.scale)
    else:
        blocks = c.payload.reshape(-1, QUANT_BLOCK).astype(jnp.float32)
        flat = (blocks * c.scale[:, None]).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape).astype(dtype)


class Codec(NamedTuple):
    compress: Callable
    decompress: Callable  # (Compressed, shape, dtype) -> array
    wire_bytes_per_elem: float


CODECS: dict[str, Codec] = {
    "bf16": Codec(
        lambda x, use_pallas=False: bf16_compress(x),
        lambda c, shape, dtype, use_pallas=False: bf16_decompress(c, dtype).reshape(shape),
        2.0,
    ),
    "int8": Codec(
        int8_compress,
        lambda c, shape, dtype, use_pallas=False: int8_decompress(
            c, shape, dtype, use_pallas),
        1.0 + 4.0 / QUANT_BLOCK,
    ),
}


def get_codec(name: str) -> Codec:
    if name not in CODECS:
        raise ValueError(f"unknown codec {name!r}; have {sorted(CODECS)}")
    return CODECS[name]
