"""Hardware constants for the roofline model (TPU v5e target).

ACCL+ evaluates on Alveo-U55C + 100 Gb/s Ethernet; our target is a TPU v5e
pod slice. These constants feed the algorithm selector's alpha-beta cost
model (core/selector.py) and the roofline analysis (benchmarks/roofline.py).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class HwSpec:
    """Per-chip hardware description."""

    name: str = "tpu-v5e"
    # Compute.
    peak_flops_bf16: float = 197e12  # FLOP/s per chip
    peak_flops_int8: float = 394e12
    # Memory.
    hbm_bytes: float = 16e9         # capacity per chip
    hbm_bw: float = 819e9           # bytes/s per chip
    vmem_bytes: float = 128 * 2**20  # ~128 MiB VMEM per chip
    # Interconnect.
    ici_link_bw: float = 50e9       # bytes/s per ICI link (per direction)
    ici_links_per_chip: int = 4     # 2-D torus: +x, -x, +y, -y
    dcn_bw: float = 25e9            # bytes/s per chip, pod-to-pod (data center network)
    # Latency terms (alpha in the alpha-beta model), seconds.
    ici_hop_latency: float = 1e-6   # per-hop ICI latency
    dcn_hop_latency: float = 10e-6  # pod-to-pod latency
    # Wire-segmentation floors (Rx-buffer minimums): never cut a step's
    # payload below this many bytes per segment. The DCN floor is much
    # higher than the ICI one because the 10 us pod-to-pod alpha makes
    # tiny segments pure latency (alpha*bw is 250 KB on DCN vs 50 KB on
    # ICI), so the pod axis prices a different segment optimum.
    ici_min_segment_bytes: float = 8 * 1024
    dcn_min_segment_bytes: float = 256 * 1024
    # Eager-protocol modeled staging-copy bandwidth (HBM copy at receiver).
    eager_copy_bw: float = 819e9
    # Eager-protocol cutoffs: the Rx staging pool is per-fabric, and the
    # DCN pool is provisioned smaller (more peers share it), so a DCN
    # communicator rejects eager at sizes the ICI one still accepts.
    ici_eager_max_bytes: float = 64 * 1024
    dcn_eager_max_bytes: float = 32 * 1024
    # Mesh axes that cross the pod boundary (priced on DCN). Renamed or
    # additional DCN axes belong here rather than in string compares.
    dcn_axes: tuple = ("pod",)
    # Rendezvous handshake: one extra round trip before payload.
    rendezvous_rtt: float = 2e-6

    # MXU native tile (for kernel block alignment checks).
    mxu_dim: int = 128
    vpu_lanes: int = 8 * 128


# The paper's cluster, for benchmark parity tables: 100 Gb/s = 12.5 GB/s.
ACCL_CLUSTER = HwSpec(
    name="alveo-u55c-100gbe",
    peak_flops_bf16=30e12,
    hbm_bytes=16e9,
    hbm_bw=460e9,
    ici_link_bw=12.5e9,
    ici_links_per_chip=1,
    dcn_bw=12.5e9,
    ici_hop_latency=2e-6,
    dcn_hop_latency=2e-6,
)

TPU_V5E = HwSpec()


def bytes_of(shape, dtype_bytes: int) -> int:
    n = 1
    for s in shape:
        n *= int(s)
    return n * dtype_bytes
