"""Collective offload sequencer — the CCLO request queue (§5, use case 1).

ACCL+'s second headline role is the *collective offload engine*: a CPU
application enqueues non-blocking collective calls into the CCLO's
request queue and overlaps its own compute while the engine drains the
outstanding operations (the distributed vector-matrix use case). This
module is that queue for our reproduction:

  CollectiveEngine.issue(...) -> Request     enqueue, return immediately
  Request.wait() / Sequencer.drain()         materialize results
  Sequencer.makespan(axis)                   queue-level pricing

The `Sequencer` tracks outstanding requests per communicator (mesh axis)
with FIFO ordering — the CCLO pops its command queue in order — plus
cross-request dependency edges: two requests naming the same buffer
object conflict (the queue must not reorder them), a request whose
operand IS another `Request` depends on that request's result, and
`after=` overrides the inference. Materializing a request materializes
its FIFO prefix on the same communicator and the dependency closure
across communicators, so conflicting requests never reorder.

Coalescing (the paper's offload win for many tiny CPU-side calls):
consecutive queued small same-(axis, op, dtype) reductions collapse into
ONE bucketed program before compile — one alpha, one selector choice,
one wire crossing for the whole bucket. Coalescing is bitwise-neutral
by construction: a bucket forms only when every member AND the combined
bucket resolve to an algorithm whose elementwise combine order is
independent of element position and message size (`ORDER_SAFE` — the
SEL_ALL pairwise hypercube exchanges: every element is reduced by the
identical sequence of adds wherever it sits), so slicing the bucketed
result reproduces the unbucketed bits exactly.

Queue-level pricing (`makespan`) composes the per-program split cost
(`Program.cost_terms`) the same way the data plane's fill/drain model
prices segments: requests sharing one communicator serialize their WIRE
occupancy (one set of links), while the per-hop alpha/handshake half of
a *queued* request hides behind the wire time of the one in flight —
non-blocking issue keeps the queue primed, so the control plane never
re-enters the loop between requests. Nothing hides along a dependency
chain: dependent requests serialize their full costs, and the longest
chain lower-bounds the makespan:

    makespan = max( max over dependency chains of sum(full_i),
                    sum_i wire_i + max_i latency_i )

For a queue of independent requests this sits strictly below the sum of
blocking `Program.cost`s (all but one request's alpha is hidden); for a
fully serial chain it degenerates to exactly that sum — no credit the
drain cannot cash, mirroring the split segment-pricing model.

The numpy simulator executes drained queues over per-rank buffers
(`simulate_drain`) through the SAME compiled programs the pricing walks
(`simulator.run_collective`), so makespan and execution are validated
against one artifact. A sequencer drains either through its engine
(inside a trace) or through the simulator — not both.

Trace-time contract: requests issued inside a traced function hold
tracers and MUST be waited/drained before the trace ends (the engine's
MPI-like calls are trace-time too; the queue only defers them).

Reliability (the ACCL+ fault story): every request ends in exactly one
typed terminal state — DONE, TIMED_OUT, CANCELLED, or PEER_FAILED —
never a hang. `simulate_drain` accepts a `FaultPlan` + `ReliabilityTier`
and executes the queue against the lossy fabric with a purely VIRTUAL
clock (priced program cost + retry alphas + deterministic backoff; no
wall-clock anywhere): a request whose tier-level retries recover
materializes bitwise-identical to the fault-free drain, one that cannot
ends typed, and failures cascade as CANCELLED to dependents. A
`FaultPlan` that kills a rank shrinks the communicator to the survivors
and the selector REPLANS the still-queued collectives on the degraded
fabric. `Sequencer.abort()` (or using the sequencer as a context
manager) cancels everything outstanding and provably empties the
engine's queue — no stale tracers survive an aborted trace.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Optional

import numpy as np

from repro.core import telemetry
from repro.core.pricing import resolve_env


class RequestCancelled(RuntimeError):
    """Typed terminal error raised when a CANCELLED request is waited."""


class DrainModeError(RuntimeError):
    """A sequencer drains either through its engine (inside a trace) or
    through the numpy simulator — never both. Mixing the two on one
    queue would interleave trace-time tracers with per-rank numpy
    buffers and silently corrupt whichever drain ran second; the first
    drain claims the queue and the other path raises this instead."""


def _size_of(shape) -> int:
    n = 1
    for d in shape:
        n *= int(d)
    return n


def _result_shape(collective: str, shape: tuple, nranks: int) -> tuple:
    """Static result shape of an engine collective (engine.py wrappers).

    Custom (plugin-registered) collectives are priced/chained at their
    operand shape — good enough for the queue model; their materialized
    result follows the schedule's own convention."""
    size = _size_of(shape)
    if collective == "reduce_scatter":
        return (size // nranks,)
    if collective in ("allgather", "gather"):
        return (size * nranks,)
    return tuple(shape)


@dataclasses.dataclass(eq=False)
class Request:
    """Handle for one queued collective — the CCLO request-queue entry.

    `operand` is the issuing array (or another Request, a dependency
    edge); `kwargs` are the engine-call keywords (op, root, algorithm,
    compression, segments). `shape`/`dtype` are the STATIC result
    signature — known at issue time, so the queue prices and chains
    requests without materializing anything.

    `status` walks PENDING -> exactly one terminal state: DONE (result
    available), TIMED_OUT (deadline or retry budget exhausted),
    CANCELLED (explicit `cancel()`/`abort()` or a failed dependency),
    PEER_FAILED (a peer rank died). `timeout` is a VIRTUAL-seconds
    deadline enforced by the simulated drain's clock.
    """

    PENDING = "PENDING"
    DONE = "DONE"
    TIMED_OUT = "TIMED_OUT"
    CANCELLED = "CANCELLED"
    PEER_FAILED = "PEER_FAILED"

    rid: int
    collective: str
    axis: str
    operand: object
    kwargs: dict
    shape: tuple
    dtype: object
    deps: tuple = ()
    timeout: Optional[float] = None
    status: str = PENDING
    error: object = dataclasses.field(default=None, repr=False)
    _seq: object = dataclasses.field(default=None, repr=False)
    _pre: object = dataclasses.field(default=None, repr=False)
    _post: object = dataclasses.field(default=None, repr=False)
    _done: bool = dataclasses.field(default=False, repr=False)
    _result: object = dataclasses.field(default=None, repr=False)

    @property
    def done(self) -> bool:
        return self._done

    @property
    def failed(self) -> bool:
        return self.status in (self.TIMED_OUT, self.CANCELLED,
                               self.PEER_FAILED)

    @property
    def finished(self) -> bool:
        """Terminal (success OR typed failure) — never a hang."""
        return self._done or self.status != self.PENDING

    @property
    def msg_bytes(self) -> int:
        """Bytes of the ISSUED payload (the wire-pricing size). Works
        for array and Request operands alike — both carry a static
        shape."""
        return _size_of(self.operand.shape) * np.dtype(self.dtype).itemsize

    @property
    def result(self):
        if self.failed:
            err = self.error if isinstance(self.error, BaseException) \
                else RequestCancelled(
                    f"request {self.rid} ended {self.status}")
            raise err
        if not self._done:
            raise ValueError(f"request {self.rid} not materialized; "
                             f"call wait() or Sequencer.drain()")
        return self._result

    def wait(self):
        """Materialize this request (and, by FIFO + dependency order,
        everything that must execute before it). Returns the result;
        raises the typed terminal error if the request failed."""
        if self.failed:
            return self.result  # raises the typed error
        return self._seq._materialize(self)

    def cancel(self) -> None:
        """Cancel this queued request and, transitively, every
        outstanding request that depends on it. Idempotent; a no-op on
        requests already in a terminal state."""
        self._seq._fail(self, self.CANCELLED,
                        RequestCancelled(f"request {self.rid} cancelled"))


@dataclasses.dataclass(frozen=True)
class PlanItem:
    """One drain step: a single request, or a coalesced bucket of >= 2."""

    requests: tuple

    @property
    def coalesced(self) -> bool:
        return len(self.requests) > 1

    @property
    def msg_bytes(self) -> int:
        return sum(r.msg_bytes for r in self.requests)


class Sequencer:
    """Outstanding-request tracker for one `CollectiveEngine`.

    Reached via `engine.queue`; `engine.issue(...)` / the `i`-prefixed
    conveniences (`iallreduce`, ...) enqueue here.
    """

    #: per-request coalescing cap: only reductions at or below this many
    #: payload bytes bucket (the offload win is many tiny CPU-side calls;
    #: large requests already amortize their alpha).
    COALESCE_BYTES = 64 * 1024

    #: algorithms whose elementwise combine order is independent of both
    #: element position and message size: every step exchanges and
    #: combines the FULL buffer pairwise (SEL_ALL), so element i of a
    #: coalesced bucket sees the identical sequence of fp adds it would
    #: see uncoalesced — the bitwise-neutrality precondition. Chunked
    #: algorithms (rings, halving/doubling) order each element's
    #: reduction by its chunk index and may NOT coalesce.
    ORDER_SAFE_ALGORITHMS = frozenset({"recursive_doubling"})

    def __init__(self, engine, coalesce_bytes: int = COALESCE_BYTES):
        self.engine = engine
        self.coalesce_bytes = int(coalesce_bytes)
        self._queues: dict = {}        # axis -> list[Request] (FIFO)
        self._rids = itertools.count()
        self._buffer_owner: dict = {}  # id(array) -> last touching Request
        # "engine" | "simulator" once a drain path has touched the queue;
        # the other path then raises DrainModeError (PR 5 watch item)
        self._drain_mode: Optional[str] = None
        # control-plane telemetry, asserted on by tests / trainer logs;
        # `stats` is the read-compatible live view over the registry
        self.metrics = telemetry.MetricsRegistry()
        for _name in ("issued", "executed",
                      "coalesced_buckets", "coalesced_requests"):
            self.metrics.counter(_name)
        self.stats = self.metrics.view()

    # -- enqueue -------------------------------------------------------------
    def issue(self, collective: str, x, axis: str, *, after=None,
              timeout: Optional[float] = None, _pre=None, _post=None,
              _shape=None, **kwargs) -> Request:
        """Enqueue a collective; returns a `Request` handle immediately.

        `x` is the operand array, or another `Request` (its result feeds
        this call — a structural DATAFLOW edge the queue always keeps).
        Ordering conflicts are additionally inferred from buffer
        identity: a request whose operand IS the same array object as an
        outstanding request's will not reorder past it. `after=` (an
        iterable of Requests) overrides that inference with explicit
        edges — it never removes a dataflow edge, since the drain must
        materialize the operand regardless and the makespan model may
        not credit overlap the drain cannot cash. `timeout` is a
        virtual-seconds deadline enforced by the simulated drain's
        clock (typed TIMED_OUT, never a hang). Remaining keywords are
        forwarded to the blocking engine call at drain time.
        """
        if isinstance(x, Request):
            if x._seq is not self:
                raise ValueError("operand request belongs to a different "
                                 "sequencer")
            in_shape, dtype = x.shape, x.dtype
            structural = () if x._done else (x,)
            inferred = ()
        else:
            in_shape, dtype = tuple(x.shape), np.dtype(x.dtype)
            structural = ()
            owner = self._buffer_owner.get(id(x))
            inferred = (owner,) if owner is not None and not owner._done \
                else ()
        if after is None:
            deps = structural + inferred
        else:
            extra = tuple(r for r in after if not r._done)
            for r in extra:
                if r._seq is not self:
                    raise ValueError("after= request belongs to a "
                                     "different sequencer")
            deps = structural + tuple(r for r in extra
                                      if r not in structural)
        n = self.engine.comm(axis).size
        shape = tuple(_shape) if _shape is not None \
            else _result_shape(collective, in_shape, n)
        req = Request(rid=next(self._rids), collective=collective,
                      axis=axis, operand=x, kwargs=dict(kwargs),
                      shape=shape, dtype=dtype, deps=deps, timeout=timeout,
                      _seq=self, _pre=_pre, _post=_post)
        if not isinstance(x, Request):
            self._buffer_owner[id(x)] = req
        self._queues.setdefault(axis, []).append(req)
        self.metrics.inc("issued")
        tr = telemetry.current()
        if tr.enabled:
            tr.instant("request.issued",
                       track=f"queue:{telemetry.axis_label(axis)}",
                       rid=req.rid, collective=collective,
                       msg_bytes=req.msg_bytes,
                       deps=[d.rid for d in deps],
                       timeout_s=timeout)
        return req

    def issue_multi(self, x, axes, op: str = "add",
                    algorithm: str = "auto",
                    compression: Optional[str] = None) -> Request:
        """Non-blocking hierarchical allreduce: `engine.allreduce_multi`
        as queued work. Two live axes fold into ONE tuple-axis request
        (a single two-level hierarchical program); more than two fall
        back to the request chain (RS over axes[0] -> recurse -> AG
        back), each stage depending on the previous one. The returned
        request's wait() yields the fully reduced array in the operand's
        shape."""
        eng = self.engine
        axes = [a for a in axes if eng.mesh.shape[a] > 1]
        src_shape = x.shape if isinstance(x, Request) else tuple(x.shape)
        if not axes:
            # degenerate communicator: nothing moves. A Request operand
            # IS the answer (do not wait it here — issue never blocks);
            # an array operand is wrapped as an already-done request so
            # callers treat every leaf uniformly.
            if isinstance(x, Request):
                return x
            return Request(rid=next(self._rids), collective="allreduce",
                           axis="", operand=x, kwargs={},
                           shape=tuple(src_shape), dtype=np.dtype(x.dtype),
                           status=Request.DONE, _seq=self, _done=True,
                           _result=x)
        if len(axes) == 1:
            return self.issue("allreduce", x, axes[0], op=op,
                              algorithm=algorithm, compression=compression)
        if len(axes) == 2:
            # two-level case: ONE tuple-axis request — the engine runs it
            # as a single hierarchical program (or the priced flat
            # fallback), the queue prices it on the ProductComm's
            # per-level fabrics, and no pad/trim hooks are needed (so
            # simulate_drain can execute it)
            return self.issue("allreduce", x, (axes[1], axes[0]), op=op,
                              algorithm=algorithm, compression=compression)
        n0 = eng.mesh.shape[axes[0]]
        size = _size_of(src_shape)
        pad = (-size) % n0

        def pre(v, pad=pad):
            import jax.numpy as jnp
            flat = v.reshape(-1)
            if pad:
                flat = jnp.concatenate(
                    [flat, jnp.zeros((pad,), flat.dtype)])
            return flat

        r_rs = self.issue("reduce_scatter", x, axes[0], op=op,
                          algorithm=algorithm, compression=compression,
                          _pre=pre, _shape=((size + pad) // n0,))
        r_mid = self.issue_multi(r_rs, axes[1:], op=op,
                                 algorithm=algorithm,
                                 compression=compression)

        def post(v, size=size, shape=tuple(src_shape)):
            return v[:size].reshape(shape)

        return self.issue("allgather", r_mid, axes[0],
                          algorithm=algorithm, _post=post,
                          _shape=tuple(src_shape))

    # -- queue inspection ----------------------------------------------------
    def outstanding(self, axis: Optional[str] = None) -> list:
        if axis is not None:
            return list(self._queues.get(axis, ()))
        return sorted((r for q in self._queues.values() for r in q),
                      key=lambda r: r.rid)

    def axes_outstanding(self) -> list:
        """Axis keys (str or tuple) with outstanding requests, in
        first-issue order — what `MeshMakespan.of` composes over."""
        return [a for a, q in self._queues.items() if q]

    def clear(self) -> None:
        """Drop every outstanding request WITHOUT executing (model-only
        uses: makespan sweeps over hypothetical queues)."""
        self._queues.clear()
        self._buffer_owner.clear()

    # -- cancellation / abort ------------------------------------------------
    def _fail(self, req: Request, status: str, error) -> None:
        """Move `req` to terminal `status`, drop it from its queue and
        the buffer-identity index, and cascade CANCELLED to every
        outstanding dependent (their operand can never materialize).
        Idempotent on already-terminal requests."""
        if req._done or req.status != Request.PENDING:
            return
        req.status = status
        req.error = error
        tr = telemetry.current()
        if tr.enabled:
            tr.instant("request.terminal",
                       track=f"queue:{telemetry.axis_label(req.axis)}",
                       rid=req.rid, status=status,
                       error=type(error).__name__)
        q = self._queues.get(req.axis)
        if q is not None and req in q:
            q.remove(req)
        if not isinstance(req.operand, Request) \
                and self._buffer_owner.get(id(req.operand)) is req:
            del self._buffer_owner[id(req.operand)]
        for r in self.outstanding():
            if req in r.deps or r.operand is req:
                self._fail(r, Request.CANCELLED, RequestCancelled(
                    f"request {r.rid} cancelled: dependency {req.rid} "
                    f"ended {req.status}"))

    def abort(self) -> list:
        """Cancel EVERY outstanding request and empty the queue — the
        guaranteed cleanup path for an abandoned trace. After abort the
        engine's queue holds no requests and no stale tracers: the
        buffer-identity index is cleared, so the next collective issued
        through the engine starts from an empty sequencer state.
        Returns the cancelled requests (each in status CANCELLED)."""
        dropped = [r for r in self.outstanding() if not r.finished]
        for r in dropped:
            self._fail(r, Request.CANCELLED,
                       RequestCancelled(f"request {r.rid} aborted"))
        self._queues.clear()
        self._buffer_owner.clear()
        return dropped

    def __enter__(self) -> "Sequencer":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        """Context-manager cleanup: whatever the block left outstanding
        (normally or via an exception mid-drain) is aborted, so
        `engine.queue` is provably empty on exit."""
        self.abort()
        return False

    # -- coalescing ----------------------------------------------------------
    def _coalescible(self, r: Request) -> bool:
        kw = r.kwargs
        return (r.collective == "allreduce"
                and not r.deps and r._pre is None and r._post is None
                and not isinstance(r.operand, Request)
                and kw.get("compression") is None
                and kw.get("segments") is None
                and getattr(self.engine, "backend", "microcode")
                == "microcode"
                and r.msg_bytes <= self.coalesce_bytes)

    @staticmethod
    def _coalesce_key(r: Request) -> tuple:
        return (r.kwargs.get("op", "add"), np.dtype(r.dtype).str,
                r.kwargs.get("algorithm", "auto"))

    def _resolved_algorithm(self, collective: str, msg_bytes: int,
                            comm, algorithm, codec, elem_bytes) -> str:
        if algorithm in (None, "auto"):
            return self.engine.selector.choose(
                collective, msg_bytes, comm, codec=codec,
                elem_bytes=elem_bytes).algorithm
        return algorithm

    def _bucket_safe(self, group: list, comm) -> bool:
        """Bitwise-neutrality check: every member AND the combined
        bucket must resolve to one ORDER_SAFE algorithm (see class
        docstring). Resolution goes through the memoized selector, so
        the check prices nothing new. `comm` is the communicator the
        plan is being built FOR — the engine's own fabric when
        draining, the caller's override when pricing a hypothetical
        cluster — so coalescing decisions and pricing never diverge."""
        algo_kw = group[0].kwargs.get("algorithm", "auto")
        elem = np.dtype(group[0].dtype).itemsize
        algos = {self._resolved_algorithm("allreduce", r.msg_bytes, comm,
                                          algo_kw, None, elem)
                 for r in group}
        total = sum(r.msg_bytes for r in group)
        algos.add(self._resolved_algorithm("allreduce", total, comm,
                                           algo_kw, None, elem))
        return len(algos) == 1 and algos <= self.ORDER_SAFE_ALGORITHMS

    def _head_item(self, q, comm) -> PlanItem:
        """The next drain step of queue `q`: its head request, extended
        over the maximal run of consecutive coalescible same-key
        followers when the bucket passes `_bucket_safe`. The greedy scan
        is prefix-stable (a group never depends on what follows it), so
        draining head items one at a time yields exactly the groups
        `_partition` plans — without re-planning the whole queue per
        executed item."""
        r = q[0]
        if self._coalescible(r):
            key = self._coalesce_key(r)
            j = 1
            while (j < len(q) and self._coalescible(q[j])
                   and self._coalesce_key(q[j]) == key):
                j += 1
            if j >= 2 and self._bucket_safe(q[:j], comm):
                return PlanItem(requests=tuple(q[:j]))
        return PlanItem(requests=(r,))

    def _partition(self, axis: str, comm=None) -> list:
        """The drain plan for one communicator: the FIFO queue, with
        maximal runs of consecutive coalescible same-key requests folded
        into buckets (consecutive => no conflicting request can sit
        between members, so bucketing never reorders). `comm` defaults
        to the engine's own fabric (the drain plan); pricing against a
        different cluster passes its communicator so the plan matches
        what THAT hardware would coalesce."""
        comm = comm if comm is not None else self.engine.comm(axis)
        q = list(self._queues.get(axis, ()))
        items = []
        while q:
            item = self._head_item(q, comm)
            items.append(item)
            q = q[len(item.requests):]
        return items

    def plan(self, axis: str, comm=None) -> list:
        """The `PlanItem` sequence `drain` will execute for `axis` —
        the artifact `makespan` prices and `simulate_drain` runs."""
        return self._partition(axis, comm)

    # -- pricing -------------------------------------------------------------
    def _resolve_item(self, item: PlanItem, comm):
        """(schedule, program, msg_bytes, elem_bytes) for one plan item.

        The ONE resolver pricing, simulation, and chaining share: the
        program is the same compiled artifact the drain's blocking
        engine call memoizes (selector choice for auto, cached schedule
        + memoized compile for explicit algorithms); the schedule rides
        along for the simulator's result/owned_chunk conventions."""
        r = item.requests[0]
        kw = r.kwargs
        collective = r.collective if not item.coalesced else "allreduce"
        nbytes = item.msg_bytes
        elem = np.dtype(r.dtype).itemsize
        algorithm = kw.get("algorithm", "auto")
        codec = kw.get("compression")
        root, op = kw.get("root", 0), kw.get("op", "add")
        if algorithm in (None, "auto"):
            lead = int(r.operand.shape[0]) if collective == "alltoall" \
                and len(r.operand.shape) else None
            choice = self.engine.selector.choose(
                collective, nbytes, comm, codec=codec, elem_bytes=elem,
                lead_dim=lead)
            if root == 0 and op == "add":
                return choice.schedule, choice.program, nbytes, elem
            # the selector priced the root=0/op='add' schedule; the
            # drain executes the chosen ALGORITHM rebuilt for this
            # request's root/op (the same rule as engine._resolve)
            algorithm, segments = choice.algorithm, choice.segments
        else:
            segments = kw.get("segments") or 1
        sched = self.engine._cached_schedule(
            collective, algorithm, comm, root, op)
        sched = sched.with_segments(segments)
        return sched, sched.compile(codec=codec), nbytes, elem

    def _priced_plan(self, axis: str, env) -> tuple:
        """(comm, items, recs) for `axis`'s outstanding queue under a
        `PricingEnv`: `items` is the drain's `PlanItem` partition and
        `recs[i] = (full_s, lat_s, wire_s, links)` prices item i off the
        same compiled program the drain executes (`links` is the
        per-physical-link wire attribution from
        `Program.cost_terms(per_link=True)`). The shared source of
        truth for the single-queue `makespan` and the mesh-level
        composition (`core/mesh_cost.py`) — the latter never re-walks
        programs."""
        comm = env.comm if env.comm is not None else self.engine.comm(axis)
        items = self._partition(axis, comm)
        recs = []
        for it in items:
            _sched, prog, nbytes, elem = self._resolve_item(it, comm)
            full = prog.cost(nbytes, comm, elem_bytes=elem, env=env)
            lat, wire, links = prog.cost_terms(
                nbytes, comm, elem_bytes=elem, env=env, per_link=True)
            recs.append((full, lat, wire, links))
        return comm, items, recs

    @staticmethod
    def _compose(items: list, recs: list) -> float:
        """The queue-level pipelining composition over priced items:
        wire occupancy serializes across the plan, queued requests'
        alpha halves hide behind it, dependency chains serialize their
        full costs and lower-bound the result. Exactly the historical
        `makespan` arithmetic (values and summation order), so the
        refactor is bitwise-neutral."""
        pos = {r: i for i, it in enumerate(items) for r in it.requests}
        fulls = [rec[0] for rec in recs]
        lats = [rec[1] for rec in recs]
        wires = [rec[2] for rec in recs]
        chain = [0.0] * len(items)
        for i, it in enumerate(items):
            best = 0.0
            for r in it.requests:
                for d in r.deps:
                    j = pos.get(d)
                    if j is not None and j < i:
                        best = max(best, chain[j])
            chain[i] = best + fulls[i]
        return max(max(chain), sum(wires) + max(lats))

    def makespan(self, axis: str, comm=None,
                 tier=None, drop_prob: float = 0.0, env=None) -> float:
        """Predicted seconds to drain `axis`'s outstanding queue.

        The queue-level pipelining model (module docstring), priced off
        the same compiled programs the drain executes.
        Cross-communicator dependencies are priced on their own axis's
        makespan and treated as satisfied here — `core/mesh_cost.py`
        composes ALL axes' queues (shared-link contention + cross-axis
        chains) when that isolation is too optimistic.

        Pricing parameters arrive in a `pricing.PricingEnv` (`env=`):
        a comm override and the reliability surcharge
        (`Program.cost`/`cost_terms`), so the queue's price reflects
        the chosen reliability contract. The bare `comm=`/`tier=`/
        `drop_prob=` kwargs are a deprecation shim with identical
        semantics; the default env is bitwise-neutral fault-free
        pricing."""
        env = resolve_env(env, comm=comm, tier=tier, drop_prob=drop_prob)
        _comm, items, recs = self._priced_plan(axis, env)
        if not items:
            return 0.0
        return self._compose(items, recs)

    def serial_cost(self, axis: str, comm=None) -> float:
        """Sum of the blocking `Program.cost`s of the outstanding
        requests, priced individually (no coalescing, no overlap) — the
        serial-blocking reference makespan is measured against."""
        comm = comm if comm is not None else self.engine.comm(axis)
        total = 0.0
        for r in self._queues.get(axis, ()):
            _sched, prog, nbytes, elem = self._resolve_item(
                PlanItem(requests=(r,)), comm)
            total += prog.cost(nbytes, comm, elem_bytes=elem)
        return total

    # -- engine drain (trace-time execution) ---------------------------------
    def _operand_value(self, r: Request):
        if isinstance(r.operand, Request):
            val = self._materialize(r.operand)
        else:
            val = r.operand
        return r._pre(val) if r._pre is not None else val

    def _dispatch(self, r: Request, val):
        eng = self.engine
        if r.collective in ("allreduce", "reduce_scatter", "allgather",
                            "bcast", "reduce", "gather", "alltoall"):
            out = getattr(eng, r.collective)(val, r.axis, **r.kwargs)
        else:
            out = eng.collective(r.collective, val, r.axis, **r.kwargs)
        return r._post(out) if r._post is not None else out

    def _finish(self, r: Request, result) -> None:
        r._result = result
        r._done = True
        r.status = Request.DONE
        self.metrics.inc("executed")
        tr = telemetry.current()
        if tr.enabled:
            tr.instant("request.done",
                       track=f"queue:{telemetry.axis_label(r.axis)}",
                       rid=r.rid)
        if not isinstance(r.operand, Request) \
                and self._buffer_owner.get(id(r.operand)) is r:
            del self._buffer_owner[id(r.operand)]

    def _claim_drain(self, mode: str) -> None:
        if self._drain_mode is None:
            self._drain_mode = mode
        elif self._drain_mode != mode:
            raise DrainModeError(
                f"this sequencer already drained through the "
                f"{self._drain_mode}; it cannot also drain through the "
                f"{mode} (use a fresh Sequencer per drain path)")

    def _check_dag(self) -> None:
        """DL_DEP_CYCLE (core/verify.py): prove the outstanding request
        DAG acyclic before draining. `issue` keeps it acyclic by
        construction (deps always point at earlier rids), so this guards
        tampered handles and future edge sources — including cross-axis
        `issue_multi` chains, whose stage edges all live in `deps`."""
        from repro.core.verify import check_request_dag
        check_request_dag(
            [r for q in self._queues.values() for r in q if not r._done])

    def _run_item(self, item: PlanItem) -> None:
        tr = telemetry.current()
        if not tr.enabled:
            return self._run_item_inner(item)
        with tr.span(
                "drain.item",
                track=f"queue:{telemetry.axis_label(item.requests[0].axis)}",
                rids=[r.rid for r in item.requests],
                coalesced=item.coalesced):
            return self._run_item_inner(item)

    def _run_item_inner(self, item: PlanItem) -> None:
        self._claim_drain("engine")
        for r in item.requests:
            for d in r.deps:
                self._materialize(d)
        q = self._queues[item.requests[0].axis]
        if not item.coalesced:
            r = item.requests[0]
            out = self._dispatch(r, self._operand_value(r))
            self._finish(r, out)
            q.remove(r)
            return
        # bucketed reduction: ONE program for the whole run — compiled,
        # priced, and executed at the concatenated size; bitwise-neutral
        # by the ORDER_SAFE eligibility check
        import jax.numpy as jnp
        flats = [self._operand_value(r).reshape(-1) for r in item.requests]
        buf = jnp.concatenate(flats)
        r0 = item.requests[0]
        out = self.engine.allreduce(buf, r0.axis, **r0.kwargs)
        off = 0
        for r, flat in zip(item.requests, flats):
            n = flat.shape[0]
            self._finish(r, out[off:off + n].reshape(r.operand.shape))
            off += n
            q.remove(r)
        self.metrics.inc("coalesced_buckets")
        self.metrics.inc("coalesced_requests", len(item.requests))

    def _materialize(self, req: Request):
        if req._seq is not self:
            raise ValueError("request belongs to a different sequencer")
        if req.failed:
            return req.result  # raises the typed terminal error
        if not req._done and req not in self._queues.get(req.axis, ()):
            raise ValueError(f"request {req.rid} is not outstanding")
        while not req._done:
            if req.failed:
                return req.result  # raises the typed terminal error
            comm = self.engine.comm(req.axis)
            self._run_item(self._head_item(self._queues[req.axis], comm))
        return req._result

    def drain(self, axis: Optional[str] = None) -> list:
        """Materialize every outstanding request (on `axis`, or all
        communicators in global issue order). Returns the drained
        requests; results hang off each `Request.result`."""
        drained = []
        self._check_dag()
        if axis is not None:
            comm = self.engine.comm(axis)
            while self._queues.get(axis):
                item = self._head_item(self._queues[axis], comm)
                drained.extend(item.requests)
                self._run_item(item)
            return drained
        for r in self.outstanding():
            if not r._done:
                self._materialize(r)
            drained.append(r)
        return drained

    # -- simulator drain (numpy validation path) -----------------------------
    def simulate_drain(self, feeds: dict, fault_plan=None, tier=None,
                       degrade: bool = False) -> dict:
        """Drain the whole queue in the numpy simulator.

        `feeds` maps each leaf request (array operand) to its per-rank
        input list; requests whose operand is another Request consume
        that request's simulated per-rank results. Executes plan items
        in global issue order — per-communicator FIFO plus dependency
        order, exactly the engine drain's discipline — through
        `simulator.run_collective` on the SAME compiled programs
        `makespan` prices. Returns {request: per-rank result list} and
        marks the requests done (a simulated sequencer is spent; use a
        fresh one per engine drain).

        `fault_plan` (a `faults.FaultPlan`, with `tier` defaulting to
        tcp-like) executes the drain against the lossy fabric: a request
        whose tier-level retries recover materializes bitwise-identical
        to the fault-free drain; one that cannot ends in a TYPED
        terminal state (TIMED_OUT on loss/deadline, PEER_FAILED on a
        dead rank) with its dependents CANCELLED — never a hang, never
        a partial write. Per-request `timeout`s are enforced on the
        VIRTUAL clock (priced program cost + retry alphas + the tier's
        deterministic backoff); no wall-clock is consulted anywhere.
        With `degrade=True` a dead rank additionally shrinks the
        communicator to the survivors (`Communicator.without_ranks` — the
        degraded comm's rank table keeps every survivor's ORIGINAL id,
        so mid-mesh, non-contiguous survivors keep their data shards),
        the selector replans every still-queued collective on the
        degraded fabric, and surviving ranks' feeds carry on — the
        shrink-and-continue path the trainer demo rides."""
        from repro.core import simulator as sim
        from repro.core.faults import (
            FaultyTransport, PeerFailedError, TIERS, TransportError,
            TransportTimeout,
        )
        if any(r._pre is not None or r._post is not None
               for q in self._queues.values() for r in q):
            raise NotImplementedError(
                "simulate_drain does not execute issue_multi chains "
                "(their pad/trim hooks are trace-time jnp closures)")
        if any(self._queues.values()):
            self._claim_drain("simulator")
        self._check_dag()
        transport = None
        if fault_plan is not None:
            transport = FaultyTransport(
                plan=fault_plan,
                tier=tier if tier is not None else TIERS["tcp-like"])
        results: dict = {}
        comm_override: dict = {}   # axis -> degraded communicator
        # virtual drain clock — trace-only state: pricing never reads it,
        # and none of it is computed unless a tracer is installed
        tr = telemetry.current()
        clock = 0.0                # serial virtual clock (priced seconds)
        done_at: dict = {}         # rid -> virtual completion time
        occ = None                 # FabricOccupancy, lazily built
        while any(self._queues.values()):
            # global issue order: among queue heads, run the item whose
            # head request was issued first — dependencies always point
            # at earlier rids, so their communicator's head is scheduled
            # before the dependent request can reach its own head slot
            axis = min((a for a, q in self._queues.items() if q),
                       key=lambda a: self._queues[a][0].rid)
            comm = comm_override.get(axis)
            if comm is None:
                comm = self.engine.comm(axis)
            item = self._head_item(self._queues[axis], comm)
            # a failed dependency cancels the dependent before it runs
            bad = next(
                (d for r in item.requests
                 for d in (r.deps + ((r.operand,) if isinstance(
                     r.operand, Request) else ()))
                 if d.failed), None)
            if bad is not None:
                for r in item.requests:
                    self._fail(r, Request.CANCELLED, RequestCancelled(
                        f"request {r.rid} cancelled: dependency "
                        f"{bad.rid} ended {bad.status}"))
                continue
            sched, prog, nbytes, elem = self._resolve_item(item, comm)

            def _fit(v, comm=comm):
                # a feed recorded at the pre-shrink size is sliced to
                # the survivors' ORIGINAL rank ids (the degraded comm's
                # rank table); post-shrink results already fit.
                # ProductComm has no rank table (degradation is flat-
                # comm only), so tuple axes pass through.
                if getattr(comm, "ranks", None) is not None \
                        and len(v) != comm.size:
                    return [v[g] for g in comm.global_ranks]
                return list(v)

            vals = []
            for r in item.requests:
                if isinstance(r.operand, Request):
                    vals.append(_fit(results[r.operand]))
                else:
                    vals.append(_fit(feeds[r]))
            q = self._queues[axis]
            pre_retries = transport.retries if transport else 0
            pre_backoff = transport.backoff_s if transport else 0.0
            try:
                results_item = self._sim_item(
                    sim, item, sched, prog, vals, comm, transport)
            except PeerFailedError as e:
                if degrade:
                    # e.rank is local to the CURRENT comm; the rank
                    # table composes the original ids across repeated
                    # shrinks
                    comm_override[axis] = comm.without_ranks([e.rank])
                    if transport is not None:
                        # rank-keyed schedule entries do not survive the
                        # renumbering; background loss (drop_prob) does
                        transport = FaultyTransport(
                            plan=dataclasses.replace(
                                fault_plan, drops=frozenset(),
                                flaps=(), dead=()),
                            tier=transport.tier,
                            exchange=transport.exchange,
                            retries=transport.retries,
                            backoff_s=transport.backoff_s)
                for r in item.requests:
                    self._fail(r, Request.PEER_FAILED, e)
                continue
            except TransportError as e:
                for r in item.requests:
                    self._fail(r, Request.TIMED_OUT, e)
                continue
            # virtual clock for this item: priced cost + retry penalty
            elapsed = prog.cost(nbytes, comm, elem_bytes=elem)
            if transport is not None:
                elapsed += ((transport.retries - pre_retries)
                            * comm.hop_latency
                            + transport.backoff_s - pre_backoff)
            late = [r for r in item.requests
                    if r.timeout is not None and elapsed > r.timeout]
            if tr.enabled:
                # request-lifecycle attribution on the virtual clock:
                # dep_stall = waiting on dependencies, queue_wait = the
                # rest of the time between issue (t=0) and dispatch
                if occ is None:
                    from repro.core.topology import FabricOccupancy
                    occ = FabricOccupancy()
                dep_ready = max((done_at.get(d.rid, 0.0)
                                 for r in item.requests for d in r.deps),
                                default=0.0)
                lat_s, wire_s, links = prog.cost_terms(
                    nbytes, comm, elem_bytes=elem, per_link=True)
                tr.interval(
                    "request", f"queue:{telemetry.axis_label(axis)}",
                    clock, clock + elapsed,
                    rids=[r.rid for r in item.requests],
                    collective=item.requests[0].collective,
                    queue_wait_s=clock - dep_ready, dep_stall_s=dep_ready,
                    wire_s=wire_s, lat_s=lat_s,
                    retries=(transport.retries - pre_retries
                             if transport else 0),
                    backoff_s=(transport.backoff_s - pre_backoff
                               if transport else 0.0),
                    status="TIMED_OUT" if late else "DONE",
                    coalesced=item.coalesced)
                for lkey, w in links.items():
                    ck = occ.canonical(lkey)
                    tr.interval(
                        "wire", "link:" + "/".join(str(p) for p in ck),
                        clock, clock + w,
                        rids=[r.rid for r in item.requests])
                if not late:
                    for r in item.requests:
                        done_at[r.rid] = clock + elapsed
                clock += elapsed
            if late:
                for r in item.requests:
                    self._fail(r, Request.TIMED_OUT, TransportTimeout(
                        f"request {r.rid}: drain step took "
                        f"{elapsed:.3e}s virtual > timeout"))
                continue
            for r, per in results_item:
                results[r] = per
                self._finish(r, per)
                q.remove(r)
            if item.coalesced:
                self.metrics.inc("coalesced_buckets")
                self.metrics.inc("coalesced_requests", len(item.requests))
        return results

    def _sim_item(self, sim, item: PlanItem, sched, prog, vals, comm,
                  transport) -> list:
        """Run one plan item through `simulator.run_collective`;
        returns [(request, per_rank_results), ...] without touching
        queue state (the caller commits or converts a typed failure)."""
        if item.coalesced:
            n = comm.size
            cat = [np.concatenate([v[rank].reshape(-1) for v in vals])
                   for rank in range(n)]
            r0 = item.requests[0]
            outs = sim.run_collective(
                "allreduce", sched, prog, cat,
                root=r0.kwargs.get("root", 0), transport=transport)
            pairs = []
            off = 0
            for r, v in zip(item.requests, vals):
                ln = v[0].size
                per = [outs[rank][off:off + ln].reshape(v[rank].shape)
                       for rank in range(n)]
                pairs.append((r, per))
                off += ln
            return pairs
        r = item.requests[0]
        for d in r.deps:
            if not d._done:
                raise AssertionError(
                    "global-order drain reached a request before "
                    "its dependency — sequencer invariant broken")
        outs = sim.run_collective(
            r.collective, sched, prog, vals[0],
            root=r.kwargs.get("root", 0), transport=transport)
        return [(r, outs)]
