"""Collective algorithm library — schedule generators (the uC firmware).

Paper Table 1 algorithms, plus beyond-paper ones (Bruck all-to-all,
bidirectional ring, recursive halving) marked [+]:

  collective      eager (small msg)       rendezvous (large msg)
  --------------  ----------------------  --------------------------------
  bcast           one-to-all              binomial tree (recursive doubling)
  reduce          ring (unchunked relay)  all-to-one; binomial tree
  gather          ring                    all-to-one; binomial tree
  all-to-all      linear                  linear; [+] Bruck
  allreduce       recursive doubling      ring RS+AG; [+] bidirectional ring
  reduce-scatter  —                       ring; [+] recursive halving
  allgather       ring                    [+] recursive doubling

Every generator returns a `Schedule` (core/schedule.py) — pure data plus
rank-index closures. Nothing here touches jax; the engine interprets the
schedule, the simulator executes it in numpy, the selector prices it.
"""
from __future__ import annotations

import math

from repro.core.schedule import Schedule, Sel, Step
from repro.core.topology import Communicator


def _log2(n: int) -> int:
    k = int(math.log2(n))
    if (1 << k) != n:
        raise ValueError(f"power-of-two rank count required, got {n}")
    return k


# --------------------------------------------------------------------------
# Ring family (bandwidth-optimal chunked rings; paper's workhorse)
# --------------------------------------------------------------------------

def ring_reduce_scatter(comm: Communicator, op: str = "add") -> Schedule:
    """Chunked ring: n-1 steps, each moving 1/n of the buffer.

    Canonical layout (matches lax.psum_scatter tiled): after the schedule,
    rank r owns fully-reduced chunk r. Chunk c starts its journey at rank
    c+1 and lands at rank c after n-1 hops.

    The selector closures are shared across steps and pure in the step
    index (uniform=True), so the IR compiler rolls the whole ring into
    one LOOP micro-op — a single lax.scan with one live buffer.
    """
    n = comm.size
    perm = tuple(comm.ring_perm(1))
    send = Sel.chunk(lambda r, s: (r - s - 1) % n)
    recv = Sel.chunk(lambda r, s: (r - s - 2) % n)
    steps = tuple(
        Step(perm=perm, op=op, send_sel=send, recv_sel=recv,
             bytes_frac=1.0 / n, uniform=True)
        for _ in range(n - 1)
    )
    return Schedule(
        name="ring", collective="reduce_scatter", nranks=n, steps=steps,
        chunks=n, result="shard", owned_chunk=lambda r: r,
    )


def ring_allgather(comm: Communicator, own_shift: int = 0,
                   step_offset: int = 0) -> Schedule:
    """Chunked ring allgather; rank r initially owns chunk (r+own_shift)%n.

    `step_offset` is the global step index of this phase's first step when
    the steps are embedded in a composite schedule (ring allreduce): the
    shared uniform closures subtract it from the step index they receive.
    """
    n = comm.size
    perm = tuple(comm.ring_perm(1))
    send = Sel.chunk(
        lambda r, s, off=step_offset: (r + own_shift - (s - off)) % n)
    recv = Sel.chunk(
        lambda r, s, off=step_offset: (r + own_shift - 1 - (s - off)) % n)
    steps = tuple(
        Step(perm=perm, op="copy", send_sel=send, recv_sel=recv,
             bytes_frac=1.0 / n, uniform=True)
        for _ in range(n - 1)
    )
    return Schedule(
        name="ring", collective="allgather", nranks=n, steps=steps,
        chunks=n, result="full",
    )


def ring_allreduce(comm: Communicator, op: str = "add") -> Schedule:
    """Bandwidth-optimal ring allreduce: RS then AG, 2(n-1) steps."""
    n = comm.size
    rs = ring_reduce_scatter(comm, op)
    ag = ring_allgather(comm, own_shift=0, step_offset=n - 1)
    return Schedule(
        name="ring", collective="allreduce", nranks=n,
        steps=rs.steps + ag.steps, chunks=n, result="full",
    )


def bidi_ring_allreduce(comm: Communicator, op: str = "add") -> Schedule:
    """[+] Bidirectional ring: halves travel opposite directions (2 ICI links).

    Chunk space 2n: chunks [0, n) ride the clockwise ring, [n, 2n) the
    counter-clockwise ring. Steps alternate cw/ccw so XLA can schedule the
    two independent permutes concurrently; the cost model credits
    overlap_factor=2.
    """
    n = comm.size
    cw, ccw = tuple(comm.ring_perm(1)), tuple(comm.ring_perm(-1))
    # Steps interleave cw/ccw, so phase index = step_index // 2 (works for
    # both slots: global index 2s and 2s+1 floor-divide to s). Closures
    # are shared per direction and pure in (rank, step), so the compiler
    # coalesces each phase into one period-2 LOOP whose two slots write
    # disjoint chunk halves ([0, n) cw, [n, 2n) ccw) — XLA schedules the
    # two permutes on both ICI directions concurrently.
    rs_cw_send = Sel.chunk(lambda r, g: (r - g // 2 - 1) % n)
    rs_cw_recv = Sel.chunk(lambda r, g: (r - g // 2 - 2) % n)
    rs_ccw_send = Sel.chunk(lambda r, g: n + (r + g // 2 + 1) % n)
    rs_ccw_recv = Sel.chunk(lambda r, g: n + (r + g // 2 + 2) % n)
    ag_base = 2 * (n - 1)
    ag_cw_send = Sel.chunk(lambda r, g: (r - (g - ag_base) // 2) % n)
    ag_cw_recv = Sel.chunk(lambda r, g: (r - 1 - (g - ag_base) // 2) % n)
    ag_ccw_send = Sel.chunk(lambda r, g: n + (r + (g - ag_base) // 2) % n)
    ag_ccw_recv = Sel.chunk(
        lambda r, g: n + (r + 1 + (g - ag_base) // 2) % n)
    steps = []
    # reduce-scatter phase (canonical: rank r ends owning cw chunk r and
    # ccw chunk n + r, both fully reduced)
    for _ in range(n - 1):
        steps.append(Step(perm=cw, op=op, send_sel=rs_cw_send,
                          recv_sel=rs_cw_recv, bytes_frac=0.5 / n,
                          uniform=True))
        steps.append(Step(perm=ccw, op=op, send_sel=rs_ccw_send,
                          recv_sel=rs_ccw_recv, bytes_frac=0.5 / n,
                          uniform=True))
    # allgather phase (both halves owned at chunk r / n + r)
    for _ in range(n - 1):
        steps.append(Step(perm=cw, op="copy", send_sel=ag_cw_send,
                          recv_sel=ag_cw_recv, bytes_frac=0.5 / n,
                          uniform=True))
        steps.append(Step(perm=ccw, op="copy", send_sel=ag_ccw_send,
                          recv_sel=ag_ccw_recv, bytes_frac=0.5 / n,
                          uniform=True))
    return Schedule(
        name="bidi_ring", collective="allreduce", nranks=n,
        steps=tuple(steps), chunks=2 * n, result="full", overlap_factor=2.0,
    )


def ring_reduce(comm: Communicator, root: int = 0, op: str = "add") -> Schedule:
    """Eager ring reduce (paper Table 1): unchunked rotate-and-accumulate.

    Every rank relays what it received last step (not its accumulator), so
    after n-1 full-buffer rotations every rank — in particular the root —
    holds the complete reduction. relay='received'.
    """
    n = comm.size
    perm = tuple(comm.ring_perm(1))
    steps = tuple(
        Step(perm=perm, op=op, send_sel=Sel.all(), recv_sel=Sel.all(),
             bytes_frac=1.0, uniform=True)
        for _ in range(n - 1)
    )
    return Schedule(
        name="ring", collective="reduce", nranks=n, steps=steps,
        chunks=1, result="full", relay="received",
    )


def ring_gather(comm: Communicator, root: int = 0) -> Schedule:
    """Eager ring gather: chunks circulate until the root has all of them.

    Implemented as a full ring allgather (cost-identical; the paper's ring
    gather also moves every chunk n-1 hops); result marked 'root'.
    """
    g = ring_allgather(comm)
    return Schedule(
        name="ring", collective="gather", nranks=comm.size, steps=g.steps,
        chunks=comm.size, result="full",
    )


# --------------------------------------------------------------------------
# Hypercube family (log-step; paper's "recursive doubling" rendezvous algos)
# --------------------------------------------------------------------------

def recursive_doubling_allreduce(comm: Communicator, op: str = "add") -> Schedule:
    """log2(n) full-buffer pairwise exchanges; latency-optimal allreduce."""
    n = comm.size
    k = _log2(n)
    steps = tuple(
        Step(perm=tuple(comm.hypercube_perm(d)), op=op,
             send_sel=Sel.all(), recv_sel=Sel.all(), bytes_frac=1.0)
        for d in range(k)
    )
    return Schedule(
        name="recursive_doubling", collective="allreduce", nranks=n,
        steps=steps, chunks=1, result="full",
    )


def recursive_halving_reduce_scatter(comm: Communicator, op: str = "add") -> Schedule:
    """[+] log2(n) steps, halving the active range; rank r owns chunk r."""
    n = comm.size
    k = _log2(n)
    steps = []
    for j in range(k):
        d = n >> (j + 1)  # partner distance & half-size in chunks

        # Active range after j halvings starts at r & (n - n>>j) and has
        # length n >> j. Each step we keep the half selected by bit
        # log2(d) of r (send the other half, receive into the kept one).
        def send_range(r, s, d=d, j=j):
            off = r & (n - (n >> j))
            keep_upper = (r // d) % 2  # (r & d) != 0, written arithmetically
            return (off + (1 - keep_upper) * d, d)

        def recv_range(r, s, d=d, j=j):
            off = r & (n - (n >> j))
            keep_upper = (r // d) % 2
            return (off + keep_upper * d, d)

        steps.append(Step(
            perm=tuple(comm.hypercube_perm(int(math.log2(d)))),
            op=op,
            send_sel=Sel.range(send_range),
            recv_sel=Sel.range(recv_range),
            bytes_frac=d / n,
        ))
    return Schedule(
        name="recursive_halving", collective="reduce_scatter", nranks=n,
        steps=tuple(steps), chunks=n, result="shard",
        owned_chunk=lambda r: r,
    )


def recursive_doubling_allgather(comm: Communicator) -> Schedule:
    """[+] log2(n) steps, doubling the owned range; inverse of halving RS."""
    n = comm.size
    k = _log2(n)
    steps = []
    for j in range(k):
        d = 1 << j  # current owned length in chunks

        def send_range(r, s, d=d):
            return (r & ~(d - 1), d)

        def recv_range(r, s, d=d):
            return ((r ^ d) & ~(d - 1), d)

        steps.append(Step(
            perm=tuple(comm.hypercube_perm(j)),
            op="copy",
            send_sel=Sel.range(send_range),
            recv_sel=Sel.range(recv_range),
            bytes_frac=d / n,
        ))
    return Schedule(
        name="recursive_doubling", collective="allgather", nranks=n,
        steps=tuple(steps), chunks=n, result="full",
    )


def halving_doubling_allreduce(comm: Communicator, op: str = "add") -> Schedule:
    """[+] Rabenseifner: recursive-halving RS + recursive-doubling AG."""
    rs = recursive_halving_reduce_scatter(comm, op)
    ag = recursive_doubling_allgather(comm)
    return Schedule(
        name="halving_doubling", collective="allreduce", nranks=comm.size,
        steps=rs.steps + ag.steps, chunks=comm.size, result="full",
    )


# --------------------------------------------------------------------------
# Tree / star family (paper's bcast / reduce / gather algorithms)
# --------------------------------------------------------------------------

def binomial_tree_bcast(comm: Communicator, root: int = 0) -> Schedule:
    """Recursive-doubling broadcast: informed set doubles each round."""
    n = comm.size
    steps = tuple(
        Step(perm=tuple(pairs), op="copy", send_sel=Sel.all(),
             recv_sel=Sel.all(), bytes_frac=1.0, mask_recv=True)
        for pairs in comm.tree_rounds(root)
    )
    return Schedule(
        name="binomial_tree", collective="bcast", nranks=n, steps=steps,
        chunks=1, result="full",
    )


def one_to_all_bcast(comm: Communicator, root: int = 0) -> Schedule:
    """Eager linear broadcast: root sends to each rank in turn (n-1 steps)."""
    n = comm.size
    steps = tuple(
        Step(perm=((root, (root + i + 1) % n),), op="copy",
             send_sel=Sel.all(), recv_sel=Sel.all(), bytes_frac=1.0,
             mask_recv=True)
        for i in range(n - 1)
    )
    return Schedule(
        name="one_to_all", collective="bcast", nranks=n, steps=steps,
        chunks=1, result="full",
    )


def all_to_one_reduce(comm: Communicator, root: int = 0, op: str = "add") -> Schedule:
    """Rendezvous small-msg reduce: every rank sends straight to root.

    Serialized per-step single pairs model the paper's in-cast exposure.
    relay='original' — each rank wires its original contribution.
    """
    n = comm.size
    steps = tuple(
        Step(perm=(((root + i + 1) % n, root),), op=op,
             send_sel=Sel.all(), recv_sel=Sel.all(), bytes_frac=1.0,
             mask_recv=True)
        for i in range(n - 1)
    )
    return Schedule(
        name="all_to_one", collective="reduce", nranks=n, steps=steps,
        chunks=1, result="root", relay="original",
    )


def binomial_tree_reduce(comm: Communicator, root: int = 0, op: str = "add") -> Schedule:
    """Rendezvous large-msg reduce: binomial tree, leaves toward root."""
    n = comm.size
    rounds = comm.tree_rounds(root)
    steps = tuple(
        Step(perm=tuple((dst, src) for (src, dst) in pairs), op=op,
             send_sel=Sel.all(), recv_sel=Sel.all(), bytes_frac=1.0,
             mask_recv=True)
        for pairs in reversed(rounds)
    )
    return Schedule(
        name="binomial_tree", collective="reduce", nranks=n, steps=steps,
        chunks=1, result="root",
    )


def all_to_one_gather(comm: Communicator, root: int = 0) -> Schedule:
    """Each rank sends its chunk straight to the root (n-1 single pairs)."""
    n = comm.size
    steps = tuple(
        Step(perm=(((root + i + 1) % n, root),), op="copy",
             send_sel=Sel.chunk(lambda r, s: r),
             recv_sel=Sel.chunk(lambda r, s, i=i: (root + i + 1) % n),
             bytes_frac=1.0 / n, mask_recv=True)
        for i in range(n - 1)
    )
    return Schedule(
        name="all_to_one", collective="gather", nranks=n, steps=steps,
        chunks=n, result="root", relay="original",
    )


def binomial_tree_gather(comm: Communicator, root: int = 0) -> Schedule:
    """Binomial gather: owned ranges double as they climb toward the root.

    Chunk j (relative coordinates) holds rank (root+j)%n's data.
    """
    n = comm.size
    k = _log2(n)
    steps = []
    for j in range(k):
        d = 1 << j
        pairs = tuple(
            ((root + m * 2 * d + d) % n, (root + m * 2 * d) % n)
            for m in range(n // (2 * d))
        )

        def rng(r, s, d=d, root=root, n=n):
            # Sender rel has bit d set (rel | d == rel); receiver rel has it
            # clear (rel | d == rel + d). One branch-free formula covers both
            # so it traces cleanly on jax rank values.
            rel = (r - root) % n
            return (rel | d, d)

        steps.append(Step(
            perm=pairs, op="copy",
            send_sel=Sel.range(rng), recv_sel=Sel.range(rng),
            bytes_frac=d / n, mask_recv=True,
        ))
    return Schedule(
        name="binomial_tree", collective="gather", nranks=n,
        steps=tuple(steps), chunks=n, result="root", relay="buffer",
        chunk_coords="relative",
    )


# --------------------------------------------------------------------------
# All-to-all family
# --------------------------------------------------------------------------

def linear_alltoall(comm: Communicator) -> Schedule:
    """Paper's all-to-all: n-1 rotations, step s routes chunk (r+s)%n.

    Buffer convention: chunk j outbound = data for rank j; after the
    schedule chunk j holds data *from* rank j.

    Every step uses a different ring shift, so these steps can never
    coalesce into a LOOP micro-op. The compiler's stacked-receive
    peephole (`program.fuse_stacked_recv`) instead collapses the run
    into one STACKED_RECV: all n-1 permutes issue from the immutable
    original buffer and the arrivals land with a single chunk scatter,
    not n-1 full-buffer update-slices.
    """
    n = comm.size
    steps = tuple(
        Step(perm=tuple(comm.ring_perm(s)), op="copy",
             send_sel=Sel.chunk(lambda r, st, s=s: (r + s) % n),
             recv_sel=Sel.chunk(lambda r, st, s=s: (r - s) % n),
             bytes_frac=1.0 / n)
        for s in range(1, n)
    )
    return Schedule(
        name="linear", collective="alltoall", nranks=n, steps=steps,
        chunks=n, result="full", relay="original",
    )


def bruck_alltoall(comm: Communicator) -> Schedule:
    """[+] Bruck: log2(n) phases, each moving the chunks whose destination
    offset has bit k set, to rank r + 2^k. Needs pre-rotation (chunk j ->
    data for rank (r+j)%n) and post-rotation; the engine performs those as
    local rolls. Mask selectors are rank-independent (pure data).
    """
    n = comm.size
    k = _log2(n)
    steps = []
    for ph in range(k):
        d = 1 << ph
        mask = tuple(j for j in range(n) if j & d)

        def msel(r, s, mask=mask):
            return mask

        sel = Sel.mask(msel)
        steps.append(Step(
            perm=tuple(comm.ring_perm(d)), op="copy",
            # identical send/recv masks: the gathered payload segments on
            # the wire and scatters back (segmentable=True annotation)
            send_sel=sel, recv_sel=sel,
            bytes_frac=len(mask) / n, segmentable=True,
        ))
    return Schedule(
        name="bruck", collective="alltoall", nranks=n, steps=tuple(steps),
        chunks=n, result="full", pre_rotate="bruck", post_rotate="bruck",
    )


# --------------------------------------------------------------------------
# Registry (what the selector chooses from)
# --------------------------------------------------------------------------

GENERATORS = {
    ("allreduce", "ring"): ring_allreduce,
    ("allreduce", "bidi_ring"): bidi_ring_allreduce,
    ("allreduce", "recursive_doubling"): recursive_doubling_allreduce,
    ("allreduce", "halving_doubling"): halving_doubling_allreduce,
    ("reduce_scatter", "ring"): ring_reduce_scatter,
    ("reduce_scatter", "recursive_halving"): recursive_halving_reduce_scatter,
    ("allgather", "ring"): ring_allgather,
    ("allgather", "recursive_doubling"): recursive_doubling_allgather,
    ("bcast", "one_to_all"): one_to_all_bcast,
    ("bcast", "binomial_tree"): binomial_tree_bcast,
    ("reduce", "ring"): ring_reduce,
    ("reduce", "all_to_one"): all_to_one_reduce,
    ("reduce", "binomial_tree"): binomial_tree_reduce,
    ("gather", "ring"): ring_gather,
    ("gather", "all_to_one"): all_to_one_gather,
    ("gather", "binomial_tree"): binomial_tree_gather,
    ("alltoall", "linear"): linear_alltoall,
    ("alltoall", "bruck"): bruck_alltoall,
}
