"""Static verifier for compiled collective programs.

Every `Schedule.compile()` product is a linear micro-op `Program` that
three consumers trust blindly: the engine traces it, the simulator
executes it, the selector prices it. ACCL+'s extensibility story — new
collectives deploy through `plugins.register_collective` without
re-synthesizing anything — only holds if a malformed program is caught
*before* it deadlocks the fabric or silently corrupts a buffer ("up to
48 FPGAs" reports exactly that failure mode from mismatched send/recv
pairs). This module proves well-formedness statically, on the compiled
artifact, with typed rank/op-addressed diagnostics.

Passes (rule-id prefix → pass):

  ST_*  structural     every exchange is a well-shaped
                       load/[compress]/send/[decompress]/recv-combine
                       body; perms stay in-range and collision-free.
  XM_*  exchange       cross-rank matching: every SEND has its receive,
                       byte counts agree under segmentation and codec
                       (scale-block-consistent int8 wires).
  DL_*  deadlock       no rank waits on itself inside one bulk-
                       synchronous exchange; the Sequencer's request
                       DAG (deps + buffer-hazard edges, including
                       cross-axis `issue_multi` chains) is acyclic.
  LV_*  level          hierarchical consistency: `level` tags resolve
                       under `level_sizes`, level perms stay inside
                       their level's rank space and expand to exactly
                       the flat perm the simulator executes.
  DF_*  dataflow       per-rank symbolic buffer walk: no read-before-
                       write, no combine into an unwritten segment,
                       chunk-grid coverage per the collective's
                       postcondition, and STREAM/STREAM_CHAIN fusions
                       re-prove their reorder-safety regions.

Selector closures are pure (rank, step) arithmetic for every built-in;
the verifier evaluates them concretely, and — matching the fusion
passes' precedent — opts out of region-dependent checks (never errors)
when a user closure raises on plain ints. Structural, matching,
deadlock and level rules need no selector evaluation and are cheap
enough to run on every compile; the dataflow walk runs under
`verify="full"` (the registration probe, CI's verify lane, and
`REPRO_VERIFY=full`).

Verification levels: "off", "structural" (default on compile), "full".
"""
from __future__ import annotations

import math
from typing import Iterator, Optional

from repro.core.program import (
    Copy, Compress, Decompress, Loop, Program, RecvCombine, SegLoop,
    Send, StackedRecv, Stream, StreamChain,
    SRC_ORIGINAL, SRC_RECEIVED,
    _chain_body_eligible, _regions_stream_safe, _stream_eligible,
    split_exchange,
)
from repro.core.schedule import (
    COMBINE_OPS, SEL_ALL, SEL_CHUNK, SEL_MASK, SEL_RANGE, Sel,
)

VERIFY_LEVELS = ("off", "structural", "full")

# rule id -> (pass name, property proved). The README's rule table and
# the mutation tests in tests/test_verify.py are generated against this.
RULES = {
    "ST_BODY_SHAPE": (
        "structural",
        "exchange bodies are load/[compress]/send/[decompress]/"
        "recv-combine with a known combine op and paired codec stages"),
    "ST_PERM_RANGE": (
        "structural", "perm endpoints lie in [0, nranks)"),
    "ST_PERM_DUP": (
        "structural", "no rank appears twice as src or dst in one permute"),
    "ST_SEL_BOUNDS": (
        "dataflow", "selector results lie in [0, chunks) and are non-empty"),
    "XM_UNMATCHED_RECV": (
        "exchange",
        "unmasked exchanges deliver to every rank (no rank blocks on a "
        "receive that never arrives)"),
    "XM_DSTS_MISMATCH": (
        "exchange", "RECV_COMBINE.dsts equals the perm's destination set"),
    "XM_BYTES_MISMATCH": (
        "exchange", "send and receive regions agree in length per pair"),
    "XM_BYTES_FRAC": (
        "exchange", "Send.bytes_frac equals payload chunks / chunk grid"),
    "XM_SCALE_BLOCK": (
        "exchange",
        "compress/decompress codecs match across the wire and the "
        "program's declared codec (scale blocks land aligned)"),
    "DL_SELF_SEND": (
        "deadlock", "no rank sends to itself inside one exchange"),
    "DL_DEP_CYCLE": (
        "deadlock", "the Sequencer request DAG is acyclic"),
    "LV_ORPHAN_LEVEL": (
        "level",
        "level tags resolve under level_sizes (and flat programs carry "
        "no level perms)"),
    "LV_PERM_MISMATCH": (
        "level",
        "level perms stay inside their level's rank space and expand to "
        "exactly the flat perm"),
    "DF_READ_BEFORE_WRITE": (
        "dataflow", "payloads only read chunks already valid at the rank"),
    "DF_COMBINE_UNWRITTEN": (
        "dataflow", "non-copy combines only target valid chunks"),
    "DF_DOUBLE_WRITE": (
        "dataflow",
        "no two writes of one bulk-synchronous group collide; gather-"
        "family programs deliver each chunk exactly once"),
    "DF_COVERAGE": (
        "dataflow",
        "the chunk grid is covered per the collective's postcondition"),
    "DF_STREAM_UNSAFE": (
        "dataflow",
        "STREAM/STREAM_CHAIN fusions satisfy the region-overlap proof"),
}


class VerifyError(ValueError):
    """A verification failure, addressed to the offending op/rank/step."""

    def __init__(self, rule: str, message: str, *,
                 op_index: Optional[int] = None,
                 rank: Optional[int] = None,
                 step: Optional[int] = None):
        self.rule = rule
        self.op_index = op_index
        self.rank = rank
        self.step = step
        where = "".join(
            f" {k}={v}" for k, v in
            (("op", op_index), ("rank", rank), ("step", step))
            if v is not None)
        super().__init__(f"[{rule}]{where and ' at' + where}: {message}")


def _err(rule: str, message: str, **where) -> None:
    raise VerifyError(rule, message, **where)


# --------------------------------------------------------------------------
# IR walkers
# --------------------------------------------------------------------------

def _body_step(body: tuple) -> Optional[int]:
    head = body[0] if body else None
    return getattr(head, "step", None)


def _instance_groups(prog: Program) -> Iterator[tuple]:
    """Unrolled execution walk.

    Yields ("rot", op_index, kind) for Bruck rotations and
    ("group", op_index, [(step, body, k), ...]) for every bulk-
    synchronous write group — the unit whose reads all see the group-
    start buffer and whose writes land together (LOOP/STREAM iteration
    semantics, STACKED_RECV's one scatter). STREAM_CHAIN waves and
    plain unrolled exchanges are singleton groups in program order.
    """
    ops = prog.ops
    i, n_ops = 0, len(ops)
    while i < n_ops:
        op = ops[i]
        if isinstance(op, Copy) and op.kind in ("bruck_pre", "bruck_post"):
            yield ("rot", i, op.kind)
        elif isinstance(op, Loop):
            bodies = [split_exchange(slot) for slot in op.slots]
            for it in range(op.trip):
                yield ("group", i, [
                    (op.base + it * op.period + j, body, k)
                    for j, (body, k) in enumerate(bodies)])
        elif isinstance(op, Stream):
            for it in range(op.trip):
                yield ("group", i, [
                    (op.base + it * op.period + j, body, op.segments)
                    for j, body in enumerate(op.slots)])
        elif isinstance(op, StreamChain):
            for body in op.bodies:
                yield ("group", i, [(_body_step(body), body, op.segments)])
        elif isinstance(op, StackedRecv):
            yield ("group", i,
                   [(_body_step(b), b, 1) for b in op.bodies])
        elif isinstance(op, SegLoop):
            yield ("group", i,
                   [(_body_step(op.body), op.body, op.segments)])
        elif isinstance(op, Copy) and op.kind == "load":
            j = i
            while j < n_ops and not isinstance(ops[j], RecvCombine):
                j += 1
            if j >= n_ops:
                _err("ST_BODY_SHAPE",
                     "exchange run is not terminated by a RECV_COMBINE",
                     op_index=i)
            body = tuple(ops[i:j + 1])
            yield ("group", i, [(_body_step(body), body, 1)])
            i = j + 1
            continue
        else:
            _err("ST_BODY_SHAPE",
                 f"unexpected top-level micro-op {type(op).__name__}",
                 op_index=i)
        i += 1


def _unique_bodies(prog: Program) -> Iterator[tuple]:
    """(op_index, step, body, k) once per distinct exchange body — the
    walk for checks that need no per-iteration state (LOOP slots share
    one body tuple across all trips)."""
    seen: set = set()
    for kind, oi, payload in _instance_groups(prog):
        if kind != "group":
            continue
        for step, body, k in payload:
            if id(body) in seen:
                continue
            seen.add(id(body))
            yield oi, step, body, k


def _find(body: tuple, cls) -> Optional[object]:
    for op in body:
        if isinstance(op, cls):
            return op
    return None


def _parse_body(body: tuple, op_index: int) -> tuple:
    """Strict shape check; returns (load, send, recv, codec)."""
    if (not body or not isinstance(body[0], Copy)
            or body[0].kind != "load"
            or not isinstance(body[-1], RecvCombine)):
        _err("ST_BODY_SHAPE",
             "exchange body must start with COPY(load) and end with "
             "RECV_COMBINE", op_index=op_index)
    load, recv = body[0], body[-1]
    send = comp = decomp = None
    for op in body[1:-1]:
        if isinstance(op, Send):
            if send is not None:
                _err("ST_BODY_SHAPE", "two SENDs in one exchange body",
                     op_index=op_index)
            send = op
        elif isinstance(op, Compress):
            if comp is not None or send is not None:
                _err("ST_BODY_SHAPE",
                     "COMPRESS must appear exactly once, before SEND",
                     op_index=op_index)
            comp = op
        elif isinstance(op, Decompress):
            if decomp is not None or send is None:
                _err("ST_BODY_SHAPE",
                     "DECOMPRESS must appear exactly once, after SEND",
                     op_index=op_index)
            decomp = op
        else:
            _err("ST_BODY_SHAPE",
                 f"illegal op {type(op).__name__} inside exchange body",
                 op_index=op_index)
    if send is None:
        _err("ST_BODY_SHAPE", "exchange body has no SEND", op_index=op_index)
    if (comp is None) != (decomp is None):
        _err("ST_BODY_SHAPE",
             "COMPRESS without DECOMPRESS (or vice versa)",
             op_index=op_index)
    if recv.op not in COMBINE_OPS:
        _err("ST_BODY_SHAPE", f"unknown combine op {recv.op!r}",
             op_index=op_index)
    if load.sel is None:
        _err("ST_BODY_SHAPE", "COPY(load) carries no selector",
             op_index=op_index)
    return load, send, recv, (comp.codec if comp is not None else None)


# --------------------------------------------------------------------------
# Selector evaluation (concrete regions, with the fusion passes' opt-out)
# --------------------------------------------------------------------------

def _region(sel: Sel, rank: int, step: Optional[int], chunks: int,
            op_index: int) -> Optional[frozenset]:
    """Chunk set a selector touches at a concrete (rank, step); None when
    the closure is not pure (rank, step) arithmetic (region checks opt
    out, matching `program._sel_region`'s callers)."""
    if sel.kind == SEL_ALL:
        return frozenset(range(chunks))
    try:
        if sel.kind == SEL_CHUNK:
            reg = (int(sel.fn(rank, step)),)
        elif sel.kind == SEL_RANGE:
            off, length = sel.fn(rank, step)
            reg = tuple(range(int(off), int(off) + int(length)))
        elif sel.kind == SEL_MASK:
            reg = tuple(int(j) for j in sel.fn(rank, step))
        else:
            _err("ST_BODY_SHAPE", f"unknown selector kind {sel.kind!r}",
                 op_index=op_index)
    except VerifyError:
        raise
    except Exception:
        return None
    if not reg:
        _err("ST_SEL_BOUNDS", "selector produced an empty region",
             op_index=op_index, rank=rank, step=step)
    for c in reg:
        if not 0 <= c < chunks:
            _err("ST_SEL_BOUNDS",
                 f"selector chunk {c} outside grid [0, {chunks})",
                 op_index=op_index, rank=rank, step=step)
    return frozenset(reg)


# --------------------------------------------------------------------------
# Pass 0 — structural
# --------------------------------------------------------------------------

def structural_pass(prog: Program) -> None:
    n = prog.nranks
    for oi, step, body, k in _unique_bodies(prog):
        _, send, _, _ = _parse_body(body, oi)
        if k < 1:
            _err("ST_BODY_SHAPE", f"segment count {k} < 1", op_index=oi)
        seen_src: set = set()
        seen_dst: set = set()
        for s, d in send.perm:
            if not (0 <= s < n and 0 <= d < n):
                _err("ST_PERM_RANGE",
                     f"perm pair ({s}, {d}) outside [0, {n})",
                     op_index=oi, step=step)
            if s in seen_src:
                _err("ST_PERM_DUP", f"rank {s} sends twice in one permute",
                     op_index=oi, rank=s, step=step)
            if d in seen_dst:
                _err("ST_PERM_DUP",
                     f"rank {d} receives twice in one permute",
                     op_index=oi, rank=d, step=step)
            seen_src.add(s)
            seen_dst.add(d)


# --------------------------------------------------------------------------
# Pass 1 — cross-rank exchange matching
# --------------------------------------------------------------------------

def _codec_block(name: str) -> Optional[int]:
    """Scale-block size of a registered codec; None when the codec
    registry is unavailable (jax-free contexts keep this module usable)."""
    try:
        from repro.core import plugins
    except Exception:
        return None
    spec = plugins.CODECS.get(name)
    if spec is None:
        _err("XM_SCALE_BLOCK", f"unknown codec {name!r}")
    return spec.block_elems


def exchange_pass(prog: Program, full: bool = True) -> None:
    """Every SEND has its matching receive; byte counts agree.

    The matching half (unmatched receives, dsts drift, codec pairing)
    is selector-free and runs at every compile; the byte-count half
    (`full=True`) evaluates regions concretely.
    """
    n, chunks = prog.nranks, prog.chunks
    for oi, step, body, k in _unique_bodies(prog):
        send = _find(body, Send)
        recv = _find(body, RecvCombine)
        if send is None or recv is None:
            continue  # structural_pass owns the shape diagnostics
        dsts = {d for _s, d in send.perm}
        if recv.dsts is None:
            missing = sorted(set(range(n)) - dsts)
            if missing:
                _err("XM_UNMATCHED_RECV",
                     f"ranks {missing} receive nothing but the exchange "
                     f"is unmasked (mask_recv=False) — every peer would "
                     f"block on an arrival that never comes",
                     op_index=oi, rank=missing[0], step=step)
        elif set(recv.dsts) != dsts:
            _err("XM_DSTS_MISMATCH",
                 f"RECV_COMBINE.dsts {sorted(recv.dsts)} != perm "
                 f"destinations {sorted(dsts)}", op_index=oi, step=step)
        comp = _find(body, Compress)
        decomp = _find(body, Decompress)
        names = {o.codec for o in (comp, decomp) if o is not None}
        if names:
            if len(names) > 1:
                _err("XM_SCALE_BLOCK",
                     f"compress codec differs across the wire: {sorted(names)}",
                     op_index=oi, step=step)
            name = names.pop()
            if prog.codec is not None and name != prog.codec:
                _err("XM_SCALE_BLOCK",
                     f"exchange codec {name!r} != program codec "
                     f"{prog.codec!r}", op_index=oi, step=step)
            _codec_block(name)
    if not full:
        return
    for kind, oi, payload in _instance_groups(prog):
        if kind != "group":
            continue
        for step, body, k in payload:
            load = _find(body, Copy)
            send = _find(body, Send)
            recv = _find(body, RecvCombine)
            if load is None or load.sel is None or send is None \
                    or recv is None:
                continue
            for s, d in send.perm:
                s_reg = _region(load.sel, s, step, chunks, oi)
                r_reg = _region(recv.sel, d, step, chunks, oi)
                if s_reg is None or r_reg is None:
                    continue
                if len(s_reg) != len(r_reg):
                    _err("XM_BYTES_MISMATCH",
                         f"rank {s} sends {len(s_reg)} chunk(s) but rank "
                         f"{d} receives {len(r_reg)}",
                         op_index=oi, rank=d, step=step)
                if not math.isclose(send.bytes_frac, len(s_reg) / chunks,
                                    rel_tol=1e-9, abs_tol=1e-12):
                    _err("XM_BYTES_FRAC",
                         f"Send.bytes_frac={send.bytes_frac!r} but the "
                         f"payload is {len(s_reg)}/{chunks} of the buffer "
                         f"— the cost walk would price a different wire "
                         f"volume than the executor moves",
                         op_index=oi, rank=s, step=step)


# --------------------------------------------------------------------------
# Pass 2 — deadlock freedom
# --------------------------------------------------------------------------

def deadlock_pass(prog: Program) -> None:
    """Within one bulk-synchronous exchange all sends progress together
    (ring cycles in one ppermute are fine); the only intra-exchange
    wait-for cycle a program can express is a rank waiting on itself."""
    for oi, step, body, k in _unique_bodies(prog):
        send = _find(body, Send)
        if send is None:
            continue
        for s, d in send.perm:
            if s == d:
                _err("DL_SELF_SEND",
                     f"rank {s} sends to itself — it would wait on its "
                     f"own uncombined receive", op_index=oi, rank=s,
                     step=step)


def check_request_dag(requests) -> None:
    """DL_DEP_CYCLE over Sequencer requests: edges are `Request.deps`
    plus operand-request chaining (the buffer WAR/WAW/RAW hazards the
    queue materializes as deps at issue time, including cross-axis
    `issue_multi` chains). Completed upstream requests no longer block,
    so only edges inside `requests` participate."""
    by_id = {id(r): r for r in requests}

    def _edges(req):
        for dep in (getattr(req, "deps", None) or ()):
            if id(dep) in by_id:
                yield dep
        operand = getattr(req, "operand", None)
        if operand is not None and id(operand) in by_id:
            yield operand

    WHITE, GREY, BLACK = 0, 1, 2
    color = {rid: WHITE for rid in by_id}
    for start in requests:
        if color[id(start)] != WHITE:
            continue
        stack = [(start, iter(list(_edges(start))))]
        color[id(start)] = GREY
        path = [start]
        while stack:
            node, it = stack[-1]
            nxt = next(it, None)
            if nxt is None:
                color[id(node)] = BLACK
                stack.pop()
                path.pop()
                continue
            c = color[id(nxt)]
            if c == GREY:
                cyc = [getattr(r, "rid", None) for r in path] + \
                    [getattr(nxt, "rid", None)]
                _err("DL_DEP_CYCLE",
                     f"request dependency cycle {cyc} — the queue would "
                     f"never drain")
            if c == WHITE:
                color[id(nxt)] = GREY
                path.append(nxt)
                stack.append((nxt, iter(list(_edges(nxt)))))


# --------------------------------------------------------------------------
# Pass 3 — level / fabric consistency
# --------------------------------------------------------------------------

def level_pass(prog: Program) -> None:
    sizes = dict(prog.level_sizes) if prog.level_sizes else None
    if sizes is not None:
        bad = sorted(set(sizes) - {"intra", "inter"})
        if bad:
            _err("LV_ORPHAN_LEVEL", f"unknown level name(s) {bad} in "
                 f"level_sizes {prog.level_sizes}")
        P, M = sizes.get("inter"), sizes.get("intra")
        if P is None or M is None or P * M != prog.nranks:
            _err("LV_ORPHAN_LEVEL",
                 f"level_sizes {prog.level_sizes} do not factor "
                 f"nranks={prog.nranks} as inter x intra")
    for oi, step, body, k in _unique_bodies(prog):
        send = _find(body, Send)
        if send is None:
            continue
        if send.level is None:
            if send.level_perm is not None:
                _err("LV_ORPHAN_LEVEL",
                     "level_perm without a level tag", op_index=oi,
                     step=step)
            continue
        if sizes is None or send.level not in sizes:
            _err("LV_ORPHAN_LEVEL",
                 f"level {send.level!r} does not resolve under "
                 f"level_sizes={prog.level_sizes}", op_index=oi, step=step)
        if send.level_perm is None:
            _err("LV_ORPHAN_LEVEL",
                 f"level {send.level!r} exchange carries no level_perm "
                 f"(the engine cannot ppermute it on the level's mesh "
                 f"axis)", op_index=oi, step=step)
        size = sizes[send.level]
        for s, d in send.level_perm:
            if not (0 <= s < size and 0 <= d < size):
                _err("LV_PERM_MISMATCH",
                     f"level perm pair ({s}, {d}) outside the "
                     f"{send.level} rank space [0, {size})",
                     op_index=oi, step=step)
        from repro.core.hierarchical import (
            _expand_inter_perm, _expand_intra_perm)
        P, M = sizes["inter"], sizes["intra"]
        expanded = (_expand_intra_perm(send.level_perm, P)
                    if send.level == "intra"
                    else _expand_inter_perm(send.level_perm, P, M))
        if tuple(send.perm) != tuple(expanded):
            _err("LV_PERM_MISMATCH",
                 f"flat perm is not the {send.level} expansion of "
                 f"level_perm {send.level_perm} (simulator and engine "
                 f"would route different pairs)", op_index=oi, step=step)


# --------------------------------------------------------------------------
# Pass 4 — per-rank dataflow
# --------------------------------------------------------------------------

def _infer_root(prog: Program, schedule) -> int:
    """Best-effort root: bcast roots send first, 'root'-result
    collectives receive last. Falls back to 0 (every built-in default)."""
    groups = [p for kind, _oi, p in _instance_groups(prog)
              if kind == "group"]
    if not groups:
        return 0
    if prog.collective == "bcast":
        srcs = {s for _step, body, _k in groups[0]
                for (s, _d) in (_find(body, Send) or Send(())).perm}
        return min(srcs) if srcs else 0
    result = getattr(schedule, "result", None)
    if result == "root":
        dsts = {d for _step, body, _k in groups[-1]
                for (_s, d) in (_find(body, Send) or Send(())).perm}
        if len(dsts) == 1:
            return dsts.pop()
    return 0


def _initial_valid(prog: Program, schedule, root: int) -> list:
    """Chunk sets valid before op 0, per `simulator.run_collective`'s
    input conventions: gather-family programs on an n-chunk grid start
    with only the own shard in its slot; everything else starts from a
    full (or don't-care-but-initialized) buffer."""
    n, chunks = prog.nranks, prog.chunks
    full = frozenset(range(chunks))
    if prog.collective in ("allgather", "gather") and chunks == n:
        coords = getattr(schedule, "chunk_coords", "absolute")
        if prog.collective == "gather" and coords == "relative":
            return [{(r - root) % n} for r in range(n)]
        return [{r} for r in range(n)]
    return [set(full) for _ in range(n)]


def _rotate(sets: list, chunks: int, kind: str) -> list:
    """Permute per-rank chunk sets through a Bruck rotation (matching
    `simulator._bruck_pre/_bruck_post`): pre puts old chunk (j + r) % n
    at j; post puts old chunk (r - j) % n at j."""
    out = []
    for r, s in enumerate(sets):
        if kind == "bruck_pre":
            out.append({j for j in range(chunks) if (j + r) % chunks in s})
        else:
            out.append({j for j in range(chunks) if (r - j) % chunks in s})
    return out


def dataflow_pass(prog: Program, schedule=None) -> None:
    """Symbolic per-rank buffer walk over the unrolled program.

    Tracks, per rank, the set of *valid* chunks (initialized data) and —
    for bcast — the set of *fresh* chunks (derived from the root's
    payload), because `hier_bcast` legitimately overwrites stale scatter
    output with bitwise-identical fresh data: write-once is the wrong
    invariant there, root-freshness of every chunk is the right one.
    Gather-family copy collectives additionally prove exactly-once
    delivery (DF_DOUBLE_WRITE); within any bulk-synchronous group all
    writes must be disjoint on every executor.
    """
    n, chunks, coll = prog.nranks, prog.chunks, prog.collective
    root = _infer_root(prog, schedule)
    init = _initial_valid(prog, schedule, root)
    written: list = [set() for _ in range(n)]
    fresh: Optional[list] = None
    if coll == "bcast" and prog.relay != "received":
        fresh = [set(range(chunks)) if r == root else set()
                 for r in range(n)]
    deliver_once = coll in ("allgather", "gather")

    for kind, oi, payload in _instance_groups(prog):
        if kind == "rot":
            written = _rotate(written, chunks, payload)
            init = _rotate(init, chunks, payload)
            if fresh is not None:
                fresh = _rotate(fresh, chunks, payload)
            continue
        snap_valid = [init[r] | written[r] for r in range(n)]
        snap_fresh = [set(f) for f in fresh] if fresh is not None else None
        group_written: list = [set() for _ in range(n)]
        pending: list = []
        for step, body, k in payload:
            load = _find(body, Copy)
            send = _find(body, Send)
            recv = _find(body, RecvCombine)
            if load is None or load.sel is None or send is None \
                    or recv is None:
                continue
            for s, d in send.perm:
                s_reg = _region(load.sel, s, step, chunks, oi)
                r_reg = _region(recv.sel, d, step, chunks, oi)
                if s_reg is None and fresh is not None \
                        and load.source != SRC_ORIGINAL:
                    # Can't trace freshness through an opaque selector;
                    # drop the bcast taint analysis rather than report a
                    # false stale chunk.
                    fresh = None
                    snap_fresh = None
                if s_reg is not None:
                    if load.source == SRC_ORIGINAL:
                        pass  # the original operand is immutably valid
                    elif load.source == SRC_RECEIVED:
                        pass  # relay register is seeded with the input
                    elif not s_reg <= snap_valid[s]:
                        _err("DF_READ_BEFORE_WRITE",
                             f"rank {s} wires chunk(s) "
                             f"{sorted(s_reg - snap_valid[s])} it never "
                             f"received nor owned", op_index=oi, rank=s,
                             step=step)
                if r_reg is None:
                    continue
                if recv.op != "copy" and not r_reg <= snap_valid[d]:
                    _err("DF_COMBINE_UNWRITTEN",
                         f"rank {d} combines ({recv.op}) into "
                         f"uninitialized chunk(s) "
                         f"{sorted(r_reg - snap_valid[d])}",
                         op_index=oi, rank=d, step=step)
                if group_written[d] & r_reg:
                    _err("DF_DOUBLE_WRITE",
                         f"rank {d} receives chunk(s) "
                         f"{sorted(group_written[d] & r_reg)} twice "
                         f"inside one bulk-synchronous group (write "
                         f"order would be executor-dependent)",
                         op_index=oi, rank=d, step=step)
                group_written[d] |= r_reg
                if deliver_once and recv.op == "copy" \
                        and r_reg & (written[d] | init[d]):
                    _err("DF_DOUBLE_WRITE",
                         f"rank {d} is re-delivered chunk(s) "
                         f"{sorted(r_reg & (written[d] | init[d]))} it "
                         f"already holds", op_index=oi, rank=d, step=step)
                pay_fresh = False
                if snap_fresh is not None and s_reg is not None:
                    if load.source == SRC_ORIGINAL:
                        pay_fresh = s == root
                    else:
                        pay_fresh = s_reg <= snap_fresh[s]
                    if recv.op != "copy":
                        pay_fresh = pay_fresh and r_reg <= snap_fresh[d]
                pending.append((d, r_reg, pay_fresh))
        for d, r_reg, pay_fresh in pending:
            written[d] |= r_reg
            if fresh is not None:
                if pay_fresh:
                    fresh[d] |= r_reg
                else:
                    fresh[d] -= r_reg

    _coverage_check(prog, schedule, root, init, written, fresh)


def _coverage_check(prog: Program, schedule, root: int, init: list,
                    written: list, fresh: Optional[list]) -> None:
    n, chunks, coll = prog.nranks, prog.chunks, prog.collective
    full = set(range(chunks))
    have = [init[r] | written[r] for r in range(n)]
    if coll == "bcast":
        if fresh is None:
            return
        for r in range(n):
            if fresh[r] != full:
                _err("DF_COVERAGE",
                     f"rank {r} ends with chunk(s) {sorted(full - fresh[r])} "
                     f"not derived from the root's buffer", rank=r)
        return
    result = getattr(schedule, "result", None)
    if result is None and coll in ("allreduce", "allgather", "alltoall"):
        result = "full"
    if result == "full":
        for r in range(n):
            if have[r] != full:
                _err("DF_COVERAGE",
                     f"rank {r} never receives chunk(s) "
                     f"{sorted(full - have[r])}", rank=r)
    elif result == "shard":
        owned = getattr(schedule, "owned_chunk", None)
        if owned is None:
            return
        for r in range(n):
            try:
                oc = int(owned(r))
            except Exception:
                return
            if oc not in have[r]:
                _err("DF_COVERAGE",
                     f"rank {r} never receives its own shard chunk {oc}",
                     rank=r)
    elif result == "root":
        if have[root] != full:
            _err("DF_COVERAGE",
                 f"root {root} never receives chunk(s) "
                 f"{sorted(full - have[root])}", rank=root)


def stream_pass(prog: Program) -> None:
    """Re-prove the reorder-safety region of every STREAM/STREAM_CHAIN:
    a fused op whose regions fail `program._regions_stream_safe` would
    execute in a wave order that is not value-identical to the per-step
    order the simulator defines."""
    for oi, op in enumerate(prog.ops):
        if isinstance(op, Stream):
            loop = Loop(base=op.base, trip=op.trip, period=op.period,
                        slots=tuple((SegLoop(op.segments, b),)
                                    for b in op.slots))
            if not _stream_eligible(loop, op.segments, prog.nranks):
                _err("DF_STREAM_UNSAFE",
                     "STREAM fusion fails the cross-step region-overlap "
                     "proof (wave order would not be value-identical to "
                     "per-step order)", op_index=oi)
        elif isinstance(op, StreamChain):
            wrapped = [SegLoop(op.segments, b) for b in op.bodies]
            for w in wrapped:
                if not _chain_body_eligible(w, op.segments):
                    _err("DF_STREAM_UNSAFE",
                         "STREAM_CHAIN body is not chain-eligible at its "
                         "segment count", op_index=oi,
                         step=_body_step(w.body))
            seq = []
            for w in wrapped:
                load = _find(w.body, Copy)
                recv = _find(w.body, RecvCombine)
                seq.append((load.sel, recv.sel, load.source, load.step))
            for a, b in zip(seq, seq[1:]):
                if not _regions_stream_safe([a, b], op.segments,
                                            prog.nranks):
                    _err("DF_STREAM_UNSAFE",
                         "adjacent STREAM_CHAIN waves fail the region-"
                         "overlap proof", op_index=oi, step=b[3])


# --------------------------------------------------------------------------
# Entry point
# --------------------------------------------------------------------------

def verify_program(prog: Program, schedule=None,
                   level: str = "full") -> Program:
    """Run the static passes at `level` ("off" | "structural" | "full");
    raises `VerifyError` on the first violation, returns `prog`."""
    if level not in VERIFY_LEVELS:
        raise ValueError(
            f"verify level must be one of {VERIFY_LEVELS}, got {level!r}")
    if level == "off":
        return prog
    structural_pass(prog)
    exchange_pass(prog, full=(level == "full"))
    deadlock_pass(prog)
    level_pass(prog)
    if level == "full":
        dataflow_pass(prog, schedule)
        stream_pass(prog)
    return prog
