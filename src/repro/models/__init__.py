from repro.models import attention, blocks, common, lm, mlp, serve, ssm

__all__ = ["attention", "blocks", "common", "lm", "mlp", "serve", "ssm"]
