"""LM assembly: embeddings, vocab-parallel loss, train/prefill/decode.

Sharding summary (mesh pod x data x model):
  embedding/head (V, D): V over 'model' (vocab-parallel), D over 'data'
  activations: batch over ('pod','data'); optionally seq over 'model' (SP)
  caches (decode): KV-sequence over 'model' + engine flash-combine, or KV
  heads over 'model' when n_kv >= tp (whisper)

Loss-scaling contract (see parallel/ops.py): shard_map autodiff sums
per-rank losses; the head input is always full-sequence and model-axis
replicated, so local_loss = ce_local_sum / (total_tokens * tp_size).
MoE aux stats are token-sharded, scaled by 1 / n_ranks_total.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models.blocks import layer_params, stack_forward, stacked
from repro.models.common import Builder, rms_norm, sinusoidal_positions
from repro.parallel.ops import ParCtx


def padded_vocab(cfg: ArchConfig, tp: int) -> int:
    return ((cfg.vocab_size + tp - 1) // tp) * tp


# --------------------------------------------------------------------------
# Params
# --------------------------------------------------------------------------

def model_params(b: Builder, cfg: ArchConfig, tp: int):
    vp = padded_vocab(cfg, tp)
    d = cfg.d_model
    p = {
        "embed": b.param((vp, d), P("model", "data"), scale=0.02),
        "final_norm": b.param((d,), P(None), init="ones"),
        "layers": stacked(b, cfg.n_layers,
                          lambda bb: layer_params(
                              bb, cfg, tp, cross=bool(cfg.encoder_layers))),
    }
    if not cfg.tie_embeddings:
        p["head"] = b.param((vp, d), P("model", "data"), scale=0.02)
    if cfg.encoder_layers:
        p["enc_layers"] = stacked(
            b, cfg.encoder_layers,
            lambda bb: layer_params(bb, cfg, tp, family="dense"))
        p["enc_norm"] = b.param((d,), P(None), init="ones")
    return p


def batch_specs(cfg: ArchConfig, kind: str, dp=("pod", "data")):
    """PartitionSpecs for the input batch pytree. dp=None replicates the
    batch dim (global batch smaller than the DP group, e.g. B=1 decode)."""
    if kind == "train":
        spec = {"tokens": P(dp, None), "labels": P(dp, None)}
        if cfg.family == "vlm":
            spec["vis_embed"] = P(dp, None, None)
        if cfg.encoder_layers:
            spec["frames"] = P(dp, None, None)
        return spec
    if kind == "prefill":
        spec = {"tokens": P(dp, None)}
        if cfg.family == "vlm":
            spec["vis_embed"] = P(dp, None, None)
        if cfg.encoder_layers:
            spec["frames"] = P(dp, None, None)
        return spec
    raise ValueError(kind)


# --------------------------------------------------------------------------
# Embedding + head (vocab-parallel)
# --------------------------------------------------------------------------

def embed_tokens(params, tokens, cfg: ArchConfig, ctx: ParCtx):
    """tokens: (B, S) global ids -> (B, S, D). Vocab-parallel gather+psum."""
    vp = padded_vocab(cfg, ctx.tp)
    v_l = vp // ctx.tp
    emb = ctx.gather_fsdp(params["embed"], dim=1)     # (V_l, D)
    lo = ctx.tp_rank() * v_l
    local = tokens - lo
    hit = (local >= 0) & (local < v_l)
    rows = jnp.take(emb, jnp.clip(local, 0, v_l - 1), axis=0)
    rows = jnp.where(hit[..., None], rows, 0)
    if ctx.tp > 1:
        rows = ctx.engine.allreduce(rows, ctx.tp_axis)
    return rows


def lm_head_ce(params, x, labels, cfg: ArchConfig, ctx: ParCtx,
               mask=None):
    """Vocab-parallel cross-entropy. x: (B, S, D); labels: (B, S) int.

    Returns (ce_sum, token_count) — sums over local batch tokens (the
    model-replicated partial; caller applies the 1/(T_total*tp) scale).
    """
    vp = padded_vocab(cfg, ctx.tp)
    v_l = vp // ctx.tp
    w = params["embed"] if cfg.tie_embeddings else params["head"]
    w = ctx.gather_fsdp(w, dim=1)                     # (V_l, D)
    logits = jnp.einsum("bsd,vd->bsv", x.astype(jnp.float32),
                        w.astype(jnp.float32))        # (B, S, V_l)
    # mask padded vocab rows
    lo = ctx.tp_rank() * v_l
    vocab_ok = (lo + jnp.arange(v_l)) < cfg.vocab_size
    logits = jnp.where(vocab_ok[None, None], logits, -1e30)

    # logsumexp stabilizer: gradient-free by identity. The max-allreduce
    # pins the microcode path even on the native backend: lax.pmax has no
    # differentiation rule, and stop_gradient alone does not stop jax from
    # linearizing through it.
    m_local = jax.lax.stop_gradient(logits.max(-1))
    if ctx.tp > 1:
        m = ctx.engine.allreduce(m_local, ctx.tp_axis, op="max",
                                 algorithm="recursive_doubling"
                                 if ctx.tp & (ctx.tp - 1) == 0 else "ring")
    else:
        m = m_local
    m = jax.lax.stop_gradient(m)
    e = jnp.exp(logits - m[..., None])
    denom = e.sum(-1)
    if ctx.tp > 1:
        denom = ctx.engine.allreduce(denom, ctx.tp_axis)
    lse = jnp.log(denom) + m

    local_label = labels - lo
    hit = (local_label >= 0) & (local_label < v_l)
    picked = jnp.take_along_axis(
        logits, jnp.clip(local_label, 0, v_l - 1)[..., None], axis=-1)[..., 0]
    picked = jnp.where(hit, picked, 0.0)
    if ctx.tp > 1:
        picked = ctx.engine.allreduce(picked, ctx.tp_axis)

    ce = lse - picked                                  # (B, S)
    if mask is None:
        mask = (labels >= 0)
    ce = jnp.where(mask, ce, 0.0)
    return ce.sum(), mask.sum()


def lm_head_sample(params, x, cfg: ArchConfig, ctx: ParCtx):
    """Greedy next-token over the vocab-parallel head. x: (B, D)."""
    vp = padded_vocab(cfg, ctx.tp)
    v_l = vp // ctx.tp
    w = params["embed"] if cfg.tie_embeddings else params["head"]
    w = ctx.gather_fsdp(w, dim=1)
    logits = jnp.einsum("bd,vd->bv", x.astype(jnp.float32),
                        w.astype(jnp.float32))
    lo = ctx.tp_rank() * v_l
    vocab_ok = (lo + jnp.arange(v_l)) < cfg.vocab_size
    logits = jnp.where(vocab_ok[None], logits, -1e30)
    val = logits.max(-1)
    idx = lo + logits.argmax(-1).astype(jnp.int32)
    if ctx.tp > 1:
        best = ctx.engine.allreduce(val, ctx.tp_axis, op="max")
        cand = jnp.where(val >= best - 1e-6, idx, jnp.int32(2 ** 30))
        idx = -ctx.engine.allreduce(-cand, ctx.tp_axis, op="max")  # min
    return idx                                        # (B,)


# --------------------------------------------------------------------------
# Training forward + loss
# --------------------------------------------------------------------------

def _input_stream(params, batch, cfg: ArchConfig, ctx: ParCtx):
    """Token embeddings with family-specific prefixes; returns (x, enc_out)."""
    enc_out = None
    if cfg.encoder_layers:
        frames = batch["frames"]                      # (B, S_enc, D) stub
        s_enc = frames.shape[1]
        pe = sinusoidal_positions(s_enc, cfg.d_model).astype(frames.dtype)
        h = frames + pe[None]
        # the encoder stream is sequence-sharded under SP exactly like the
        # decoder stream (blocks re-gather at their boundaries)
        if ctx.pcfg.sequence_parallel and ctx.tp > 1 and s_enc % ctx.tp == 0:
            sl = s_enc // ctx.tp
            h = jax.lax.dynamic_slice_in_dim(h, ctx.tp_rank() * sl, sl, 1)
        h, _, _ = stack_forward(params["enc_layers"], h, cfg, ctx,
                                jnp.arange(s_enc), causal=False,
                                family="encoder")
        h = ctx.sp_allgather_seq(h)   # cross-attention needs full seq
        enc_out = rms_norm(h, params["enc_norm"], cfg.norm_eps)
    x = embed_tokens(params, batch["tokens"], cfg, ctx)
    if cfg.family == "vlm" and "vis_embed" in batch:
        nv = batch["vis_embed"].shape[1]
        x = jnp.concatenate(
            [batch["vis_embed"].astype(x.dtype), x[:, nv:]], axis=1)
    return x, enc_out


def forward(params, batch, cfg: ArchConfig, ctx: ParCtx):
    """(B, S) tokens -> (B, S, D) final hidden + moe aux."""
    x, enc_out = _input_stream(params, batch, cfg, ctx)
    s = x.shape[1]
    positions = jnp.arange(s)
    if ctx.pcfg.sequence_parallel and ctx.tp > 1 and s % ctx.tp == 0:
        sl = s // ctx.tp
        x = jax.lax.dynamic_slice_in_dim(x, ctx.tp_rank() * sl, sl, 1)
    x, aux, _ = stack_forward(params["layers"], x, cfg, ctx, positions,
                              causal=True, enc_out=enc_out)
    x = ctx.sp_allgather_seq(x)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, aux


def loss_fn(params, batch, cfg: ArchConfig, ctx: ParCtx,
            aux_coef: float = 0.01):
    """Scalar local loss honouring the shard_map sum-of-losses contract."""
    x, aux = forward(params, batch, cfg, ctx)
    ce_sum, _ = lm_head_ce(params, x, batch["labels"], cfg, ctx)
    sizes = dict(ctx.mesh.shape)
    dp = sizes.get("pod", 1) * sizes.get("data", 1)
    tp = sizes.get(ctx.pcfg.tp_axis, 1)
    b_l, s = batch["labels"].shape
    t_total = b_l * s * dp
    loss = ce_sum / (t_total * tp)
    if cfg.family == "moe":
        loss = loss + aux_coef * aux / (dp * tp)
    # metrics are globally reduced (out_specs P() reads one rank's value;
    # a local batch mean would be rank-dependent)
    ce_global = ce_sum
    for ax in ("pod", "data"):
        if sizes.get(ax, 1) > 1:
            ce_global = ctx.engine.allreduce(ce_global, ax)
    metrics = {
        "ce_mean": ce_global / t_total,
        "aux": aux,
    }
    return loss, metrics
