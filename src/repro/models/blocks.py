"""Transformer blocks per family + stacked-layer scan machinery.

Layers are stacked (L, ...) per param leaf and iterated with lax.scan
(MaxText-style) so compile time and HLO size stay O(1) in depth — essential
for lowering 48-layer models on 512 virtual devices. Per-layer
heterogeneity (hymba's global-attention layers, mixtral's SWA) rides along
as a scanned (L,) window array consumed branchlessly by the attention mask.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from jax.ad_checkpoint import checkpoint_name

from repro.configs.base import ArchConfig
from repro.models import attention as attn_mod
from repro.models import mlp as mlp_mod
from repro.models import ssm as ssm_mod
from repro.models.attention import AttnConfig, attention_block
from repro.models.common import Builder, rms_norm
from repro.parallel.ops import ParCtx


def stacked(b: Builder, n: int, fn: Callable):
    """Build n stacked copies of fn(builder) (params/specs/shapes)."""
    if b.mode == "init":
        base = jax.random.fold_in(b.key, b.counter)
        b.counter += 1
        keys = jax.random.split(base, n)
        return jax.vmap(
            lambda k: fn(Builder("init", key=k, dtype=b.dtype)))(keys)
    if b.mode == "spec":
        inner = fn(Builder("spec"))
        return jax.tree.map(
            lambda s: P(*((None,) + tuple(s))), inner,
            is_leaf=lambda x: isinstance(x, P))
    # shape mode: prepend the layer dim, replicated
    inner_specs = fn(Builder("spec"))
    inner = fn(Builder("shape", mesh=None, dtype=b.dtype))

    def expand(sd, spec):
        sharding = None
        if b.mesh is not None:
            sharding = jax.sharding.NamedSharding(
                b.mesh, P(*((None,) + tuple(spec))))
        return jax.ShapeDtypeStruct((n,) + sd.shape, sd.dtype,
                                    sharding=sharding)

    return jax.tree.map(expand, inner, inner_specs,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


# --------------------------------------------------------------------------
# Per-family layer params
# --------------------------------------------------------------------------

def layer_params(b: Builder, cfg: ArchConfig, tp: int, cross: bool = False,
                 family: Optional[str] = None):
    family = family or cfg.family
    d = cfg.d_model
    p = {"norm1": b.param((d,), P(None), init="ones")}
    if family == "ssm":
        p["ssm"] = ssm_mod.ssm_params(b, cfg, tp)
        return p
    p["attn"] = attn_mod.attn_params(b, cfg, tp)
    p["norm2"] = b.param((d,), P(None), init="ones")
    if family == "moe":
        p["moe"] = mlp_mod.moe_params(b, cfg, tp)
    else:
        p["mlp"] = mlp_mod.mlp_params(b, cfg)
    if family == "hybrid":
        p["ssm"] = ssm_mod.ssm_params(b, cfg, tp)
        p["norm_attn_out"] = b.param((d,), P(None), init="ones")
        p["norm_ssm_out"] = b.param((d,), P(None), init="ones")
    if cross:
        p["xattn"] = attn_mod.attn_params(b, cfg, tp)
        p["norm_x"] = b.param((d,), P(None), init="ones")
    return p


# --------------------------------------------------------------------------
# Forward (training / prefill, no cache)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class LayerIO:
    window: jax.Array = None        # () int32; 0 = full attention
    positions: jax.Array = None     # (S,)
    enc_out: jax.Array = None       # encoder output for cross-attn


def layer_forward(lp, x, cfg: ArchConfig, ctx: ParCtx, io: LayerIO,
                  causal: bool = True, family: Optional[str] = None,
                  collect_cache: bool = False):
    """One block. Returns (x, moe_probs_or_None, cache_tuple)."""
    family = family or cfg.family
    pc = ctx.pcfg
    aux = None
    cache = ()
    h = rms_norm(x, lp["norm1"], cfg.norm_eps)
    if family == "ssm":
        y, (conv, st) = ssm_mod.ssm_mixer(lp["ssm"], h, cfg, ctx)
        y = checkpoint_name(y, "mixer_out")
        if collect_cache:
            cache = (conv, st)
        return x + y, aux, cache

    acfg = AttnConfig(causal=causal)
    if family == "hybrid":
        a_out = attention_block(
            lp["attn"], h, cfg, ctx, acfg, io.positions, window=io.window,
            q_block=pc.attn_q_block, kv_block=pc.attn_kv_block,
            return_kv=collect_cache)
        if collect_cache:
            a_out, (kc, vc) = a_out
        s_out, (conv, st) = ssm_mod.ssm_mixer(lp["ssm"], h, cfg, ctx)
        if collect_cache:
            cache = (kc, vc, conv, st)
        y = 0.5 * (rms_norm(a_out, lp["norm_attn_out"], cfg.norm_eps)
                   + rms_norm(s_out, lp["norm_ssm_out"], cfg.norm_eps))
        y = checkpoint_name(y, "mixer_out")
        x = x + y
    else:
        y = attention_block(
            lp["attn"], h, cfg, ctx, acfg, io.positions, window=io.window,
            q_block=pc.attn_q_block, kv_block=pc.attn_kv_block,
            return_kv=collect_cache)
        if collect_cache:
            y, (kc, vc) = y
            cache = (kc, vc)
        y = checkpoint_name(y, "mixer_out")
        x = x + y

    if "xattn" in lp:
        hx = rms_norm(x, lp["norm_x"], cfg.norm_eps)
        y = attention_block(
            lp["xattn"], hx, cfg, ctx, AttnConfig(causal=False, cross=True),
            io.positions, kv_source=io.enc_out,
            q_block=pc.attn_q_block, kv_block=pc.attn_kv_block,
            return_kv=collect_cache)
        if collect_cache:
            y, (xk, xv) = y
            cache = cache + (xk, xv)
        x = x + y

    h = rms_norm(x, lp["norm2"], cfg.norm_eps)
    if family == "moe":
        y, probs = mlp_mod.moe_block(lp["moe"], h, cfg, ctx,
                                     pc.moe_capacity_factor)
        aux = probs
    else:
        y = mlp_mod.mlp_block(lp["mlp"], h, cfg, ctx)
    y = checkpoint_name(y, "mlp_out")
    return x + y, aux, cache


def window_per_layer(cfg: ArchConfig, n_layers: int) -> list:
    """Per-layer attention window (python ints); 0 = full attention."""
    w = []
    for i in range(n_layers):
        if cfg.sliding_window and i not in cfg.global_attn_layers:
            w.append(cfg.sliding_window)
        else:
            w.append(0)
    return w


def stack_forward(stack_params, x, cfg: ArchConfig, ctx: ParCtx,
                  positions, *, causal=True, enc_out=None,
                  family: Optional[str] = None, collect_cache: bool = False):
    """Scan (or unroll) the layer stack.

    Returns (x, moe_aux_loss, caches) — caches is a per-layer-stacked
    tuple pytree when collect_cache (prefill), else ().
    """
    pc = ctx.pcfg
    family = family or cfg.family
    n_layers = cfg.encoder_layers if family == "encoder" else cfg.n_layers
    fam = "dense" if family == "encoder" else family
    windows = jnp.asarray(window_per_layer(cfg, n_layers),
                          jnp.int32)

    def body(x, inp):
        lp, w = inp
        io = LayerIO(window=w, positions=positions, enc_out=enc_out)
        x, aux, cache = layer_forward(lp, x, cfg, ctx, io, causal=causal,
                                      family=fam,
                                      collect_cache=collect_cache)
        if aux is None:
            a = jnp.zeros((), jnp.float32)
        else:
            pe = aux.mean(0)  # (E,) mean router prob per expert
            a = cfg.n_experts * jnp.sum(pe * pe)  # switch-style balance
        return x, (a, cache)

    if pc.remat == "full":
        body = jax.checkpoint(body, prevent_cse=False)
    elif pc.remat == "dots":
        body = jax.checkpoint(
            body,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            prevent_cse=False)
    elif pc.remat == "names":
        # save only the block-boundary outputs (bf16, d-width): each
        # branch's backward recomputes only its own branch
        body = jax.checkpoint(
            body,
            policy=jax.checkpoint_policies.save_only_these_names(
                "mixer_out", "mlp_out"),
            prevent_cse=False)

    if pc.scan_layers:
        x, (aux_l, caches) = jax.lax.scan(body, x, (stack_params, windows))
        aux_loss = aux_l.mean()
    else:
        aux_terms, cache_list = [], []
        for i in range(n_layers):
            lp = jax.tree.map(lambda a: a[i], stack_params)
            x, (a, c) = body(x, (lp, windows[i]))
            aux_terms.append(a)
            cache_list.append(c)
        aux_loss = jnp.stack(aux_terms).mean()
        caches = jax.tree.map(lambda *ls: jnp.stack(ls), *cache_list) \
            if cache_list and cache_list[0] != () else ()
    return x, aux_loss, caches
