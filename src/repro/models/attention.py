"""Attention: GQA with RoPE/qk-norm/SWA, flash-style chunked kernel,
sequence-sharded decode with engine flash-combine.

Compute-memory design (TPU): scores never materialize beyond a
(q_block, kv_block) tile held in fp32 registers/VMEM; the outer structure is
lax.scan over kv blocks inside lax.map over q blocks, so the compiled body
is O(blocks) small and the working set is O(q_block * kv_block).

Decode over long caches shards the *sequence* of the KV cache across the TP
axis; every rank computes all heads over its cache slice, and the partial
softmax statistics (m, l, acc) are merged across ranks with engine
collectives — a distributed flash-combine (this is where the collective
engine touches the 500k-context path).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import Builder, rms_norm, rope
from repro.parallel.ops import ParCtx
from jax.sharding import PartitionSpec as P

NEG_INF = -1e30


def padded_heads(cfg: ArchConfig, tp: int) -> int:
    """Q heads padded to a TP multiple (dead heads are masked out)."""
    h = cfg.n_heads
    return ((h + tp - 1) // tp) * tp


def kv_layout(cfg: ArchConfig, tp: int):
    """(kv_heads_local, sharded?) — replicate KV when tp > n_kv.

    KV sharding additionally requires unpadded Q heads, so that the local
    q-head block aligns with the local kv-head block (GQA grouping).
    """
    if (cfg.n_kv_heads >= tp and cfg.n_kv_heads % tp == 0
            and cfg.n_heads % tp == 0):
        return cfg.n_kv_heads // tp, True
    return cfg.n_kv_heads, False


def attn_params(b: Builder, cfg: ArchConfig, tp: int):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    hp = padded_heads(cfg, tp)
    _, kv_sharded = kv_layout(cfg, tp)
    kv_spec = P("data", "model") if kv_sharded else P("data", None)
    p = {
        "wq": b.param((d, hp * hd), P("data", "model")),
        "wk": b.param((d, cfg.n_kv_heads * hd), kv_spec),
        "wv": b.param((d, cfg.n_kv_heads * hd), kv_spec),
        "wo": b.param((hp * hd, d), P("model", "data")),
    }
    if cfg.qk_norm:
        p["q_norm"] = b.param((hd,), P(None), init="ones")
        p["k_norm"] = b.param((hd,), P(None), init="ones")
    return p


# --------------------------------------------------------------------------
# Flash-style chunked attention (training / prefill)
# --------------------------------------------------------------------------

def chunked_attention(q, k, v, *, causal: bool, window=0,
                      q_block: int = 512, kv_block: int = 1024,
                      q_offset=0):
    """q: (B, Sq, H, hd); k, v: (B, Skv, KV, hd); H % KV == 0.

    Returns (B, Sq, H, hd). `window` > 0 masks keys older than `window`
    positions; it may be a traced scalar (0 = unlimited, applied
    branchlessly so per-layer windows can ride through lax.scan).
    `q_offset` is the absolute position of q[0] (for caches).
    """
    b, sq, h, hd = q.shape
    skv, kv = k.shape[1], k.shape[2]
    g = h // kv
    scale = 1.0 / math.sqrt(hd)
    qb = min(q_block, sq)
    kb = min(kv_block, skv)
    nq, nk = sq // qb, skv // kb
    assert sq % qb == 0 and skv % kb == 0, (sq, qb, skv, kb)

    qr = q.reshape(b, nq, qb, kv, g, hd)
    kr = k.reshape(b, nk, kb, kv, hd)
    vr = v.reshape(b, nk, kb, kv, hd)
    kr = jnp.moveaxis(kr, 1, 0)  # (nk, b, kb, kv, hd)
    vr = jnp.moveaxis(vr, 1, 0)

    def q_step(qi, qblk):
        # qblk: (b, qb, kv, g, hd)
        q_pos = q_offset + qi * qb + jnp.arange(qb)

        def kv_step(carry, inp):
            m, l, acc = carry
            ki, kblk, vblk = inp
            k_pos = ki * kb + jnp.arange(kb)
            s = jnp.einsum("bqkgh,bskh->bkgqs", qblk, kblk,
                           preferred_element_type=jnp.float32) * scale
            mask = jnp.ones((qb, kb), bool)
            if causal:
                mask &= k_pos[None, :] <= q_pos[:, None]
            # branchless sliding window: 0 means unlimited
            w = jnp.asarray(window, jnp.int32)
            eff_w = jnp.where(w > 0, w, jnp.int32(1 << 30))
            mask &= k_pos[None, :] > q_pos[:, None] - eff_w
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            pv = jnp.einsum("bkgqs,bskh->bkgqh", p.astype(vblk.dtype), vblk,
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kv, g, qb), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kv, g, qb), jnp.float32)
        a0 = jnp.zeros((b, kv, g, qb, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nk), kr, vr))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.astype(q.dtype)  # (b, kv, g, qb, hd)

    outs = jax.lax.map(lambda args: q_step(*args),
                       (jnp.arange(nq), jnp.moveaxis(qr, 1, 0)))
    # outs: (nq, b, kv, g, qb, hd) -> (b, nq*qb, kv*g, hd)
    outs = jnp.moveaxis(outs, 0, 1).transpose(0, 1, 4, 2, 3, 5)
    return outs.reshape(b, sq, h, hd)


# --------------------------------------------------------------------------
# Flash attention with recompute-style custom VJP
# --------------------------------------------------------------------------
#
# chunked_attention above is the oracle; differentiating it directly makes
# scan linearization save every (q_block, kv_block) probability tile —
# O(S^2) residuals, which is what flash attention exists to avoid. The
# custom_vjp below saves only (q, k, v, out, lse) and recomputes P tiles in
# the backward block loops (standard flash backward).

def _flash_fwd_blocks(q, k, v, window, *, causal, qb, kb, q_offset):
    """Returns (out, lse). Shapes as chunked_attention (already grouped):
    q: (b, nq, qb, kv, g, hd); k, v: (nk, b, kb, kv, hd)."""
    b, nq, qbs, kv, g, hd = q.shape
    nk = k.shape[0]
    scale = 1.0 / math.sqrt(hd)
    w = jnp.asarray(window, jnp.int32)
    eff_w = jnp.where(w > 0, w, jnp.int32(1 << 30))

    def q_step(qi, qblk):
        q_pos = q_offset + qi * qbs + jnp.arange(qbs)

        def kv_step(carry, inp):
            m, l, acc = carry
            ki, kblk, vblk = inp
            k_pos = ki * kb + jnp.arange(kb)
            s = jnp.einsum("bqkgh,bskh->bkgqs", qblk, kblk,
                           preferred_element_type=jnp.float32) * scale
            mask = jnp.ones((qbs, kb), bool)
            if causal:
                mask &= k_pos[None, :] <= q_pos[:, None]
            mask &= k_pos[None, :] > q_pos[:, None] - eff_w
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            pv = jnp.einsum("bkgqs,bskh->bkgqh", p.astype(vblk.dtype), vblk,
                            preferred_element_type=jnp.float32)
            return (m_new, l_new, acc * corr[..., None] + pv), None

        m0 = jnp.full((b, kv, g, qbs), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kv, g, qbs), jnp.float32)
        a0 = jnp.zeros((b, kv, g, qbs, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                      (jnp.arange(nk), k, v))
        out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        return out, lse

    outs, lses = jax.lax.map(lambda args: q_step(*args),
                             (jnp.arange(nq), jnp.moveaxis(q, 1, 0)))
    return jnp.moveaxis(outs, 0, 1), jnp.moveaxis(lses, 0, 1)


@functools.lru_cache(maxsize=64)
def _make_flash(causal: bool, qb: int, kb: int, nq: int, nk: int):
    """custom_vjp flash attention specialized to static block structure."""

    @jax.custom_vjp
    def flash(q, k, v, window):
        out, _ = _flash_fwd_blocks(q, k, v, window, causal=causal, qb=qb,
                                   kb=kb, q_offset=0)
        return out

    def fwd(q, k, v, window):
        out, lse = _flash_fwd_blocks(q, k, v, window, causal=causal, qb=qb,
                                     kb=kb, q_offset=0)
        return out, (q, k, v, window, out, lse)

    def bwd(res, dout):
        q, k, v, window, out, lse = res
        b, nq_, qbs, kv, g, hd = q.shape
        scale = 1.0 / math.sqrt(hd)
        w = jnp.asarray(window, jnp.int32)
        eff_w = jnp.where(w > 0, w, jnp.int32(1 << 30))
        doutf = dout.astype(jnp.float32)
        delta = jnp.sum(doutf * out.astype(jnp.float32), axis=-1)

        def kv_step(dq_full, inp):
            ki, kblk, vblk = inp
            k_pos = ki * kb + jnp.arange(kb)

            def q_step(carry, qinp):
                dkb, dvb, dq_full = carry
                qi, qblk, doblk, lseblk, dblk = qinp
                q_pos = qi * qbs + jnp.arange(qbs)
                s = jnp.einsum("bqkgh,bskh->bkgqs", qblk, kblk,
                               preferred_element_type=jnp.float32) * scale
                mask = jnp.ones((qbs, kb), bool)
                if causal:
                    mask &= k_pos[None, :] <= q_pos[:, None]
                mask &= k_pos[None, :] > q_pos[:, None] - eff_w
                s = jnp.where(mask[None, None, None], s, NEG_INF)
                p = jnp.exp(s - lseblk[..., None])          # (b,kv,g,q,s)
                dv_c = jnp.einsum("bkgqs,bkgqh->bskh", p, doblk)
                dp = jnp.einsum("bkgqh,bskh->bkgqs", doblk,
                                vblk.astype(jnp.float32))
                ds = p * (dp - dblk[..., None]) * scale
                dq_c = jnp.einsum("bkgqs,bskh->bqkgh", ds,
                                  kblk.astype(jnp.float32))
                dk_c = jnp.einsum("bkgqs,bqkgh->bskh", ds,
                                  qblk.astype(jnp.float32))
                dq_full = jax.lax.dynamic_update_index_in_dim(
                    dq_full, dq_full[qi] + dq_c, qi, 0)
                return (dkb + dk_c, dvb + dv_c, dq_full), None

            dkb0 = jnp.zeros(kblk.shape, jnp.float32)
            dvb0 = jnp.zeros(vblk.shape, jnp.float32)
            (dkb, dvb, dq_full), _ = jax.lax.scan(
                q_step, (dkb0, dvb0, dq_full),
                (jnp.arange(nq_), jnp.moveaxis(q, 1, 0),
                 jnp.moveaxis(doutf, 1, 0), jnp.moveaxis(lse, 1, 0),
                 jnp.moveaxis(delta, 1, 0)))
            return dq_full, (dkb, dvb)

        dq0 = jnp.zeros((nq_,) + q.shape[:1] + q.shape[2:], jnp.float32)
        dq_full, (dk, dv) = jax.lax.scan(
            kv_step, dq0, (jnp.arange(k.shape[0]), k, v))
        dq = jnp.moveaxis(dq_full, 0, 1).astype(q.dtype)
        return dq, dk.astype(k.dtype), dv.astype(v.dtype), None

    flash.defvjp(fwd, bwd)
    return flash


def flash_attention(q, k, v, *, causal: bool, window=0,
                    q_block: int = 512, kv_block: int = 1024):
    """Memory-efficient attention (training/prefill path).

    Same contract as chunked_attention; O(S) residuals via custom VJP.
    """
    b, sq, h, hd = q.shape
    skv, kv = k.shape[1], k.shape[2]
    g = h // kv
    qb = min(q_block, sq)
    kb = min(kv_block, skv)
    nq, nk = sq // qb, skv // kb
    assert sq % qb == 0 and skv % kb == 0, (sq, qb, skv, kb)
    qr = q.reshape(b, nq, qb, kv, g, hd)
    kr = jnp.moveaxis(k.reshape(b, nk, kb, kv, hd), 1, 0)
    vr = jnp.moveaxis(v.reshape(b, nk, kb, kv, hd), 1, 0)
    fn = _make_flash(causal, qb, kb, nq, nk)
    out = fn(qr, kr, vr, jnp.asarray(window, jnp.int32))
    out = out.transpose(0, 1, 4, 2, 3, 5)  # (b,nq,qb,kv,g,hd)->(b,nq,qb,...)
    return out.reshape(b, sq, h, hd)


# --------------------------------------------------------------------------
# Decode attention (single new token over a cache)
# --------------------------------------------------------------------------

def decode_attention(q, k_cache, v_cache, *, slot_positions, cur_pos,
                     combine_axis: Optional[str] = None, engine=None):
    """q: (B, H, hd); caches: (B, Sc, KV, hd) (a local slice when
    combine_axis is set). slot_positions: (Sc,) absolute position held by
    each cache slot (< 0 = unwritten); slots with position <= cur_pos
    attend.

    With combine_axis, partial (m, l, acc) merge across the TP group via
    engine collectives — distributed flash-combine.
    """
    b, h, hd = q.shape
    sc, kv = k_cache.shape[1], k_cache.shape[2]
    g = h // kv
    scale = 1.0 / math.sqrt(hd)
    qr = q.reshape(b, kv, g, hd)
    mask = (slot_positions >= 0) & (slot_positions <= cur_pos)

    s = jnp.einsum("bkgh,bskh->bkgs", qr, k_cache,
                   preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask[None, None, None, :], s, NEG_INF)
    m = s.max(-1)
    p = jnp.exp(s - m[..., None])
    l = p.sum(-1)
    acc = jnp.einsum("bkgs,bskh->bkgh", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)

    if combine_axis is not None and engine is not None \
            and engine.mesh.shape[combine_axis] > 1:
        m_g = engine.allreduce(m, combine_axis, op="max")
        w = jnp.exp(m - m_g)
        l = engine.allreduce(l * w, combine_axis)
        acc = engine.allreduce(acc * w[..., None], combine_axis)

    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, h, hd).astype(q.dtype)


# --------------------------------------------------------------------------
# Full attention layer (projections + cache plumbing)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class AttnConfig:
    causal: bool = True
    cross: bool = False       # cross-attention (kv from encoder output)


def head_mask(cfg: ArchConfig, ctx: ParCtx, local_heads: int, local: bool):
    """Mask padded Q heads: global head index >= n_heads contributes 0."""
    hp = padded_heads(cfg, ctx.tp)
    if hp == cfg.n_heads:
        return None
    if local:
        base = ctx.tp_rank() * local_heads
        idx = base + jnp.arange(local_heads)
    else:
        idx = jnp.arange(hp)
    return (idx < cfg.n_heads)


def attention_block(params, x, cfg: ArchConfig, ctx: ParCtx,
                    acfg: AttnConfig, positions, kv_source=None,
                    window=0, q_block=512, kv_block=1024,
                    return_kv: bool = False):
    """Training/prefill attention over local Q heads.

    x: (B, S, D) (seq-sharded under SP); kv_source overrides the kv input
    (cross-attention). Returns (B, S, D)-partial summed via
    row_parallel_finish.
    """
    hd = cfg.resolved_head_dim
    hp = padded_heads(cfg, ctx.tp)
    hl = hp // ctx.tp
    kv_l, kv_sharded = kv_layout(cfg, ctx.tp)

    if kv_source is None:
        # fused QKV projection: ONE sequence gather / collective matmul
        # feeds all three heads (a separate gather per projection tripled
        # SP's wire bytes — see EXPERIMENTS §Perf iteration 1)
        w_q = ctx.gather_fsdp(params["wq"])
        w_k = ctx.gather_fsdp(params["wk"])
        w_v = ctx.gather_fsdp(params["wv"])
        w_qkv = jnp.concatenate([w_q, w_k, w_v], axis=1)
        qkv = ctx.col_parallel_matmul(x, w_qkv, pregathered=True)
        d_q = w_q.shape[1]
        d_k = w_k.shape[1]
        q = qkv[..., :d_q]
        k = qkv[..., d_q:d_q + d_k]
        v = qkv[..., d_q + d_k:]
    else:
        q = ctx.col_parallel_matmul(x, params["wq"])
        k = ctx.dense(kv_source, params["wk"])
        v = ctx.dense(kv_source, params["wv"])
    b, s = q.shape[0], q.shape[1]
    skv = k.shape[1]
    q = q.reshape(b, s, hl, hd)
    k = k.reshape(b, skv, kv_l, hd)
    v = v.reshape(b, skv, kv_l, hd)

    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    if not acfg.cross:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)

    # GQA group alignment: local q heads must map onto local kv heads.
    if not kv_sharded:
        # every rank has all kv heads; local q heads belong to global groups
        # -> bring q to (B,S,KV, hl/KV...) by padding group dim per rank.
        # Simplest correct mapping: repeat kv to match local q heads.
        group = max(cfg.n_heads // cfg.n_kv_heads, 1)
        base = ctx.tp_rank() * hl
        owner = jnp.clip((base + jnp.arange(hl)) // group, 0,
                         cfg.n_kv_heads - 1)
        k = jnp.take(k, owner, axis=2)  # (B, Skv, hl, hd)
        v = jnp.take(v, owner, axis=2)

    out = flash_attention(q, k, v, causal=acfg.causal, window=window,
                          q_block=q_block, kv_block=kv_block)
    hm = head_mask(cfg, ctx, hl, local=True)
    if hm is not None:
        out = out * hm[None, None, :, None].astype(out.dtype)
    out = out.reshape(b, s, hl * hd)
    wo = ctx.gather_fsdp(params["wo"], dim=1)
    y = jnp.einsum("bsf,fd->bsd", out, wo.astype(out.dtype))
    y = ctx.row_parallel_finish(y)
    if not return_kv:
        return y
    # prefill cache emission, decode layout: seq-shard the cache over the
    # TP axis when KV heads replicate (the flash-combine decode path),
    # else keep the full sequence with local KV heads.
    if (not kv_sharded) and ctx.pcfg.decode_seq_shard and ctx.tp > 1 \
            and skv % ctx.tp == 0:
        sl = skv // ctx.tp
        kc = jax.lax.dynamic_slice_in_dim(k, ctx.tp_rank() * sl, sl, 1)
        vc = jax.lax.dynamic_slice_in_dim(v, ctx.tp_rank() * sl, sl, 1)
    else:
        kc, vc = k, v
    return y, (kc, vc)
