"""Mamba2 / SSD mixer: chunked state-space dual scan + O(1) decode.

TP layout: inner channels (heads x head_dim) shard over 'model'; the shared
B/C state projections (n_groups=1) replicate; the gated RMSNorm over the
sharded inner dim reduces its mean-square across TP through the engine.

Chunked SSD (paper Alg. 1 of arXiv:2405.21060): within a chunk the dual
quadratic form (an L x L decay-masked attention-like product); across chunks
a lax.scan recurrence over (heads, state, head_dim) states. Decode carries
(conv window, ssm state) — constant memory, which is why the mamba2/hymba
cells run the long_500k shape.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models.common import Builder, silu
from repro.parallel.ops import ParCtx


def padded_ssm_heads(cfg: ArchConfig, tp: int) -> int:
    """SSM heads padded to a TP multiple (hymba: 50 -> 64 on tp=16).

    Padded channels are zero-masked before the gated norm, so they
    contribute nothing to outputs or gradients (see ssm_mixer)."""
    nh = cfg.ssm_n_heads
    return ((nh + tp - 1) // tp) * tp


def ssm_params(b: Builder, cfg: ArchConfig, tp: int):
    d = cfg.d_model
    nh = padded_ssm_heads(cfg, tp)
    di = nh * cfg.ssm_head_dim
    n = cfg.ssm_state
    cw = cfg.ssm_conv
    return {
        # z and x projections are separate params: a concatenated (d, 2*di)
        # matrix sharded on dim1 would hand each TP rank a misaligned slice
        # spanning the z|x boundary.
        "w_z": b.param((d, di), P("data", "model")),
        "w_x": b.param((d, di), P("data", "model")),
        "w_bc": b.param((d, 2 * n), P("data", None)),
        "w_dt": b.param((d, nh), P("data", "model")),
        "conv_x": b.param((cw, di), P(None, "model"), scale=0.5),
        "conv_bc": b.param((cw, 2 * n), P(None, None), scale=0.5),
        "a_log": b.param((nh,), P("model"), init="ssm_a", dtype=jnp.float32),
        "dt_bias": b.param((nh,), P("model"), init="ssm_dt",
                           dtype=jnp.float32),
        "d_skip": b.param((nh,), P("model"), init="ones", dtype=jnp.float32),
        "norm": b.param((di,), P("model"), init="ones"),
        "out_proj": b.param((di, d), P("model", "data")),
    }


def _causal_conv(x, w, state=None):
    """Depthwise causal conv, width cw. x: (B, S, C); w: (cw, C).

    With `state` (B, cw-1, C) uses it as left context and returns
    (y, new_state) — the decode path.
    """
    cw = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
        xp = jnp.concatenate([pad, x], axis=1)
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i][None, None].astype(x.dtype)
            for i in range(cw))
    new_state = xp[:, -(cw - 1):] if cw > 1 else None
    return y, new_state


def _ssd_chunked(xh, dt, a_neg, b_in, c_in, chunk: int):
    """Chunked SSD scan.

    xh: (B, S, H, P); dt: (B, S, H) (post-softplus); a_neg: (H,) negative;
    b_in, c_in: (B, S, N). Returns (y: (B, S, H, P), final state
    (B, H, N, P)).
    """
    bsz, s, h, p = xh.shape
    n = b_in.shape[-1]
    l = min(chunk, s)
    assert s % l == 0, (s, l)
    nc = s // l

    xc = xh.reshape(bsz, nc, l, h, p).astype(jnp.float32)
    dtc = dt.reshape(bsz, nc, l, h).astype(jnp.float32)
    bc = b_in.reshape(bsz, nc, l, n).astype(jnp.float32)
    cc = c_in.reshape(bsz, nc, l, n).astype(jnp.float32)

    log_a = dtc * a_neg[None, None, None, :]              # (b,c,l,h) <= 0
    ll = jnp.cumsum(log_a, axis=2)                        # within-chunk
    ll_last = ll[:, :, -1:]                               # (b,c,1,h)

    # intra-chunk quadratic form
    scores = jnp.einsum("bcln,bcsn->bcls", cc, bc)        # (b,c,l,s)
    decay = ll[:, :, :, None, :] - ll[:, :, None, :, :]   # (b,c,l,s,h)
    mask = jnp.tril(jnp.ones((l, l), bool))
    m = jnp.where(mask[None, None, :, :, None],
                  jnp.exp(decay), 0.0) * scores[..., None]
    xdt = xc * dtc[..., None]                             # (b,c,l,h,p)
    y_intra = jnp.einsum("bclsh,bcshp->bclhp", m, xdt)

    # chunk-end states and inter-chunk recurrence
    decay_to_end = jnp.exp(ll_last - ll)                  # (b,c,l,h)
    s_chunk = jnp.einsum("bcln,bclh,bclhp->bchnp",
                         bc, decay_to_end * dtc, xc)
    a_chunk = jnp.exp(ll_last[:, :, 0])                   # (b,c,h)

    def scan_fn(h_prev, inp):
        a_c, s_c = inp                                    # (b,h), (b,h,n,p)
        h_new = a_c[..., None, None] * h_prev + s_c
        return h_new, h_prev

    h0 = jnp.zeros((bsz, h, n, p), jnp.float32)
    h_final, h_prevs = jax.lax.scan(
        scan_fn, h0,
        (jnp.moveaxis(a_chunk, 1, 0), jnp.moveaxis(s_chunk, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                 # (b,c,h,n,p)

    y_inter = jnp.einsum("bcln,bchnp->bclhp", cc, h_prevs) \
        * jnp.exp(ll)[..., None]
    y = (y_intra + y_inter).reshape(bsz, s, h, p)
    return y.astype(xh.dtype), h_final


def ssm_mixer(params, x, cfg: ArchConfig, ctx: ParCtx, conv_state=None,
              ssm_state=None, decode: bool = False):
    """x: (B, S, D) -> (B, S, D). decode=True: S==1, carries required.

    Returns (y, (new_conv_state, new_ssm_state)).
    """
    tp = ctx.tp
    nh_p = padded_ssm_heads(cfg, tp)
    di_p = nh_p * cfg.ssm_head_dim
    di_l = di_p // tp
    nh_l = nh_p // tp
    p = cfg.ssm_head_dim
    n = cfg.ssm_state

    x = ctx.sp_allgather_seq(x) if (not decode) else x
    # fused in-projection: one matmul for z | x | bc | dt
    w_z = ctx.gather_fsdp(params["w_z"])
    w_x = ctx.gather_fsdp(params["w_x"])
    w_bc = ctx.gather_fsdp(params["w_bc"])
    w_dt = ctx.gather_fsdp(params["w_dt"])
    w_in = jnp.concatenate([w_z, w_x, w_bc, w_dt], axis=1)
    zxbd = jnp.einsum("...d,df->...f", x, w_in.astype(x.dtype))
    o1, o2 = w_z.shape[1], w_z.shape[1] + w_x.shape[1]
    o3 = o2 + w_bc.shape[1]
    z, xin, bc, dt_raw = (zxbd[..., :o1], zxbd[..., o1:o2],
                          zxbd[..., o2:o3], zxbd[..., o3:])

    conv_in = jnp.concatenate([xin, bc], axis=-1)
    # conv weights: x-part is TP-local already (spec shards dim1); bc-part
    # replicated — concat matches conv_in's channel layout.
    wc = jnp.concatenate([params["conv_x"], params["conv_bc"]], axis=-1)
    conv_out, new_conv = _causal_conv(conv_in, wc, conv_state)
    conv_out = silu(conv_out)
    xin = conv_out[..., :di_l]
    b_in = conv_out[..., di_l:di_l + n]
    c_in = conv_out[..., di_l + n:]

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"][None, None])
    a_neg = -jnp.exp(params["a_log"])

    bsz, s = xin.shape[0], xin.shape[1]
    xh = xin.reshape(bsz, s, nh_l, p)

    if decode:
        a_step = jnp.exp(dt[:, 0] * a_neg[None])            # (B, nh_l)
        upd = jnp.einsum("bn,bh,bhp->bhnp", b_in[:, 0].astype(jnp.float32),
                         dt[:, 0], xh[:, 0].astype(jnp.float32))
        new_ssm = a_step[..., None, None] * ssm_state + upd
        y = jnp.einsum("bn,bhnp->bhp", c_in[:, 0].astype(jnp.float32),
                       new_ssm)[:, None]
    else:
        y, new_ssm = _ssd_chunked(xh, dt, a_neg, b_in, c_in, cfg.ssm_chunk)

    y = y + params["d_skip"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(bsz, s, di_l).astype(x.dtype)
    y = y * silu(z)
    # zero padded channels (hymba: heads padded to a TP multiple) so they
    # never reach the norm statistics, outputs, or gradients
    ch = ctx.tp_rank() * di_l + jnp.arange(di_l)
    live = ch < cfg.ssm_d_inner
    y = y * live[None, None, :].astype(y.dtype)
    # gated RMSNorm over the REAL inner width (cross-TP mean-square)
    yf = y.astype(jnp.float32)
    ss = jnp.sum(yf * yf, axis=-1, keepdims=True)
    if tp > 1:
        ss = ctx.engine.allreduce(ss, ctx.tp_axis)
    ms = ss / cfg.ssm_d_inner
    y = (yf * jax.lax.rsqrt(ms + cfg.norm_eps)
         * params["norm"].astype(jnp.float32)[None, None]).astype(x.dtype)
    wo = ctx.gather_fsdp(params["out_proj"], dim=1)
    out = jnp.einsum("bsf,fd->bsd", y, wo.astype(y.dtype))
    out = ctx.row_parallel_finish(out) if not decode \
        else (ctx.engine.allreduce(out, ctx.tp_axis) if tp > 1 else out)
    return out, (new_conv, new_ssm)
