"""Shared model machinery: param builder, norms, rope, activations.

The `Builder` gives every layer a single definition that can produce
  mode='init'   real initialized jnp arrays (smoke tests, examples),
  mode='spec'   a PartitionSpec pytree (shard_map in_specs, checkpointing),
  mode='shape'  ShapeDtypeStructs with NamedSharding (the dry-run: no
                allocation ever happens for the 26B configs).

Spec conventions over the production mesh (pod, data, model):
  * 'data'  appearing in a param spec = FSDP shard (gathered at use),
  * 'model' = tensor-parallel shard,
  * axes absent from a spec mean the param is replicated there and its
    gradient must be summed over that axis (runtime/grad_sync handles it).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P


def dt(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


@dataclasses.dataclass
class Builder:
    """One param definition -> init array | spec | ShapeDtypeStruct."""

    mode: str                      # 'init' | 'spec' | 'shape'
    key: Optional[jax.Array] = None
    mesh: Optional[jax.sharding.Mesh] = None
    dtype: object = jnp.float32
    counter: int = 0

    def _next_key(self):
        self.counter += 1
        return jax.random.fold_in(self.key, self.counter)

    def param(self, shape, spec: P, init: str = "normal",
              scale: Optional[float] = None, dtype=None):
        dtype = dtype or self.dtype
        if self.mode == "spec":
            return spec
        if self.mode == "shape":
            if self.mesh is not None:
                return jax.ShapeDtypeStruct(
                    shape, dtype, sharding=NamedSharding(self.mesh, spec))
            return jax.ShapeDtypeStruct(shape, dtype)
        k = self._next_key()
        if init == "zeros":
            return jnp.zeros(shape, dtype)
        if init == "ones":
            return jnp.ones(shape, dtype)
        if init == "normal":
            if scale is None:
                scale = 1.0 / math.sqrt(shape[0] if len(shape) > 1 else 1.0)
            return (jax.random.normal(k, shape, jnp.float32) * scale
                    ).astype(dtype)
        if init == "ssm_a":  # mamba A_log in [log 1, log 16]
            u = jax.random.uniform(k, shape, jnp.float32, 1.0, 16.0)
            return jnp.log(u).astype(jnp.float32)
        if init == "ssm_dt":  # dt bias ~ softplus^-1(U(1e-3, 1e-1))
            u = jax.random.uniform(k, shape, jnp.float32, 1e-3, 1e-1)
            return jnp.log(jnp.expm1(u)).astype(jnp.float32)
        raise ValueError(init)


# --------------------------------------------------------------------------
# Numerics
# --------------------------------------------------------------------------

def rms_norm(x, weight, eps: float = 1e-6, psum_axis=None, engine=None):
    """RMSNorm; if the feature dim is TP-sharded, pass psum_axis to reduce
    the mean-square across the shard group (engine optional for microcode)."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    if psum_axis is not None:
        if engine is not None and engine.backend == "microcode":
            ms = engine.allreduce(ms, psum_axis) / engine.mesh.shape[psum_axis]
        else:
            ms = jax.lax.pmean(ms, psum_axis)
    y = xf * jax.lax.rsqrt(ms + eps)
    return (y * weight.astype(jnp.float32)).astype(x.dtype)


def rope(x, positions, theta: float):
    """Rotary embeddings. x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32)
                    / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    ).astype(x.dtype)


def silu(x):
    return x * jax.nn.sigmoid(x)


def gelu(x):
    return jax.nn.gelu(x)


def sinusoidal_positions(seq_len: int, d_model: int, offset=0):
    """Whisper-style absolute sinusoidal embeddings, computed on the fly."""
    pos = jnp.arange(seq_len, dtype=jnp.float32) + offset
    half = d_model // 2
    freqs = jnp.exp(-math.log(10000.0)
                    * jnp.arange(half, dtype=jnp.float32) / half)
    ang = pos[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
