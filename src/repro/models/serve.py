"""Serving: prefill + single-token decode over sharded caches.

Decode cache layouts (per attention layer):
  seq-sharded   (B, len/tp, KV, hd) over 'model' — every rank computes all
                (padded) Q heads on its slice; partial softmax stats merge
                via engine flash-combine. Used when KV heads replicate
                (n_kv < tp) — the long-context path (32k/500k cells).
  head-sharded  (B, len, KV/tp, hd) when n_kv >= tp (whisper MHA).
  SWA layers    rolling cache of length `window` (slot = pos % W), layout
                as above; slot->position recovered arithmetically for the
                mask, so RoPE is applied before caching and slot order
                never matters.

SSM layers carry (conv_state, ssm_state) — O(1), which is what makes the
long_500k cells runnable for mamba2/hymba.

The decode layer loop is unrolled (not scanned) because cache shapes vary
per layer (hymba: 3 global layers at full length, 29 at window length).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import ssm as ssm_mod
from repro.models.attention import (
    decode_attention, kv_layout, padded_heads,
)
from repro.models.blocks import window_per_layer
from repro.models.common import Builder, rms_norm, rope
from repro.models.lm import (
    _input_stream, embed_tokens, lm_head_sample,
)
from repro.models.blocks import stack_forward
from repro.parallel.ops import ParCtx


def layer_cache_len(cfg: ArchConfig, layer: int, s_max: int) -> int:
    w = cfg.sliding_window
    if w and layer not in cfg.global_attn_layers:
        return min(w, s_max)
    return s_max


def attn_cache_params(b: Builder, cfg: ArchConfig, tp: int, b_local_axis,
                      length: int, decode_seq_shard: bool):
    """Cache leaves for one attention layer."""
    hd = cfg.resolved_head_dim
    kv_l, kv_sharded = kv_layout(cfg, tp)
    dp = b_local_axis
    if kv_sharded:
        spec = P(dp, None, "model", None)
        shape = (None, length, cfg.n_kv_heads, hd)
    elif decode_seq_shard and tp > 1 and length % tp == 0:
        spec = P(dp, "model", None, None)
        shape = (None, length, cfg.n_kv_heads, hd)
    else:
        spec = P(dp, None, None, None)
        shape = (None, length, cfg.n_kv_heads, hd)
    return shape, spec


def make_cache(b: Builder, cfg: ArchConfig, tp: int, batch: int,
               s_max: int, pcfg, s_enc: int = 0, dp=("pod", "data")):
    """Full decode-cache pytree (list per layer). Shapes are GLOBAL
    (shard_map in_specs split them); dp=None replicates the batch dim
    (the B=1 long-context cells)."""
    caches = []
    for layer in range(cfg.n_layers):
        entry = {}
        if cfg.has_attention:
            length = layer_cache_len(cfg, layer, s_max)
            shp, spec = attn_cache_params(b, cfg, tp, dp, length,
                                          pcfg.decode_seq_shard)
            shp = (batch,) + shp[1:]
            q8 = pcfg.kv_cache_dtype == "int8"
            kdt = jnp.int8 if q8 else None
            entry["k"] = b.param(shp, spec, init="zeros", dtype=kdt)
            entry["v"] = b.param(shp, spec, init="zeros", dtype=kdt)
            if q8:
                # one symmetric scale per (slot, kv head) — the unary
                # compression plugin applied to cache storage
                sshp, sspec = shp[:3], P(*spec[:3])
                entry["k_scale"] = b.param(sshp, sspec, init="zeros",
                                           dtype=jnp.float32)
                entry["v_scale"] = b.param(sshp, sspec, init="zeros",
                                           dtype=jnp.float32)
            if cfg.encoder_layers and s_enc:
                xshp, xspec = attn_cache_params(b, cfg, tp, dp, s_enc,
                                                False)
                xshp = (batch,) + xshp[1:]
                entry["xk"] = b.param(xshp, xspec, init="zeros")
                entry["xv"] = b.param(xshp, xspec, init="zeros")
        if cfg.family in ("ssm", "hybrid"):
            from repro.models.ssm import padded_ssm_heads
            nh_p = padded_ssm_heads(cfg, tp)
            di_l = nh_p * cfg.ssm_head_dim // tp
            # conv channels are TP-local (x-part sharded, bc-part
            # replicated); globally the cache is the concat of the
            # per-rank local states, sharded back out on use.
            chan_global = tp * (di_l + 2 * cfg.ssm_state)
            entry["conv"] = b.param(
                (batch, cfg.ssm_conv - 1, chan_global),
                P(dp, None, "model" if tp > 1 else None), init="zeros")
            entry["state"] = b.param(
                (batch, nh_p, cfg.ssm_state, cfg.ssm_head_dim),
                P(dp, "model" if tp > 1 else None, None, None),
                init="zeros", dtype=jnp.float32)
        caches.append(entry)
    return caches


def prefill_cache_specs(cfg: ArchConfig, pcfg, tp: int, s: int,
                        dp=("pod", "data")):
    """out_specs for the layer-stacked caches prefill emits (leading layer
    dim; uniform full-sequence layout across layers)."""
    kv_l, kv_sharded = kv_layout(cfg, tp)
    m = "model" if tp > 1 else None
    if kv_sharded:
        kv = P(None, dp, None, "model", None)
    elif pcfg.decode_seq_shard and tp > 1 and s % tp == 0:
        kv = P(None, dp, "model", None, None)
    else:
        kv = P(None, dp, None, None, None)
    conv = P(None, dp, None, m)
    state = P(None, dp, m, None, None)
    if cfg.family == "ssm":
        return (conv, state)
    if cfg.family == "hybrid":
        return (kv, kv, conv, state)
    if cfg.encoder_layers:
        xkv = P(None, dp, None, "model" if kv_sharded else None, None)
        return (kv, kv, xkv, xkv)
    return (kv, kv)


# --------------------------------------------------------------------------
# Decode
# --------------------------------------------------------------------------

def _slot_and_positions(length_total: int, rolling: bool, pos,
                        local_len: int, rank, tp_sharded: bool):
    """Write slot + per-slot absolute positions for the mask.

    rolling caches hold the last `length_total` positions at slot
    p % length_total; slot i therefore holds position
    pos - ((pos - i) mod length_total) (negative = not yet written).
    """
    slot = pos % length_total if rolling else pos
    base = rank * local_len if tp_sharded else 0
    idx = base + jnp.arange(local_len)
    if rolling:
        slot_pos = pos - ((pos - idx) % length_total)
    else:
        slot_pos = idx
    return slot, slot_pos


def attn_decode(lp, h, cache, cfg: ArchConfig, ctx: ParCtx, pos,
                window: int, s_max: int, cross: bool = False):
    """h: (B, 1, D) normed input. Returns (y (B,1,D), new cache)."""
    hd = cfg.resolved_head_dim
    tp = ctx.tp
    hp = padded_heads(cfg, tp)
    hl = hp // tp
    kv_l, kv_sharded = kv_layout(cfg, tp)
    bsz = h.shape[0]
    params = lp["xattn"] if cross else lp["attn"]
    kname, vname = ("xk", "xv") if cross else ("k", "v")

    q = ctx.dense(h, params["wq"]).reshape(bsz, 1, hl, hd)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
    if not cross:
        q = rope(q, jnp.asarray(pos)[None], cfg.rope_theta)
    q = q[:, 0]                                       # (B, hl, hd)

    k_cache, v_cache = cache[kname], cache[vname]
    local_len = k_cache.shape[1]
    if cross:
        # static cross-attention cache: its own length, never seq-sharded
        length_total = local_len
        seq_sharded = False
    else:
        # mirror make_cache's layout decision exactly
        length_total = min(window, s_max) if (window and window < s_max) \
            else s_max
        seq_sharded = (not kv_sharded) and ctx.pcfg.decode_seq_shard \
            and tp > 1 and (length_total % tp == 0)
        assert local_len == (length_total // tp if seq_sharded
                             else length_total), \
            (local_len, length_total, seq_sharded)

    quant = (not cross) and k_cache.dtype == jnp.int8
    k_scale = cache.get("k_scale") if quant else None
    v_scale = cache.get("v_scale") if quant else None

    def _wr(buf, new, cl, ok):
        upd = jax.lax.dynamic_update_slice_in_dim(
            buf, new.astype(buf.dtype), cl, 1)
        return jnp.where(ok, upd, buf) if seq_sharded else upd

    if not cross:
        k_new = ctx.dense(h, params["wk"]).reshape(bsz, 1, kv_l, hd)
        v_new = ctx.dense(h, params["wv"]).reshape(bsz, 1, kv_l, hd)
        if cfg.qk_norm:
            k_new = rms_norm(k_new, params["k_norm"], cfg.norm_eps)
        k_new = rope(k_new, jnp.asarray(pos)[None], cfg.rope_theta)
        rolling = bool(window) and window < s_max  # cache len == window
        slot, slot_pos = _slot_and_positions(
            length_total, rolling, pos, local_len, ctx.tp_rank(),
            seq_sharded)
        local_slot = slot - (ctx.tp_rank() * local_len if seq_sharded else 0)
        ok = (local_slot >= 0) & (local_slot < local_len)
        cl = jnp.clip(local_slot, 0, local_len - 1)
        if quant:
            # int8 KV cache: one symmetric scale per (slot, kv head)
            def _q(x):
                s = jnp.maximum(
                    jnp.max(jnp.abs(x.astype(jnp.float32)), -1) / 127.0,
                    1e-8)                          # (B, 1, kv)
                qv = jnp.clip(jnp.round(x.astype(jnp.float32)
                                        / s[..., None]), -127, 127)
                return qv.astype(jnp.int8), s
            kq, ks = _q(k_new)
            vq, vs = _q(v_new)
            k_cache = _wr(k_cache, kq, cl, ok)
            v_cache = _wr(v_cache, vq, cl, ok)
            k_scale = _wr(k_scale, ks, cl, ok)
            v_scale = _wr(v_scale, vs, cl, ok)
        else:
            k_cache = _wr(k_cache, k_new, cl, ok)
            v_cache = _wr(v_cache, v_new, cl, ok)
    else:
        slot_pos = jnp.arange(local_len)
        pos = jnp.asarray(2 ** 30)

    # flash-combine path needs all (padded) q heads on every rank
    if seq_sharded:
        qf = ctx.engine.allgather(q.transpose(1, 0, 2), ctx.tp_axis)
        qf = qf.reshape(hp, bsz, hd).transpose(1, 0, 2)   # (B, hp, hd)
        n_q = hp
    else:
        qf = q
        n_q = hl

    # GQA owner-gather (g=1 einsum)
    group = max(cfg.n_heads // cfg.n_kv_heads, 1)
    if kv_sharded:
        owner = jnp.arange(n_q) // (n_q // k_cache.shape[2])
    else:
        base = 0 if seq_sharded else ctx.tp_rank() * hl
        owner = jnp.clip((base + jnp.arange(n_q)) // group,
                         0, cfg.n_kv_heads - 1)
    k_sel = jnp.take(k_cache, owner, axis=2)
    v_sel = jnp.take(v_cache, owner, axis=2)
    if quant:
        # dequantize on read (in VMEM tiles on real TPU; see DESIGN §7b.5)
        ks_sel = jnp.take(k_scale, owner, axis=2)
        vs_sel = jnp.take(v_scale, owner, axis=2)
        k_sel = (k_sel.astype(jnp.float32)
                 * ks_sel[..., None]).astype(h.dtype)
        v_sel = (v_sel.astype(jnp.float32)
                 * vs_sel[..., None]).astype(h.dtype)

    out = decode_attention(
        qf, k_sel, v_sel, slot_positions=slot_pos, cur_pos=pos,
        combine_axis=ctx.tp_axis if seq_sharded else None,
        engine=ctx.engine)

    # mask padded heads, take local rows for the row-parallel o_proj
    if seq_sharded:
        head_idx = jnp.arange(hp)
        out = out * (head_idx < cfg.n_heads)[None, :, None].astype(out.dtype)
        out = jax.lax.dynamic_slice_in_dim(out, ctx.tp_rank() * hl, hl, 1)
    else:
        base = ctx.tp_rank() * hl
        head_idx = base + jnp.arange(hl)
        out = out * (head_idx < cfg.n_heads)[None, :, None].astype(out.dtype)
    out = out.reshape(bsz, 1, hl * hd)
    wo = ctx.gather_fsdp(params["wo"], dim=1)
    y = jnp.einsum("bsf,fd->bsd", out, wo.astype(out.dtype))
    if tp > 1:
        y = ctx.engine.allreduce(y, ctx.tp_axis)
    cache = dict(cache)
    cache[kname], cache[vname] = k_cache, v_cache
    if quant:
        cache["k_scale"], cache["v_scale"] = k_scale, v_scale
    return y, cache


def decode_step(params, caches, tokens, pos, cfg: ArchConfig, ctx: ParCtx,
                s_max: int):
    """One greedy decode step. tokens: (B, 1); pos: () int32.

    Returns (next_tokens (B,), new caches).
    """
    from repro.models import mlp as mlp_mod
    windows = window_per_layer(cfg, cfg.n_layers)  # python ints
    x = embed_tokens(params, tokens, cfg, ctx)
    new_caches = []
    for i in range(cfg.n_layers):
        lp = jax.tree.map(lambda a: a[i], params["layers"])
        cache = caches[i]
        h = rms_norm(x, lp["norm1"], cfg.norm_eps)
        new_cache = dict(cache)
        if cfg.family == "ssm":
            y, (conv, st) = ssm_mod.ssm_mixer(
                lp["ssm"], h, cfg, ctx, conv_state=cache["conv"],
                ssm_state=cache["state"], decode=True)
            new_cache["conv"], new_cache["state"] = conv, st
            x = x + y
            new_caches.append(new_cache)
            continue
        if cfg.family == "hybrid":
            a_out, c1 = attn_decode(lp, h, cache, cfg, ctx, pos,
                                    windows[i], s_max)
            s_out, (conv, st) = ssm_mod.ssm_mixer(
                lp["ssm"], h, cfg, ctx, conv_state=cache["conv"],
                ssm_state=cache["state"], decode=True)
            new_cache.update(c1)
            new_cache["conv"], new_cache["state"] = conv, st
            y = 0.5 * (rms_norm(a_out, lp["norm_attn_out"], cfg.norm_eps)
                       + rms_norm(s_out, lp["norm_ssm_out"], cfg.norm_eps))
            x = x + y
        else:
            y, new_cache = attn_decode(lp, h, cache, cfg, ctx, pos,
                                       windows[i], s_max)
            x = x + y
        if "xattn" in lp:
            hx = rms_norm(x, lp["norm_x"], cfg.norm_eps)
            y, new_cache = attn_decode(lp, hx, new_cache, cfg, ctx, pos,
                                       0, s_max, cross=True)
            x = x + y
        h2 = rms_norm(x, lp["norm2"], cfg.norm_eps)
        if cfg.family == "moe":
            y, _ = mlp_mod.moe_block(lp["moe"], h2, cfg, ctx,
                                     ctx.pcfg.moe_capacity_factor,
                                     dropless=True)
        else:
            y = mlp_mod.mlp_block(lp["mlp"], h2, cfg, ctx)
        x = x + y
        new_caches.append(new_cache)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    nxt = lm_head_sample(params, x[:, 0], cfg, ctx)
    return nxt, new_caches


# --------------------------------------------------------------------------
# Prefill
# --------------------------------------------------------------------------

def prefill(params, batch, cfg: ArchConfig, ctx: ParCtx,
            collect_cache: bool = True):
    """Forward over the prompt; emit next token + caches.

    Caches come back layer-stacked in uniform full-sequence layout
    (scan-friendly; SWA layers included at full length); runtime/serve
    converts to per-layer decode layouts on handoff.
    """
    x, enc_out = _input_stream(params, batch, cfg, ctx)
    s = x.shape[1]
    positions = jnp.arange(s)
    x, _, caches = stack_forward(params["layers"], x, cfg, ctx, positions,
                                 causal=True, enc_out=enc_out,
                                 collect_cache=collect_cache)
    x = ctx.sp_allgather_seq(x)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    nxt = lm_head_sample(params, x[:, -1], cfg, ctx)
    return nxt, caches
