"""Distributed DLRM inference — the paper's §6 use case, TPU-native.

Paper design (Fig. 15): embedding tables distributed over nodes 1-4,
FC1 checkerboard-decomposed over 8 nodes, FC2/FC3 pipelined on nodes 9/10,
all communication through ACCL+ streaming collectives.

TPU mapping over the (data, model) mesh:
  * tables shard over 'model' (the HBM-capacity argument is identical:
    50 GB of embeddings > 16 GB HBM/chip) — each rank holds a table slice
    and serves lookups for its rows (vocab-parallel gather + psum, exactly
    the embedding-node -> compute-node transmission of partial vectors);
  * FC1 is checkerboard (row+column) decomposed: columns over 'model'
    (each rank consumes its slice of the concat vector — the row partition)
    and the partial products reduce through the engine (the paper's
    "reduce slave" nodes) — matmul_reduce_scatter = FC1 + reduction fused;
  * FC2/FC3 column-parallel, batch streams over 'data' (the pipeline axis
    of nodes 9/10 becomes pure data parallelism — on a TPU mesh the
    all-reduce fabric replaces the point-to-point pipeline).

Requests are batched along 'data'; the Pallas embedding_gather kernel
serves the per-rank lookups when use_pallas is on.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.dlrm import DLRMConfig
from repro.models.common import Builder
from repro.parallel.ops import ParCtx


def dlrm_params(b: Builder, cfg: DLRMConfig, tp: int):
    """Tables stacked (T, rows, dim) sharded over model on rows."""
    rows = ((cfg.rows_per_table + tp - 1) // tp) * tp
    concat = cfg.n_tables * cfg.emb_dim
    p = {
        "tables": b.param((cfg.n_tables, rows, cfg.emb_dim),
                          P(None, "model", None), scale=0.01),
        "fc": [],
    }
    dims = (concat,) + tuple(cfg.fc_dims) + (cfg.out_dim,)
    fcs = []
    last = len(dims) - 2
    for i in range(len(dims) - 1):
        # FC1 checkerboard: in-dim over model (row partition of the concat
        # vector); middle FCs column-parallel; the tiny head replicates.
        if i == 0:
            spec = P("model", None)
        elif i < last:
            spec = P(None, "model")
        else:
            spec = P(None, None)
        fcs.append({
            "w": b.param((dims[i], dims[i + 1]), spec),
            "b": b.param((dims[i + 1],), P(None), init="zeros"),
        })
    p["fc"] = fcs
    return p


def dlrm_specs(cfg: DLRMConfig, tp: int):
    return dlrm_params(Builder("spec"), cfg, tp)


def embedding_lookup(tables, indices, ctx: ParCtx, use_pallas: bool = False):
    """tables: (T, rows_local, dim) local slice over 'model'; indices:
    (B, T) global row ids. Returns (B, T*dim) concat vector, replicated.

    Each rank serves the rows it owns (partial vectors), then one engine
    allreduce assembles the concat vector — the paper's partial-embedding
    transmission from memory nodes to compute nodes.
    """
    t, rows_l, dim = tables.shape
    tp = ctx.tp
    lo = ctx.tp_rank() * rows_l
    local = indices.T - lo                       # (T, B)
    hit = (local >= 0) & (local < rows_l)
    safe = jnp.clip(local, 0, rows_l - 1)
    if use_pallas:
        from repro.kernels import ops as kops
        rows = jnp.stack([
            kops.embedding_gather(tables[i], safe[i]) for i in range(t)])
    else:
        rows = jax.vmap(lambda tab, ix: jnp.take(tab, ix, axis=0))(
            tables, safe)                         # (T, B, dim)
    rows = jnp.where(hit[..., None], rows, 0.0)
    vec = jnp.moveaxis(rows, 0, 1).reshape(indices.shape[0], t * dim)
    if tp > 1:
        vec = ctx.engine.allreduce(vec, ctx.tp_axis)
    return vec


def dlrm_forward(params, indices, ctx: ParCtx, use_pallas: bool = False):
    """indices: (B_local, T) -> (B_local, out_dim) click-through logits."""
    vec = embedding_lookup(params["tables"], indices, ctx, use_pallas)
    tp = ctx.tp
    x = vec
    for i, fc in enumerate(params["fc"]):
        w, bias = fc["w"], fc["b"]
        if i == 0 and tp > 1:
            # checkerboard FC1: row-partitioned input slice x column slice
            in_l = w.shape[0]
            x_slice = jax.lax.dynamic_slice_in_dim(
                x, ctx.tp_rank() * in_l, in_l, 1)
            if ctx.pcfg.collective_matmul:
                y = ctx.engine.matmul_reduce_scatter(x_slice, w, ctx.tp_axis)
                y = ctx.engine.allgather(y, ctx.tp_axis).reshape(
                    x.shape[0], -1)
            else:
                y = jnp.einsum("bi,io->bo", x_slice, w)
                y = ctx.engine.allreduce(y, ctx.tp_axis)
        else:
            y = jnp.einsum("bi,io->bo", x, w)
            if tp > 1 and 0 < i < len(params["fc"]) - 1:
                # column-parallel: out-dim sharded; gather for next layer
                y = ctx.engine.allgather(
                    y.T, ctx.tp_axis).reshape(-1, x.shape[0]).T
        y = y + bias
        x = jax.nn.relu(y) if i < len(params["fc"]) - 1 else y
    return x


def dlrm_reference(params_full, indices):
    """Single-device oracle on gathered params (tests)."""
    t = params_full["tables"].shape[0]
    rows = jnp.stack([params_full["tables"][i][indices[:, i]]
                      for i in range(t)])
    x = jnp.moveaxis(rows, 0, 1).reshape(indices.shape[0], -1)
    n = len(params_full["fc"])
    for i, fc in enumerate(params_full["fc"]):
        x = x @ fc["w"] + fc["b"]
        if i < n - 1:
            x = jax.nn.relu(x)
    return x
