"""Dense SwiGLU MLP and MoE layer with engine all-to-all dispatch.

MoE expert parallelism rides the TP axis. When n_experts < ep ranks, each
expert is split into f = ep/n_experts *pseudo-experts* along d_ff — exact
for SwiGLU because silu/mul act elementwise per hidden unit and the w2
partial products sum linearly (checkerboard decomposition of the expert FFN,
the same trick the paper uses for DLRM FC1).

Dispatch is sort-based with a capacity limit (tokens beyond capacity drop,
standard Switch-style), then one engine all-to-all over the EP axis each
way — the collective the paper's linear/Bruck schedules serve.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models.common import Builder, silu
from repro.parallel.ops import ParCtx


def mlp_params(b: Builder, cfg: ArchConfig):
    d, f = cfg.d_model, cfg.d_ff
    return {
        "w1": b.param((d, f), P("data", "model")),
        "w3": b.param((d, f), P("data", "model")),
        "w2": b.param((f, d), P("model", "data")),
    }


def mlp_block(params, x, cfg: ArchConfig, ctx: ParCtx):
    # fused gate/up projection: one sequence gather / collective matmul
    w1 = ctx.gather_fsdp(params["w1"])
    w3 = ctx.gather_fsdp(params["w3"])
    w13 = jnp.concatenate([w1, w3], axis=1)
    h13 = ctx.col_parallel_matmul(x, w13, pregathered=True)
    f = w1.shape[1]
    h = silu(h13[..., :f]) * h13[..., f:]
    w2 = ctx.gather_fsdp(params["w2"], dim=1)
    y = jnp.einsum("bsf,fd->bsd", h, w2.astype(h.dtype))
    return ctx.row_parallel_finish(y)


# --------------------------------------------------------------------------
# MoE
# --------------------------------------------------------------------------

def moe_factor(cfg: ArchConfig, ep: int) -> int:
    """Pseudo-expert split factor f (Mixtral on 16 ranks: f=2)."""
    if cfg.n_experts >= ep:
        if cfg.n_experts % ep:
            raise ValueError(f"{cfg.n_experts} experts on {ep} ranks")
        return 1
    if ep % cfg.n_experts:
        raise ValueError(f"{cfg.n_experts} experts on {ep} ranks")
    return ep // cfg.n_experts


def moe_params(b: Builder, cfg: ArchConfig, ep: int):
    d, f_ff, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    fac = moe_factor(cfg, ep)
    e_eff, f_eff = e * fac, f_ff // fac
    return {
        "router": b.param((d, e), P("data", None)),
        "w1": b.param((e_eff, d, f_eff), P("model", "data", None)),
        "w3": b.param((e_eff, d, f_eff), P("model", "data", None)),
        "w2": b.param((e_eff, f_eff, d), P("model", None, "data")),
    }


def _dispatch_indices(expert_ids, n_experts: int, capacity: int):
    """Sort-based capacity dispatch (O(A log A), no dense matrices).

    expert_ids: (A,) int32 assignment slots. Returns slot_for_assignment
    (A,) int32 in [0, n_experts*capacity) or -1 if dropped.
    """
    a = expert_ids.shape[0]
    order = jnp.argsort(expert_ids, stable=True)
    sorted_e = expert_ids[order]
    # position within each expert group = idx - (running max of group-start idx)
    seg_start = jnp.concatenate(
        [jnp.zeros((1,), bool), sorted_e[1:] != sorted_e[:-1]])
    idx = jnp.arange(a)
    start_idx = jnp.where(seg_start, idx, 0)
    start_idx = jax.lax.associative_scan(jnp.maximum, start_idx)
    pos_in_group = idx - start_idx
    keep = pos_in_group < capacity
    slot_sorted = jnp.where(keep, sorted_e * capacity + pos_in_group, -1)
    inv = jnp.argsort(order)
    return slot_sorted[inv]


def moe_block(params, x, cfg: ArchConfig, ctx: ParCtx,
              capacity_factor: float = 1.25, dropless: bool = False):
    """x: (B, S, D) -> (B, S, D). EP all-to-all over the TP axis.

    Tokens are sequence-sharded across the EP group before dispatch so each
    token is routed exactly once (no TP-redundant expert compute); outputs
    are re-gathered unless SP already keeps the stream sharded. Falls back
    to replicated dispatch when S doesn't divide (tiny decode steps).
    """
    ep = ctx.tp
    fac = moe_factor(cfg, ep)
    e, k = cfg.n_experts, cfg.experts_per_token
    e_eff = e * fac
    b, s_in, d = x.shape
    token_sharded = ctx.pcfg.sequence_parallel
    regather = False
    if not token_sharded and ep > 1 and s_in % ep == 0:
        rank = ctx.tp_rank()
        sl = s_in // ep
        x = jax.lax.dynamic_slice_in_dim(x, rank * sl, sl, 1)
        token_sharded, regather = True, True
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)

    router = ctx.gather_fsdp(params["router"])
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate, top_e = jax.lax.top_k(probs, k)              # (t, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # pseudo-expert expansion: token -> f slots per routed expert
    top_pe = (top_e[..., None] * fac + jnp.arange(fac)).reshape(t, k * fac)
    gate_pe = jnp.repeat(gate, fac, axis=-1)           # same weight per half

    if dropless:
        # serving: 4x-expected headroom, capped at the true-dropless bound
        # (tiny token counts hit the cap and are exactly dropless; larger
        # decode batches keep the dispatch buffer - and the compiled
        # expert matmuls - proportional to the real load).
        expected = -(-t * k * fac // e_eff)  # ceil
        capacity = min(t * k * fac, max(1, expected * 4))
    else:
        capacity = int(max(1, round(t * k * capacity_factor / e)))
    # per-rank buffer (e_eff, capacity, d)
    slots = _dispatch_indices(top_pe.reshape(-1), e_eff, capacity)
    valid = slots >= 0
    buf = jnp.zeros((e_eff * capacity, d), x.dtype)
    buf = buf.at[jnp.where(valid, slots, e_eff * capacity - 1)].add(
        jnp.where(valid[:, None], jnp.repeat(xt, k * fac, axis=0), 0))

    # EP all-to-all: (e_eff*cap, d) -> rows grouped by destination rank
    recv = ctx.engine.alltoall(buf, ctx.tp_axis)       # (ep * el * cap, d)
    el = e_eff // ep
    recv = recv.reshape(ep, el, capacity, d)
    recv = jnp.moveaxis(recv, 1, 0).reshape(el, ep * capacity, d)

    w1 = ctx.gather_fsdp(params["w1"], 1)
    w3 = ctx.gather_fsdp(params["w3"], 1)
    w2 = ctx.gather_fsdp(params["w2"], 2)
    h = silu(jnp.einsum("ecd,edf->ecf", recv, w1.astype(recv.dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", recv, w3.astype(recv.dtype))
    out = jnp.einsum("ecf,efd->ecd", h, w2.astype(h.dtype))

    # reverse all-to-all
    out = jnp.moveaxis(out.reshape(el, ep, capacity, d), 0, 1)
    out = out.reshape(e_eff * capacity, d)
    back = ctx.engine.alltoall(out, ctx.tp_axis)       # (e_eff*cap, d)

    # combine: gather each assignment's slot, weight, sum over k*fac
    safe = jnp.where(valid, slots, 0)
    picked = back[safe] * valid[:, None]
    picked = picked.reshape(t, k * fac, d)
    y = jnp.einsum("tkd,tk->td", picked.astype(jnp.float32),
                   gate_pe.astype(jnp.float32))
    y = y.astype(x.dtype).reshape(b, s, d)
    if regather:  # non-SP callers expect the full sequence back
        flat = ctx.engine.allgather(jnp.moveaxis(y, 1, 0), ctx.tp_axis)
        y = jnp.moveaxis(
            flat.reshape(s_in, b, d), 1, 0)
        # note: token-shard compute is NOT replicated over TP, so this MoE
        # output leaves each rank identical only after the gather above.
    return y, probs
